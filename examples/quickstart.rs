//! Quickstart: load the trained LeNet (or a synthetic stand-in when no
//! artifacts are present), quantize it with QSQ, and compare accuracy / size
//! before and after — the 60-second tour of the library.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! cargo run --release --example quickstart      # artifact-free tour
//! ```

use anyhow::Result;

use qsq_edge::codec;
use qsq_edge::coordinator::deploy;
use qsq_edge::data::synth_store;
use qsq_edge::device::QualityConfig;
use qsq_edge::model::meta::ModelKind;
use qsq_edge::model::store::{artifacts_dir, Dataset, WeightStore};
use qsq_edge::quant::qsq::AssignMode;
use qsq_edge::repro;
use qsq_edge::runtime::client::Runtime;

fn main() -> Result<()> {
    let dir = artifacts_dir();
    println!("== qsq-edge quickstart ==\n");
    let trained = dir.join("manifest.json").exists();

    // 1. trained weights via the PJRT runtime when artifacts exist; a
    //    synthetic store otherwise (python is build-time only either way)
    let store = if trained {
        WeightStore::load(&dir, ModelKind::Lenet)?
    } else {
        println!("(no artifacts/ — synthetic weights; accuracy numbers skipped)");
        synth_store(1, ModelKind::Lenet)
    };

    // 2. accuracy before/after quantization (needs the trained artifacts)
    if trained {
        let mut rt = Runtime::new(&dir)?;
        println!("PJRT platform: {}", rt.platform());
        let test = Dataset::load(&dir, "mnist", "test")?;
        let base = repro::eval_store(&mut rt, &store, &test, 1024)?;
        println!("LeNet fp32 accuracy      : {:.2}%", 100.0 * base);
        for phi in [1u32, 2, 4] {
            let names = repro::quantized_names(ModelKind::Lenet);
            let q = repro::quantized_store(&store, &names, phi, 16, AssignMode::SigmaSearch)?;
            let acc = repro::eval_store(&mut rt, &q, &test, 1024)?;
            println!("quantized phi={phi} accuracy  : {:.2}%", 100.0 * acc);
        }
    }

    // 3. Quality Scalable Quantization at every phi: what actually ships
    for phi in [1u32, 2, 4] {
        let encoded = deploy::encode_store(
            &store,
            QualityConfig { phi, group: 16 },
            AssignMode::SigmaSearch,
        )?;
        let bytes = codec::encode_model(&encoded)?;
        println!(
            "container phi={phi}: {:>6} bytes on the wire ({} tensors, {:.2}% savings vs fp32)",
            bytes.len(),
            encoded.tensors.len(),
            100.0 * (1.0 - encoded.encoded_bits() as f64 / encoded.full_precision_bits() as f64)
        );
    }
    println!("\nnext: `cargo run --release --example edge_deployment` for the full story");
    Ok(())
}
