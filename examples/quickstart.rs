//! Quickstart: load the trained LeNet, quantize it with QSQ, and compare
//! accuracy / size before and after — the 60-second tour of the library.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::Result;

use qsq_edge::codec;
use qsq_edge::coordinator::deploy;
use qsq_edge::device::QualityConfig;
use qsq_edge::model::meta::ModelKind;
use qsq_edge::model::store::{artifacts_dir, Dataset, WeightStore};
use qsq_edge::quant::qsq::AssignMode;
use qsq_edge::repro;
use qsq_edge::runtime::client::Runtime;

fn main() -> Result<()> {
    let dir = artifacts_dir();
    println!("== qsq-edge quickstart ==\n");

    // 1. the PJRT runtime over the AOT artifacts (python is build-time only)
    let mut rt = Runtime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());

    // 2. trained weights + held-out eval set
    let store = WeightStore::load(&dir, ModelKind::Lenet)?;
    let test = Dataset::load(&dir, "mnist", "test")?;
    let base = repro::eval_store(&mut rt, &store, &test, 1024)?;
    println!("LeNet fp32 accuracy      : {:.2}%", 100.0 * base);

    // 3. Quality Scalable Quantization at the paper's operating point
    for phi in [1u32, 2, 4] {
        let names = repro::quantized_names(ModelKind::Lenet);
        let q = repro::quantized_store(&store, &names, phi, 16, AssignMode::SigmaSearch)?;
        let acc = repro::eval_store(&mut rt, &q, &test, 1024)?;
        println!("quantized phi={phi} accuracy  : {:.2}%", 100.0 * acc);
    }

    // 4. what actually ships: the QSQ container
    let encoded = deploy::encode_store(
        &store,
        QualityConfig { phi: 4, group: 16 },
        AssignMode::SigmaSearch,
    )?;
    let bytes = codec::encode_model(&encoded)?;
    println!(
        "\ncontainer: {} bytes on the wire ({} bits encoded vs {} bits fp32 = {:.2}% savings)",
        bytes.len(),
        encoded.encoded_bits(),
        encoded.full_precision_bits(),
        100.0 * (1.0 - encoded.encoded_bits() as f64 / encoded.full_precision_bits() as f64)
    );
    println!("\nnext: `cargo run --release --example edge_deployment` for the full story");
    Ok(())
}
