//! Serving demo: start the TCP inference server, hammer it with concurrent
//! synthetic clients, and print the batching/latency behaviour.
//!
//! ```bash
//! cargo run --release --example serve_and_query [-- --clients 8 --n 100]
//! ```

use std::time::{Duration, Instant};

use anyhow::Result;

use qsq_edge::coordinator::server::{Client, Server, ServerConfig};
use qsq_edge::data::RequestGen;
use qsq_edge::model::meta::ModelKind;
use qsq_edge::model::store::artifacts_dir;
use qsq_edge::util::cli::Args;
use qsq_edge::util::stats;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let clients = args.get_usize("clients", 8);
    let per_client = args.get_usize("n", 100);

    println!("starting server (LeNet, batch 32, 5 ms window)...");
    let srv = Server::start(
        artifacts_dir(),
        ServerConfig { max_delay: Duration::from_millis(5), ..Default::default() },
    )?;
    let port = srv.port;
    println!("server up on 127.0.0.1:{port}; {clients} clients x {per_client} requests\n");

    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|t| {
            std::thread::spawn(move || -> (Vec<f64>, Vec<f64>) {
                let mut c = Client::connect(&format!("127.0.0.1:{port}")).unwrap();
                let mut gen = RequestGen::new(ModelKind::Lenet, t as u64);
                let (mut lat, mut batches) = (Vec::new(), Vec::new());
                for i in 0..per_client {
                    let (img, _) = gen.next();
                    let reply = c.infer((t * 100_000 + i) as u64, img.data()).unwrap();
                    assert!(reply.get("error").is_null(), "{}", reply.to_json());
                    lat.push(reply.get("latency_us").as_f64().unwrap() / 1e3);
                    batches.push(reply.get("batch").as_f64().unwrap_or(1.0));
                }
                (lat, batches)
            })
        })
        .collect();

    let mut lat = Vec::new();
    let mut batch_sizes = Vec::new();
    for h in handles {
        let (l, b) = h.join().unwrap();
        lat.extend(l);
        batch_sizes.extend(b);
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = (clients * per_client) as f64;

    println!("throughput : {:.0} req/s ({:.2} s wall)", total / wall, wall);
    println!(
        "latency ms : p50 {:.2}  p95 {:.2}  p99 {:.2}  max {:.2}",
        stats::percentile(&lat, 50.0),
        stats::percentile(&lat, 95.0),
        stats::percentile(&lat, 99.0),
        lat.iter().cloned().fold(0.0, f64::max)
    );
    println!(
        "batching   : mean batch {:.1} (server: {} batches / {} requests)",
        stats::mean(&batch_sizes),
        srv.metrics.counter("batches"),
        srv.metrics.counter("requests")
    );
    if let Some((mean, p50, p95, _, _)) = srv.metrics.latency_summary("infer_batch") {
        println!(
            "PJRT infer : mean {:.2} ms  p50 {:.2} ms  p95 {:.2} ms per batch",
            mean * 1e3,
            p50 * 1e3,
            p95 * 1e3
        );
    }
    println!("\nmetrics snapshot:\n{}", srv.metrics.snapshot().to_json());
    srv.stop();
    Ok(())
}
