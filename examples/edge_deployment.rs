//! END-TO-END DRIVER (DESIGN.md deliverable (b) / system-prompt validation):
//! the full edge story on a real small workload, proving all layers compose.
//!
//! 1. trained LeNet weights (L2/L1 artifacts from `make artifacts`; a
//!    synthetic store stands in when artifacts are absent, e.g. in CI),
//! 2. device-aware *joint* quality selection: the memory budget sizes the
//!    QSQ (phi, N) dial, the MACs-derived energy budget sizes the CSD digit
//!    dial (Fig. 3 + §V.B),
//! 3. quantize → QSQ container → noisy channel (ARQ) → bit-level decode →
//!    the truncated-CSD serving engine stacked on the edge store,
//! 4. batched inference serving on the PJRT runtime with latency/throughput,
//! 5. on-device FC fine-tune (Table III protocol) and re-evaluation,
//! 6. energy/memory report (Figs. 1/2/9/10 machinery).
//!
//! Stages 4–5 need the trained artifacts and are skipped without them.
//! Results of this run are recorded in EXPERIMENTS.md §End-to-end.

use std::time::Instant;

use anyhow::Result;

use qsq_edge::channel::LinkConfig;
use qsq_edge::coordinator::server::{Client, Server, ServerConfig};
use qsq_edge::coordinator::{deploy, finetune};
use qsq_edge::data::{synth_store, RequestGen};
use qsq_edge::device::DeviceProfile;
use qsq_edge::model::meta::ModelKind;
use qsq_edge::model::store::{artifacts_dir, Dataset, WeightStore};
use qsq_edge::quant::qsq::AssignMode;
use qsq_edge::repro;
use qsq_edge::runtime::client::Runtime;
use qsq_edge::runtime::engine::Engine;
use qsq_edge::tensor::Tensor;
use qsq_edge::util::stats;

fn main() -> Result<()> {
    let dir = artifacts_dir();
    println!("== edge deployment: train-side -> channel -> edge device ==\n");
    let trained = dir.join("manifest.json").exists();
    let store = if trained {
        WeightStore::load(&dir, ModelKind::Lenet)?
    } else {
        println!("(no artifacts/ — synthetic weights; accuracy/serving stages skipped)\n");
        synth_store(7, ModelKind::Lenet)
    };

    // -- stages 1+2: the device profile alone drives the deployment ----------
    // deploy_for_device_with_link is the production path: the profile's
    // memory budget sizes (phi, N), its MACs-derived energy budget sizes
    // the CSD digit dial, the container crosses the (noise-injected) link,
    // and the CSD engine stacks the digit dial on the post-channel edge
    // store — one pipeline pass, nothing quantized or transmitted twice
    let device = DeviceProfile::roster()
        .into_iter()
        .find(|d| d.name == "edge-fpga-small")
        .unwrap();
    let link = LinkConfig { ber: 1e-5, ..device.link };
    let (edge_store, engine, rep) =
        deploy::deploy_for_device_with_link(&store, &device, AssignMode::SigmaSearch, link, 7)?;
    let quality = rep.quality;
    let csd = rep.csd.expect("csd deployment records the digit dial");
    println!(
        "[1] device {} (budget {} KB, {:.0} MMAC/s) -> phi={}, N={} + csd digits={}",
        device.name,
        device.model_budget_bytes / 1024,
        device.macs_per_s / 1e6,
        quality.phi,
        quality.group,
        csd.max_digits,
    );
    println!(
        "[2] shipped {} bytes over {:.1} Mbps (ber 1e-5): {:.3} s, {} retransmissions",
        rep.container_bytes,
        link.bandwidth_bps / 1e6,
        rep.transfer.elapsed_s,
        rep.transfer.retransmissions
    );
    println!(
        "    memory savings {:.2}%, zeros {:.2}%, decoder ops {} (exp-add) / {} (sign-flip)",
        100.0 * rep.memory_savings(),
        100.0 * rep.zeros_fraction,
        rep.decoder_ops.exponent_adds,
        rep.decoder_ops.sign_flips
    );

    // -- stage 3: the stacked-dial engine the device serves with -------------
    engine.forward(&Tensor::zeros(vec![1, 28, 28, 1]))?;
    let report = (&engine as &dyn Engine).report();
    println!(
        "[3] csd engine ({}): {:.2} pp/MAC at digits={}, {:.1}% MACs gated, \
         {:.1} nJ compute/request",
        report.name,
        report.mean_pp,
        csd.max_digits,
        100.0 * report.skipped_fraction,
        report.ledger.compute_pj() / 1e3
    );

    if !trained {
        println!("\n(stages 4-6 need trained artifacts: run `make artifacts`)");
        return Ok(());
    }

    let mut rt = Runtime::new(&dir)?;
    let train = Dataset::load(&dir, "mnist", "train")?;
    let test = Dataset::load(&dir, "mnist", "test")?;

    // -- stage 4: accuracy at the edge ---------------------------------------
    let base = repro::eval_store(&mut rt, &store, &test, usize::MAX)?;
    let edge_acc = repro::eval_store(&mut rt, &edge_store, &test, usize::MAX)?;
    println!("[4] accuracy: fp32 {:.2}% -> edge {:.2}%", 100.0 * base, 100.0 * edge_acc);

    // -- stage 5: batched serving on the PJRT runtime ------------------------
    let srv = Server::start(dir.clone(), ServerConfig::default())?;
    let port = srv.port;
    let n_clients = 4usize;
    let per_client = 64usize;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_clients)
        .map(|t| {
            std::thread::spawn(move || -> Vec<f64> {
                let mut c = Client::connect(&format!("127.0.0.1:{port}")).unwrap();
                let mut gen = RequestGen::new(ModelKind::Lenet, t as u64);
                let mut lat = Vec::new();
                for i in 0..per_client {
                    let (img, _) = gen.next();
                    let reply = c.infer((t * 1000 + i) as u64, img.data()).unwrap();
                    lat.push(reply.get("latency_us").as_f64().unwrap_or(0.0) / 1000.0);
                }
                lat
            })
        })
        .collect();
    let mut lat_ms = Vec::new();
    for h in handles {
        lat_ms.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = (n_clients * per_client) as f64;
    println!(
        "[5] served {} requests from {} clients in {:.2} s: {:.0} req/s, latency ms p50={:.2} p95={:.2}",
        total as u64,
        n_clients,
        wall,
        total / wall,
        stats::percentile(&lat_ms, 50.0),
        stats::percentile(&lat_ms, 95.0),
    );
    let batches = srv.metrics.counter("batches");
    println!(
        "    dynamic batching: {} batches for {} requests (mean {:.1} req/batch)",
        batches,
        srv.metrics.counter("requests"),
        total / batches.max(1) as f64
    );
    srv.stop();

    // -- stage 6: on-device FC fine-tune (Table III protocol) ----------------
    let (w, b, ft) = finetune::finetune_fc(&mut rt, &edge_store, &train, &test, 5, 0.05, 0)?;
    let mut tuned = edge_store.clone();
    tuned.set("f3w", w)?;
    tuned.set("f3b", b)?;
    let tuned_acc = repro::eval_store(&mut rt, &tuned, &test, usize::MAX)?;
    println!(
        "[6] on-device FC fine-tune (5 epochs): {:.2}% -> {:.2}% (losses {:?})",
        100.0 * ft.acc_before,
        100.0 * tuned_acc,
        ft.losses.iter().map(|l| (l * 1000.0).round() / 1000.0).collect::<Vec<_>>()
    );

    // -- summary --------------------------------------------------------------
    println!("\n== summary (paper Table III shape) ==");
    println!("  fp32 baseline            : {:.2}%", 100.0 * base);
    println!("  quantized, no retrain    : {:.2}%", 100.0 * edge_acc);
    println!("  + FC fine-tune (edge)    : {:.2}%", 100.0 * tuned_acc);
    println!("  model size on the wire   : {:.2}% smaller", 100.0 * rep.memory_savings());
    Ok(())
}
