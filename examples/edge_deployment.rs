//! END-TO-END DRIVER (DESIGN.md deliverable (b) / system-prompt validation):
//! the full edge story on a real small workload, proving all layers compose.
//!
//! 1. trained LeNet weights (L2/L1 artifacts from `make artifacts`),
//! 2. device-aware quality selection (Fig. 3),
//! 3. quantize → QSQ container → noisy channel (ARQ) → bit-level decode,
//! 4. batched inference serving on the PJRT runtime with latency/throughput,
//! 5. on-device FC fine-tune (Table III protocol) and re-evaluation,
//! 6. energy/memory report (Figs. 1/2/9/10 machinery).
//!
//! Results of this run are recorded in EXPERIMENTS.md §End-to-end.

use std::time::Instant;

use anyhow::Result;

use qsq_edge::channel::LinkConfig;
use qsq_edge::coordinator::server::{Client, Server, ServerConfig};
use qsq_edge::coordinator::{deploy, finetune};
use qsq_edge::data::RequestGen;
use qsq_edge::device::DeviceProfile;
use qsq_edge::model::bits;
use qsq_edge::model::meta::ModelKind;
use qsq_edge::model::store::{artifacts_dir, Dataset, WeightStore};
use qsq_edge::quant::qsq::AssignMode;
use qsq_edge::repro;
use qsq_edge::runtime::client::Runtime;
use qsq_edge::util::stats;

fn main() -> Result<()> {
    let dir = artifacts_dir();
    println!("== edge deployment: train-side -> channel -> edge device ==\n");
    let mut rt = Runtime::new(&dir)?;
    let store = WeightStore::load(&dir, ModelKind::Lenet)?;
    let train = Dataset::load(&dir, "mnist", "train")?;
    let test = Dataset::load(&dir, "mnist", "test")?;

    // -- stage 1: device selection ------------------------------------------
    let device = DeviceProfile::roster()
        .into_iter()
        .find(|d| d.name == "edge-fpga-small")
        .unwrap();
    let meta = store.meta.clone();
    let quality = device
        .select_quality(|phi, g| bits::model_bits(&meta, phi, g).encoded_bits)
        .expect("device fits LeNet");
    println!(
        "[1] device {} (budget {} KB) -> quality phi={}, N={}",
        device.name,
        device.model_budget_bytes / 1024,
        quality.phi,
        quality.group
    );

    // -- stage 2: encode + transmit over a noisy link ------------------------
    let link = LinkConfig { ber: 1e-5, ..device.link };
    let (edge_store, rep) = deploy::deploy(&store, quality, AssignMode::SigmaSearch, link, 7)?;
    println!(
        "[2] shipped {} bytes over {:.1} Mbps (ber 1e-5): {:.3} s, {} retransmissions",
        rep.container_bytes,
        link.bandwidth_bps / 1e6,
        rep.transfer.elapsed_s,
        rep.transfer.retransmissions
    );
    println!(
        "    memory savings {:.2}%, zeros {:.2}%, decoder ops {} (exp-add) / {} (sign-flip)",
        100.0 * rep.memory_savings(),
        100.0 * rep.zeros_fraction,
        rep.decoder_ops.exponent_adds,
        rep.decoder_ops.sign_flips
    );

    // -- stage 3: accuracy at the edge ---------------------------------------
    let base = repro::eval_store(&mut rt, &store, &test, usize::MAX)?;
    let edge_acc = repro::eval_store(&mut rt, &edge_store, &test, usize::MAX)?;
    println!("[3] accuracy: fp32 {:.2}% -> edge {:.2}%", 100.0 * base, 100.0 * edge_acc);

    // -- stage 4: batched serving on the PJRT runtime ------------------------
    let srv = Server::start(dir.clone(), ServerConfig::default())?;
    let port = srv.port;
    let n_clients = 4usize;
    let per_client = 64usize;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_clients)
        .map(|t| {
            std::thread::spawn(move || -> Vec<f64> {
                let mut c = Client::connect(&format!("127.0.0.1:{port}")).unwrap();
                let mut gen = RequestGen::new(ModelKind::Lenet, t as u64);
                let mut lat = Vec::new();
                for i in 0..per_client {
                    let (img, _) = gen.next();
                    let reply = c.infer((t * 1000 + i) as u64, img.data()).unwrap();
                    lat.push(reply.get("latency_us").as_f64().unwrap_or(0.0) / 1000.0);
                }
                lat
            })
        })
        .collect();
    let mut lat_ms = Vec::new();
    for h in handles {
        lat_ms.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = (n_clients * per_client) as f64;
    println!(
        "[4] served {} requests from {} clients in {:.2} s: {:.0} req/s, latency ms p50={:.2} p95={:.2}",
        total as u64,
        n_clients,
        wall,
        total / wall,
        stats::percentile(&lat_ms, 50.0),
        stats::percentile(&lat_ms, 95.0),
    );
    let batches = srv.metrics.counter("batches");
    println!(
        "    dynamic batching: {} batches for {} requests (mean {:.1} req/batch)",
        batches,
        srv.metrics.counter("requests"),
        total / batches.max(1) as f64
    );
    srv.stop();

    // -- stage 5: on-device FC fine-tune (Table III protocol) ----------------
    let (w, b, ft) = finetune::finetune_fc(&mut rt, &edge_store, &train, &test, 5, 0.05, 0)?;
    let mut tuned = edge_store.clone();
    tuned.set("f3w", w)?;
    tuned.set("f3b", b)?;
    let tuned_acc = repro::eval_store(&mut rt, &tuned, &test, usize::MAX)?;
    println!(
        "[5] on-device FC fine-tune (5 epochs): {:.2}% -> {:.2}% (losses {:?})",
        100.0 * ft.acc_before,
        100.0 * tuned_acc,
        ft.losses.iter().map(|l| (l * 1000.0).round() / 1000.0).collect::<Vec<_>>()
    );

    // -- stage 6: the paper's summary ----------------------------------------
    println!("\n== summary (paper Table III shape) ==");
    println!("  fp32 baseline            : {:.2}%", 100.0 * base);
    println!("  quantized, no retrain    : {:.2}%", 100.0 * edge_acc);
    println!("  + FC fine-tune (edge)    : {:.2}%", 100.0 * tuned_acc);
    println!("  model size on the wire   : {:.2}% smaller", 100.0 * rep.memory_savings());
    Ok(())
}
