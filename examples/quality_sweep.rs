//! Design-space exploration (Fig.-10 style): sweep quality level phi and
//! vector length N over both models; print (memory savings, energy
//! efficiency, accuracy) per point plus the QSM multiplier trade-off.
//!
//! ```bash
//! cargo run --release --example quality_sweep [-- --fast]
//! ```

use anyhow::Result;

use qsq_edge::hw::energy;
use qsq_edge::hw::fixedpoint::Format;
use qsq_edge::hw::multiplier::{dot, QsmConfig};
use qsq_edge::model::bits;
use qsq_edge::model::meta::{ModelKind, ModelMeta};
use qsq_edge::model::store::{artifacts_dir, Dataset, WeightStore};
use qsq_edge::quant::qsq::AssignMode;
use qsq_edge::repro;
use qsq_edge::runtime::client::Runtime;
use qsq_edge::util::rng::Rng;

fn main() -> Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let limit = if fast { 512 } else { 2048 };
    let dir = artifacts_dir();
    let mut rt = Runtime::new(&dir)?;

    for kind in [ModelKind::Lenet, ModelKind::Convnet] {
        let store = WeightStore::load(&dir, kind)?;
        let test = Dataset::load(&dir, kind.dataset(), "test")?;
        let meta = ModelMeta::of(kind);
        let names = repro::quantized_names(kind);
        let base = repro::eval_store(&mut rt, &store, &test, limit)?;
        println!("\n== {} (fp32 {:.2}%) ==", kind.name(), 100.0 * base);
        println!(
            "{:<5} {:<4} {:>10} {:>12} {:>10} {:>12}",
            "phi", "N", "savings", "energy eff", "accuracy", "acc (opt-a)"
        );
        let ns: &[usize] = if fast { &[8, 32] } else { &[4, 8, 16, 32, 64] };
        for &phi in &[1u32, 4] {
            for &n in ns {
                let b = bits::quantized_only_bits(&meta, phi, n);
                let eff = energy::energy_efficiency(b.full_bits, b.encoded_bits);
                let qs = repro::quantized_store(&store, &names, phi, n, AssignMode::SigmaSearch)?;
                let acc = repro::eval_store(&mut rt, &qs, &test, limit)?;
                let qo = repro::quantized_store(&store, &names, phi, n, AssignMode::NearestOpt)?;
                let acc_o = repro::eval_store(&mut rt, &qo, &test, limit)?;
                println!(
                    "{:<5} {:<4} {:>9.2}% {:>11.2}% {:>9.2}% {:>11.2}%",
                    phi,
                    n,
                    100.0 * b.savings(),
                    100.0 * eff,
                    100.0 * acc,
                    100.0 * acc_o
                );
            }
        }
    }

    // QSM multiplier micro design space: partial products vs error
    println!("\n== quality scalable multiplier (Q32.24, 4096 random MACs) ==");
    println!("{:<10} {:>12} {:>14} {:>12}", "digits", "mean PPs", "energy pJ/mul", "rms err");
    let mut r = Rng::new(1);
    let xs: Vec<f64> = (0..4096).map(|_| r.normal()).collect();
    let ws: Vec<f64> = (0..4096).map(|_| r.normal() * 0.1).collect();
    for digits in [1usize, 2, 3, 4, 6, usize::MAX] {
        let cfg = QsmConfig::new(Format::Q32_24, digits);
        let (_, st) = dot(cfg, &xs, &ws);
        println!(
            "{:<10} {:>12.2} {:>14.3} {:>12.3e}",
            if digits == usize::MAX { "exact".into() } else { digits.to_string() },
            st.mean_pp(),
            st.energy_pj / st.multiplies as f64,
            st.rms_err()
        );
    }
    Ok(())
}
