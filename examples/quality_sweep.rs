//! Design-space exploration (Fig.-10 style): sweep quality level phi and
//! vector length N over both models; print (memory savings, energy
//! efficiency, accuracy) per point plus the QSM multiplier trade-off — the
//! CSD digit dial stacked on top of (phi, N), and the activation-bits dial
//! (f32 vs calibrated i16 fixed-point serving) as the third axis, i.e. the
//! full accuracy-vs-energy frontier all three quality knobs span.
//!
//! ```bash
//! cargo run --release --example quality_sweep [-- --fast]
//! ```
//!
//! The trained-model sweep needs `artifacts/`; without it that section is
//! skipped and the synthetic-store CSD frontier still runs.

use anyhow::Result;

use qsq_edge::device::CsdQuality;
use qsq_edge::hw::energy;
use qsq_edge::hw::fixedpoint::Format;
use qsq_edge::hw::multiplier::{dot, QsmConfig};
use qsq_edge::model::bits;
use qsq_edge::model::meta::{ModelKind, ModelMeta};
use qsq_edge::model::store::{artifacts_dir, Dataset, WeightStore};
use qsq_edge::quant::qsq::AssignMode;
use qsq_edge::repro;
use qsq_edge::runtime::client::Runtime;
use qsq_edge::runtime::host::{forward, CsdEngine};
use qsq_edge::tensor::{ops, Tensor};
use qsq_edge::util::rng::Rng;

fn main() -> Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    if let Err(e) = trained_sweep(fast) {
        println!("(trained-model sweep skipped: {e:#})");
    }
    qsm_micro_sweep();
    csd_dial_sweep(fast)?;
    act_dial_sweep(fast)?;
    Ok(())
}

/// The original Fig.-10 sweep on trained artifacts (PJRT evaluation).
fn trained_sweep(fast: bool) -> Result<()> {
    let limit = if fast { 512 } else { 2048 };
    let dir = artifacts_dir();
    let mut rt = Runtime::new(&dir)?;

    for kind in [ModelKind::Lenet, ModelKind::Convnet] {
        let store = WeightStore::load(&dir, kind)?;
        let test = Dataset::load(&dir, kind.dataset(), "test")?;
        let meta = ModelMeta::of(kind);
        let names = repro::quantized_names(kind);
        let base = repro::eval_store(&mut rt, &store, &test, limit)?;
        println!("\n== {} (fp32 {:.2}%) ==", kind.name(), 100.0 * base);
        println!(
            "{:<5} {:<4} {:>10} {:>12} {:>10} {:>12}",
            "phi", "N", "savings", "energy eff", "accuracy", "acc (opt-a)"
        );
        let ns: &[usize] = if fast { &[8, 32] } else { &[4, 8, 16, 32, 64] };
        for &phi in &[1u32, 4] {
            for &n in ns {
                let b = bits::quantized_only_bits(&meta, phi, n);
                let eff = energy::energy_efficiency(b.full_bits, b.encoded_bits);
                let qs = repro::quantized_store(&store, &names, phi, n, AssignMode::SigmaSearch)?;
                let acc = repro::eval_store(&mut rt, &qs, &test, limit)?;
                let qo = repro::quantized_store(&store, &names, phi, n, AssignMode::NearestOpt)?;
                let acc_o = repro::eval_store(&mut rt, &qo, &test, limit)?;
                println!(
                    "{:<5} {:<4} {:>9.2}% {:>11.2}% {:>9.2}% {:>11.2}%",
                    phi,
                    n,
                    100.0 * b.savings(),
                    100.0 * eff,
                    100.0 * acc,
                    100.0 * acc_o
                );
            }
        }
    }
    Ok(())
}

/// QSM multiplier micro design space: partial products vs error.
fn qsm_micro_sweep() {
    println!("\n== quality scalable multiplier (Q32.24, 4096 random MACs) ==");
    println!("{:<10} {:>12} {:>14} {:>12}", "digits", "mean PPs", "energy pJ/mul", "rms err");
    let mut r = Rng::new(1);
    let xs: Vec<f64> = (0..4096).map(|_| r.normal()).collect();
    let ws: Vec<f64> = (0..4096).map(|_| r.normal() * 0.1).collect();
    for digits in [1usize, 2, 3, 4, 6, usize::MAX] {
        let cfg = QsmConfig::new(Format::Q32_24, digits);
        let (_, st) = dot(cfg, &xs, &ws);
        println!(
            "{:<10} {:>12.2} {:>14.3} {:>12.3e}",
            if digits == usize::MAX { "exact".into() } else { digits.to_string() },
            st.mean_pp(),
            st.energy_pj / st.multiplies as f64,
            st.rms_err()
        );
    }
}

/// The CSD digit dial stacked on (phi, N): quantize + decode at the QSQ
/// point, serve through [`CsdEngine`] at each digit budget, and print the
/// accuracy-vs-energy frontier — argmax agreement with the fp32 forward as
/// the accuracy proxy (synthetic store, so no artifacts needed), partial
/// products per MAC and pJ/input from the engine's energy ledger as the
/// energy axis.
fn csd_dial_sweep(fast: bool) -> Result<()> {
    use qsq_edge::data::synth_store;

    let kind = ModelKind::Lenet;
    let store = synth_store(33, kind);
    let n = if fast { 32 } else { 128 };
    let mut r = Rng::new(7);
    let xdata: Vec<f32> = (0..n * 28 * 28).map(|_| r.f32()).collect();
    let x = Tensor::new(vec![n, 28, 28, 1], xdata)?;
    let base_pred = ops::argmax_rows(&forward(&store, &x)?);

    println!("\n== CSD digit dial x (phi, N) — accuracy-vs-energy frontier ==");
    println!("   (synthetic LeNet, {n} inputs; agreement vs the fp32 forward)");
    println!(
        "{:<5} {:<4} {:<8} {:>9} {:>9} {:>10} {:>12}",
        "phi", "N", "digits", "agree", "pp/MAC", "gated", "pJ/input"
    );
    let names = repro::quantized_names(kind);
    for &(phi, group) in &[(4u32, 16usize), (1, 16)] {
        // the QSQ dial first: quantize + decode at (phi, N)
        let qs = repro::quantized_store(&store, &names, phi, group, AssignMode::SigmaSearch)?;
        for &digits in &[1usize, 2, 3, 4, usize::MAX] {
            // ... then the CSD dial on the decoded weights
            let engine = CsdEngine::from_store(&qs, CsdQuality::new(digits))?;
            let pred = ops::argmax_rows(&engine.forward(&x)?);
            let agree = pred.iter().zip(&base_pred).filter(|(a, b)| a == b).count();
            let led = engine.ledger();
            println!(
                "{:<5} {:<4} {:<8} {:>8.1}% {:>9.2} {:>9.1}% {:>12.3e}",
                phi,
                group,
                if digits == usize::MAX { "exact".into() } else { digits.to_string() },
                100.0 * agree as f64 / n as f64,
                engine.mean_pp(),
                100.0 * engine.skipped_fraction(),
                // one forward served all n inputs: normalize to per input
                led.total_pj() / (engine.forwards().max(1) as usize * n) as f64
            );
        }
    }
    println!("   (fewer digits -> fewer partial products -> less pJ/input;");
    println!("    the dial is runtime-selectable via EngineSelect::HostCsd)");
    Ok(())
}

/// The activation-bits dial stacked on (phi, N) — the third frontier axis:
/// the same code-domain engine served with f32 activations (act 32) and
/// with the calibrated i16 fixed-point datapath (act 16, one calibration
/// pass on the input batch).  Agreement vs the fp32 forward is the
/// accuracy proxy; the ledger's integer adds vs fp32 multiplies show the
/// arithmetic the dial moves out of floating point.
fn act_dial_sweep(fast: bool) -> Result<()> {
    use qsq_edge::data::synth_store;
    use qsq_edge::device::QualityConfig;
    use qsq_edge::runtime::host::QuantizedEngine;

    let kind = ModelKind::Lenet;
    let store = synth_store(34, kind);
    let n = if fast { 32 } else { 128 };
    let mut r = Rng::new(8);
    let xdata: Vec<f32> = (0..n * 28 * 28).map(|_| r.f32()).collect();
    let x = Tensor::new(vec![n, 28, 28, 1], xdata)?;
    let base_pred = ops::argmax_rows(&forward(&store, &x)?);

    println!("\n== activation-bits dial x (phi, N) — the third frontier axis ==");
    println!("   (synthetic LeNet, {n} inputs; agreement vs the fp32 forward)");
    println!(
        "{:<5} {:<4} {:<5} {:>9} {:>12} {:>12} {:>12}",
        "phi", "N", "act", "agree", "int adds", "fp muls", "pJ/input"
    );
    for &(phi, group) in &[(4u32, 16usize), (1, 16)] {
        let quality = QualityConfig { phi, group };
        for act in [32u32, 16] {
            let mut engine =
                QuantizedEngine::quantize_store(&store, quality, AssignMode::SigmaSearch)?;
            if act == 16 {
                engine.calibrate(&x)?;
            }
            let pred = ops::argmax_rows(&engine.forward(&x)?);
            let agree = pred.iter().zip(&base_pred).filter(|(a, b)| a == b).count();
            let led = engine.ledger();
            println!(
                "{:<5} {:<4} {:<5} {:>8.1}% {:>12} {:>12} {:>12.3e}",
                phi,
                group,
                act,
                100.0 * agree as f64 / n as f64,
                led.int_adds,
                led.fp_muls,
                led.total_pj() / (engine.forwards().max(1) as usize * n) as f64
            );
        }
    }
    println!("   (act 16 runs the calibrated i16 SWAR plane sums with one");
    println!("    dequant-rescale per cell; act 32 keeps f32 activations —");
    println!("    DeviceProfile::select_act_bits picks the width per class)");
    Ok(())
}
