//! Quantizer benchmarks: the encode-side cost of every assignment mode on
//! real LeNet/ConvNet tensors (backs Figs. 7/8/10: each sweep point pays one
//! of these quantization calls).

use qsq_edge::bench::run_bench;
use qsq_edge::model::meta::ModelKind;
use qsq_edge::model::store::{artifacts_dir, WeightStore};
use qsq_edge::quant::qsq::{quantize, AssignMode};
use qsq_edge::util::prop::gen_weights;
use qsq_edge::util::rng::Rng;

fn main() {
    println!("== bench_quantizer ==");
    let dir = artifacts_dir();

    // synthetic tensor, all modes
    let mut r = Rng::new(0);
    let w = gen_weights(&mut r, 256 * 120, 0.1);
    for (mode, name) in [
        (AssignMode::Nearest, "nearest"),
        (AssignMode::NearestOpt, "nearest-opt"),
        (AssignMode::Sigma { gamma: 0.5, delta: 2.0 }, "sigma-fixed"),
        (AssignMode::SigmaSearch, "sigma-search (19x8 grid)"),
    ] {
        let res = run_bench(
            &format!("quantize f1w-sized [256,120] {name}"),
            2,
            if matches!(mode, AssignMode::SigmaSearch) { 5 } else { 20 },
            (256 * 120) as f64,
            || quantize(&w, &[256, 120], 16, 4, mode).unwrap(),
        );
        println!("{}", res.report());
    }

    // real model tensors end-to-end (whole-model encode, the deploy cost)
    for kind in [ModelKind::Lenet, ModelKind::Convnet] {
        if let Ok(store) = WeightStore::load(&dir, kind) {
            let tensors: Vec<_> = store
                .meta
                .quantized_tensors()
                .map(|t| (store.get(t.name).unwrap().clone(), t.shape.clone()))
                .collect();
            let total: usize = tensors.iter().map(|(t, _)| t.len()).sum();
            let res = run_bench(
                &format!("encode whole {} (sigma-search)", kind.name()),
                1,
                5,
                total as f64,
                || {
                    for (t, shape) in &tensors {
                        let g = qsq_edge::quant::vectorize::Grouping::nearest_divisor(shape, 16)
                            .unwrap();
                        quantize(t.data(), shape, g, 4, AssignMode::SigmaSearch).unwrap();
                    }
                },
            );
            println!("{}", res.report());
        }
    }
}
