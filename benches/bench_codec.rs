//! Codec + decoder benchmarks: bit packing, container round-trip, channel
//! framing, and the shift-and-scale decoder — the edge-side hot path of the
//! deployment pipeline (backs Table II / Fig. 9 machinery and §Perf L3).

use qsq_edge::bench::run_bench;
use qsq_edge::channel::{Link, LinkConfig};
use qsq_edge::codec::{decode_model, encode_model, pack, EncodedModel, EncodedTensor};
use qsq_edge::hw::decoder_rtl;
use qsq_edge::quant::qsq::{quantize, AssignMode};
use qsq_edge::util::prop::gen_weights;
use qsq_edge::util::rng::Rng;

fn main() {
    println!("== bench_codec ==");
    let mut r = Rng::new(0);
    let w = gen_weights(&mut r, 256 * 120, 0.1);
    let qt = quantize(&w, &[256, 120], 16, 4, AssignMode::Nearest).unwrap();
    let n = qt.codes.len();

    let res = run_bench("pack 3-bit codes [30720]", 3, 50, n as f64, || {
        pack::pack_codes(&qt.codes, 3).unwrap()
    });
    println!("{}", res.report());

    let packed = pack::pack_codes(&qt.codes, 3).unwrap();
    let res = run_bench("unpack 3-bit codes [30720]", 3, 50, n as f64, || {
        pack::unpack_codes(&packed, n, 3).unwrap()
    });
    println!("{}", res.report());

    let model = EncodedModel {
        tensors: vec![EncodedTensor { name: "f1w".into(), tensor: qt.clone() }],
    };
    let res = run_bench("container encode (1 tensor, 30720 codes)", 3, 50, n as f64, || {
        encode_model(&model).unwrap()
    });
    println!("{}", res.report());

    let bytes = encode_model(&model).unwrap();
    let res = run_bench("container decode + CRC verify", 3, 50, n as f64, || {
        decode_model(&bytes).unwrap()
    });
    println!("{}", res.report());

    let res = run_bench(
        "shift-and-scale decode_stream [30720 weights]",
        3,
        50,
        n as f64,
        || decoder_rtl::decode_stream(&qt.codes, &qt.scalars, qt.group, qt.oc),
    );
    println!("{}", res.report());

    // arithmetic decode for comparison (QuantizedTensor::decode)
    let res = run_bench("arithmetic decode [30720 weights]", 3, 50, n as f64, || qt.decode());
    println!("{}", res.report());

    // channel transfer of the whole container (clean + noisy)
    for (ber, label) in [(0.0, "clean"), (1e-5, "ber=1e-5")] {
        let cfg = LinkConfig { ber, ..Default::default() };
        let res = run_bench(
            &format!("link transmit {} bytes ({label})", bytes.len()),
            1,
            10,
            bytes.len() as f64,
            || Link::new(cfg, 7).transmit(&bytes).unwrap(),
        );
        println!("{}", res.report());
    }
}
