//! End-to-end serving benchmark, four parts:
//!
//! * **Per-policy dispatch** (no artifacts needed): the `Auto` engine
//!   roster over a synthetic store, timed per batch size under each
//!   `DispatchPolicy` (batch-fill / latency-floor / energy-budget), with
//!   the routed engine named in each entry.  Results are appended to
//!   `BENCH_kernels.json` (created if absent) so the dispatch trajectory
//!   rides the same cross-PR artifact and CI step summary as the kernels.
//! * **Overload sweep** (no artifacts needed): a live TCP server over a
//!   synthetic store with a deliberately tiny admission cap, hammered by an
//!   increasing closed-loop client count.  Each load level emits its shed
//!   rate and the tail (p99) latency of the requests that *were* served —
//!   the two numbers that show bounded admission doing its job: sheds rise
//!   with offered load while the served tail stays flat instead of growing
//!   with queue depth.  Also merged into `BENCH_kernels.json`.
//! * **Hot-swap latency** (no artifacts needed): a zero-downtime
//!   `deploy_store` against a live server under closed-loop traffic —
//!   transfer start → the first reply served by the new generation, and the
//!   p99 of requests served *during* the swap window (the zero-downtime
//!   claim as a number).  Also merged into `BENCH_kernels.json`.
//! * **TCP + dynamic batching + PJRT** (needs `make artifacts`): the
//!   system-level throughput/latency number the edge story rests on
//!   (§Perf L3), measured as a client sees it.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use qsq_edge::bench::{run_bench, BenchResult};
use qsq_edge::coordinator::server::{Client, Roster, Server, ServerConfig};
use qsq_edge::coordinator::swap::SwapConfig;
use qsq_edge::data::{synth_store, RequestGen};
use qsq_edge::kernels::Scratch;
use qsq_edge::model::meta::ModelKind;
use qsq_edge::model::store::artifacts_dir;
use qsq_edge::runtime::engine::PolicySelect;
use qsq_edge::tensor::Tensor;
use qsq_edge::util::json::{self, Value};
use qsq_edge::util::rng::Rng;
use qsq_edge::util::stats;

/// Time every (policy, batch-size) dispatch route of the Auto roster on a
/// synthetic LeNet store.  Entry names carry the routed engine, so the JSON
/// shows which engine each policy hands each batch size to.
fn policy_dispatch_entries() -> Vec<BenchResult> {
    println!("== per-policy roster dispatch (synthetic store, no artifacts) ==");
    let mut out = Vec::new();
    let mut r = Rng::new(5);
    for policy in [
        PolicySelect::BatchFill,
        PolicySelect::LatencyFloor,
        PolicySelect::EnergyBudget,
    ] {
        let cfg = ServerConfig { policy, ..Default::default() };
        let roster = Roster::build(None, synth_store(5, ModelKind::Lenet), &cfg).unwrap();
        let mut scratch = Scratch::new();
        for n in [1usize, 8, 32] {
            let xdata: Vec<f32> = (0..n * 28 * 28).map(|_| r.f32()).collect();
            let x = Tensor::new(vec![n, 28, 28, 1], xdata).unwrap();
            let engine = roster.engine_name(roster.route(n));
            let name = format!("dispatch {:<13} b={n:<2} -> {engine}", policy.name());
            let b = run_bench(&name, 2, 12, n as f64, || {
                roster.dispatch(&x, &mut scratch).unwrap()
            });
            println!("{}", b.report());
            out.push(b);
        }
    }
    out
}

/// Append `entries` to `BENCH_kernels.json`'s results array (keeping the
/// existing kernel entries when the kernel bench ran first in this
/// directory), creating the file when absent — one artifact, one step
/// summary, one cross-PR trajectory.
fn merge_into_bench_kernels(entries: &[BenchResult]) {
    const PATH: &str = "BENCH_kernels.json";
    let mut results: Vec<Value> = std::fs::read_to_string(PATH)
        .ok()
        .and_then(|text| json::parse(text.trim()).ok())
        .map(|doc| doc.get("results").as_arr().unwrap_or(&[]).to_vec())
        .unwrap_or_default();
    // re-runs replace their own entries instead of duplicating them
    results.retain(|v| {
        v.get("name")
            .as_str()
            .map(|n| {
                !n.starts_with("dispatch ")
                    && !n.starts_with("overload ")
                    && !n.starts_with("swap ")
            })
            .unwrap_or(true)
    });
    results.extend(entries.iter().map(|r| r.to_json()));
    let merged = json::obj(vec![
        ("bench", json::s("bench_kernels")),
        ("results", Value::Arr(results)),
    ]);
    std::fs::write(PATH, merged.to_json() + "\n").unwrap();
    println!("merged {} dispatch entries into {PATH}", entries.len());
}

/// A `BenchResult` carrying a measured scalar rather than a timing
/// distribution (the cross-PR trajectory file has one schema; scalar
/// entries put the value in every timing field and name what it is).
fn scalar_entry(name: &str, iters: usize, value_s: f64, items_per_iter: f64) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: value_s,
        median_s: value_s,
        p95_s: value_s,
        min_s: value_s,
        items_per_iter,
    }
}

/// Push a small-cap server past its admission limit and measure what the
/// fault-tolerance layer promises: sheds absorb the excess (shed rate) while
/// the served requests keep a bounded tail (p99), because queue wait is
/// capped by the queue depth rather than the offered load.
fn overload_sweep_entries() -> Vec<BenchResult> {
    println!("\n== overload sweep (synthetic store, queue-cap 4, batch 4) ==");
    println!(
        "{:<24} {:>8} {:>8} {:>11} {:>10}",
        "load", "served", "shed", "shed-rate", "p99 ms"
    );
    let mut out = Vec::new();
    for clients in [2usize, 8, 32] {
        let cfg = ServerConfig {
            batch: 4,
            queue_cap: 4,
            max_delay: Duration::from_millis(1),
            ..Default::default()
        };
        let srv =
            Server::start_with_store(synth_store(5, ModelKind::Lenet), cfg).unwrap();
        let port = srv.port;
        let per_client = 40usize;
        let handles: Vec<_> = (0..clients)
            .map(|t| {
                std::thread::spawn(move || -> (Vec<f64>, u64) {
                    let mut c = Client::connect(&format!("127.0.0.1:{port}")).unwrap();
                    let mut gen = RequestGen::new(ModelKind::Lenet, 900 + t as u64);
                    let mut served = Vec::new();
                    let mut shed = 0u64;
                    for i in 0..per_client {
                        let (img, _) = gen.next();
                        let t0 = Instant::now();
                        let r = c.infer((t * 100_000 + i) as u64, img.data()).unwrap();
                        if r.get("pred").as_f64().is_some() {
                            served.push(t0.elapsed().as_secs_f64());
                        } else {
                            shed += 1;
                        }
                    }
                    (served, shed)
                })
            })
            .collect();
        let mut served = Vec::new();
        let mut shed = 0u64;
        for h in handles {
            let (s, x) = h.join().unwrap();
            served.extend(s);
            shed += x;
        }
        srv.stop();
        let total = (clients * per_client) as u64;
        let shed_rate = shed as f64 / total as f64;
        let p99_s = if served.is_empty() { 0.0 } else { stats::percentile(&served, 99.0) };
        println!(
            "{:<24} {:>8} {:>8} {:>11.3} {:>10.2}",
            format!("{clients} closed-loop clients"),
            served.len(),
            shed,
            shed_rate,
            p99_s * 1e3
        );
        // shed rate rides items_per_iter (a dimensionless fraction); the
        // served-tail entry is a real latency in the timing fields
        out.push(scalar_entry(
            &format!("overload c={clients:<2} shed-rate"),
            total as usize,
            0.0,
            shed_rate,
        ));
        out.push(scalar_entry(
            &format!("overload c={clients:<2} served-p99"),
            served.len(),
            p99_s,
            0.0,
        ));
    }
    out
}

/// Hot-swap a live server under closed-loop traffic and measure the two
/// numbers the zero-downtime claim rests on: transfer start → the first
/// reply served by the new generation, and the p99 of requests served
/// *during* the swap window (a flat p99 means staging really happened off
/// the serving thread).
fn swap_latency_entries() -> Vec<BenchResult> {
    println!("\n== hot model swap (synthetic store, clean link) ==");
    let cfg = ServerConfig {
        batch: 4,
        max_delay: Duration::from_millis(1),
        ..Default::default()
    };
    let srv = Server::start_with_store(synth_store(5, ModelKind::Lenet), cfg).unwrap();
    let port = srv.port;

    // closed-loop traffic for the whole run; only latencies taken inside
    // the swap window feed the served-p99 entry
    let stop = Arc::new(AtomicBool::new(false));
    let window = Arc::new(AtomicBool::new(false));
    let handles: Vec<_> = (0..2u64)
        .map(|t| {
            let stop = stop.clone();
            let window = window.clone();
            std::thread::spawn(move || -> Vec<f64> {
                let mut c = Client::connect(&format!("127.0.0.1:{port}")).unwrap();
                let mut gen = RequestGen::new(ModelKind::Lenet, 700 + t);
                let mut lat = Vec::new();
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let (img, _) = gen.next();
                    let t0 = Instant::now();
                    let r = c.infer(t * 100_000 + i, img.data()).unwrap();
                    assert!(
                        r.get("pred").as_f64().is_some(),
                        "swap bench traffic must never drop: {}",
                        r.to_json()
                    );
                    if window.load(Ordering::Relaxed) {
                        lat.push(t0.elapsed().as_secs_f64());
                    }
                    i += 1;
                }
                lat
            })
        })
        .collect();

    std::thread::sleep(Duration::from_millis(30));
    window.store(true, Ordering::Relaxed);
    let t0 = Instant::now();
    let rep = srv
        .deploy_store(&synth_store(6, ModelKind::Lenet), &SwapConfig::default())
        .unwrap();
    // transfer start → the first reply the new generation serves
    let mut probe = Client::connect(&format!("127.0.0.1:{port}")).unwrap();
    let mut pg = RequestGen::new(ModelKind::Lenet, 800);
    let swap_latency_s = loop {
        let (img, _) = pg.next();
        let r = probe.infer(999_000, img.data()).unwrap();
        if r.get("gen").as_f64() == Some(rep.generation as f64) {
            break t0.elapsed().as_secs_f64();
        }
    };
    std::thread::sleep(Duration::from_millis(30));
    window.store(false, Ordering::Relaxed);
    stop.store(true, Ordering::Relaxed);
    let mut lat = Vec::new();
    for h in handles {
        lat.extend(h.join().unwrap());
    }
    srv.stop();

    let p99_s = if lat.is_empty() { 0.0 } else { stats::percentile(&lat, 99.0) };
    println!(
        "swap latency (transfer start -> new-gen first reply): {:.2} ms \
         ({} container bytes, {} frames)",
        swap_latency_s * 1e3,
        rep.container_bytes,
        rep.transfer.frames
    );
    println!(
        "served p99 during the swap window: {:.2} ms over {} requests",
        p99_s * 1e3,
        lat.len()
    );
    vec![
        scalar_entry("swap latency", 1, swap_latency_s, 0.0),
        scalar_entry("swap served-p99", lat.len(), p99_s, 0.0),
    ]
}

fn drive(clients: usize, per_client: usize, delay: Duration) -> Option<(f64, Vec<f64>)> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return None;
    }
    let srv = Server::start(dir, ServerConfig { max_delay: delay, ..Default::default() }).unwrap();
    let port = srv.port;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|t| {
            std::thread::spawn(move || -> Vec<f64> {
                let mut c = Client::connect(&format!("127.0.0.1:{port}")).unwrap();
                let mut gen = RequestGen::new(ModelKind::Lenet, t as u64);
                (0..per_client)
                    .map(|i| {
                        let (img, _) = gen.next();
                        let reply = c.infer((t * 100_000 + i) as u64, img.data()).unwrap();
                        reply.get("latency_us").as_f64().unwrap_or(0.0) / 1e3
                    })
                    .collect()
            })
        })
        .collect();
    let mut lat = Vec::new();
    for h in handles {
        lat.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    srv.stop();
    Some(((clients * per_client) as f64 / wall, lat))
}

fn main() {
    let mut entries = policy_dispatch_entries();
    entries.extend(overload_sweep_entries());
    entries.extend(swap_latency_entries());
    merge_into_bench_kernels(&entries);

    println!("\n== bench_serving_e2e (LeNet, batch-32 artifact) ==");
    println!(
        "{:<26} {:>12} {:>10} {:>10} {:>10}",
        "scenario", "req/s", "p50 ms", "p95 ms", "p99 ms"
    );
    for (clients, n, delay_ms) in [
        (1usize, 200usize, 5u64),
        (4, 100, 5),
        (8, 100, 5),
        (16, 50, 5),
        (8, 100, 1),
        (8, 100, 20),
    ] {
        match drive(clients, n, Duration::from_millis(delay_ms)) {
            Some((rps, lat)) => println!(
                "{:<26} {:>12.0} {:>10.2} {:>10.2} {:>10.2}",
                format!("{clients} clients, {delay_ms} ms win"),
                rps,
                stats::percentile(&lat, 50.0),
                stats::percentile(&lat, 95.0),
                stats::percentile(&lat, 99.0),
            ),
            None => {
                eprintln!("no artifacts; skipping the TCP/PJRT scenarios");
                return;
            }
        }
    }
}
