//! End-to-end serving benchmark: TCP + dynamic batching + PJRT, measured as
//! a client sees it.  This is the system-level throughput/latency number the
//! edge story rests on (§Perf L3).

use std::time::{Duration, Instant};

use qsq_edge::coordinator::server::{Client, Server, ServerConfig};
use qsq_edge::data::RequestGen;
use qsq_edge::model::meta::ModelKind;
use qsq_edge::model::store::artifacts_dir;
use qsq_edge::util::stats;

fn drive(clients: usize, per_client: usize, delay: Duration) -> Option<(f64, Vec<f64>)> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return None;
    }
    let srv = Server::start(dir, ServerConfig { max_delay: delay, ..Default::default() }).unwrap();
    let port = srv.port;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|t| {
            std::thread::spawn(move || -> Vec<f64> {
                let mut c = Client::connect(&format!("127.0.0.1:{port}")).unwrap();
                let mut gen = RequestGen::new(ModelKind::Lenet, t as u64);
                (0..per_client)
                    .map(|i| {
                        let (img, _) = gen.next();
                        let reply = c.infer((t * 100_000 + i) as u64, img.data()).unwrap();
                        reply.get("latency_us").as_f64().unwrap_or(0.0) / 1e3
                    })
                    .collect()
            })
        })
        .collect();
    let mut lat = Vec::new();
    for h in handles {
        lat.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    srv.stop();
    Some(((clients * per_client) as f64 / wall, lat))
}

fn main() {
    println!("== bench_serving_e2e (LeNet, batch-32 artifact) ==");
    println!(
        "{:<26} {:>12} {:>10} {:>10} {:>10}",
        "scenario", "req/s", "p50 ms", "p95 ms", "p99 ms"
    );
    for (clients, n, delay_ms) in [
        (1usize, 200usize, 5u64),
        (4, 100, 5),
        (8, 100, 5),
        (16, 50, 5),
        (8, 100, 1),
        (8, 100, 20),
    ] {
        match drive(clients, n, Duration::from_millis(delay_ms)) {
            Some((rps, lat)) => println!(
                "{:<26} {:>12.0} {:>10.2} {:>10.2} {:>10.2}",
                format!("{clients} clients, {delay_ms} ms win"),
                rps,
                stats::percentile(&lat, 50.0),
                stats::percentile(&lat, 95.0),
                stats::percentile(&lat, 99.0),
            ),
            None => {
                eprintln!("no artifacts; skipping");
                return;
            }
        }
    }
}
