//! Kernel benchmarks — the three hot paths this layer owns, each against its
//! naive oracle, at real LeNet/ConvNet layer shapes:
//!
//! * code-domain `qgemm` (packed codes, zero-skip, shift/add) vs
//!   decode-to-f32 + naive matmul — the old serving path;
//! * blocked/parallel f32 matmul vs the naive ikj loop;
//! * O(sort) sigma-search quantization vs the naive 19x8 grid (152 full
//!   assignment passes).
//!
//! Emits `BENCH_kernels.json` (name/median/p95/throughput per entry) so the
//! perf trajectory is tracked across PRs.

use qsq_edge::bench::{run_bench, write_json, BenchResult};
use qsq_edge::kernels::{self, PackedQTensor};
use qsq_edge::quant::qsq::{matrix_dims, quantize, quantize_sigma_search_naive, AssignMode};
use qsq_edge::tensor::{ops, Tensor};
use qsq_edge::util::prop::gen_weights;
use qsq_edge::util::rng::Rng;

fn main() {
    println!("== bench_kernels ==");
    let mut results: Vec<BenchResult> = Vec::new();
    let mut r = Rng::new(0);

    // --- qgemm vs decode + naive matmul at real layer shapes ----------------
    let qgemm_layers: &[(&str, usize, &[usize], usize)] = &[
        ("lenet-c2w[150,16]", 64, &[5, 5, 6, 16], 6),
        ("lenet-f1w[256,120]", 32, &[256, 120], 16),
        ("convnet-k3[288,64]", 64, &[3, 3, 32, 64], 16),
    ];
    for &(name, m, shape, group) in qgemm_layers {
        let (k, oc) = matrix_dims(shape).unwrap();
        let w = gen_weights(&mut r, k * oc, 0.2);
        let qt = quantize(&w, shape, group, 4, AssignMode::SigmaSearch).unwrap();
        let packed = PackedQTensor::pack(&qt).unwrap();
        let x = Tensor::new(vec![m, k], gen_weights(&mut r, m * k, 1.0)).unwrap();
        let items = (m * k * oc) as f64;

        let base = run_bench(&format!("decode+naive-matmul {name} m={m}"), 3, 20, items, || {
            let dec = Tensor::new(vec![k, oc], qt.decode()).unwrap();
            ops::matmul_naive(&x, &dec).unwrap()
        });
        println!("{}", base.report());
        // the steady-state old serving path: weights decoded once at deploy
        // time, every inference pays only the f32 matmul
        let dec = Tensor::new(vec![k, oc], qt.decode()).unwrap();
        let predec = run_bench(&format!("predecoded-matmul   {name} m={m}"), 3, 20, items, || {
            ops::matmul_naive(&x, &dec).unwrap()
        });
        println!("{}", predec.report());
        let fast = run_bench(&format!("qgemm-packed        {name} m={m}"), 3, 20, items, || {
            kernels::qgemm(&x, &packed).unwrap()
        });
        println!("{}", fast.report());
        println!(
            "  -> qgemm speedup {:.2}x vs decode+matmul, {:.2}x vs predecoded matmul \
             (zero-skip {:.1}% of codes)",
            base.median_s / fast.median_s.max(1e-12),
            predec.median_s / fast.median_s.max(1e-12),
            100.0 * packed.skipped_fraction()
        );
        results.push(base);
        results.push(predec);
        results.push(fast);
    }

    // --- blocked/parallel f32 matmul vs the naive ikj loop ------------------
    let mm_shapes: &[(&str, usize, usize, usize)] = &[
        ("conv-im2col[784,150]x[150,16]", 784, 150, 16),
        ("fc[128,256]x[256,120]", 128, 256, 120),
        ("square[256,256]^2", 256, 256, 256),
    ];
    for &(name, m, k, n) in mm_shapes {
        let x = Tensor::new(vec![m, k], gen_weights(&mut r, m * k, 1.0)).unwrap();
        let w = Tensor::new(vec![k, n], gen_weights(&mut r, k * n, 1.0)).unwrap();
        let items = (m * k * n) as f64;
        let naive = run_bench(&format!("matmul-naive   {name}"), 2, 12, items, || {
            ops::matmul_naive(&x, &w).unwrap()
        });
        println!("{}", naive.report());
        let blocked = run_bench(&format!("matmul-blocked {name}"), 2, 12, items, || {
            ops::matmul(&x, &w).unwrap()
        });
        println!("{}", blocked.report());
        println!(
            "  -> blocked speedup {:.2}x",
            naive.median_s / blocked.median_s.max(1e-12)
        );
        results.push(naive);
        results.push(blocked);
    }

    // --- O(sort) sigma-search vs the naive 19x8 grid ------------------------
    let qshapes: &[(&str, &[usize], usize)] = &[
        ("convnet-k3[3,3,32,64]", &[3, 3, 32, 64], 16),
        ("lenet-f1w[256,120]", &[256, 120], 16),
    ];
    for &(name, shape, group) in qshapes {
        let (k, oc) = matrix_dims(shape).unwrap();
        let w = gen_weights(&mut r, k * oc, 0.1);
        // sanity: the two searches must agree exactly before we time them
        let a = quantize(&w, shape, group, 4, AssignMode::SigmaSearch).unwrap();
        let b = quantize_sigma_search_naive(&w, shape, group, 4).unwrap();
        assert_eq!((a.gamma, a.delta), (b.gamma, b.delta), "{name}: search argmin diverged");
        assert_eq!(a.codes, b.codes, "{name}: codes diverged");

        let items = (k * oc) as f64;
        let naive = run_bench(&format!("sigma-search-naive-grid {name}"), 1, 5, items, || {
            quantize_sigma_search_naive(&w, shape, group, 4).unwrap()
        });
        println!("{}", naive.report());
        let fast = run_bench(&format!("sigma-search-osort      {name}"), 1, 20, items, || {
            quantize(&w, shape, group, 4, AssignMode::SigmaSearch).unwrap()
        });
        println!("{}", fast.report());
        println!(
            "  -> sigma-search speedup {:.2}x",
            naive.median_s / fast.median_s.max(1e-12)
        );
        results.push(naive);
        results.push(fast);
    }

    write_json("BENCH_kernels.json", "bench_kernels", &results).unwrap();
    println!("\nwrote BENCH_kernels.json ({} entries)", results.len());
}
