//! Kernel benchmarks — the hot paths the kernels layer owns, each against
//! its naive oracle, at real LeNet/ConvNet layer shapes:
//!
//! * code-domain `qgemm` v1 (entry-packed, single-thread reference) and v2
//!   (plane-packed, row-parallel) vs decode-to-f32 + naive matmul — the old
//!   serving path — and against each other;
//! * the fused `qconv` (scratch-arena patch staging) vs the materialized
//!   pad + im2col + qgemm2 pipeline it replaced, with the arena's
//!   reuse/alloc counters printed so "zero per-request im2col allocations"
//!   is visible in the output;
//! * end-to-end engine forwards (f32 fused vs code-domain) on random stores;
//! * the truncated-CSD shift-and-add GEMM (`kernels::csd`) across digit
//!   budgets vs the f32 matmul over its decode, next to the per-scalar QSM
//!   datapath simulator (`hw::multiplier::dot`) it is reconciled against —
//!   the `bench_csd_multiplier`-vs-`kernels::csd` trajectory entries;
//! * blocked/microtiled f32 matmul vs the naive ikj loop;
//! * O(sort) sigma-search quantization vs the naive 19x8 grid (152 full
//!   assignment passes).
//!
//! * the lane-ized plane-sum primitives (`kernels::lanes`) vs their
//!   retained scalar oracles — the `plane-sum-*` / `swar-sum-*` pairs the
//!   CI bench summary renders as a speedup ratio — and warm engine
//!   forwards with sticky band pinning vs re-dealt leasing at the server
//!   batch size;
//! * the calibrated integer-activation datapath: the i16 SWAR plane gather
//!   vs its scalar oracle *and* vs the f32 lane path on the same planes
//!   (the headline int-vs-f32 ratio), a calibrated integer engine forward
//!   vs the f32-activation code-domain engine, and the integer engine
//!   under pinned vs re-dealt band placement (what the cross-forward
//!   affinity table buys the i16 ping/pong planes).
//!
//! Emits `BENCH_kernels.json` (name/median/p95/throughput per entry) so the
//! perf trajectory is tracked across PRs, including counter entries for the
//! scratch arena (reuse/alloc), the persistent worker pool
//! (spawn-vs-wakeup — spawns are asserted frozen across warm forwards —
//! and pin hits-vs-misses), and the per-layer scratch high-water marks.

use qsq_edge::bench::{run_bench, write_json, BenchResult};
use qsq_edge::data::synth_store;
use qsq_edge::device::QualityConfig;
use qsq_edge::kernels::{self, PackedQTensor, PackedQTensorV2, Scratch};
use qsq_edge::model::meta::ModelKind;
use qsq_edge::quant::qsq::{matrix_dims, quantize, quantize_sigma_search_naive, AssignMode};
use qsq_edge::quant::vectorize::Grouping;
use qsq_edge::runtime::host::{self, QuantizedEngine};
use qsq_edge::tensor::{ops, Tensor};
use qsq_edge::util::prop::gen_weights;
use qsq_edge::util::rng::Rng;

/// A synthetic JSON entry carrying the scratch-arena counters under a
/// *stable* name so cross-PR tooling can track the series: `items_per_iter`
/// holds the reuse count and `iters` the alloc count (the timing fields are
/// zero — this entry measures allocation behavior, not latency).
fn scratch_entry(name: &str, stats: kernels::ScratchStats) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        iters: stats.allocs as usize,
        mean_s: 0.0,
        median_s: 0.0,
        p95_s: 0.0,
        min_s: 0.0,
        items_per_iter: stats.reuses as f64,
    }
}

/// A synthetic JSON entry for the persistent-pool counters (same convention
/// as [`scratch_entry`]): `iters` holds the spawn count — which must stay
/// frozen once serving is warm — and `items_per_iter` the wakeup count.
fn pool_entry(name: &str, stats: kernels::PoolStats) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        iters: stats.spawns as usize,
        mean_s: 0.0,
        median_s: 0.0,
        p95_s: 0.0,
        min_s: 0.0,
        items_per_iter: stats.wakeups as f64,
    }
}

/// A synthetic JSON entry for the sticky-pinning counters (same convention
/// as [`pool_entry`]): `iters` holds the pin-hit count and `items_per_iter`
/// the pin-miss count.
fn pin_entry(name: &str, stats: kernels::PoolStats) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        iters: stats.pin_hits as usize,
        mean_s: 0.0,
        median_s: 0.0,
        p95_s: 0.0,
        min_s: 0.0,
        items_per_iter: stats.pin_misses as f64,
    }
}

/// A synthetic JSON entry for one layer's scratch high-water marks:
/// `iters` holds the peak staging bytes (patch + pad) and `items_per_iter`
/// the peak activation bytes.
fn highwater_entry(name: &str, pk: kernels::LayerPeak) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        iters: pk.patch_bytes + pk.pad_bytes,
        mean_s: 0.0,
        median_s: 0.0,
        p95_s: 0.0,
        min_s: 0.0,
        items_per_iter: pk.act_bytes as f64,
    }
}

fn main() {
    println!("== bench_kernels ==");
    let mut results: Vec<BenchResult> = Vec::new();
    let mut r = Rng::new(0);

    // --- qgemm v1/v2 vs decode + naive matmul at real layer shapes ----------
    let qgemm_layers: &[(&str, usize, &[usize], usize)] = &[
        ("lenet-c2w[150,16]", 64, &[5, 5, 6, 16], 6),
        ("lenet-f1w[256,120]", 32, &[256, 120], 16),
        ("convnet-k3[288,64]", 64, &[3, 3, 32, 64], 16),
    ];
    for &(name, m, shape, group) in qgemm_layers {
        let (k, oc) = matrix_dims(shape).unwrap();
        let w = gen_weights(&mut r, k * oc, 0.2);
        let qt = quantize(&w, shape, group, 4, AssignMode::SigmaSearch).unwrap();
        let packed = PackedQTensor::pack(&qt).unwrap();
        let packed2 = PackedQTensorV2::pack(&qt).unwrap();
        let x = Tensor::new(vec![m, k], gen_weights(&mut r, m * k, 1.0)).unwrap();
        let items = (m * k * oc) as f64;

        let base = run_bench(&format!("decode+naive-matmul {name} m={m}"), 3, 20, items, || {
            let dec = Tensor::new(vec![k, oc], qt.decode()).unwrap();
            ops::matmul_naive(&x, &dec).unwrap()
        });
        println!("{}", base.report());
        // the steady-state old serving path: weights decoded once at deploy
        // time, every inference pays only the f32 matmul
        let dec = Tensor::new(vec![k, oc], qt.decode()).unwrap();
        let predec = run_bench(&format!("predecoded-matmul   {name} m={m}"), 3, 20, items, || {
            ops::matmul_naive(&x, &dec).unwrap()
        });
        println!("{}", predec.report());
        let fast = run_bench(&format!("qgemm-packed        {name} m={m}"), 3, 20, items, || {
            kernels::qgemm(&x, &packed).unwrap()
        });
        println!("{}", fast.report());
        let v2 = run_bench(&format!("qgemm2-planes       {name} m={m}"), 3, 20, items, || {
            kernels::qgemm2(&x, &packed2).unwrap()
        });
        println!("{}", v2.report());
        println!(
            "  -> qgemm v1 speedup {:.2}x vs decode+matmul, {:.2}x vs predecoded; \
             v2 speedup {:.2}x vs v1 (zero-skip {:.1}% of codes)",
            base.median_s / fast.median_s.max(1e-12),
            predec.median_s / fast.median_s.max(1e-12),
            fast.median_s / v2.median_s.max(1e-12),
            100.0 * packed.skipped_fraction()
        );
        results.push(base);
        results.push(predec);
        results.push(fast);
        results.push(v2);
    }

    // --- lane-ized plane sums vs the retained scalar oracles ----------------
    {
        use qsq_edge::kernels::lanes;
        // a server-batch-scale plane workload: 64 planes of 4096 offsets
        // gathering from a 16k activation buffer — the exact inner loop
        // qgemm2's level planes and the CSD digit planes spend their time in
        let nact = 16 * 1024usize;
        let xs = gen_weights(&mut r, nact, 1.0);
        let planes: Vec<Vec<u16>> = (0..64)
            .map(|_| (0..4096).map(|_| r.below(nact as u64) as u16).collect())
            .collect();
        let items = (planes.len() * 4096) as f64;
        let scalar = run_bench("plane-sum-scalar 64x4096", 3, 30, items, || {
            planes.iter().map(|p| lanes::gather_sum_scalar(p, &xs)).sum::<f32>()
        });
        println!("{}", scalar.report());
        let lane = run_bench("plane-sum-lanes  64x4096", 3, 30, items, || {
            planes.iter().map(|p| lanes::gather_sum(p, &xs)).sum::<f32>()
        });
        println!("{}", lane.report());
        println!(
            "  -> plane-sum lane speedup {:.2}x vs scalar",
            scalar.median_s / lane.median_s.max(1e-12)
        );
        let f32_lane_median = lane.median_s;
        results.push(scalar);
        results.push(lane);

        // the SWAR word sums behind the integer datapath, same gate: the
        // differential harness (tests/test_lanes.rs) pins bitwise equality,
        // this pins the speedup trajectory
        let i16s: Vec<i16> = (0..256 * 1024).map(|_| r.range_i64(-32768, 32767) as i16).collect();
        let sitems = i16s.len() as f64;
        assert_eq!(lanes::sum_i16(&i16s), lanes::sum_i16_scalar(&i16s));
        let s16 = run_bench("swar-sum-i16-scalar 256k", 3, 30, sitems, || {
            lanes::sum_i16_scalar(&i16s)
        });
        println!("{}", s16.report());
        let l16 = run_bench("swar-sum-i16-lanes  256k", 3, 30, sitems, || lanes::sum_i16(&i16s));
        println!("{}", l16.report());
        println!(
            "  -> swar i16 speedup {:.2}x vs scalar",
            s16.median_s / l16.median_s.max(1e-12)
        );
        results.push(s16);
        results.push(l16);

        // the integer-datapath plane sum: the very same planes, activations
        // calibrated down to i16 — gathers become pure SWAR integer
        // reductions (exact, order-free) instead of f32 lane folds.  The
        // f32-lane-vs-i16-lane pair is the headline ratio of the integer
        // activation datapath.
        let fmt = kernels::format_for_max_abs(kernels::max_abs(&xs));
        let mut xq = vec![0i16; nact];
        kernels::quantize_into(&xs, fmt, &mut xq);
        let gs16 = run_bench("plane-sum-i16-scalar 64x4096", 3, 30, items, || {
            planes.iter().map(|p| lanes::gather_sum_i16_scalar(p, &xq)).sum::<i64>()
        });
        println!("{}", gs16.report());
        let gl16 = run_bench("plane-sum-i16-lanes  64x4096", 3, 30, items, || {
            planes.iter().map(|p| lanes::gather_sum_i16(p, &xq)).sum::<i64>()
        });
        println!("{}", gl16.report());
        println!(
            "  -> i16 plane-sum {:.2}x vs i16 scalar, {:.2}x vs the f32 lane path",
            gs16.median_s / gl16.median_s.max(1e-12),
            f32_lane_median / gl16.median_s.max(1e-12)
        );
        results.push(gs16);
        results.push(gl16);
    }

    // --- fused qconv vs the materialized pad+im2col+qgemm2 pipeline ---------
    let conv_layers: &[(&str, &[usize], &[usize], bool)] = &[
        ("lenet-c1[5,5,1,6]   b=32", &[5, 5, 1, 6], &[32, 28, 28, 1], false),
        ("convnet-k2[3,3,32,32] b=8", &[3, 3, 32, 32], &[8, 16, 16, 32], true),
    ];
    let mut scratch = Scratch::new();
    for &(name, wshape, xshape, same) in conv_layers {
        let nw: usize = wshape.iter().product();
        let w = gen_weights(&mut r, nw, 0.2);
        let group = Grouping::nearest_divisor(wshape, 16).unwrap();
        let qt = quantize(&w, wshape, group, 4, AssignMode::SigmaSearch).unwrap();
        let p = PackedQTensorV2::pack(&qt).unwrap();
        let nx: usize = xshape.iter().product();
        let x = Tensor::new(xshape.to_vec(), gen_weights(&mut r, nx, 1.0)).unwrap();
        let (kh, kw) = (wshape[0], wshape[1]);
        // items = output elements * patch width (the GEMM work)
        let pad = if same { kh / 2 } else { 0 };
        let oh = xshape[1] + 2 * pad - kh + 1;
        let ow = xshape[2] + 2 * pad - kw + 1;
        let items = (xshape[0] * oh * ow * wshape[3] * kh * kw * wshape[2]) as f64;

        let mat = run_bench(&format!("conv-materialized {name}"), 3, 15, items, || {
            let padded;
            let xin = if same {
                padded = ops::pad_hw(&x, kh / 2).unwrap();
                &padded
            } else {
                &x
            };
            let (patches, _, _) = ops::im2col(xin, kh, kw).unwrap();
            kernels::qgemm2(&patches, &p).unwrap()
        });
        println!("{}", mat.report());
        let fused = run_bench(&format!("conv-fused-arena  {name}"), 3, 15, items, || {
            kernels::qconv(&x, &p, same, &mut scratch).unwrap()
        });
        println!("{}", fused.report());
        println!(
            "  -> fused-conv speedup {:.2}x vs materialized im2col",
            mat.median_s / fused.median_s.max(1e-12)
        );
        results.push(mat);
        results.push(fused);
    }
    println!(
        "  scratch arena after fused convs: {} buffer reuses, {} allocs \
         (warm iterations allocate no im2col buffers)",
        scratch.stats.reuses, scratch.stats.allocs
    );
    results.push(scratch_entry("qconv-scratch-arena", scratch.stats));

    // --- end-to-end engine forwards on random stores ------------------------
    {
        let store = synth_store(42, ModelKind::Lenet);
        let quality = QualityConfig { phi: 4, group: 16 };
        let engine =
            QuantizedEngine::quantize_store(&store, quality, AssignMode::SigmaSearch).unwrap();
        let b = 32usize;
        let xdata = gen_weights(&mut r, b * 28 * 28, 1.0);
        let x = Tensor::new(vec![b, 28, 28, 1], xdata).unwrap();
        let items = b as f64;
        let mut s_f32 = Scratch::new();
        let f32e = run_bench("engine-fwd lenet f32-fused   b=32", 2, 12, items, || {
            host::forward_with(&store, &x, &mut s_f32).unwrap()
        });
        println!("{}", f32e.report());
        let mut s_q = Scratch::new();
        let qe = run_bench("engine-fwd lenet code-domain b=32", 2, 12, items, || {
            engine.forward_with(&x, &mut s_q).unwrap()
        });
        println!("{}", qe.report());
        println!(
            "  -> code-domain engine {:.2}x vs f32 fused (zero-skip {:.1}%)",
            f32e.median_s / qe.median_s.max(1e-12),
            100.0 * engine.skipped_fraction()
        );
        // the calibrated integer-activation datapath on the same store and
        // batch: activations quantized to i16 between layers, plane sums on
        // the SWAR integer gather, one dequant-rescale per output cell
        let mut int_engine =
            QuantizedEngine::quantize_store(&store, quality, AssignMode::SigmaSearch).unwrap();
        int_engine.calibrate(&x).unwrap();
        let mut s_i = Scratch::new();
        let ie = run_bench("engine-fwd lenet int-datapath b=32", 2, 12, items, || {
            int_engine.forward_with(&x, &mut s_i).unwrap()
        });
        println!("{}", ie.report());
        println!(
            "  -> integer datapath {:.2}x vs f32-activation code-domain (act_bits {})",
            qe.median_s / ie.median_s.max(1e-12),
            int_engine.act_plan().unwrap().act_bits()
        );
        results.push(f32e);
        results.push(qe);
        results.push(ie);
        results.push(scratch_entry("engine-scratch-arena", s_q.stats));
        results.push(scratch_entry("int-engine-scratch-arena", s_i.stats));

        // --- persistent worker pool: spawns must be frozen once warm --------
        let warm = engine.pool().stats();
        for _ in 0..8 {
            engine.forward_with(&x, &mut s_q).unwrap();
        }
        let after = engine.pool().stats();
        assert_eq!(
            after.spawns, warm.spawns,
            "warm engine forwards must not spawn pool threads"
        );
        println!(
            "  kernel pool: {} worker spawns (frozen across warm forwards), \
             {} wakeups, {} band jobs",
            after.spawns, after.wakeups, after.jobs
        );
        results.push(pool_entry("kernel-pool-spawns-vs-wakeups", after));

        // --- sticky band pinning vs re-dealt leasing at the server batch ----
        // placement-only, so the outputs are bitwise identical either way;
        // what this tracks is the wall-clock delta cache locality buys
        let pool = engine.pool();
        pool.set_pinned(true);
        let pinned = run_bench("engine-fwd lenet pinned-bands  b=32", 2, 12, items, || {
            engine.forward_with(&x, &mut s_q).unwrap()
        });
        println!("{}", pinned.report());
        pool.set_pinned(false);
        let redealt = run_bench("engine-fwd lenet redealt-bands b=32", 2, 12, items, || {
            engine.forward_with(&x, &mut s_q).unwrap()
        });
        pool.set_pinned(true);
        println!("{}", redealt.report());
        let ps = pool.stats();
        println!(
            "  -> pinned bands {:.2}x vs re-dealt ({} pin hits, {} pin misses)",
            redealt.median_s / pinned.median_s.max(1e-12),
            ps.pin_hits,
            ps.pin_misses
        );
        results.push(pinned);
        results.push(redealt);
        results.push(pin_entry("kernel-pool-pin-hits-vs-misses", ps));

        // the same placement experiment on the integer datapath, warm
        // across forwards: the affinity table keeps each band's slice of
        // the i16 ping/pong planes on the worker that last touched it, so
        // this pair tracks what cross-forward stickiness buys the
        // integer-activation engine
        pool.set_pinned(true);
        let ipinned = run_bench("engine-fwd lenet int-pinned-bands  b=32", 2, 12, items, || {
            int_engine.forward_with(&x, &mut s_i).unwrap()
        });
        println!("{}", ipinned.report());
        pool.set_pinned(false);
        let iredealt = run_bench("engine-fwd lenet int-redealt-bands b=32", 2, 12, items, || {
            int_engine.forward_with(&x, &mut s_i).unwrap()
        });
        pool.set_pinned(true);
        println!("{}", iredealt.report());
        println!(
            "  -> int datapath pinned bands {:.2}x vs re-dealt",
            iredealt.median_s / ipinned.median_s.max(1e-12)
        );
        results.push(ipinned);
        results.push(iredealt);

        // --- per-layer scratch high-water marks -----------------------------
        for (layer, pk) in s_q.layer_peaks() {
            println!(
                "  scratch high-water {layer}: patch {} B, pad {} B, act {} B",
                pk.patch_bytes, pk.pad_bytes, pk.act_bytes
            );
            results.push(highwater_entry(&format!("scratch-hw lenet {layer}"), *pk));
        }
    }

    // --- truncated-CSD shift-and-add GEMM vs the per-scalar QSM simulator ---
    {
        use qsq_edge::device::CsdQuality;
        use qsq_edge::hw::fixedpoint::Format;
        use qsq_edge::hw::multiplier::{dot, QsmConfig};
        use qsq_edge::kernels::PackedCsdTensor;

        let (name, m, shape): (&str, usize, &[usize]) = ("lenet-f1w[256,120]", 32, &[256, 120]);
        let (k, oc) = matrix_dims(shape).unwrap();
        let w = gen_weights(&mut r, k * oc, 0.2);
        let x = Tensor::new(vec![m, k], gen_weights(&mut r, m * k, 1.0)).unwrap();
        let items = (m * k * oc) as f64;
        // f32 baseline at the same shape: what the CSD dial is traded against
        let dec = Tensor::new(
            vec![k, oc],
            PackedCsdTensor::pack(&w, shape, CsdQuality::exact()).unwrap().decode(),
        )
        .unwrap();
        let f32base = run_bench(&format!("csd-decoded-matmul  {name} m={m}"), 3, 20, items, || {
            ops::matmul(&x, &dec).unwrap()
        });
        println!("{}", f32base.report());
        results.push(f32base);
        for digits in [2usize, 4, usize::MAX] {
            let q = CsdQuality { fmt: Format::Q16_14, max_digits: digits };
            let p = PackedCsdTensor::pack(&w, shape, q).unwrap();
            let label =
                if digits == usize::MAX { "exact".to_string() } else { format!("k={digits}") };
            let b = run_bench(&format!("csd-gemm {label:<7} {name} m={m}"), 3, 20, items, || {
                kernels::csd_gemm(&x, &p).unwrap()
            });
            println!("{}", b.report());
            println!(
                "  -> digit dial {label}: {:.2} pp/MAC, {:.1}% MACs fully gated",
                p.stats.mean_pp(),
                100.0 * p.skipped_fraction()
            );
            results.push(b);
        }
        // the per-scalar QSM datapath simulator over one column of the same
        // MACs — the bit-accurate oracle `kernels::csd` is reconciled with
        // (bench_csd_multiplier sweeps it in depth); items = k MACs
        let cfg = QsmConfig::new(Format::Q16_14, 4);
        let xs: Vec<f64> = x.data()[..k].iter().map(|&v| v as f64).collect();
        let ws: Vec<f64> = (0..k).map(|row| w[row * oc] as f64).collect();
        let sim = run_bench(&format!("qsm-dot-sim k=4     {name} 1col"), 2, 20, k as f64, || {
            dot(cfg, &xs, &ws)
        });
        println!("{}", sim.report());
        results.push(sim);
    }

    // --- blocked/parallel f32 matmul vs the naive ikj loop ------------------
    let mm_shapes: &[(&str, usize, usize, usize)] = &[
        ("conv-im2col[784,150]x[150,16]", 784, 150, 16),
        ("fc[128,256]x[256,120]", 128, 256, 120),
        ("square[256,256]^2", 256, 256, 256),
    ];
    for &(name, m, k, n) in mm_shapes {
        let x = Tensor::new(vec![m, k], gen_weights(&mut r, m * k, 1.0)).unwrap();
        let w = Tensor::new(vec![k, n], gen_weights(&mut r, k * n, 1.0)).unwrap();
        let items = (m * k * n) as f64;
        let naive = run_bench(&format!("matmul-naive   {name}"), 2, 12, items, || {
            ops::matmul_naive(&x, &w).unwrap()
        });
        println!("{}", naive.report());
        let blocked = run_bench(&format!("matmul-blocked {name}"), 2, 12, items, || {
            ops::matmul(&x, &w).unwrap()
        });
        println!("{}", blocked.report());
        println!(
            "  -> blocked speedup {:.2}x",
            naive.median_s / blocked.median_s.max(1e-12)
        );
        results.push(naive);
        results.push(blocked);
    }

    // --- O(sort) sigma-search vs the naive 19x8 grid ------------------------
    let qshapes: &[(&str, &[usize], usize)] = &[
        ("convnet-k3[3,3,32,64]", &[3, 3, 32, 64], 16),
        ("lenet-f1w[256,120]", &[256, 120], 16),
    ];
    for &(name, shape, group) in qshapes {
        let (k, oc) = matrix_dims(shape).unwrap();
        let w = gen_weights(&mut r, k * oc, 0.1);
        // sanity: the two searches must agree exactly before we time them
        let a = quantize(&w, shape, group, 4, AssignMode::SigmaSearch).unwrap();
        let b = quantize_sigma_search_naive(&w, shape, group, 4).unwrap();
        assert_eq!((a.gamma, a.delta), (b.gamma, b.delta), "{name}: search argmin diverged");
        assert_eq!(a.codes, b.codes, "{name}: codes diverged");

        let items = (k * oc) as f64;
        let naive = run_bench(&format!("sigma-search-naive-grid {name}"), 1, 5, items, || {
            quantize_sigma_search_naive(&w, shape, group, 4).unwrap()
        });
        println!("{}", naive.report());
        let fast = run_bench(&format!("sigma-search-osort      {name}"), 1, 20, items, || {
            quantize(&w, shape, group, 4, AssignMode::SigmaSearch).unwrap()
        });
        println!("{}", fast.report());
        println!(
            "  -> sigma-search speedup {:.2}x",
            naive.median_s / fast.median_s.max(1e-12)
        );
        results.push(naive);
        results.push(fast);
    }

    write_json("BENCH_kernels.json", "bench_kernels", &results).unwrap();
    println!("\nwrote BENCH_kernels.json ({} entries)", results.len());
}
