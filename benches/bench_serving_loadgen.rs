//! Open-loop serving load harness: Poisson arrivals at a swept target QPS
//! against a live server (synthetic store, no artifacts needed), measuring
//! the numbers a saturation story actually needs — p50/p99/p999 latency and
//! the shed rate at each offered level — and writing them to
//! `BENCH_serving.json` so the serving trajectory is tracked across PRs
//! next to `BENCH_kernels.json`.
//!
//! **Open-loop** is the load model that finds saturation: arrivals follow a
//! fixed schedule drawn before the run (exponential inter-arrival gaps, so
//! a Poisson process), and a slow server does *not* slow the arrival
//! process down — unlike closed-loop clients, which self-throttle and hide
//! queueing collapse.  Latency is measured from each request's *scheduled*
//! arrival time, not from when the writer actually got it onto the wire,
//! so coordinated omission cannot flatter the tail.
//!
//! The offered load is spread over `LOADGEN_CONNS` pipelined connections
//! (independent Poisson streams sum to a Poisson stream), each with many
//! requests in flight — this leans on the mux front end's id-keyed
//! out-of-order replies; a closed-loop one-at-a-time client could never
//! offer load beyond `conns / latency`.
//!
//! Environment knobs (CI smoke uses low levels; local runs can sweep to
//! saturation):
//!
//! * `LOADGEN_QPS`   — comma-separated target levels (default `100,300,600`)
//! * `LOADGEN_SECS`  — seconds per level (default `4`)
//! * `LOADGEN_CONNS` — connections the load is spread over (default `16`)

use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use qsq_edge::coordinator::server::{Server, ServerConfig};
use qsq_edge::data::{synth_store, RequestGen};
use qsq_edge::model::meta::ModelKind;
use qsq_edge::util::json::{self, Value};
use qsq_edge::util::rng::Rng;
use qsq_edge::util::stats;

/// Requests per connection are numbered locally; ids encode (conn, seq) so
/// the reader can map a reply back to its scheduled arrival.
const CONN_ID_STRIDE: u64 = 1_000_000;

struct LevelResult {
    target_qps: f64,
    offered: usize,
    completed: usize,
    shed: usize,
    achieved_qps: f64,
    p50_ms: f64,
    p99_ms: f64,
    p999_ms: f64,
}

fn env_f64_list(name: &str, default: &str) -> Vec<f64> {
    std::env::var(name)
        .unwrap_or_else(|_| default.to_string())
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .filter(|&q| q > 0.0)
        .collect()
}

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(default)
        .max(1)
}

/// Draw one connection's Poisson arrival schedule: offsets (seconds from
/// run start) with exponential gaps at `rate` arrivals/sec, covering
/// `secs`.
fn poisson_offsets(rng: &mut Rng, rate: f64, secs: f64) -> Vec<f64> {
    let mut offsets = Vec::new();
    let mut t = 0.0f64;
    loop {
        let u = (rng.f32() as f64).min(1.0 - 1e-9);
        t += -(1.0 - u).ln() / rate;
        if t >= secs {
            return offsets;
        }
        offsets.push(t);
    }
}

/// One reply line, classified.  `seq` is the per-connection sequence the
/// id encodes.
enum Reply {
    Completed { seq: usize, at: Instant },
    Shed,
    Other(String),
}

fn classify(line: &str) -> Option<Reply> {
    let v = json::parse(line).ok()?;
    let seq = (v.get("id").as_f64()? as u64 % CONN_ID_STRIDE) as usize;
    if v.get("pred").as_f64().is_some() {
        return Some(Reply::Completed { seq, at: Instant::now() });
    }
    match v.get("error").as_str() {
        Some("overloaded") | Some("deadline exceeded") | Some("server shutting down") => {
            Some(Reply::Shed)
        }
        Some(e) => Some(Reply::Other(e.to_string())),
        None => Some(Reply::Other(line.to_string())),
    }
}

/// Run one offered-load level against a fresh server.
fn run_level(target_qps: f64, secs: f64, conns: usize) -> LevelResult {
    let cfg = ServerConfig {
        max_delay: Duration::from_millis(2),
        ..Default::default()
    };
    let srv = Server::start_with_store(synth_store(5, ModelKind::Lenet), cfg).unwrap();
    let port = srv.port;

    // one request body reused for every send: the load harness measures the
    // serving path, not image generation
    let (img, _) = RequestGen::new(ModelKind::Lenet, 11).next();
    let pixels: Vec<Value> = img.data().iter().map(|&p| json::num(p as f64)).collect();
    let pixels = Arc::new(Value::Arr(pixels));

    let per_conn_rate = target_qps / conns as f64;
    let schedules: Vec<Arc<Vec<f64>>> = (0..conns)
        .map(|c| {
            let mut rng = Rng::new(1000 + c as u64);
            Arc::new(poisson_offsets(&mut rng, per_conn_rate, secs))
        })
        .collect();
    let offered: usize = schedules.iter().map(|s| s.len()).sum();

    let start = Instant::now() + Duration::from_millis(50); // connect window
    let handles: Vec<_> = (0..conns)
        .map(|c| {
            let schedule = schedules[c].clone();
            let pixels = pixels.clone();
            std::thread::spawn(move || -> (usize, usize, usize, Vec<f64>) {
                let stream = TcpStream::connect(format!("127.0.0.1:{port}")).unwrap();
                stream.set_nodelay(true).ok();
                let mut reader = BufReader::new(stream.try_clone().unwrap());

                // writer half on this thread's spawn: paces sends to the
                // precomputed schedule, pipelining without waiting on replies
                let wsched = schedule.clone();
                let mut wstream = stream.try_clone().unwrap();
                let writer = std::thread::spawn(move || {
                    for (seq, &off) in wsched.iter().enumerate() {
                        let due = start + Duration::from_secs_f64(off);
                        let now = Instant::now();
                        if due > now {
                            std::thread::sleep(due - now);
                        }
                        let id = c as u64 * CONN_ID_STRIDE + seq as u64;
                        let req = json::obj(vec![
                            ("id", json::num(id as f64)),
                            ("pixels", (*pixels).clone()),
                        ]);
                        wstream.write_all(req.to_json().as_bytes()).unwrap();
                        wstream.write_all(b"\n").unwrap();
                    }
                    // half-close: the server flushes every in-flight reply,
                    // then closes — the reader below sees EOF when done
                    wstream.shutdown(Shutdown::Write).ok();
                });

                let mut completed = 0usize;
                let mut shed = 0usize;
                let mut other = 0usize;
                let mut lat_ms = Vec::new();
                let mut line = String::new();
                loop {
                    line.clear();
                    match reader.read_line(&mut line) {
                        Ok(0) | Err(_) => break,
                        Ok(_) => {}
                    }
                    match classify(line.trim()) {
                        Some(Reply::Completed { seq, at }) => {
                            completed += 1;
                            // latency from the *scheduled* arrival — the
                            // anti-coordinated-omission measurement
                            let sched = start + Duration::from_secs_f64(schedule[seq]);
                            lat_ms.push(
                                at.saturating_duration_since(sched).as_secs_f64() * 1e3,
                            );
                        }
                        Some(Reply::Shed) => shed += 1,
                        Some(Reply::Other(e)) => {
                            eprintln!("loadgen: unexpected reply: {e}");
                            other += 1;
                        }
                        None => other += 1,
                    }
                }
                writer.join().unwrap();
                (completed, shed, other, lat_ms)
            })
        })
        .collect();

    let t0 = Instant::now();
    let mut completed = 0usize;
    let mut shed = 0usize;
    let mut other = 0usize;
    let mut lat_ms = Vec::new();
    for h in handles {
        let (c, s, o, l) = h.join().unwrap();
        completed += c;
        shed += s;
        other += o;
        lat_ms.extend(l);
    }
    let wall = t0.elapsed().as_secs_f64().max(secs);
    srv.stop();
    assert_eq!(other, 0, "load harness saw non-shed error replies");
    assert_eq!(
        completed + shed,
        offered,
        "every offered request must get a terminal reply"
    );

    let pct = |p: f64| if lat_ms.is_empty() { 0.0 } else { stats::percentile(&lat_ms, p) };
    LevelResult {
        target_qps,
        offered,
        completed,
        shed,
        achieved_qps: completed as f64 / wall,
        p50_ms: pct(50.0),
        p99_ms: pct(99.0),
        p999_ms: pct(99.9),
    }
}

fn main() {
    let levels = env_f64_list("LOADGEN_QPS", "100,300,600");
    let secs = env_f64_list("LOADGEN_SECS", "4").first().copied().unwrap_or(4.0);
    let conns = env_usize("LOADGEN_CONNS", 16);

    println!(
        "== open-loop serving loadgen (synthetic store, {conns} conns, {secs}s/level) =="
    );
    println!(
        "{:>10} {:>8} {:>10} {:>6} {:>10} {:>9} {:>9} {:>9} {:>10}",
        "target", "offered", "completed", "shed", "shed-rate", "p50 ms", "p99 ms", "p999 ms",
        "achieved"
    );
    let mut results = Vec::new();
    for qps in levels {
        let r = run_level(qps, secs, conns);
        let shed_rate = r.shed as f64 / r.offered.max(1) as f64;
        println!(
            "{:>10.0} {:>8} {:>10} {:>6} {:>10.3} {:>9.2} {:>9.2} {:>9.2} {:>10.1}",
            r.target_qps,
            r.offered,
            r.completed,
            r.shed,
            shed_rate,
            r.p50_ms,
            r.p99_ms,
            r.p999_ms,
            r.achieved_qps
        );
        results.push(json::obj(vec![
            ("name", json::s(&format!("loadgen qps={:.0}", r.target_qps))),
            ("target_qps", json::num(r.target_qps)),
            ("offered", json::num(r.offered as f64)),
            ("completed", json::num(r.completed as f64)),
            ("shed", json::num(r.shed as f64)),
            ("shed_rate", json::num(shed_rate)),
            ("achieved_qps", json::num(r.achieved_qps)),
            ("p50_ms", json::num(r.p50_ms)),
            ("p99_ms", json::num(r.p99_ms)),
            ("p999_ms", json::num(r.p999_ms)),
        ]));
    }
    let doc = json::obj(vec![
        ("bench", json::s("serving_loadgen")),
        ("results", Value::Arr(results)),
    ]);
    std::fs::write("BENCH_serving.json", doc.to_json() + "\n").unwrap();
    println!("wrote BENCH_serving.json");
}
