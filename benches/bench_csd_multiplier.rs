//! Quality Scalable Multiplier benchmarks — the §V.B / Fig.-11 numbers:
//! partial products, energy/multiply, and error as the digit budget scales,
//! on real trained-filter weight distributions — plus the tensor-path
//! `kernels::csd` twin at the same digit budgets, so scalar-simulator and
//! serving-kernel throughput sit side by side.

use qsq_edge::bench::run_bench;
use qsq_edge::device::CsdQuality;
use qsq_edge::hw::csd;
use qsq_edge::hw::fixedpoint::Format;
use qsq_edge::hw::multiplier::{csd_nonzero_histogram, dot, QsmConfig};
use qsq_edge::kernels::{csd_gemm_into, PackedCsdTensor};
use qsq_edge::model::meta::ModelKind;
use qsq_edge::model::store::{artifacts_dir, WeightStore};
use qsq_edge::util::rng::Rng;

fn main() {
    println!("== bench_csd_multiplier ==");
    let mut r = Rng::new(0);
    let xs: Vec<f64> = (0..4096).map(|_| r.normal()).collect();

    // weight source: trained LeNet f1w if available, else synthetic
    let ws: Vec<f64> = match WeightStore::load(&artifacts_dir(), ModelKind::Lenet) {
        Ok(store) => store.get("f1w").unwrap().data()[..4096].iter().map(|&v| v as f64).collect(),
        Err(_) => (0..4096).map(|_| r.normal() * 0.1).collect(),
    };

    println!("\n-- energy/accuracy vs digit budget (4096-MAC dot, Q32.24) --");
    println!(
        "{:<8} {:>10} {:>14} {:>12} {:>14}",
        "digits", "mean PP", "pJ/multiply", "rms err", "gated rows"
    );
    for digits in [1usize, 2, 3, 4, 6, 8, usize::MAX] {
        let cfg = QsmConfig::new(Format::Q32_24, digits);
        let (_, st) = dot(cfg, &xs, &ws);
        println!(
            "{:<8} {:>10.2} {:>14.3} {:>12.3e} {:>14.2}",
            if digits == usize::MAX { "exact".into() } else { digits.to_string() },
            st.mean_pp(),
            st.energy_pj / st.multiplies as f64,
            st.rms_err(),
            st.gated_rows as f64 / st.multiplies as f64,
        );
    }

    println!("\n-- throughput --");
    for digits in [2usize, 4, usize::MAX] {
        let cfg = QsmConfig::new(Format::Q32_24, digits);
        let res = run_bench(
            &format!(
                "qsm dot 4096 MACs (digits={})",
                if digits == usize::MAX { "exact".into() } else { digits.to_string() }
            ),
            2,
            20,
            4096.0,
            || dot(cfg, &xs, &ws),
        );
        println!("{}", res.report());
    }

    let res = run_bench("csd encode i64 x 4096", 2, 50, 4096.0, || {
        ws.iter().map(|&w| csd::to_csd((w * (1 << 24) as f64) as i64).len()).sum::<usize>()
    });
    println!("{}", res.report());

    let ws32: Vec<f32> = ws.iter().map(|&v| v as f32).collect();
    let res = run_bench("csd_nonzero_histogram 4096 (fig11 kernel)", 2, 50, 4096.0, || {
        csd_nonzero_histogram(&ws32, Format::Q16_14)
    });
    println!("{}", res.report());

    // the same 4096 MACs through the tensor-path twin: weights packed once
    // into digit planes (kernels::csd), activations as one [1, 4096] row —
    // the per-multiply CSD work moves to pack time, which is the point
    println!("\n-- kernels::csd tensor path (same MACs, packed once) --");
    let xs32: Vec<f32> = xs.iter().map(|&v| v as f32).collect();
    for digits in [2usize, 4, usize::MAX] {
        let q = CsdQuality { fmt: Format::Q16_14, max_digits: digits };
        let p = PackedCsdTensor::pack(&ws32, &[4096, 1], q).unwrap();
        let label = if digits == usize::MAX { "exact".into() } else { digits.to_string() };
        let mut out = [0.0f32; 1];
        let res = run_bench(
            &format!("csd-gemm 4096 MACs (digits={label}, {:.2} pp/MAC)", p.stats.mean_pp()),
            2,
            50,
            4096.0,
            || {
                out[0] = 0.0;
                csd_gemm_into(&mut out, &xs32, 1, &p);
                out[0]
            },
        );
        println!("{}", res.report());
    }
}
