//! PJRT inference benchmarks — the serving hot path behind Tables/Figures
//! that report accuracy at system level, and the §Perf L1/L2 comparison:
//! fused Pallas QSQ artifact vs XLA-native reference vs host fallback.

use qsq_edge::bench::run_bench;
use qsq_edge::model::meta::ModelKind;
use qsq_edge::model::store::{artifacts_dir, Dataset, WeightStore};
use qsq_edge::quant::qsq::{quantize, AssignMode};
use qsq_edge::runtime::client::{ArgValue, Runtime};
use qsq_edge::runtime::host;
use qsq_edge::tensor::Tensor;

fn main() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("bench_runtime_infer: no artifacts (run `make artifacts`); skipping");
        return;
    }
    println!("== bench_runtime_infer ==");
    let mut rt = Runtime::new(&dir).unwrap();

    for kind in [ModelKind::Lenet, ModelKind::Convnet] {
        let store = WeightStore::load(&dir, kind).unwrap();
        let test = Dataset::load(&dir, kind.dataset(), "test").unwrap();
        let weights: Vec<Tensor> = store.ordered().into_iter().cloned().collect();
        for b in [1usize, 32, 128] {
            let exe = rt.load(&format!("{}_fwd_b{}", kind.name(), b)).unwrap();
            let x = test.batch(0, b);
            let mut args = vec![ArgValue::F32(x)];
            args.extend(weights.iter().map(|t| ArgValue::F32(t.clone())));
            let res = run_bench(
                &format!("pjrt {}_fwd_b{}", kind.name(), b),
                3,
                if b == 128 { 10 } else { 30 },
                b as f64,
                || exe.run(&args).unwrap(),
            );
            println!("{}", res.report());
        }
        // host fallback for comparison (L3-only path)
        let x = test.batch(0, 32);
        let res = run_bench(
            &format!("host {} fwd b32 (pure rust)", kind.name()),
            1,
            5,
            32.0,
            || host::forward(&store, &x).unwrap(),
        );
        println!("{}", res.report());
    }

    // fused Pallas QSQ vs XLA-native ref artifact (same math) — §Perf L1
    println!("\n-- fused QSQ kernel: pallas interpret vs XLA-native lowering --");
    let store = WeightStore::load(&dir, ModelKind::Lenet).unwrap();
    let test = Dataset::load(&dir, "mnist", "test").unwrap();
    let groups: &[(&str, usize)] = &[("c1w", 5), ("c2w", 6), ("f1w", 16), ("f2w", 8)];
    let mut args = vec![ArgValue::F32(test.batch(0, 32))];
    for &(name, g) in groups {
        let tm = store.meta.tensor(name).unwrap().clone();
        let qt =
            quantize(store.get(name).unwrap().data(), &tm.shape, g, 4, AssignMode::Nearest)
                .unwrap();
        args.push(ArgValue::codes(vec![qt.k, qt.oc], &qt.codes));
        args.push(ArgValue::F32(
            Tensor::new(vec![qt.k / qt.group, qt.oc], qt.scalars.clone()).unwrap(),
        ));
    }
    for name in ["c1b", "c2b", "f1b", "f2b", "f3w", "f3b"] {
        args.push(ArgValue::F32(store.get(name).unwrap().clone()));
    }
    for artifact in ["lenet_fwd_qsq_b32", "lenet_fwd_qsq_ref_b32"] {
        let exe = rt.load(artifact).unwrap();
        let res = run_bench(artifact, 3, 20, 32.0, || exe.run(&args).unwrap());
        println!("{}", res.report());
    }

    // standalone CSD matmul kernel artifact
    let exe = rt.load("csd_matmul_demo").unwrap();
    let mut r = qsq_edge::util::rng::Rng::new(0);
    let x = Tensor::new(vec![256, 256], (0..256 * 256).map(|_| (r.normal() * 0.5) as f32).collect()).unwrap();
    let w = Tensor::new(vec![256, 256], (0..256 * 256).map(|_| (r.normal() * 0.1) as f32).collect()).unwrap();
    let csd_args = vec![ArgValue::F32(x), ArgValue::F32(w)];
    let res = run_bench("csd_matmul_demo [256x256x256, 3 digits]", 3, 20, (256 * 256 * 256) as f64, || {
        exe.run(&csd_args).unwrap()
    });
    println!("{}", res.report());
}
