//! Minimal, API-compatible stand-in for the `anyhow` crate.
//!
//! The build environment is fully offline (no registry), so the subset of
//! `anyhow` this workspace actually uses is vendored here: [`Error`],
//! [`Result`], the [`Context`] extension trait, and the `anyhow!` / `bail!` /
//! `ensure!` macros.  Errors carry a flat context chain of strings —
//! `{e}` prints the outermost message, `{e:#}` prints the whole chain joined
//! with `": "`, exactly like the real crate's Display impls.
//!
//! Like the real crate, an [`Error`] built from a typed error value
//! ([`Error::new`] or the blanket `From`/`?` conversion) keeps that value
//! and [`Error::downcast_ref`] recovers it; adding `.context(..)` frames
//! does not disturb it.  `anyhow!`/`bail!` errors carry no value and never
//! downcast.

use std::any::Any;
use std::convert::Infallible;
use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with the same defaulted error parameter as the
/// real crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chain error: outermost context first, plus the typed error
/// value it was built from (when it was built from one) for downcasting.
pub struct Error {
    chain: Vec<String>,
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()], payload: None }
    }

    /// Construct from a typed error value, keeping it downcastable.
    pub fn new<E>(e: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain, payload: Some(Box::new(e)) }
    }

    /// Wrap with an outer context message.
    pub fn context<C: Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The typed error value this `Error` was built from, if it was built
    /// from one of type `E` (context frames layered on top don't hide it).
    pub fn downcast_ref<E: Any>(&self) -> Option<&E> {
        self.payload.as_ref()?.downcast_ref::<E>()
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow: a blanket From for std errors. `Error` itself does
// not implement `std::error::Error`, so this does not overlap with the
// reflexive `From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// (any error convertible into [`Error`], including `Error` itself) and to
/// `Option`.
pub trait Context<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, Infallible> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))?;
        Ok(())
    }

    #[test]
    fn context_chain_formats() {
        let e = io_fail().context("writing model").unwrap_err();
        assert_eq!(format!("{e}"), "writing model");
        assert_eq!(format!("{e:#}"), "writing model: disk on fire");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(format!("{e}"), "missing field");
        assert_eq!(Some(7).context("missing").unwrap(), 7);
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(format!("{:#}", f(12).unwrap_err()).contains("x too big: 12"));
        assert!(f(5).is_err());
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
    }

    #[test]
    fn with_context_on_anyhow_result() {
        let r: Result<()> = Err(Error::msg("inner"));
        let e = r.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "outer 1: inner");
    }

    #[derive(Debug, PartialEq)]
    struct Typed {
        code: u32,
    }
    impl Display for Typed {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "typed error {}", self.code)
        }
    }
    impl std::error::Error for Typed {}

    #[test]
    fn typed_payloads_downcast_through_context() {
        let e = Error::new(Typed { code: 7 });
        assert_eq!(e.downcast_ref::<Typed>(), Some(&Typed { code: 7 }));
        assert!(e.downcast_ref::<std::io::Error>().is_none());
        // context frames change the message, not the payload
        let e = e.context("while frobbing");
        assert_eq!(format!("{e:#}"), "while frobbing: typed error 7");
        assert_eq!(e.downcast_ref::<Typed>(), Some(&Typed { code: 7 }));
        // `?`-converted std errors downcast too
        fn fails() -> Result<()> {
            Err(Typed { code: 9 })?;
            Ok(())
        }
        assert_eq!(fails().unwrap_err().downcast_ref::<Typed>(), Some(&Typed { code: 9 }));
        // message-only errors carry no payload
        assert!(anyhow!("plain").downcast_ref::<Typed>().is_none());
    }
}
