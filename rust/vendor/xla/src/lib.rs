//! Offline stub of the `xla` (PJRT) bindings.
//!
//! The real crate needs the `xla_extension` native toolchain, which is not
//! present in the offline build image.  This stub mirrors exactly the API
//! surface `qsq_edge::runtime::client` uses, so the crate compiles and every
//! non-PJRT code path (quantizer, codec, channel, host kernels, server with
//! the host engine) works; any attempt to actually start a PJRT client
//! returns an "unavailable" error at runtime, which the callers treat the
//! same way as missing `artifacts/` (they skip or fall back to the host
//! engine).  Swap this path dependency for the real `xla` crate to enable
//! the PJRT serving path.

#![allow(dead_code)]

use std::fmt;

/// Stub error: every fallible operation reports PJRT as unavailable.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT is unavailable in this offline build \
         (rust/vendor/xla is a stub; swap in the real `xla` crate and the \
         xla_extension toolchain to enable the PJRT path)"
    ))
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S8,
    S32,
}

/// Host-side literal value (shape + f32 storage; adequate for the stub).
#[derive(Clone, Debug)]
pub struct Literal {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Literal {
    pub fn scalar(v: f32) -> Literal {
        Literal { shape: vec![], data: vec![v] }
    }

    pub fn vec1(v: &[f32]) -> Literal {
        Literal { shape: vec![v.len()], data: v.to_vec() }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(unavailable("Literal::reshape: element count mismatch"));
        }
        Ok(Literal {
            shape: dims.iter().map(|&d| d as usize).collect(),
            data: self.data.clone(),
        })
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        Ok(Literal {
            shape: shape.to_vec(),
            data: data.iter().map(|&b| b as f32).collect(),
        })
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.shape.iter().map(|&d| d as i64).collect() })
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> Vec<i64> {
        self.dims.clone()
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub (no PJRT)".to_string()
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: AsRef<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), vec![2, 2]);
        assert!(Literal::vec1(&[1.0]).reshape(&[3]).is_err());
    }
}
