//! Link-layer framing: fixed-size payload frames with sequence numbers and
//! per-frame CRC, so the receiver can detect corrupt frames and request
//! selective retransmission.

use anyhow::{bail, Result};

use crate::codec::crc::crc32;

pub const DEFAULT_PAYLOAD: usize = 1024;

/// One frame: `[u32 seq][u32 payload_len][payload][u32 crc]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub seq: u32,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(12 + self.payload.len());
        out.extend_from_slice(&self.seq.to_le_bytes());
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.payload);
        let c = crc32(&out);
        out.extend_from_slice(&c.to_le_bytes());
        out
    }

    /// Parse and CRC-verify one frame.
    pub fn from_bytes(b: &[u8]) -> Result<Frame> {
        if b.len() < 12 {
            bail!("frame too short");
        }
        let (body, tail) = b.split_at(b.len() - 4);
        let stored = u32::from_le_bytes(tail.try_into().unwrap());
        if crc32(body) != stored {
            bail!("frame CRC mismatch");
        }
        let seq = u32::from_le_bytes(body[0..4].try_into().unwrap());
        let len = u32::from_le_bytes(body[4..8].try_into().unwrap()) as usize;
        if body.len() != 8 + len {
            bail!("frame length mismatch");
        }
        Ok(Frame { seq, payload: body[8..].to_vec() })
    }

    /// Wire overhead per frame (header + crc).
    pub const OVERHEAD: usize = 12;
}

/// Split a message into frames of `payload` bytes.
pub fn fragment(data: &[u8], payload: usize) -> Vec<Frame> {
    assert!(payload > 0);
    data.chunks(payload)
        .enumerate()
        .map(|(i, c)| Frame { seq: i as u32, payload: c.to_vec() })
        .collect()
}

/// Reassemble frames (must be complete and in any order).
pub fn reassemble(mut frames: Vec<Frame>) -> Result<Vec<u8>> {
    frames.sort_by_key(|f| f.seq);
    for (i, f) in frames.iter().enumerate() {
        if f.seq != i as u32 {
            bail!("missing frame {i}");
        }
    }
    Ok(frames.into_iter().flat_map(|f| f.payload).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn frame_roundtrip() {
        let f = Frame { seq: 7, payload: vec![1, 2, 3, 4, 5] };
        assert_eq!(Frame::from_bytes(&f.to_bytes()).unwrap(), f);
    }

    #[test]
    fn corrupt_frame_rejected() {
        let mut b = Frame { seq: 0, payload: vec![9; 64] }.to_bytes();
        b[20] ^= 1;
        assert!(Frame::from_bytes(&b).is_err());
    }

    #[test]
    fn fragment_reassemble() {
        let mut r = Rng::new(0);
        let data: Vec<u8> = (0..5000).map(|_| r.below(256) as u8).collect();
        let mut frames = fragment(&data, 1024);
        assert_eq!(frames.len(), 5);
        // shuffle to prove order-independence
        frames.reverse();
        assert_eq!(reassemble(frames).unwrap(), data);
    }

    #[test]
    fn missing_frame_detected() {
        let data = vec![0u8; 3000];
        let mut frames = fragment(&data, 1024);
        frames.remove(1);
        assert!(reassemble(frames).is_err());
    }

    #[test]
    fn empty_message() {
        let frames = fragment(&[], 100);
        assert!(frames.is_empty());
        assert_eq!(reassemble(frames).unwrap(), Vec::<u8>::new());
    }
}
