//! Simulated communication channel between the model server and the edge
//! device (the paper's §I edge-computing story: encode → transmit → decode).

pub mod frame;
pub mod link;

pub use link::{BurstConfig, Link, LinkConfig, TransferError, TransferReport};
