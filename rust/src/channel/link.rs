//! Point-to-point link simulator: bandwidth, propagation latency, and an
//! optional bit-error rate.  Transfers are framed ([`super::frame`]); corrupt
//! frames are detected by their CRC and retransmitted (stop-and-wait
//! per-frame ARQ — adequate for the deployment pipeline's model push).

use anyhow::Result;

use super::frame::{fragment, reassemble, Frame};
use crate::hw::energy;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct LinkConfig {
    /// Payload bits per second.
    pub bandwidth_bps: f64,
    /// One-way propagation latency, seconds.
    pub latency_s: f64,
    /// Independent bit-error probability on the wire.
    pub ber: f64,
    /// Frame payload size in bytes.
    pub frame_payload: usize,
    /// Give up after this many retransmissions of a single frame.
    pub max_retries: u32,
}

impl Default for LinkConfig {
    fn default() -> Self {
        LinkConfig {
            bandwidth_bps: 10e6, // 10 Mbit/s edge uplink
            latency_s: 0.02,
            ber: 0.0,
            frame_payload: super::frame::DEFAULT_PAYLOAD,
            max_retries: 16,
        }
    }
}

/// What a transfer cost.
#[derive(Clone, Copy, Debug, Default)]
pub struct TransferReport {
    pub payload_bytes: usize,
    pub wire_bytes: usize,
    pub frames: usize,
    pub retransmissions: u32,
    pub elapsed_s: f64,
    /// DRAM-interface energy equivalent of the payload (paper §IV.C metric).
    pub transfer_energy_pj: f64,
}

pub struct Link {
    pub cfg: LinkConfig,
    rng: Rng,
}

impl Link {
    pub fn new(cfg: LinkConfig, seed: u64) -> Link {
        Link { cfg, rng: Rng::new(seed) }
    }

    /// Corrupt a byte stream according to the BER.
    fn corrupt(&mut self, data: &mut [u8]) -> bool {
        if self.cfg.ber <= 0.0 {
            return false;
        }
        let mut hit = false;
        // Expected flips = bits * ber; sample per-byte to stay O(n).
        let per_byte = 1.0 - (1.0 - self.cfg.ber).powi(8);
        for b in data.iter_mut() {
            if self.rng.chance(per_byte) {
                *b ^= 1 << self.rng.below(8);
                hit = true;
            }
        }
        hit
    }

    /// Transmit a message, returning the received bytes and the cost report.
    /// Every frame is CRC-checked; corrupt frames retransmit (ARQ).
    pub fn transmit(&mut self, data: &[u8]) -> Result<(Vec<u8>, TransferReport)> {
        let frames = fragment(data, self.cfg.frame_payload);
        let mut received: Vec<Frame> = Vec::with_capacity(frames.len());
        let mut report = TransferReport {
            payload_bytes: data.len(),
            frames: frames.len(),
            ..Default::default()
        };

        for f in &frames {
            let wire = f.to_bytes();
            let mut tries = 0;
            loop {
                let mut sent = wire.clone();
                self.corrupt(&mut sent);
                report.wire_bytes += sent.len();
                match Frame::from_bytes(&sent) {
                    Ok(ok) => {
                        received.push(ok);
                        break;
                    }
                    Err(_) => {
                        tries += 1;
                        report.retransmissions += 1;
                        if tries > self.cfg.max_retries {
                            anyhow::bail!(
                                "frame {} exceeded {} retries (ber={})",
                                f.seq,
                                self.cfg.max_retries,
                                self.cfg.ber
                            );
                        }
                    }
                }
            }
        }

        report.elapsed_s = self.cfg.latency_s
            + report.wire_bytes as f64 * 8.0 / self.cfg.bandwidth_bps
            // one RTT per retransmission (stop-and-wait)
            + report.retransmissions as f64 * 2.0 * self.cfg.latency_s;
        report.transfer_energy_pj = energy::transfer_pj(data.len() as u64 * 8, false);

        let bytes = reassemble(received)?;
        Ok((bytes, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(n: usize) -> Vec<u8> {
        (0..n).map(|i| (i * 31 % 251) as u8).collect()
    }

    #[test]
    fn clean_link_delivers_exactly() {
        let mut link = Link::new(LinkConfig::default(), 1);
        let data = payload(10_000);
        let (got, rep) = link.transmit(&data).unwrap();
        assert_eq!(got, data);
        assert_eq!(rep.retransmissions, 0);
        assert!(rep.wire_bytes > rep.payload_bytes); // framing overhead
        assert!(rep.elapsed_s > 0.0);
    }

    #[test]
    fn noisy_link_recovers_via_arq() {
        let cfg = LinkConfig { ber: 2e-5, ..Default::default() };
        let mut link = Link::new(cfg, 2);
        let data = payload(50_000);
        let (got, rep) = link.transmit(&data).unwrap();
        assert_eq!(got, data);
        assert!(rep.retransmissions > 0, "expected some retransmissions");
    }

    #[test]
    fn hopeless_link_errors_out() {
        let cfg = LinkConfig { ber: 0.05, max_retries: 3, ..Default::default() };
        let mut link = Link::new(cfg, 3);
        assert!(link.transmit(&payload(5_000)).is_err());
    }

    #[test]
    fn elapsed_scales_with_bandwidth() {
        let data = payload(100_000);
        let fast = Link::new(LinkConfig { bandwidth_bps: 100e6, ..Default::default() }, 4)
            .transmit(&data)
            .unwrap()
            .1;
        let slow = Link::new(LinkConfig { bandwidth_bps: 1e6, ..Default::default() }, 4)
            .transmit(&data)
            .unwrap()
            .1;
        assert!(slow.elapsed_s > 10.0 * fast.elapsed_s);
    }

    #[test]
    fn empty_transfer() {
        let mut link = Link::new(LinkConfig::default(), 5);
        let (got, rep) = link.transmit(&[]).unwrap();
        assert!(got.is_empty());
        assert_eq!(rep.frames, 0);
    }
}
