//! Point-to-point link simulator: bandwidth, propagation latency, and an
//! optional bit-error rate.  Transfers are framed ([`super::frame`]); corrupt
//! frames are detected by their CRC and retransmitted (stop-and-wait
//! per-frame ARQ — adequate for the deployment pipeline's model push).
//!
//! Beyond the i.i.d. BER, a [`BurstConfig`] arms a two-state Gilbert–Elliott
//! error model: the wire flips between a *good* state (the base `ber`) and a
//! *bad* state (`ber_bad`), with per-byte transition probabilities.  Real
//! edge radios fail exactly this way — fades and interference hit in bursts,
//! not as independent coin flips — and bursts are the adversarial case for
//! per-frame ARQ (a burst concentrates its damage on consecutive frames and
//! their retransmissions, since the channel state persists across retries).
//! The chaos harness arms it via `PALLAS_FAULTS=link.burst=ENTER:EXIT:BER`
//! ([`crate::util::faults`]).

use std::fmt;

use anyhow::Result;

use super::frame::{fragment, reassemble, Frame};
use crate::hw::energy;
use crate::util::rng::Rng;

/// Gilbert–Elliott burst-error profile: per-byte transition probabilities
/// between the good state (the base [`LinkConfig::ber`]) and a bad state
/// with its own, much higher, bit-error rate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BurstConfig {
    /// Per-byte probability of entering the bad state.
    pub p_enter: f64,
    /// Per-byte probability of leaving the bad state (1/p_exit is the mean
    /// burst length in bytes).
    pub p_exit: f64,
    /// Bit-error probability while in the bad state.
    pub ber_bad: f64,
}

#[derive(Clone, Copy, Debug)]
pub struct LinkConfig {
    /// Payload bits per second.
    pub bandwidth_bps: f64,
    /// One-way propagation latency, seconds.
    pub latency_s: f64,
    /// Independent bit-error probability on the wire.
    pub ber: f64,
    /// Frame payload size in bytes.
    pub frame_payload: usize,
    /// Give up after this many retransmissions of a single frame.
    pub max_retries: u32,
    /// Optional Gilbert–Elliott burst profile layered over `ber` (the good
    /// state keeps the base BER; the bad state uses [`BurstConfig::ber_bad`]).
    pub burst: Option<BurstConfig>,
}

impl Default for LinkConfig {
    fn default() -> Self {
        LinkConfig {
            bandwidth_bps: 10e6, // 10 Mbit/s edge uplink
            latency_s: 0.02,
            ber: 0.0,
            frame_payload: super::frame::DEFAULT_PAYLOAD,
            max_retries: 16,
            burst: None,
        }
    }
}

/// What a transfer cost.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TransferReport {
    pub payload_bytes: usize,
    pub wire_bytes: usize,
    pub frames: usize,
    /// Frames that actually made it across (equals `frames` on success;
    /// strictly fewer in the partial report of a [`TransferError`]).
    pub frames_delivered: usize,
    pub retransmissions: u32,
    pub elapsed_s: f64,
    /// DRAM-interface energy equivalent of the payload (paper §IV.C metric).
    /// Only priced on delivered payloads (0 in a partial report — the wasted
    /// air time is `elapsed_s`/`wire_bytes`).
    pub transfer_energy_pj: f64,
}

/// Typed ARQ-exhaustion error: [`Link::transmit`] gave up because one frame
/// exceeded [`LinkConfig::max_retries`].  Carries the partial
/// [`TransferReport`] accumulated up to the abort — frames delivered, wire
/// bytes burned, retransmissions, wasted air time — so a failed deploy is
/// diagnosable instead of a bare message.  Recover it from an
/// `anyhow::Error` with `err.downcast_ref::<TransferError>()` — context
/// frames layered on top don't hide it.
#[derive(Clone, Debug)]
pub struct TransferError {
    /// Sequence number of the frame that exhausted its retries.
    pub frame: u32,
    /// The retry cap that was exceeded.
    pub max_retries: u32,
    /// Everything the transfer cost before it was abandoned.
    pub partial: TransferReport,
}

impl fmt::Display for TransferError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "frame {} exceeded {} retries ({}/{} frames delivered, \
             {} retransmissions, {} wire bytes wasted)",
            self.frame,
            self.max_retries,
            self.partial.frames_delivered,
            self.partial.frames,
            self.partial.retransmissions,
            self.partial.wire_bytes,
        )
    }
}

impl std::error::Error for TransferError {}

pub struct Link {
    pub cfg: LinkConfig,
    rng: Rng,
    /// Gilbert–Elliott channel state: currently in the bad (burst) state.
    /// Persists across frames *and* retransmissions — that persistence is
    /// what makes bursts adversarial for stop-and-wait ARQ.
    bad: bool,
}

/// Per-byte corruption probability for a bit-error rate (expected flips =
/// bits × ber; sampling per byte keeps corruption O(n)).
fn per_byte(ber: f64) -> f64 {
    if ber <= 0.0 {
        0.0
    } else {
        1.0 - (1.0 - ber).powi(8)
    }
}

impl Link {
    pub fn new(cfg: LinkConfig, seed: u64) -> Link {
        Link { cfg, rng: Rng::new(seed), bad: false }
    }

    /// Corrupt a byte stream according to the error model: i.i.d. BER, or —
    /// with a [`BurstConfig`] armed — the two-state Gilbert–Elliott chain.
    fn corrupt(&mut self, data: &mut [u8]) -> bool {
        match self.cfg.burst {
            Some(b) => self.corrupt_burst(data, b),
            None => self.corrupt_iid(data),
        }
    }

    fn corrupt_iid(&mut self, data: &mut [u8]) -> bool {
        let p = per_byte(self.cfg.ber);
        if p <= 0.0 {
            return false;
        }
        let mut hit = false;
        for b in data.iter_mut() {
            if self.rng.chance(p) {
                *b ^= 1 << self.rng.below(8);
                hit = true;
            }
        }
        hit
    }

    fn corrupt_burst(&mut self, data: &mut [u8], burst: BurstConfig) -> bool {
        let p_good = per_byte(self.cfg.ber);
        let p_bad = per_byte(burst.ber_bad);
        let mut hit = false;
        for b in data.iter_mut() {
            // state transition per byte, then corrupt at the state's rate
            if self.bad {
                if self.rng.chance(burst.p_exit) {
                    self.bad = false;
                }
            } else if self.rng.chance(burst.p_enter) {
                self.bad = true;
            }
            let p = if self.bad { p_bad } else { p_good };
            if p > 0.0 && self.rng.chance(p) {
                *b ^= 1 << self.rng.below(8);
                hit = true;
            }
        }
        hit
    }

    /// Transmit a message, returning the received bytes and the cost report.
    /// Every frame is CRC-checked; corrupt frames retransmit (ARQ).
    pub fn transmit(&mut self, data: &[u8]) -> Result<(Vec<u8>, TransferReport)> {
        let frames = fragment(data, self.cfg.frame_payload);
        let mut received: Vec<Frame> = Vec::with_capacity(frames.len());
        let mut report = TransferReport {
            payload_bytes: data.len(),
            frames: frames.len(),
            ..Default::default()
        };

        for f in &frames {
            let wire = f.to_bytes();
            let mut tries = 0;
            loop {
                let mut sent = wire.clone();
                self.corrupt(&mut sent);
                report.wire_bytes += sent.len();
                match Frame::from_bytes(&sent) {
                    Ok(ok) => {
                        received.push(ok);
                        break;
                    }
                    Err(_) => {
                        tries += 1;
                        report.retransmissions += 1;
                        if tries > self.cfg.max_retries {
                            // hand back everything the doomed transfer cost:
                            // the typed error keeps the partial report so a
                            // failed deploy stays diagnosable
                            report.frames_delivered = received.len();
                            report.elapsed_s = self.cfg.latency_s
                                + report.wire_bytes as f64 * 8.0 / self.cfg.bandwidth_bps
                                + report.retransmissions as f64 * 2.0 * self.cfg.latency_s;
                            return Err(anyhow::Error::new(TransferError {
                                frame: f.seq,
                                max_retries: self.cfg.max_retries,
                                partial: report,
                            }));
                        }
                    }
                }
            }
        }

        report.frames_delivered = received.len();
        report.elapsed_s = self.cfg.latency_s
            + report.wire_bytes as f64 * 8.0 / self.cfg.bandwidth_bps
            // one RTT per retransmission (stop-and-wait)
            + report.retransmissions as f64 * 2.0 * self.cfg.latency_s;
        report.transfer_energy_pj = energy::transfer_pj(data.len() as u64 * 8, false);

        let bytes = reassemble(received)?;
        Ok((bytes, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(n: usize) -> Vec<u8> {
        (0..n).map(|i| (i * 31 % 251) as u8).collect()
    }

    #[test]
    fn clean_link_delivers_exactly() {
        let mut link = Link::new(LinkConfig::default(), 1);
        let data = payload(10_000);
        let (got, rep) = link.transmit(&data).unwrap();
        assert_eq!(got, data);
        assert_eq!(rep.retransmissions, 0);
        assert!(rep.wire_bytes > rep.payload_bytes); // framing overhead
        assert!(rep.elapsed_s > 0.0);
    }

    #[test]
    fn noisy_link_recovers_via_arq() {
        let cfg = LinkConfig { ber: 2e-5, ..Default::default() };
        let mut link = Link::new(cfg, 2);
        let data = payload(50_000);
        let (got, rep) = link.transmit(&data).unwrap();
        assert_eq!(got, data);
        assert!(rep.retransmissions > 0, "expected some retransmissions");
    }

    #[test]
    fn hopeless_link_errors_out() {
        let cfg = LinkConfig { ber: 0.05, max_retries: 3, ..Default::default() };
        let mut link = Link::new(cfg, 3);
        assert!(link.transmit(&payload(5_000)).is_err());
    }

    #[test]
    fn exhaustion_error_carries_the_partial_report() {
        // Gilbert–Elliott stuck in the bad state: p_enter = 1 flips to bad on
        // the first byte and p_exit = 0 never leaves, so at ber_bad = 0.5
        // every frame corrupts and the very first frame exhausts its retries
        // regardless of the RNG walk — a deterministic exhaustion.
        let cfg = LinkConfig {
            burst: Some(BurstConfig { p_enter: 1.0, p_exit: 0.0, ber_bad: 0.5 }),
            max_retries: 3,
            ..Default::default()
        };
        let err = Link::new(cfg, 7).transmit(&payload(5_000)).unwrap_err();
        let te = err
            .downcast_ref::<TransferError>()
            .expect("exhaustion must surface the typed TransferError");
        assert_eq!(te.frame, 0, "the first frame already exhausts");
        assert_eq!(te.max_retries, 3);
        assert_eq!(te.partial.frames_delivered, 0);
        assert_eq!(te.partial.frames, 5); // 5000 B / 1024 B payload
        assert_eq!(te.partial.retransmissions, cfg.max_retries + 1);
        assert!(te.partial.wire_bytes > 0, "wasted wire bytes must be priced");
        assert!(te.partial.elapsed_s > 0.0, "wasted air time must be priced");
        assert_eq!(te.partial.transfer_energy_pj, 0.0, "nothing was delivered");
    }

    #[test]
    fn stuck_bad_burst_exhausts_identically_per_seed() {
        let cfg = LinkConfig {
            burst: Some(BurstConfig { p_enter: 1.0, p_exit: 0.0, ber_bad: 0.5 }),
            max_retries: 5,
            ..Default::default()
        };
        let data = payload(8_000);
        let partial_of = |seed: u64| -> TransferReport {
            let err = Link::new(cfg, seed).transmit(&data).unwrap_err();
            err.downcast_ref::<TransferError>().expect("typed error").partial
        };
        assert_eq!(partial_of(13), partial_of(13), "same seed, same abort");
        // a different seed corrupts different bits but the stuck-bad chain
        // still dooms frame 0 after exactly max_retries + 1 sends
        assert_eq!(partial_of(14).retransmissions, cfg.max_retries + 1);
    }

    #[test]
    fn elapsed_scales_with_bandwidth() {
        let data = payload(100_000);
        let fast = Link::new(LinkConfig { bandwidth_bps: 100e6, ..Default::default() }, 4)
            .transmit(&data)
            .unwrap()
            .1;
        let slow = Link::new(LinkConfig { bandwidth_bps: 1e6, ..Default::default() }, 4)
            .transmit(&data)
            .unwrap()
            .1;
        assert!(slow.elapsed_s > 10.0 * fast.elapsed_s);
    }

    #[test]
    fn burst_link_recovers_via_arq() {
        // correlated loss: mean burst of ~20 bytes at a bad-state BER that
        // almost certainly corrupts any frame the burst touches
        let cfg = LinkConfig {
            burst: Some(BurstConfig { p_enter: 5e-4, p_exit: 0.05, ber_bad: 5e-3 }),
            max_retries: 64,
            ..Default::default()
        };
        let mut link = Link::new(cfg, 11);
        let data = payload(50_000);
        let (got, rep) = link.transmit(&data).unwrap();
        assert_eq!(got, data, "ARQ must still deliver exactly under bursts");
        assert!(rep.retransmissions > 0, "bursts must have hit some frames");
    }

    #[test]
    fn burst_outcome_is_deterministic_per_seed() {
        let cfg = LinkConfig {
            burst: Some(BurstConfig { p_enter: 1e-3, p_exit: 0.1, ber_bad: 2e-3 }),
            max_retries: 64,
            ..Default::default()
        };
        let data = payload(30_000);
        let rep_a = Link::new(cfg, 21).transmit(&data).unwrap().1;
        let rep_b = Link::new(cfg, 21).transmit(&data).unwrap().1;
        assert_eq!(rep_a.retransmissions, rep_b.retransmissions);
        assert_eq!(rep_a.wire_bytes, rep_b.wire_bytes);
        // a different seed walks a different burst pattern (same totals
        // would be a one-in-millions coincidence at these rates)
        let rep_c = Link::new(cfg, 22).transmit(&data).unwrap().1;
        assert!(
            rep_a.retransmissions != rep_c.retransmissions
                || rep_a.wire_bytes != rep_c.wire_bytes,
            "different seeds should differ somewhere"
        );
    }

    #[test]
    fn unentered_burst_state_is_a_clean_link() {
        // p_enter = 0: the chain never leaves the good state, and with the
        // base BER at 0 the burst-mode path must deliver without a single
        // corruption (exactly like no burst config at all)
        let cfg = LinkConfig {
            burst: Some(BurstConfig { p_enter: 0.0, p_exit: 0.5, ber_bad: 0.5 }),
            ..Default::default()
        };
        let mut link = Link::new(cfg, 31);
        let data = payload(20_000);
        let (got, rep) = link.transmit(&data).unwrap();
        assert_eq!(got, data);
        assert_eq!(rep.retransmissions, 0);
    }

    #[test]
    fn empty_transfer() {
        let mut link = Link::new(LinkConfig::default(), 5);
        let (got, rep) = link.transmit(&[]).unwrap();
        assert!(got.is_empty());
        assert_eq!(rep.frames, 0);
    }
}
