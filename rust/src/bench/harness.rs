//! Timing harness: warmup + timed iterations, robust statistics, and a
//! stable one-line report format that `cargo bench` targets print.

use std::time::Instant;

use crate::util::stats;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// per-iteration seconds
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    /// optional throughput denominator (items per iteration)
    pub items_per_iter: f64,
}

impl BenchResult {
    pub fn throughput(&self) -> f64 {
        if self.median_s > 0.0 {
            self.items_per_iter / self.median_s
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        let scale = |s: f64| {
            if s >= 1.0 {
                format!("{s:.3} s")
            } else if s >= 1e-3 {
                format!("{:.3} ms", s * 1e3)
            } else {
                format!("{:.3} µs", s * 1e6)
            }
        };
        let mut line = format!(
            "{:<42} {:>12} median  {:>12} mean  {:>12} p95  ({} iters)",
            self.name,
            scale(self.median_s),
            scale(self.mean_s),
            scale(self.p95_s),
            self.iters
        );
        if self.items_per_iter > 0.0 {
            line.push_str(&format!("  [{:.0} items/s]", self.throughput()));
        }
        line
    }
}

/// Time `f` with `warmup` + `iters` runs; `items_per_iter` feeds throughput.
pub fn run_bench<T>(
    name: &str,
    warmup: usize,
    iters: usize,
    items_per_iter: f64,
    mut f: impl FnMut() -> T,
) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: stats::mean(&samples),
        median_s: stats::percentile(&samples, 50.0),
        p95_s: stats::percentile(&samples, 95.0),
        min_s: samples.iter().cloned().fold(f64::MAX, f64::min),
        items_per_iter,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        let r = run_bench("spin", 2, 10, 100.0, || (0..1000).sum::<u64>());
        assert_eq!(r.iters, 10);
        assert!(r.mean_s >= 0.0 && r.median_s >= r.min_s);
        assert!(r.throughput() > 0.0);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn report_scales_units() {
        let mut r = run_bench("x", 0, 1, 0.0, || ());
        r.median_s = 2.0;
        assert!(r.report().contains(" s "));
        r.median_s = 2e-3;
        r.mean_s = 2e-3;
        assert!(r.report().contains("ms"));
    }
}
