//! Timing harness: warmup + timed iterations, robust statistics, a stable
//! one-line report format that `cargo bench` targets print, and a
//! machine-readable JSON emitter (`BENCH_<name>.json`) so the perf
//! trajectory is trackable across PRs.

use std::path::Path;
use std::time::Instant;

use crate::util::json::{self, Value};
use crate::util::stats;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// per-iteration seconds
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    /// optional throughput denominator (items per iteration)
    pub items_per_iter: f64,
}

impl BenchResult {
    pub fn throughput(&self) -> f64 {
        if self.median_s > 0.0 {
            self.items_per_iter / self.median_s
        } else {
            0.0
        }
    }

    pub fn report(&self) -> String {
        let scale = |s: f64| {
            if s >= 1.0 {
                format!("{s:.3} s")
            } else if s >= 1e-3 {
                format!("{:.3} ms", s * 1e3)
            } else {
                format!("{:.3} µs", s * 1e6)
            }
        };
        let mut line = format!(
            "{:<42} {:>12} median  {:>12} mean  {:>12} p95  ({} iters)",
            self.name,
            scale(self.median_s),
            scale(self.mean_s),
            scale(self.p95_s),
            self.iters
        );
        if self.items_per_iter > 0.0 {
            line.push_str(&format!("  [{:.0} items/s]", self.throughput()));
        }
        line
    }

    /// Machine-readable form (one entry of a `BENCH_*.json` file).
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("name", json::s(&self.name)),
            ("iters", json::num(self.iters as f64)),
            ("median_s", json::num(self.median_s)),
            ("mean_s", json::num(self.mean_s)),
            ("p95_s", json::num(self.p95_s)),
            ("min_s", json::num(self.min_s)),
            ("items_per_iter", json::num(self.items_per_iter)),
            ("throughput_items_per_s", json::num(self.throughput())),
        ])
    }
}

/// Write a bench suite's results as `{"bench": <suite>, "results": [...]}`.
pub fn write_json(
    path: impl AsRef<Path>,
    suite: &str,
    results: &[BenchResult],
) -> std::io::Result<()> {
    let v = json::obj(vec![
        ("bench", json::s(suite)),
        ("results", Value::Arr(results.iter().map(|r| r.to_json()).collect())),
    ]);
    std::fs::write(path, v.to_json() + "\n")
}

/// Time `f` with `warmup` + `iters` runs; `items_per_iter` feeds throughput.
pub fn run_bench<T>(
    name: &str,
    warmup: usize,
    iters: usize,
    items_per_iter: f64,
    mut f: impl FnMut() -> T,
) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: stats::mean(&samples),
        median_s: stats::percentile(&samples, 50.0),
        p95_s: stats::percentile(&samples, 95.0),
        min_s: samples.iter().cloned().fold(f64::MAX, f64::min),
        items_per_iter,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        let r = run_bench("spin", 2, 10, 100.0, || (0..1000).sum::<u64>());
        assert_eq!(r.iters, 10);
        assert!(r.mean_s >= 0.0 && r.median_s >= r.min_s);
        assert!(r.throughput() > 0.0);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn json_emission_parses_back() {
        let r = run_bench("spin2", 0, 3, 10.0, || (0..1000).sum::<u64>());
        let path = std::env::temp_dir().join("qsq_bench_harness_test.json");
        write_json(&path, "unit-suite", &[r]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::util::json::parse(text.trim()).unwrap();
        assert_eq!(v.get("bench").as_str(), Some("unit-suite"));
        let results = v.get("results").as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").as_str(), Some("spin2"));
        assert!(results[0].get("median_s").as_f64().is_some());
        assert!(results[0].get("p95_s").as_f64().is_some());
        assert!(results[0].get("throughput_items_per_s").as_f64().unwrap() > 0.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn report_scales_units() {
        let mut r = run_bench("x", 0, 1, 0.0, || ());
        r.median_s = 2.0;
        assert!(r.report().contains(" s "));
        r.median_s = 2e-3;
        r.mean_s = 2e-3;
        assert!(r.report().contains("ms"));
    }
}
