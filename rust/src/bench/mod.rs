//! Micro-benchmark harness (criterion is not in the offline crate set).

pub mod harness;

pub use harness::{run_bench, write_json, BenchResult};
