//! Procedural image generators for serving-load traffic.
//!
//! Deliberately simpler than the python training generators (blobby digits /
//! colour patches), but shape- and range-compatible, so the server's input
//! validation and the batcher see realistic tensors at line rate.

use crate::model::meta::{ModelKind, ModelMeta};
use crate::model::store::WeightStore;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// A seeded random [`WeightStore`] with the exact tensor roster of `kind` —
/// the artifact-free stand-in that engine tests and kernel benches forward
/// through (weights ~ N(0, 0.1), nothing trained).
pub fn synth_store(seed: u64, kind: ModelKind) -> WeightStore {
    let mut r = Rng::new(seed);
    let meta = ModelMeta::of(kind);
    let mut s = WeightStore::empty(kind);
    for t in &meta.tensors {
        let data: Vec<f32> = (0..t.numel()).map(|_| (r.normal() * 0.1) as f32).collect();
        s.set_unchecked(t.name, Tensor::new(t.shape.clone(), data).unwrap());
    }
    s
}

/// Streaming generator of (image, nominal_label) pairs for one model.
pub struct RequestGen {
    kind: ModelKind,
    rng: Rng,
}

impl RequestGen {
    pub fn new(kind: ModelKind, seed: u64) -> RequestGen {
        RequestGen { kind, rng: Rng::new(seed) }
    }

    /// Next synthetic request image ([H, W, C] in [0,1]) and its class id.
    pub fn next(&mut self) -> (Tensor, usize) {
        let label = self.rng.below(10) as usize;
        let img = match self.kind {
            ModelKind::Lenet => self.digit_blob(label),
            ModelKind::Convnet => self.colour_patch(label),
        };
        (img, label)
    }

    /// A noisy stroke-blob vaguely shaped by the label (28x28x1).
    fn digit_blob(&mut self, label: usize) -> Tensor {
        let mut data = vec![0.0f32; 28 * 28];
        // label-dependent arc of gaussian blobs
        let cx = 10.0 + (label % 5) as f64 * 2.0;
        let cy = 8.0 + (label / 5) as f64 * 6.0;
        let n_blobs = 6 + label % 4;
        for b in 0..n_blobs {
            let t = b as f64 / n_blobs as f64 * std::f64::consts::PI * 1.5;
            let bx = cx + 6.0 * t.cos() + self.rng.range_f64(-1.0, 1.0);
            let by = cy + 6.0 * t.sin() + self.rng.range_f64(-1.0, 1.0);
            for i in 0..28 {
                for j in 0..28 {
                    let d2 = (i as f64 - by).powi(2) + (j as f64 - bx).powi(2);
                    let v = (-d2 / 3.0).exp() as f32;
                    let idx = i * 28 + j;
                    if v > data[idx] {
                        data[idx] = v;
                    }
                }
            }
        }
        for v in data.iter_mut() {
            *v = (*v + self.rng.range_f64(-0.08, 0.08) as f32).clamp(0.0, 1.0);
        }
        Tensor::new(vec![28, 28, 1], data).unwrap()
    }

    /// A coloured shape patch keyed by the label (32x32x3).
    fn colour_patch(&mut self, label: usize) -> Tensor {
        let base = [
            [0.85, 0.15, 0.15],
            [0.95, 0.35, 0.10],
            [0.15, 0.70, 0.20],
            [0.15, 0.45, 0.85],
            [0.80, 0.20, 0.80],
            [0.90, 0.85, 0.20],
            [0.20, 0.80, 0.80],
            [0.55, 0.30, 0.85],
            [0.90, 0.90, 0.90],
            [0.55, 0.55, 0.55],
        ][label];
        let cy = self.rng.range_f64(12.0, 20.0);
        let cx = self.rng.range_f64(12.0, 20.0);
        let r = self.rng.range_f64(6.0, 10.0);
        let mut data = vec![0.0f32; 32 * 32 * 3];
        for i in 0..32 {
            for j in 0..32 {
                let inside = ((i as f64 - cy).powi(2) + (j as f64 - cx).powi(2)).sqrt() < r;
                for c in 0..3 {
                    let bg = 0.2 + 0.1 * (i as f32 / 32.0);
                    let v = if inside { base[c] as f32 } else { bg };
                    data[(i * 32 + j) * 3 + c] =
                        (v + self.rng.range_f64(-0.1, 0.1) as f32).clamp(0.0, 1.0);
                }
            }
        }
        Tensor::new(vec![32, 32, 3], data).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_range() {
        let mut g = RequestGen::new(ModelKind::Lenet, 1);
        let (img, label) = g.next();
        assert_eq!(img.shape(), &[28, 28, 1]);
        assert!(label < 10);
        assert!(img.data().iter().all(|v| (0.0..=1.0).contains(v)));

        let mut g = RequestGen::new(ModelKind::Convnet, 1);
        let (img, _) = g.next();
        assert_eq!(img.shape(), &[32, 32, 3]);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = RequestGen::new(ModelKind::Lenet, 5);
        let mut b = RequestGen::new(ModelKind::Lenet, 5);
        let (ia, la) = a.next();
        let (ib, lb) = b.next();
        assert_eq!(la, lb);
        assert_eq!(ia.data(), ib.data());
    }

    #[test]
    fn labels_cover_classes() {
        let mut g = RequestGen::new(ModelKind::Convnet, 9);
        let mut seen = [false; 10];
        for _ in 0..200 {
            seen[g.next().1] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
