//! Rust-side synthetic request generators (load-testing traffic for the
//! serving path).  Evaluation always uses the python-generated .npy splits
//! in `artifacts/data/` so both languages score identical examples; these
//! generators only have to produce *plausible* in-distribution traffic.

pub mod synth;

pub use synth::{synth_store, RequestGen};
