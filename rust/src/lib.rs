//! # qsq-edge
//!
//! Production-quality reproduction of *"Quality Scalable Quantization
//! Methodology for Deep Learning on Edge"* (Khaliq & Hafiz, CS.DC 2024) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the edge-deployment coordinator: QSQ
//!   encoder/decoder, model container codec, channel simulator, device-aware
//!   quality router, dynamic batcher, TCP serving loop, on-device FC
//!   fine-tuning, and bit-accurate hardware simulators (shift-and-scale
//!   decoder, CSD quality-scalable multiplier, energy model).
//! * **L2/L1 (python, build-time only)** — JAX model graphs and Pallas
//!   kernels, AOT-lowered to HLO text in `artifacts/`, loaded and executed
//!   here via the PJRT CPU client ([`runtime`]).
//!
//! Python never runs on the request path; `artifacts/` is the only interface.
//!
//! ## Layer map (weights flowing left to right)
//!
//! ```text
//! quant ──▶ codec/channel ──▶ kernels ──▶ runtime ──▶ coordinator
//!   │                            ▲                        │
//!   └────────── hw (oracles) ────┘        repro (paper tables/figures)
//! ```
//!
//! * [`quant`] — the QSQ quantizer: 3-bit codes over {0, ±1, ±2, ±4} with
//!   per-group scalars, the O(sort) sigma-search, vector grouping.
//! * [`codec`] / [`channel`] — the shipped container (CRC-framed, eq.-11/12
//!   bit accounting) and the lossy ARQ link it crosses.
//! * [`kernels`] — the serving hot path: blocked f32 GEMM, the code-domain
//!   `qgemm` (v1/v2), the truncated-CSD shift-and-add
//!   [`kernels::csd`], the fused conv arena, and the persistent
//!   worker pool all of them band on.
//! * [`runtime`] — the engines, all behind the unified
//!   [`runtime::engine::Engine`] trait: PJRT executables when `artifacts/`
//!   is present ([`runtime::engine::PjrtEngine`]), the pure-rust fused f32
//!   [`runtime::host::F32Engine`], the code-domain
//!   [`runtime::host::QuantizedEngine`], and the CSD
//!   [`runtime::host::CsdEngine`] — each reporting the same
//!   [`runtime::engine::EngineReport`] telemetry schema, with the pluggable
//!   [`runtime::engine::DispatchPolicy`] batch routers alongside.
//! * [`coordinator`] — serving: dynamic batcher, the policy-driven engine
//!   roster ([`coordinator::server::Roster`]), deploy pipeline
//!   ([`coordinator::deploy`], incl. the device-profile-driven
//!   [`coordinator::deploy::deploy_for_device`]), metrics snapshot (schema
//!   in `docs/METRICS.md`).
//! * [`hw`] — bit-accurate micro-architecture simulators, the oracles the
//!   kernels are property-tested against.
//! * [`repro`] — one module per table/figure of the paper.
//!
//! ## The three quality dials
//!
//! The paper's deployment story exposes three orthogonal quality/energy
//! knobs, all runtime-selectable here:
//!
//! 1. **QSQ (phi, N)** ([`device::QualityConfig`]) — how many code levels
//!    and how long each scalar group is; decides what crosses the channel.
//! 2. **CSD digits** ([`device::CsdQuality`]) — how many signed-power-of-two
//!    partial products the Quality Scalable Multiplier spends per weight at
//!    inference; decides what the edge multiplier computes
//!    ([`kernels::csd`], §V.B).
//! 3. **Activation bits** ([`kernels::ACT_TOTAL_BITS`], `kernels::calib`) —
//!    whether activations between layers stay f32 or run the calibrated
//!    i16 fixed-point datapath (SWAR integer plane sums, one
//!    dequant-rescale per output cell).
//!
//! [`device::DeviceProfile::select_quality`] picks all three jointly: the
//! memory budget sizes the QSQ dial, a MACs-derived energy budget sizes the
//! digit dial, and the device class sets the activation width — one device
//! profile determines the full stacked configuration.
//!
//! See the repository `README.md` for the build/test/bench workflow,
//! `docs/METRICS.md` for the serving metrics schema, and [`repro`] for the
//! per-experiment index (every table and figure of the paper maps to a
//! module there).

pub mod bench;
pub mod channel;
pub mod codec;
pub mod coordinator;
pub mod data;
pub mod device;
pub mod hw;
pub mod kernels;
pub mod model;
pub mod quant;
pub mod repro;
pub mod runtime;
pub mod tensor;
pub mod util;
