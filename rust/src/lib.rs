//! # qsq-edge
//!
//! Production-quality reproduction of *"Quality Scalable Quantization
//! Methodology for Deep Learning on Edge"* (Khaliq & Hafiz, CS.DC 2024) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the edge-deployment coordinator: QSQ
//!   encoder/decoder, model container codec, channel simulator, device-aware
//!   quality router, dynamic batcher, TCP serving loop, on-device FC
//!   fine-tuning, and bit-accurate hardware simulators (shift-and-scale
//!   decoder, CSD quality-scalable multiplier, energy model).
//! * **L2/L1 (python, build-time only)** — JAX model graphs and Pallas
//!   kernels, AOT-lowered to HLO text in `artifacts/`, loaded and executed
//!   here via the PJRT CPU client ([`runtime`]).
//!
//! Python never runs on the request path; `artifacts/` is the only interface.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index
//! (every table and figure of the paper maps to a module in [`repro`]).

pub mod bench;
pub mod channel;
pub mod codec;
pub mod coordinator;
pub mod data;
pub mod device;
pub mod hw;
pub mod kernels;
pub mod model;
pub mod quant;
pub mod repro;
pub mod runtime;
pub mod tensor;
pub mod util;
