//! Figs. 5/6 — channel-wise vs filter-wise vector selection, operationalized.
//!
//! The paper draws the two grouping strategies but never compares them
//! head-to-head; this experiment does: reconstruction error (eq. 5), encoded
//! bits, and end-to-end accuracy for channel-wise (Fig. 5), filter-wise
//! (Fig. 6), and fixed-N grouping on both models.

use anyhow::Result;

use super::{eval_store, quantized_names, Ctx};
use crate::model::meta::ModelKind;
use crate::model::store::{Dataset, WeightStore};
use crate::quant::qsq::{quantize, AssignMode};
use crate::quant::vectorize::Grouping;
use crate::runtime::client::Runtime;
use crate::tensor::Tensor;

fn quantize_with(
    store: &WeightStore,
    grouping: Grouping,
) -> Result<(WeightStore, f64, u64)> {
    let mut out = store.clone();
    let mut err = 0.0f64;
    let mut bits = 0u64;
    for tm in store.meta.quantized_tensors() {
        let g = match grouping {
            Grouping::FixedN(n) => Grouping::nearest_divisor(&tm.shape, n)?,
            other => other.resolve(&tm.shape)?,
        };
        let w = store.get(tm.name)?;
        let qt = quantize(w.data(), &tm.shape, g, 4, AssignMode::SigmaSearch)?;
        err += qt.error(w.data());
        bits += qt.encoded_bits(32);
        out.set(tm.name, Tensor::new(tm.shape.clone(), qt.decode())?)?;
    }
    Ok((out, err, bits))
}

pub fn run(ctx: &Ctx) -> Result<String> {
    let mut rt = Runtime::new(&ctx.artifacts)?;
    let mut out = String::from(
        "Figs. 5/6 — vector selection strategies (phi=4, sigma-search, all quantized tensors)\n",
    );
    for kind in [ModelKind::Lenet, ModelKind::Convnet] {
        let store = WeightStore::load(&ctx.artifacts, kind)?;
        let test = Dataset::load(&ctx.artifacts, kind.dataset(), "test")?;
        let base = eval_store(&mut rt, &store, &test, ctx.eval_limit())?;
        out.push_str(&format!("\n{} (fp32 {:.2}%):\n", kind.name(), 100.0 * base));
        out.push_str(&format!(
            "{:<26} {:>14} {:>12} {:>10}\n",
            "grouping", "eq.5 error", "enc. kbits", "accuracy"
        ));
        let strategies = [
            Grouping::ChannelWise,
            Grouping::FilterWise,
            Grouping::FixedN(8),
            Grouping::FixedN(32),
        ];
        for s in strategies {
            let (q, err, bits) = quantize_with(&store, s)?;
            let acc = eval_store(&mut rt, &q, &test, ctx.eval_limit())?;
            out.push_str(&format!(
                "{:<26} {:>14.4} {:>12.1} {:>9.2}%\n",
                s.name(),
                err,
                bits as f64 / 1000.0,
                100.0 * acc
            ));
        }
        let _ = quantized_names(kind);
    }
    out.push_str(
        "\n(channel-wise = Fig. 5: one scalar per kernel position; filter-wise = Fig. 6:\n one scalar per output filter — cheapest but coarsest; fixed-N interpolates)\n",
    );
    Ok(out)
}
