//! Fig. 3 — resource comparison between edge devices (the paper compares
//! FPGA boards; we carry the device roster that drives quality selection).

use anyhow::Result;

use super::Ctx;
use crate::coordinator::router::plan_deployments;
use crate::device::DeviceProfile;
use crate::model::meta::ModelMeta;
use crate::quant::qsq::AssignMode;

pub fn run(_ctx: &Ctx) -> Result<String> {
    let roster = DeviceProfile::roster();
    let mut out = String::from("Fig. 3 — edge-device resource spread + selected quality\n");
    out.push_str(&format!(
        "{:<18} {:>12} {:>12} {:>12}   lenet(phi,N)   convnet(phi,N)\n",
        "device", "mem budget", "MACs/s", "downlink"
    ));
    let lenet = ModelMeta::lenet();
    let convnet = ModelMeta::convnet();
    let lp = plan_deployments(&lenet, &roster, AssignMode::SigmaSearch);
    let cp = plan_deployments(&convnet, &roster, AssignMode::SigmaSearch);
    for (i, d) in roster.iter().enumerate() {
        let fmt_q = |p: &anyhow::Result<crate::coordinator::router::DeployPlan>| match p {
            Ok(plan) => format!("({}, {})", plan.quality.phi, plan.quality.group),
            Err(_) => "  —".to_string(),
        };
        out.push_str(&format!(
            "{:<18} {:>10} KB {:>12.0e} {:>9.1} Mbps   {:<14} {}\n",
            d.name,
            d.model_budget_bytes / 1024,
            d.macs_per_s,
            d.link.bandwidth_bps / 1e6,
            fmt_q(&lp[i]),
            fmt_q(&cp[i]),
        ));
    }
    out.push_str("\n(quality scalability: constrained devices receive lower phi / larger N)\n");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_roster() {
        let s = run(&Ctx::new("artifacts".into(), true)).unwrap();
        assert!(s.contains("mcu-m4"));
        assert!(s.contains("server"));
    }
}
