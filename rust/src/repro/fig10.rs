//! Fig. 10 — design-space exploration: energy savings vs classification
//! accuracy for 2-bit (ternary, phi=1) and 3-bit (phi=4) encodings across
//! vector lengths N in {2, 4, 8, 16, 32, 64}, on ConvNet-4 with all conv
//! layers quantized.  Also reproduces the §VI headline pair
//! (2-bit: 91.95% eff / 68.47% acc; 3-bit: 88.82% / 73.28%).

use anyhow::Result;

use super::{eval_store, quantized_names, quantized_store, Ctx};
use crate::hw::energy;
use crate::model::bits;
use crate::model::meta::{ModelKind, ModelMeta};
use crate::model::store::{Dataset, WeightStore};
use crate::quant::qsq::AssignMode;
use crate::runtime::client::Runtime;

pub fn run(ctx: &Ctx) -> Result<String> {
    let mut rt = Runtime::new(&ctx.artifacts)?;
    let store = WeightStore::load(&ctx.artifacts, ModelKind::Convnet)?;
    let test = Dataset::load(&ctx.artifacts, "cifar", "test")?;
    let limit = ctx.eval_limit();
    let meta = ModelMeta::convnet();
    let names = quantized_names(ModelKind::Convnet);

    let base = eval_store(&mut rt, &store, &test, limit)?;
    let ns: &[usize] = if ctx.fast { &[8, 32] } else { &[2, 4, 8, 16, 32, 64] };

    let mut out = String::from(
        "Fig. 10 — design space: energy savings vs accuracy (ConvNet-4, all conv layers)\n",
    );
    out.push_str(&format!("baseline (fp32): {:.2}%\n", 100.0 * base));
    out.push_str(&format!(
        "{:<10} {:<4} {:>14} {:>12} {:>14}\n",
        "encoding", "N", "energy saving", "accuracy", "mode"
    ));

    let mut headline: Vec<(u32, f64, f64)> = Vec::new();
    for &(phi, label) in &[(1u32, "2-bit"), (4u32, "3-bit")] {
        for &n in ns {
            let b = bits::quantized_only_bits(&meta, phi, n);
            let eff = energy::energy_efficiency(b.full_bits, b.encoded_bits);
            // paper method (sigma-search) and the alpha-search ablation
            let qs = quantized_store(&store, &names, phi, n, AssignMode::SigmaSearch)?;
            let acc_s = eval_store(&mut rt, &qs, &test, limit)?;
            let qo = quantized_store(&store, &names, phi, n, AssignMode::NearestOpt)?;
            let acc_o = eval_store(&mut rt, &qo, &test, limit)?;
            out.push_str(&format!(
                "{:<10} {:<4} {:>13.2}% {:>11.2}% {:>14}\n",
                label, n, 100.0 * eff, 100.0 * acc_s, "sigma-search"
            ));
            out.push_str(&format!(
                "{:<10} {:<4} {:>13.2}% {:>11.2}% {:>14}\n",
                label, n, 100.0 * eff, 100.0 * acc_o, "nearest-opt"
            ));
            if n == 16 {
                headline.push((phi, eff, acc_s));
            }
        }
    }

    out.push_str("\n§VI headline comparison (paper vs ours @ N=16, sigma-search):\n");
    for (phi, eff, acc) in headline {
        let (p_eff, p_acc, label) = if phi == 1 {
            (91.95, 68.47, "2-bit")
        } else {
            (88.82, 73.28, "3-bit")
        };
        out.push_str(&format!(
            "  {label}: paper ({p_eff:.2}% eff, {p_acc:.2}% acc)  ours ({:.2}% eff, {:.2}% acc)\n",
            100.0 * eff,
            100.0 * acc
        ));
    }
    out.push_str(
        "\n(the paper's trade-off shape: 2-bit saves slightly more energy but loses\n far more accuracy than 3-bit — the 'good energy saving to accuracy ratio')\n",
    );
    Ok(out)
}
