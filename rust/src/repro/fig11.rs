//! Fig. 11 — distribution of CSD non-zero digits in trained filters.
//!
//! Paper: AlexNet filters analyzed with MATLAB `fi` showing most weights need
//! few non-zero CSD digits (justifying the QSM truncation).  Substitution
//! (DESIGN.md §2): our trained ConvNet/LeNet filters + a synthetic
//! AlexNet-shaped Gaussian filter bank, in Q16.14 fixed point.

use anyhow::Result;

use super::Ctx;
use crate::hw::fixedpoint::Format;
use crate::hw::multiplier::csd_nonzero_histogram;
use crate::model::meta::ModelKind;
use crate::model::store::WeightStore;
use crate::util::rng::Rng;

fn render_hist(name: &str, hist: &[u64], out: &mut String) {
    let total: u64 = hist.iter().sum();
    out.push_str(&format!("\n{name} ({} weights):\n", total));
    for (nz, &count) in hist.iter().enumerate() {
        if count == 0 && nz > 8 {
            continue;
        }
        let frac = count as f64 / total.max(1) as f64;
        out.push_str(&format!(
            "  {:>2} non-zeros: {:>7.3}%  {}\n",
            nz,
            100.0 * frac,
            "#".repeat((frac * 120.0) as usize)
        ));
    }
    let cum: u64 = hist[..5.min(hist.len())].iter().sum();
    out.push_str(&format!(
        "  <=4 non-zeros cover {:.2}% of weights\n",
        100.0 * cum as f64 / total.max(1) as f64
    ));
}

pub fn run(ctx: &Ctx) -> Result<String> {
    let fmt = Format::Q16_14;
    let mut out = String::from("Fig. 11 — CSD non-zero distribution of filter weights (Q16.14)\n");

    // trained filters from artifacts (both models)
    for kind in [ModelKind::Lenet, ModelKind::Convnet] {
        if let Ok(store) = WeightStore::load(&ctx.artifacts, kind) {
            let mut all = Vec::new();
            for tm in store.meta.quantized_tensors() {
                all.extend_from_slice(store.get(tm.name)?.data());
            }
            render_hist(&format!("trained {} conv/fc filters", kind.name()), &csd_nonzero_histogram(&all, fmt), &mut out);
        }
    }

    // synthetic AlexNet-shaped filter bank (the paper's subject)
    let mut rng = Rng::new(11);
    let alexnet_shapes: &[(usize, f64)] = &[
        (11 * 11 * 3 * 96, 0.02),
        (5 * 5 * 96 * 256 / 16, 0.015), // subsampled for runtime
        (3 * 3 * 256 * 384 / 64, 0.01),
    ];
    let mut synth = Vec::new();
    for &(n, sigma) in alexnet_shapes {
        for _ in 0..n {
            synth.push((rng.normal() * sigma) as f32);
        }
    }
    render_hist("synthetic AlexNet-shaped Gaussian filters", &csd_nonzero_histogram(&synth, fmt), &mut out);

    out.push_str(
        "\n(the paper's point: few non-zeros represent most weights, so truncating\n CSD partial products in the QSM costs little accuracy — see bench_csd_multiplier)\n",
    );
    Ok(out)
}
