//! Table II — the 3-bit code alphabet and its shift/invert decode semantics,
//! verified against the bit-level decoder simulator.

use anyhow::Result;

use super::Ctx;
use crate::hw::decoder_rtl;
use crate::quant::codes::Code;

pub fn run(_ctx: &Ctx) -> Result<String> {
    let alpha = 0.8125f32; // arbitrary scalar with a non-trivial mantissa
    let mut out = String::from("Table II — 3-bit code decode semantics (scalar alpha = 0.8125)\n");
    out.push_str(&format!(
        "{:<6} {:<6} {:<9} {:<26} {:>10}  {:>10}\n",
        "code", "bits", "level", "operation", "decoded", "bit-level"
    ));
    let ops_desc = [
        "0 is skipped",
        "scalar used as-is",
        "shift left once",
        "shift left twice",
        "invert",
        "invert, shift once",
        "invert, shift twice",
        "unused (reserved)",
    ];
    for c in 0..8u8 {
        let code = Code(c);
        let arithmetic = code.decode(alpha);
        let (bitlevel, _) = decoder_rtl::decode_f32(code, alpha);
        out.push_str(&format!(
            "{:<6} {:<6} {:<9} {:<26} {:>10.4}  {:>10.4}\n",
            c,
            format!("{c:03b}"),
            if code.is_reserved() { "—".into() } else { format!("{:+}", code.level()) },
            ops_desc[c as usize],
            arithmetic,
            bitlevel,
        ));
        anyhow::ensure!(
            (arithmetic - bitlevel).abs() < 1e-9 || code.is_skippable(),
            "bit-level decoder diverges at code {c}"
        );
    }
    out.push_str("\n(bit-level decoder = sign-bit XOR + exponent add; verified identical)\n");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_and_verifies() {
        let s = run(&Ctx::new("artifacts".into(), true)).unwrap();
        assert!(s.contains("shift left twice"));
        assert!(s.contains("verified identical"));
    }
}
