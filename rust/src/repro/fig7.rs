//! Fig. 7 — accuracy scales with quantization level (phi in {1, 2, 4}) on
//! LeNet; both the paper's sigma-search assignment and the nearest-level
//! ablation (DESIGN.md §6).

use anyhow::Result;

use super::{eval_store, quantized_names, quantized_store, Ctx};
use crate::model::meta::ModelKind;
use crate::model::store::{Dataset, WeightStore};
use crate::quant::qsq::AssignMode;
use crate::runtime::client::Runtime;

pub fn run(ctx: &Ctx) -> Result<String> {
    let mut rt = Runtime::new(&ctx.artifacts)?;
    let store = WeightStore::load(&ctx.artifacts, ModelKind::Lenet)?;
    let test = Dataset::load(&ctx.artifacts, "mnist", "test")?;
    let limit = ctx.eval_limit();
    let names = quantized_names(ModelKind::Lenet);

    let base = eval_store(&mut rt, &store, &test, limit)?;
    let mut out = String::from("Fig. 7 — LeNet accuracy vs quantization level phi (N=16)\n");
    out.push_str(&format!("baseline (fp32): {:.2}%\n", 100.0 * base));
    out.push_str(&format!(
        "{:<6} {:>22} {:>22}\n",
        "phi", "sigma-search (paper)", "nearest (ablation)"
    ));
    let mut prev = 0.0;
    for phi in [1u32, 2, 4] {
        let qs = quantized_store(&store, &names, phi, 16, AssignMode::SigmaSearch)?;
        let a_sigma = eval_store(&mut rt, &qs, &test, limit)?;
        let qn = quantized_store(&store, &names, phi, 16, AssignMode::Nearest)?;
        let a_near = eval_store(&mut rt, &qn, &test, limit)?;
        let bar = "#".repeat((a_sigma * 40.0) as usize);
        out.push_str(&format!(
            "{:<6} {:>21.2}% {:>21.2}%  {}\n",
            phi,
            100.0 * a_sigma,
            100.0 * a_near,
            bar
        ));
        prev = a_sigma.max(prev);
    }
    out.push_str("\n(paper's trend: accuracy increases with phi — 'quantization levels show a\n direct relation with the quality of deep learning models')\n");
    Ok(out)
}
