//! Fig. 1 — energy per operation (add/mult vs DRAM access).
//!
//! Paper: a bar chart of 45 nm per-op energies showing DRAM reads dominating
//! arithmetic by orders of magnitude (the motivation for model compression).
//! Reproduced from the same Horowitz constants the paper cites through [8].

use anyhow::Result;

use super::Ctx;
use crate::hw::energy;

pub fn run(_ctx: &Ctx) -> Result<String> {
    let rows = energy::fig1_rows();
    let dram = rows.last().unwrap().1;
    let mut out = String::from("Fig. 1 — energy per operation (45 nm)\n");
    out.push_str(&format!("{:<16} {:>10}  {:>12}  bar\n", "operation", "pJ", "DRAM ratio"));
    for (label, e) in &rows {
        let ratio = dram / e;
        let bar_len = ((e.log10() + 2.0) * 6.0).max(1.0) as usize;
        out.push_str(&format!(
            "{:<16} {:>10.2}  {:>11.0}x  {}\n",
            label,
            e,
            ratio,
            "#".repeat(bar_len)
        ));
    }
    out.push_str(&format!(
        "\npaper's §IV.C DRAM constant: {} pJ / 32 bits (kept for Fig.-10 parity; Horowitz value {} pJ)\n",
        energy::pj::PAPER_DRAM_32,
        energy::pj::DRAM_32
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_rows() {
        let ctx = Ctx::new("artifacts".into(), true);
        let s = run(&ctx).unwrap();
        assert!(s.contains("DRAM"));
        assert!(s.contains("32b fp MULT"));
    }
}
