//! Table III — LeNet accuracy: baseline / quantized (no retrain) / + FC
//! fine-tune (5 and 20 epochs), plus the §IV.A headline numbers (82.49 %
//! memory savings, +6 % zeros).
//!
//! The fine-tune rows run **on-device**: the quantized backbone stays
//! frozen and the fp32 head updates through the `fc_step_b128` artifact.

use anyhow::Result;

use super::{eval_store, quantized_names, quantized_store, Ctx};
use crate::coordinator::finetune;
use crate::hw::zskip;
use crate::model::bits;
use crate::model::meta::{ModelKind, ModelMeta};
use crate::model::store::{Dataset, WeightStore};
use crate::quant::qsq::{quantize, AssignMode};
use crate::quant::vectorize::Grouping;
use crate::runtime::client::Runtime;

pub fn run(ctx: &Ctx) -> Result<String> {
    let mut rt = Runtime::new(&ctx.artifacts)?;
    let store = WeightStore::load(&ctx.artifacts, ModelKind::Lenet)?;
    let test = Dataset::load(&ctx.artifacts, "mnist", "test")?;
    let train = Dataset::load(&ctx.artifacts, "mnist", "train")?;
    let limit = ctx.eval_limit();

    let base_acc = eval_store(&mut rt, &store, &test, limit)?;

    // quantize at the paper's operating point: phi=4, nominal N=16, sigma-search
    let names = quantized_names(ModelKind::Lenet);
    let qstore = quantized_store(&store, &names, 4, 16, AssignMode::SigmaSearch)?;
    let quant_acc = eval_store(&mut rt, &qstore, &test, limit)?;

    let (ep5, ep20) = if ctx.fast { (2, 5) } else { (5, 20) };
    let (w5, b5, rep5) = finetune::finetune_fc(&mut rt, &qstore, &train, &test, ep5, 0.05, 0)?;
    let mut ft5 = qstore.clone();
    ft5.set("f3w", w5)?;
    ft5.set("f3b", b5)?;
    let acc5 = eval_store(&mut rt, &ft5, &test, limit)?;

    let (w20, b20, _rep20) =
        finetune::finetune_fc(&mut rt, &qstore, &train, &test, ep20, 0.05, 0)?;
    let mut ft20 = qstore.clone();
    ft20.set("f3w", w20)?;
    ft20.set("f3b", b20)?;
    let acc20 = eval_store(&mut rt, &ft20, &test, limit)?;

    // headline: memory savings over quantized tensors + zero increase
    let meta = ModelMeta::lenet();
    let mem = bits::quantized_only_bits(&meta, 4, 16);
    let mut zeros_before = 0.0;
    let mut zeros_after = 0.0;
    let mut total = 0usize;
    for tm in meta.quantized_tensors() {
        let w = store.get(tm.name)?;
        let g = Grouping::nearest_divisor(&tm.shape, 16)?;
        let qt = quantize(w.data(), &tm.shape, g, 4, AssignMode::SigmaSearch)?;
        let n = tm.numel();
        zeros_before += zskip::raw_zero_fraction(w.data(), 1e-4) * n as f64;
        zeros_after += qt.zeros_fraction() * n as f64;
        total += n;
    }
    zeros_before /= total as f64;
    zeros_after /= total as f64;

    let pct = |a: f64| 100.0 * a;
    let mut out = String::from("Table III — LeNet accuracy (paper vs ours; synthetic-MNIST substitution)\n");
    out.push_str(&format!("{:<52} {:>8} {:>8}\n", "configuration", "paper", "ours"));
    out.push_str(&format!(
        "{:<52} {:>7.2}% {:>7.2}%\n",
        "without quantizing the weights", 98.68, pct(base_acc)
    ));
    out.push_str(&format!(
        "{:<52} {:>7.2}% {:>7.2}%\n",
        "after weight quantization (no retraining)", 97.59, pct(quant_acc)
    ));
    out.push_str(&format!(
        "{:<52} {:>7.2}% {:>7.2}%\n",
        format!("after quantization ({ep5} epochs, only FC)"),
        98.35,
        pct(acc5)
    ));
    out.push_str(&format!(
        "{:<52} {:>7.2}% {:>7.2}%\n",
        format!("after quantization ({ep20} epochs, only FC)"),
        98.55,
        pct(acc20)
    ));
    out.push_str(&format!(
        "\n§IV.A headlines:\n  memory savings of quantized params: paper 82.49%  ours {:.2}%\n",
        100.0 * mem.savings()
    ));
    out.push_str(&format!(
        "  zero weights: paper \"+6% zeros\"      ours {:.2}% -> {:.2}% (+{:.2}%)\n",
        100.0 * zeros_before,
        100.0 * zeros_after,
        100.0 * (zeros_after - zeros_before)
    ));
    out.push_str(&format!(
        "  (on-device FC fine-tune: first-epoch loss {:.4} -> last {:.4})\n",
        rep5.losses.first().unwrap_or(&0.0),
        rep5.losses.last().unwrap_or(&0.0)
    ));
    Ok(out)
}
