//! Fig. 2 — contribution of each component to total CNN inference energy.
//!
//! Paper: a breakdown showing memory (weight/activation movement) dominating
//! compute.  We regenerate it from the energy ledger of one simulated LeNet
//! and ConvNet inference, in three configurations: full-precision from DRAM,
//! QSQ-encoded weights (3-bit traffic + on-chip decode), and QSQ+zero-skip.

use anyhow::Result;

use super::Ctx;
use crate::hw::energy::{pj, Ledger};
use crate::model::meta::{ModelKind, ModelMeta};
use crate::quant::codes::code_bits;

/// Build the inference ledger for one image.
fn inference_ledger(meta: &ModelMeta, qsq: bool, zero_skip_frac: f64) -> Ledger {
    let mut l = Ledger::new();
    let macs = meta.macs_per_image();
    let params: u64 = meta.total_params() as u64;
    let (h, w, c) = meta.kind.input_hwc();
    let input_vals = (h * w * c) as u64;

    // weight traffic: every parameter crosses DRAM once per inference
    // (no on-chip reuse in the baseline accelerator model)
    if qsq {
        let quant: u64 = meta.quantized_tensors().map(|t| t.numel() as u64).sum();
        let rest = params - quant;
        let groups: u64 = meta
            .quantized_tensors()
            .map(|t| (t.numel() / 16).max(1) as u64)
            .sum();
        l.dram_bits += quant * code_bits(4) as u64 + groups * 32 + rest * 32;
        l.decoder_ops += quant;
    } else {
        l.dram_bits += params * 32;
    }
    // activation traffic: input + one intermediate pass (SRAM-resident after)
    l.dram_bits += input_vals * 32;
    l.sram_bits += macs / 4 * 32; // activation reuse through SRAM

    // compute
    let effective_macs = (macs as f64 * (1.0 - zero_skip_frac)) as u64;
    l.fp_muls += effective_macs;
    l.fp_adds += effective_macs;
    l.skipped_macs += macs - effective_macs;
    l
}

fn breakdown(l: &Ledger) -> String {
    let total = l.total_pj();
    format!(
        "DRAM {:>10.1} nJ ({:>4.1}%) | SRAM {:>8.1} nJ ({:>4.1}%) | compute {:>8.1} nJ ({:>4.1}%) | total {:>9.1} nJ",
        l.dram_pj() / 1e3,
        100.0 * l.dram_pj() / total,
        l.sram_pj() / 1e3,
        100.0 * l.sram_pj() / total,
        l.compute_pj() / 1e3,
        100.0 * l.compute_pj() / total,
        total / 1e3
    )
}

pub fn run(_ctx: &Ctx) -> Result<String> {
    let mut out = String::from(
        "Fig. 2 — energy breakdown per inference (ledger model; one image)\n",
    );
    for kind in [ModelKind::Lenet, ModelKind::Convnet] {
        let meta = ModelMeta::of(kind);
        out.push_str(&format!("\n{} ({} params, {} MACs):\n", kind.name(), meta.total_params(), meta.macs_per_image()));
        let base = inference_ledger(&meta, false, 0.0);
        let qsq = inference_ledger(&meta, true, 0.0);
        let qsq_skip = inference_ledger(&meta, true, 0.45);
        out.push_str(&format!("  fp32 weights        : {}\n", breakdown(&base)));
        out.push_str(&format!("  QSQ 3-bit weights   : {}\n", breakdown(&qsq)));
        out.push_str(&format!("  QSQ + zero-skip     : {}\n", breakdown(&qsq_skip)));
        let save = 1.0 - qsq.total_pj() / base.total_pj();
        out.push_str(&format!("  QSQ total-energy saving vs fp32: {:.1}%\n", 100.0 * save));
    }
    out.push_str(&format!(
        "\n(decoder op cost {} pJ/op; zero-skip removes the multiply+add of zero codes)\n",
        pj::DECODER_OP
    ));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_dominates_baseline() {
        // the paper's Fig.-2 point
        let meta = ModelMeta::lenet();
        let l = inference_ledger(&meta, false, 0.0);
        assert!(l.dram_pj() > l.compute_pj());
    }

    #[test]
    fn qsq_cuts_weight_traffic() {
        let meta = ModelMeta::lenet();
        let base = inference_ledger(&meta, false, 0.0);
        let qsq = inference_ledger(&meta, true, 0.0);
        assert!(qsq.dram_bits < base.dram_bits);
        assert!(qsq.total_pj() < base.total_pj());
    }

    #[test]
    fn zero_skip_cuts_compute() {
        let meta = ModelMeta::convnet();
        let a = inference_ledger(&meta, true, 0.0);
        let b = inference_ledger(&meta, true, 0.45);
        assert!(b.compute_pj() < a.compute_pj());
        assert!(b.skipped_macs > 0);
    }

    #[test]
    fn renders() {
        let s = run(&Ctx::new("artifacts".into(), true)).unwrap();
        assert!(s.contains("lenet") && s.contains("convnet"));
        assert!(s.contains("zero-skip"));
    }
}
