//! Paper reproduction drivers — one module per table/figure (DESIGN.md §5).
//!
//! Every module exposes `run(&Ctx) -> Result<String>`; the CLI (`qsq-edge
//! repro --exp <id>`) prints the result, and EXPERIMENTS.md records
//! paper-vs-measured for each.

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::model::meta::ModelKind;
use crate::model::store::{Dataset, WeightStore};
use crate::quant::qsq::AssignMode;
use crate::quant::vectorize::Grouping;
use crate::runtime::client::{ArgValue, Runtime};
use crate::tensor::{ops, Tensor};

pub mod fig1;
pub mod fig10;
pub mod fig11;
pub mod fig2;
pub mod fig3;
pub mod fig56;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod table2;
pub mod table3;

/// Shared experiment context.
pub struct Ctx {
    pub artifacts: PathBuf,
    /// Trim sweeps/eval sizes for CI-speed runs.
    pub fast: bool,
}

impl Ctx {
    pub fn new(artifacts: PathBuf, fast: bool) -> Ctx {
        Ctx { artifacts, fast }
    }

    /// Eval-set size cap (fast mode trims to 512 images).
    pub fn eval_limit(&self) -> usize {
        if self.fast {
            512
        } else {
            usize::MAX
        }
    }
}

/// Dispatch an experiment id to its driver.
pub fn run_experiment(ctx: &Ctx, exp: &str) -> Result<String> {
    match exp {
        "fig1" => fig1::run(ctx),
        "fig2" => fig2::run(ctx),
        "fig3" => fig3::run(ctx),
        "fig56" => fig56::run(ctx),
        "fig7" => fig7::run(ctx),
        "fig8" => fig8::run(ctx),
        "fig9" => fig9::run(ctx),
        "fig10" => fig10::run(ctx),
        "fig11" => fig11::run(ctx),
        "table2" => table2::run(ctx),
        "table3" => table3::run(ctx),
        other => anyhow::bail!("unknown experiment {other:?} (try fig1..fig11, fig56, table2, table3)"),
    }
}

pub const ALL_EXPERIMENTS: [&str; 11] = [
    "fig1", "fig2", "fig3", "table2", "table3", "fig56", "fig7", "fig8", "fig9", "fig10",
    "fig11",
];

/// Evaluate a weight store on a dataset through the PJRT b128 artifact.
pub fn eval_store(
    rt: &mut Runtime,
    store: &WeightStore,
    ds: &Dataset,
    limit: usize,
) -> Result<f64> {
    const B: usize = 128;
    let art = format!("{}_fwd_b128", store.kind.name());
    let exe = rt.load(&art)?;
    let n = ds.len().min(limit) / B * B;
    anyhow::ensure!(n > 0, "eval set too small for batch {B}");
    let weights: Vec<&Tensor> = store.ordered();
    let mut hits = 0usize;
    for start in (0..n).step_by(B) {
        let mut args = vec![ArgValue::F32(ds.batch(start, B))];
        args.extend(weights.iter().map(|t| ArgValue::F32((*t).clone())));
        let out = exe.run(&args)?;
        for (j, &p) in ops::argmax_rows(&out[0]).iter().enumerate() {
            if p as i32 == ds.y[start + j] {
                hits += 1;
            }
        }
    }
    Ok(hits as f64 / n as f64)
}

/// Quantize selected tensors of a store (decode-then-replace), returning the
/// edge-side approximate store.
pub fn quantized_store(
    store: &WeightStore,
    tensor_names: &[&str],
    phi: u32,
    nominal_n: usize,
    mode: AssignMode,
) -> Result<WeightStore> {
    let mut out = store.clone();
    for name in tensor_names {
        let tm = store
            .meta
            .tensor(name)
            .with_context(|| format!("tensor {name}"))?;
        let g = Grouping::nearest_divisor(&tm.shape, nominal_n)?;
        let qt = crate::quant::qsq::quantize(store.get(name)?.data(), &tm.shape, g, phi, mode)?;
        out.set(name, Tensor::new(tm.shape.clone(), qt.decode())?)?;
    }
    Ok(out)
}

/// All quantized-tensor names of a model.
pub fn quantized_names(kind: ModelKind) -> Vec<&'static str> {
    crate::model::meta::ModelMeta::of(kind)
        .quantized_tensors()
        .map(|t| t.name)
        .collect()
}
