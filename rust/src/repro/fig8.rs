//! Fig. 8 — ConvNet-4 quality-scalable quantization for varying vector
//! lengths N: four bars per N (accuracy after quantizing the 1st, 2nd, 3rd,
//! 4th conv layer respectively).

use anyhow::Result;

use super::{eval_store, quantized_store, Ctx};
use crate::model::meta::ModelKind;
use crate::model::store::{Dataset, WeightStore};
use crate::quant::qsq::AssignMode;
use crate::runtime::client::Runtime;

pub fn run(ctx: &Ctx) -> Result<String> {
    let mut rt = Runtime::new(&ctx.artifacts)?;
    let store = WeightStore::load(&ctx.artifacts, ModelKind::Convnet)?;
    let test = Dataset::load(&ctx.artifacts, "cifar", "test")?;
    let limit = ctx.eval_limit();

    let layers = ["k1", "k2", "k3", "k4"];
    let ns: &[usize] = if ctx.fast { &[8, 32] } else { &[2, 4, 8, 16, 32, 64] };

    let base = eval_store(&mut rt, &store, &test, limit)?;
    let mut out = String::from(
        "Fig. 8 — ConvNet-4 accuracy after quantizing each conv layer (phi=4, sigma-search)\n",
    );
    out.push_str(&format!("baseline (fp32): {:.2}%\n", 100.0 * base));
    out.push_str(&format!(
        "{:<6} {:>9} {:>9} {:>9} {:>9}\n",
        "N", "conv1", "conv2", "conv3", "conv4"
    ));
    for &n in ns {
        let mut row = format!("{n:<6}");
        for layer in layers {
            let q = quantized_store(&store, &[layer], 4, n, AssignMode::SigmaSearch)?;
            let acc = eval_store(&mut rt, &q, &test, limit)?;
            row.push_str(&format!(" {:>8.2}%", 100.0 * acc));
        }
        out.push_str(&row);
        out.push('\n');
    }
    out.push_str("\n(per-layer bars as in the paper; smaller N = finer scalars = higher accuracy)\n");
    Ok(out)
}
