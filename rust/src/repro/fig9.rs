//! Fig. 9 — memory savings from encoding full-precision weight vectors as
//! codes + one scalar, as a function of vector length N (eqs. 11/12).

use anyhow::Result;

use super::Ctx;
use crate::model::bits;
use crate::model::meta::ModelMeta;

pub fn run(_ctx: &Ctx) -> Result<String> {
    let mut out = String::from("Fig. 9 — memory savings vs vector length N (eqs. 11/12, phi=4 → 3-bit codes)\n");
    out.push_str(&format!(
        "{:<6} {:>16} {:>16} {:>18} {:>18}\n",
        "N", "lenet (quant)", "convnet (quant)", "lenet (whole)", "convnet (whole)"
    ));
    let lenet = ModelMeta::lenet();
    let convnet = ModelMeta::convnet();
    for n in [2usize, 4, 8, 16, 32, 64] {
        let lq = bits::quantized_only_bits(&lenet, 4, n).savings();
        let cq = bits::quantized_only_bits(&convnet, 4, n).savings();
        let lw = bits::model_bits(&lenet, 4, n).savings();
        let cw = bits::model_bits(&convnet, 4, n).savings();
        out.push_str(&format!(
            "{:<6} {:>15.2}% {:>15.2}% {:>17.2}% {:>17.2}%  {}\n",
            n,
            100.0 * lq,
            100.0 * cq,
            100.0 * lw,
            100.0 * cw,
            "#".repeat((lq * 40.0) as usize)
        ));
    }
    out.push_str("\n(savings saturate at 1 - 3/32 ≈ 90.6% as the per-vector scalar amortizes)\n");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn savings_monotone_in_n() {
        let s = run(&Ctx::new("artifacts".into(), true)).unwrap();
        assert!(s.contains("N"));
        // lenet quantized-savings at N=16 reproduces the 82.49% headline band
        assert!(s.contains("82.") || s.contains("83.") || s.contains("84."));
    }
}
