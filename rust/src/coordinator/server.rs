//! TCP inference server: JSON-lines protocol over a multiplexed event-loop
//! front end, with N replicated inference workers over a shared engine
//! roster.
//!
//! Protocol (one JSON object per line):
//! ```text
//! -> {"id": 7, "pixels": [ ... H*W*C floats ... ]}
//! <- {"id": 7, "pred": 3, "latency_us": 812, "batch": 32, "gen": 1}
//! ```
//! `gen` is the roster generation that served the request (it advances on a
//! hot model swap — see below).
//!
//! ## Front end and workers
//!
//! A single non-blocking mux thread ([`super::mux`]) owns the listener and
//! every client socket: requests on one connection may be *pipelined* and
//! replies come back keyed by `id` in completion order, so one slow batch
//! never head-of-line-blocks a connection.  The same port answers plain
//! HTTP `GET`s for ops: `/healthz`, `/metrics` (Prometheus text), and
//! `/metrics.json` (the JSON snapshot).
//!
//! Parsed requests land on the shared bounded [`BatchQueue`], drained by
//! [`ServerConfig::workers`] replicated inference workers (default:
//! `available_parallelism`).  Each worker owns its own [`Scratch`] arena and
//! leases the persistent kernel pool; all of them execute over one shared
//! [`Roster`] of boxed [`Engine`]s behind a read-write lock — forwards take
//! a read lock (concurrent across workers), a hot-swap install takes the
//! write lock, which is exactly the old "install between batches" contract
//! generalized to N workers.  The roster holds the PJRT artifact wrapper
//! (padded to the compiled batch size), the pure-rust blocked-GEMM
//! [`F32Engine`], the code-domain [`QuantizedEngine`] (plane-packed codes on
//! qgemm v2), and the CSD shift-and-add [`CsdEngine`] (truncated-CSD digit
//! planes on `kernels::csd`).  [`EngineSelect`] pins the roster to one
//! engine, or `Auto` builds the full roster and a pluggable
//! [`DispatchPolicy`] re-routes every popped batch (`--policy`
//! batch-fill|latency|energy): artifact-filling batches to the compiled
//! path, small/singleton batches to the low-latency or minimum-energy host
//! engines — under the energy policy the smallest batches reach the CSD
//! engine.  Row-band kernels compute each output row independently, so
//! logits are bitwise identical whichever worker serves the batch:
//! `--workers N` reproduces `--workers 1` exactly on a pinned engine.
//! After every batch the serving worker exports the pool's spawn/wakeup
//! counters, its arena's per-layer high-water marks (`pool.*`,
//! `scratch_hw.*`), its own `worker.<i>.batches` / `worker.<i>.ewma_ms`
//! gauges, and the routed engine's uniform
//! [`crate::runtime::engine::EngineReport`] as the `engine.<name>.*` gauge
//! family (`docs/METRICS.md`).
//!
//! ## Fault tolerance
//!
//! The serving path degrades gracefully under the three pressures that
//! actually hit edge deployments:
//!
//! * **Overload** — the queue is bounded ([`ServerConfig::queue_cap`],
//!   default 4× the batch size): at capacity, `push` rejects and the mux
//!   replies `{"id":N,"error":"overloaded","retry_after_ms":R}`, with `R`
//!   derived from the observed per-batch inference EWMA times the backlog
//!   depth.  Jobs that waited past [`ServerConfig::deadline`] are shed by
//!   the popping worker with a `deadline exceeded` reply instead of burning
//!   a kernel slot (`shed_overload` / `shed_deadline` counters,
//!   `queue.depth` gauge).
//! * **Engine failures** — every forward runs under `catch_unwind` inside
//!   [`Roster::serve_batch`]: an engine error or panic fails only the
//!   in-flight batch (each job gets a terminal error reply) and the worker
//!   keeps serving with a fresh [`Scratch`].  An engine that fails
//!   [`ServerConfig::quarantine_after`] times consecutively is
//!   *quarantined*: [`Roster::route`] hides it from the dispatch policy, so
//!   the existing preference orders degrade traffic to the next engine
//!   class, and after [`ServerConfig::quarantine_cooldown`] routed batches
//!   the engine is probed once — a successful probe reinstates it, a failed
//!   one re-quarantines (`engine.<name>.quarantined` gauges, `quarantines`
//!   / `engine_failures` / `worker_panics` counters).
//! * **Shutdown** — [`Server::stop`] drains the queue and sends every
//!   unserved job an explicit `server shutting down` reply
//!   (`shed_shutdown`), so clients never hang out their reply timeout,
//!   which is itself derived from the configured deadline
//!   ([`ServerConfig::reply_timeout`]) rather than a hardcoded 30s.
//!
//! ## Hot model swap
//!
//! [`Server::deploy_store`] replaces the serving model with zero downtime:
//! the [`super::swap`] pipeline stages a complete replacement generation off
//! the serving threads (encode → noisy-channel transfer → hardened decode →
//! engine build → canary gate), posts it to the shared
//! [`SwapSlot`](super::swap::SwapSlot), and whichever worker next reaches
//! its between-batches check installs it into the shared roster under the
//! write lock — in-flight batches finish on the old generation (their read
//! locks are held through the forward), and the [`Roster`] generation
//! counter advances (`swap.generation` gauge, `gen` in every reply).  The
//! displaced engines are retained for [`ServerConfig::probation_batches`]
//! served batches *across all workers* (the accounting is global, under one
//! mutex): if the new generation racks up
//! [`ServerConfig::rollback_quarantines`] quarantine events inside that
//! window, the observing worker rolls the old generation straight back
//! (`swap.rollbacks`).  A failure at any staging stage leaves the old
//! generation serving untouched and bumps the matching `swap.fail.*`
//! counter.  All PR-6 guarantees hold across the swap boundary: admission
//! stays bounded (the queue is never touched), quarantine state is rebuilt
//! per generation, and [`Server::stop`] marks the slot dead so no deployer
//! blocks on workers that exited.
//!
//! Chaos scenarios are driven through [`crate::util::faults`]
//! (`PALLAS_FAULTS`): when armed at roster-build time every engine is
//! wrapped in a [`FaultInjector`]; disarmed, the wrapper is never
//! constructed and the hot path is untouched.  Swapped-in generations get
//! the same treatment at install time, and the `swap.build` / `swap.canary`
//! clauses fail the staging pipeline at those stages.  While faults are
//! armed the worker count is clamped to 1 — fault decisions are drawn from
//! one RNG stream, and replicated workers would interleave draws
//! nondeterministically.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::{self, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::batcher::{BatchQueue, Pending};
use super::metrics::Metrics;
use super::mux;
use super::swap::{self, PendingSwap, SwapConfig, SwapError, SwapReport, SwapSlot, SwapStage};
use crate::device::{CsdQuality, QualityConfig};
use crate::kernels::{self, Scratch};
use crate::model::meta::ModelKind;
use crate::model::store::WeightStore;
use crate::quant::qsq::AssignMode;
use crate::runtime::engine::{
    DispatchPolicy, Engine, EngineKind, EngineReport, FaultInjector, PjrtEngine, PolicySelect,
};
use crate::runtime::host::{CsdEngine, F32Engine, QuantizedEngine};
use crate::tensor::{ops, Tensor};
use crate::util::json::{self, Value};

pub use crate::runtime::engine::batch_prefers_artifact;

/// Quality the `Auto` roster quantizes its code-domain engine at (the
/// canonical phi=4, N=16 point the deploy pipeline defaults to).  Public so
/// [`super::swap::SwapConfig`]'s defaults replace like with like.
pub const AUTO_QUALITY: QualityConfig = QualityConfig { phi: 4, group: 16 };

/// Digit budget the `Auto` roster's CSD engine serves at: 4 kept partial
/// products per weight keeps truncation error small while the energy policy
/// still halves-or-better the shift-and-add work of exact CSD.
pub const AUTO_CSD_DIGITS: usize = 4;

/// Longest a deployer waits for a worker to pick up and acknowledge a
/// posted generation.  Workers install between batches, so this only
/// trips if every worker is wedged in a pathological forward.
const SWAP_INSTALL_TIMEOUT: Duration = Duration::from_secs(30);

/// Which inference engine(s) the worker threads run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineSelect {
    /// Batch-aware roster: every popped batch is re-routed by the
    /// [`DispatchPolicy`] in [`ServerConfig::policy`] over the full engine
    /// roster — the PJRT artifact (threaded f32 host engine when PJRT is
    /// unavailable), the code-domain quantized engine, and the CSD
    /// shift-and-add engine.
    Auto,
    /// PJRT only; startup fails if it is unavailable.
    Pjrt,
    /// Pure-rust f32 engine (blocked/parallel GEMM).
    Host,
    /// Pure-rust code-domain engine: weights quantized at this quality and
    /// served from packed codes on the qgemm kernel.
    HostQuantized(QualityConfig),
    /// Pure-rust CSD shift-and-add engine (§V.B): weights truncated-CSD
    /// packed at this digit budget and served on `kernels::csd`, with the
    /// per-request energy ledger exported via the `engine.host-csd.*`
    /// gauge family.
    HostCsd(CsdQuality),
}

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub model: ModelKind,
    /// Compiled artifact batch (the padded execution size on PJRT).
    pub batch: usize,
    /// Dynamic batching window.
    pub max_delay: Duration,
    /// Bind address, e.g. "127.0.0.1:0" (port 0 = ephemeral).
    pub bind: String,
    /// Inference engine selection.
    pub engine: EngineSelect,
    /// Batch-dispatch policy for the `Auto` roster (ignored when the
    /// roster is pinned to a single engine).
    pub policy: PolicySelect,
    /// Admission cap on the batch queue (`--queue-cap`); 0 means "derive":
    /// 4× the batch size ([`ServerConfig::effective_queue_cap`]).
    pub queue_cap: usize,
    /// Queue-wait deadline (`--deadline-ms`): a job still queued this long
    /// after arrival is shed with a `deadline exceeded` reply.
    pub deadline: Duration,
    /// Consecutive `forward_with` failures (errors or panics) after which an
    /// engine is quarantined and routed around.
    pub quarantine_after: u32,
    /// Routed batches a quarantined engine sits out before one probe batch
    /// is sent its way (tick-based, not wall-clock, so chaos outcomes are
    /// deterministic under any pool configuration).
    pub quarantine_cooldown: u64,
    /// Batches a freshly swapped-in generation serves with the displaced
    /// engines still retained: within this window a quarantine storm rolls
    /// the old generation straight back.  0 disables probation (the old
    /// engines retire at install).
    pub probation_batches: u64,
    /// Quarantine events within the probation window that trigger an
    /// automatic rollback to the displaced generation.
    pub rollback_quarantines: u64,
    /// Replicated inference workers draining the shared queue
    /// (`--workers`); 0 derives the count from `available_parallelism`.
    /// Clamped to 1 while fault injection is armed, so chaos outcomes draw
    /// from one RNG stream deterministically
    /// ([`ServerConfig::effective_workers`]).
    pub workers: usize,
}

impl ServerConfig {
    /// The admission cap actually applied: `queue_cap`, or 4× the batch
    /// size when left at 0 — deep enough to absorb a burst of a few full
    /// batches, shallow enough that queue wait stays bounded by a handful
    /// of batch windows.
    pub fn effective_queue_cap(&self) -> usize {
        if self.queue_cap == 0 {
            self.batch.saturating_mul(4).max(1)
        } else {
            self.queue_cap
        }
    }

    /// How long a connection waits for its reply before giving up: the
    /// queue deadline (the longest a job may legitimately sit queued), one
    /// batching window, and a generous inference allowance.  Replaces the
    /// old hardcoded 30s wait, and stays consistent with `deadline` by
    /// construction.
    pub fn reply_timeout(&self) -> Duration {
        self.deadline + self.max_delay + Duration::from_secs(5)
    }

    /// The worker count actually spawned: `workers`, or
    /// `available_parallelism` when left at 0 — and always 1 while fault
    /// injection is armed (fault decisions are drawn from a single seeded
    /// stream; replicated workers would interleave draws and break the
    /// chaos determinism gate).
    pub fn effective_workers(&self) -> usize {
        if crate::util::faults::armed() {
            return 1;
        }
        if self.workers > 0 {
            self.workers
        } else {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            model: ModelKind::Lenet,
            batch: 32,
            max_delay: Duration::from_millis(5),
            bind: "127.0.0.1:0".into(),
            engine: EngineSelect::Auto,
            policy: PolicySelect::BatchFill,
            queue_cap: 0,
            deadline: Duration::from_secs(2),
            quarantine_after: 3,
            quarantine_cooldown: 64,
            probation_batches: 32,
            rollback_quarantines: 1,
            workers: 0,
        }
    }
}

/// Sentinel for [`Health::quarantined_until`]: not quarantined.
const HEALTHY: u64 = u64::MAX;

/// Per-engine failure bookkeeping for quarantine.  Atomic because N workers
/// report outcomes concurrently under the roster's *read* lock; the
/// bookkeeping rides along without forcing forwards to serialize.
struct Health {
    /// Consecutive `forward_with` failures; any success resets it.
    consecutive: AtomicU32,
    /// The route tick at which the engine becomes a probe candidate again;
    /// [`HEALTHY`] (`u64::MAX`) while not quarantined.
    quarantined_until: AtomicU64,
}

impl Health {
    fn new() -> Health {
        Health { consecutive: AtomicU32::new(0), quarantined_until: AtomicU64::new(HEALTHY) }
    }

    fn is_quarantined(&self) -> bool {
        self.quarantined_until.load(Ordering::Relaxed) != HEALTHY
    }

    /// Visible to the dispatch policy at `tick`: healthy, or quarantined
    /// with the cooldown expired (a probe candidate).
    fn available(&self, tick: u64) -> bool {
        let until = self.quarantined_until.load(Ordering::Relaxed);
        until == HEALTHY || tick >= until
    }
}

/// One generation of the roster: the engine set plus everything derived
/// from it.  Swapped wholesale under the write lock by [`Roster::install`].
struct GenerationSet {
    engines: Vec<Box<dyn Engine + Send + Sync>>,
    /// `engines[i]`'s kind, precomputed for the policy's route call.
    kinds: Vec<EngineKind>,
    /// `dispatch_<engine>` counter names, precomputed per roster index so
    /// the workers' hot loop does not format a key per batch.
    dispatch_counters: Vec<String>,
    /// `engine.<name>.quarantined` gauge names, precomputed likewise.
    quarantine_gauges: Vec<String>,
    health: Vec<Health>,
    /// The batch size the policy crossovers price against: the compiled
    /// artifact batch (the padded cost a routed batch actually pays) when a
    /// PJRT engine is on the roster, the dynamic-batching cap otherwise.
    artifact_batch: usize,
    /// Which model generation this engine set serves (1 at startup,
    /// advanced by [`Roster::install`] on every hot swap — and moved *back*
    /// on a probation rollback).  Stamped into every reply as `gen`.
    generation: u64,
}

impl GenerationSet {
    fn new(
        engines: Vec<Box<dyn Engine + Send + Sync>>,
        artifact_batch: usize,
        generation: u64,
    ) -> GenerationSet {
        let kinds = engines.iter().map(|e| e.kind()).collect();
        let dispatch_counters = engines
            .iter()
            .map(|e| format!("dispatch_{}", e.name().replace('-', "_")))
            .collect();
        let quarantine_gauges = engines
            .iter()
            .map(|e| format!("engine.{}.quarantined", e.name()))
            .collect();
        let health = engines.iter().map(|_| Health::new()).collect();
        GenerationSet {
            engines,
            kinds,
            dispatch_counters,
            quarantine_gauges,
            health,
            artifact_batch,
            generation,
        }
    }
}

/// How [`Roster::serve_batch`] resolved one batch.
pub enum BatchOutcome {
    /// The forward succeeded; real rows only (the PJRT wrapper trims its
    /// padding).
    Logits(Tensor),
    /// The engine returned an error (formatted for the terminal reply).
    Error(String),
    /// The engine panicked; the caller's scratch arena may be mid-mutation
    /// and must be rebuilt.
    Panic,
}

/// Everything a worker needs to account for one served batch, captured
/// under a single read lock so the roster indices are consistent even if an
/// install lands immediately after.
pub struct ServedBatch {
    /// Roster index the policy routed to.
    pub idx: usize,
    /// Generation that served (or failed) the batch.
    pub generation: u64,
    /// The routed engine's precomputed `dispatch_<engine>` counter key.
    pub dispatch_counter: String,
    /// Whether a failure outcome put (or kept) the engine in quarantine.
    pub quarantined_now: bool,
    /// The routed engine's report, on success (exported as the
    /// `engine.<name>.*` gauge family).
    pub report: Option<EngineReport>,
    pub outcome: BatchOutcome,
}

/// The shared engine roster: every serving engine as a boxed [`Engine`],
/// with a [`DispatchPolicy`] picking one per popped batch.  A pinned
/// [`EngineSelect`] builds a one-engine roster (the policy is then inert);
/// `Auto` builds the full roster.
///
/// Shared across the replicated inference workers behind a read-write
/// lock: [`Roster::serve_batch`] routes and forwards under a read lock
/// (concurrent across workers), and [`Roster::install`] swaps the whole
/// generation under the write lock — so an install waits for in-flight
/// batches and an in-flight batch never sees a half-swapped roster.
///
/// The roster also owns the quarantine state: batch outcomes are recorded
/// via [`Roster::note_ok`] / [`Roster::note_failure`] (atomics under the
/// read lock), and [`Roster::route`] hides quarantined engines from the
/// policy so the preference orders degrade traffic to the next engine
/// class.
pub struct Roster {
    set: RwLock<GenerationSet>,
    policy: Box<dyn DispatchPolicy + Send + Sync>,
    /// Route calls so far — the deterministic clock quarantine cooldowns
    /// count in (wall time would make chaos outcomes timing-dependent).
    tick: AtomicU64,
    /// Fast path: when false, `route` skips all quarantine filtering.
    any_quarantined: AtomicBool,
    /// Lifetime quarantine events (entries and probe-failure renewals).
    quarantine_events: AtomicU64,
    quarantine_after: u32,
    quarantine_cooldown: u64,
}

impl Roster {
    /// Build the roster `cfg` asks for over an already-loaded store.
    /// `artifacts` is the directory the PJRT artifact would compile from;
    /// pass `None` to skip the PJRT path (benches and dispatch tests run
    /// rosters over synthetic stores with no artifacts on disk).
    pub fn build(
        artifacts: Option<&Path>,
        store: WeightStore,
        cfg: &ServerConfig,
    ) -> Result<Roster> {
        let mut engines: Vec<Box<dyn Engine + Send + Sync>> = Vec::new();
        // the batch size the policy crossovers price against: the PJRT
        // engine's *compiled* batch when one is on the roster — artifact_for
        // rounds cfg.batch up to a compiled size, and that padded size is
        // the cost a routed batch actually pays, whatever the dynamic
        // batcher's cap is — cfg.batch otherwise
        let mut artifact_batch = cfg.batch;
        match cfg.engine {
            EngineSelect::Pjrt => {
                let dir = artifacts.context("PJRT engine needs an artifacts directory")?;
                let p = PjrtEngine::load(dir, cfg.model, cfg.batch, &store)?;
                artifact_batch = p.batch();
                engines.push(Box::new(p));
            }
            EngineSelect::Host => engines.push(Box::new(F32Engine::new(store))),
            EngineSelect::HostQuantized(q) => engines.push(Box::new(
                QuantizedEngine::quantize_store(&store, q, AssignMode::SigmaSearch)?,
            )),
            EngineSelect::HostCsd(q) => {
                engines.push(Box::new(CsdEngine::from_store(&store, q)?))
            }
            EngineSelect::Auto => {
                // a packing failure must not take Auto down: each engine
                // that fails to build is simply absent from the roster, and
                // the policies' preference orders route around it
                let pjrt = artifacts.and_then(|dir| {
                    match PjrtEngine::load(dir, cfg.model, cfg.batch, &store) {
                        Ok(p) => Some(p),
                        Err(e) => {
                            eprintln!(
                                "server: PJRT unavailable ({e:#}); the f32 host engine \
                                 serves artifact-sized batches"
                            );
                            None
                        }
                    }
                });
                let quant =
                    QuantizedEngine::quantize_store(&store, AUTO_QUALITY, AssignMode::SigmaSearch);
                match quant {
                    Ok(q) => engines.push(Box::new(q)),
                    Err(e) => eprintln!("server: quantized engine unavailable ({e:#})"),
                }
                match CsdEngine::from_store(&store, CsdQuality::new(AUTO_CSD_DIGITS)) {
                    Ok(c) => engines.push(Box::new(c)),
                    Err(e) => eprintln!("server: csd engine unavailable ({e:#})"),
                }
                // artifact-class engine last: PJRT when live (the weights
                // already sit in its prebuilt args), the f32 store otherwise
                match pjrt {
                    Some(p) => {
                        artifact_batch = p.batch();
                        engines.push(Box::new(p));
                    }
                    None => engines.push(Box::new(F32Engine::new(store))),
                }
            }
        }
        if engines.is_empty() {
            bail!("no engine could be built for {:?}", cfg.engine);
        }
        if artifact_batch > cfg.batch && engines.len() > 1 {
            // the dynamic batcher can never form a batch that fills the
            // compiled artifact — under latency-floor the artifact engine
            // will (correctly: every batch would pay padding) see no traffic
            eprintln!(
                "server: compiled artifact batch {artifact_batch} exceeds the batching \
                 cap {}; padding-averse policies will keep batches on the host engines",
                cfg.batch
            );
        }
        // chaos harness: with fault injection armed at build time, every
        // roster engine is wrapped so injected errors/panics/delays hit the
        // exact forward path real failures would.  Disarmed (the normal
        // case), the wrapper is never constructed and the serving hot path
        // carries zero fault-layer code.
        if crate::util::faults::armed() {
            engines = engines
                .into_iter()
                .map(|e| Box::new(FaultInjector::new(e)) as Box<dyn Engine + Send + Sync>)
                .collect();
        }
        Ok(Roster {
            set: RwLock::new(GenerationSet::new(engines, artifact_batch, 1)),
            policy: cfg.policy.build(),
            tick: AtomicU64::new(0),
            any_quarantined: AtomicBool::new(false),
            quarantine_events: AtomicU64::new(0),
            quarantine_after: cfg.quarantine_after.max(1),
            quarantine_cooldown: cfg.quarantine_cooldown.max(1),
        })
    }

    /// Read-lock the generation set.  Poison-tolerant: engine panics are
    /// caught *inside* [`Roster::serve_batch`]'s closure (the guard lives
    /// outside it), so a poisoned lock can only mean a panic in roster
    /// bookkeeping itself — the data is still a coherent generation, and
    /// refusing to serve would turn one bug into a full outage.
    fn read(&self) -> RwLockReadGuard<'_, GenerationSet> {
        self.set.read().unwrap_or_else(|e| e.into_inner())
    }

    fn write(&self) -> RwLockWriteGuard<'_, GenerationSet> {
        self.set.write().unwrap_or_else(|e| e.into_inner())
    }

    /// The model generation currently serving.
    pub fn generation(&self) -> u64 {
        self.read().generation
    }

    /// The batch size the dispatch policy prices crossovers against.
    pub fn artifact_batch(&self) -> usize {
        self.read().artifact_batch
    }

    /// Atomically replace the engine set (hot swap / rollback): the new
    /// engines take over with fresh health, dispatch and quarantine
    /// bookkeeping, and the roster starts reporting `generation`.  Returns
    /// the displaced engines — the caller keeps them through the probation
    /// window (rollback reinstalls them) or drops them to retire.  Policy
    /// and quarantine thresholds persist across generations; the route tick
    /// keeps counting so cooldown arithmetic never goes backwards.  Takes
    /// the write lock, so the install waits out in-flight forwards and no
    /// worker ever sees a half-swapped roster.
    pub fn install(
        &self,
        engines: Vec<Box<dyn Engine + Send + Sync>>,
        generation: u64,
        artifact_batch: usize,
    ) -> Vec<Box<dyn Engine + Send + Sync>> {
        assert!(!engines.is_empty(), "a roster generation needs at least one engine");
        let mut set = self.write();
        self.any_quarantined.store(false, Ordering::Relaxed);
        std::mem::replace(&mut *set, GenerationSet::new(engines, artifact_batch, generation))
            .engines
    }

    /// Backend label for the startup `engine_*` counter: the pinned engine's
    /// name, or `auto-hybrid` for a policy-routed roster.
    pub fn name(&self) -> &'static str {
        let set = self.read();
        if set.engines.len() == 1 {
            set.engines[0].name()
        } else {
            "auto-hybrid"
        }
    }

    /// The active dispatch policy's name.
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn len(&self) -> usize {
        self.read().engines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The kind of the engine at roster index `i`.
    pub fn kind_of(&self, i: usize) -> EngineKind {
        self.read().kinds[i]
    }

    /// The stable name of the engine at roster index `i`.
    pub fn engine_name(&self, i: usize) -> &'static str {
        self.read().engines[i].name()
    }

    /// The lifetime report of the engine at roster index `i`.
    pub fn report_of(&self, i: usize) -> EngineReport {
        self.read().engines[i].report()
    }

    /// Every roster engine's report, in roster order (telemetry export).
    pub fn reports(&self) -> Vec<EngineReport> {
        self.read().engines.iter().map(|e| e.report()).collect()
    }

    /// The precomputed `dispatch_<engine>` counter key for roster index `i`.
    pub fn dispatch_counter(&self, i: usize) -> String {
        self.read().dispatch_counters[i].clone()
    }

    /// Emit every engine's `engine.<name>.quarantined` gauge (1.0/0.0).
    pub fn export_quarantine_gauges(&self, mut f: impl FnMut(&str, f64)) {
        let set = self.read();
        for (g, h) in set.quarantine_gauges.iter().zip(&set.health) {
            f(g, if h.is_quarantined() { 1.0 } else { 0.0 });
        }
    }

    /// Whether roster index `i` is currently quarantined.
    pub fn quarantined(&self, i: usize) -> bool {
        self.read().health[i].is_quarantined()
    }

    /// Whether any engine is currently quarantined.
    pub fn any_quarantined(&self) -> bool {
        self.any_quarantined.load(Ordering::Relaxed)
    }

    /// Lifetime quarantine events (initial entries plus probe-failure
    /// renewals).
    pub fn quarantine_events(&self) -> u64 {
        self.quarantine_events.load(Ordering::Relaxed)
    }

    fn route_locked(&self, set: &GenerationSet, n: usize) -> usize {
        let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
        if set.engines.len() == 1 {
            return 0;
        }
        if !self.any_quarantined.load(Ordering::Relaxed) {
            return self
                .policy
                .route(n, set.artifact_batch, &set.kinds)
                .min(set.engines.len() - 1);
        }
        let mut avail_kinds = Vec::with_capacity(set.kinds.len());
        let mut avail_idx = Vec::with_capacity(set.kinds.len());
        for (i, h) in set.health.iter().enumerate() {
            if h.available(tick) {
                avail_kinds.push(set.kinds[i]);
                avail_idx.push(i);
            }
        }
        if avail_idx.is_empty() {
            return self
                .policy
                .route(n, set.artifact_batch, &set.kinds)
                .min(set.engines.len() - 1);
        }
        let j = self
            .policy
            .route(n, set.artifact_batch, &avail_kinds)
            .min(avail_idx.len() - 1);
        avail_idx[j]
    }

    fn note_ok_locked(&self, set: &GenerationSet, i: usize) {
        let h = &set.health[i];
        h.consecutive.store(0, Ordering::Relaxed);
        if h.is_quarantined() {
            h.quarantined_until.store(HEALTHY, Ordering::Relaxed);
            self.any_quarantined.store(
                set.health.iter().any(|h| h.is_quarantined()),
                Ordering::Relaxed,
            );
        }
    }

    fn note_failure_locked(&self, set: &GenerationSet, i: usize) -> bool {
        let h = &set.health[i];
        let streak = h.consecutive.fetch_add(1, Ordering::Relaxed) + 1;
        if streak >= self.quarantine_after || h.is_quarantined() {
            h.quarantined_until.store(
                self.tick.load(Ordering::Relaxed) + self.quarantine_cooldown,
                Ordering::Relaxed,
            );
            self.any_quarantined.store(true, Ordering::Relaxed);
            self.quarantine_events.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }

    /// The roster index the policy routes an `n`-row batch to.  Quarantined
    /// engines are invisible to the policy until their cooldown expires
    /// (then exactly eligible again — the next batch they win is their
    /// probe); if *everything* is quarantined the full roster is used, since
    /// routing around every engine would mean serving nothing.
    pub fn route(&self, n: usize) -> usize {
        let set = self.read();
        self.route_locked(&set, n)
    }

    /// Record a successful forward on roster index `i`: resets its failure
    /// streak, and — if this was a probe of a quarantined engine —
    /// reinstates it.
    pub fn note_ok(&self, i: usize) {
        let set = self.read();
        self.note_ok_locked(&set, i);
    }

    /// Record a failed forward (error or panic) on roster index `i`.
    /// Returns `true` when this failure put (or kept) the engine in
    /// quarantine — a fresh entry after `quarantine_after` consecutive
    /// failures, or an immediate renewal when a probe of an
    /// already-quarantined engine fails.
    pub fn note_failure(&self, i: usize) -> bool {
        let set = self.read();
        self.note_failure_locked(&set, i)
    }

    /// Forward one batch on roster index `i` with no health bookkeeping
    /// (chaos tests drive route/forward/note_* granularly to observe the
    /// fault stream; the serving workers use [`Roster::serve_batch`]).
    pub fn forward(&self, i: usize, x: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        self.read().engines[i].forward_with(x, scratch)
    }

    /// Route and execute one batch; returns the chosen roster index and the
    /// logits (real rows only — the PJRT wrapper trims its padding).  The
    /// outcome feeds the quarantine bookkeeping.
    pub fn dispatch(&self, x: &Tensor, scratch: &mut Scratch) -> Result<(usize, Tensor)> {
        let set = self.read();
        let i = self.route_locked(&set, x.shape()[0]);
        match set.engines[i].forward_with(x, scratch) {
            Ok(logits) => {
                self.note_ok_locked(&set, i);
                Ok((i, logits))
            }
            Err(e) => {
                self.note_failure_locked(&set, i);
                Err(e)
            }
        }
    }

    /// Route, forward (supervised), and record one batch under a *single*
    /// read lock — the serving workers' whole per-batch roster interaction.
    /// The `catch_unwind` wraps only the engine forward, *inside* the
    /// guard's scope: a panicking engine never unwinds past the lock, so
    /// the roster cannot be poisoned by the failure modes it exists to
    /// absorb.  (The caller's scratch arena may be mid-mutation after a
    /// [`BatchOutcome::Panic`] and must be rebuilt.)
    pub fn serve_batch(&self, x: &Tensor, scratch: &mut Scratch) -> ServedBatch {
        let set = self.read();
        let idx = self.route_locked(&set, x.shape()[0]);
        let engine = set.engines[idx].as_ref();
        let caught =
            panic::catch_unwind(AssertUnwindSafe(|| engine.forward_with(x, scratch)));
        let (quarantined_now, report, outcome) = match caught {
            Ok(Ok(logits)) => {
                self.note_ok_locked(&set, idx);
                (false, Some(engine.report()), BatchOutcome::Logits(logits))
            }
            Ok(Err(e)) => (
                self.note_failure_locked(&set, idx),
                None,
                BatchOutcome::Error(format!("{e:#}")),
            ),
            Err(_) => (self.note_failure_locked(&set, idx), None, BatchOutcome::Panic),
        };
        ServedBatch {
            idx,
            generation: set.generation,
            dispatch_counter: set.dispatch_counters[idx].clone(),
            quarantined_now,
            report,
            outcome,
        }
    }
}

/// Copy a dynamic batch into one [rows, H, W, C] tensor; `rows` beyond the
/// batch stay zero.  The worker passes `rows == batch.len()` — any padding
/// to a compiled artifact size happens inside the PJRT engine wrapper.
fn batch_tensor(
    batch: &[Pending<Job>],
    rows: usize,
    h: usize,
    w: usize,
    c: usize,
) -> Result<Tensor> {
    let pix = h * w * c;
    let mut xdata = vec![0.0f32; rows * pix];
    for (i, job) in batch.iter().enumerate() {
        xdata[i * pix..(i + 1) * pix].copy_from_slice(&job.payload.pixels);
    }
    Tensor::new(vec![rows, h, w, c], xdata)
}

/// One admitted inference request: parsed by the mux front end, batched by
/// the queue, served by a worker, and answered through `resp` (the mux
/// holds the receiving end in the connection's in-flight table).
pub(crate) struct Job {
    pub(crate) id: u64,
    pub(crate) pixels: Vec<f32>,
    pub(crate) enqueued: Instant,
    pub(crate) resp: mpsc::Sender<Value>,
}

/// Reply `{"id":..,"error":..}` to one job (terminal error path).
fn reply_error(job: &Pending<Job>, msg: &str) {
    let resp = json::obj(vec![
        ("id", json::num(job.payload.id as f64)),
        ("error", json::s(msg)),
    ]);
    let _ = job.payload.resp.send(resp);
}

/// Where the workers get their weights: an artifact directory on disk (the
/// CLI path — also enables PJRT), or an in-memory store (tests and benches
/// serve synthetic models with nothing on disk).
enum EngineSource {
    Artifacts(PathBuf),
    Store(WeightStore),
}

/// The displaced generation, retained while a swapped-in one proves
/// itself.  Shared across workers under a mutex — the probation window and
/// rollback trigger are global, not per-worker.  Dropped (engines retire)
/// when `left` reaches 0; moved back into the roster on a quarantine storm.
struct Probation {
    generation: u64,
    engines: Vec<Box<dyn Engine + Send + Sync>>,
    artifact_batch: usize,
    /// Served batches remaining in the window (across all workers).
    left: u64,
    /// `Roster::quarantine_events` at install time — events above this
    /// baseline were earned by the new generation.
    baseline: u64,
}

/// Prepare a staged generation's engines for install — mirroring
/// [`Roster::build`], wrap each in a [`FaultInjector`] when chaos is
/// armed, so injected faults hit swapped-in generations exactly like the
/// boot generation.
fn wrap_generation(
    engines: Vec<Box<dyn Engine + Send + Sync>>,
) -> Vec<Box<dyn Engine + Send + Sync>> {
    if !crate::util::faults::armed() {
        return engines;
    }
    engines
        .into_iter()
        .map(|e| Box::new(FaultInjector::new(e)) as Box<dyn Engine + Send + Sync>)
        .collect()
}

/// A running server; `stop()` for graceful shutdown,
/// [`deploy_store`](Server::deploy_store) for zero-downtime model swaps.
pub struct Server {
    pub port: u16,
    pub metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    queue: Arc<BatchQueue<Job>>,
    /// Mailbox between deploy callers and the serving workers.
    swap: Arc<SwapSlot>,
    /// Next generation number a successful deploy gets (boot roster is 1).
    next_gen: AtomicU64,
    handles: Vec<JoinHandle<()>>,
}

/// Everything one replicated inference worker needs (bundled so the spawn
/// site stays readable).
struct WorkerCtx {
    index: usize,
    cfg: ServerConfig,
    queue: Arc<BatchQueue<Job>>,
    metrics: Arc<Metrics>,
    roster: Arc<Roster>,
    slot: Arc<SwapSlot>,
    probation: Arc<Mutex<Option<Probation>>>,
}

impl Server {
    /// Start the server; blocks until the weights are loaded and the roster
    /// (including any PJRT artifact compile) is built, so the first request
    /// is never a cold start.
    pub fn start(artifacts: PathBuf, cfg: ServerConfig) -> Result<Server> {
        Self::start_inner(EngineSource::Artifacts(artifacts), cfg)
    }

    /// Start the server over an already-loaded weight store, with no
    /// artifacts on disk (the PJRT path is skipped).  Chaos tests and the
    /// overload bench serve synthetic stores this way.
    pub fn start_with_store(store: WeightStore, cfg: ServerConfig) -> Result<Server> {
        Self::start_inner(EngineSource::Store(store), cfg)
    }

    fn start_inner(source: EngineSource, cfg: ServerConfig) -> Result<Server> {
        // arm fault injection from PALLAS_FAULTS before the roster builds
        // (the build wraps engines only when armed); a malformed spec fails
        // startup loudly rather than running a chaos scenario fault-free
        crate::util::faults::arm_from_env()?;
        // reject an unparsable PALLAS_POOL_THREADS here, before the global
        // pool lazily initializes: a typo'd width must fail startup, not
        // silently serve at the default
        crate::kernels::pool::validate_env().map_err(|e| anyhow::anyhow!(e))?;
        let listener = TcpListener::bind(&cfg.bind)
            .with_context(|| format!("binding {}", cfg.bind))?;
        listener.set_nonblocking(true)?;
        let port = listener.local_addr()?.port();

        let shutdown = Arc::new(AtomicBool::new(false));
        let queue = Arc::new(BatchQueue::<Job>::bounded(
            cfg.batch,
            cfg.max_delay,
            cfg.effective_queue_cap(),
            Some(cfg.deadline),
        ));
        let metrics = Arc::new(Metrics::new());
        let swap_slot = Arc::new(SwapSlot::new());

        // build the shared roster on *this* thread: startup failures surface
        // directly, and callers return with the model loaded (and any PJRT
        // artifact compiled) — the first request is never a cold start
        let roster = Arc::new(match source {
            EngineSource::Artifacts(dir) => {
                let store = WeightStore::load(&dir, cfg.model)?;
                Roster::build(Some(&dir), store, &cfg)?
            }
            EngineSource::Store(store) => Roster::build(None, store, &cfg)?,
        });
        metrics.inc(&format!("engine_{}", roster.name()), 1);
        metrics.inc(&format!("policy_{}", roster.policy_name()), 1);
        metrics.set_gauge("swap.generation", roster.generation() as f64);

        let workers = cfg.effective_workers();
        metrics.set_gauge("workers", workers as f64);
        let probation: Arc<Mutex<Option<Probation>>> = Arc::new(Mutex::new(None));

        let mut handles = Vec::with_capacity(workers + 1);
        for index in 0..workers {
            let ctx = WorkerCtx {
                index,
                cfg: cfg.clone(),
                queue: queue.clone(),
                metrics: metrics.clone(),
                roster: roster.clone(),
                slot: swap_slot.clone(),
                probation: probation.clone(),
            };
            handles.push(
                thread::Builder::new()
                    .name(format!("infer-worker-{index}"))
                    .spawn(move || worker_loop(ctx))?,
            );
        }

        let pix_expected = {
            let (h, w, c) = cfg.model.input_hwc();
            h * w * c
        };
        let params = mux::MuxParams {
            queue: queue.clone(),
            metrics: metrics.clone(),
            roster,
            shutdown: shutdown.clone(),
            pix_expected,
            reply_timeout: cfg.reply_timeout(),
            workers,
        };
        handles.push(
            thread::Builder::new()
                .name("mux".into())
                .spawn(move || mux::run(listener, params))?,
        );

        Ok(Server {
            port,
            metrics,
            shutdown,
            queue,
            swap: swap_slot,
            next_gen: AtomicU64::new(2),
            handles,
        })
    }

    /// Hot-swap the serving model to `store` with zero downtime: stage a
    /// complete replacement generation through the [`super::swap`] pipeline
    /// (encode → noisy-channel transfer → hardened decode → engine build →
    /// canary gate) on *this* thread, then hand it to the serving workers;
    /// whichever reaches its between-batches check first installs it.
    /// Blocks until the install is acknowledged (bounded by an internal
    /// timeout) and returns the [`SwapReport`].
    ///
    /// On any failure the old generation keeps serving untouched; the
    /// matching `swap.fail.*` / `swap.canary_rejects` counter and
    /// `swap.failed` are bumped, and the returned error downcasts to
    /// [`SwapError`] naming the stage (with the partial
    /// [`TransferReport`](crate::channel::TransferReport) reachable under a
    /// transfer failure).
    pub fn deploy_store(&self, store: &WeightStore, cfg: &SwapConfig) -> Result<SwapReport> {
        let t0 = Instant::now();
        self.metrics.inc("swap.attempts", 1);
        let staged = match swap::stage(store, cfg) {
            Ok(s) => s,
            Err(e) => {
                let stage = e
                    .downcast_ref::<SwapError>()
                    .map_or(SwapStage::Build, |se| se.stage);
                self.metrics.inc(stage.fail_counter(), 1);
                self.metrics.inc("swap.failed", 1);
                return Err(e);
            }
        };
        let generation = self.next_gen.fetch_add(1, Ordering::Relaxed);
        if let Err(e) = self
            .swap
            .post(PendingSwap { generation, engines: staged.engines })
        {
            self.metrics.inc(SwapStage::Install.fail_counter(), 1);
            self.metrics.inc("swap.failed", 1);
            return Err(e);
        }
        // wake a worker even with no traffic flowing: the kicked queue
        // returns an empty pop to exactly one worker, which notices the
        // pending generation without waiting out a batch window
        self.queue.kick();
        if let Err(e) = self.swap.wait_installed(generation, SWAP_INSTALL_TIMEOUT) {
            self.metrics.inc(SwapStage::Install.fail_counter(), 1);
            self.metrics.inc("swap.failed", 1);
            return Err(e);
        }
        self.metrics.inc("swap.installs", 1);
        let elapsed_s = t0.elapsed().as_secs_f64();
        self.metrics.set_gauge("swap.last_latency_ms", elapsed_s * 1e3);
        Ok(SwapReport {
            generation,
            container_bytes: staged.container_bytes,
            transfer: staged.transfer,
            canary: staged.canary,
            elapsed_s,
        })
    }

    /// Graceful shutdown: stop accepting, drain the queue, join threads.
    /// Every queued-but-unserved job gets an explicit `server shutting
    /// down` reply (counted in `shed_shutdown`) — dropping their response
    /// senders would leave those clients hanging until their reply timeout.
    /// The mux flushes the terminal replies to their connections before
    /// exiting.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // give in-flight connection reads a beat, then close the queue
        thread::sleep(Duration::from_millis(20));
        let backlog = self.queue.close();
        if !backlog.is_empty() {
            self.metrics.inc("shed_shutdown", backlog.len() as u64);
            for job in &backlog {
                reply_error(job, "server shutting down");
            }
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One replicated inference worker: drains the shared queue, serves batches
/// over the shared roster, and runs the between-batches checks (hot-swap
/// pickup, probation accounting) that used to belong to the single owner
/// thread.  Any worker may pick up a posted swap; probation is global.
fn worker_loop(ctx: WorkerCtx) {
    let (h, w, c) = ctx.cfg.model.input_hwc();
    // one arena per worker: the host engines stop allocating per request
    // once the buffers are warm
    let mut scratch = Scratch::new();
    // the persistent kernel pool the host engines dispatch bands on; its
    // spawn counter stays flat once serving is warm
    let pool = kernels::Pool::global();
    // per-worker gauge keys, formatted once (docs/METRICS.md: worker.<i>.*)
    let batches_key = format!("worker.{}.batches", ctx.index);
    let ewma_key = format!("worker.{}.ewma_ms", ctx.index);
    let mut my_batches = 0u64;

    while let Some(popped) = ctx.queue.pop_batch() {
        // hot-swap pickup: installs land here, *between* this worker's
        // batches; the roster's write lock makes other workers' in-flight
        // batches finish on the generation that started them
        // (deploy_store kicks the queue, so an idle worker reaches this
        // point without waiting for traffic)
        if ctx.slot.has_pending() {
            if let Some(p) = ctx.slot.take_pending() {
                let gen = p.generation;
                // probation mutex held across the install so no other
                // worker runs storm accounting against a half-updated pair
                let mut prob = ctx.probation.lock().unwrap();
                let displaced_gen = ctx.roster.generation();
                let displaced_ab = ctx.roster.artifact_batch();
                let displaced =
                    ctx.roster.install(wrap_generation(p.engines), gen, ctx.cfg.batch);
                *prob = if ctx.cfg.probation_batches > 0 {
                    Some(Probation {
                        generation: displaced_gen,
                        engines: displaced,
                        artifact_batch: displaced_ab,
                        left: ctx.cfg.probation_batches,
                        baseline: ctx.roster.quarantine_events(),
                    })
                } else {
                    None // probation disabled: the old engines retire now
                };
                ctx.metrics.set_gauge("swap.generation", gen as f64);
                ctx.metrics.set_gauge(
                    "swap.probation_left",
                    prob.as_ref().map_or(0.0, |p| p.left as f64),
                );
                drop(prob);
                ctx.slot.ack_installed(gen);
            }
        }
        // deadline sheds: terminal replies, no kernel slot spent
        for job in &popped.expired {
            ctx.metrics.inc("shed_deadline", 1);
            reply_error(job, "deadline exceeded");
        }
        ctx.metrics.set_gauge("queue.depth", ctx.queue.len() as f64);
        let batch = popped.jobs;
        if batch.is_empty() {
            continue;
        }
        let t0 = Instant::now();
        let n = batch.len();
        let x = match batch_tensor(&batch, n, h, w, c) {
            Ok(x) => x,
            Err(e) => {
                let msg = format!("{e:#}");
                for job in &batch {
                    reply_error(job, &msg);
                }
                continue;
            }
        };
        let served = ctx.roster.serve_batch(&x, &mut scratch);
        match served.outcome {
            BatchOutcome::Logits(ref logits) => {
                let preds = ops::argmax_rows(logits);
                ctx.metrics.inc(&served.dispatch_counter, 1);
                let infer_s = t0.elapsed().as_secs_f64();
                ctx.metrics.observe_s("infer_batch", infer_s);
                // smoothed batch time, the retry_after_ms basis for
                // overload sheds on the admission path
                ctx.metrics.observe_ewma("infer_batch.ewma_ms", infer_s * 1e3);
                ctx.metrics.inc("batches", 1);
                ctx.metrics.inc("requests", n as u64);
                my_batches += 1;
                ctx.metrics.set_gauge(&batches_key, my_batches as f64);
                ctx.metrics.observe_ewma(&ewma_key, infer_s * 1e3);
                // pool + arena telemetry: spawns must stay flat once warm
                // (a moving spawn gauge is a perf regression), and the
                // per-layer high-water marks show how much arena each layer
                // of the served model really needs
                let ps = pool.stats();
                ctx.metrics.set_gauge("pool.spawns", ps.spawns as f64);
                ctx.metrics.set_gauge("pool.wakeups", ps.wakeups as f64);
                ctx.metrics.set_gauge("pool.jobs", ps.jobs as f64);
                ctx.metrics.set_gauge("pool.pin_hits", ps.pin_hits as f64);
                ctx.metrics.set_gauge("pool.pin_misses", ps.pin_misses as f64);
                for (layer, pk) in scratch.layer_peaks() {
                    ctx.metrics.set_gauge(
                        &format!("scratch_hw.{layer}.patch_bytes"),
                        pk.patch_bytes as f64,
                    );
                    ctx.metrics.set_gauge(
                        &format!("scratch_hw.{layer}.pad_bytes"),
                        pk.pad_bytes as f64,
                    );
                    ctx.metrics.set_gauge(
                        &format!("scratch_hw.{layer}.act_bytes"),
                        pk.act_bytes as f64,
                    );
                }
                // uniform per-engine telemetry: the engine that served this
                // batch exports the `engine.<name>.*` gauge family from its
                // EngineReport — forwards, zero-skip, mean partial
                // products, the lifetime energy ledger (divide by
                // `.forwards` for per-batch numbers, by counter.requests
                // for per-request — docs/METRICS.md).  Only the routed
                // engine's report can have changed, so the other roster
                // members' gauges stay at their last export.
                if let Some(rep) = &served.report {
                    rep.export(|k, v| ctx.metrics.set_gauge(k, v));
                }
                for (i, job) in batch.into_iter().enumerate() {
                    let e2e = job.payload.enqueued.elapsed();
                    ctx.metrics.observe_s("request_e2e", e2e.as_secs_f64());
                    let resp = json::obj(vec![
                        ("id", json::num(job.payload.id as f64)),
                        ("pred", json::num(preds[i] as f64)),
                        ("latency_us", json::num(e2e.as_micros() as f64)),
                        ("batch", json::num(n as f64)),
                        ("gen", json::num(served.generation as f64)),
                    ]);
                    let _ = job.payload.resp.send(resp);
                }
            }
            BatchOutcome::Error(ref msg) => {
                // engine error: fail only this batch, keep serving
                if served.quarantined_now {
                    ctx.metrics.inc("quarantines", 1);
                }
                ctx.metrics.inc("engine_failures", 1);
                for job in &batch {
                    reply_error(job, msg);
                }
            }
            BatchOutcome::Panic => {
                // engine panic: the arena may be mid-mutation — rebuild it,
                // fail this batch, keep the roster and keep serving
                scratch = Scratch::new();
                if served.quarantined_now {
                    ctx.metrics.inc("quarantines", 1);
                }
                ctx.metrics.inc("worker_panics", 1);
                for job in &batch {
                    reply_error(job, "engine panicked; batch failed");
                }
            }
        }
        // probation accounting for the batch just served — global, under
        // the shared mutex: a quarantine storm earned by the new generation
        // rolls the displaced one straight back (whichever worker observes
        // it; taking the Option makes the rollback happen exactly once);
        // otherwise the window shrinks and, once cleared, the displaced
        // engines retire
        let mut prob = ctx.probation.lock().unwrap();
        let storm = prob.as_ref().is_some_and(|p| {
            ctx.roster.quarantine_events()
                >= p.baseline + ctx.cfg.rollback_quarantines.max(1)
        });
        if storm {
            let p = prob.take().unwrap();
            let rolled_gen = p.generation;
            ctx.roster.install(p.engines, p.generation, p.artifact_batch);
            ctx.metrics.inc("swap.rollbacks", 1);
            ctx.metrics.set_gauge("swap.generation", rolled_gen as f64);
            ctx.metrics.set_gauge("swap.probation_left", 0.0);
            eprintln!(
                "server: quarantine storm during probation; rolled back to \
                 generation {rolled_gen}"
            );
        } else if let Some(p) = prob.as_mut() {
            p.left -= 1;
            ctx.metrics.set_gauge("swap.probation_left", p.left as f64);
            if p.left == 0 {
                *prob = None; // window cleared; displaced engines retire
            }
        }
        drop(prob);
        ctx.roster
            .export_quarantine_gauges(|k, v| ctx.metrics.set_gauge(k, v));
    }
    // queue closed: no deploy can ever land again — fail any in-flight or
    // future deploy instead of leaving it blocked (idempotent across the
    // replicated workers; the first to exit flips the slot)
    ctx.slot.mark_dead("server shut down");
}

/// The backoff hint attached to an `overloaded` shed: the time to drain the
/// current backlog, estimated as (batches queued) × (observed per-batch
/// inference EWMA).  Before the first batch completes there is no EWMA yet;
/// one batching window is the honest floor.
pub(crate) fn retry_after_ms(queue: &BatchQueue<Job>, metrics: &Metrics) -> f64 {
    let ewma_ms = metrics
        .gauge("infer_batch.ewma_ms")
        .unwrap_or_else(|| queue.max_delay.as_secs_f64() * 1e3);
    let backlog_batches = queue.len().div_ceil(queue.max_batch).max(1);
    (ewma_ms * backlog_batches as f64).ceil().max(1.0)
}

/// Simple blocking client for examples/tests (one request in flight at a
/// time; the mux front end also accepts pipelined traffic from clients
/// that key replies by `id`).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Send one request, wait for its reply.
    pub fn infer(&mut self, id: u64, pixels: &[f32]) -> Result<Value> {
        let req = json::obj(vec![
            ("id", json::num(id as f64)),
            (
                "pixels",
                Value::Arr(pixels.iter().map(|&p| json::num(p as f64)).collect()),
            ),
        ]);
        self.writer.write_all(req.to_json().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        json::parse(&line).map_err(|e| anyhow::anyhow!("bad reply: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_sane() {
        let c = ServerConfig::default();
        assert_eq!(c.batch, 32);
        assert!(c.bind.ends_with(":0"));
        assert_eq!(c.engine, EngineSelect::Auto);
        assert_eq!(c.policy, PolicySelect::BatchFill);
        // admission-control defaults: cap derives from the batch size, the
        // client reply wait strictly dominates the queue deadline
        assert_eq!(c.queue_cap, 0);
        assert_eq!(c.effective_queue_cap(), 4 * 32);
        assert_eq!(
            ServerConfig { queue_cap: 7, ..ServerConfig::default() }.effective_queue_cap(),
            7
        );
        assert_eq!(c.deadline, Duration::from_secs(2));
        assert!(c.reply_timeout() > c.deadline + c.max_delay);
        assert_eq!(c.quarantine_after, 3);
        assert_eq!(c.quarantine_cooldown, 64);
        // hot-swap probation defaults: a one-quarantine storm inside a
        // 32-batch window rolls back
        assert_eq!(c.probation_batches, 32);
        assert_eq!(c.rollback_quarantines, 1);
        // worker replication: 0 derives from available_parallelism, an
        // explicit count is honored verbatim (fault injection is never
        // armed inside unit tests, so no clamp applies here)
        assert_eq!(c.workers, 0);
        assert!(c.effective_workers() >= 1);
        assert_eq!(
            ServerConfig { workers: 3, ..ServerConfig::default() }.effective_workers(),
            3
        );
    }

    use crate::data::synth_store;
    use crate::util::rng::Rng;

    fn synth_batch(r: &mut Rng, n: usize) -> Tensor {
        let xdata: Vec<f32> = (0..n * 28 * 28).map(|_| r.f32()).collect();
        Tensor::new(vec![n, 28, 28, 1], xdata).unwrap()
    }

    /// The acceptance route map: `--engine auto --policy energy` must reach
    /// every engine class — PJRT-or-f32 for artifact-filling batches, the
    /// code-domain engine for mid-size, and the CSD engine (previously
    /// unreachable from Auto) for the smallest — with every route's
    /// `engine.*` gauges populated from the same EngineReport schema.
    #[test]
    fn energy_policy_routes_each_engine_and_exports_uniform_gauges() {
        let store = synth_store(71, ModelKind::Lenet);
        let cfg = ServerConfig { policy: PolicySelect::EnergyBudget, ..Default::default() };
        // no artifacts on disk -> the artifact-class slot is the f32 engine
        let roster = Roster::build(None, store, &cfg).unwrap();
        assert_eq!(roster.len(), 3, "auto roster: qgemm2 + csd + f32");
        assert_eq!(roster.name(), "auto-hybrid");
        assert_eq!(roster.policy_name(), "energy-budget");

        let m = Metrics::new();
        let mut scratch = Scratch::new();
        let mut r = Rng::new(72);
        let mut routed = std::collections::BTreeSet::new();
        for n in [1usize, 5, 32] {
            let x = synth_batch(&mut r, n);
            let (i, logits) = roster.dispatch(&x, &mut scratch).unwrap();
            assert_eq!(logits.shape(), &[n, 10], "n={n}");
            routed.insert(roster.kind_of(i));
        }
        assert_eq!(
            routed.into_iter().collect::<Vec<_>>(),
            vec![EngineKind::F32, EngineKind::Quantized, EngineKind::Csd],
            "energy policy must route a batch to each engine class"
        );

        // every engine's report lands in the uniform engine.* gauge family
        for rep in roster.reports() {
            rep.export(|k, v| m.set_gauge(k, v));
        }
        for name in ["host-f32", "host-qgemm", "host-csd"] {
            assert_eq!(
                m.gauge(&format!("engine.{name}.forwards")),
                Some(1.0),
                "{name}: exactly one batch routed"
            );
            for suffix in [
                "skipped_fraction",
                "mean_pp",
                "energy.partial_products",
                "energy.fp_muls",
                "energy.compute_pj",
                "energy.total_pj",
                "pool.spawns",
                "pool.pin_hits",
                "pool.pin_misses",
            ] {
                assert!(
                    m.gauge(&format!("engine.{name}.{suffix}")).is_some(),
                    "engine.{name}.{suffix} missing from the uniform schema"
                );
            }
        }
        // and the fields mean what they say: the CSD route spent partial
        // products, the f32 route spent fp32 MACs, the code-domain route
        // skipped zero codes and charged only its fp32 head
        assert!(m.gauge("engine.host-csd.energy.partial_products").unwrap() > 0.0);
        assert!(m.gauge("engine.host-csd.mean_pp").unwrap() > 0.0);
        assert!(m.gauge("engine.host-f32.energy.fp_muls").unwrap() > 0.0);
        assert!(m.gauge("engine.host-qgemm.skipped_fraction").unwrap() > 0.0);
        let head = m.gauge("engine.host-qgemm.energy.fp_muls").unwrap();
        let full = m.gauge("engine.host-f32.energy.fp_muls").unwrap();
        assert!(head > 0.0 && head < full, "code-domain charges only the fp32 head");
    }

    #[test]
    fn pinned_roster_routes_everything_to_its_engine() {
        let store = synth_store(73, ModelKind::Lenet);
        let cfg = ServerConfig {
            engine: EngineSelect::HostCsd(CsdQuality::new(3)),
            policy: PolicySelect::EnergyBudget,
            ..Default::default()
        };
        let roster = Roster::build(None, store, &cfg).unwrap();
        assert_eq!(roster.len(), 1);
        assert_eq!(roster.name(), "host-csd");
        for n in [1usize, 8, 32] {
            assert_eq!(roster.route(n), 0);
        }
        let mut r = Rng::new(74);
        let mut scratch = Scratch::new();
        let (i, logits) = roster.dispatch(&synth_batch(&mut r, 2), &mut scratch).unwrap();
        assert_eq!((i, logits.shape()), (0, &[2usize, 10][..]));
        let rep = roster.report_of(0);
        assert_eq!(rep.kind, EngineKind::Csd);
        assert!(rep.mean_pp <= 3.0 + 1e-12, "digit dial bounds the report's pp");
    }

    #[test]
    fn policies_differ_on_partial_batches() {
        // the three policies are genuinely different routers on the same
        // roster: a half-full batch goes artifact-class under batch-fill,
        // stays host under latency-floor, and the smallest batch only
        // reaches CSD under the energy policy
        let mk = |policy| {
            let cfg = ServerConfig { policy, ..Default::default() };
            Roster::build(None, synth_store(75, ModelKind::Lenet), &cfg).unwrap()
        };
        let fill = mk(PolicySelect::BatchFill);
        let floor = mk(PolicySelect::LatencyFloor);
        let energy = mk(PolicySelect::EnergyBudget);
        let kind_at = |r: &Roster, n: usize| r.kind_of(r.route(n));
        assert_eq!(kind_at(&fill, 16), EngineKind::F32);
        assert_eq!(kind_at(&floor, 16), EngineKind::Quantized);
        assert_eq!(kind_at(&fill, 1), EngineKind::Quantized);
        assert_eq!(kind_at(&energy, 1), EngineKind::Csd);
        assert_eq!(kind_at(&floor, 32), EngineKind::F32);
    }

    #[test]
    fn quarantine_routes_around_then_probes_back() {
        let store = synth_store(81, ModelKind::Lenet);
        let cfg = ServerConfig {
            policy: PolicySelect::EnergyBudget,
            quarantine_after: 2,
            quarantine_cooldown: 4,
            ..Default::default()
        };
        let roster = Roster::build(None, store, &cfg).unwrap();
        // the energy policy sends singletons to the CSD engine
        let csd = roster.route(1);
        assert_eq!(roster.kind_of(csd), EngineKind::Csd);
        assert!(!roster.any_quarantined());

        // two consecutive failures quarantine it; the first is forgiven
        assert!(!roster.note_failure(csd));
        assert!(roster.note_failure(csd));
        assert!(roster.quarantined(csd));
        assert!(roster.any_quarantined());
        assert_eq!(roster.quarantine_events(), 1);

        // routed around: singletons degrade to the next energy preference
        let alt = roster.route(1);
        assert_ne!(alt, csd);
        assert_eq!(roster.kind_of(alt), EngineKind::Quantized);

        // a success elsewhere must not reinstate the quarantined engine
        roster.note_ok(alt);
        assert!(roster.quarantined(csd));

        // after the (tick-based) cooldown, the engine wins a probe batch
        let mut probed = false;
        for _ in 0..2 * cfg.quarantine_cooldown {
            if roster.route(1) == csd {
                probed = true;
                break;
            }
        }
        assert!(probed, "cooldown expiry must make the engine a probe candidate");

        // a failed probe re-quarantines immediately (no fresh streak)
        assert!(roster.note_failure(csd));
        assert_eq!(roster.quarantine_events(), 2);
        assert_ne!(roster.route(1), csd, "failed probe: back behind the fence");

        // a successful probe reinstates it
        let mut probe2 = false;
        for _ in 0..2 * cfg.quarantine_cooldown {
            if roster.route(1) == csd {
                probe2 = true;
                break;
            }
        }
        assert!(probe2);
        roster.note_ok(csd);
        assert!(!roster.quarantined(csd));
        assert!(!roster.any_quarantined());
        assert_eq!(roster.route(1), csd, "reinstated engine serves again");
    }

    #[test]
    fn fully_quarantined_roster_keeps_serving() {
        let store = synth_store(82, ModelKind::Lenet);
        let cfg = ServerConfig {
            quarantine_after: 1,
            quarantine_cooldown: 1000,
            ..Default::default()
        };
        let roster = Roster::build(None, store, &cfg).unwrap();
        for i in 0..roster.len() {
            assert!(roster.note_failure(i), "quarantine_after=1: first failure fences");
            assert!(roster.quarantined(i));
        }
        // routing around *everything* would mean serving nothing — the full
        // roster stays in play instead
        for n in [1usize, 8, 32] {
            let i = roster.route(n);
            assert!(i < roster.len());
        }
        // and a success anywhere starts reinstating
        let i = roster.route(32);
        roster.note_ok(i);
        assert!(!roster.quarantined(i));
    }

    #[test]
    fn roster_install_swaps_generation_and_returns_the_displaced_engines() {
        let cfg = ServerConfig::default();
        let roster =
            Roster::build(None, synth_store(83, ModelKind::Lenet), &cfg).unwrap();
        assert_eq!(roster.generation(), 1);
        assert_eq!(roster.len(), 3);
        // poison the boot generation's health so the reset is observable
        for _ in 0..cfg.quarantine_after {
            roster.note_failure(0);
        }
        assert!(roster.any_quarantined());

        let staged = swap::stage(&synth_store(84, ModelKind::Lenet), &SwapConfig::default())
            .unwrap();
        let displaced = roster.install(wrap_generation(staged.engines), 2, cfg.batch);
        assert_eq!(roster.generation(), 2);
        assert_eq!(displaced.len(), 3, "the whole boot generation comes back out");
        assert_eq!(roster.len(), 3);
        // fresh generation, fresh health: the old quarantine is gone
        assert!(!roster.any_quarantined());
        for i in 0..roster.len() {
            assert!(!roster.quarantined(i));
        }
        // and it serves: a dispatch routes + forwards on the new engines
        let mut r = Rng::new(85);
        let mut scratch = Scratch::new();
        let (_, logits) = roster.dispatch(&synth_batch(&mut r, 2), &mut scratch).unwrap();
        assert_eq!(logits.shape(), &[2, 10]);

        // rollback path: reinstalling the displaced set restores generation 1
        roster.install(displaced, 1, cfg.batch);
        assert_eq!(roster.generation(), 1);
        let (_, logits) = roster.dispatch(&synth_batch(&mut r, 1), &mut scratch).unwrap();
        assert_eq!(logits.shape(), &[1, 10]);
    }

    #[test]
    fn serve_batch_reports_under_one_lock_and_survives_concurrent_readers() {
        // serve_batch is the workers' whole per-batch roster interaction:
        // run it from several threads at once against the shared roster and
        // check every outcome is coherent (valid index, right generation,
        // real logits)
        let cfg = ServerConfig::default();
        let roster = Arc::new(
            Roster::build(None, synth_store(86, ModelKind::Lenet), &cfg).unwrap(),
        );
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let roster = roster.clone();
                thread::spawn(move || {
                    let mut scratch = Scratch::new();
                    let mut r = Rng::new(90 + t);
                    for _ in 0..8 {
                        let n = 1 + (r.f32() * 4.0) as usize;
                        let xdata: Vec<f32> =
                            (0..n * 28 * 28).map(|_| r.f32()).collect();
                        let x = Tensor::new(vec![n, 28, 28, 1], xdata).unwrap();
                        let served = roster.serve_batch(&x, &mut scratch);
                        assert!(served.idx < 3);
                        assert_eq!(served.generation, 1);
                        assert!(served.dispatch_counter.starts_with("dispatch_"));
                        match served.outcome {
                            BatchOutcome::Logits(l) => {
                                assert_eq!(l.shape(), &[n, 10]);
                                assert!(served.report.is_some());
                            }
                            _ => panic!("healthy engines must serve"),
                        }
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert!(!roster.any_quarantined());
    }

    #[test]
    fn batch_tensor_copies_rows() {
        let (tx, _rx) = mpsc::channel();
        let jobs: Vec<Pending<Job>> = (0..2)
            .map(|i| Pending {
                payload: Job {
                    id: i,
                    pixels: vec![i as f32; 4],
                    enqueued: Instant::now(),
                    resp: tx.clone(),
                },
                enqueued: Instant::now(),
            })
            .collect();
        let t = batch_tensor(&jobs, 2, 2, 2, 1).unwrap();
        assert_eq!(t.shape(), &[2, 2, 2, 1]);
        assert_eq!(t.data(), &[0.0, 0.0, 0.0, 0.0, 1.0, 1.0, 1.0, 1.0]);
        // padded rows stay zero (the PJRT path)
        let p = batch_tensor(&jobs, 3, 2, 2, 1).unwrap();
        assert_eq!(p.shape(), &[3, 2, 2, 1]);
        assert_eq!(&p.data()[8..], &[0.0; 4]);
    }

    #[test]
    fn retry_after_scales_with_backlog() {
        let q: BatchQueue<Job> = BatchQueue::bounded(4, Duration::from_millis(5), 64, None);
        let m = Metrics::new();
        // no EWMA yet: the batching window is the floor
        assert_eq!(retry_after_ms(&q, &m), 5.0);
        m.observe_ewma("infer_batch.ewma_ms", 8.0);
        // empty queue still hints one batch worth
        assert_eq!(retry_after_ms(&q, &m), 8.0);
        let (tx, _rx) = mpsc::channel();
        for id in 0..9 {
            q.push(Job {
                id,
                pixels: Vec::new(),
                enqueued: Instant::now(),
                resp: tx.clone(),
            })
            .unwrap();
        }
        // 9 queued jobs at max_batch 4 = 3 batches to drain
        assert_eq!(retry_after_ms(&q, &m), 24.0);
    }
}
