//! TCP inference server: JSON-lines protocol, dynamic batching, one
//! inference owner thread over a pluggable engine.
//!
//! Protocol (one JSON object per line):
//! ```text
//! -> {"id": 7, "pixels": [ ... H*W*C floats ... ]}
//! <- {"id": 7, "pred": 3, "latency_us": 812, "batch": 32}
//! ```
//! Each connection is synchronous (request → response); concurrency comes
//! from multiple connections feeding the shared [`BatchQueue`], which the
//! worker drains in dynamic batches.  The worker executes on one of the
//! engines ([`EngineSelect`]): the PJRT artifact (padded to the compiled
//! batch size), the pure-rust blocked-GEMM f32 engine, the code-domain
//! [`QuantizedEngine`] (plane-packed codes on qgemm v2), or the CSD
//! shift-and-add [`CsdEngine`] (truncated-CSD digit planes on
//! `kernels::csd`, which additionally exports its per-request energy ledger
//! as `energy.*` gauges).  `Auto` is
//! *batch-aware*: instead of picking one engine at startup it re-dispatches
//! every popped batch — batches that fill enough of the compiled artifact
//! run on PJRT (or the threaded f32 host engine when PJRT is absent), while
//! small/singleton batches skip the padding waste and run on the low-latency
//! code-domain engine.  The worker owns one [`Scratch`] arena, so the host
//! paths stop allocating per request once warm, and all host kernels
//! dispatch row bands on the persistent worker pool — the worker exports the
//! pool's spawn/wakeup counters and the arena's per-layer high-water marks
//! as metrics gauges (`pool.*`, `scratch_hw.*`), where a flat `pool.spawns`
//! is the "zero threads spawned per request" steady-state invariant.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::batcher::{BatchQueue, Pending};
use super::metrics::Metrics;
use crate::device::{CsdQuality, QualityConfig};
use crate::kernels::{self, Scratch};
use crate::model::meta::ModelKind;
use crate::model::store::WeightStore;
use crate::quant::qsq::AssignMode;
use crate::runtime::client::{ArgValue, Executable, Runtime};
use crate::runtime::host::{self, CsdEngine, QuantizedEngine};
use crate::tensor::{ops, Tensor};
use crate::util::json::{self, Value};

/// Quality the batch-aware `Auto` backend quantizes its small-batch engine
/// at (the canonical phi=4, N=16 point the deploy pipeline defaults to).
const AUTO_QUALITY: QualityConfig = QualityConfig { phi: 4, group: 16 };

/// Which inference engine the worker thread runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineSelect {
    /// Batch-aware hybrid: every popped batch is re-dispatched — to the
    /// PJRT artifact when the batch fills enough of the compiled size
    /// ([`batch_prefers_artifact`]; threaded f32 host engine when PJRT is
    /// unavailable), and to the code-domain quantized engine for
    /// small/singleton batches where padding waste would dominate.
    Auto,
    /// PJRT only; startup fails if it is unavailable.
    Pjrt,
    /// Pure-rust f32 engine (blocked/parallel GEMM).
    Host,
    /// Pure-rust code-domain engine: weights quantized at this quality and
    /// served from packed codes on the qgemm kernel.
    HostQuantized(QualityConfig),
    /// Pure-rust CSD shift-and-add engine (§V.B): weights truncated-CSD
    /// packed at this digit budget and served on `kernels::csd`, with the
    /// per-request energy ledger exported as `energy.*` gauges.
    HostCsd(CsdQuality),
}

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub model: ModelKind,
    /// Compiled artifact batch (the padded execution size on PJRT).
    pub batch: usize,
    /// Dynamic batching window.
    pub max_delay: Duration,
    /// Bind address, e.g. "127.0.0.1:0" (port 0 = ephemeral).
    pub bind: String,
    /// Inference engine selection.
    pub engine: EngineSelect,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            model: ModelKind::Lenet,
            batch: 32,
            max_delay: Duration::from_millis(5),
            bind: "127.0.0.1:0".into(),
            engine: EngineSelect::Auto,
        }
    }
}

/// The loaded PJRT pieces (client kept alive for the executable's lifetime).
struct PjrtParts {
    _rt: Runtime,
    exe: Arc<Executable>,
    /// Prebuilt argument vector: slot 0 is overwritten with each batch
    /// tensor, slots 1.. hold the weights — wrapped once at startup so
    /// dispatching a batch never re-copies the model.
    args: Vec<ArgValue>,
}

/// The worker's engine (constructed on, and owned by, the worker thread —
/// `Runtime` is not `Send`).
enum Backend {
    Pjrt(PjrtParts),
    Host(WeightStore),
    Quant(QuantizedEngine),
    /// CSD shift-and-add engine with the per-request energy ledger.
    Csd(CsdEngine),
    /// Batch-aware hybrid ([`EngineSelect::Auto`]): each popped batch picks
    /// PJRT (if loaded) or the f32 store for artifact-sized batches, and the
    /// code-domain engine for small ones.  The f32 store is kept only when
    /// PJRT is absent — with PJRT live it would never be read, and the
    /// weights already sit in the prebuilt `PjrtParts::args` slots.
    Hybrid {
        pjrt: Option<PjrtParts>,
        store: Option<WeightStore>,
        quant: QuantizedEngine,
    },
}

impl Backend {
    fn name(&self) -> &'static str {
        match self {
            Backend::Pjrt { .. } => "pjrt",
            Backend::Host(_) => "host-f32",
            Backend::Quant(_) => "host-qgemm",
            Backend::Csd(_) => "host-csd",
            Backend::Hybrid { .. } => "auto-hybrid",
        }
    }
}

/// The `threads_for`-style crossover of the batch-aware dispatch: running a
/// padded artifact costs the full compiled batch regardless of occupancy,
/// and the compiled kernels are roughly a few times faster per row than the
/// host engines — so the artifact wins once a batch fills at least a
/// quarter of the compiled size, and below that the padding waste hands the
/// batch to the low-latency code-domain engine.
pub fn batch_prefers_artifact(n: usize, artifact_batch: usize) -> bool {
    n.saturating_mul(4) >= artifact_batch
}

fn pjrt_parts(artifacts: &Path, cfg: &ServerConfig, store: &WeightStore) -> Result<PjrtParts> {
    let mut rt = Runtime::new(artifacts)?;
    let (art, _) = super::router::artifact_for(cfg.model, cfg.batch)?;
    let exe = rt.load(&art)?;
    let mut args = vec![ArgValue::F32(Tensor::zeros(vec![0]))];
    args.extend(store.ordered().into_iter().map(|t| ArgValue::F32(t.clone())));
    Ok(PjrtParts { _rt: rt, exe, args })
}

fn build_backend(artifacts: &Path, cfg: &ServerConfig) -> Result<Backend> {
    let store = WeightStore::load(artifacts, cfg.model)?;
    match cfg.engine {
        EngineSelect::Pjrt => Ok(Backend::Pjrt(pjrt_parts(artifacts, cfg, &store)?)),
        EngineSelect::Host => Ok(Backend::Host(store)),
        EngineSelect::HostQuantized(q) => Ok(Backend::Quant(QuantizedEngine::quantize_store(
            &store,
            q,
            AssignMode::SigmaSearch,
        )?)),
        EngineSelect::HostCsd(q) => Ok(Backend::Csd(CsdEngine::from_store(&store, q)?)),
        EngineSelect::Auto => {
            let pjrt = match pjrt_parts(artifacts, cfg, &store) {
                Ok(p) => Some(p),
                Err(e) => {
                    eprintln!(
                        "server: PJRT unavailable ({e:#}); host engines will serve all batches"
                    );
                    None
                }
            };
            // a quantization failure must not take Auto down — degrade to
            // the pre-hybrid behavior (PJRT, or the plain f32 engine)
            match QuantizedEngine::quantize_store(&store, AUTO_QUALITY, AssignMode::SigmaSearch) {
                Ok(quant) => {
                    let store = if pjrt.is_none() { Some(store) } else { None };
                    Ok(Backend::Hybrid { pjrt, store, quant })
                }
                Err(e) => {
                    eprintln!(
                        "server: quantized engine unavailable ({e:#}); \
                         batch-aware dispatch disabled"
                    );
                    match pjrt {
                        Some(pj) => Ok(Backend::Pjrt(pj)),
                        None => Ok(Backend::Host(store)),
                    }
                }
            }
        }
    }
}

/// Run one batch on the PJRT artifact, padding to the compiled batch size.
/// Only the batch tensor slot of the prebuilt args is replaced.
fn run_pjrt(pj: &mut PjrtParts, batch: &[Pending<Job>], cfg: &ServerConfig) -> Result<Vec<usize>> {
    let (h, w, c) = cfg.model.input_hwc();
    let x = batch_tensor(batch, cfg.batch, h, w, c)?;
    pj.args[0] = ArgValue::F32(x);
    let out = pj.exe.run(&pj.args)?;
    Ok(ops::argmax_rows(&out[0]))
}

/// Copy a dynamic batch into one [rows, H, W, C] tensor; `rows` beyond the
/// batch stay zero (the PJRT path pads to the compiled batch size, the host
/// path passes `rows == batch.len()` for no padding).
fn batch_tensor(
    batch: &[Pending<Job>],
    rows: usize,
    h: usize,
    w: usize,
    c: usize,
) -> Result<Tensor> {
    let pix = h * w * c;
    let mut xdata = vec![0.0f32; rows * pix];
    for (i, job) in batch.iter().enumerate() {
        xdata[i * pix..(i + 1) * pix].copy_from_slice(&job.payload.pixels);
    }
    Tensor::new(vec![rows, h, w, c], xdata)
}

struct Job {
    id: u64,
    pixels: Vec<f32>,
    enqueued: Instant,
    resp: mpsc::Sender<Value>,
}

/// A running server; `stop()` for graceful shutdown.
pub struct Server {
    pub port: u16,
    pub metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    queue: Arc<BatchQueue<Job>>,
    handles: Vec<JoinHandle<()>>,
}

impl Server {
    /// Start the server; blocks until the PJRT worker has loaded weights and
    /// compiled the artifact (so the first request is never a cold start).
    pub fn start(artifacts: PathBuf, cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.bind)
            .with_context(|| format!("binding {}", cfg.bind))?;
        listener.set_nonblocking(true)?;
        let port = listener.local_addr()?.port();

        let shutdown = Arc::new(AtomicBool::new(false));
        let queue = Arc::new(BatchQueue::<Job>::new(cfg.batch, cfg.max_delay));
        let metrics = Arc::new(Metrics::new());

        // --- inference worker (owns the non-Send Backend) -------------------
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let wq = queue.clone();
        let wm = metrics.clone();
        let wcfg = cfg.clone();
        let worker = thread::Builder::new().name("infer-worker".into()).spawn(move || {
            let mut backend = match build_backend(&artifacts, &wcfg) {
                Ok(b) => {
                    let _ = ready_tx.send(Ok(()));
                    b
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            wm.inc(&format!("engine_{}", backend.name()), 1);
            let (h, w, c) = wcfg.model.input_hwc();
            // one arena per worker: the host engines stop allocating per
            // request once the buffers are warm
            let mut scratch = Scratch::new();
            // the persistent kernel pool the host engines dispatch bands on;
            // its spawn counter stays flat once serving is warm
            let pool = kernels::Pool::global();

            while let Some(batch) = wq.pop_batch() {
                let t0 = Instant::now();
                let n = batch.len();
                let preds: Result<Vec<usize>> = match &mut backend {
                    Backend::Pjrt(pj) => run_pjrt(pj, &batch, &wcfg),
                    Backend::Host(store) => batch_tensor(&batch, n, h, w, c)
                        .and_then(|x| host::forward_with(store, &x, &mut scratch))
                        .map(|logits| ops::argmax_rows(&logits)),
                    Backend::Quant(engine) => batch_tensor(&batch, n, h, w, c)
                        .and_then(|x| engine.forward_with(&x, &mut scratch))
                        .map(|logits| ops::argmax_rows(&logits)),
                    Backend::Csd(engine) => batch_tensor(&batch, n, h, w, c)
                        .and_then(|x| engine.forward_with(&x, &mut scratch))
                        .map(|logits| ops::argmax_rows(&logits)),
                    Backend::Hybrid { pjrt, store, quant } => {
                        // batch-aware re-dispatch: artifact-sized batches on
                        // PJRT (or the threaded f32 engine), small ones on
                        // the code-domain engine
                        match (batch_prefers_artifact(n, wcfg.batch), pjrt, store) {
                            (true, Some(pj), _) => {
                                wm.inc("dispatch_pjrt", 1);
                                run_pjrt(pj, &batch, &wcfg)
                            }
                            (true, None, Some(store)) => {
                                wm.inc("dispatch_host_f32", 1);
                                batch_tensor(&batch, n, h, w, c)
                                    .and_then(|x| host::forward_with(store, &x, &mut scratch))
                                    .map(|logits| ops::argmax_rows(&logits))
                            }
                            _ => {
                                wm.inc("dispatch_host_quant", 1);
                                batch_tensor(&batch, n, h, w, c)
                                    .and_then(|x| quant.forward_with(&x, &mut scratch))
                                    .map(|logits| ops::argmax_rows(&logits))
                            }
                        }
                    }
                };
                match preds {
                    Ok(preds) => {
                        let infer_s = t0.elapsed().as_secs_f64();
                        wm.observe_s("infer_batch", infer_s);
                        wm.inc("batches", 1);
                        wm.inc("requests", n as u64);
                        // pool + arena telemetry: spawns must stay flat once
                        // warm (a moving spawn gauge is a perf regression),
                        // and the per-layer high-water marks show how much
                        // arena each layer of the served model really needs
                        let ps = pool.stats();
                        wm.set_gauge("pool.spawns", ps.spawns as f64);
                        wm.set_gauge("pool.wakeups", ps.wakeups as f64);
                        wm.set_gauge("pool.jobs", ps.jobs as f64);
                        for (layer, pk) in scratch.layer_peaks() {
                            wm.set_gauge(
                                &format!("scratch_hw.{layer}.patch_bytes"),
                                pk.patch_bytes as f64,
                            );
                            wm.set_gauge(
                                &format!("scratch_hw.{layer}.pad_bytes"),
                                pk.pad_bytes as f64,
                            );
                            wm.set_gauge(
                                &format!("scratch_hw.{layer}.act_bytes"),
                                pk.act_bytes as f64,
                            );
                        }
                        // energy ledger (CSD engine): lifetime totals as
                        // absolute gauges.  `energy.forwards` divides to
                        // per-batch numbers (one forward per popped batch);
                        // per-request uses counter.requests — docs/METRICS.md
                        if let Backend::Csd(engine) = &backend {
                            let led = engine.ledger();
                            wm.set_gauge("energy.partial_products", led.partial_products as f64);
                            wm.set_gauge("energy.gated_rows", led.gated_rows as f64);
                            wm.set_gauge("energy.skipped_macs", led.skipped_macs as f64);
                            wm.set_gauge("energy.fp_muls", led.fp_muls as f64);
                            wm.set_gauge("energy.fp_adds", led.fp_adds as f64);
                            wm.set_gauge("energy.compute_pj", led.compute_pj());
                            wm.set_gauge("energy.total_pj", led.total_pj());
                            wm.set_gauge("energy.forwards", engine.forwards() as f64);
                        }
                        for (i, job) in batch.into_iter().enumerate() {
                            let e2e = job.payload.enqueued.elapsed();
                            wm.observe_s("request_e2e", e2e.as_secs_f64());
                            let resp = json::obj(vec![
                                ("id", json::num(job.payload.id as f64)),
                                ("pred", json::num(preds[i] as f64)),
                                ("latency_us", json::num(e2e.as_micros() as f64)),
                                ("batch", json::num(n as f64)),
                            ]);
                            let _ = job.payload.resp.send(resp);
                        }
                    }
                    Err(e) => {
                        for job in batch {
                            let resp = json::obj(vec![
                                ("id", json::num(job.payload.id as f64)),
                                ("error", json::s(&format!("{e:#}"))),
                            ]);
                            let _ = job.payload.resp.send(resp);
                        }
                    }
                }
            }
        })?;
        ready_rx
            .recv()
            .context("inference worker died during startup")??;

        // --- acceptor -------------------------------------------------------
        let aq = queue.clone();
        let ash = shutdown.clone();
        let am = metrics.clone();
        let pix_expected = {
            let (h, w, c) = cfg.model.input_hwc();
            h * w * c
        };
        let acceptor = thread::Builder::new().name("acceptor".into()).spawn(move || {
            let mut conns: Vec<JoinHandle<()>> = Vec::new();
            while !ash.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let q = aq.clone();
                        let m = am.clone();
                        let sh = ash.clone();
                        conns.push(
                            thread::Builder::new()
                                .name("conn".into())
                                .spawn(move || {
                                    let _ = handle_conn(stream, q, m, pix_expected, sh);
                                })
                                .unwrap(),
                        );
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        })?;

        Ok(Server {
            port,
            metrics,
            shutdown,
            queue,
            handles: vec![worker, acceptor],
        })
    }

    /// Graceful shutdown: stop accepting, drain the queue, join threads.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // give in-flight connection reads a beat, then close the queue
        thread::sleep(Duration::from_millis(20));
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    queue: Arc<BatchQueue<Job>>,
    metrics: Arc<Metrics>,
    pix_expected: usize,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // read timeout so the thread notices shutdown even on idle connections
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // `line` persists across timeout retries: read_line appends, so a line
    // split by a read timeout reassembles on the next pass.
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) if line.ends_with('\n') => {}
            Ok(_) => continue, // partial line at EOF-less boundary; keep reading
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if shutdown.load(Ordering::Relaxed) {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            line.clear();
            continue;
        }
        let reply = match parse_request(&line, pix_expected) {
            Ok((id, pixels)) => {
                let (tx, rx) = mpsc::channel();
                let job = Job { id, pixels, enqueued: Instant::now(), resp: tx };
                if !queue.push(job) {
                    json::obj(vec![("error", json::s("server shutting down"))])
                } else {
                    match rx.recv_timeout(Duration::from_secs(30)) {
                        Ok(v) => v,
                        Err(_) => json::obj(vec![("error", json::s("inference timeout"))]),
                    }
                }
            }
            Err(e) => {
                metrics.inc("bad_requests", 1);
                json::obj(vec![("error", json::s(&format!("{e:#}")))])
            }
        };
        writer.write_all(reply.to_json().as_bytes())?;
        writer.write_all(b"\n")?;
        line.clear();
    }
}

fn parse_request(line: &str, pix_expected: usize) -> Result<(u64, Vec<f32>)> {
    let v = json::parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    let id = v
        .get("id")
        .as_f64()
        .context("missing id")? as u64;
    let pixels: Vec<f32> = v
        .get("pixels")
        .as_arr()
        .context("missing pixels")?
        .iter()
        .map(|x| x.as_f64().unwrap_or(0.0) as f32)
        .collect();
    if pixels.len() != pix_expected {
        bail!("expected {pix_expected} pixels, got {}", pixels.len());
    }
    Ok((id, pixels))
}

/// Simple blocking client for examples/tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Send one request, wait for its reply.
    pub fn infer(&mut self, id: u64, pixels: &[f32]) -> Result<Value> {
        let req = json::obj(vec![
            ("id", json::num(id as f64)),
            (
                "pixels",
                Value::Arr(pixels.iter().map(|&p| json::num(p as f64)).collect()),
            ),
        ]);
        self.writer.write_all(req.to_json().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        json::parse(&line).map_err(|e| anyhow::anyhow!("bad reply: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_validates() {
        assert!(parse_request("{\"id\":1,\"pixels\":[0.0,1.0]}", 2).is_ok());
        assert!(parse_request("{\"id\":1,\"pixels\":[0.0]}", 2).is_err());
        assert!(parse_request("{\"pixels\":[0.0,1.0]}", 2).is_err());
        assert!(parse_request("not json", 2).is_err());
    }

    #[test]
    fn crossover_prefers_artifact_only_when_batch_fills_it() {
        // singletons and near-empty batches stay on the host-quant engine
        assert!(!batch_prefers_artifact(1, 32));
        assert!(!batch_prefers_artifact(7, 32));
        // a quarter-full (or better) batch amortizes the padding
        assert!(batch_prefers_artifact(8, 32));
        assert!(batch_prefers_artifact(32, 32));
        // degenerate compiled sizes never panic
        assert!(batch_prefers_artifact(1, 1));
        assert!(batch_prefers_artifact(0, 0));
    }

    #[test]
    fn default_config_sane() {
        let c = ServerConfig::default();
        assert_eq!(c.batch, 32);
        assert!(c.bind.ends_with(":0"));
        assert_eq!(c.engine, EngineSelect::Auto);
    }

    #[test]
    fn batch_tensor_copies_rows() {
        let (tx, _rx) = mpsc::channel();
        let jobs: Vec<Pending<Job>> = (0..2)
            .map(|i| Pending {
                payload: Job {
                    id: i,
                    pixels: vec![i as f32; 4],
                    enqueued: Instant::now(),
                    resp: tx.clone(),
                },
                enqueued: Instant::now(),
            })
            .collect();
        let t = batch_tensor(&jobs, 2, 2, 2, 1).unwrap();
        assert_eq!(t.shape(), &[2, 2, 2, 1]);
        assert_eq!(t.data(), &[0.0, 0.0, 0.0, 0.0, 1.0, 1.0, 1.0, 1.0]);
        // padded rows stay zero (the PJRT path)
        let p = batch_tensor(&jobs, 3, 2, 2, 1).unwrap();
        assert_eq!(p.shape(), &[3, 2, 2, 1]);
        assert_eq!(&p.data()[8..], &[0.0; 4]);
    }
}
