//! TCP inference server: JSON-lines protocol, dynamic batching, one
//! inference owner thread over a pluggable engine.
//!
//! Protocol (one JSON object per line):
//! ```text
//! -> {"id": 7, "pixels": [ ... H*W*C floats ... ]}
//! <- {"id": 7, "pred": 3, "latency_us": 812, "batch": 32}
//! ```
//! Each connection is synchronous (request → response); concurrency comes
//! from multiple connections feeding the shared [`BatchQueue`], which the
//! worker drains in dynamic batches.  The worker executes over a [`Roster`]
//! of boxed [`Engine`]s: the PJRT artifact wrapper (padded to the compiled
//! batch size), the pure-rust blocked-GEMM [`F32Engine`], the code-domain
//! [`QuantizedEngine`] (plane-packed codes on qgemm v2), and the CSD
//! shift-and-add [`CsdEngine`] (truncated-CSD digit planes on
//! `kernels::csd`).  [`EngineSelect`] pins the roster to one engine, or
//! `Auto` builds the full roster and a pluggable
//! [`DispatchPolicy`] re-routes every popped batch (`--policy`
//! batch-fill|latency|energy): artifact-filling batches to the compiled
//! path, small/singleton batches to the low-latency or minimum-energy host
//! engines — under the energy policy the smallest batches reach the CSD
//! engine.  The worker owns one [`Scratch`] arena, so the host paths stop
//! allocating per request once warm, and all host kernels dispatch row bands
//! on the persistent worker pool.  After every batch the worker exports the
//! pool's spawn/wakeup counters, the arena's per-layer high-water marks
//! (`pool.*`, `scratch_hw.*` — a flat `pool.spawns` is the "zero threads
//! spawned per request" steady-state invariant), and every roster engine's
//! uniform [`crate::runtime::engine::EngineReport`] as the
//! `engine.<name>.*` gauge family (`docs/METRICS.md`).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::batcher::{BatchQueue, Pending};
use super::metrics::Metrics;
use crate::device::{CsdQuality, QualityConfig};
use crate::kernels::{self, Scratch};
use crate::model::meta::ModelKind;
use crate::model::store::WeightStore;
use crate::quant::qsq::AssignMode;
use crate::runtime::engine::{DispatchPolicy, Engine, EngineKind, PjrtEngine, PolicySelect};
use crate::runtime::host::{CsdEngine, F32Engine, QuantizedEngine};
use crate::tensor::{ops, Tensor};
use crate::util::json::{self, Value};

pub use crate::runtime::engine::batch_prefers_artifact;

/// Quality the `Auto` roster quantizes its code-domain engine at (the
/// canonical phi=4, N=16 point the deploy pipeline defaults to).
const AUTO_QUALITY: QualityConfig = QualityConfig { phi: 4, group: 16 };

/// Digit budget the `Auto` roster's CSD engine serves at: 4 kept partial
/// products per weight keeps truncation error small while the energy policy
/// still halves-or-better the shift-and-add work of exact CSD.
const AUTO_CSD_DIGITS: usize = 4;

/// Which inference engine(s) the worker thread runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineSelect {
    /// Batch-aware roster: every popped batch is re-routed by the
    /// [`DispatchPolicy`] in [`ServerConfig::policy`] over the full engine
    /// roster — the PJRT artifact (threaded f32 host engine when PJRT is
    /// unavailable), the code-domain quantized engine, and the CSD
    /// shift-and-add engine.
    Auto,
    /// PJRT only; startup fails if it is unavailable.
    Pjrt,
    /// Pure-rust f32 engine (blocked/parallel GEMM).
    Host,
    /// Pure-rust code-domain engine: weights quantized at this quality and
    /// served from packed codes on the qgemm kernel.
    HostQuantized(QualityConfig),
    /// Pure-rust CSD shift-and-add engine (§V.B): weights truncated-CSD
    /// packed at this digit budget and served on `kernels::csd`, with the
    /// per-request energy ledger exported via the `engine.host-csd.*`
    /// gauge family.
    HostCsd(CsdQuality),
}

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub model: ModelKind,
    /// Compiled artifact batch (the padded execution size on PJRT).
    pub batch: usize,
    /// Dynamic batching window.
    pub max_delay: Duration,
    /// Bind address, e.g. "127.0.0.1:0" (port 0 = ephemeral).
    pub bind: String,
    /// Inference engine selection.
    pub engine: EngineSelect,
    /// Batch-dispatch policy for the `Auto` roster (ignored when the
    /// roster is pinned to a single engine).
    pub policy: PolicySelect,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            model: ModelKind::Lenet,
            batch: 32,
            max_delay: Duration::from_millis(5),
            bind: "127.0.0.1:0".into(),
            engine: EngineSelect::Auto,
            policy: PolicySelect::BatchFill,
        }
    }
}

/// The worker's engine roster: every serving engine as a boxed [`Engine`],
/// with a [`DispatchPolicy`] picking one per popped batch.  A pinned
/// [`EngineSelect`] builds a one-engine roster (the policy is then inert);
/// `Auto` builds the full roster.  Constructed on, and owned by, the worker
/// thread — the PJRT runtime is not `Send`.
pub struct Roster {
    engines: Vec<Box<dyn Engine>>,
    /// `engines[i]`'s kind, precomputed for the policy's route call.
    kinds: Vec<EngineKind>,
    policy: Box<dyn DispatchPolicy>,
    /// The batch size the policy crossovers price against: the compiled
    /// artifact batch (the padded cost a routed batch actually pays) when a
    /// PJRT engine is on the roster, the dynamic-batching cap otherwise.
    artifact_batch: usize,
    /// `dispatch_<engine>` counter names, precomputed per roster index so
    /// the worker's hot loop does not format a key per batch.
    dispatch_counters: Vec<String>,
}

impl Roster {
    /// Build the roster `cfg` asks for over an already-loaded store.
    /// `artifacts` is the directory the PJRT artifact would compile from;
    /// pass `None` to skip the PJRT path (benches and dispatch tests run
    /// rosters over synthetic stores with no artifacts on disk).
    pub fn build(
        artifacts: Option<&Path>,
        store: WeightStore,
        cfg: &ServerConfig,
    ) -> Result<Roster> {
        let mut engines: Vec<Box<dyn Engine>> = Vec::new();
        // the batch size the policy crossovers price against: the PJRT
        // engine's *compiled* batch when one is on the roster — artifact_for
        // rounds cfg.batch up to a compiled size, and that padded size is
        // the cost a routed batch actually pays, whatever the dynamic
        // batcher's cap is — cfg.batch otherwise
        let mut artifact_batch = cfg.batch;
        match cfg.engine {
            EngineSelect::Pjrt => {
                let dir = artifacts.context("PJRT engine needs an artifacts directory")?;
                let p = PjrtEngine::load(dir, cfg.model, cfg.batch, &store)?;
                artifact_batch = p.batch();
                engines.push(Box::new(p));
            }
            EngineSelect::Host => engines.push(Box::new(F32Engine::new(store))),
            EngineSelect::HostQuantized(q) => engines.push(Box::new(
                QuantizedEngine::quantize_store(&store, q, AssignMode::SigmaSearch)?,
            )),
            EngineSelect::HostCsd(q) => {
                engines.push(Box::new(CsdEngine::from_store(&store, q)?))
            }
            EngineSelect::Auto => {
                // a packing failure must not take Auto down: each engine
                // that fails to build is simply absent from the roster, and
                // the policies' preference orders route around it
                let pjrt = artifacts.and_then(|dir| {
                    match PjrtEngine::load(dir, cfg.model, cfg.batch, &store) {
                        Ok(p) => Some(p),
                        Err(e) => {
                            eprintln!(
                                "server: PJRT unavailable ({e:#}); the f32 host engine \
                                 serves artifact-sized batches"
                            );
                            None
                        }
                    }
                });
                let quant =
                    QuantizedEngine::quantize_store(&store, AUTO_QUALITY, AssignMode::SigmaSearch);
                match quant {
                    Ok(q) => engines.push(Box::new(q)),
                    Err(e) => eprintln!("server: quantized engine unavailable ({e:#})"),
                }
                match CsdEngine::from_store(&store, CsdQuality::new(AUTO_CSD_DIGITS)) {
                    Ok(c) => engines.push(Box::new(c)),
                    Err(e) => eprintln!("server: csd engine unavailable ({e:#})"),
                }
                // artifact-class engine last: PJRT when live (the weights
                // already sit in its prebuilt args), the f32 store otherwise
                match pjrt {
                    Some(p) => {
                        artifact_batch = p.batch();
                        engines.push(Box::new(p));
                    }
                    None => engines.push(Box::new(F32Engine::new(store))),
                }
            }
        }
        if engines.is_empty() {
            bail!("no engine could be built for {:?}", cfg.engine);
        }
        if artifact_batch > cfg.batch && engines.len() > 1 {
            // the dynamic batcher can never form a batch that fills the
            // compiled artifact — under latency-floor the artifact engine
            // will (correctly: every batch would pay padding) see no traffic
            eprintln!(
                "server: compiled artifact batch {artifact_batch} exceeds the batching \
                 cap {}; padding-averse policies will keep batches on the host engines",
                cfg.batch
            );
        }
        let kinds = engines.iter().map(|e| e.kind()).collect();
        let dispatch_counters = engines
            .iter()
            .map(|e| format!("dispatch_{}", e.name().replace('-', "_")))
            .collect();
        Ok(Roster { engines, kinds, policy: cfg.policy.build(), artifact_batch, dispatch_counters })
    }

    /// Backend label for the startup `engine_*` counter: the pinned engine's
    /// name, or `auto-hybrid` for a policy-routed roster.
    pub fn name(&self) -> &'static str {
        if self.engines.len() == 1 {
            self.engines[0].name()
        } else {
            "auto-hybrid"
        }
    }

    /// The active dispatch policy's name.
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn len(&self) -> usize {
        self.engines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.engines.is_empty()
    }

    /// The engine at roster index `i`.
    pub fn engine(&self, i: usize) -> &dyn Engine {
        self.engines[i].as_ref()
    }

    /// The precomputed `dispatch_<engine>` counter key for roster index `i`.
    pub fn dispatch_counter(&self, i: usize) -> &str {
        &self.dispatch_counters[i]
    }

    /// Every engine on the roster (for telemetry export).
    pub fn engines(&self) -> impl Iterator<Item = &dyn Engine> {
        self.engines.iter().map(|e| e.as_ref())
    }

    /// The roster index the policy routes an `n`-row batch to.
    pub fn route(&self, n: usize) -> usize {
        if self.engines.len() == 1 {
            return 0;
        }
        self.policy
            .route(n, self.artifact_batch, &self.kinds)
            .min(self.engines.len() - 1)
    }

    /// Route and execute one batch; returns the chosen roster index and the
    /// logits (real rows only — the PJRT wrapper trims its padding).
    pub fn dispatch(&self, x: &Tensor, scratch: &mut Scratch) -> Result<(usize, Tensor)> {
        let i = self.route(x.shape()[0]);
        let logits = self.engines[i].forward_with(x, scratch)?;
        Ok((i, logits))
    }
}

/// Copy a dynamic batch into one [rows, H, W, C] tensor; `rows` beyond the
/// batch stay zero.  The worker passes `rows == batch.len()` — any padding
/// to a compiled artifact size happens inside the PJRT engine wrapper.
fn batch_tensor(
    batch: &[Pending<Job>],
    rows: usize,
    h: usize,
    w: usize,
    c: usize,
) -> Result<Tensor> {
    let pix = h * w * c;
    let mut xdata = vec![0.0f32; rows * pix];
    for (i, job) in batch.iter().enumerate() {
        xdata[i * pix..(i + 1) * pix].copy_from_slice(&job.payload.pixels);
    }
    Tensor::new(vec![rows, h, w, c], xdata)
}

struct Job {
    id: u64,
    pixels: Vec<f32>,
    enqueued: Instant,
    resp: mpsc::Sender<Value>,
}

/// A running server; `stop()` for graceful shutdown.
pub struct Server {
    pub port: u16,
    pub metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    queue: Arc<BatchQueue<Job>>,
    handles: Vec<JoinHandle<()>>,
}

impl Server {
    /// Start the server; blocks until the PJRT worker has loaded weights and
    /// compiled the artifact (so the first request is never a cold start).
    pub fn start(artifacts: PathBuf, cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.bind)
            .with_context(|| format!("binding {}", cfg.bind))?;
        listener.set_nonblocking(true)?;
        let port = listener.local_addr()?.port();

        let shutdown = Arc::new(AtomicBool::new(false));
        let queue = Arc::new(BatchQueue::<Job>::new(cfg.batch, cfg.max_delay));
        let metrics = Arc::new(Metrics::new());

        // --- inference worker (owns the non-Send engine roster) -------------
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let wq = queue.clone();
        let wm = metrics.clone();
        let wcfg = cfg.clone();
        let worker = thread::Builder::new().name("infer-worker".into()).spawn(move || {
            let roster = match WeightStore::load(&artifacts, wcfg.model)
                .and_then(|store| Roster::build(Some(&artifacts), store, &wcfg))
            {
                Ok(r) => {
                    let _ = ready_tx.send(Ok(()));
                    r
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            wm.inc(&format!("engine_{}", roster.name()), 1);
            wm.inc(&format!("policy_{}", roster.policy_name()), 1);
            let (h, w, c) = wcfg.model.input_hwc();
            // one arena per worker: the host engines stop allocating per
            // request once the buffers are warm
            let mut scratch = Scratch::new();
            // the persistent kernel pool the host engines dispatch bands on;
            // its spawn counter stays flat once serving is warm
            let pool = kernels::Pool::global();

            while let Some(batch) = wq.pop_batch() {
                let t0 = Instant::now();
                let n = batch.len();
                let routed: Result<(usize, Vec<usize>)> = batch_tensor(&batch, n, h, w, c)
                    .and_then(|x| roster.dispatch(&x, &mut scratch))
                    .map(|(i, logits)| (i, ops::argmax_rows(&logits)));
                match routed {
                    Ok((idx, preds)) => {
                        let engine = roster.engine(idx);
                        wm.inc(roster.dispatch_counter(idx), 1);
                        let infer_s = t0.elapsed().as_secs_f64();
                        wm.observe_s("infer_batch", infer_s);
                        wm.inc("batches", 1);
                        wm.inc("requests", n as u64);
                        // pool + arena telemetry: spawns must stay flat once
                        // warm (a moving spawn gauge is a perf regression),
                        // and the per-layer high-water marks show how much
                        // arena each layer of the served model really needs
                        let ps = pool.stats();
                        wm.set_gauge("pool.spawns", ps.spawns as f64);
                        wm.set_gauge("pool.wakeups", ps.wakeups as f64);
                        wm.set_gauge("pool.jobs", ps.jobs as f64);
                        for (layer, pk) in scratch.layer_peaks() {
                            wm.set_gauge(
                                &format!("scratch_hw.{layer}.patch_bytes"),
                                pk.patch_bytes as f64,
                            );
                            wm.set_gauge(
                                &format!("scratch_hw.{layer}.pad_bytes"),
                                pk.pad_bytes as f64,
                            );
                            wm.set_gauge(
                                &format!("scratch_hw.{layer}.act_bytes"),
                                pk.act_bytes as f64,
                            );
                        }
                        // uniform per-engine telemetry: the engine that
                        // served this batch exports the `engine.<name>.*`
                        // gauge family from its EngineReport — forwards,
                        // zero-skip, mean partial products, the lifetime
                        // energy ledger (divide by `.forwards` for
                        // per-batch numbers, by counter.requests for
                        // per-request — docs/METRICS.md).  Only the routed
                        // engine's report can have changed, so the other
                        // roster members' gauges stay at their last export.
                        engine.report().export(|k, v| wm.set_gauge(k, v));
                        for (i, job) in batch.into_iter().enumerate() {
                            let e2e = job.payload.enqueued.elapsed();
                            wm.observe_s("request_e2e", e2e.as_secs_f64());
                            let resp = json::obj(vec![
                                ("id", json::num(job.payload.id as f64)),
                                ("pred", json::num(preds[i] as f64)),
                                ("latency_us", json::num(e2e.as_micros() as f64)),
                                ("batch", json::num(n as f64)),
                            ]);
                            let _ = job.payload.resp.send(resp);
                        }
                    }
                    Err(e) => {
                        for job in batch {
                            let resp = json::obj(vec![
                                ("id", json::num(job.payload.id as f64)),
                                ("error", json::s(&format!("{e:#}"))),
                            ]);
                            let _ = job.payload.resp.send(resp);
                        }
                    }
                }
            }
        })?;
        ready_rx
            .recv()
            .context("inference worker died during startup")??;

        // --- acceptor -------------------------------------------------------
        let aq = queue.clone();
        let ash = shutdown.clone();
        let am = metrics.clone();
        let pix_expected = {
            let (h, w, c) = cfg.model.input_hwc();
            h * w * c
        };
        let acceptor = thread::Builder::new().name("acceptor".into()).spawn(move || {
            let mut conns: Vec<JoinHandle<()>> = Vec::new();
            while !ash.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let q = aq.clone();
                        let m = am.clone();
                        let sh = ash.clone();
                        conns.push(
                            thread::Builder::new()
                                .name("conn".into())
                                .spawn(move || {
                                    let _ = handle_conn(stream, q, m, pix_expected, sh);
                                })
                                .unwrap(),
                        );
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        })?;

        Ok(Server {
            port,
            metrics,
            shutdown,
            queue,
            handles: vec![worker, acceptor],
        })
    }

    /// Graceful shutdown: stop accepting, drain the queue, join threads.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // give in-flight connection reads a beat, then close the queue
        thread::sleep(Duration::from_millis(20));
        self.queue.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    queue: Arc<BatchQueue<Job>>,
    metrics: Arc<Metrics>,
    pix_expected: usize,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // read timeout so the thread notices shutdown even on idle connections
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // `line` persists across timeout retries: read_line appends, so a line
    // split by a read timeout reassembles on the next pass.
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) if line.ends_with('\n') => {}
            Ok(_) => continue, // partial line at EOF-less boundary; keep reading
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if shutdown.load(Ordering::Relaxed) {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            line.clear();
            continue;
        }
        let reply = match parse_request(&line, pix_expected) {
            Ok((id, pixels)) => {
                let (tx, rx) = mpsc::channel();
                let job = Job { id, pixels, enqueued: Instant::now(), resp: tx };
                if !queue.push(job) {
                    json::obj(vec![("error", json::s("server shutting down"))])
                } else {
                    match rx.recv_timeout(Duration::from_secs(30)) {
                        Ok(v) => v,
                        Err(_) => json::obj(vec![("error", json::s("inference timeout"))]),
                    }
                }
            }
            Err(e) => {
                metrics.inc("bad_requests", 1);
                json::obj(vec![("error", json::s(&format!("{e:#}")))])
            }
        };
        writer.write_all(reply.to_json().as_bytes())?;
        writer.write_all(b"\n")?;
        line.clear();
    }
}

fn parse_request(line: &str, pix_expected: usize) -> Result<(u64, Vec<f32>)> {
    let v = json::parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    let id = v
        .get("id")
        .as_f64()
        .context("missing id")? as u64;
    let pixels: Vec<f32> = v
        .get("pixels")
        .as_arr()
        .context("missing pixels")?
        .iter()
        .map(|x| x.as_f64().unwrap_or(0.0) as f32)
        .collect();
    if pixels.len() != pix_expected {
        bail!("expected {pix_expected} pixels, got {}", pixels.len());
    }
    Ok((id, pixels))
}

/// Simple blocking client for examples/tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Send one request, wait for its reply.
    pub fn infer(&mut self, id: u64, pixels: &[f32]) -> Result<Value> {
        let req = json::obj(vec![
            ("id", json::num(id as f64)),
            (
                "pixels",
                Value::Arr(pixels.iter().map(|&p| json::num(p as f64)).collect()),
            ),
        ]);
        self.writer.write_all(req.to_json().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        json::parse(&line).map_err(|e| anyhow::anyhow!("bad reply: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_validates() {
        assert!(parse_request("{\"id\":1,\"pixels\":[0.0,1.0]}", 2).is_ok());
        assert!(parse_request("{\"id\":1,\"pixels\":[0.0]}", 2).is_err());
        assert!(parse_request("{\"pixels\":[0.0,1.0]}", 2).is_err());
        assert!(parse_request("not json", 2).is_err());
    }

    #[test]
    fn default_config_sane() {
        let c = ServerConfig::default();
        assert_eq!(c.batch, 32);
        assert!(c.bind.ends_with(":0"));
        assert_eq!(c.engine, EngineSelect::Auto);
        assert_eq!(c.policy, PolicySelect::BatchFill);
    }

    use crate::data::synth_store;
    use crate::util::rng::Rng;

    fn synth_batch(r: &mut Rng, n: usize) -> Tensor {
        let xdata: Vec<f32> = (0..n * 28 * 28).map(|_| r.f32()).collect();
        Tensor::new(vec![n, 28, 28, 1], xdata).unwrap()
    }

    /// The acceptance route map: `--engine auto --policy energy` must reach
    /// every engine class — PJRT-or-f32 for artifact-filling batches, the
    /// code-domain engine for mid-size, and the CSD engine (previously
    /// unreachable from Auto) for the smallest — with every route's
    /// `engine.*` gauges populated from the same EngineReport schema.
    #[test]
    fn energy_policy_routes_each_engine_and_exports_uniform_gauges() {
        let store = synth_store(71, ModelKind::Lenet);
        let cfg = ServerConfig { policy: PolicySelect::EnergyBudget, ..Default::default() };
        // no artifacts on disk -> the artifact-class slot is the f32 engine
        let roster = Roster::build(None, store, &cfg).unwrap();
        assert_eq!(roster.len(), 3, "auto roster: qgemm2 + csd + f32");
        assert_eq!(roster.name(), "auto-hybrid");
        assert_eq!(roster.policy_name(), "energy-budget");

        let m = Metrics::new();
        let mut scratch = Scratch::new();
        let mut r = Rng::new(72);
        let mut routed = std::collections::BTreeSet::new();
        for n in [1usize, 5, 32] {
            let x = synth_batch(&mut r, n);
            let (i, logits) = roster.dispatch(&x, &mut scratch).unwrap();
            assert_eq!(logits.shape(), &[n, 10], "n={n}");
            routed.insert(roster.engine(i).kind());
        }
        assert_eq!(
            routed.into_iter().collect::<Vec<_>>(),
            vec![EngineKind::F32, EngineKind::Quantized, EngineKind::Csd],
            "energy policy must route a batch to each engine class"
        );

        // every engine's report lands in the uniform engine.* gauge family
        for e in roster.engines() {
            e.report().export(|k, v| m.set_gauge(k, v));
        }
        for name in ["host-f32", "host-qgemm", "host-csd"] {
            assert_eq!(
                m.gauge(&format!("engine.{name}.forwards")),
                Some(1.0),
                "{name}: exactly one batch routed"
            );
            for suffix in [
                "skipped_fraction",
                "mean_pp",
                "energy.partial_products",
                "energy.fp_muls",
                "energy.compute_pj",
                "energy.total_pj",
                "pool.spawns",
            ] {
                assert!(
                    m.gauge(&format!("engine.{name}.{suffix}")).is_some(),
                    "engine.{name}.{suffix} missing from the uniform schema"
                );
            }
        }
        // and the fields mean what they say: the CSD route spent partial
        // products, the f32 route spent fp32 MACs, the code-domain route
        // skipped zero codes and charged only its fp32 head
        assert!(m.gauge("engine.host-csd.energy.partial_products").unwrap() > 0.0);
        assert!(m.gauge("engine.host-csd.mean_pp").unwrap() > 0.0);
        assert!(m.gauge("engine.host-f32.energy.fp_muls").unwrap() > 0.0);
        assert!(m.gauge("engine.host-qgemm.skipped_fraction").unwrap() > 0.0);
        let head = m.gauge("engine.host-qgemm.energy.fp_muls").unwrap();
        let full = m.gauge("engine.host-f32.energy.fp_muls").unwrap();
        assert!(head > 0.0 && head < full, "code-domain charges only the fp32 head");
    }

    #[test]
    fn pinned_roster_routes_everything_to_its_engine() {
        let store = synth_store(73, ModelKind::Lenet);
        let cfg = ServerConfig {
            engine: EngineSelect::HostCsd(CsdQuality::new(3)),
            policy: PolicySelect::EnergyBudget,
            ..Default::default()
        };
        let roster = Roster::build(None, store, &cfg).unwrap();
        assert_eq!(roster.len(), 1);
        assert_eq!(roster.name(), "host-csd");
        for n in [1usize, 8, 32] {
            assert_eq!(roster.route(n), 0);
        }
        let mut r = Rng::new(74);
        let mut scratch = Scratch::new();
        let (i, logits) = roster.dispatch(&synth_batch(&mut r, 2), &mut scratch).unwrap();
        assert_eq!((i, logits.shape()), (0, &[2usize, 10][..]));
        let rep = roster.engine(0).report();
        assert_eq!(rep.kind, EngineKind::Csd);
        assert!(rep.mean_pp <= 3.0 + 1e-12, "digit dial bounds the report's pp");
    }

    #[test]
    fn policies_differ_on_partial_batches() {
        // the three policies are genuinely different routers on the same
        // roster: a half-full batch goes artifact-class under batch-fill,
        // stays host under latency-floor, and the smallest batch only
        // reaches CSD under the energy policy
        let mk = |policy| {
            let cfg = ServerConfig { policy, ..Default::default() };
            Roster::build(None, synth_store(75, ModelKind::Lenet), &cfg).unwrap()
        };
        let fill = mk(PolicySelect::BatchFill);
        let floor = mk(PolicySelect::LatencyFloor);
        let energy = mk(PolicySelect::EnergyBudget);
        let kind_at = |r: &Roster, n: usize| r.engine(r.route(n)).kind();
        assert_eq!(kind_at(&fill, 16), EngineKind::F32);
        assert_eq!(kind_at(&floor, 16), EngineKind::Quantized);
        assert_eq!(kind_at(&fill, 1), EngineKind::Quantized);
        assert_eq!(kind_at(&energy, 1), EngineKind::Csd);
        assert_eq!(kind_at(&floor, 32), EngineKind::F32);
    }

    #[test]
    fn batch_tensor_copies_rows() {
        let (tx, _rx) = mpsc::channel();
        let jobs: Vec<Pending<Job>> = (0..2)
            .map(|i| Pending {
                payload: Job {
                    id: i,
                    pixels: vec![i as f32; 4],
                    enqueued: Instant::now(),
                    resp: tx.clone(),
                },
                enqueued: Instant::now(),
            })
            .collect();
        let t = batch_tensor(&jobs, 2, 2, 2, 1).unwrap();
        assert_eq!(t.shape(), &[2, 2, 2, 1]);
        assert_eq!(t.data(), &[0.0, 0.0, 0.0, 0.0, 1.0, 1.0, 1.0, 1.0]);
        // padded rows stay zero (the PJRT path)
        let p = batch_tensor(&jobs, 3, 2, 2, 1).unwrap();
        assert_eq!(p.shape(), &[3, 2, 2, 1]);
        assert_eq!(&p.data()[8..], &[0.0; 4]);
    }
}
