//! TCP inference server: JSON-lines protocol, dynamic batching, one
//! inference owner thread over a pluggable engine.
//!
//! Protocol (one JSON object per line):
//! ```text
//! -> {"id": 7, "pixels": [ ... H*W*C floats ... ]}
//! <- {"id": 7, "pred": 3, "latency_us": 812, "batch": 32, "gen": 1}
//! ```
//! `gen` is the roster generation that served the request (it advances on a
//! hot model swap — see below).
//! Each connection is synchronous (request → response); concurrency comes
//! from multiple connections feeding the shared [`BatchQueue`], which the
//! worker drains in dynamic batches.  The worker executes over a [`Roster`]
//! of boxed [`Engine`]s: the PJRT artifact wrapper (padded to the compiled
//! batch size), the pure-rust blocked-GEMM [`F32Engine`], the code-domain
//! [`QuantizedEngine`] (plane-packed codes on qgemm v2), and the CSD
//! shift-and-add [`CsdEngine`] (truncated-CSD digit planes on
//! `kernels::csd`).  [`EngineSelect`] pins the roster to one engine, or
//! `Auto` builds the full roster and a pluggable
//! [`DispatchPolicy`] re-routes every popped batch (`--policy`
//! batch-fill|latency|energy): artifact-filling batches to the compiled
//! path, small/singleton batches to the low-latency or minimum-energy host
//! engines — under the energy policy the smallest batches reach the CSD
//! engine.  The worker owns one [`Scratch`] arena, so the host paths stop
//! allocating per request once warm, and all host kernels dispatch row bands
//! on the persistent worker pool.  After every batch the worker exports the
//! pool's spawn/wakeup counters, the arena's per-layer high-water marks
//! (`pool.*`, `scratch_hw.*` — a flat `pool.spawns` is the "zero threads
//! spawned per request" steady-state invariant), and every roster engine's
//! uniform [`crate::runtime::engine::EngineReport`] as the
//! `engine.<name>.*` gauge family (`docs/METRICS.md`).
//!
//! ## Fault tolerance
//!
//! The serving path degrades gracefully under the three pressures that
//! actually hit edge deployments:
//!
//! * **Overload** — the queue is bounded ([`ServerConfig::queue_cap`],
//!   default 4× the batch size): at capacity, `push` rejects and the
//!   connection replies `{"error":"overloaded","retry_after_ms":N}`, with
//!   `N` derived from the observed per-batch inference EWMA times the
//!   backlog depth.  Jobs that waited past [`ServerConfig::deadline`] are
//!   shed by the worker with a `deadline exceeded` reply instead of burning
//!   a kernel slot (`shed_overload` / `shed_deadline` counters,
//!   `queue.depth` gauge).
//! * **Engine failures** — every forward runs under `catch_unwind`: an
//!   engine error or panic fails only the in-flight batch (each job gets a
//!   terminal error reply) and the worker keeps serving with a fresh
//!   [`Scratch`].  An engine that fails
//!   [`ServerConfig::quarantine_after`] times consecutively is
//!   *quarantined*: [`Roster::route`] hides it from the dispatch policy, so
//!   the existing preference orders degrade traffic to the next engine
//!   class, and after [`ServerConfig::quarantine_cooldown`] routed batches
//!   the engine is probed once — a successful probe reinstates it, a failed
//!   one re-quarantines (`engine.<name>.quarantined` gauges, `quarantines`
//!   / `engine_failures` / `worker_panics` counters).
//! * **Shutdown** — [`Server::stop`] drains the queue and sends every
//!   unserved job an explicit `server shutting down` reply
//!   (`shed_shutdown`), so clients never hang out their reply timeout,
//!   which is itself derived from the configured deadline
//!   ([`ServerConfig::reply_timeout`]) rather than a hardcoded 30s.
//!
//! ## Hot model swap
//!
//! [`Server::deploy_store`] replaces the serving model with zero downtime:
//! the [`super::swap`] pipeline stages a complete replacement generation off
//! the serving thread (encode → noisy-channel transfer → hardened decode →
//! engine build → canary gate), posts it to the worker's
//! [`SwapSlot`](super::swap::SwapSlot), and the worker installs it *between*
//! batches — the in-flight batch finishes on the old generation, and the
//! [`Roster`] generation counter advances (`swap.generation` gauge, `gen` in
//! every reply).  The displaced engines are retained for
//! [`ServerConfig::probation_batches`]: if the new generation racks up
//! [`ServerConfig::rollback_quarantines`] quarantine events inside that
//! window, the worker rolls the old generation straight back
//! (`swap.rollbacks`).  A failure at any staging stage leaves the old
//! generation serving untouched and bumps the matching `swap.fail.*`
//! counter.  All PR-6 guarantees hold across the swap boundary: admission
//! stays bounded (the queue is never touched), quarantine state is rebuilt
//! per generation, and [`Server::stop`] marks the slot dead so no deployer
//! blocks on a worker that exited.
//!
//! Chaos scenarios are driven through [`crate::util::faults`]
//! (`PALLAS_FAULTS`): when armed at roster-build time every engine is
//! wrapped in a [`FaultInjector`]; disarmed, the wrapper is never
//! constructed and the hot path is untouched.  Swapped-in generations get
//! the same treatment at install time, and the `swap.build` / `swap.canary`
//! clauses fail the staging pipeline at those stages.

use std::cell::Cell;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::{self, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::batcher::{BatchQueue, Pending, PushError};
use super::metrics::Metrics;
use super::swap::{self, PendingSwap, SwapConfig, SwapError, SwapReport, SwapSlot, SwapStage};
use crate::device::{CsdQuality, QualityConfig};
use crate::kernels::{self, Scratch};
use crate::model::meta::ModelKind;
use crate::model::store::WeightStore;
use crate::quant::qsq::AssignMode;
use crate::runtime::engine::{
    DispatchPolicy, Engine, EngineKind, FaultInjector, PjrtEngine, PolicySelect,
};
use crate::runtime::host::{CsdEngine, F32Engine, QuantizedEngine};
use crate::tensor::{ops, Tensor};
use crate::util::json::{self, Value};

pub use crate::runtime::engine::batch_prefers_artifact;

/// Quality the `Auto` roster quantizes its code-domain engine at (the
/// canonical phi=4, N=16 point the deploy pipeline defaults to).  Public so
/// [`super::swap::SwapConfig`]'s defaults replace like with like.
pub const AUTO_QUALITY: QualityConfig = QualityConfig { phi: 4, group: 16 };

/// Digit budget the `Auto` roster's CSD engine serves at: 4 kept partial
/// products per weight keeps truncation error small while the energy policy
/// still halves-or-better the shift-and-add work of exact CSD.
pub const AUTO_CSD_DIGITS: usize = 4;

/// Longest a deployer waits for the worker to pick up and acknowledge a
/// posted generation.  The worker installs between batches, so this only
/// trips if the worker is wedged in a pathological forward.
const SWAP_INSTALL_TIMEOUT: Duration = Duration::from_secs(30);

/// Which inference engine(s) the worker thread runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineSelect {
    /// Batch-aware roster: every popped batch is re-routed by the
    /// [`DispatchPolicy`] in [`ServerConfig::policy`] over the full engine
    /// roster — the PJRT artifact (threaded f32 host engine when PJRT is
    /// unavailable), the code-domain quantized engine, and the CSD
    /// shift-and-add engine.
    Auto,
    /// PJRT only; startup fails if it is unavailable.
    Pjrt,
    /// Pure-rust f32 engine (blocked/parallel GEMM).
    Host,
    /// Pure-rust code-domain engine: weights quantized at this quality and
    /// served from packed codes on the qgemm kernel.
    HostQuantized(QualityConfig),
    /// Pure-rust CSD shift-and-add engine (§V.B): weights truncated-CSD
    /// packed at this digit budget and served on `kernels::csd`, with the
    /// per-request energy ledger exported via the `engine.host-csd.*`
    /// gauge family.
    HostCsd(CsdQuality),
}

#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub model: ModelKind,
    /// Compiled artifact batch (the padded execution size on PJRT).
    pub batch: usize,
    /// Dynamic batching window.
    pub max_delay: Duration,
    /// Bind address, e.g. "127.0.0.1:0" (port 0 = ephemeral).
    pub bind: String,
    /// Inference engine selection.
    pub engine: EngineSelect,
    /// Batch-dispatch policy for the `Auto` roster (ignored when the
    /// roster is pinned to a single engine).
    pub policy: PolicySelect,
    /// Admission cap on the batch queue (`--queue-cap`); 0 means "derive":
    /// 4× the batch size ([`ServerConfig::effective_queue_cap`]).
    pub queue_cap: usize,
    /// Queue-wait deadline (`--deadline-ms`): a job still queued this long
    /// after arrival is shed with a `deadline exceeded` reply.
    pub deadline: Duration,
    /// Consecutive `forward_with` failures (errors or panics) after which an
    /// engine is quarantined and routed around.
    pub quarantine_after: u32,
    /// Routed batches a quarantined engine sits out before one probe batch
    /// is sent its way (tick-based, not wall-clock, so chaos outcomes are
    /// deterministic under any pool configuration).
    pub quarantine_cooldown: u64,
    /// Batches a freshly swapped-in generation serves with the displaced
    /// engines still retained: within this window a quarantine storm rolls
    /// the old generation straight back.  0 disables probation (the old
    /// engines retire at install).
    pub probation_batches: u64,
    /// Quarantine events within the probation window that trigger an
    /// automatic rollback to the displaced generation.
    pub rollback_quarantines: u64,
}

impl ServerConfig {
    /// The admission cap actually applied: `queue_cap`, or 4× the batch
    /// size when left at 0 — deep enough to absorb a burst of a few full
    /// batches, shallow enough that queue wait stays bounded by a handful
    /// of batch windows.
    pub fn effective_queue_cap(&self) -> usize {
        if self.queue_cap == 0 {
            self.batch.saturating_mul(4).max(1)
        } else {
            self.queue_cap
        }
    }

    /// How long a connection waits for its reply before giving up: the
    /// queue deadline (the longest a job may legitimately sit queued), one
    /// batching window, and a generous inference allowance.  Replaces the
    /// old hardcoded 30s wait, and stays consistent with `deadline` by
    /// construction.
    pub fn reply_timeout(&self) -> Duration {
        self.deadline + self.max_delay + Duration::from_secs(5)
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            model: ModelKind::Lenet,
            batch: 32,
            max_delay: Duration::from_millis(5),
            bind: "127.0.0.1:0".into(),
            engine: EngineSelect::Auto,
            policy: PolicySelect::BatchFill,
            queue_cap: 0,
            deadline: Duration::from_secs(2),
            quarantine_after: 3,
            quarantine_cooldown: 64,
            probation_batches: 32,
            rollback_quarantines: 1,
        }
    }
}

/// Per-engine failure bookkeeping for quarantine.  `Cell`-based because the
/// roster is owned by the single inference-worker thread and routing takes
/// `&self`.
struct Health {
    /// Consecutive `forward_with` failures; any success resets it.
    consecutive: Cell<u32>,
    /// `Some(tick)` while quarantined: the route tick at which the engine
    /// becomes a probe candidate again.  `None` = healthy.
    quarantined_until: Cell<Option<u64>>,
}

impl Health {
    fn new() -> Health {
        Health { consecutive: Cell::new(0), quarantined_until: Cell::new(None) }
    }

    fn is_quarantined(&self) -> bool {
        self.quarantined_until.get().is_some()
    }

    /// Visible to the dispatch policy at `tick`: healthy, or quarantined
    /// with the cooldown expired (a probe candidate).
    fn available(&self, tick: u64) -> bool {
        match self.quarantined_until.get() {
            None => true,
            Some(until) => tick >= until,
        }
    }
}

/// The worker's engine roster: every serving engine as a boxed [`Engine`],
/// with a [`DispatchPolicy`] picking one per popped batch.  A pinned
/// [`EngineSelect`] builds a one-engine roster (the policy is then inert);
/// `Auto` builds the full roster.  Constructed on, and owned by, the worker
/// thread — the PJRT runtime is not `Send`.
///
/// The roster also owns the quarantine state: the worker reports each
/// batch's outcome via [`Roster::note_ok`] / [`Roster::note_failure`], and
/// [`Roster::route`] hides quarantined engines from the policy so the
/// preference orders degrade traffic to the next engine class.
pub struct Roster {
    engines: Vec<Box<dyn Engine>>,
    /// `engines[i]`'s kind, precomputed for the policy's route call.
    kinds: Vec<EngineKind>,
    policy: Box<dyn DispatchPolicy>,
    /// The batch size the policy crossovers price against: the compiled
    /// artifact batch (the padded cost a routed batch actually pays) when a
    /// PJRT engine is on the roster, the dynamic-batching cap otherwise.
    artifact_batch: usize,
    /// `dispatch_<engine>` counter names, precomputed per roster index so
    /// the worker's hot loop does not format a key per batch.
    dispatch_counters: Vec<String>,
    /// `engine.<name>.quarantined` gauge names, precomputed likewise.
    quarantine_gauges: Vec<String>,
    health: Vec<Health>,
    /// Route calls so far — the deterministic clock quarantine cooldowns
    /// count in (wall time would make chaos outcomes timing-dependent).
    tick: Cell<u64>,
    /// Fast path: when false, `route` skips all quarantine filtering.
    any_quarantined: Cell<bool>,
    /// Lifetime quarantine events (entries and probe-failure renewals).
    quarantine_events: Cell<u64>,
    quarantine_after: u32,
    quarantine_cooldown: u64,
    /// Which model generation this engine set serves (1 at startup,
    /// advanced by [`Roster::install`] on every hot swap — and moved *back*
    /// on a probation rollback).  Stamped into every reply as `gen`.
    generation: Cell<u64>,
}

impl Roster {
    /// Build the roster `cfg` asks for over an already-loaded store.
    /// `artifacts` is the directory the PJRT artifact would compile from;
    /// pass `None` to skip the PJRT path (benches and dispatch tests run
    /// rosters over synthetic stores with no artifacts on disk).
    pub fn build(
        artifacts: Option<&Path>,
        store: WeightStore,
        cfg: &ServerConfig,
    ) -> Result<Roster> {
        let mut engines: Vec<Box<dyn Engine>> = Vec::new();
        // the batch size the policy crossovers price against: the PJRT
        // engine's *compiled* batch when one is on the roster — artifact_for
        // rounds cfg.batch up to a compiled size, and that padded size is
        // the cost a routed batch actually pays, whatever the dynamic
        // batcher's cap is — cfg.batch otherwise
        let mut artifact_batch = cfg.batch;
        match cfg.engine {
            EngineSelect::Pjrt => {
                let dir = artifacts.context("PJRT engine needs an artifacts directory")?;
                let p = PjrtEngine::load(dir, cfg.model, cfg.batch, &store)?;
                artifact_batch = p.batch();
                engines.push(Box::new(p));
            }
            EngineSelect::Host => engines.push(Box::new(F32Engine::new(store))),
            EngineSelect::HostQuantized(q) => engines.push(Box::new(
                QuantizedEngine::quantize_store(&store, q, AssignMode::SigmaSearch)?,
            )),
            EngineSelect::HostCsd(q) => {
                engines.push(Box::new(CsdEngine::from_store(&store, q)?))
            }
            EngineSelect::Auto => {
                // a packing failure must not take Auto down: each engine
                // that fails to build is simply absent from the roster, and
                // the policies' preference orders route around it
                let pjrt = artifacts.and_then(|dir| {
                    match PjrtEngine::load(dir, cfg.model, cfg.batch, &store) {
                        Ok(p) => Some(p),
                        Err(e) => {
                            eprintln!(
                                "server: PJRT unavailable ({e:#}); the f32 host engine \
                                 serves artifact-sized batches"
                            );
                            None
                        }
                    }
                });
                let quant =
                    QuantizedEngine::quantize_store(&store, AUTO_QUALITY, AssignMode::SigmaSearch);
                match quant {
                    Ok(q) => engines.push(Box::new(q)),
                    Err(e) => eprintln!("server: quantized engine unavailable ({e:#})"),
                }
                match CsdEngine::from_store(&store, CsdQuality::new(AUTO_CSD_DIGITS)) {
                    Ok(c) => engines.push(Box::new(c)),
                    Err(e) => eprintln!("server: csd engine unavailable ({e:#})"),
                }
                // artifact-class engine last: PJRT when live (the weights
                // already sit in its prebuilt args), the f32 store otherwise
                match pjrt {
                    Some(p) => {
                        artifact_batch = p.batch();
                        engines.push(Box::new(p));
                    }
                    None => engines.push(Box::new(F32Engine::new(store))),
                }
            }
        }
        if engines.is_empty() {
            bail!("no engine could be built for {:?}", cfg.engine);
        }
        if artifact_batch > cfg.batch && engines.len() > 1 {
            // the dynamic batcher can never form a batch that fills the
            // compiled artifact — under latency-floor the artifact engine
            // will (correctly: every batch would pay padding) see no traffic
            eprintln!(
                "server: compiled artifact batch {artifact_batch} exceeds the batching \
                 cap {}; padding-averse policies will keep batches on the host engines",
                cfg.batch
            );
        }
        // chaos harness: with fault injection armed at build time, every
        // roster engine is wrapped so injected errors/panics/delays hit the
        // exact forward path real failures would.  Disarmed (the normal
        // case), the wrapper is never constructed and the serving hot path
        // carries zero fault-layer code.
        if crate::util::faults::armed() {
            engines = engines
                .into_iter()
                .map(|e| Box::new(FaultInjector::new(e)) as Box<dyn Engine>)
                .collect();
        }
        let kinds: Vec<EngineKind> = engines.iter().map(|e| e.kind()).collect();
        let dispatch_counters = engines
            .iter()
            .map(|e| format!("dispatch_{}", e.name().replace('-', "_")))
            .collect();
        let quarantine_gauges = engines
            .iter()
            .map(|e| format!("engine.{}.quarantined", e.name()))
            .collect();
        let health = engines.iter().map(|_| Health::new()).collect();
        Ok(Roster {
            engines,
            kinds,
            policy: cfg.policy.build(),
            artifact_batch,
            dispatch_counters,
            quarantine_gauges,
            health,
            tick: Cell::new(0),
            any_quarantined: Cell::new(false),
            quarantine_events: Cell::new(0),
            quarantine_after: cfg.quarantine_after.max(1),
            quarantine_cooldown: cfg.quarantine_cooldown.max(1),
            generation: Cell::new(1),
        })
    }

    /// The model generation currently serving.
    pub fn generation(&self) -> u64 {
        self.generation.get()
    }

    /// The batch size the dispatch policy prices crossovers against.
    pub fn artifact_batch(&self) -> usize {
        self.artifact_batch
    }

    /// Atomically replace the engine set (hot swap / rollback): the new
    /// engines take over with fresh health, dispatch and quarantine
    /// bookkeeping, and the roster starts reporting `generation`.  Returns
    /// the displaced engines — the caller keeps them through the probation
    /// window (rollback reinstalls them) or drops them to retire.  Policy
    /// and quarantine thresholds persist across generations; the route tick
    /// keeps counting so cooldown arithmetic never goes backwards.
    pub fn install(
        &mut self,
        engines: Vec<Box<dyn Engine>>,
        generation: u64,
        artifact_batch: usize,
    ) -> Vec<Box<dyn Engine>> {
        assert!(!engines.is_empty(), "a roster generation needs at least one engine");
        self.kinds = engines.iter().map(|e| e.kind()).collect();
        self.dispatch_counters = engines
            .iter()
            .map(|e| format!("dispatch_{}", e.name().replace('-', "_")))
            .collect();
        self.quarantine_gauges = engines
            .iter()
            .map(|e| format!("engine.{}.quarantined", e.name()))
            .collect();
        self.health = engines.iter().map(|_| Health::new()).collect();
        self.any_quarantined.set(false);
        self.artifact_batch = artifact_batch;
        self.generation.set(generation);
        std::mem::replace(&mut self.engines, engines)
    }

    /// Backend label for the startup `engine_*` counter: the pinned engine's
    /// name, or `auto-hybrid` for a policy-routed roster.
    pub fn name(&self) -> &'static str {
        if self.engines.len() == 1 {
            self.engines[0].name()
        } else {
            "auto-hybrid"
        }
    }

    /// The active dispatch policy's name.
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn len(&self) -> usize {
        self.engines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.engines.is_empty()
    }

    /// The engine at roster index `i`.
    pub fn engine(&self, i: usize) -> &dyn Engine {
        self.engines[i].as_ref()
    }

    /// The precomputed `dispatch_<engine>` counter key for roster index `i`.
    pub fn dispatch_counter(&self, i: usize) -> &str {
        &self.dispatch_counters[i]
    }

    /// The precomputed `engine.<name>.quarantined` gauge key for index `i`.
    pub fn quarantine_gauge(&self, i: usize) -> &str {
        &self.quarantine_gauges[i]
    }

    /// Every engine on the roster (for telemetry export).
    pub fn engines(&self) -> impl Iterator<Item = &dyn Engine> {
        self.engines.iter().map(|e| e.as_ref())
    }

    /// Whether roster index `i` is currently quarantined.
    pub fn quarantined(&self, i: usize) -> bool {
        self.health[i].is_quarantined()
    }

    /// Whether any engine is currently quarantined.
    pub fn any_quarantined(&self) -> bool {
        self.any_quarantined.get()
    }

    /// Lifetime quarantine events (initial entries plus probe-failure
    /// renewals).
    pub fn quarantine_events(&self) -> u64 {
        self.quarantine_events.get()
    }

    /// The roster index the policy routes an `n`-row batch to.  Quarantined
    /// engines are invisible to the policy until their cooldown expires
    /// (then exactly eligible again — the next batch they win is their
    /// probe); if *everything* is quarantined the full roster is used, since
    /// routing around every engine would mean serving nothing.
    pub fn route(&self, n: usize) -> usize {
        let tick = self.tick.get() + 1;
        self.tick.set(tick);
        if self.engines.len() == 1 {
            return 0;
        }
        if !self.any_quarantined.get() {
            return self
                .policy
                .route(n, self.artifact_batch, &self.kinds)
                .min(self.engines.len() - 1);
        }
        let mut avail_kinds = Vec::with_capacity(self.kinds.len());
        let mut avail_idx = Vec::with_capacity(self.kinds.len());
        for (i, h) in self.health.iter().enumerate() {
            if h.available(tick) {
                avail_kinds.push(self.kinds[i]);
                avail_idx.push(i);
            }
        }
        if avail_idx.is_empty() {
            return self
                .policy
                .route(n, self.artifact_batch, &self.kinds)
                .min(self.engines.len() - 1);
        }
        let j = self
            .policy
            .route(n, self.artifact_batch, &avail_kinds)
            .min(avail_idx.len() - 1);
        avail_idx[j]
    }

    /// Record a successful forward on roster index `i`: resets its failure
    /// streak, and — if this was a probe of a quarantined engine —
    /// reinstates it.
    pub fn note_ok(&self, i: usize) {
        let h = &self.health[i];
        h.consecutive.set(0);
        if h.is_quarantined() {
            h.quarantined_until.set(None);
            self.any_quarantined
                .set(self.health.iter().any(|h| h.is_quarantined()));
        }
    }

    /// Record a failed forward (error or panic) on roster index `i`.
    /// Returns `true` when this failure put (or kept) the engine in
    /// quarantine — a fresh entry after `quarantine_after` consecutive
    /// failures, or an immediate renewal when a probe of an
    /// already-quarantined engine fails.
    pub fn note_failure(&self, i: usize) -> bool {
        let h = &self.health[i];
        let streak = h.consecutive.get() + 1;
        h.consecutive.set(streak);
        if streak >= self.quarantine_after || h.is_quarantined() {
            h.quarantined_until
                .set(Some(self.tick.get() + self.quarantine_cooldown));
            self.any_quarantined.set(true);
            self.quarantine_events.set(self.quarantine_events.get() + 1);
            return true;
        }
        false
    }

    /// Forward one batch on roster index `i` (no health bookkeeping — the
    /// supervised worker wraps this in `catch_unwind` and reports the
    /// outcome via [`Roster::note_ok`] / [`Roster::note_failure`]).
    pub fn forward(&self, i: usize, x: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        self.engines[i].forward_with(x, scratch)
    }

    /// Route and execute one batch; returns the chosen roster index and the
    /// logits (real rows only — the PJRT wrapper trims its padding).  The
    /// outcome feeds the quarantine bookkeeping.
    pub fn dispatch(&self, x: &Tensor, scratch: &mut Scratch) -> Result<(usize, Tensor)> {
        let i = self.route(x.shape()[0]);
        match self.engines[i].forward_with(x, scratch) {
            Ok(logits) => {
                self.note_ok(i);
                Ok((i, logits))
            }
            Err(e) => {
                self.note_failure(i);
                Err(e)
            }
        }
    }
}

/// Copy a dynamic batch into one [rows, H, W, C] tensor; `rows` beyond the
/// batch stay zero.  The worker passes `rows == batch.len()` — any padding
/// to a compiled artifact size happens inside the PJRT engine wrapper.
fn batch_tensor(
    batch: &[Pending<Job>],
    rows: usize,
    h: usize,
    w: usize,
    c: usize,
) -> Result<Tensor> {
    let pix = h * w * c;
    let mut xdata = vec![0.0f32; rows * pix];
    for (i, job) in batch.iter().enumerate() {
        xdata[i * pix..(i + 1) * pix].copy_from_slice(&job.payload.pixels);
    }
    Tensor::new(vec![rows, h, w, c], xdata)
}

struct Job {
    id: u64,
    pixels: Vec<f32>,
    enqueued: Instant,
    resp: mpsc::Sender<Value>,
}

/// Reply `{"id":..,"error":..}` to one job (terminal error path).
fn reply_error(job: &Pending<Job>, msg: &str) {
    let resp = json::obj(vec![
        ("id", json::num(job.payload.id as f64)),
        ("error", json::s(msg)),
    ]);
    let _ = job.payload.resp.send(resp);
}

/// Where the worker gets its weights: an artifact directory on disk (the
/// CLI path — also enables PJRT), or an in-memory store (tests and benches
/// serve synthetic models with nothing on disk).
enum EngineSource {
    Artifacts(PathBuf),
    Store(WeightStore),
}

/// The displaced generation, retained by the worker while a swapped-in one
/// proves itself.  Dropped (engines retire) when `left` reaches 0; moved
/// back into the roster on a quarantine storm.
struct Probation {
    generation: u64,
    engines: Vec<Box<dyn Engine>>,
    artifact_batch: usize,
    /// Served batches remaining in the window.
    left: u64,
    /// `Roster::quarantine_events` at install time — events above this
    /// baseline were earned by the new generation.
    baseline: u64,
}

/// Prepare a staged generation's engines for install: coerce away the
/// `Send` bound (the worker owns them from here on) and — mirroring
/// [`Roster::build`] — wrap each in a [`FaultInjector`] when chaos is
/// armed, so injected faults hit swapped-in generations exactly like the
/// boot generation.
fn wrap_generation(engines: Vec<Box<dyn Engine + Send>>) -> Vec<Box<dyn Engine>> {
    let armed = crate::util::faults::armed();
    engines
        .into_iter()
        .map(|e| {
            let e: Box<dyn Engine> = e;
            if armed {
                Box::new(FaultInjector::new(e)) as Box<dyn Engine>
            } else {
                e
            }
        })
        .collect()
}

/// A running server; `stop()` for graceful shutdown,
/// [`deploy_store`](Server::deploy_store) for zero-downtime model swaps.
pub struct Server {
    pub port: u16,
    pub metrics: Arc<Metrics>,
    shutdown: Arc<AtomicBool>,
    queue: Arc<BatchQueue<Job>>,
    /// Mailbox between deploy callers and the serving worker.
    swap: Arc<SwapSlot>,
    /// Next generation number a successful deploy gets (boot roster is 1).
    next_gen: AtomicU64,
    handles: Vec<JoinHandle<()>>,
}

impl Server {
    /// Start the server; blocks until the PJRT worker has loaded weights and
    /// compiled the artifact (so the first request is never a cold start).
    pub fn start(artifacts: PathBuf, cfg: ServerConfig) -> Result<Server> {
        Self::start_inner(EngineSource::Artifacts(artifacts), cfg)
    }

    /// Start the server over an already-loaded weight store, with no
    /// artifacts on disk (the PJRT path is skipped).  Chaos tests and the
    /// overload bench serve synthetic stores this way.
    pub fn start_with_store(store: WeightStore, cfg: ServerConfig) -> Result<Server> {
        Self::start_inner(EngineSource::Store(store), cfg)
    }

    fn start_inner(source: EngineSource, cfg: ServerConfig) -> Result<Server> {
        // arm fault injection from PALLAS_FAULTS before the roster builds
        // (the build wraps engines only when armed); a malformed spec fails
        // startup loudly rather than running a chaos scenario fault-free
        crate::util::faults::arm_from_env()?;
        let listener = TcpListener::bind(&cfg.bind)
            .with_context(|| format!("binding {}", cfg.bind))?;
        listener.set_nonblocking(true)?;
        let port = listener.local_addr()?.port();

        let shutdown = Arc::new(AtomicBool::new(false));
        let queue = Arc::new(BatchQueue::<Job>::bounded(
            cfg.batch,
            cfg.max_delay,
            cfg.effective_queue_cap(),
            Some(cfg.deadline),
        ));
        let metrics = Arc::new(Metrics::new());
        let swap_slot = Arc::new(SwapSlot::new());

        // --- inference worker (owns the non-Send engine roster) -------------
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let wq = queue.clone();
        let wm = metrics.clone();
        let wcfg = cfg.clone();
        let ws = swap_slot.clone();
        let worker = thread::Builder::new().name("infer-worker".into()).spawn(move || {
            let built = match source {
                EngineSource::Artifacts(dir) => WeightStore::load(&dir, wcfg.model)
                    .and_then(|store| Roster::build(Some(&dir), store, &wcfg)),
                EngineSource::Store(store) => Roster::build(None, store, &wcfg),
            };
            let mut roster = match built {
                Ok(r) => {
                    let _ = ready_tx.send(Ok(()));
                    r
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    ws.mark_dead("engine roster failed to build");
                    return;
                }
            };
            wm.inc(&format!("engine_{}", roster.name()), 1);
            wm.inc(&format!("policy_{}", roster.policy_name()), 1);
            wm.set_gauge("swap.generation", roster.generation() as f64);
            // displaced engines held through a swapped-in generation's
            // probation window (rollback re-installs them)
            let mut probation: Option<Probation> = None;
            let (h, w, c) = wcfg.model.input_hwc();
            // one arena per worker: the host engines stop allocating per
            // request once the buffers are warm
            let mut scratch = Scratch::new();
            // the persistent kernel pool the host engines dispatch bands on;
            // its spawn counter stays flat once serving is warm
            let pool = kernels::Pool::global();

            while let Some(popped) = wq.pop_batch() {
                // hot-swap pickup: installs land here, *between* batches, so
                // an in-flight batch always finishes on the generation that
                // started it (deploy_store kicks the queue, so an idle
                // worker reaches this point without waiting for traffic)
                if ws.has_pending() {
                    if let Some(p) = ws.take_pending() {
                        let gen = p.generation;
                        let displaced_gen = roster.generation();
                        let displaced_ab = roster.artifact_batch();
                        let displaced =
                            roster.install(wrap_generation(p.engines), gen, wcfg.batch);
                        probation = if wcfg.probation_batches > 0 {
                            Some(Probation {
                                generation: displaced_gen,
                                engines: displaced,
                                artifact_batch: displaced_ab,
                                left: wcfg.probation_batches,
                                baseline: roster.quarantine_events(),
                            })
                        } else {
                            None // probation disabled: the old engines retire now
                        };
                        wm.set_gauge("swap.generation", gen as f64);
                        wm.set_gauge(
                            "swap.probation_left",
                            probation.as_ref().map_or(0.0, |p| p.left as f64),
                        );
                        ws.ack_installed(gen);
                    }
                }
                // deadline sheds: terminal replies, no kernel slot spent
                for job in &popped.expired {
                    wm.inc("shed_deadline", 1);
                    reply_error(job, "deadline exceeded");
                }
                wm.set_gauge("queue.depth", wq.len() as f64);
                let batch = popped.jobs;
                if batch.is_empty() {
                    continue;
                }
                let t0 = Instant::now();
                let n = batch.len();
                let x = match batch_tensor(&batch, n, h, w, c) {
                    Ok(x) => x,
                    Err(e) => {
                        let msg = format!("{e:#}");
                        for job in &batch {
                            reply_error(job, &msg);
                        }
                        continue;
                    }
                };
                // route *before* the supervised forward so an error or
                // panic is attributed to the engine that actually ran
                let idx = roster.route(n);
                let outcome =
                    panic::catch_unwind(AssertUnwindSafe(|| {
                        roster.forward(idx, &x, &mut scratch)
                    }));
                match outcome {
                    Ok(Ok(logits)) => {
                        roster.note_ok(idx);
                        let preds = ops::argmax_rows(&logits);
                        let engine = roster.engine(idx);
                        wm.inc(roster.dispatch_counter(idx), 1);
                        let infer_s = t0.elapsed().as_secs_f64();
                        wm.observe_s("infer_batch", infer_s);
                        // smoothed batch time, the retry_after_ms basis for
                        // overload sheds on the admission path
                        wm.observe_ewma("infer_batch.ewma_ms", infer_s * 1e3);
                        wm.inc("batches", 1);
                        wm.inc("requests", n as u64);
                        // pool + arena telemetry: spawns must stay flat once
                        // warm (a moving spawn gauge is a perf regression),
                        // and the per-layer high-water marks show how much
                        // arena each layer of the served model really needs
                        let ps = pool.stats();
                        wm.set_gauge("pool.spawns", ps.spawns as f64);
                        wm.set_gauge("pool.wakeups", ps.wakeups as f64);
                        wm.set_gauge("pool.jobs", ps.jobs as f64);
                        for (layer, pk) in scratch.layer_peaks() {
                            wm.set_gauge(
                                &format!("scratch_hw.{layer}.patch_bytes"),
                                pk.patch_bytes as f64,
                            );
                            wm.set_gauge(
                                &format!("scratch_hw.{layer}.pad_bytes"),
                                pk.pad_bytes as f64,
                            );
                            wm.set_gauge(
                                &format!("scratch_hw.{layer}.act_bytes"),
                                pk.act_bytes as f64,
                            );
                        }
                        // uniform per-engine telemetry: the engine that
                        // served this batch exports the `engine.<name>.*`
                        // gauge family from its EngineReport — forwards,
                        // zero-skip, mean partial products, the lifetime
                        // energy ledger (divide by `.forwards` for
                        // per-batch numbers, by counter.requests for
                        // per-request — docs/METRICS.md).  Only the routed
                        // engine's report can have changed, so the other
                        // roster members' gauges stay at their last export.
                        engine.report().export(|k, v| wm.set_gauge(k, v));
                        for (i, job) in batch.into_iter().enumerate() {
                            let e2e = job.payload.enqueued.elapsed();
                            wm.observe_s("request_e2e", e2e.as_secs_f64());
                            let resp = json::obj(vec![
                                ("id", json::num(job.payload.id as f64)),
                                ("pred", json::num(preds[i] as f64)),
                                ("latency_us", json::num(e2e.as_micros() as f64)),
                                ("batch", json::num(n as f64)),
                                ("gen", json::num(roster.generation() as f64)),
                            ]);
                            let _ = job.payload.resp.send(resp);
                        }
                    }
                    Ok(Err(e)) => {
                        // engine error: fail only this batch, keep serving
                        if roster.note_failure(idx) {
                            wm.inc("quarantines", 1);
                        }
                        wm.inc("engine_failures", 1);
                        let msg = format!("{e:#}");
                        for job in &batch {
                            reply_error(job, &msg);
                        }
                    }
                    Err(_) => {
                        // engine panic: the arena may be mid-mutation —
                        // rebuild it, fail this batch, keep the roster and
                        // keep serving
                        scratch = Scratch::new();
                        if roster.note_failure(idx) {
                            wm.inc("quarantines", 1);
                        }
                        wm.inc("worker_panics", 1);
                        for job in &batch {
                            reply_error(job, "engine panicked; batch failed");
                        }
                    }
                }
                // probation accounting for the batch just served: a
                // quarantine storm earned by the new generation rolls the
                // displaced one straight back; otherwise the window shrinks
                // and, once cleared, the displaced engines retire
                let storm = probation.as_ref().map_or(false, |p| {
                    roster.quarantine_events()
                        >= p.baseline + wcfg.rollback_quarantines.max(1)
                });
                if storm {
                    let p = probation.take().unwrap();
                    roster.install(p.engines, p.generation, p.artifact_batch);
                    wm.inc("swap.rollbacks", 1);
                    wm.set_gauge("swap.generation", p.generation as f64);
                    wm.set_gauge("swap.probation_left", 0.0);
                    eprintln!(
                        "server: quarantine storm during probation; rolled back to \
                         generation {}",
                        p.generation
                    );
                } else if let Some(p) = probation.as_mut() {
                    p.left -= 1;
                    wm.set_gauge("swap.probation_left", p.left as f64);
                }
                if probation.as_ref().map_or(false, |p| p.left == 0) {
                    probation = None; // window cleared; displaced engines retire
                }
                for i in 0..roster.len() {
                    wm.set_gauge(
                        roster.quarantine_gauge(i),
                        if roster.quarantined(i) { 1.0 } else { 0.0 },
                    );
                }
            }
            // queue closed: no deploy can ever land again — fail any
            // in-flight or future deploy instead of leaving it blocked
            ws.mark_dead("server shut down");
        })?;
        ready_rx
            .recv()
            .context("inference worker died during startup")??;

        // --- acceptor -------------------------------------------------------
        let aq = queue.clone();
        let ash = shutdown.clone();
        let am = metrics.clone();
        let pix_expected = {
            let (h, w, c) = cfg.model.input_hwc();
            h * w * c
        };
        let reply_timeout = cfg.reply_timeout();
        let acceptor = thread::Builder::new().name("acceptor".into()).spawn(move || {
            let mut conns: Vec<JoinHandle<()>> = Vec::new();
            while !ash.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let q = aq.clone();
                        let m = am.clone();
                        let sh = ash.clone();
                        conns.push(
                            thread::Builder::new()
                                .name("conn".into())
                                .spawn(move || {
                                    let _ = handle_conn(
                                        stream,
                                        q,
                                        m,
                                        pix_expected,
                                        sh,
                                        reply_timeout,
                                    );
                                })
                                .unwrap(),
                        );
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        })?;

        Ok(Server {
            port,
            metrics,
            shutdown,
            queue,
            swap: swap_slot,
            next_gen: AtomicU64::new(2),
            handles: vec![worker, acceptor],
        })
    }

    /// Hot-swap the serving model to `store` with zero downtime: stage a
    /// complete replacement generation through the [`super::swap`] pipeline
    /// (encode → noisy-channel transfer → hardened decode → engine build →
    /// canary gate) on *this* thread, then hand it to the serving worker,
    /// which installs it between batches.  Blocks until the worker
    /// acknowledges the install (bounded by an internal timeout) and
    /// returns the [`SwapReport`].
    ///
    /// On any failure the old generation keeps serving untouched; the
    /// matching `swap.fail.*` / `swap.canary_rejects` counter and
    /// `swap.failed` are bumped, and the returned error downcasts to
    /// [`SwapError`] naming the stage (with the partial
    /// [`TransferReport`](crate::channel::TransferReport) reachable under a
    /// transfer failure).
    pub fn deploy_store(&self, store: &WeightStore, cfg: &SwapConfig) -> Result<SwapReport> {
        let t0 = Instant::now();
        self.metrics.inc("swap.attempts", 1);
        let staged = match swap::stage(store, cfg) {
            Ok(s) => s,
            Err(e) => {
                let stage = e
                    .downcast_ref::<SwapError>()
                    .map_or(SwapStage::Build, |se| se.stage);
                self.metrics.inc(stage.fail_counter(), 1);
                self.metrics.inc("swap.failed", 1);
                return Err(e);
            }
        };
        let generation = self.next_gen.fetch_add(1, Ordering::Relaxed);
        if let Err(e) = self
            .swap
            .post(PendingSwap { generation, engines: staged.engines })
        {
            self.metrics.inc(SwapStage::Install.fail_counter(), 1);
            self.metrics.inc("swap.failed", 1);
            return Err(e);
        }
        // wake the worker even with no traffic flowing: the kicked queue
        // returns an empty pop, and the worker notices the pending
        // generation without waiting out a batch window
        self.queue.kick();
        if let Err(e) = self.swap.wait_installed(generation, SWAP_INSTALL_TIMEOUT) {
            self.metrics.inc(SwapStage::Install.fail_counter(), 1);
            self.metrics.inc("swap.failed", 1);
            return Err(e);
        }
        self.metrics.inc("swap.installs", 1);
        let elapsed_s = t0.elapsed().as_secs_f64();
        self.metrics.set_gauge("swap.last_latency_ms", elapsed_s * 1e3);
        Ok(SwapReport {
            generation,
            container_bytes: staged.container_bytes,
            transfer: staged.transfer,
            canary: staged.canary,
            elapsed_s,
        })
    }

    /// Graceful shutdown: stop accepting, drain the queue, join threads.
    /// Every queued-but-unserved job gets an explicit `server shutting
    /// down` reply (counted in `shed_shutdown`) — dropping their response
    /// senders would leave those clients hanging until their reply timeout.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // give in-flight connection reads a beat, then close the queue
        thread::sleep(Duration::from_millis(20));
        let backlog = self.queue.close();
        if !backlog.is_empty() {
            self.metrics.inc("shed_shutdown", backlog.len() as u64);
            for job in &backlog {
                reply_error(job, "server shutting down");
            }
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    queue: Arc<BatchQueue<Job>>,
    metrics: Arc<Metrics>,
    pix_expected: usize,
    shutdown: Arc<AtomicBool>,
    reply_timeout: Duration,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // read timeout so the thread notices shutdown even on idle connections
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // `line` persists across timeout retries: read_line appends, so a line
    // split by a read timeout reassembles on the next pass.
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) if line.ends_with('\n') => {}
            Ok(_) => continue, // partial line at EOF-less boundary; keep reading
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if shutdown.load(Ordering::Relaxed) {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            line.clear();
            continue;
        }
        let reply = match parse_request(&line, pix_expected) {
            Ok((id, pixels)) => {
                let (tx, rx) = mpsc::channel();
                let job = Job { id, pixels, enqueued: Instant::now(), resp: tx };
                match queue.push(job) {
                    Ok(()) => match rx.recv_timeout(reply_timeout) {
                        Ok(v) => v,
                        Err(_) => json::obj(vec![("error", json::s("inference timeout"))]),
                    },
                    Err(PushError::Full) => {
                        metrics.inc("shed_overload", 1);
                        json::obj(vec![
                            ("error", json::s("overloaded")),
                            ("retry_after_ms", json::num(retry_after_ms(&queue, &metrics))),
                        ])
                    }
                    Err(PushError::Closed) => {
                        json::obj(vec![("error", json::s("server shutting down"))])
                    }
                }
            }
            Err(e) => {
                metrics.inc("bad_requests", 1);
                json::obj(vec![("error", json::s(&format!("{e:#}")))])
            }
        };
        writer.write_all(reply.to_json().as_bytes())?;
        writer.write_all(b"\n")?;
        line.clear();
    }
}

/// The backoff hint attached to an `overloaded` shed: the time to drain the
/// current backlog, estimated as (batches queued) × (observed per-batch
/// inference EWMA).  Before the first batch completes there is no EWMA yet;
/// one batching window is the honest floor.
fn retry_after_ms(queue: &BatchQueue<Job>, metrics: &Metrics) -> f64 {
    let ewma_ms = metrics
        .gauge("infer_batch.ewma_ms")
        .unwrap_or_else(|| queue.max_delay.as_secs_f64() * 1e3);
    let backlog_batches = queue.len().div_ceil(queue.max_batch).max(1);
    (ewma_ms * backlog_batches as f64).ceil().max(1.0)
}

fn parse_request(line: &str, pix_expected: usize) -> Result<(u64, Vec<f32>)> {
    let v = json::parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    let id = v
        .get("id")
        .as_f64()
        .context("missing id")? as u64;
    let arr = v.get("pixels").as_arr().context("missing pixels")?;
    let mut pixels = Vec::with_capacity(arr.len());
    for (i, x) in arr.iter().enumerate() {
        // a non-numeric entry is a malformed request: reject it instead of
        // silently serving garbage (the old path mapped it to 0.0)
        match x.as_f64() {
            Some(f) => pixels.push(f as f32),
            None => bail!("pixel {i} is not a number"),
        }
    }
    if pixels.len() != pix_expected {
        bail!("expected {pix_expected} pixels, got {}", pixels.len());
    }
    Ok((id, pixels))
}

/// Simple blocking client for examples/tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        Ok(Client { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }

    /// Send one request, wait for its reply.
    pub fn infer(&mut self, id: u64, pixels: &[f32]) -> Result<Value> {
        let req = json::obj(vec![
            ("id", json::num(id as f64)),
            (
                "pixels",
                Value::Arr(pixels.iter().map(|&p| json::num(p as f64)).collect()),
            ),
        ]);
        self.writer.write_all(req.to_json().as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        json::parse(&line).map_err(|e| anyhow::anyhow!("bad reply: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_validates() {
        assert!(parse_request("{\"id\":1,\"pixels\":[0.0,1.0]}", 2).is_ok());
        assert!(parse_request("{\"id\":1,\"pixels\":[0.0]}", 2).is_err());
        assert!(parse_request("{\"pixels\":[0.0,1.0]}", 2).is_err());
        assert!(parse_request("not json", 2).is_err());
    }

    #[test]
    fn parse_request_rejects_non_numeric_pixels() {
        // regression: these used to be silently served as 0.0
        for bad in [
            "{\"id\":1,\"pixels\":[0.0,\"x\"]}",
            "{\"id\":1,\"pixels\":[null,1.0]}",
            "{\"id\":1,\"pixels\":[0.0,true]}",
            "{\"id\":1,\"pixels\":[[],1.0]}",
        ] {
            let e = parse_request(bad, 2).unwrap_err();
            assert!(
                format!("{e:#}").contains("not a number"),
                "{bad}: unexpected error {e:#}"
            );
        }
    }

    #[test]
    fn default_config_sane() {
        let c = ServerConfig::default();
        assert_eq!(c.batch, 32);
        assert!(c.bind.ends_with(":0"));
        assert_eq!(c.engine, EngineSelect::Auto);
        assert_eq!(c.policy, PolicySelect::BatchFill);
        // admission-control defaults: cap derives from the batch size, the
        // client reply wait strictly dominates the queue deadline
        assert_eq!(c.queue_cap, 0);
        assert_eq!(c.effective_queue_cap(), 4 * 32);
        assert_eq!(
            ServerConfig { queue_cap: 7, ..ServerConfig::default() }.effective_queue_cap(),
            7
        );
        assert_eq!(c.deadline, Duration::from_secs(2));
        assert!(c.reply_timeout() > c.deadline + c.max_delay);
        assert_eq!(c.quarantine_after, 3);
        assert_eq!(c.quarantine_cooldown, 64);
        // hot-swap probation defaults: a one-quarantine storm inside a
        // 32-batch window rolls back
        assert_eq!(c.probation_batches, 32);
        assert_eq!(c.rollback_quarantines, 1);
    }

    use crate::data::synth_store;
    use crate::util::rng::Rng;

    fn synth_batch(r: &mut Rng, n: usize) -> Tensor {
        let xdata: Vec<f32> = (0..n * 28 * 28).map(|_| r.f32()).collect();
        Tensor::new(vec![n, 28, 28, 1], xdata).unwrap()
    }

    /// The acceptance route map: `--engine auto --policy energy` must reach
    /// every engine class — PJRT-or-f32 for artifact-filling batches, the
    /// code-domain engine for mid-size, and the CSD engine (previously
    /// unreachable from Auto) for the smallest — with every route's
    /// `engine.*` gauges populated from the same EngineReport schema.
    #[test]
    fn energy_policy_routes_each_engine_and_exports_uniform_gauges() {
        let store = synth_store(71, ModelKind::Lenet);
        let cfg = ServerConfig { policy: PolicySelect::EnergyBudget, ..Default::default() };
        // no artifacts on disk -> the artifact-class slot is the f32 engine
        let roster = Roster::build(None, store, &cfg).unwrap();
        assert_eq!(roster.len(), 3, "auto roster: qgemm2 + csd + f32");
        assert_eq!(roster.name(), "auto-hybrid");
        assert_eq!(roster.policy_name(), "energy-budget");

        let m = Metrics::new();
        let mut scratch = Scratch::new();
        let mut r = Rng::new(72);
        let mut routed = std::collections::BTreeSet::new();
        for n in [1usize, 5, 32] {
            let x = synth_batch(&mut r, n);
            let (i, logits) = roster.dispatch(&x, &mut scratch).unwrap();
            assert_eq!(logits.shape(), &[n, 10], "n={n}");
            routed.insert(roster.engine(i).kind());
        }
        assert_eq!(
            routed.into_iter().collect::<Vec<_>>(),
            vec![EngineKind::F32, EngineKind::Quantized, EngineKind::Csd],
            "energy policy must route a batch to each engine class"
        );

        // every engine's report lands in the uniform engine.* gauge family
        for e in roster.engines() {
            e.report().export(|k, v| m.set_gauge(k, v));
        }
        for name in ["host-f32", "host-qgemm", "host-csd"] {
            assert_eq!(
                m.gauge(&format!("engine.{name}.forwards")),
                Some(1.0),
                "{name}: exactly one batch routed"
            );
            for suffix in [
                "skipped_fraction",
                "mean_pp",
                "energy.partial_products",
                "energy.fp_muls",
                "energy.compute_pj",
                "energy.total_pj",
                "pool.spawns",
            ] {
                assert!(
                    m.gauge(&format!("engine.{name}.{suffix}")).is_some(),
                    "engine.{name}.{suffix} missing from the uniform schema"
                );
            }
        }
        // and the fields mean what they say: the CSD route spent partial
        // products, the f32 route spent fp32 MACs, the code-domain route
        // skipped zero codes and charged only its fp32 head
        assert!(m.gauge("engine.host-csd.energy.partial_products").unwrap() > 0.0);
        assert!(m.gauge("engine.host-csd.mean_pp").unwrap() > 0.0);
        assert!(m.gauge("engine.host-f32.energy.fp_muls").unwrap() > 0.0);
        assert!(m.gauge("engine.host-qgemm.skipped_fraction").unwrap() > 0.0);
        let head = m.gauge("engine.host-qgemm.energy.fp_muls").unwrap();
        let full = m.gauge("engine.host-f32.energy.fp_muls").unwrap();
        assert!(head > 0.0 && head < full, "code-domain charges only the fp32 head");
    }

    #[test]
    fn pinned_roster_routes_everything_to_its_engine() {
        let store = synth_store(73, ModelKind::Lenet);
        let cfg = ServerConfig {
            engine: EngineSelect::HostCsd(CsdQuality::new(3)),
            policy: PolicySelect::EnergyBudget,
            ..Default::default()
        };
        let roster = Roster::build(None, store, &cfg).unwrap();
        assert_eq!(roster.len(), 1);
        assert_eq!(roster.name(), "host-csd");
        for n in [1usize, 8, 32] {
            assert_eq!(roster.route(n), 0);
        }
        let mut r = Rng::new(74);
        let mut scratch = Scratch::new();
        let (i, logits) = roster.dispatch(&synth_batch(&mut r, 2), &mut scratch).unwrap();
        assert_eq!((i, logits.shape()), (0, &[2usize, 10][..]));
        let rep = roster.engine(0).report();
        assert_eq!(rep.kind, EngineKind::Csd);
        assert!(rep.mean_pp <= 3.0 + 1e-12, "digit dial bounds the report's pp");
    }

    #[test]
    fn policies_differ_on_partial_batches() {
        // the three policies are genuinely different routers on the same
        // roster: a half-full batch goes artifact-class under batch-fill,
        // stays host under latency-floor, and the smallest batch only
        // reaches CSD under the energy policy
        let mk = |policy| {
            let cfg = ServerConfig { policy, ..Default::default() };
            Roster::build(None, synth_store(75, ModelKind::Lenet), &cfg).unwrap()
        };
        let fill = mk(PolicySelect::BatchFill);
        let floor = mk(PolicySelect::LatencyFloor);
        let energy = mk(PolicySelect::EnergyBudget);
        let kind_at = |r: &Roster, n: usize| r.engine(r.route(n)).kind();
        assert_eq!(kind_at(&fill, 16), EngineKind::F32);
        assert_eq!(kind_at(&floor, 16), EngineKind::Quantized);
        assert_eq!(kind_at(&fill, 1), EngineKind::Quantized);
        assert_eq!(kind_at(&energy, 1), EngineKind::Csd);
        assert_eq!(kind_at(&floor, 32), EngineKind::F32);
    }

    #[test]
    fn quarantine_routes_around_then_probes_back() {
        let store = synth_store(81, ModelKind::Lenet);
        let cfg = ServerConfig {
            policy: PolicySelect::EnergyBudget,
            quarantine_after: 2,
            quarantine_cooldown: 4,
            ..Default::default()
        };
        let roster = Roster::build(None, store, &cfg).unwrap();
        // the energy policy sends singletons to the CSD engine
        let csd = roster.route(1);
        assert_eq!(roster.engine(csd).kind(), EngineKind::Csd);
        assert!(!roster.any_quarantined());

        // two consecutive failures quarantine it; the first is forgiven
        assert!(!roster.note_failure(csd));
        assert!(roster.note_failure(csd));
        assert!(roster.quarantined(csd));
        assert!(roster.any_quarantined());
        assert_eq!(roster.quarantine_events(), 1);

        // routed around: singletons degrade to the next energy preference
        let alt = roster.route(1);
        assert_ne!(alt, csd);
        assert_eq!(roster.engine(alt).kind(), EngineKind::Quantized);

        // a success elsewhere must not reinstate the quarantined engine
        roster.note_ok(alt);
        assert!(roster.quarantined(csd));

        // after the (tick-based) cooldown, the engine wins a probe batch
        let mut probed = false;
        for _ in 0..2 * cfg.quarantine_cooldown {
            if roster.route(1) == csd {
                probed = true;
                break;
            }
        }
        assert!(probed, "cooldown expiry must make the engine a probe candidate");

        // a failed probe re-quarantines immediately (no fresh streak)
        assert!(roster.note_failure(csd));
        assert_eq!(roster.quarantine_events(), 2);
        assert_ne!(roster.route(1), csd, "failed probe: back behind the fence");

        // a successful probe reinstates it
        let mut probe2 = false;
        for _ in 0..2 * cfg.quarantine_cooldown {
            if roster.route(1) == csd {
                probe2 = true;
                break;
            }
        }
        assert!(probe2);
        roster.note_ok(csd);
        assert!(!roster.quarantined(csd));
        assert!(!roster.any_quarantined());
        assert_eq!(roster.route(1), csd, "reinstated engine serves again");
    }

    #[test]
    fn fully_quarantined_roster_keeps_serving() {
        let store = synth_store(82, ModelKind::Lenet);
        let cfg = ServerConfig {
            quarantine_after: 1,
            quarantine_cooldown: 1000,
            ..Default::default()
        };
        let roster = Roster::build(None, store, &cfg).unwrap();
        for i in 0..roster.len() {
            assert!(roster.note_failure(i), "quarantine_after=1: first failure fences");
            assert!(roster.quarantined(i));
        }
        // routing around *everything* would mean serving nothing — the full
        // roster stays in play instead
        for n in [1usize, 8, 32] {
            let i = roster.route(n);
            assert!(i < roster.len());
        }
        // and a success anywhere starts reinstating
        let i = roster.route(32);
        roster.note_ok(i);
        assert!(!roster.quarantined(i));
    }

    #[test]
    fn roster_install_swaps_generation_and_returns_the_displaced_engines() {
        let cfg = ServerConfig::default();
        let mut roster =
            Roster::build(None, synth_store(83, ModelKind::Lenet), &cfg).unwrap();
        assert_eq!(roster.generation(), 1);
        assert_eq!(roster.len(), 3);
        // poison the boot generation's health so the reset is observable
        for _ in 0..cfg.quarantine_after {
            roster.note_failure(0);
        }
        assert!(roster.any_quarantined());

        let staged = swap::stage(&synth_store(84, ModelKind::Lenet), &SwapConfig::default())
            .unwrap();
        let displaced = roster.install(wrap_generation(staged.engines), 2, cfg.batch);
        assert_eq!(roster.generation(), 2);
        assert_eq!(displaced.len(), 3, "the whole boot generation comes back out");
        assert_eq!(roster.len(), 3);
        // fresh generation, fresh health: the old quarantine is gone
        assert!(!roster.any_quarantined());
        for i in 0..roster.len() {
            assert!(!roster.quarantined(i));
        }
        // and it serves: a dispatch routes + forwards on the new engines
        let mut r = Rng::new(85);
        let mut scratch = Scratch::new();
        let (_, logits) = roster.dispatch(&synth_batch(&mut r, 2), &mut scratch).unwrap();
        assert_eq!(logits.shape(), &[2, 10]);

        // rollback path: reinstalling the displaced set restores generation 1
        roster.install(displaced, 1, cfg.batch);
        assert_eq!(roster.generation(), 1);
        let (_, logits) = roster.dispatch(&synth_batch(&mut r, 1), &mut scratch).unwrap();
        assert_eq!(logits.shape(), &[1, 10]);
    }

    #[test]
    fn batch_tensor_copies_rows() {
        let (tx, _rx) = mpsc::channel();
        let jobs: Vec<Pending<Job>> = (0..2)
            .map(|i| Pending {
                payload: Job {
                    id: i,
                    pixels: vec![i as f32; 4],
                    enqueued: Instant::now(),
                    resp: tx.clone(),
                },
                enqueued: Instant::now(),
            })
            .collect();
        let t = batch_tensor(&jobs, 2, 2, 2, 1).unwrap();
        assert_eq!(t.shape(), &[2, 2, 2, 1]);
        assert_eq!(t.data(), &[0.0, 0.0, 0.0, 0.0, 1.0, 1.0, 1.0, 1.0]);
        // padded rows stay zero (the PJRT path)
        let p = batch_tensor(&jobs, 3, 2, 2, 1).unwrap();
        assert_eq!(p.shape(), &[3, 2, 2, 1]);
        assert_eq!(&p.data()[8..], &[0.0; 4]);
    }

    #[test]
    fn retry_after_scales_with_backlog() {
        let q: BatchQueue<Job> = BatchQueue::bounded(4, Duration::from_millis(5), 64, None);
        let m = Metrics::new();
        // no EWMA yet: the batching window is the floor
        assert_eq!(retry_after_ms(&q, &m), 5.0);
        m.observe_ewma("infer_batch.ewma_ms", 8.0);
        // empty queue still hints one batch worth
        assert_eq!(retry_after_ms(&q, &m), 8.0);
        let (tx, _rx) = mpsc::channel();
        for id in 0..9 {
            q.push(Job {
                id,
                pixels: Vec::new(),
                enqueued: Instant::now(),
                resp: tx.clone(),
            })
            .unwrap();
        }
        // 9 queued jobs at max_batch 4 = 3 batches to drain
        assert_eq!(retry_after_ms(&q, &m), 24.0);
    }
}
