//! Quality-aware request routing.
//!
//! Two routing decisions live here:
//!   1. **Deployment routing** — which quality (phi, N) a device receives,
//!      driven by its memory budget ([`DeviceProfile::select_quality`]).
//!   2. **Serving routing** — which compiled artifact executes a batch,
//!      driven by model kind and batch size (batch-1 for latency-critical
//!      singletons, batch-32 for the batched path, batch-128 for bulk eval).

use anyhow::{bail, Result};

use crate::device::{CsdQuality, DeviceProfile, QualityConfig};
use crate::model::bits;
use crate::model::meta::{ModelKind, ModelMeta};
use crate::quant::qsq::AssignMode;

/// A deployment decision for one device: all three stacked quality dials.
#[derive(Clone, Debug)]
pub struct DeployPlan {
    pub device: String,
    /// QSQ dial — what crosses the channel (memory budget).
    pub quality: QualityConfig,
    /// CSD digit dial — what the edge multiplier spends per weight
    /// (MACs-derived energy budget).
    pub csd: CsdQuality,
    /// Activation bit-width dial — the fixed-point width the device's
    /// serving datapath runs activations at (16 for the calibrated i16
    /// integer path on edge classes, 32 for server-class f32).
    pub act_bits: u32,
    pub mode: AssignMode,
    pub estimated_bits: u64,
}

/// Decide the stacked-dial quality level for every device in a roster.
pub fn plan_deployments(
    meta: &ModelMeta,
    devices: &[DeviceProfile],
    mode: AssignMode,
) -> Vec<Result<DeployPlan>> {
    let macs = meta.macs_per_image();
    devices
        .iter()
        .map(|d| {
            let bits_at = |phi: u32, group: usize| {
                // whole-model footprint: encoded quantized tensors + fp rest
                bits::model_bits(meta, phi, group).encoded_bits
            };
            match d.select_quality(bits_at, macs) {
                Some((q, csd, act_bits)) => Ok(DeployPlan {
                    device: d.name.clone(),
                    quality: q,
                    csd,
                    act_bits,
                    mode,
                    estimated_bits: bits_at(q.phi, q.group),
                }),
                None => bail!(
                    "device {} cannot fit {} at any quality",
                    d.name,
                    meta.kind.name()
                ),
            }
        })
        .collect()
}

/// Artifact name for (model, batch) on the serving path.
pub fn artifact_for(kind: ModelKind, batch: usize) -> Result<(String, usize)> {
    // supported compiled batch sizes, ascending
    const SIZES: [usize; 3] = [1, 32, 128];
    if batch == 0 {
        bail!("empty batch");
    }
    let b = *SIZES
        .iter()
        .find(|&&s| batch <= s)
        .unwrap_or(&SIZES[SIZES.len() - 1]);
    Ok((format!("{}_fwd_b{}", kind.name(), b), b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::meta::ModelMeta;

    #[test]
    fn artifact_selection() {
        assert_eq!(
            artifact_for(ModelKind::Lenet, 1).unwrap(),
            ("lenet_fwd_b1".into(), 1)
        );
        assert_eq!(
            artifact_for(ModelKind::Lenet, 7).unwrap(),
            ("lenet_fwd_b32".into(), 32)
        );
        assert_eq!(
            artifact_for(ModelKind::Convnet, 32).unwrap(),
            ("convnet_fwd_b32".into(), 32)
        );
        assert_eq!(
            artifact_for(ModelKind::Convnet, 100).unwrap(),
            ("convnet_fwd_b128".into(), 128)
        );
        // oversize batches clamp to the largest artifact (caller splits)
        assert_eq!(artifact_for(ModelKind::Lenet, 500).unwrap().1, 128);
        assert!(artifact_for(ModelKind::Lenet, 0).is_err());
    }

    #[test]
    fn deployment_plans_scale_with_device() {
        let meta = ModelMeta::lenet();
        let roster = crate::device::DeviceProfile::roster();
        let plans = plan_deployments(&meta, &roster, AssignMode::SigmaSearch);
        // every roster device fits LeNet at some quality
        for p in &plans {
            assert!(p.is_ok(), "{p:?}");
        }
        // server-class device gets the best quality on both dials
        let server = plans.last().unwrap().as_ref().unwrap();
        assert_eq!(server.quality.phi, 4);
        assert_eq!(server.csd, crate::device::CsdQuality::exact());
        // the MCU plan carries a strictly smaller digit budget
        let mcu = plans.first().unwrap().as_ref().unwrap();
        assert!(mcu.csd.max_digits < server.csd.max_digits);
    }

    #[test]
    fn estimated_bits_fit_budget() {
        let meta = ModelMeta::convnet();
        let roster = crate::device::DeviceProfile::roster();
        for (d, p) in roster.iter().zip(plan_deployments(&meta, &roster, AssignMode::Nearest)) {
            if let Ok(plan) = p {
                assert!(plan.estimated_bits / 8 <= d.model_budget_bytes, "{}", d.name);
            }
        }
    }
}
