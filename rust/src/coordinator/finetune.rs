//! On-device FC fine-tuning (paper Table III rows "5 epochs only FC" /
//! "20 epochs only FC"), driven entirely from rust over two AOT artifacts:
//!
//!   * `lenet_features_b128` — frozen (quantized) backbone → 84-d features,
//!   * `fc_step_b128`        — one SGD step on the fp32 head.
//!
//! The backbone weights stay encoded/approximate; only the head updates —
//! exactly the paper's protocol, but running at the edge.

use anyhow::{ensure, Result};

use crate::model::store::{Dataset, WeightStore};
use crate::runtime::client::{ArgValue, Runtime};
use crate::tensor::{ops, Tensor};
use crate::util::rng::Rng;

pub const STEP_BATCH: usize = 128;

/// Outcome of a fine-tuning run.
#[derive(Clone, Debug)]
pub struct FinetuneReport {
    pub epochs: usize,
    pub lr: f32,
    /// Mean loss per epoch.
    pub losses: Vec<f32>,
    pub acc_before: f64,
    pub acc_after: f64,
}

/// Compute backbone features for a whole dataset via the PJRT artifact.
pub fn backbone_features(
    rt: &mut Runtime,
    store: &WeightStore,
    data: &Dataset,
) -> Result<Tensor> {
    let exe = rt.load("lenet_features_b128")?;
    let n = data.len();
    ensure!(n % STEP_BATCH == 0, "dataset size {n} not divisible by {STEP_BATCH}");
    let backbone = ["c1w", "c1b", "c2w", "c2b", "f1w", "f1b", "f2w", "f2b"];
    let mut feats = Vec::with_capacity(n * 84);
    for start in (0..n).step_by(STEP_BATCH) {
        let mut args = vec![ArgValue::F32(data.batch(start, STEP_BATCH))];
        for name in backbone {
            args.push(ArgValue::F32(store.get(name)?.clone()));
        }
        let out = exe.run(&args)?;
        feats.extend_from_slice(out[0].data());
    }
    Tensor::new(vec![n, 84], feats)
}

fn one_hot(labels: &[i32]) -> Tensor {
    let mut data = vec![0.0f32; labels.len() * 10];
    for (i, &y) in labels.iter().enumerate() {
        data[i * 10 + y as usize] = 1.0;
    }
    Tensor::new(vec![labels.len(), 10], data).unwrap()
}

/// Head accuracy given precomputed features.
pub fn head_accuracy(feats: &Tensor, y: &[i32], w: &Tensor, b: &Tensor) -> Result<f64> {
    let logits = ops::add_bias(&ops::matmul(feats, w)?, b)?;
    let preds = ops::argmax_rows(&logits);
    let hits = preds.iter().zip(y).filter(|(&p, &t)| p as i32 == t).count();
    Ok(hits as f64 / y.len().max(1) as f64)
}

/// Fine-tune the fp32 head on-device. Returns (w', b', report).
pub fn finetune_fc(
    rt: &mut Runtime,
    store: &WeightStore,
    train: &Dataset,
    test: &Dataset,
    epochs: usize,
    lr: f32,
    seed: u64,
) -> Result<(Tensor, Tensor, FinetuneReport)> {
    let train_feats = backbone_features(rt, store, train)?;
    let test_feats = backbone_features(rt, store, test)?;

    let mut w = store.get("f3w")?.clone();
    let mut b = store.get("f3b")?.clone();
    let acc_before = head_accuracy(&test_feats, &test.y, &w, &b)?;

    let step = rt.load("fc_step_b128")?;
    let mut rng = Rng::new(seed);
    let n = train.len();
    let mut order: Vec<usize> = (0..n).collect();
    let mut losses = Vec::with_capacity(epochs);

    for _ep in 0..epochs {
        rng.shuffle(&mut order);
        let mut tot = 0.0f32;
        let mut steps = 0;
        for chunk in order.chunks(STEP_BATCH) {
            if chunk.len() < STEP_BATCH {
                break;
            }
            // gather the feature rows + labels of this shuffled batch
            let mut fb = Vec::with_capacity(STEP_BATCH * 84);
            let mut yb = Vec::with_capacity(STEP_BATCH);
            for &i in chunk {
                fb.extend_from_slice(&train_feats.data()[i * 84..(i + 1) * 84]);
                yb.push(train.y[i]);
            }
            let out = step.run(&[
                ArgValue::F32(Tensor::new(vec![STEP_BATCH, 84], fb)?),
                ArgValue::F32(one_hot(&yb)),
                ArgValue::F32(w.clone()),
                ArgValue::F32(b.clone()),
                ArgValue::Scalar(lr),
            ])?;
            tot += out[0].data()[0];
            w = out[1].clone();
            b = out[2].clone();
            steps += 1;
        }
        losses.push(tot / steps.max(1) as f32);
    }

    let acc_after = head_accuracy(&test_feats, &test.y, &w, &b)?;
    Ok((
        w,
        b,
        FinetuneReport { epochs, lr, losses, acc_before, acc_after },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_hot_layout() {
        let t = one_hot(&[2, 0]);
        assert_eq!(t.shape(), &[2, 10]);
        assert_eq!(t.at2(0, 2), 1.0);
        assert_eq!(t.at2(1, 0), 1.0);
        assert_eq!(t.data().iter().sum::<f32>(), 2.0);
    }

    #[test]
    fn head_accuracy_perfect_and_zero() {
        // features = identity rows, head = identity -> logits pick the label
        let feats = Tensor::new(vec![2, 84], {
            let mut d = vec![0.0; 2 * 84];
            d[3] = 1.0; // row 0 -> class 3
            d[84 + 7] = 1.0; // row 1 -> class 7
            d
        })
        .unwrap();
        let mut wdata = vec![0.0f32; 84 * 10];
        for c in 0..10 {
            wdata[c * 10 + c] = 1.0; // feature c votes class c
        }
        let w = Tensor::new(vec![84, 10], wdata).unwrap();
        let b = Tensor::zeros(vec![10]);
        assert_eq!(head_accuracy(&feats, &[3, 7], &w, &b).unwrap(), 1.0);
        assert_eq!(head_accuracy(&feats, &[0, 0], &w, &b).unwrap(), 0.0);
    }
}
