//! Zero-downtime model hot-swap over the noisy channel.
//!
//! The paper's deployment story — 3-bit QSQ containers small enough to ship
//! over a communication channel and decode on the edge device — previously
//! only worked offline: one process served one immutable model.  This module
//! makes the serving [`Roster`](super::server::Roster) *generational*: a
//! freshly trained store is staged into a complete replacement engine set
//! off the serving thread, gated, and atomically installed while traffic
//! keeps flowing.
//!
//! ## Pipeline (all off the serving thread)
//!
//! ```text
//! trainer store ──encode──▶ QSQ1 container ──Link (ARQ, bursts)──▶ bytes
//!                                                                   │
//!                       hardened decode_model (per-section CRC) ◀───┘
//!                                │
//!                 engine build (qgemm + CSD + f32 on the edge store)
//!                                │
//!                 canary gate (held-back batch vs the decode oracle)
//!                                │
//!            SwapSlot ──▶ worker installs between batches (atomic swap)
//! ```
//!
//! * **Transfer** rides [`Link`] — frames + CRC + stop-and-wait ARQ, with
//!   any `PALLAS_FAULTS` `link.burst` profile applied, exactly like
//!   `deploy-sim`.  Retry exhaustion surfaces the typed
//!   [`TransferError`](crate::channel::TransferError) with its partial
//!   report.
//! * **Decode** is the hardened [`decode_model`] (bounds-scanned sections,
//!   per-section CRC naming the offending tensor).
//! * **Build** constructs the full host engine set on the decoded edge
//!   store: code-domain qgemm from exactly the codes that crossed the wire,
//!   truncated-CSD, and the exact f32 path.  PJRT is deliberately excluded
//!   from hot swap — its runtime is thread-owned and artifact-bound; a
//!   swapped-in generation always serves the host roster.
//! * **Canary** forwards a held-back validation batch on every new engine
//!   and compares against the decode oracle (the fused f32 forward of the
//!   edge store): max |logit diff| and argmax agreement must clear
//!   [`CanaryConfig`] thresholds before the generation ever sees traffic.
//! * **Install** posts the staged generation to the worker's [`SwapSlot`];
//!   the worker picks it up *between* batches, so the in-flight batch
//!   finishes on the old generation.  The displaced engines are retained
//!   for a probation window — a quarantine storm rolls straight back
//!   (see `coordinator::server`).
//!
//! Any stage failure leaves the old generation serving untouched; the error
//! downcasts to [`SwapError`] naming the stage, and the server bumps the
//! matching `swap.fail.*` / `swap.canary_rejects` counter
//! (`docs/METRICS.md`).
//!
//! Fault points for chaos testing: `swap.build` and `swap.canary` clauses
//! in `PALLAS_FAULTS` fail the respective stage
//! ([`crate::util::faults::swap_build_fail`] /
//! [`crate::util::faults::swap_canary_fail`]).

use std::fmt;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::deploy::encode_store;
use crate::channel::{Link, LinkConfig, TransferReport};
use crate::codec::{decode_model, encode_model};
use crate::device::{CsdQuality, QualityConfig};
use crate::kernels::Scratch;
use crate::model::store::WeightStore;
use crate::quant::qsq::AssignMode;
use crate::runtime::engine::Engine;
use crate::runtime::host::{self, CsdEngine, F32Engine, QuantizedEngine};
use crate::tensor::{ops, Tensor};
use crate::util::faults;

use super::server::{AUTO_CSD_DIGITS, AUTO_QUALITY};

/// Where in the pipeline a swap failed (the `swap.fail.*` counter key).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwapStage {
    /// Channel transfer (ARQ exhaustion — the partial
    /// [`TransferReport`] rides the inner
    /// [`TransferError`](crate::channel::TransferError)).
    Transfer,
    /// Container integrity: CRC mismatch, truncation, malformed sections.
    Decode,
    /// Engine construction on the decoded edge store (or encode-side
    /// failure before the transfer).
    Build,
    /// Canary divergence against the decode oracle.
    Canary,
    /// Posting to / waiting on the serving worker.
    Install,
}

impl SwapStage {
    pub fn name(self) -> &'static str {
        match self {
            SwapStage::Transfer => "transfer",
            SwapStage::Decode => "decode",
            SwapStage::Build => "build",
            SwapStage::Canary => "canary",
            SwapStage::Install => "install",
        }
    }

    /// The metrics counter a failure at this stage increments.
    pub fn fail_counter(self) -> &'static str {
        match self {
            SwapStage::Transfer => "swap.fail.transfer",
            SwapStage::Decode => "swap.fail.decode",
            SwapStage::Build => "swap.fail.build",
            SwapStage::Canary => "swap.canary_rejects",
            SwapStage::Install => "swap.fail.install",
        }
    }
}

/// A staging failure, tagged with the pipeline stage it happened at.  The
/// underlying cause stays reachable through the public `source` field (e.g.
/// `source.downcast_ref::<TransferError>()` for the partial transfer
/// report).
#[derive(Debug)]
pub struct SwapError {
    pub stage: SwapStage,
    pub source: anyhow::Error,
}

impl fmt::Display for SwapError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "swap {} stage failed: {:#}", self.stage.name(), self.source)
    }
}

impl std::error::Error for SwapError {}

fn stage_err(stage: SwapStage, source: anyhow::Error) -> anyhow::Error {
    anyhow::Error::new(SwapError { stage, source })
}

/// The held-back validation gate a staged generation must clear before it
/// ever sees traffic.
#[derive(Clone, Copy, Debug)]
pub struct CanaryConfig {
    /// Rows in the held-back validation batch.
    pub batch: usize,
    /// Seed of the synthetic validation inputs ([`crate::data::RequestGen`]).
    pub seed: u64,
    /// Max tolerated |logit difference| vs the decode oracle, per engine.
    /// The gate catches *gross* divergence (a wrong or corrupt build), not
    /// quantization noise — the packed engines legitimately differ from the
    /// oracle by their approximation error.
    pub max_abs_diff: f64,
    /// Min argmax agreement with the oracle over the batch, per engine.
    pub min_agreement: f64,
}

impl Default for CanaryConfig {
    fn default() -> Self {
        CanaryConfig { batch: 8, seed: 701, max_abs_diff: 0.5, min_agreement: 0.25 }
    }
}

/// Everything a hot deploy needs: the quality dials the new generation is
/// encoded/served at, the channel the container crosses, and the canary
/// gate.  The defaults match the `Auto` roster's canonical quality point,
/// so a default swap replaces like with like.
#[derive(Clone, Copy, Debug)]
pub struct SwapConfig {
    /// QSQ dial (phi, N) the store is encoded at.
    pub quality: QualityConfig,
    /// CSD digit dial the new generation's CSD engine serves at.
    pub csd: CsdQuality,
    /// Code-assignment mode for the encode.
    pub mode: AssignMode,
    /// The channel profile; any armed `PALLAS_FAULTS` `link.burst` profile
    /// is overlaid on top, exactly like `deploy-sim`.
    pub link: LinkConfig,
    /// Link RNG seed (deterministic channel walk per seed).
    pub seed: u64,
    pub canary: CanaryConfig,
}

impl Default for SwapConfig {
    fn default() -> Self {
        SwapConfig {
            quality: AUTO_QUALITY,
            csd: CsdQuality::new(AUTO_CSD_DIGITS),
            mode: AssignMode::SigmaSearch,
            link: LinkConfig::default(),
            seed: 7,
            canary: CanaryConfig::default(),
        }
    }
}

/// Per-engine canary result (also returned in the [`SwapReport`] so deploy
/// callers can log how close the gate was).
#[derive(Clone, Debug)]
pub struct CanaryOutcome {
    pub engine: &'static str,
    pub max_abs_diff: f64,
    pub agreement: f64,
}

/// A fully staged replacement generation: the decoded edge store, the built
/// (but not yet installed) engine set, and what staging cost.  Engines are
/// `Send + Sync` — they are built on the deploy thread, handed through the
/// [`SwapSlot`], and installed into the shared roster that every replicated
/// inference worker reads.
pub struct StagedGeneration {
    /// The edge-side store: original fp32 head/biases + decoded approximate
    /// weights, the oracle the canary compared against.
    pub edge: WeightStore,
    /// The replacement engine set, in the `Auto` roster's host order:
    /// code-domain qgemm, truncated CSD, exact f32.
    pub engines: Vec<Box<dyn Engine + Send + Sync>>,
    pub transfer: TransferReport,
    /// Container bytes that crossed the channel.
    pub container_bytes: usize,
    pub canary: Vec<CanaryOutcome>,
}

/// What a completed swap reports back to the deployer.
#[derive(Clone, Debug)]
pub struct SwapReport {
    /// The generation number now serving.
    pub generation: u64,
    pub container_bytes: usize,
    pub transfer: TransferReport,
    pub canary: Vec<CanaryOutcome>,
    /// Transfer start → worker acknowledged the install.
    pub elapsed_s: f64,
}

/// Run the staging pipeline (encode → transfer → decode → build → canary)
/// for `store`; returns the staged generation ready to post to a
/// [`SwapSlot`].  Pure with respect to the serving thread — tests use it
/// directly to build the bitwise reference for post-swap logits.
pub fn stage(store: &WeightStore, cfg: &SwapConfig) -> Result<StagedGeneration> {
    // trainer side: encode at the requested dial (an encode failure is a
    // build-class failure — nothing ever left the trainer)
    let encoded =
        encode_store(store, cfg.quality, cfg.mode).map_err(|e| stage_err(SwapStage::Build, e))?;
    let container = encode_model(&encoded).map_err(|e| stage_err(SwapStage::Build, e))?;

    // the channel: frames + CRC + ARQ, with any armed burst profile overlaid
    let mut link_cfg = cfg.link;
    if let Some(b) = faults::link_burst() {
        link_cfg.burst = Some(b);
    }
    let mut link = Link::new(link_cfg, cfg.seed);
    let (received, transfer) =
        link.transmit(&container).map_err(|e| stage_err(SwapStage::Transfer, e))?;

    // edge side: integrity-checked decode, then reconstruct the edge store
    // (decoded approximate weights over the original fp32 head/biases)
    let decoded = decode_model(&received).map_err(|e| stage_err(SwapStage::Decode, e))?;
    let mut edge = store.clone();
    for et in &decoded.tensors {
        let w = et.tensor.decode();
        let t = Tensor::new(et.tensor.shape.clone(), w)
            .and_then(|t| edge.set(&et.name, t).map(|_| ()));
        if let Err(e) = t {
            return Err(stage_err(SwapStage::Decode, e));
        }
    }

    if faults::swap_build_fail() {
        return Err(stage_err(
            SwapStage::Build,
            anyhow!("injected engine-build failure (PALLAS_FAULTS swap.build)"),
        ));
    }
    let quant = QuantizedEngine::from_encoded(&edge, &decoded)
        .map_err(|e| stage_err(SwapStage::Build, e))?;
    let csd =
        CsdEngine::from_store(&edge, cfg.csd).map_err(|e| stage_err(SwapStage::Build, e))?;
    let f32e = F32Engine::new(edge.clone());
    let engines: Vec<Box<dyn Engine + Send + Sync>> =
        vec![Box::new(quant), Box::new(csd), Box::new(f32e)];

    let canary =
        canary_check(&edge, &engines, &cfg.canary).map_err(|e| stage_err(SwapStage::Canary, e))?;

    Ok(StagedGeneration { edge, engines, transfer, container_bytes: container.len(), canary })
}

/// Forward the held-back validation batch on every staged engine and compare
/// against the decode oracle (the fused f32 forward of the edge store).
/// Fails naming the first engine outside the gate.
fn canary_check(
    edge: &WeightStore,
    engines: &[Box<dyn Engine + Send + Sync>],
    cfg: &CanaryConfig,
) -> Result<Vec<CanaryOutcome>> {
    if faults::swap_canary_fail() {
        bail!("injected canary divergence (PALLAS_FAULTS swap.canary)");
    }
    let rows = cfg.batch.max(1);
    let (h, w, c) = edge.kind.input_hwc();
    let pix = h * w * c;
    let mut gen = crate::data::RequestGen::new(edge.kind, cfg.seed);
    let mut xdata = Vec::with_capacity(rows * pix);
    for _ in 0..rows {
        let (img, _) = gen.next();
        xdata.extend_from_slice(img.data());
    }
    let x = Tensor::new(vec![rows, h, w, c], xdata)?;
    let want = host::forward(edge, &x)?;
    let want_arg = ops::argmax_rows(&want);
    let mut outcomes = Vec::with_capacity(engines.len());
    let mut scratch = Scratch::new();
    for e in engines {
        let got = e.forward_with(&x, &mut scratch)?;
        let diff = got.max_abs_diff(&want) as f64;
        let got_arg = ops::argmax_rows(&got);
        let agree = want_arg.iter().zip(&got_arg).filter(|(a, b)| a == b).count() as f64
            / want_arg.len().max(1) as f64;
        if diff > cfg.max_abs_diff || agree < cfg.min_agreement {
            bail!(
                "canary divergence on {}: max |logit diff| {diff:.4} (limit {}), \
                 argmax agreement {agree:.2} (floor {})",
                e.name(),
                cfg.max_abs_diff,
                cfg.min_agreement
            );
        }
        outcomes.push(CanaryOutcome { engine: e.name(), max_abs_diff: diff, agreement: agree });
    }
    Ok(outcomes)
}

/// A staged generation in flight to the serving worker.
pub(crate) struct PendingSwap {
    pub generation: u64,
    pub engines: Vec<Box<dyn Engine + Send + Sync>>,
}

enum SlotState {
    Idle,
    Pending(PendingSwap),
    Installed(u64),
    /// The worker exited; deploys can no longer land.
    Dead(String),
}

/// The single-slot mailbox between a deploy thread and the serving worker.
/// The deployer [`post`](SwapSlot::post)s a staged generation and
/// [`wait_installed`](SwapSlot::wait_installed)s; the worker polls
/// [`has_pending`](SwapSlot::has_pending) between batches (one relaxed
/// atomic load — the serving hot path cost of the swap layer), takes the
/// pending generation, installs it, and
/// [`ack_installed`](SwapSlot::ack_installed)s.
pub(crate) struct SwapSlot {
    armed: std::sync::atomic::AtomicBool,
    state: Mutex<SlotState>,
    cv: Condvar,
}

impl SwapSlot {
    pub(crate) fn new() -> SwapSlot {
        SwapSlot {
            armed: std::sync::atomic::AtomicBool::new(false),
            state: Mutex::new(SlotState::Idle),
            cv: Condvar::new(),
        }
    }

    /// Worker-side fast path: anything staged?
    #[inline]
    pub(crate) fn has_pending(&self) -> bool {
        self.armed.load(std::sync::atomic::Ordering::Acquire)
    }

    /// Stage a generation for the worker.  One deploy at a time: a second
    /// post while one is pending is rejected (the caller reports a failed
    /// deploy; the pending one is untouched).
    pub(crate) fn post(&self, p: PendingSwap) -> Result<()> {
        let mut g = self.state.lock().unwrap();
        match &*g {
            SlotState::Idle | SlotState::Installed(_) => {
                *g = SlotState::Pending(p);
                self.armed.store(true, std::sync::atomic::Ordering::Release);
                Ok(())
            }
            SlotState::Pending(_) => bail!("another deploy is already staged"),
            SlotState::Dead(msg) => bail!("serving worker is gone: {msg}"),
        }
    }

    /// Worker side: take the staged generation, if any.
    pub(crate) fn take_pending(&self) -> Option<PendingSwap> {
        let mut g = self.state.lock().unwrap();
        if matches!(&*g, SlotState::Pending(_)) {
            self.armed.store(false, std::sync::atomic::Ordering::Release);
            match std::mem::replace(&mut *g, SlotState::Idle) {
                SlotState::Pending(p) => Some(p),
                _ => unreachable!(),
            }
        } else {
            None
        }
    }

    /// Worker side: the taken generation is now serving.
    pub(crate) fn ack_installed(&self, generation: u64) {
        *self.state.lock().unwrap() = SlotState::Installed(generation);
        self.cv.notify_all();
    }

    /// Deployer side: block until the worker acknowledges `generation` (or
    /// the worker dies / `timeout` passes).  Resets the slot to idle on
    /// success so the next deploy can post.
    pub(crate) fn wait_installed(&self, generation: u64, timeout: Duration) -> Result<()> {
        let deadline = Instant::now() + timeout;
        let mut g = self.state.lock().unwrap();
        loop {
            match &*g {
                SlotState::Installed(gen) if *gen == generation => {
                    *g = SlotState::Idle;
                    return Ok(());
                }
                SlotState::Dead(msg) => bail!("swap not installed: {msg}"),
                _ => {}
            }
            let now = Instant::now();
            if now >= deadline {
                bail!("swap install timed out after {timeout:?}");
            }
            let (ng, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
            g = ng;
        }
    }

    /// Worker side, on exit: fail any in-flight or future deploy instead of
    /// leaving its thread blocked on the condvar.  A staged-but-never-
    /// installed generation is dropped here.
    pub(crate) fn mark_dead(&self, msg: &str) {
        self.armed.store(false, std::sync::atomic::Ordering::Release);
        *self.state.lock().unwrap() = SlotState::Dead(msg.to_string());
        self.cv.notify_all();
    }
}

impl Default for SwapSlot {
    fn default() -> Self {
        SwapSlot::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_store;
    use crate::model::meta::ModelKind;

    // Fault injection is never armed here (process-global); fault-driven
    // swap behavior lives in the test_chaos integration binary.

    #[test]
    fn staging_is_deterministic_and_logits_are_bitwise() {
        let cfg = SwapConfig::default();
        let a = stage(&synth_store(61, ModelKind::Lenet), &cfg).unwrap();
        let b = stage(&synth_store(61, ModelKind::Lenet), &cfg).unwrap();
        assert_eq!(a.transfer, b.transfer, "same seed, same channel walk");
        assert_eq!(a.container_bytes, b.container_bytes);
        assert_eq!(a.engines.len(), 3, "qgemm + csd + f32");
        // post-swap logits must bitwise-match the new store: two independent
        // stagings of the same store produce bitwise-identical engines
        let mut gen = crate::data::RequestGen::new(ModelKind::Lenet, 99);
        let (h, w, c) = ModelKind::Lenet.input_hwc();
        let mut xdata = Vec::new();
        for _ in 0..3 {
            let (img, _) = gen.next();
            xdata.extend_from_slice(img.data());
        }
        let x = Tensor::new(vec![3, h, w, c], xdata).unwrap();
        let mut sa = Scratch::new();
        let mut sb = Scratch::new();
        for (ea, eb) in a.engines.iter().zip(&b.engines) {
            let ya = ea.forward_with(&x, &mut sa).unwrap();
            let yb = eb.forward_with(&x, &mut sb).unwrap();
            assert_eq!(ya.data(), yb.data(), "{} logits must be bitwise equal", ea.name());
        }
        // the f32 engine serves the edge store exactly: bitwise the oracle
        let oracle = host::forward(&a.edge, &x).unwrap();
        let yf = a.engines[2].forward_with(&x, &mut sa).unwrap();
        assert_eq!(yf.data(), oracle.data());
        // canary outcomes are recorded for every engine and inside the gate
        assert_eq!(a.canary.len(), 3);
        for o in &a.canary {
            assert!(o.max_abs_diff <= cfg.canary.max_abs_diff, "{}: {o:?}", o.engine);
            assert!(o.agreement >= cfg.canary.min_agreement, "{}: {o:?}", o.engine);
        }
    }

    #[test]
    fn impossible_canary_gate_rejects_the_generation() {
        // an agreement floor above 1.0 can never be met — the gate must
        // reject at the Canary stage (deterministically, whatever the
        // numerics), and the error names the stage
        let cfg = SwapConfig {
            canary: CanaryConfig { min_agreement: 2.0, ..CanaryConfig::default() },
            ..SwapConfig::default()
        };
        let err = stage(&synth_store(62, ModelKind::Lenet), &cfg).unwrap_err();
        let se = err.downcast_ref::<SwapError>().expect("typed SwapError");
        assert_eq!(se.stage, SwapStage::Canary);
        assert!(format!("{se}").contains("canary divergence"), "{se}");
    }

    #[test]
    fn hopeless_link_fails_at_the_transfer_stage_with_partial_report() {
        use crate::channel::{BurstConfig, TransferError};
        let cfg = SwapConfig {
            link: LinkConfig {
                burst: Some(BurstConfig { p_enter: 1.0, p_exit: 0.0, ber_bad: 0.5 }),
                max_retries: 3,
                ..LinkConfig::default()
            },
            ..SwapConfig::default()
        };
        let err = stage(&synth_store(63, ModelKind::Lenet), &cfg).unwrap_err();
        let se = err.downcast_ref::<SwapError>().expect("typed SwapError");
        assert_eq!(se.stage, SwapStage::Transfer);
        let te = se
            .source
            .downcast_ref::<TransferError>()
            .expect("partial transfer report must survive the stage wrapper");
        assert_eq!(te.partial.frames_delivered, 0);
        assert_eq!(te.partial.retransmissions, 4, "max_retries 3 → 4 sends");
    }

    #[test]
    fn slot_handshake_posts_installs_and_rejects_double_post() {
        let slot = SwapSlot::new();
        assert!(!slot.has_pending());
        assert!(slot.take_pending().is_none());
        let engines = || -> Vec<Box<dyn Engine + Send + Sync>> {
            vec![Box::new(F32Engine::new(synth_store(64, ModelKind::Lenet)))]
        };
        slot.post(PendingSwap { generation: 2, engines: engines() }).unwrap();
        assert!(slot.has_pending());
        // one deploy at a time
        let err = slot.post(PendingSwap { generation: 3, engines: engines() }).unwrap_err();
        assert!(format!("{err:#}").contains("already staged"));
        // worker takes and acks; the waiting deployer unblocks
        let p = slot.take_pending().unwrap();
        assert_eq!(p.generation, 2);
        assert!(!slot.has_pending());
        slot.ack_installed(2);
        slot.wait_installed(2, Duration::from_secs(1)).unwrap();
        // slot is idle again: the next deploy can post
        slot.post(PendingSwap { generation: 3, engines: engines() }).unwrap();
        // a dead worker fails pending and future deploys
        slot.mark_dead("test shutdown");
        assert!(!slot.has_pending());
        assert!(slot.wait_installed(3, Duration::from_millis(10)).is_err());
        let err = slot.post(PendingSwap { generation: 4, engines: engines() }).unwrap_err();
        assert!(format!("{err:#}").contains("worker is gone"));
    }
}
