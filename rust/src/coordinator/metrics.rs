//! Serving metrics: counters, gauges, and a bounded latency recorder with
//! percentile snapshots.
//!
//! The JSON snapshot schema — `counter.*`, `gauge.pool.*`,
//! `gauge.scratch_hw.<layer>.*`, the unified per-engine
//! `gauge.engine.<name>.*` family, `latency_ms.<series>.*` and the
//! latency-ring semantics — is documented for dashboard consumers
//! in `docs/METRICS.md`; keep the two in sync.  The same registry also
//! renders as Prometheus text ([`Metrics::prometheus`]) for the server's
//! `/metrics` endpoint.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::{self, Value};
use crate::util::stats;

/// Cap on stored samples per latency series.  Under sustained traffic an
/// unbounded `Vec` grows forever; instead each series keeps a ring of the
/// most recent [`LATENCY_WINDOW`] samples (percentiles reflect the recent
/// window — exactly what serving dashboards want) while `total` keeps the
/// lifetime observation count.
pub const LATENCY_WINDOW: usize = 4096;

/// Smoothing factor for [`Metrics::observe_ewma`] (1/8: a step change
/// settles within a few tens of observations without chasing one outlier).
pub const EWMA_ALPHA: f64 = 0.125;

/// One latency series: a bounded ring of recent samples plus the lifetime
/// count.
#[derive(Default)]
struct Series {
    /// The most recent samples, at most [`LATENCY_WINDOW`] of them.
    samples: Vec<f64>,
    /// Ring cursor: the oldest sample, overwritten next once full.
    next: usize,
    /// Samples ever observed (reported as the series count).
    total: u64,
}

impl Series {
    fn push(&mut self, v: f64) {
        self.total += 1;
        if self.samples.len() < LATENCY_WINDOW {
            self.samples.push(v);
        } else {
            self.samples[self.next] = v;
            self.next = (self.next + 1) % LATENCY_WINDOW;
        }
    }
}

/// Process-wide metrics registry (cheap enough for the serving rates here;
/// the §Perf pass measures its overhead explicitly).
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, f64>>,
    latencies: Mutex<BTreeMap<String, Series>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += by;
    }

    /// Set an absolute (last-write-wins) value — used for externally-owned
    /// counters like the kernel pool's spawn/wakeup totals and the scratch
    /// arena's per-layer high-water marks.
    pub fn set_gauge(&self, name: &str, value: f64) {
        self.gauges.lock().unwrap().insert(name.to_string(), value);
    }

    /// Fold `value` into an exponentially-weighted moving average stored as
    /// the gauge `name` (the first observation seeds the average).  The
    /// serving worker smooths per-batch inference time into
    /// `infer_batch.ewma_ms` this way; the admission-control path reads that
    /// gauge to derive the `retry_after_ms` hint on overload sheds.
    pub fn observe_ewma(&self, name: &str, value: f64) {
        let mut g = self.gauges.lock().unwrap();
        match g.get_mut(name) {
            Some(prev) => *prev += EWMA_ALPHA * (value - *prev),
            None => {
                g.insert(name.to_string(), value);
            }
        }
    }

    pub fn observe_s(&self, name: &str, seconds: f64) {
        self.latencies
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .push(seconds);
    }

    pub fn counter(&self, name: &str) -> u64 {
        *self.counters.lock().unwrap().get(name).unwrap_or(&0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.lock().unwrap().get(name).copied()
    }

    /// (mean, p50, p95, p99, max) over the retained window of a latency
    /// series (the most recent [`LATENCY_WINDOW`] samples), seconds.
    pub fn latency_summary(&self, name: &str) -> Option<(f64, f64, f64, f64, f64)> {
        let g = self.latencies.lock().unwrap();
        let xs = &g.get(name)?.samples;
        if xs.is_empty() {
            return None;
        }
        Some((
            stats::mean(xs),
            stats::percentile(xs, 50.0),
            stats::percentile(xs, 95.0),
            stats::percentile(xs, 99.0),
            xs.iter().cloned().fold(f64::MIN, f64::max),
        ))
    }

    /// JSON snapshot (counters + gauges + latency summaries in ms; the
    /// latency `count` is the lifetime total, the percentiles cover the
    /// retained window).
    pub fn snapshot(&self) -> Value {
        let counters = self.counters.lock().unwrap();
        let gauges = self.gauges.lock().unwrap();
        let lats = self.latencies.lock().unwrap();
        let mut obj = BTreeMap::new();
        for (k, v) in counters.iter() {
            obj.insert(format!("counter.{k}"), json::num(*v as f64));
        }
        for (k, v) in gauges.iter() {
            obj.insert(format!("gauge.{k}"), json::num(*v));
        }
        for (k, s) in lats.iter() {
            let xs = &s.samples;
            if xs.is_empty() {
                continue;
            }
            obj.insert(format!("latency_ms.{k}.mean"), json::num(stats::mean(xs) * 1e3));
            obj.insert(
                format!("latency_ms.{k}.p50"),
                json::num(stats::percentile(xs, 50.0) * 1e3),
            );
            obj.insert(
                format!("latency_ms.{k}.p95"),
                json::num(stats::percentile(xs, 95.0) * 1e3),
            );
            obj.insert(
                format!("latency_ms.{k}.p99"),
                json::num(stats::percentile(xs, 99.0) * 1e3),
            );
            obj.insert(format!("latency_ms.{k}.count"), json::num(s.total as f64));
        }
        Value::Obj(obj)
    }

    /// Prometheus text exposition (version 0.0.4) over the same registry the
    /// JSON snapshot reads.  Dotted keys become `qsq_`-prefixed metric names
    /// with every non-`[a-zA-Z0-9_]` byte mapped to `_`: counters export as
    /// `qsq_<name>_total` (`TYPE counter`), gauges as `qsq_<name>`
    /// (`TYPE gauge`), and each latency series as a `TYPE summary` —
    /// `qsq_<name>_seconds{quantile="…"}` over the retained window plus
    /// `qsq_<name>_seconds_count` carrying the lifetime total.  (No `_sum`
    /// line: the ring forgets old samples, so a lifetime sum would drift
    /// from the window and `rate()` over it would lie.  `BTreeMap` iteration
    /// keeps the output stably ordered for diffing.)
    pub fn prometheus(&self) -> String {
        fn sanitize(name: &str) -> String {
            name.chars()
                .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
                .collect()
        }
        let mut out = String::new();
        {
            let counters = self.counters.lock().unwrap();
            for (k, v) in counters.iter() {
                let n = sanitize(k);
                out.push_str(&format!("# TYPE qsq_{n}_total counter\n"));
                out.push_str(&format!("qsq_{n}_total {v}\n"));
            }
        }
        {
            let gauges = self.gauges.lock().unwrap();
            for (k, v) in gauges.iter() {
                let n = sanitize(k);
                out.push_str(&format!("# TYPE qsq_{n} gauge\n"));
                out.push_str(&format!("qsq_{n} {v}\n"));
            }
        }
        {
            let lats = self.latencies.lock().unwrap();
            for (k, s) in lats.iter() {
                if s.samples.is_empty() {
                    continue;
                }
                let n = sanitize(k);
                out.push_str(&format!("# TYPE qsq_{n}_seconds summary\n"));
                for (q, p) in [("0.5", 50.0), ("0.95", 95.0), ("0.99", 99.0), ("0.999", 99.9)] {
                    let v = stats::percentile(&s.samples, p);
                    out.push_str(&format!("qsq_{n}_seconds{{quantile=\"{q}\"}} {v}\n"));
                }
                out.push_str(&format!("qsq_{n}_seconds_count {}\n", s.total));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.inc("req", 1);
        m.inc("req", 2);
        assert_eq!(m.counter("req"), 3);
        assert_eq!(m.counter("other"), 0);
    }

    #[test]
    fn gauges_last_write_wins() {
        let m = Metrics::new();
        assert_eq!(m.gauge("pool.spawns"), None);
        m.set_gauge("pool.spawns", 3.0);
        m.set_gauge("pool.spawns", 3.0);
        m.set_gauge("pool.wakeups", 120.0);
        assert_eq!(m.gauge("pool.spawns"), Some(3.0));
        let snap = m.snapshot().to_json();
        assert!(snap.contains("gauge.pool.spawns"));
        assert!(snap.contains("gauge.pool.wakeups"));
    }

    #[test]
    fn ewma_seeds_then_tracks() {
        let m = Metrics::new();
        m.observe_ewma("e", 10.0);
        assert_eq!(m.gauge("e"), Some(10.0), "first observation seeds the average");
        m.observe_ewma("e", 20.0);
        assert!((m.gauge("e").unwrap() - 11.25).abs() < 1e-12, "alpha = 1/8");
        for _ in 0..200 {
            m.observe_ewma("e", 20.0);
        }
        assert!((m.gauge("e").unwrap() - 20.0).abs() < 1e-6, "converges to the new level");
    }

    #[test]
    fn latency_percentiles() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe_s("infer", i as f64 / 1000.0);
        }
        let (mean, p50, p95, _p99, max) = m.latency_summary("infer").unwrap();
        assert!((mean - 0.0505).abs() < 1e-9);
        assert!((p50 - 0.0505).abs() < 0.001);
        assert!(p95 > 0.09 && p95 <= 0.1);
        assert_eq!(max, 0.1);
    }

    #[test]
    fn latency_series_is_bounded_under_sustained_traffic() {
        // regression: observe_s used to grow each series without bound
        let m = Metrics::new();
        for _ in 0..6000 {
            m.observe_s("e2e", 1.0);
        }
        for _ in 0..LATENCY_WINDOW {
            m.observe_s("e2e", 3.0);
        }
        {
            let g = m.latencies.lock().unwrap();
            let s = g.get("e2e").unwrap();
            assert_eq!(s.samples.len(), LATENCY_WINDOW, "ring must cap retained samples");
            assert_eq!(s.total, 6000 + LATENCY_WINDOW as u64);
        }
        // the retained window holds only the most recent samples
        let (mean, p50, _p95, _p99, max) = m.latency_summary("e2e").unwrap();
        assert_eq!(p50, 3.0);
        assert_eq!(mean, 3.0);
        assert_eq!(max, 3.0);
        // the snapshot count reports the lifetime total, not the window
        let snap = m.snapshot().to_json();
        assert!(
            snap.contains(&format!("\"latency_ms.e2e.count\":{}", 6000 + LATENCY_WINDOW)),
            "snapshot: {snap}"
        );
    }

    #[test]
    fn ring_overwrites_oldest_first() {
        let mut s = Series::default();
        for i in 0..LATENCY_WINDOW + 10 {
            s.push(i as f64);
        }
        assert_eq!(s.samples.len(), LATENCY_WINDOW);
        // the first 10 slots now hold the wrapped-around newest samples
        assert_eq!(s.samples[0], LATENCY_WINDOW as f64);
        assert_eq!(s.samples[9], (LATENCY_WINDOW + 9) as f64);
        // slot 10 still holds the oldest retained sample
        assert_eq!(s.samples[10], 10.0);
        assert_eq!(s.total, (LATENCY_WINDOW + 10) as u64);
    }

    #[test]
    fn prometheus_renders_all_three_families() {
        let m = Metrics::new();
        m.inc("requests", 7);
        m.set_gauge("engine.host-csd.forwards", 3.0);
        for i in 1..=100 {
            m.observe_s("infer_batch", i as f64 / 1000.0);
        }
        let text = m.prometheus();
        // counters: sanitized name, _total suffix, TYPE line
        assert!(text.contains("# TYPE qsq_requests_total counter\n"));
        assert!(text.contains("qsq_requests_total 7\n"));
        // gauges: dots and dashes both map to underscores
        assert!(text.contains("# TYPE qsq_engine_host_csd_forwards gauge\n"));
        assert!(text.contains("qsq_engine_host_csd_forwards 3\n"));
        // latency series: summary with the four quantiles + lifetime count
        assert!(text.contains("# TYPE qsq_infer_batch_seconds summary\n"));
        assert!(text.contains("qsq_infer_batch_seconds{quantile=\"0.5\"}"));
        assert!(text.contains("qsq_infer_batch_seconds{quantile=\"0.999\"}"));
        assert!(text.contains("qsq_infer_batch_seconds_count 100\n"));
        // exposition hygiene: every line is either a comment or `name value`
        for line in text.lines() {
            assert!(
                line.starts_with("# TYPE qsq_") || line.starts_with("qsq_"),
                "unexpected exposition line: {line}"
            );
        }
        assert!(text.ends_with('\n'));
    }

    #[test]
    fn prometheus_skips_empty_series_and_snapshot_carries_p99() {
        let m = Metrics::new();
        // a series that exists but has no samples yet must not emit a
        // quantile-less summary block
        m.latencies.lock().unwrap().entry("empty".into()).or_default();
        assert!(!m.prometheus().contains("qsq_empty"));
        for i in 1..=100 {
            m.observe_s("e2e", i as f64 / 1000.0);
        }
        let snap = m.snapshot().to_json();
        assert!(snap.contains("latency_ms.e2e.p99"), "snapshot: {snap}");
    }

    #[test]
    fn snapshot_is_json() {
        let m = Metrics::new();
        m.inc("served", 5);
        m.set_gauge("scratch_hw.c1w.act_bytes", 1024.0);
        m.observe_s("e2e", 0.002);
        let snap = m.snapshot().to_json();
        assert!(snap.contains("counter.served"));
        assert!(snap.contains("gauge.scratch_hw.c1w.act_bytes"));
        assert!(snap.contains("latency_ms.e2e.mean"));
        // parses back
        assert!(crate::util::json::parse(&snap).is_ok());
    }
}
