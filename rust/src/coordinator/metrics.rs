//! Serving metrics: counters + latency recorder with percentile snapshots.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::{self, Value};
use crate::util::stats;

/// Process-wide metrics registry (cheap enough for the serving rates here;
/// the §Perf pass measures its overhead explicitly).
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    latencies: Mutex<BTreeMap<String, Vec<f64>>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += by;
    }

    pub fn observe_s(&self, name: &str, seconds: f64) {
        self.latencies
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .push(seconds);
    }

    pub fn counter(&self, name: &str) -> u64 {
        *self.counters.lock().unwrap().get(name).unwrap_or(&0)
    }

    /// (mean, p50, p95, p99, max) over a latency series, seconds.
    pub fn latency_summary(&self, name: &str) -> Option<(f64, f64, f64, f64, f64)> {
        let g = self.latencies.lock().unwrap();
        let xs = g.get(name)?;
        if xs.is_empty() {
            return None;
        }
        Some((
            stats::mean(xs),
            stats::percentile(xs, 50.0),
            stats::percentile(xs, 95.0),
            stats::percentile(xs, 99.0),
            xs.iter().cloned().fold(f64::MIN, f64::max),
        ))
    }

    /// JSON snapshot (counters + latency summaries in ms).
    pub fn snapshot(&self) -> Value {
        let counters = self.counters.lock().unwrap();
        let lats = self.latencies.lock().unwrap();
        let mut obj = BTreeMap::new();
        for (k, v) in counters.iter() {
            obj.insert(format!("counter.{k}"), json::num(*v as f64));
        }
        for (k, xs) in lats.iter() {
            if xs.is_empty() {
                continue;
            }
            obj.insert(format!("latency_ms.{k}.mean"), json::num(stats::mean(xs) * 1e3));
            obj.insert(
                format!("latency_ms.{k}.p50"),
                json::num(stats::percentile(xs, 50.0) * 1e3),
            );
            obj.insert(
                format!("latency_ms.{k}.p95"),
                json::num(stats::percentile(xs, 95.0) * 1e3),
            );
            obj.insert(format!("latency_ms.{k}.count"), json::num(xs.len() as f64));
        }
        Value::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.inc("req", 1);
        m.inc("req", 2);
        assert_eq!(m.counter("req"), 3);
        assert_eq!(m.counter("other"), 0);
    }

    #[test]
    fn latency_percentiles() {
        let m = Metrics::new();
        for i in 1..=100 {
            m.observe_s("infer", i as f64 / 1000.0);
        }
        let (mean, p50, p95, _p99, max) = m.latency_summary("infer").unwrap();
        assert!((mean - 0.0505).abs() < 1e-9);
        assert!((p50 - 0.0505).abs() < 0.001);
        assert!(p95 > 0.09 && p95 <= 0.1);
        assert_eq!(max, 0.1);
    }

    #[test]
    fn snapshot_is_json() {
        let m = Metrics::new();
        m.inc("served", 5);
        m.observe_s("e2e", 0.002);
        let snap = m.snapshot().to_json();
        assert!(snap.contains("counter.served"));
        assert!(snap.contains("latency_ms.e2e.mean"));
        // parses back
        assert!(crate::util::json::parse(&snap).is_ok());
    }
}
