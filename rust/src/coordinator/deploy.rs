//! The end-to-end deployment pipeline (the paper's edge-computing story):
//!
//! ```text
//! trained weights ──quantize──▶ QSQ container ──channel──▶ edge decode
//!        │                                                    │
//!        └──────────── full-precision head ───────────────────┘
//!                                              ▼
//!                        WeightStore with approximate weights
//! ```
//!
//! Produces a [`DeployReport`] with every number the paper's §IV.C cares
//! about: encoded size, memory savings, transfer cost, decoder-op counts,
//! zero fractions.

use anyhow::{Context, Result};

use crate::channel::{Link, LinkConfig, TransferReport};
use crate::codec::{decode_model, encode_model, EncodedModel};
use crate::device::{CsdQuality, DeviceProfile, QualityConfig};
use crate::hw::decoder_rtl;
use crate::model::store::WeightStore;
use crate::quant::qsq::AssignMode;
use crate::runtime::host::{CsdEngine, QuantizedEngine};
use crate::tensor::Tensor;

/// Everything the deployment produced, for reporting.
#[derive(Clone, Debug)]
pub struct DeployReport {
    pub quality: QualityConfig,
    /// The stacked CSD digit dial, when the deployment built a CSD engine
    /// ([`deploy_csd_engine`] / [`deploy_for_device`]); `None` for
    /// QSQ-only deployments.
    pub csd: Option<CsdQuality>,
    pub mode: AssignMode,
    /// Encoded bits of the quantized tensors (eq. 12).
    pub encoded_bits: u64,
    /// Full-precision bits of the same tensors (eq. 11).
    pub full_bits: u64,
    /// Container bytes actually shipped.
    pub container_bytes: usize,
    pub transfer: TransferReport,
    /// Total decoder operations at the edge.
    pub decoder_ops: decoder_rtl::DecodeOps,
    /// Zero-code fraction (zero-skip opportunity).
    pub zeros_fraction: f64,
    /// Mean relative reconstruction error across quantized tensors.
    pub mean_rel_error: f64,
}

impl DeployReport {
    pub fn memory_savings(&self) -> f64 {
        1.0 - self.encoded_bits as f64 / self.full_bits as f64
    }
}

/// Quantize the store's quantized tensors at (phi, N) and build a container.
/// (Delegates to [`crate::runtime::host::quantize_tensors`] — the same
/// policy the serving engine quantizes with, so shipped codes and
/// host-quantized serving can never drift.)
pub fn encode_store(
    store: &WeightStore,
    quality: QualityConfig,
    mode: AssignMode,
) -> Result<EncodedModel> {
    Ok(EncodedModel { tensors: crate::runtime::host::quantize_tensors(store, quality, mode)? })
}

/// Run the whole pipeline; returns the edge-side store (decoded approximate
/// weights + original fp32 head/biases) and the report.
pub fn deploy(
    store: &WeightStore,
    quality: QualityConfig,
    mode: AssignMode,
    link_cfg: LinkConfig,
    seed: u64,
) -> Result<(WeightStore, DeployReport)> {
    let (edge, report, _) = deploy_full(store, quality, mode, link_cfg, seed)?;
    Ok((edge, report))
}

/// [`deploy`] plus a code-domain serving engine built from exactly the codes
/// that crossed the channel: quantized layers run on
/// [`mod@crate::kernels::qgemm`] without ever materializing f32 weights.
pub fn deploy_engine(
    store: &WeightStore,
    quality: QualityConfig,
    mode: AssignMode,
    link_cfg: LinkConfig,
    seed: u64,
) -> Result<(QuantizedEngine, DeployReport)> {
    let (edge, report, decoded) = deploy_full(store, quality, mode, link_cfg, seed)?;
    let engine = QuantizedEngine::from_encoded(&edge, &decoded)?;
    Ok((engine, report))
}

/// [`deploy`] plus a CSD shift-and-add serving engine
/// ([`crate::runtime::host::CsdEngine`]) built on the edge-side store: the
/// QSQ dial (phi, N) decides which codes cross the channel, then the `csd`
/// digit dial truncates the decoded weights' CSD form on top — the two
/// quality knobs compose, and the engine's energy ledger prices exactly the
/// composition the device serves.
pub fn deploy_csd_engine(
    store: &WeightStore,
    quality: QualityConfig,
    csd: CsdQuality,
    mode: AssignMode,
    link_cfg: LinkConfig,
    seed: u64,
) -> Result<(CsdEngine, DeployReport)> {
    let (edge, mut report, _) = deploy_full(store, quality, mode, link_cfg, seed)?;
    let engine = CsdEngine::from_store(&edge, csd)?;
    report.csd = Some(csd);
    Ok((engine, report))
}

/// The device-profile-driven form of the whole pipeline: the profile's
/// memory budget sizes the QSQ dial, its MACs-derived energy budget sizes
/// the CSD digit dial ([`DeviceProfile::select_quality`]), and the model
/// ships over the profile's own link — a device profile alone determines
/// the full stacked-dial configuration the returned engine serves at (the
/// report records both dials).
pub fn deploy_for_device(
    store: &WeightStore,
    device: &DeviceProfile,
    mode: AssignMode,
    seed: u64,
) -> Result<(CsdEngine, DeployReport)> {
    let (_, engine, report) = deploy_for_device_with_link(store, device, mode, device.link, seed)?;
    Ok((engine, report))
}

/// [`deploy_for_device`] with an explicit link override (e.g. a `--ber`
/// noise injection on the profile's link); additionally returns the
/// post-channel edge store so callers can score or re-pack it without
/// replaying the deployment.
pub fn deploy_for_device_with_link(
    store: &WeightStore,
    device: &DeviceProfile,
    mode: AssignMode,
    link_cfg: LinkConfig,
    seed: u64,
) -> Result<(WeightStore, CsdEngine, DeployReport)> {
    let meta = &store.meta;
    let (quality, csd, _act_bits) = device
        .select_quality(
            |phi, group| crate::model::bits::model_bits(meta, phi, group).encoded_bits,
            meta.macs_per_image(),
        )
        .with_context(|| {
            format!("device {} cannot fit {} at any quality", device.name, store.kind.name())
        })?;
    let (edge, mut report, _) = deploy_full(store, quality, mode, link_cfg, seed)?;
    let engine = CsdEngine::from_store(&edge, csd)?;
    report.csd = Some(csd);
    Ok((edge, engine, report))
}

/// Pipeline internals shared by [`deploy`] and [`deploy_engine`]: also
/// returns the post-channel [`EncodedModel`] (the shipped codes).
pub fn deploy_full(
    store: &WeightStore,
    quality: QualityConfig,
    mode: AssignMode,
    link_cfg: LinkConfig,
    seed: u64,
) -> Result<(WeightStore, DeployReport, EncodedModel)> {
    let encoded = encode_store(store, quality, mode)?;
    let container = encode_model(&encoded)?;

    let mut link = Link::new(link_cfg, seed);
    let (received, transfer) = link.transmit(&container)?;
    let decoded = decode_model(&received)?;

    // edge side: reconstruct weights through the bit-level decoder simulator
    let mut edge = store.clone();
    let mut total_ops = decoder_rtl::DecodeOps::default();
    let mut rel_err_sum = 0.0f64;
    let mut nz = 0usize;
    let mut zeros = 0u64;
    let mut total_codes = 0u64;
    for et in &decoded.tensors {
        let (ws, ops) = decoder_rtl::decode_stream(
            &et.tensor.codes,
            &et.tensor.scalars,
            et.tensor.group,
            et.tensor.oc,
        );
        total_ops.exponent_adds += ops.exponent_adds;
        total_ops.sign_flips += ops.sign_flips;
        total_ops.zero_outputs += ops.zero_outputs;
        zeros += et.tensor.codes.iter().filter(|c| c.is_skippable()).count() as u64;
        total_codes += et.tensor.codes.len() as u64;

        let orig = store.get(&et.name)?;
        let diff: f64 = orig
            .data()
            .iter()
            .zip(&ws)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        let norm: f64 = orig.data().iter().map(|&a| (a as f64).powi(2)).sum();
        if norm > 0.0 {
            rel_err_sum += (diff / norm).sqrt();
            nz += 1;
        }
        edge.set(&et.name, Tensor::new(et.tensor.shape.clone(), ws)?)?;
    }

    let report = DeployReport {
        quality,
        csd: None,
        mode,
        encoded_bits: encoded.encoded_bits(),
        full_bits: encoded.full_precision_bits(),
        container_bytes: container.len(),
        transfer,
        decoder_ops: total_ops,
        zeros_fraction: zeros as f64 / total_codes.max(1) as f64,
        mean_rel_error: if nz > 0 { rel_err_sum / nz as f64 } else { 0.0 },
    };
    Ok((edge, report, decoded))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::meta::ModelKind;
    use crate::util::rng::Rng;

    fn fake_store(seed: u64) -> WeightStore {
        let mut r = Rng::new(seed);
        let meta = crate::model::meta::ModelMeta::lenet();
        let mut s = WeightStore::empty(ModelKind::Lenet);
        for t in &meta.tensors {
            let data: Vec<f32> = (0..t.numel()).map(|_| (r.normal() * 0.1) as f32).collect();
            s.set_unchecked(t.name, Tensor::new(t.shape.clone(), data).unwrap());
        }
        s
    }

    #[test]
    fn pipeline_roundtrip_clean_link() {
        let store = fake_store(1);
        let q = QualityConfig { phi: 4, group: 16 };
        let (edge, rep) =
            deploy(&store, q, AssignMode::Nearest, LinkConfig::default(), 7).unwrap();
        assert!(rep.memory_savings() > 0.75, "savings {}", rep.memory_savings());
        assert!(rep.mean_rel_error < 0.8);
        assert!(rep.zeros_fraction > 0.0);
        assert_eq!(rep.transfer.retransmissions, 0);
        // unquantized tensors untouched
        assert_eq!(edge.get("f3w").unwrap().data(), store.get("f3w").unwrap().data());
        // quantized tensors actually changed
        assert_ne!(edge.get("c2w").unwrap().data(), store.get("c2w").unwrap().data());
    }

    #[test]
    fn pipeline_survives_noisy_link() {
        let store = fake_store(2);
        let q = QualityConfig { phi: 4, group: 8 };
        let noisy = LinkConfig { ber: 1e-5, ..Default::default() };
        let (edge_clean, _) =
            deploy(&store, q, AssignMode::Nearest, LinkConfig::default(), 3).unwrap();
        let (edge_noisy, rep) = deploy(&store, q, AssignMode::Nearest, noisy, 3).unwrap();
        // ARQ must deliver bit-identical weights despite corruption
        for t in ["c1w", "c2w", "f1w", "f2w"] {
            assert_eq!(
                edge_clean.get(t).unwrap().data(),
                edge_noisy.get(t).unwrap().data(),
                "{t} differs after noisy transit"
            );
        }
        assert!(rep.transfer.retransmissions > 0);
    }

    #[test]
    fn phi1_ships_fewer_bits_than_phi4() {
        let store = fake_store(3);
        let r1 = deploy(
            &store,
            QualityConfig { phi: 1, group: 16 },
            AssignMode::Nearest,
            LinkConfig::default(),
            1,
        )
        .unwrap()
        .1;
        let r4 = deploy(
            &store,
            QualityConfig { phi: 4, group: 16 },
            AssignMode::Nearest,
            LinkConfig::default(),
            1,
        )
        .unwrap()
        .1;
        assert!(r1.container_bytes < r4.container_bytes);
        assert!(r1.mean_rel_error >= r4.mean_rel_error - 1e-9);
    }

    #[test]
    fn deploy_engine_matches_edge_store_forward() {
        let store = fake_store(6);
        let q = QualityConfig { phi: 4, group: 16 };
        let (edge, _) =
            deploy(&store, q, AssignMode::SigmaSearch, LinkConfig::default(), 11).unwrap();
        let (engine, rep) =
            deploy_engine(&store, q, AssignMode::SigmaSearch, LinkConfig::default(), 11).unwrap();
        assert!(rep.zeros_fraction > 0.0);
        // the engine skips exactly the zero codes the report counted
        assert!((engine.skipped_fraction() - rep.zeros_fraction).abs() < 1e-12);

        let mut r = Rng::new(42);
        let xdata: Vec<f32> = (0..2 * 28 * 28).map(|_| r.f64() as f32).collect();
        let x = Tensor::new(vec![2, 28, 28, 1], xdata).unwrap();
        let got = engine.forward(&x).unwrap();
        let want = crate::runtime::host::forward(&edge, &x).unwrap();
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-2, "engine vs decoded edge store: {diff}");
    }

    #[test]
    fn deploy_csd_engine_composes_both_dials() {
        let store = fake_store(8);
        let q = QualityConfig { phi: 4, group: 16 };
        let (edge, _) =
            deploy(&store, q, AssignMode::SigmaSearch, LinkConfig::default(), 13).unwrap();
        let (engine, rep) = super::deploy_csd_engine(
            &store,
            q,
            CsdQuality::exact(),
            AssignMode::SigmaSearch,
            LinkConfig::default(),
            13,
        )
        .unwrap();
        assert!(rep.memory_savings() > 0.5);

        // exact CSD on top of the QSQ-decoded edge store: the engine output
        // tracks the edge-store f32 forward (same weights, fixed-point
        // recoded, different reduction order)
        let mut r = Rng::new(43);
        let xdata: Vec<f32> = (0..2 * 28 * 28).map(|_| r.f64() as f32).collect();
        let x = Tensor::new(vec![2, 28, 28, 1], xdata).unwrap();
        let got = engine.forward(&x).unwrap();
        let want = crate::runtime::host::forward(&edge, &x).unwrap();
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-2, "csd engine vs decoded edge store: {diff}");
        // the dial's energy ledger was charged for the forward
        let led = engine.ledger();
        assert!(led.partial_products > 0);
        assert_eq!(engine.forwards(), 1);

        // a 1-digit budget spends strictly fewer partial products per MAC
        let (cheap, _) = super::deploy_csd_engine(
            &store,
            q,
            CsdQuality::new(1),
            AssignMode::SigmaSearch,
            LinkConfig::default(),
            13,
        )
        .unwrap();
        assert!(cheap.mean_pp() <= 1.0 + 1e-12);
        assert!(cheap.mean_pp() < engine.mean_pp());
    }

    #[test]
    fn deploy_for_device_derives_both_dials_from_the_profile() {
        use crate::device::DeviceProfile;
        let store = fake_store(9);
        let roster = DeviceProfile::roster();
        let mcu = roster.iter().find(|d| d.name == "mcu-m4").unwrap();
        let server = roster.iter().find(|d| d.name == "server").unwrap();
        let (mcu_engine, mcu_rep) =
            deploy_for_device(&store, mcu, AssignMode::SigmaSearch, 5).unwrap();
        let (srv_engine, srv_rep) =
            deploy_for_device(&store, server, AssignMode::SigmaSearch, 5).unwrap();
        // both dials recorded in the report, and the engine serves at the
        // report's digit dial
        let mcu_csd = mcu_rep.csd.unwrap();
        let srv_csd = srv_rep.csd.unwrap();
        assert_eq!(mcu_engine.quality(), mcu_csd);
        assert_eq!(srv_engine.quality(), srv_csd);
        // the MCU-class profile selects a smaller digit budget than the
        // server-class profile, and the realized energy follows the dial
        assert!(
            mcu_csd.max_digits < srv_csd.max_digits,
            "mcu {} vs server {}",
            mcu_csd.max_digits,
            srv_csd.max_digits
        );
        assert!(mcu_engine.mean_pp() <= mcu_csd.max_digits as f64 + 1e-12);
        assert!(mcu_engine.mean_pp() < srv_engine.mean_pp());
        // the QSQ dial still tracks the memory budget (server >= mcu quality)
        assert!(srv_rep.quality.phi >= mcu_rep.quality.phi);
    }

    #[test]
    fn decoder_op_counts_match_code_population() {
        let store = fake_store(4);
        let (_, rep) = deploy(
            &store,
            QualityConfig { phi: 4, group: 16 },
            AssignMode::Nearest,
            LinkConfig::default(),
            5,
        )
        .unwrap();
        let total = rep.decoder_ops.exponent_adds
            + rep.decoder_ops.sign_flips
            + rep.decoder_ops.zero_outputs;
        assert!(total > 0);
        // every zero code produced exactly one zero_output
        assert!(rep.decoder_ops.zero_outputs as f64 > 0.0);
    }
}
