//! Dynamic batching queue for the serving loop.
//!
//! Requests arrive from acceptor threads; the single inference worker pops a
//! batch when either (a) `max_batch` requests are waiting or (b) the oldest
//! request has waited `max_delay` — the classic dynamic-batching policy the
//! batch-32 PJRT artifact wants (the batch is padded to the artifact size by
//! the worker).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One queued inference request.
pub struct Pending<T> {
    pub payload: T,
    pub enqueued: Instant,
}

/// Thread-safe batch queue. `close()` wakes all waiters and drains.
pub struct BatchQueue<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
    pub max_batch: usize,
    pub max_delay: Duration,
}

struct Inner<T> {
    queue: VecDeque<Pending<T>>,
    closed: bool,
}

impl<T> BatchQueue<T> {
    pub fn new(max_batch: usize, max_delay: Duration) -> Self {
        assert!(max_batch > 0);
        BatchQueue {
            inner: Mutex::new(Inner { queue: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            max_batch,
            max_delay,
        }
    }

    /// Enqueue a request. Returns false if the queue is closed.
    pub fn push(&self, payload: T) -> bool {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return false;
        }
        g.queue.push_back(Pending { payload, enqueued: Instant::now() });
        // single-consumer queue: the inference worker is the only condvar
        // waiter (push never blocks), so one wakeup per push suffices —
        // notify_all would make every producer syscall-storm the same
        // thread.  close() keeps notify_all as the belt-and-braces wakeup
        // for that same worker.
        self.cv.notify_one();
        true
    }

    /// Pop the next batch, blocking until the batching policy fires or the
    /// queue closes.  Returns `None` only when closed *and* drained.
    pub fn pop_batch(&self) -> Option<Vec<Pending<T>>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if !g.queue.is_empty() {
                let oldest = g.queue.front().unwrap().enqueued;
                let waited = oldest.elapsed();
                if g.queue.len() >= self.max_batch || waited >= self.max_delay || g.closed {
                    let n = g.queue.len().min(self.max_batch);
                    return Some(g.queue.drain(..n).collect());
                }
                let remaining = self.max_delay - waited;
                let (ng, _timeout) = self.cv.wait_timeout(g, remaining).unwrap();
                g = ng;
            } else if g.closed {
                return None;
            } else {
                g = self.cv.wait(g).unwrap();
            }
        }
    }

    /// Close the queue; wakes all waiters.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn full_batch_pops_immediately() {
        let q = BatchQueue::new(4, Duration::from_secs(10));
        for i in 0..4 {
            assert!(q.push(i));
        }
        let batch = q.pop_batch().unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch[0].payload, 0);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let q = Arc::new(BatchQueue::new(64, Duration::from_millis(30)));
        q.push(42);
        let t0 = Instant::now();
        let batch = q.pop_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn oversize_queue_pops_max_batch() {
        let q = BatchQueue::new(3, Duration::from_secs(10));
        for i in 0..7 {
            q.push(i);
        }
        assert_eq!(q.pop_batch().unwrap().len(), 3);
        assert_eq!(q.pop_batch().unwrap().len(), 3);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn close_drains_then_none() {
        let q = BatchQueue::new(8, Duration::from_secs(10));
        q.push(1);
        q.close();
        assert!(!q.push(2));
        assert_eq!(q.pop_batch().unwrap().len(), 1);
        assert!(q.pop_batch().is_none());
    }

    #[test]
    fn timeout_flush_fires_under_concurrent_pushers() {
        // regression for the notify_one switch: with max_batch far above the
        // offered load, every pop must come from the timeout path, and
        // concurrent pushers re-notifying the single consumer must never
        // stall it past the flush deadline
        let q = Arc::new(BatchQueue::new(1024, Duration::from_millis(20)));
        let total = 15;
        let producers: Vec<_> = (0..3)
            .map(|p| {
                let q = q.clone();
                thread::spawn(move || {
                    for i in 0..5 {
                        assert!(q.push(p * 100 + i));
                        thread::sleep(Duration::from_millis(7));
                    }
                })
            })
            .collect();
        let mut got = 0;
        while got < total {
            let t0 = Instant::now();
            let batch = q.pop_batch().expect("queue is never closed here");
            assert!(!batch.is_empty());
            // each flush must come from the max_delay timer, not a full
            // batch — generous bound for slow CI
            assert!(
                t0.elapsed() < Duration::from_millis(1500),
                "timeout flush stalled: {:?}",
                t0.elapsed()
            );
            got += batch.len();
        }
        assert_eq!(got, total);
        for p in producers {
            p.join().unwrap();
        }
    }

    #[test]
    fn concurrent_producers_consumer() {
        let q = Arc::new(BatchQueue::new(16, Duration::from_millis(5)));
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                thread::spawn(move || {
                    for i in 0..50 {
                        q.push(p * 100 + i);
                    }
                })
            })
            .collect();
        let qc = q.clone();
        let consumer = thread::spawn(move || {
            let mut got = 0;
            while got < 200 {
                if let Some(b) = qc.pop_batch() {
                    got += b.len();
                } else {
                    break;
                }
            }
            got
        });
        for p in producers {
            p.join().unwrap();
        }
        assert_eq!(consumer.join().unwrap(), 200);
    }
}
