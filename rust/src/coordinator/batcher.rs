//! Dynamic batching queue for the serving loop, with bounded admission and
//! per-request deadlines.
//!
//! Requests arrive from the mux front end; the replicated inference workers
//! each pop a batch when either (a) `max_batch` requests are waiting or
//! (b) the oldest request has waited `max_delay` — the classic
//! dynamic-batching policy the batch-32 PJRT artifact wants (the batch is
//! padded to the artifact size by the worker).  The queue is safe with any
//! number of producers and consumers: batches are drained under one mutex
//! hold, so a job lands in exactly one worker's batch.
//!
//! Two fault-tolerance mechanisms bound the queue's behavior under pressure:
//!
//! * **Admission control** — the queue holds at most `capacity` jobs;
//!   [`BatchQueue::push`] returns [`PushError::Full`] at the cap instead of
//!   growing without bound, and the server sheds the request with an
//!   `overloaded` + `retry_after_ms` reply.
//! * **Deadlines** — a job that already waited longer than `deadline` when
//!   the worker pops is *shed* (returned in [`Popped::expired`], oldest
//!   first) rather than served: its client has likely given up, and burning
//!   a kernel slot on it would delay every live request behind it.
//!
//! [`BatchQueue::close`] drains and returns every queued-but-unserved job so
//! the caller can send each a terminal reply — senders are never silently
//! dropped on shutdown.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One queued inference request.
pub struct Pending<T> {
    pub payload: T,
    pub enqueued: Instant,
}

/// Why a [`BatchQueue::push`] was rejected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushError {
    /// The queue is at capacity — shed with a `retry_after` hint.
    Full,
    /// The queue is closed (server shutting down).
    Closed,
}

/// One pop: the batch to serve plus any jobs shed at their deadline.
pub struct Popped<T> {
    /// The dynamic batch to execute (may be empty when only sheds fired).
    pub jobs: Vec<Pending<T>>,
    /// Jobs whose queue wait exceeded the deadline, oldest first — reply
    /// `deadline exceeded` to these instead of serving them.
    pub expired: Vec<Pending<T>>,
}

/// Thread-safe bounded batch queue. `close()` wakes all waiters and returns
/// the drained backlog.
pub struct BatchQueue<T> {
    inner: Mutex<Inner<T>>,
    cv: Condvar,
    pub max_batch: usize,
    pub max_delay: Duration,
    /// Admission cap ([`PushError::Full`] at this depth); `usize::MAX` keeps
    /// the queue unbounded.
    pub capacity: usize,
    /// Queue-wait deadline after which a popped job is shed; `None` never
    /// sheds.
    pub deadline: Option<Duration>,
}

struct Inner<T> {
    queue: VecDeque<Pending<T>>,
    closed: bool,
    /// One-shot wakeup flag set by [`BatchQueue::kick`]: the next
    /// `pop_batch` returns (with an empty batch if nothing else is due) so
    /// the consumer re-checks out-of-band state such as the hot-swap slot.
    kicked: bool,
}

impl<T> BatchQueue<T> {
    /// An unbounded queue with no deadline (bench/unit-test convenience;
    /// the server always uses [`BatchQueue::bounded`]).
    pub fn new(max_batch: usize, max_delay: Duration) -> Self {
        Self::bounded(max_batch, max_delay, usize::MAX, None)
    }

    /// A queue with an admission cap and an optional queue-wait deadline.
    pub fn bounded(
        max_batch: usize,
        max_delay: Duration,
        capacity: usize,
        deadline: Option<Duration>,
    ) -> Self {
        assert!(max_batch > 0);
        assert!(capacity > 0);
        BatchQueue {
            inner: Mutex::new(Inner { queue: VecDeque::new(), closed: false, kicked: false }),
            cv: Condvar::new(),
            max_batch,
            max_delay,
            capacity,
            deadline,
        }
    }

    /// Enqueue a request; rejects when closed or at capacity.
    pub fn push(&self, payload: T) -> Result<(), PushError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(PushError::Closed);
        }
        if g.queue.len() >= self.capacity {
            return Err(PushError::Full);
        }
        g.queue.push_back(Pending { payload, enqueued: Instant::now() });
        // One wakeup per push is enough even with N worker threads parked on
        // the condvar: each push adds one job, and one woken worker either
        // serves it or goes back to a `wait_timeout` bounded by `max_delay`,
        // so no job can strand a sleeping worker for longer than the batching
        // window.  notify_all here would make every producer syscall-storm
        // the whole worker pool per request; close() and kick() keep
        // notify_all because those events concern every waiter.
        self.cv.notify_one();
        Ok(())
    }

    /// Pop the next batch, blocking until the batching policy fires or the
    /// queue closes.  Jobs past their deadline are shed into
    /// [`Popped::expired`] (oldest first) and never occupy a batch slot.
    /// Returns `None` only when closed (the backlog is drained by
    /// [`BatchQueue::close`], not here).
    pub fn pop_batch(&self) -> Option<Popped<T>> {
        // injectable consumer stall (chaos testing); a no-op when disarmed
        if let Some(stall) = crate::util::faults::queue_stall() {
            std::thread::sleep(stall);
        }
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.closed {
                return None;
            }
            // shed expired jobs from the front before forming a batch; the
            // front is the oldest, so shedding is oldest-first by
            // construction
            let mut expired = Vec::new();
            if let Some(dl) = self.deadline {
                while g.queue.front().map(|p| p.enqueued.elapsed() > dl).unwrap_or(false) {
                    expired.push(g.queue.pop_front().unwrap());
                }
            }
            if g.kicked {
                // a kick outranks batch formation: the consumer wants to run
                // its between-batches checks *now* (e.g. install a staged
                // hot-swap generation); any queued jobs simply wait for the
                // next pop, which follows immediately
                g.kicked = false;
                return Some(Popped { jobs: Vec::new(), expired });
            }
            if !g.queue.is_empty() {
                let waited = g.queue.front().unwrap().enqueued.elapsed();
                if g.queue.len() >= self.max_batch || waited >= self.max_delay {
                    let n = g.queue.len().min(self.max_batch);
                    return Some(Popped { jobs: g.queue.drain(..n).collect(), expired });
                }
                if !expired.is_empty() {
                    // deliver sheds now — their clients are already past the
                    // deadline; don't sit on them for the batching window
                    return Some(Popped { jobs: Vec::new(), expired });
                }
                let remaining = self.max_delay - waited;
                let (ng, _timeout) = self.cv.wait_timeout(g, remaining).unwrap();
                g = ng;
            } else if !expired.is_empty() {
                return Some(Popped { jobs: Vec::new(), expired });
            } else {
                g = self.cv.wait(g).unwrap();
            }
        }
    }

    /// Wake the (possibly idle) consumers: the next [`BatchQueue::pop_batch`]
    /// to observe the flag returns promptly — with an empty batch if nothing
    /// is due — so that worker can run its between-batches checks.  The flag
    /// is one-shot and consumed under the mutex, so with N replicated
    /// workers exactly one of them takes the empty pop; the serving workers
    /// only look at the hot-swap slot between pops, so a deploy posted to an
    /// idle server needs this nudge — without traffic every worker would
    /// otherwise sleep on the condvar and never install the staged
    /// generation.  (`notify_all` because the kicked worker may be any of
    /// them; the rest re-check state and go back to sleep.)
    pub fn kick(&self) {
        let mut g = self.inner.lock().unwrap();
        g.kicked = true;
        self.cv.notify_all();
    }

    /// Close the queue, waking all waiters, and return the drained backlog
    /// so every unserved job can be sent a terminal reply (dropping their
    /// response senders would leave clients hanging until their timeout).
    pub fn close(&self) -> Vec<Pending<T>> {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        let drained = g.queue.drain(..).collect();
        self.cv.notify_all();
        drained
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn full_batch_pops_immediately() {
        let q = BatchQueue::new(4, Duration::from_secs(10));
        for i in 0..4 {
            assert!(q.push(i).is_ok());
        }
        let popped = q.pop_batch().unwrap();
        assert_eq!(popped.jobs.len(), 4);
        assert!(popped.expired.is_empty());
        assert_eq!(popped.jobs[0].payload, 0);
    }

    #[test]
    fn timeout_flushes_partial_batch() {
        let q = Arc::new(BatchQueue::new(64, Duration::from_millis(30)));
        q.push(42).unwrap();
        let t0 = Instant::now();
        let popped = q.pop_batch().unwrap();
        assert_eq!(popped.jobs.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn oversize_queue_pops_max_batch() {
        let q = BatchQueue::new(3, Duration::from_secs(10));
        for i in 0..7 {
            q.push(i).unwrap();
        }
        assert_eq!(q.pop_batch().unwrap().jobs.len(), 3);
        assert_eq!(q.pop_batch().unwrap().jobs.len(), 3);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn close_drains_backlog_then_pop_returns_none() {
        let q = BatchQueue::new(8, Duration::from_secs(10));
        q.push(1).unwrap();
        q.push(2).unwrap();
        let drained = q.close();
        assert_eq!(drained.len(), 2, "close returns the unserved backlog");
        assert_eq!(drained[0].payload, 1);
        assert_eq!(q.push(3), Err(PushError::Closed));
        assert!(q.pop_batch().is_none());
        assert!(q.is_closed());
    }

    #[test]
    fn bounded_queue_rejects_at_capacity() {
        let q = BatchQueue::bounded(4, Duration::from_secs(10), 3, None);
        for i in 0..3 {
            assert!(q.push(i).is_ok());
        }
        assert_eq!(q.push(99), Err(PushError::Full));
        assert_eq!(q.len(), 3, "rejected pushes must not enqueue");
        // draining frees capacity again
        let popped = q.pop_batch().unwrap();
        assert_eq!(popped.jobs.len(), 3);
        assert!(q.push(100).is_ok());
    }

    #[test]
    fn bounded_push_under_concurrent_producers_never_exceeds_cap() {
        // hammer a cap-8 queue from 4 producers; every push either lands or
        // reports Full, the depth never exceeds the cap, and accepted ==
        // total - shed exactly (no lost or duplicated jobs)
        let q = Arc::new(BatchQueue::bounded(64, Duration::from_secs(10), 8, None));
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                thread::spawn(move || {
                    let mut shed = 0u64;
                    for i in 0..50 {
                        match q.push(p * 100 + i) {
                            Ok(()) => {}
                            Err(PushError::Full) => shed += 1,
                            Err(PushError::Closed) => unreachable!(),
                        }
                        assert!(q.len() <= 8, "queue depth exceeded the cap");
                    }
                    shed
                })
            })
            .collect();
        let shed: u64 = producers.into_iter().map(|t| t.join().unwrap()).sum();
        let queued = q.len() as u64;
        assert_eq!(queued + shed, 200, "accepted + shed must cover every push");
        assert!(queued <= 8);
        assert!(shed >= 200 - 8, "with no consumer, all but cap must shed");
    }

    #[test]
    fn deadline_sheds_oldest_first_and_serves_the_rest() {
        let q = BatchQueue::bounded(
            8,
            Duration::from_millis(5),
            usize::MAX,
            Some(Duration::from_millis(40)),
        );
        q.push("old-a").unwrap();
        q.push("old-b").unwrap();
        thread::sleep(Duration::from_millis(90)); // both sail past the deadline
        q.push("fresh").unwrap();
        // first pop delivers the sheds immediately (no batching-window wait)
        let popped = q.pop_batch().unwrap();
        let shed: Vec<_> = popped.expired.iter().map(|p| p.payload).collect();
        assert_eq!(shed, vec!["old-a", "old-b"], "sheds are oldest-first");
        assert!(popped.jobs.is_empty(), "sheds are delivered without delay");
        // the live job is untouched and forms the next batch
        let next = q.pop_batch().unwrap();
        let served: Vec<_> = next.jobs.iter().map(|p| p.payload).collect();
        assert_eq!(served, vec!["fresh"]);
        assert!(next.expired.is_empty());
    }

    #[test]
    fn all_expired_pop_returns_sheds_without_waiting_for_the_window() {
        let q = BatchQueue::bounded(
            8,
            Duration::from_secs(10), // window far longer than the test
            usize::MAX,
            Some(Duration::from_millis(30)),
        );
        q.push(1).unwrap();
        q.push(2).unwrap();
        thread::sleep(Duration::from_millis(80));
        let t0 = Instant::now();
        let popped = q.pop_batch().unwrap();
        assert!(popped.jobs.is_empty());
        assert_eq!(popped.expired.len(), 2);
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "sheds must not wait out the batching window"
        );
    }

    #[test]
    fn timeout_flush_fires_under_concurrent_pushers() {
        // regression for the notify_one switch: with max_batch far above the
        // offered load, every pop must come from the timeout path, and
        // concurrent pushers re-notifying the single consumer must never
        // stall it past the flush deadline
        let q = Arc::new(BatchQueue::new(1024, Duration::from_millis(20)));
        let total = 15;
        let producers: Vec<_> = (0..3)
            .map(|p| {
                let q = q.clone();
                thread::spawn(move || {
                    for i in 0..5 {
                        assert!(q.push(p * 100 + i).is_ok());
                        thread::sleep(Duration::from_millis(7));
                    }
                })
            })
            .collect();
        let mut got = 0;
        while got < total {
            let t0 = Instant::now();
            let popped = q.pop_batch().expect("queue is never closed here");
            assert!(!popped.jobs.is_empty());
            // each flush must come from the max_delay timer, not a full
            // batch — generous bound for slow CI
            assert!(
                t0.elapsed() < Duration::from_millis(1500),
                "timeout flush stalled: {:?}",
                t0.elapsed()
            );
            got += popped.jobs.len();
        }
        assert_eq!(got, total);
        for p in producers {
            p.join().unwrap();
        }
    }

    #[test]
    fn kick_wakes_an_idle_consumer_with_an_empty_pop() {
        let q = Arc::new(BatchQueue::new(8, Duration::from_millis(5)));
        let qc = q.clone();
        let consumer = thread::spawn(move || {
            let t0 = Instant::now();
            let popped = qc.pop_batch().expect("kick must not close the queue");
            (t0.elapsed(), popped.jobs.len(), popped.expired.len())
        });
        thread::sleep(Duration::from_millis(30)); // let the consumer block
        q.kick();
        let (waited, jobs, expired) = consumer.join().unwrap();
        assert_eq!((jobs, expired), (0, 0), "a kick pops an empty batch");
        assert!(waited < Duration::from_secs(5), "kick must wake promptly");
        // the flag is one-shot: queued work flows normally afterwards
        q.push(7).unwrap();
        assert_eq!(q.pop_batch().unwrap().jobs.len(), 1);
    }

    #[test]
    fn replicated_consumers_partition_jobs_exactly_once() {
        // N workers draining one queue: every job is served by exactly one
        // consumer (batches drain under the mutex), and closing the queue
        // releases all of them.
        use std::collections::HashSet;
        use std::sync::mpsc;
        let q = Arc::new(BatchQueue::new(8, Duration::from_millis(3)));
        let (tx, rx) = mpsc::channel::<Vec<i32>>();
        let workers: Vec<_> = (0..4)
            .map(|_| {
                let q = q.clone();
                let tx = tx.clone();
                thread::spawn(move || {
                    while let Some(popped) = q.pop_batch() {
                        if !popped.jobs.is_empty() {
                            tx.send(popped.jobs.iter().map(|p| p.payload).collect()).unwrap();
                        }
                    }
                })
            })
            .collect();
        drop(tx);
        let total = 300;
        for i in 0..total {
            q.push(i).unwrap();
            if i % 50 == 0 {
                thread::sleep(Duration::from_millis(1)); // vary batch shapes
            }
        }
        let mut seen = HashSet::new();
        let mut got = 0;
        while got < total {
            let batch = rx.recv_timeout(Duration::from_secs(30)).expect("workers stalled");
            for v in batch {
                assert!(seen.insert(v), "job {v} served by two workers");
                got += 1;
            }
        }
        assert!(q.close().is_empty(), "all jobs already drained");
        for w in workers {
            w.join().unwrap();
        }
        assert_eq!(seen.len() as i32, total);
    }

    #[test]
    fn kick_with_replicated_consumers_wakes_exactly_one_empty_pop() {
        // the one-shot flag must be consumed by a single worker — a kick
        // observed by every replica would multiply swap-pickup checks and,
        // worse, double-install
        use std::sync::mpsc;
        let q = Arc::new(BatchQueue::new(8, Duration::from_secs(30)));
        let (tx, rx) = mpsc::channel::<usize>();
        let workers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                let tx = tx.clone();
                thread::spawn(move || {
                    while let Some(popped) = q.pop_batch() {
                        tx.send(popped.jobs.len()).unwrap();
                    }
                })
            })
            .collect();
        drop(tx);
        thread::sleep(Duration::from_millis(30)); // let all three block
        q.kick();
        let first = rx.recv_timeout(Duration::from_secs(10)).expect("kick lost");
        assert_eq!(first, 0, "the kicked worker pops an empty batch");
        // no second empty pop arrives: the other workers went back to sleep
        assert!(
            rx.recv_timeout(Duration::from_millis(200)).is_err(),
            "kick flag consumed more than once"
        );
        q.close();
        for w in workers {
            w.join().unwrap();
        }
    }

    #[test]
    fn concurrent_producers_consumer() {
        let q = Arc::new(BatchQueue::new(16, Duration::from_millis(5)));
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                thread::spawn(move || {
                    for i in 0..50 {
                        q.push(p * 100 + i).unwrap();
                    }
                })
            })
            .collect();
        let qc = q.clone();
        let consumer = thread::spawn(move || {
            let mut got = 0;
            while got < 200 {
                if let Some(b) = qc.pop_batch() {
                    got += b.jobs.len();
                } else {
                    break;
                }
            }
            got
        });
        for p in producers {
            p.join().unwrap();
        }
        assert_eq!(consumer.join().unwrap(), 200);
    }
}
