//! L3 coordinator: deployment pipeline, router, batcher, server, fine-tune.

pub mod batcher;
pub mod deploy;
pub mod finetune;
pub mod metrics;
pub(crate) mod mux;
pub mod router;
pub mod server;
pub mod swap;
