//! Multiplexed event-loop front end: one thread, every connection.
//!
//! A single non-blocking poll loop owns the listener and all client
//! sockets — no thread-per-connection, so concurrency is bounded by file
//! descriptors, not OS threads.  Std-only by design (the vendored
//! dependency universe has no `mio`/`epoll` binding): the loop polls each
//! socket with non-blocking reads/writes and sleeps briefly only when a
//! full pass makes no progress, which keeps idle CPU negligible while
//! bounding added latency to well under a millisecond.
//!
//! Per connection the mux maintains:
//!
//! * a **read buffer** reassembling newline-delimited requests from
//!   arbitrarily fragmented TCP reads (a slow writer dribbling one request
//!   across many segments is fine);
//! * an **in-flight table** of requests handed to the worker pool, keyed
//!   by the request `id` — requests may be *pipelined* (many unanswered on
//!   one connection) and replies are forwarded in completion order, so a
//!   batch that lands early never waits behind a slow one (out-of-order
//!   responses are the contract; clients match replies by `id`);
//! * a **write buffer** absorbing partial writes — a slow reader backs up
//!   its own buffer (hard-capped, then the connection is dropped) and
//!   never stalls the loop or other connections.
//!
//! Request parsing is strict ([`parse_request`]): malformed JSON, a
//! missing/non-integer `id`, bad pixels, and a *duplicate* `id` already in
//! flight on the same connection are each a typed [`RequestError`],
//! answered with a terminal error reply and counted in `bad_requests` —
//! a duplicate id would otherwise key two in-flight replies to one slot.
//!
//! The same port speaks just enough HTTP for ops tooling: `GET /healthz`
//! (liveness + serving generation), `GET /metrics` (Prometheus text
//! exposition of the `counter.`/`gauge.`/`latency_ms.` schema), and
//! `GET /metrics.json` (the JSON snapshot).  See `docs/METRICS.md`.

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use super::batcher::{BatchQueue, PushError};
use super::metrics::Metrics;
use super::server::{retry_after_ms, Job, Roster};
use crate::util::json::{self, Value};

/// Largest buffered request line; a line still unterminated past this is
/// not a client we can serve (one request is H*W*C ≈ tens of KB of JSON).
const MAX_LINE_BYTES: usize = 1 << 20;
/// Largest backed-up write buffer before a slow reader is disconnected.
const MAX_WRITE_BUF: usize = 4 << 20;
/// Reads drained per connection per tick (fairness under a fast writer).
const READS_PER_TICK: usize = 4;
const READ_CHUNK: usize = 16 * 1024;
/// Idle sleep when a full pass over every socket made no progress.
const IDLE_SLEEP: Duration = Duration::from_micros(500);
/// How long after shutdown the mux keeps flushing terminal replies.
const DRAIN_GRACE: Duration = Duration::from_secs(5);
/// Gauge refresh period for `mux.connections` / `mux.inflight`.
const GAUGE_PERIOD: Duration = Duration::from_millis(250);

/// Everything the mux loop shares with the rest of the server.
pub(crate) struct MuxParams {
    pub(crate) queue: Arc<BatchQueue<Job>>,
    pub(crate) metrics: Arc<Metrics>,
    pub(crate) roster: Arc<Roster>,
    pub(crate) shutdown: Arc<AtomicBool>,
    /// Pixels per request (H*W*C for the served model).
    pub(crate) pix_expected: usize,
    /// How long a dispatched request may stay unanswered before the mux
    /// replies `inference timeout` on the worker's behalf.
    pub(crate) reply_timeout: Duration,
    /// Replicated worker count (reported by `/healthz`).
    pub(crate) workers: usize,
}

/// Why a request line was rejected.  Every variant is terminal for that
/// request only (the connection stays up) and counts in `bad_requests`.
#[derive(Debug, PartialEq, Eq)]
pub(crate) enum RequestError {
    BadJson(String),
    MissingId,
    NonIntegerId,
    MissingPixels,
    BadPixel(usize),
    WrongPixelCount { expected: usize, got: usize },
    /// The same `id` is already in flight on this connection — admitting it
    /// would key two replies to one slot.
    DuplicateId(u64),
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::BadJson(e) => write!(f, "bad json: {e}"),
            RequestError::MissingId => write!(f, "missing id"),
            RequestError::NonIntegerId => write!(f, "id must be a non-negative integer"),
            RequestError::MissingPixels => write!(f, "missing pixels"),
            RequestError::BadPixel(i) => write!(f, "pixel {i} is not a number"),
            RequestError::WrongPixelCount { expected, got } => {
                write!(f, "expected {expected} pixels, got {got}")
            }
            RequestError::DuplicateId(id) => {
                write!(f, "duplicate id {id}: already in flight on this connection")
            }
        }
    }
}

impl RequestError {
    /// The terminal error reply for this rejection.  A duplicate id names
    /// the id so a pipelining client can match it; the other variants have
    /// no trustworthy id to echo.
    fn reply(&self) -> Value {
        match self {
            RequestError::DuplicateId(id) => json::obj(vec![
                ("error", json::s(&self.to_string())),
                ("id", json::num(*id as f64)),
            ]),
            _ => json::obj(vec![("error", json::s(&self.to_string()))]),
        }
    }
}

/// Parse one request line: `{"id": N, "pixels": [ ... ]}` with exactly
/// `pix_expected` numeric pixels and a non-negative integer `id`.
pub(crate) fn parse_request(
    line: &str,
    pix_expected: usize,
) -> Result<(u64, Vec<f32>), RequestError> {
    let v = json::parse(line).map_err(|e| RequestError::BadJson(e.to_string()))?;
    let idf = v.get("id").as_f64().ok_or(RequestError::MissingId)?;
    if !(idf >= 0.0 && idf.fract() == 0.0 && idf <= u64::MAX as f64) {
        return Err(RequestError::NonIntegerId);
    }
    let id = idf as u64;
    let arr = v.get("pixels").as_arr().ok_or(RequestError::MissingPixels)?;
    if arr.len() != pix_expected {
        return Err(RequestError::WrongPixelCount { expected: pix_expected, got: arr.len() });
    }
    let mut pixels = Vec::with_capacity(arr.len());
    for (i, p) in arr.iter().enumerate() {
        pixels.push(p.as_f64().ok_or(RequestError::BadPixel(i))? as f32);
    }
    Ok((id, pixels))
}

/// `{"id":..,"error":..}` — the terminal reply shape for a request that
/// was admitted (so its id is trustworthy) but cannot be served.
fn err_reply(id: u64, msg: &str) -> Value {
    json::obj(vec![("error", json::s(msg)), ("id", json::num(id as f64))])
}

/// A write buffer tolerant of partial writes: [`WriteBuf::flush_to`] pushes
/// as much as the socket accepts and keeps the rest for the next tick, so
/// a slow reader costs buffer space, never loop stalls.
struct WriteBuf {
    buf: Vec<u8>,
    /// Bytes of `buf` already written (compacted once it grows).
    pos: usize,
}

impl WriteBuf {
    fn new() -> WriteBuf {
        WriteBuf { buf: Vec::new(), pos: 0 }
    }

    fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    fn len(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Write what the sink will take; `Ok(true)` if any bytes moved.
    fn flush_to(&mut self, w: &mut impl Write) -> io::Result<bool> {
        let mut progress = false;
        while self.pos < self.buf.len() {
            match w.write(&self.buf[self.pos..]) {
                Ok(0) => return Err(io::Error::new(io::ErrorKind::WriteZero, "write zero")),
                Ok(n) => {
                    self.pos += n;
                    progress = true;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos > 64 * 1024 {
            // drop the written prefix so a long-lived slow reader does not
            // pin an ever-growing allocation
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        Ok(progress)
    }
}

/// One dispatched request awaiting its worker reply.
struct Inflight {
    id: u64,
    rx: mpsc::Receiver<Value>,
    since: Instant,
}

/// One multiplexed client connection.
struct Conn {
    stream: TcpStream,
    /// Unconsumed request bytes (reassembles fragmented lines).
    rbuf: Vec<u8>,
    wbuf: WriteBuf,
    inflight: Vec<Inflight>,
    /// Close once the write buffer drains (EOF seen, HTTP reply sent, or a
    /// protocol error made further input meaningless).
    close_after_flush: bool,
    /// Read and discard further input (still detects the client's close).
    discard_input: bool,
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            rbuf: Vec::new(),
            wbuf: WriteBuf::new(),
            inflight: Vec::new(),
            close_after_flush: false,
            discard_input: false,
            dead: false,
        }
    }

    /// Nothing left to deliver: safe to let shutdown close the socket.
    fn drained(&self) -> bool {
        self.inflight.is_empty() && self.wbuf.is_empty()
    }

    fn push_reply(&mut self, v: Value) {
        if self.wbuf.len() > MAX_WRITE_BUF {
            // slow reader past the hard cap: drop the connection rather
            // than buffer without bound
            self.dead = true;
            return;
        }
        self.wbuf.push(v.to_json().as_bytes());
        self.wbuf.push(b"\n");
    }

    /// One scheduling pass: read, parse/dispatch, collect replies, flush.
    /// Returns whether any byte or reply moved (the loop's idle signal).
    fn step(&mut self, p: &MuxParams) -> bool {
        let mut progress = false;
        progress |= self.fill_read_buffer(p);
        if self.dead {
            return progress;
        }
        progress |= self.process_lines(p);
        progress |= self.poll_replies(p);
        match self.wbuf.flush_to(&mut self.stream) {
            Ok(moved) => progress |= moved,
            Err(_) => {
                self.dead = true;
                return progress;
            }
        }
        if self.close_after_flush && self.drained() {
            self.dead = true;
        }
        progress
    }

    fn fill_read_buffer(&mut self, p: &MuxParams) -> bool {
        let mut progress = false;
        let mut chunk = [0u8; READ_CHUNK];
        for _ in 0..READS_PER_TICK {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    // client closed its write side: no more requests, but
                    // pending replies still flush before we hang up
                    self.close_after_flush = true;
                    self.discard_input = true;
                    progress = true;
                    break;
                }
                Ok(n) => {
                    progress = true;
                    if !self.discard_input {
                        self.rbuf.extend_from_slice(&chunk[..n]);
                        if self.rbuf.len() > MAX_LINE_BYTES
                            && !self.rbuf.contains(&b'\n')
                        {
                            p.metrics.inc("bad_requests", 1);
                            self.push_reply(json::obj(vec![(
                                "error",
                                json::s("request line too long"),
                            )]));
                            self.close_after_flush = true;
                            self.discard_input = true;
                            self.rbuf.clear();
                            break;
                        }
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        progress
    }

    fn process_lines(&mut self, p: &MuxParams) -> bool {
        let mut progress = false;
        while !self.discard_input {
            let Some(nl) = self.rbuf.iter().position(|&b| b == b'\n') else { break };
            let raw: Vec<u8> = self.rbuf.drain(..=nl).collect();
            let line = String::from_utf8_lossy(&raw);
            let line = line.trim_end_matches('\n').trim_end_matches('\r').trim();
            if line.is_empty() {
                continue;
            }
            progress = true;
            if line.starts_with("GET ") || line.starts_with("HEAD ") {
                // just enough HTTP for ops tooling: answer the request
                // line, ignore the header block, close when flushed
                let is_head = line.starts_with("HEAD ");
                let path = line.split_whitespace().nth(1).unwrap_or("/").to_string();
                let resp = http_response(&path, is_head, p);
                self.wbuf.push(resp.as_bytes());
                self.close_after_flush = true;
                self.discard_input = true;
                self.rbuf.clear();
                break;
            }
            self.dispatch_line(line, p);
        }
        progress
    }

    fn dispatch_line(&mut self, line: &str, p: &MuxParams) {
        match parse_request(line, p.pix_expected) {
            Ok((id, pixels)) => {
                if self.inflight.iter().any(|f| f.id == id) {
                    p.metrics.inc("bad_requests", 1);
                    self.push_reply(RequestError::DuplicateId(id).reply());
                    return;
                }
                let (tx, rx) = mpsc::channel();
                let job = Job { id, pixels, enqueued: Instant::now(), resp: tx };
                match p.queue.push(job) {
                    Ok(()) => {
                        self.inflight.push(Inflight { id, rx, since: Instant::now() })
                    }
                    Err(PushError::Full) => {
                        // admission control: shed with a backoff hint
                        p.metrics.inc("shed_overload", 1);
                        let hint = retry_after_ms(&p.queue, &p.metrics);
                        self.push_reply(json::obj(vec![
                            ("error", json::s("overloaded")),
                            ("id", json::num(id as f64)),
                            ("retry_after_ms", json::num(hint)),
                        ]));
                    }
                    Err(PushError::Closed) => {
                        self.push_reply(err_reply(id, "server shutting down"));
                    }
                }
            }
            Err(e) => {
                p.metrics.inc("bad_requests", 1);
                self.push_reply(e.reply());
            }
        }
    }

    /// Forward completed replies in *completion* order — out-of-order by
    /// design; pipelining clients match replies to requests by `id`.
    fn poll_replies(&mut self, p: &MuxParams) -> bool {
        let mut progress = false;
        let mut i = 0;
        while i < self.inflight.len() {
            match self.inflight[i].rx.try_recv() {
                Ok(v) => {
                    self.inflight.swap_remove(i);
                    self.push_reply(v);
                    progress = true;
                }
                Err(mpsc::TryRecvError::Disconnected) => {
                    // the job's sender was dropped without a reply (a
                    // worker died mid-batch): terminal error, not a hang
                    let id = self.inflight.swap_remove(i).id;
                    self.push_reply(err_reply(id, "inference aborted"));
                    progress = true;
                }
                Err(mpsc::TryRecvError::Empty) => {
                    if self.inflight[i].since.elapsed() > p.reply_timeout {
                        let id = self.inflight.swap_remove(i).id;
                        self.push_reply(err_reply(id, "inference timeout"));
                        progress = true;
                    } else {
                        i += 1;
                    }
                }
            }
        }
        progress
    }
}

/// Render one ops response.  `Connection: close` keeps the HTTP surface
/// stateless — curl/Prometheus reconnect per scrape.
fn http_response(path: &str, is_head: bool, p: &MuxParams) -> String {
    let (status, ctype, body) = match path {
        "/healthz" => {
            let body = json::obj(vec![
                ("generation", json::num(p.roster.generation() as f64)),
                ("status", json::s("ok")),
                ("workers", json::num(p.workers as f64)),
            ])
            .to_json()
                + "\n";
            ("200 OK", "application/json", body)
        }
        "/metrics" => ("200 OK", "text/plain; version=0.0.4", p.metrics.prometheus()),
        "/metrics.json" => {
            ("200 OK", "application/json", p.metrics.snapshot().to_json() + "\n")
        }
        _ => ("404 Not Found", "text/plain", "not found\n".to_string()),
    };
    let head = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    if is_head {
        head
    } else {
        head + &body
    }
}

/// The mux event loop.  Accepts while the server is up; on shutdown stops
/// accepting, keeps flushing terminal replies until every connection is
/// drained (bounded by [`DRAIN_GRACE`]), then exits and drops the sockets.
pub(crate) fn run(listener: TcpListener, p: MuxParams) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut drain_deadline: Option<Instant> = None;
    let mut last_gauges = Instant::now();
    loop {
        let shutting_down = p.shutdown.load(Ordering::Relaxed);
        let mut progress = false;
        if !shutting_down {
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nodelay(true).ok();
                        if stream.set_nonblocking(true).is_ok() {
                            p.metrics.inc("mux.accepted", 1);
                            conns.push(Conn::new(stream));
                            progress = true;
                        }
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => break,
                }
            }
        }
        for conn in &mut conns {
            progress |= conn.step(&p);
        }
        conns.retain(|c| !c.dead);
        if last_gauges.elapsed() >= GAUGE_PERIOD {
            last_gauges = Instant::now();
            p.metrics.set_gauge("mux.connections", conns.len() as f64);
            p.metrics.set_gauge(
                "mux.inflight",
                conns.iter().map(|c| c.inflight.len()).sum::<usize>() as f64,
            );
        }
        if shutting_down {
            // every queued job gets its terminal reply from stop()'s drain
            // or a serving worker, and reply_timeout bounds the rest — so
            // "all connections drained" is reached, with DRAIN_GRACE as
            // the backstop against a wedged peer
            if p.queue.is_closed() && drain_deadline.is_none() {
                drain_deadline = Some(Instant::now() + DRAIN_GRACE);
            }
            let drained = conns.iter().all(|c| c.drained());
            let expired = drain_deadline.is_some_and(|d| Instant::now() >= d);
            if (drained && p.queue.is_closed()) || expired {
                break;
            }
        }
        if !progress {
            thread::sleep(IDLE_SLEEP);
        }
    }
    p.metrics.set_gauge("mux.connections", 0.0);
    p.metrics.set_gauge("mux.inflight", 0.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::{Roster, ServerConfig};
    use crate::data::synth_store;
    use crate::model::meta::ModelKind;

    #[test]
    fn parse_request_validates() {
        let ok = parse_request(r#"{"id": 7, "pixels": [0.1, 0.2, 0.3, 0.4]}"#, 4).unwrap();
        assert_eq!(ok.0, 7);
        assert_eq!(ok.1.len(), 4);

        let e = parse_request("{nope", 4).unwrap_err();
        assert!(matches!(e, RequestError::BadJson(_)));
        assert!(e.to_string().starts_with("bad json:"));

        let e = parse_request(r#"{"pixels": [1, 2, 3, 4]}"#, 4).unwrap_err();
        assert_eq!(e, RequestError::MissingId);
        assert_eq!(e.to_string(), "missing id");

        let e = parse_request(r#"{"id": 1, "nopixels": true}"#, 4).unwrap_err();
        assert_eq!(e, RequestError::MissingPixels);
        assert_eq!(e.to_string(), "missing pixels");

        let e = parse_request(r#"{"id": 1, "pixels": [1, 2]}"#, 4).unwrap_err();
        assert_eq!(e, RequestError::WrongPixelCount { expected: 4, got: 2 });
        assert_eq!(e.to_string(), "expected 4 pixels, got 2");
    }

    #[test]
    fn parse_request_rejects_non_numeric_pixels() {
        let e = parse_request(r#"{"id": 1, "pixels": [1, "x", 3, 4]}"#, 4).unwrap_err();
        assert_eq!(e, RequestError::BadPixel(1));
        assert!(e.to_string().contains("not a number"));
    }

    #[test]
    fn parse_request_rejects_bad_ids() {
        // the bugfix: a missing or malformed id is a typed rejection, never
        // a request that silently keys its reply to the wrong slot
        for line in [
            r#"{"id": -1, "pixels": [1, 2, 3, 4]}"#,
            r#"{"id": 1.5, "pixels": [1, 2, 3, 4]}"#,
        ] {
            let e = parse_request(line, 4).unwrap_err();
            assert_eq!(e, RequestError::NonIntegerId, "{line}");
        }
        let e = parse_request(r#"{"id": "seven", "pixels": [1, 2, 3, 4]}"#, 4).unwrap_err();
        assert_eq!(e, RequestError::MissingId);
        // and the duplicate-id reply names the id so a pipelining client
        // can match the rejection
        let r = RequestError::DuplicateId(9).reply();
        assert_eq!(r.get("id").as_f64(), Some(9.0));
        assert!(r.get("error").as_str().unwrap().contains("duplicate id 9"));
    }

    /// A sink that takes at most 3 bytes per write and blocks when its
    /// budget runs out — the pathological slow reader.
    struct Dribble {
        out: Vec<u8>,
        budget: usize,
    }

    impl Write for Dribble {
        fn write(&mut self, b: &[u8]) -> io::Result<usize> {
            if self.budget == 0 {
                return Err(io::Error::new(io::ErrorKind::WouldBlock, "blocked"));
            }
            let n = b.len().min(3).min(self.budget);
            self.budget -= n;
            self.out.extend_from_slice(&b[..n]);
            Ok(n)
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn write_buf_survives_partial_writes_and_compacts() {
        let payload: Vec<u8> = (0..200_000u32).map(|i| (i % 251) as u8).collect();
        let mut wb = WriteBuf::new();
        wb.push(&payload);
        assert_eq!(wb.len(), payload.len());
        let mut sink = Dribble { out: Vec::new(), budget: 0 };
        // a fully blocked sink: no progress, no error, nothing lost
        assert!(!wb.flush_to(&mut sink).unwrap());
        assert_eq!(wb.len(), payload.len());
        // dribble the rest out in small budget grants
        let mut rounds = 0;
        while !wb.is_empty() {
            sink.budget = 4096;
            wb.flush_to(&mut sink).unwrap();
            rounds += 1;
            assert!(rounds < 200, "must terminate");
        }
        assert_eq!(sink.out, payload, "every byte arrives exactly once, in order");
        // buffer fully reset after drain
        assert_eq!(wb.len(), 0);
        assert_eq!(wb.pos, 0);
        assert!(wb.buf.is_empty());
    }

    fn test_params() -> MuxParams {
        let cfg = ServerConfig::default();
        let roster = Arc::new(
            Roster::build(None, synth_store(99, ModelKind::Lenet), &cfg).unwrap(),
        );
        MuxParams {
            queue: Arc::new(BatchQueue::bounded(4, Duration::from_millis(5), 16, None)),
            metrics: Arc::new(Metrics::new()),
            roster,
            shutdown: Arc::new(AtomicBool::new(false)),
            pix_expected: 4,
            reply_timeout: Duration::from_secs(1),
            workers: 2,
        }
    }

    #[test]
    fn http_responses_render() {
        let p = test_params();
        p.metrics.inc("requests", 3);
        let h = http_response("/healthz", false, &p);
        assert!(h.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(h.contains("Content-Type: application/json"));
        assert!(h.contains("Connection: close"));
        let body = h.split("\r\n\r\n").nth(1).unwrap();
        let v = json::parse(body.trim()).unwrap();
        assert_eq!(v.get("status").as_str(), Some("ok"));
        assert_eq!(v.get("workers").as_f64(), Some(2.0));
        assert_eq!(v.get("generation").as_f64(), Some(1.0));
        // content-length is the body's exact byte count
        let clen: usize = h
            .lines()
            .find(|l| l.starts_with("Content-Length: "))
            .and_then(|l| l.trim_start_matches("Content-Length: ").trim().parse().ok())
            .unwrap();
        assert_eq!(clen, body.len());

        let m = http_response("/metrics", false, &p);
        assert!(m.contains("text/plain; version=0.0.4"));
        assert!(m.contains("qsq_requests_total 3"));

        // HEAD: headers only, same content-length
        let head = http_response("/metrics", true, &p);
        assert!(head.ends_with("\r\n\r\n"));
        assert!(!head.contains("qsq_requests_total"));

        let j = http_response("/metrics.json", false, &p);
        let jbody = j.split("\r\n\r\n").nth(1).unwrap();
        assert!(json::parse(jbody.trim()).is_ok());

        assert!(http_response("/nope", false, &p).starts_with("HTTP/1.1 404"));
    }
}
