//! Pure-rust inference engines.
//!
//! Three engines live here, all mirroring the L2 model graphs exactly (same
//! im2col ordering, same layer stack), and all running the fused zero-copy
//! pipeline: conv layers stage im2col patches band-by-band through a
//! [`Scratch`] arena ([`mod@crate::kernels::qconv`]), activations ping-pong
//! between two pooled buffers, and epilogues (bias + ReLU, 2x2 pool) run in
//! place — steady-state serving allocates only the returned logits.  All
//! row-band kernels dispatch on the persistent worker pool
//! ([`crate::kernels::Pool`]), so a warm engine spawns zero threads per
//! request.
//!
//! * the f32 path ([`forward`] / [`forward_with`], engine form
//!   [`F32Engine`]) — every layer on the blocked/microtiled GEMM
//!   ([`crate::kernels::blocked`]).  It is the oracle the PJRT path is
//!   validated against and the fallback when `artifacts/` is absent.  The
//!   original per-op tensor functions ([`lenet_fwd`], [`convnet_fwd`])
//!   survive as the readable references the fused pipeline is tested
//!   against.
//! * [`QuantizedEngine`] — the code-domain path: quantized layers execute on
//!   the plane-packed [`crate::kernels::qgemm2`] straight from packed codes
//!   (zero-skip, shift/add, hoisted alpha, row-parallel), only the fp32 head
//!   and biases touch the f32 GEMM.  This is what the edge side serves with.
//! * [`CsdEngine`] — the CSD shift-and-add path: quantized-layer weights are
//!   truncated-CSD packed ([`crate::kernels::csd`]) at a
//!   [`CsdQuality`] digit budget — the paper's §V.B quality dial.
//!
//! All three implement the unified [`crate::runtime::engine::Engine`]
//! trait next to the PJRT wrapper: each accumulates a lifetime energy
//! [`Ledger`] and a forwards counter and reports one
//! [`crate::runtime::engine::EngineReport`], which the server exports as
//! the uniform `engine.<name>.*` gauge family.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::codec::{EncodedModel, EncodedTensor};
use crate::device::{CsdQuality, QualityConfig};
use crate::hw::energy::Ledger;
use crate::hw::fixedpoint::Format;
use crate::kernels::{self, blocked, ActPlan, PackedCsdTensor, PackedQTensorV2, Pool, Scratch};
use crate::model::meta::ModelKind;
use crate::model::store::WeightStore;
use crate::quant::qsq::{quantize, AssignMode};
use crate::quant::vectorize::Grouping;
use crate::tensor::{ops, Tensor};

/// Forward one batch through the model, host-side (one-shot scratch).
pub fn forward(store: &WeightStore, x: &Tensor) -> Result<Tensor> {
    forward_with(store, x, &mut Scratch::new())
}

/// Forward one batch on the fused f32 pipeline, reusing `scratch` — the
/// serving form: a worker holds one arena and stops allocating per request
/// once it is warm.  Band jobs run on the global persistent pool.
pub fn forward_with(store: &WeightStore, x: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
    let fwd = FusedFwd {
        store,
        packed: None,
        csd: None,
        energy: None,
        pool: Pool::global(),
        scalar: false,
        acts: None,
        ranges: None,
    };
    fwd.run(x, scratch)
}

/// LeNet-5 on the per-op tensor path: x [B,28,28,1] -> logits [B,10].
/// Retained as the readable reference the fused pipeline is tested against.
pub fn lenet_fwd(store: &WeightStore, x: &Tensor) -> Result<Tensor> {
    let feat = lenet_features(store, x)?;
    let h = ops::add_bias(&ops::matmul(&feat, store.get("f3w")?)?, store.get("f3b")?)?;
    Ok(h)
}

/// LeNet backbone up to the 84-d features (input of the fp32 head).
pub fn lenet_features(store: &WeightStore, x: &Tensor) -> Result<Tensor> {
    if x.shape().len() != 4 || x.shape()[1] != 28 {
        bail!("lenet expects [B,28,28,1], got {:?}", x.shape());
    }
    let b = x.shape()[0];
    let h = ops::add_bias(&ops::conv2d(x, store.get("c1w")?)?, store.get("c1b")?)?.relu();
    let h = ops::maxpool2(&h)?;
    let h = ops::add_bias(&ops::conv2d(&h, store.get("c2w")?)?, store.get("c2b")?)?.relu();
    let h = ops::maxpool2(&h)?;
    let h = h.reshape(vec![b, 256])?;
    let h = ops::add_bias(&ops::matmul(&h, store.get("f1w")?)?, store.get("f1b")?)?.relu();
    let h = ops::add_bias(&ops::matmul(&h, store.get("f2w")?)?, store.get("f2b")?)?.relu();
    Ok(h)
}

/// ConvNet-4 on the per-op tensor path: x [B,32,32,3] -> logits [B,10].
/// Retained as the readable reference the fused pipeline is tested against.
pub fn convnet_fwd(store: &WeightStore, x: &Tensor) -> Result<Tensor> {
    if x.shape().len() != 4 || x.shape()[1] != 32 {
        bail!("convnet expects [B,32,32,3], got {:?}", x.shape());
    }
    let b = x.shape()[0];
    let mut h = x.clone();
    for (kw, bw) in [("k1", "b1"), ("k2", "b2"), ("k3", "b3"), ("k4", "b4")] {
        h = ops::add_bias(&ops::conv2d_same(&h, store.get(kw)?)?, store.get(bw)?)?.relu();
        h = ops::maxpool2(&h)?;
    }
    let h = h.reshape(vec![b, 256])?;
    ops::add_bias(&ops::matmul(&h, store.get("fcw")?)?, store.get("fcb")?)
}

/// The fused f32 host path as a first-class engine: every layer on the
/// blocked/microtiled GEMM, one energy [`Ledger`] accumulated across
/// forwards (pure fp32 MACs — the baseline the quantized and CSD dials are
/// priced against), one forwards counter.  The free function
/// [`crate::runtime::host::forward_with`] remains the engine-less form for
/// callers that own a bare [`WeightStore`]; the server serves through this
/// wrapper so the f32 path reports the same `EngineReport` schema as every
/// other engine ([`crate::runtime::engine::Engine`]).
#[derive(Debug)]
pub struct F32Engine {
    store: WeightStore,
    /// Accumulated fp32 GEMM cost over every forward of this engine.
    ledger: Mutex<Ledger>,
    /// Forwards completed (one per batch).
    forwards: AtomicU64,
    /// The persistent worker pool every row-band kernel dispatches on.
    pool: &'static Pool,
}

impl F32Engine {
    /// Wrap a weight store (typically the full-precision serving store).
    pub fn new(store: WeightStore) -> F32Engine {
        F32Engine {
            store,
            ledger: Mutex::new(Ledger::new()),
            forwards: AtomicU64::new(0),
            pool: Pool::global(),
        }
    }

    pub fn model(&self) -> ModelKind {
        self.store.kind
    }

    /// The wrapped store (read-only; the engine owns the serving copy).
    pub fn store(&self) -> &WeightStore {
        &self.store
    }

    /// The worker pool this engine dispatches on.
    pub fn pool(&self) -> &'static Pool {
        self.pool
    }

    /// Snapshot of the accumulated energy ledger.
    pub fn ledger(&self) -> Ledger {
        self.ledger.lock().unwrap().clone()
    }

    /// Forwards completed since construction.
    pub fn forwards(&self) -> u64 {
        self.forwards.load(Ordering::Relaxed)
    }

    /// Forward one batch (one-shot scratch).
    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        self.forward_with(x, &mut Scratch::new())
    }

    /// Forward one batch, reusing `scratch` — the serving form.  Bitwise
    /// identical to the free [`crate::runtime::host::forward_with`] over
    /// the same store; additionally charges the request's f32 GEMM cost to
    /// the engine ledger.
    pub fn forward_with(&self, x: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        let out = FusedFwd {
            store: &self.store,
            packed: None,
            csd: None,
            energy: Some(&self.ledger),
            pool: self.pool,
            scalar: false,
            acts: None,
            ranges: None,
        }
        .run(x, scratch);
        if out.is_ok() {
            self.forwards.fetch_add(1, Ordering::Relaxed);
        }
        out
    }
}

/// Quantize every quantized tensor of a store at (phi, N) — the one
/// canonical policy (per-tensor nearest-divisor grouping) shared by the
/// deploy pipeline's `encode_store` and the serving engine.
pub fn quantize_tensors(
    store: &WeightStore,
    quality: QualityConfig,
    mode: AssignMode,
) -> Result<Vec<EncodedTensor>> {
    let mut tensors = Vec::new();
    for tm in store.meta.quantized_tensors() {
        let w = store.get(tm.name)?;
        let group = Grouping::nearest_divisor(&tm.shape, quality.group)?;
        let qt = quantize(w.data(), &tm.shape, group, quality.phi, mode)?;
        tensors.push(EncodedTensor { name: tm.name.to_string(), tensor: qt });
    }
    Ok(tensors)
}

/// Freeze observed per-layer activation ranges into an [`ActPlan`]: each
/// quantized chain layer gets the finest Q-format that covers its observed
/// max-|activation| without wrapping ([`kernels::format_for_max_abs`]), and
/// each *interior* bias is pre-quantized in the format its epilogue emits —
/// the **next** layer's input format.  The last chain layer keeps its f32
/// bias: its epilogue stays f32 so the fp32 head sees float features.
fn build_act_plan(store: &WeightStore, ranges: &BTreeMap<String, f32>) -> Result<ActPlan> {
    let chain: &[(&str, &str)] = match store.kind {
        ModelKind::Lenet => &[("c1w", "c1b"), ("c2w", "c2b"), ("f1w", "f1b"), ("f2w", "f2b")],
        ModelKind::Convnet => &[("k1", "b1"), ("k2", "b2"), ("k3", "b3"), ("k4", "b4")],
    };
    let mut plan = ActPlan::default();
    for &(wname, _) in chain {
        let ma = *ranges
            .get(wname)
            .with_context(|| format!("{wname}: no activation range observed by calibration"))?;
        plan.set_format(wname, kernels::format_for_max_abs(ma));
    }
    for i in 0..chain.len() - 1 {
        let bname = chain[i].1;
        let fmt = plan.format(chain[i + 1].0).expect("format set above");
        plan.set_bias_q(bname, kernels::quantize_bias(store.get(bname)?.data(), fmt));
    }
    Ok(plan)
}

/// The fused zero-copy forward pipeline, shared by the f32 engine (`packed`
/// and `csd` both `None`), the code-domain [`QuantizedEngine`], and the CSD
/// [`CsdEngine`]: per layer the packed layout is preferred when present, the
/// f32 weight otherwise.  Every row-band kernel dispatches on `pool`, so
/// steady-state serving spawns zero threads per request.  When `energy` is
/// set (the CSD engine), every layer folds its per-request cost into that
/// ledger.
struct FusedFwd<'a> {
    store: &'a WeightStore,
    packed: Option<&'a BTreeMap<String, PackedQTensorV2>>,
    csd: Option<&'a BTreeMap<String, PackedCsdTensor>>,
    energy: Option<&'a Mutex<Ledger>>,
    pool: &'static Pool,
    /// Run every plane sum on the retained scalar oracle instead of the
    /// lane reduction — the differential-reference forward
    /// ([`QuantizedEngine::forward_scalar_reference`] /
    /// [`CsdEngine::forward_scalar_reference`]), never the serving path.
    /// Banding, chunking, and the f32 microkernel are identical either way.
    scalar: bool,
    /// The calibrated integer-activation plan.  When present (and
    /// non-empty) the forward runs the fixed-point datapath: activations
    /// quantized i16 between layers inside the `qact_a`/`qact_b` ping/pong
    /// buffers, packed-layer plane sums through the SWAR i16 gathers, one
    /// dequant-rescale per output cell, integer bias+ReLU and maxpool
    /// epilogues.  `None` is the plain f32 activation path.
    acts: Option<&'a ActPlan>,
    /// Calibration observer: when set, [`FusedFwd::conv_into`] /
    /// [`FusedFwd::dense_into`] fold each layer input's max-|activation|
    /// into the map (keyed by weight-tensor name).  Engines run one f32
    /// forward with this set to build an [`ActPlan`]; never set while
    /// serving.
    ranges: Option<&'a Mutex<BTreeMap<String, f32>>>,
}

impl FusedFwd<'_> {
    fn packed_for(&self, name: &str) -> Option<&PackedQTensorV2> {
        self.packed.and_then(|m| m.get(name))
    }

    fn csd_for(&self, name: &str) -> Option<&PackedCsdTensor> {
        self.csd.and_then(|m| m.get(name))
    }

    /// Fold one CSD layer's shift-and-add cost over `rows` activation rows
    /// into the per-request energy ledger.
    fn note_csd_energy(&self, p: &PackedCsdTensor, rows: usize) {
        if let Some(l) = self.energy {
            l.lock().unwrap().add(&p.ledger_for_rows(rows));
        }
    }

    /// Fold one f32 layer's GEMM cost (`macs` multiply-accumulates — the
    /// fp32 head/bias layers of the CSD engine) into the energy ledger.
    fn note_f32_energy(&self, macs: usize) {
        if let Some(l) = self.energy {
            let mut l = l.lock().unwrap();
            l.fp_muls += macs as u64;
            l.fp_adds += macs as u64;
        }
    }

    /// Fold one integer-datapath layer into the energy ledger: `int_macs`
    /// i16 multiply-accumulates done as integer adds, plus one f32
    /// dequant-rescale multiply per output cell — and raise the `act_bits`
    /// gauge to the fixed-point activation width.
    fn note_int_energy(&self, int_macs: usize, dequant_cells: usize) {
        if let Some(l) = self.energy {
            let mut l = l.lock().unwrap();
            l.int_adds += int_macs as u64;
            l.fp_muls += dequant_cells as u64;
            l.act_bits = l.act_bits.max(kernels::ACT_TOTAL_BITS as u64);
        }
    }

    /// Calibration observer: fold this layer input's max-|activation| into
    /// the ranges map (no-op while serving).
    fn observe(&self, name: &str, xb: &[f32]) {
        if let Some(r) = self.ranges {
            let m = kernels::max_abs(xb);
            let mut g = r.lock().unwrap();
            let e = g.entry(name.to_string()).or_insert(0.0);
            if m > *e {
                *e = m;
            }
        }
    }

    /// The layer's bias, validated against the layer width `n` (the in-place
    /// epilogues, unlike `ops::add_bias`, cannot detect a mismatch
    /// themselves).
    fn bias_of(&self, name: &str, n: usize) -> Result<&[f32]> {
        let b = self.store.get(name)?;
        if b.shape() != [n] {
            bail!("{name}: bias shape {:?} vs layer width {n}", b.shape());
        }
        Ok(b.data())
    }

    /// One conv layer into the pooled `out` buffer; code-domain when packed.
    fn conv_into(
        &self,
        xb: &[f32],
        dims: (usize, usize, usize, usize),
        name: &str,
        same: bool,
        scratch: &mut Scratch,
        out: &mut Vec<f32>,
    ) -> Result<(usize, usize, usize)> {
        self.observe(name, xb);
        if let Some(p) = self.csd_for(name) {
            let (oh, ow, oc) = if self.scalar {
                kernels::csd_conv_scalar_into(self.pool, xb, dims, p, same, scratch, out)?
            } else {
                kernels::csd_conv_into(self.pool, xb, dims, p, same, scratch, out)?
            };
            self.note_csd_energy(p, dims.0 * oh * ow);
            return Ok((oh, ow, oc));
        }
        if let Some(p) = self.packed_for(name) {
            return if self.scalar {
                kernels::qconv_scalar_into(self.pool, xb, dims, p, same, scratch, out)
            } else {
                kernels::qconv_into(self.pool, xb, dims, p, same, scratch, out)
            };
        }
        let wt = self.store.get(name)?;
        let ws = wt.shape();
        if ws.len() != 4 || ws[2] != dims.3 {
            bail!("{name}: conv weight must be [kh,kw,{},OC], got {:?}", dims.3, ws);
        }
        let (oh, ow) = kernels::fconv_into(
            self.pool,
            xb,
            dims,
            wt.data(),
            (ws[0], ws[1], ws[3]),
            same,
            scratch,
            out,
        )?;
        self.note_f32_energy(dims.0 * oh * ow * ws[0] * ws[1] * ws[2] * ws[3]);
        Ok((oh, ow, ws[3]))
    }

    /// One dense layer (`xb` is [m, K]) into the pooled `out` buffer;
    /// returns the layer width N.
    fn dense_into(
        &self,
        xb: &[f32],
        m: usize,
        name: &str,
        scratch: &mut Scratch,
        out: &mut Vec<f32>,
    ) -> Result<usize> {
        self.observe(name, xb);
        if let Some(p) = self.csd_for(name) {
            if xb.len() != m * p.k {
                bail!("{name}: dense input {} != {}x{}", xb.len(), m, p.k);
            }
            kernels::ensure_cap(out, m * p.oc, &mut scratch.stats);
            scratch.last.grow(0, 0, m * p.oc);
            let o = &mut out[..m * p.oc];
            o.fill(0.0);
            if self.scalar {
                kernels::csd_gemm_scalar_on(self.pool, o, xb, m, p);
            } else {
                kernels::csd_gemm_into_on(self.pool, o, xb, m, p);
            }
            self.note_csd_energy(p, m);
            return Ok(p.oc);
        }
        if let Some(p) = self.packed_for(name) {
            if xb.len() != m * p.k {
                bail!("{name}: dense input {} != {}x{}", xb.len(), m, p.k);
            }
            kernels::ensure_cap(out, m * p.oc, &mut scratch.stats);
            scratch.last.grow(0, 0, m * p.oc);
            let o = &mut out[..m * p.oc];
            o.fill(0.0);
            if self.scalar {
                kernels::qgemm2_scalar_on(self.pool, o, xb, m, p);
            } else {
                kernels::qgemm2_into_on(self.pool, o, xb, m, p);
            }
            return Ok(p.oc);
        }
        let wt = self.store.get(name)?;
        let ws = wt.shape();
        if ws.len() != 2 || xb.len() != m * ws[0] {
            bail!("{name}: dense input {} vs weight {:?}", xb.len(), ws);
        }
        let n = ws[1];
        kernels::ensure_cap(out, m * n, &mut scratch.stats);
        scratch.last.grow(0, 0, m * n);
        let o = &mut out[..m * n];
        o.fill(0.0);
        blocked::matmul_into_on(self.pool, o, xb, wt.data(), m, ws[0], n);
        self.note_f32_energy(m * ws[0] * n);
        Ok(n)
    }

    /// One conv layer of the integer datapath: raw-i16 activations `xq` (at
    /// the reciprocal scale `dequant_in`) through the packed layer's SWAR
    /// i16 kernel into the f32 accumulator `out`.  Only packed layers have
    /// an integer form — an uncalibratable f32 fallback layer is an error,
    /// not a silent domain switch.
    #[allow(clippy::too_many_arguments)] // mirrors conv_into + the dequant scale
    fn conv_i16_into(
        &self,
        xq: &[i16],
        dims: (usize, usize, usize, usize),
        name: &str,
        dequant_in: f32,
        same: bool,
        scratch: &mut Scratch,
        out: &mut Vec<f32>,
    ) -> Result<(usize, usize, usize)> {
        if let Some(p) = self.csd_for(name) {
            let (oh, ow, oc) = if self.scalar {
                kernels::csd_conv_i16_scalar_into(
                    self.pool, xq, dims, p, dequant_in, same, scratch, out,
                )?
            } else {
                kernels::csd_conv_i16_into(self.pool, xq, dims, p, dequant_in, same, scratch, out)?
            };
            let rows = dims.0 * oh * ow;
            self.note_csd_energy(p, rows);
            self.note_int_energy(0, rows * oc);
            return Ok((oh, ow, oc));
        }
        if let Some(p) = self.packed_for(name) {
            let (oh, ow, oc) = if self.scalar {
                kernels::qconv_i16_scalar_into(
                    self.pool, xq, dims, p, dequant_in, same, scratch, out,
                )?
            } else {
                kernels::qconv_i16_into(self.pool, xq, dims, p, dequant_in, same, scratch, out)?
            };
            let rows = dims.0 * oh * ow;
            self.note_int_energy(rows * p.k * p.oc, rows * oc);
            return Ok((oh, ow, oc));
        }
        bail!("{name}: the integer datapath requires a packed (code/CSD) layer")
    }

    /// One dense layer of the integer datapath (`xq` is raw-i16 `[m, K]`);
    /// returns the layer width N.  See [`FusedFwd::conv_i16_into`].
    fn dense_i16_into(
        &self,
        xq: &[i16],
        m: usize,
        name: &str,
        dequant_in: f32,
        scratch: &mut Scratch,
        out: &mut Vec<f32>,
    ) -> Result<usize> {
        if let Some(p) = self.csd_for(name) {
            if xq.len() != m * p.k {
                bail!("{name}: dense input {} != {}x{}", xq.len(), m, p.k);
            }
            kernels::ensure_cap(out, m * p.oc, &mut scratch.stats);
            scratch.last.grow(0, 0, m * p.oc);
            let o = &mut out[..m * p.oc];
            o.fill(0.0);
            if self.scalar {
                kernels::csd_gemm_i16_scalar_on(self.pool, o, xq, m, p, dequant_in);
            } else {
                kernels::csd_gemm_i16_into_on(self.pool, o, xq, m, p, dequant_in);
            }
            self.note_csd_energy(p, m);
            self.note_int_energy(0, m * p.oc);
            return Ok(p.oc);
        }
        if let Some(p) = self.packed_for(name) {
            if xq.len() != m * p.k {
                bail!("{name}: dense input {} != {}x{}", xq.len(), m, p.k);
            }
            kernels::ensure_cap(out, m * p.oc, &mut scratch.stats);
            scratch.last.grow(0, 0, m * p.oc);
            let o = &mut out[..m * p.oc];
            o.fill(0.0);
            if self.scalar {
                kernels::qgemm2_i16_scalar_on(self.pool, o, xq, m, p, dequant_in);
            } else {
                kernels::qgemm2_i16_into_on(self.pool, o, xq, m, p, dequant_in);
            }
            self.note_int_energy(m * p.k * p.oc, m * p.oc);
            return Ok(p.oc);
        }
        bail!("{name}: the integer datapath requires a packed (code/CSD) layer")
    }

    /// The calibrated input format of layer `name`, out of the plan.
    fn fmt_of(plan: &ActPlan, name: &str) -> Result<Format> {
        plan.format(name).with_context(|| format!("{name}: layer missing from the ActPlan"))
    }

    /// The pre-quantized bias of tensor `name`, validated against width `n`.
    fn bias_q_of<'p>(plan: &'p ActPlan, name: &str, n: usize) -> Result<&'p [i32]> {
        let bq = plan
            .bias_q(name)
            .with_context(|| format!("{name}: bias missing from the ActPlan"))?;
        if bq.len() != n {
            bail!("{name}: pre-quantized bias len {} vs layer width {n}", bq.len());
        }
        Ok(bq)
    }

    fn run(&self, x: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        let s = x.shape();
        let (want_hw, want_c) = match self.store.kind {
            ModelKind::Lenet => (28, 1),
            ModelKind::Convnet => (32, 3),
        };
        if s.len() != 4 || s[1] != want_hw || s[2] != want_hw || s[3] != want_c {
            bail!(
                "{:?} expects [B,{want_hw},{want_hw},{want_c}], got {s:?}",
                self.store.kind
            );
        }
        // activations ping-pong between two pooled buffers; they are moved
        // out of the arena for the duration of the pass (the arena is still
        // borrowed by every layer for patch/pad staging) and always put
        // back, error or not.  The integer datapath additionally ping-pongs
        // the i16 twins.
        let mut cur = std::mem::take(&mut scratch.act_a);
        let mut nxt = std::mem::take(&mut scratch.act_b);
        let mut qcur = std::mem::take(&mut scratch.qact_a);
        let mut qnxt = std::mem::take(&mut scratch.qact_b);
        let plan = self.acts.filter(|p| !p.is_empty());
        let out = match (self.store.kind, plan) {
            (ModelKind::Lenet, Some(p)) => {
                self.lenet_body_int(p, x, &mut cur, &mut nxt, &mut qcur, &mut qnxt, scratch)
            }
            (ModelKind::Convnet, Some(p)) => {
                self.convnet_body_int(p, x, &mut cur, &mut nxt, &mut qcur, &mut qnxt, scratch)
            }
            (ModelKind::Lenet, None) => self.lenet_body(x, &mut cur, &mut nxt, scratch),
            (ModelKind::Convnet, None) => self.convnet_body(x, &mut cur, &mut nxt, scratch),
        };
        scratch.act_a = cur;
        scratch.act_b = nxt;
        scratch.qact_a = qcur;
        scratch.qact_b = qnxt;
        out
    }

    fn lenet_body(
        &self,
        x: &Tensor,
        cur: &mut Vec<f32>,
        nxt: &mut Vec<f32>,
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let b = x.shape()[0];
        // c1 reads the request tensor directly; every later layer lives in
        // the ping/pong buffers
        let (oh, ow, oc) = self.conv_into(x.data(), (b, 28, 28, 1), "c1w", false, scratch, nxt)?;
        ops::bias_relu_inplace(&mut nxt[..b * oh * ow * oc], self.bias_of("c1b", oc)?);
        scratch.note_layer("c1w");
        let (mut dh, mut dw, mut dc) = (oh / 2, ow / 2, oc);
        kernels::ensure_cap(cur, b * dh * dw * dc, &mut scratch.stats);
        ops::maxpool2_into(&nxt[..b * oh * ow * oc], (b, oh, ow, oc), &mut cur[..b * dh * dw * dc]);

        let (oh, ow, oc) =
            self.conv_into(&cur[..b * dh * dw * dc], (b, dh, dw, dc), "c2w", false, scratch, nxt)?;
        ops::bias_relu_inplace(&mut nxt[..b * oh * ow * oc], self.bias_of("c2b", oc)?);
        scratch.note_layer("c2w");
        (dh, dw, dc) = (oh / 2, ow / 2, oc);
        kernels::ensure_cap(cur, b * dh * dw * dc, &mut scratch.stats);
        ops::maxpool2_into(&nxt[..b * oh * ow * oc], (b, oh, ow, oc), &mut cur[..b * dh * dw * dc]);

        // the NHWC activations are already row-major flat: [b, dh*dw*dc]
        let mut feat = dh * dw * dc;
        for (wname, bname) in [("f1w", "f1b"), ("f2w", "f2b")] {
            let n = self.dense_into(&cur[..b * feat], b, wname, scratch, nxt)?;
            ops::bias_relu_inplace(&mut nxt[..b * n], self.bias_of(bname, n)?);
            scratch.note_layer(wname);
            std::mem::swap(cur, nxt);
            feat = n;
        }
        let n = self.dense_into(&cur[..b * feat], b, "f3w", scratch, nxt)?;
        scratch.note_layer("f3w");
        let mut logits = nxt[..b * n].to_vec();
        ops::bias_inplace(&mut logits, self.bias_of("f3b", n)?);
        Tensor::new(vec![b, n], logits)
    }

    fn convnet_body(
        &self,
        x: &Tensor,
        cur: &mut Vec<f32>,
        nxt: &mut Vec<f32>,
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let b = x.shape()[0];
        let (mut dh, mut dw, mut dc) = (32usize, 32, 3);
        let mut first = true;
        for (kname, bname) in [("k1", "b1"), ("k2", "b2"), ("k3", "b3"), ("k4", "b4")] {
            let xin: &[f32] = if first { x.data() } else { &cur[..b * dh * dw * dc] };
            let (oh, ow, oc) = self.conv_into(xin, (b, dh, dw, dc), kname, true, scratch, nxt)?;
            ops::bias_relu_inplace(&mut nxt[..b * oh * ow * oc], self.bias_of(bname, oc)?);
            scratch.note_layer(kname);
            (dh, dw, dc) = (oh / 2, ow / 2, oc);
            kernels::ensure_cap(cur, b * dh * dw * dc, &mut scratch.stats);
            ops::maxpool2_into(
                &nxt[..b * oh * ow * oc],
                (b, oh, ow, oc),
                &mut cur[..b * dh * dw * dc],
            );
            first = false;
        }
        let feat = dh * dw * dc;
        let n = self.dense_into(&cur[..b * feat], b, "fcw", scratch, nxt)?;
        scratch.note_layer("fcw");
        let mut logits = nxt[..b * n].to_vec();
        ops::bias_inplace(&mut logits, self.bias_of("fcb", n)?);
        Tensor::new(vec![b, n], logits)
    }

    /// LeNet on the integer datapath: the request batch is quantized once at
    /// c1's calibrated format, then every quantized layer runs raw-i16 in →
    /// f32 accumulator → integer epilogue (pre-quantized bias + saturating
    /// ReLU, requantized straight into the *next* layer's format) → i16
    /// maxpool, ping-ponging the `qact` buffers.  The last quantized layer
    /// (f2) takes the f32 epilogue so the fp32 head sees float features, and
    /// the head emits f32 logits exactly like the float path.
    #[allow(clippy::too_many_arguments)] // two f32 + two i16 ping/pong buffers, by design
    fn lenet_body_int(
        &self,
        plan: &ActPlan,
        x: &Tensor,
        cur: &mut Vec<f32>,
        nxt: &mut Vec<f32>,
        qcur: &mut Vec<i16>,
        qnxt: &mut Vec<i16>,
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let b = x.shape()[0];
        // quantize the request batch into the i16 ping buffer, c1's format
        let fmt_c1 = Self::fmt_of(plan, "c1w")?;
        let n_in = b * 28 * 28;
        kernels::ensure_cap_i16(qcur, n_in, &mut scratch.stats);
        kernels::quantize_into(x.data(), fmt_c1, &mut qcur[..n_in]);

        // c1: integer conv → epilogue at c2's input format → i16 pool
        let dq = kernels::dequant_scale(fmt_c1);
        let (oh, ow, oc) =
            self.conv_i16_into(&qcur[..n_in], (b, 28, 28, 1), "c1w", dq, false, scratch, nxt)?;
        let fmt_c2 = Self::fmt_of(plan, "c2w")?;
        let n1 = b * oh * ow * oc;
        kernels::ensure_cap_i16(qnxt, n1, &mut scratch.stats);
        kernels::bias_relu_quantize_into(
            &nxt[..n1],
            Self::bias_q_of(plan, "c1b", oc)?,
            fmt_c2,
            &mut qnxt[..n1],
        );
        scratch.note_layer("c1w");
        let (mut dh, mut dw, mut dc) = (oh / 2, ow / 2, oc);
        kernels::ensure_cap_i16(qcur, b * dh * dw * dc, &mut scratch.stats);
        ops::maxpool2_i16_into(&qnxt[..n1], (b, oh, ow, oc), &mut qcur[..b * dh * dw * dc]);

        // c2: integer conv → epilogue at f1's input format → i16 pool
        let dq = kernels::dequant_scale(fmt_c2);
        let (oh, ow, oc) = self.conv_i16_into(
            &qcur[..b * dh * dw * dc],
            (b, dh, dw, dc),
            "c2w",
            dq,
            false,
            scratch,
            nxt,
        )?;
        let fmt_f1 = Self::fmt_of(plan, "f1w")?;
        let n2 = b * oh * ow * oc;
        kernels::ensure_cap_i16(qnxt, n2, &mut scratch.stats);
        kernels::bias_relu_quantize_into(
            &nxt[..n2],
            Self::bias_q_of(plan, "c2b", oc)?,
            fmt_f1,
            &mut qnxt[..n2],
        );
        scratch.note_layer("c2w");
        (dh, dw, dc) = (oh / 2, ow / 2, oc);
        kernels::ensure_cap_i16(qcur, b * dh * dw * dc, &mut scratch.stats);
        ops::maxpool2_i16_into(&qnxt[..n2], (b, oh, ow, oc), &mut qcur[..b * dh * dw * dc]);

        // f1: integer dense → epilogue at f2's input format
        let feat = dh * dw * dc;
        let fmt_f2 = Self::fmt_of(plan, "f2w")?;
        let dq = kernels::dequant_scale(fmt_f1);
        let n = self.dense_i16_into(&qcur[..b * feat], b, "f1w", dq, scratch, nxt)?;
        kernels::ensure_cap_i16(qnxt, b * n, &mut scratch.stats);
        kernels::bias_relu_quantize_into(
            &nxt[..b * n],
            Self::bias_q_of(plan, "f1b", n)?,
            fmt_f2,
            &mut qnxt[..b * n],
        );
        scratch.note_layer("f1w");
        std::mem::swap(qcur, qnxt);

        // f2: last integer layer — f32 epilogue feeds the fp32 head
        let dq = kernels::dequant_scale(fmt_f2);
        let n = self.dense_i16_into(&qcur[..b * n], b, "f2w", dq, scratch, nxt)?;
        ops::bias_relu_inplace(&mut nxt[..b * n], self.bias_of("f2b", n)?);
        scratch.note_layer("f2w");

        // fp32 head, same as the float path
        let width = self.dense_into(&nxt[..b * n], b, "f3w", scratch, cur)?;
        scratch.note_layer("f3w");
        let mut logits = cur[..b * width].to_vec();
        ops::bias_inplace(&mut logits, self.bias_of("f3b", width)?);
        Tensor::new(vec![b, width], logits)
    }

    /// ConvNet-4 on the integer datapath — same structure as
    /// [`FusedFwd::lenet_body_int`]: k1–k3 run fully integer epilogues, k4
    /// (the last quantized layer) takes the f32 epilogue and pool so the
    /// fp32 head sees float features.
    #[allow(clippy::too_many_arguments)] // two f32 + two i16 ping/pong buffers, by design
    fn convnet_body_int(
        &self,
        plan: &ActPlan,
        x: &Tensor,
        cur: &mut Vec<f32>,
        nxt: &mut Vec<f32>,
        qcur: &mut Vec<i16>,
        qnxt: &mut Vec<i16>,
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        let b = x.shape()[0];
        let layers = [("k1", "b1"), ("k2", "b2"), ("k3", "b3"), ("k4", "b4")];
        let (mut dh, mut dw, mut dc) = (32usize, 32, 3);
        let mut fmt_in = Self::fmt_of(plan, "k1")?;
        let n_in = b * dh * dw * dc;
        kernels::ensure_cap_i16(qcur, n_in, &mut scratch.stats);
        kernels::quantize_into(x.data(), fmt_in, &mut qcur[..n_in]);
        for (i, &(kname, bname)) in layers.iter().enumerate() {
            let xn = b * dh * dw * dc;
            let dq = kernels::dequant_scale(fmt_in);
            let (oh, ow, oc) =
                self.conv_i16_into(&qcur[..xn], (b, dh, dw, dc), kname, dq, true, scratch, nxt)?;
            let no = b * oh * ow * oc;
            (dh, dw, dc) = (oh / 2, ow / 2, oc);
            if i + 1 < layers.len() {
                let fmt_out = Self::fmt_of(plan, layers[i + 1].0)?;
                kernels::ensure_cap_i16(qnxt, no, &mut scratch.stats);
                kernels::bias_relu_quantize_into(
                    &nxt[..no],
                    Self::bias_q_of(plan, bname, oc)?,
                    fmt_out,
                    &mut qnxt[..no],
                );
                scratch.note_layer(kname);
                kernels::ensure_cap_i16(qcur, b * dh * dw * dc, &mut scratch.stats);
                ops::maxpool2_i16_into(&qnxt[..no], (b, oh, ow, oc), &mut qcur[..b * dh * dw * dc]);
                fmt_in = fmt_out;
            } else {
                // k4: f32 epilogue + f32 pool feed the fp32 head
                ops::bias_relu_inplace(&mut nxt[..no], self.bias_of(bname, oc)?);
                scratch.note_layer(kname);
                kernels::ensure_cap(cur, b * dh * dw * dc, &mut scratch.stats);
                ops::maxpool2_into(&nxt[..no], (b, oh, ow, oc), &mut cur[..b * dh * dw * dc]);
            }
        }
        let feat = dh * dw * dc;
        let n = self.dense_into(&cur[..b * feat], b, "fcw", scratch, nxt)?;
        scratch.note_layer("fcw");
        let mut logits = nxt[..b * n].to_vec();
        ops::bias_inplace(&mut logits, self.bias_of("fcb", n)?);
        Tensor::new(vec![b, n], logits)
    }
}

/// The code-domain serving engine: quantized tensors stay as plane-packed
/// codes and execute on [`kernels::qgemm2`] / [`kernels::qconv_into`];
/// everything else (biases, fp32 head) comes from the wrapped
/// [`WeightStore`] and runs on the blocked f32 GEMM.  The f32 forms of
/// packed tensors are dropped from the wrapped store, so quantized-layer
/// weights exist only as codes.
///
/// Like every serving engine it accumulates a lifetime energy [`Ledger`]
/// (here: the fp32 head/bias MACs — the code-domain layers spend adds the
/// ledger prices at zero) and a forwards counter, reported through the
/// uniform [`crate::runtime::engine::EngineReport`] schema.
#[derive(Debug)]
pub struct QuantizedEngine {
    store: WeightStore,
    packed: BTreeMap<String, PackedQTensorV2>,
    /// Accumulated energy over every forward (fp32 head/bias layers).
    ledger: Mutex<Ledger>,
    /// Forwards completed (one per batch).
    forwards: AtomicU64,
    /// The persistent worker pool every row-band kernel of this engine
    /// dispatches on — shared process-wide, so engines running concurrently
    /// split one warm worker set instead of spawning per matmul.
    pool: &'static Pool,
    /// The calibrated integer-activation plan ([`QuantizedEngine::calibrate`]).
    /// `None` until calibrated; once set, every forward runs the fixed-point
    /// i16 activation datapath.
    acts: Option<ActPlan>,
}

impl QuantizedEngine {
    /// Quantize the store's quantized tensors at (phi, N) and pack them.
    pub fn quantize_store(
        store: &WeightStore,
        quality: QualityConfig,
        mode: AssignMode,
    ) -> Result<QuantizedEngine> {
        let em = EncodedModel { tensors: quantize_tensors(store, quality, mode)? };
        QuantizedEngine::from_encoded(store, &em)
    }

    /// Build from codes that arrived over the channel (the edge side): the
    /// shipped [`EncodedModel`] supplies the quantized tensors, `store`
    /// supplies the fp32 head/biases.
    pub fn from_encoded(store: &WeightStore, em: &EncodedModel) -> Result<QuantizedEngine> {
        let mut packed = BTreeMap::new();
        for et in &em.tensors {
            store
                .meta
                .tensor(&et.name)
                .with_context(|| format!("encoded tensor {} not in model meta", et.name))?;
            packed.insert(et.name.clone(), PackedQTensorV2::pack(&et.tensor)?);
        }
        // drop the f32 forms the packed codes shadow — the fused pipeline
        // never reads them, so keeping them would double quantized-layer
        // memory
        let mut store = store.clone();
        for name in packed.keys() {
            store.remove(name);
        }
        Ok(QuantizedEngine {
            store,
            packed,
            ledger: Mutex::new(Ledger::new()),
            forwards: AtomicU64::new(0),
            pool: Pool::global(),
            acts: None,
        })
    }

    /// Calibrate the integer-activation datapath on a representative batch:
    /// one f32-activation forward over this engine's own packed layers with
    /// the range observer on, then freeze the observed per-layer ranges into
    /// an [`ActPlan`].  Every subsequent forward runs fixed-point.  The pass
    /// is deterministic (a pure fold over the activations) and does not
    /// count a forward or touch the energy ledger.
    pub fn calibrate(&mut self, batch: &Tensor) -> Result<()> {
        let ranges = Mutex::new(BTreeMap::new());
        FusedFwd {
            store: &self.store,
            packed: Some(&self.packed),
            csd: None,
            energy: None,
            pool: self.pool,
            scalar: false,
            acts: None,
            ranges: Some(&ranges),
        }
        .run(batch, &mut Scratch::new())?;
        self.acts = Some(build_act_plan(&self.store, &ranges.into_inner().unwrap())?);
        Ok(())
    }

    /// The calibrated activation plan (`None` before [`QuantizedEngine::calibrate`]).
    pub fn act_plan(&self) -> Option<&ActPlan> {
        self.acts.as_ref()
    }

    pub fn model(&self) -> ModelKind {
        self.store.kind
    }

    /// The worker pool this engine dispatches on (its `stats()` expose the
    /// spawn/wakeup counters; spawns stay flat across warm forwards).
    pub fn pool(&self) -> &'static Pool {
        self.pool
    }

    /// Snapshot of the accumulated energy ledger (fp32 head/bias MACs; the
    /// code-domain layers are adds-only and priced at zero here).
    pub fn ledger(&self) -> Ledger {
        self.ledger.lock().unwrap().clone()
    }

    /// Forwards completed since construction.
    pub fn forwards(&self) -> u64 {
        self.forwards.load(Ordering::Relaxed)
    }

    /// Fraction of packed codes the qgemm never touches (realized zero-skip).
    pub fn skipped_fraction(&self) -> f64 {
        let (mut total, mut skip) = (0u64, 0u64);
        for p in self.packed.values() {
            total += p.skip.total;
            skip += p.skip.skippable;
        }
        if total == 0 {
            0.0
        } else {
            skip as f64 / total as f64
        }
    }

    /// Forward one batch (one-shot scratch).
    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        self.forward_with(x, &mut Scratch::new())
    }

    /// Forward one batch, reusing `scratch` — the serving form: each layer
    /// dispatches to the plane-packed code-domain kernels or the f32 GEMM,
    /// and a warm arena allocates nothing per request.
    pub fn forward_with(&self, x: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        let out = FusedFwd {
            store: &self.store,
            packed: Some(&self.packed),
            csd: None,
            energy: Some(&self.ledger),
            pool: self.pool,
            scalar: false,
            acts: self.acts.as_ref(),
            ranges: None,
        }
        .run(x, scratch);
        if out.is_ok() {
            self.forwards.fetch_add(1, Ordering::Relaxed);
        }
        out
    }

    /// Forward one batch through the scalar plane-sum oracles — same packed
    /// planes, same banding, but every plane sum runs the single-accumulator
    /// reference loop instead of the lane-ized kernels.  A reference path:
    /// it neither counts toward [`QuantizedEngine::forwards`] nor touches the
    /// energy ledger, so differential harnesses can interleave it with
    /// serving traffic without perturbing the gauges.
    pub fn forward_scalar_reference(&self, x: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        FusedFwd {
            store: &self.store,
            packed: Some(&self.packed),
            csd: None,
            energy: None,
            pool: self.pool,
            scalar: true,
            acts: None,
            ranges: None,
        }
        .run(x, scratch)
    }

    /// Forward one batch through the *integer* datapath with every plane sum
    /// on the scalar oracle — the fixed-point twin of
    /// [`QuantizedEngine::forward_scalar_reference`], bitwise against the
    /// lane-ized integer serving path.  Errors if the engine has not been
    /// calibrated.  Does not count a forward or touch the energy ledger.
    pub fn forward_int_scalar_reference(&self, x: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        let plan =
            self.acts.as_ref().context("integer reference needs a calibrated engine (ActPlan)")?;
        FusedFwd {
            store: &self.store,
            packed: Some(&self.packed),
            csd: None,
            energy: None,
            pool: self.pool,
            scalar: true,
            acts: Some(plan),
            ranges: None,
        }
        .run(x, scratch)
    }
}

/// The CSD shift-and-add serving engine (paper §V.B on the serving path):
/// quantized-layer weights are truncated-CSD packed once
/// ([`kernels::PackedCsdTensor`]) and execute on the digit-plane
/// [`kernels::csd_gemm_into_on`] / [`kernels::csd_conv_into`] kernels with at
/// most [`CsdQuality::max_digits`] partial products per weight; biases and
/// the fp32 head come from the wrapped [`WeightStore`] on the blocked f32
/// GEMM.  The f32 forms of packed tensors are dropped from the wrapped
/// store, exactly like [`QuantizedEngine`].
///
/// Every forward folds its shift-and-add cost into a process-lifetime
/// [`Ledger`] (partial products summed, multiplier rows gated, MACs fully
/// skipped, fp32-head MACs) — [`CsdEngine::ledger`] snapshots it, and the
/// server exports via the `engine.host-csd.*` gauge family (see
/// `docs/METRICS.md`).
#[derive(Debug)]
pub struct CsdEngine {
    store: WeightStore,
    packed: BTreeMap<String, PackedCsdTensor>,
    quality: CsdQuality,
    /// Accumulated energy over every forward of this engine's lifetime.
    ledger: Mutex<Ledger>,
    /// Forwards completed (one per batch — the per-batch ledger divisor).
    forwards: AtomicU64,
    /// The persistent worker pool every row-band kernel dispatches on.
    pool: &'static Pool,
    /// The calibrated integer-activation plan ([`CsdEngine::calibrate`]).
    /// `None` until calibrated; once set, every forward runs the fixed-point
    /// i16 activation datapath.
    acts: Option<ActPlan>,
}

impl CsdEngine {
    /// Pack the store's quantized tensors at the CSD digit budget.  The
    /// store's f32 weights are the packing source, so stacking this on a
    /// QSQ-decoded edge store composes the two dials (phi/N, then digits).
    pub fn from_store(store: &WeightStore, quality: CsdQuality) -> Result<CsdEngine> {
        let mut packed = BTreeMap::new();
        for tm in store.meta.quantized_tensors() {
            let w = store.get(tm.name)?;
            packed.insert(
                tm.name.to_string(),
                PackedCsdTensor::pack(w.data(), &tm.shape, quality)?,
            );
        }
        // drop the f32 forms the packed digit planes shadow, exactly like
        // the code-domain engine
        let mut store = store.clone();
        for name in packed.keys() {
            store.remove(name);
        }
        Ok(CsdEngine {
            store,
            packed,
            quality,
            ledger: Mutex::new(Ledger::new()),
            forwards: AtomicU64::new(0),
            pool: Pool::global(),
            acts: None,
        })
    }

    /// Calibrate the integer-activation datapath on a representative batch —
    /// the CSD twin of [`QuantizedEngine::calibrate`]: one f32-activation
    /// forward over this engine's own digit planes with the range observer
    /// on, frozen into an [`ActPlan`].  Deterministic; counts no forward.
    pub fn calibrate(&mut self, batch: &Tensor) -> Result<()> {
        let ranges = Mutex::new(BTreeMap::new());
        FusedFwd {
            store: &self.store,
            packed: None,
            csd: Some(&self.packed),
            energy: None,
            pool: self.pool,
            scalar: false,
            acts: None,
            ranges: Some(&ranges),
        }
        .run(batch, &mut Scratch::new())?;
        self.acts = Some(build_act_plan(&self.store, &ranges.into_inner().unwrap())?);
        Ok(())
    }

    /// The calibrated activation plan (`None` before [`CsdEngine::calibrate`]).
    pub fn act_plan(&self) -> Option<&ActPlan> {
        self.acts.as_ref()
    }

    pub fn model(&self) -> ModelKind {
        self.store.kind
    }

    /// The digit dial this engine serves at.
    pub fn quality(&self) -> CsdQuality {
        self.quality
    }

    /// The worker pool this engine dispatches on.
    pub fn pool(&self) -> &'static Pool {
        self.pool
    }

    /// Aggregate digit statistics across every packed tensor of the engine.
    pub fn stats(&self) -> kernels::CsdStats {
        let mut agg = kernels::CsdStats::default();
        for p in self.packed.values() {
            agg.add(&p.stats);
        }
        agg
    }

    /// Mean kept partial products per MAC across the packed tensors — the
    /// realized energy side of the digit dial.
    pub fn mean_pp(&self) -> f64 {
        self.stats().mean_pp()
    }

    /// Fraction of MACs fully gated (no digits survive the budget).
    pub fn skipped_fraction(&self) -> f64 {
        self.stats().skipped_fraction()
    }

    /// Snapshot of the accumulated energy ledger.
    pub fn ledger(&self) -> Ledger {
        self.ledger.lock().unwrap().clone()
    }

    /// Forwards completed since construction.
    pub fn forwards(&self) -> u64 {
        self.forwards.load(Ordering::Relaxed)
    }

    /// Forward one batch (one-shot scratch).
    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        self.forward_with(x, &mut Scratch::new())
    }

    /// Forward one batch, reusing `scratch` — the serving form.  The
    /// request's shift-and-add cost lands in the engine ledger.
    pub fn forward_with(&self, x: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        let out = FusedFwd {
            store: &self.store,
            packed: None,
            csd: Some(&self.packed),
            energy: Some(&self.ledger),
            pool: self.pool,
            scalar: false,
            acts: self.acts.as_ref(),
            ranges: None,
        }
        .run(x, scratch);
        if out.is_ok() {
            self.forwards.fetch_add(1, Ordering::Relaxed);
        }
        out
    }

    /// Forward one batch through the scalar plane-sum oracles — same digit
    /// planes and banding, single-accumulator plane sums.  Does not count a
    /// forward or touch the energy ledger (see
    /// [`QuantizedEngine::forward_scalar_reference`]).
    pub fn forward_scalar_reference(&self, x: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        FusedFwd {
            store: &self.store,
            packed: None,
            csd: Some(&self.packed),
            energy: None,
            pool: self.pool,
            scalar: true,
            acts: None,
            ranges: None,
        }
        .run(x, scratch)
    }

    /// Forward one batch through the *integer* datapath with every plane sum
    /// on the scalar oracle — the fixed-point twin of
    /// [`CsdEngine::forward_scalar_reference`], bitwise against the lane-ized
    /// integer serving path.  Errors if the engine has not been calibrated.
    /// Does not count a forward or touch the energy ledger.
    pub fn forward_int_scalar_reference(&self, x: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        let plan =
            self.acts.as_ref().context("integer reference needs a calibrated engine (ActPlan)")?;
        FusedFwd {
            store: &self.store,
            packed: None,
            csd: Some(&self.packed),
            energy: None,
            pool: self.pool,
            scalar: true,
            acts: Some(plan),
            ranges: None,
        }
        .run(x, scratch)
    }
}

/// Batched accuracy over a dataset slice.
pub fn accuracy(
    store: &WeightStore,
    x: &Tensor,
    y: &[i32],
    batch: usize,
) -> Result<f64> {
    let n = x.shape()[0];
    if n != y.len() || n == 0 {
        bail!("dataset size mismatch");
    }
    let s = x.shape();
    let stride: usize = s[1..].iter().product();
    let mut scratch = Scratch::new();
    let mut hits = 0usize;
    let mut i = 0;
    while i < n {
        let b = batch.min(n - i);
        let xb = Tensor::new(
            vec![b, s[1], s[2], s[3]],
            x.data()[i * stride..(i + b) * stride].to_vec(),
        )?;
        let logits = forward_with(store, &xb, &mut scratch)?;
        for (j, &pred) in ops::argmax_rows(&logits).iter().enumerate() {
            if pred as i32 == y[i + j] {
                hits += 1;
            }
        }
        i += b;
    }
    Ok(hits as f64 / n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    // Full-weights tests live in tests/ (need artifacts); here: shape guards
    // plus fused-vs-per-op pipeline equality on random stores.

    #[test]
    fn rejects_wrong_input_shape() {
        let x = Tensor::zeros(vec![2, 28, 28, 1]);
        let y = vec![0i32; 3];
        // mismatched n vs y.len() must error before touching weights
        let meta_err = accuracy(&fake_store(), &x, &y, 2);
        assert!(meta_err.is_err());
    }

    fn fake_store() -> WeightStore {
        // minimal store with correct metadata but zero tensors of right shape
        let meta = crate::model::meta::ModelMeta::lenet();
        let mut s = WeightStore::empty(crate::model::meta::ModelKind::Lenet);
        for t in &meta.tensors {
            s.set_unchecked(t.name, Tensor::zeros(t.shape.clone()));
        }
        s
    }

    #[test]
    fn zero_weights_give_uniform_logits() {
        let store = fake_store();
        let x = Tensor::zeros(vec![1, 28, 28, 1]);
        let logits = forward(&store, &x).unwrap();
        assert_eq!(logits.shape(), &[1, 10]);
        assert!(logits.data().iter().all(|&v| v == 0.0));
    }

    use crate::data::synth_store as random_store;

    #[test]
    fn fused_f32_forward_matches_per_op_reference() {
        let kind = crate::model::meta::ModelKind::Lenet;
        let store = random_store(11, kind);
        let mut r = crate::util::rng::Rng::new(12);
        let xdata: Vec<f32> = (0..3 * 28 * 28).map(|_| r.f32()).collect();
        let x = Tensor::new(vec![3, 28, 28, 1], xdata).unwrap();
        let fused = forward(&store, &x).unwrap();
        let classic = lenet_fwd(&store, &x).unwrap();
        assert_eq!(fused.shape(), classic.shape());
        assert_eq!(fused.data(), classic.data(), "fused pipeline diverged from per-op path");
    }

    #[test]
    fn fused_f32_convnet_matches_per_op_reference() {
        let kind = crate::model::meta::ModelKind::Convnet;
        let store = random_store(13, kind);
        let mut r = crate::util::rng::Rng::new(14);
        let xdata: Vec<f32> = (0..2 * 32 * 32 * 3).map(|_| r.f32()).collect();
        let x = Tensor::new(vec![2, 32, 32, 3], xdata).unwrap();
        let fused = forward(&store, &x).unwrap();
        let classic = convnet_fwd(&store, &x).unwrap();
        assert_eq!(fused.data(), classic.data(), "fused convnet diverged from per-op path");
    }

    #[test]
    fn warm_scratch_stops_allocating() {
        let store = random_store(15, crate::model::meta::ModelKind::Lenet);
        let quality = QualityConfig { phi: 4, group: 16 };
        let engine =
            QuantizedEngine::quantize_store(&store, quality, AssignMode::SigmaSearch).unwrap();
        let mut r = crate::util::rng::Rng::new(16);
        let xdata: Vec<f32> = (0..4 * 28 * 28).map(|_| r.f32()).collect();
        let x = Tensor::new(vec![4, 28, 28, 1], xdata).unwrap();
        let mut scratch = Scratch::new();
        let first = engine.forward_with(&x, &mut scratch).unwrap();
        let cold_allocs = scratch.stats.allocs;
        for _ in 0..3 {
            let again = engine.forward_with(&x, &mut scratch).unwrap();
            assert_eq!(again.data(), first.data(), "warm pass changed the result");
        }
        assert_eq!(
            scratch.stats.allocs, cold_allocs,
            "warm requests must not allocate: {:?}",
            scratch.stats
        );
        assert!(scratch.stats.reuses > 0);
    }

    #[test]
    fn layer_peaks_recorded_per_layer() {
        let store = random_store(17, crate::model::meta::ModelKind::Lenet);
        let mut scratch = Scratch::new();
        let x = Tensor::zeros(vec![2, 28, 28, 1]);
        forward_with(&store, &x, &mut scratch).unwrap();
        let names: Vec<&str> = scratch.layer_peaks().iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["c1w", "c2w", "f1w", "f2w", "f3w"], "layers in execution order");
        for (n, pk) in scratch.layer_peaks() {
            assert!(pk.act_bytes > 0, "{n} must record activation bytes");
        }
        // conv layers stage patch slabs; LeNet convs are VALID, so no pad
        let c1 = scratch.layer_peaks()[0].1;
        assert!(c1.patch_bytes > 0);
        assert_eq!(c1.pad_bytes, 0);
        // a second, bigger batch raises the high-water marks monotonically
        let x2 = Tensor::zeros(vec![4, 28, 28, 1]);
        forward_with(&store, &x2, &mut scratch).unwrap();
        let c1b = scratch.layer_peaks()[0].1;
        assert!(c1b.act_bytes >= 2 * c1.act_bytes, "peaks track the larger batch");
    }

    #[test]
    fn convnet_same_layers_record_pad_staging() {
        let store = random_store(19, crate::model::meta::ModelKind::Convnet);
        let mut scratch = Scratch::new();
        let x = Tensor::zeros(vec![1, 32, 32, 3]);
        forward_with(&store, &x, &mut scratch).unwrap();
        let (name, k1) = &scratch.layer_peaks()[0];
        assert_eq!(name, "k1");
        assert!(k1.pad_bytes > 0, "SAME conv must record zero-pad staging");
    }

    #[test]
    fn quantized_engine_matches_decoded_store_forward() {
        let store = random_store(3, crate::model::meta::ModelKind::Lenet);
        let quality = QualityConfig { phi: 4, group: 16 };
        let engine =
            QuantizedEngine::quantize_store(&store, quality, AssignMode::SigmaSearch).unwrap();

        // reference: decode the same quantization into f32 weights, run the
        // plain f32 engine
        let mut decoded = store.clone();
        for tm in store.meta.quantized_tensors() {
            let g = Grouping::nearest_divisor(&tm.shape, quality.group).unwrap();
            let qt = quantize(store.get(tm.name).unwrap().data(), &tm.shape, g, 4,
                AssignMode::SigmaSearch)
            .unwrap();
            decoded
                .set(tm.name, Tensor::new(tm.shape.clone(), qt.decode()).unwrap())
                .unwrap();
        }

        let mut r = crate::util::rng::Rng::new(9);
        let xdata: Vec<f32> = (0..2 * 28 * 28).map(|_| r.f32()).collect();
        let x = Tensor::new(vec![2, 28, 28, 1], xdata).unwrap();
        let got = engine.forward(&x).unwrap();
        let want = forward(&decoded, &x).unwrap();
        assert_eq!(got.shape(), want.shape());
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-2, "qgemm engine vs decoded-store forward: {diff}");
        // same predictions
        assert_eq!(ops::argmax_rows(&got), ops::argmax_rows(&want));
        assert!(engine.skipped_fraction() > 0.0);
        assert_eq!(engine.model(), crate::model::meta::ModelKind::Lenet);
    }

    #[test]
    fn csd_engine_matches_decoded_store_forward_and_counts_energy() {
        let store = random_store(23, crate::model::meta::ModelKind::Lenet);
        let engine = CsdEngine::from_store(&store, CsdQuality::exact()).unwrap();

        // reference: replace each quantized tensor with the packed decode
        // (the exact value the shift-and-add datapath computes with), run
        // the plain f32 engine
        let mut decoded = store.clone();
        for tm in store.meta.quantized_tensors() {
            let p = kernels::PackedCsdTensor::pack(
                store.get(tm.name).unwrap().data(),
                &tm.shape,
                CsdQuality::exact(),
            )
            .unwrap();
            decoded
                .set(tm.name, Tensor::new(tm.shape.clone(), p.decode()).unwrap())
                .unwrap();
        }

        let mut r = crate::util::rng::Rng::new(24);
        let xdata: Vec<f32> = (0..2 * 28 * 28).map(|_| r.f32()).collect();
        let x = Tensor::new(vec![2, 28, 28, 1], xdata).unwrap();
        let got = engine.forward(&x).unwrap();
        let want = forward(&decoded, &x).unwrap();
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-2, "csd engine vs decoded-store forward: {diff}");
        assert_eq!(ops::argmax_rows(&got), ops::argmax_rows(&want));
        assert_eq!(engine.model(), crate::model::meta::ModelKind::Lenet);
        assert!(engine.mean_pp() > 0.0);

        // the ledger accumulates linearly with forwards: a second identical
        // batch exactly doubles every counter
        let l1 = engine.ledger();
        assert!(l1.partial_products > 0, "csd layers must spend partial products");
        assert!(l1.fp_muls > 0, "the fp32 head must be charged");
        assert!(l1.total_pj() > 0.0);
        assert_eq!(engine.forwards(), 1);
        engine.forward(&x).unwrap();
        let l2 = engine.ledger();
        assert_eq!(l2.partial_products, 2 * l1.partial_products);
        assert_eq!(l2.gated_rows, 2 * l1.gated_rows);
        assert_eq!(l2.fp_muls, 2 * l1.fp_muls);
        assert_eq!(engine.forwards(), 2);
    }

    #[test]
    fn csd_engine_digit_dial_bounds_pp_and_tracks_its_decode() {
        let store = random_store(25, crate::model::meta::ModelKind::Lenet);
        let mut r = crate::util::rng::Rng::new(26);
        let xdata: Vec<f32> = (0..2 * 28 * 28).map(|_| r.f32()).collect();
        let x = Tensor::new(vec![2, 28, 28, 1], xdata).unwrap();
        let mut last_pp = 0.0f64;
        for digits in [1usize, 2, 4] {
            let q = CsdQuality::new(digits);
            let engine = CsdEngine::from_store(&store, q).unwrap();
            // dialing digits down spends fewer partial products, never more
            // than the dial allows
            let pp = engine.mean_pp();
            assert!(pp >= last_pp, "digits={digits}: pp shrank with a larger budget");
            assert!(pp <= digits as f64 + 1e-12, "digits={digits}: pp exceeds the dial");
            last_pp = pp;
            // the truncated engine still computes exactly with its own
            // decode: the f32 engine over decoded weights agrees per-dial
            let mut decoded = store.clone();
            for tm in store.meta.quantized_tensors() {
                let p = kernels::PackedCsdTensor::pack(
                    store.get(tm.name).unwrap().data(),
                    &tm.shape,
                    q,
                )
                .unwrap();
                decoded
                    .set(tm.name, Tensor::new(tm.shape.clone(), p.decode()).unwrap())
                    .unwrap();
            }
            let got = engine.forward(&x).unwrap();
            let want = forward(&decoded, &x).unwrap();
            let diff = got.max_abs_diff(&want);
            assert!(diff < 1e-2, "digits={digits}: csd engine vs its decode: {diff}");
        }
    }

    #[test]
    fn csd_engine_warm_scratch_stops_allocating() {
        let store = random_store(27, crate::model::meta::ModelKind::Lenet);
        let engine = CsdEngine::from_store(&store, CsdQuality::new(3)).unwrap();
        let mut r = crate::util::rng::Rng::new(28);
        let xdata: Vec<f32> = (0..4 * 28 * 28).map(|_| r.f32()).collect();
        let x = Tensor::new(vec![4, 28, 28, 1], xdata).unwrap();
        let mut scratch = Scratch::new();
        let first = engine.forward_with(&x, &mut scratch).unwrap();
        let cold_allocs = scratch.stats.allocs;
        for _ in 0..3 {
            let again = engine.forward_with(&x, &mut scratch).unwrap();
            assert_eq!(again.data(), first.data(), "warm pass changed the result");
        }
        assert_eq!(
            scratch.stats.allocs, cold_allocs,
            "warm csd requests must not allocate: {:?}",
            scratch.stats
        );
    }

    #[test]
    fn quantized_convnet_engine_matches_decoded_store_forward() {
        let store = random_store(21, crate::model::meta::ModelKind::Convnet);
        let quality = QualityConfig { phi: 4, group: 16 };
        let engine =
            QuantizedEngine::quantize_store(&store, quality, AssignMode::SigmaSearch).unwrap();
        let mut decoded = store.clone();
        for tm in store.meta.quantized_tensors() {
            let g = Grouping::nearest_divisor(&tm.shape, quality.group).unwrap();
            let qt = quantize(store.get(tm.name).unwrap().data(), &tm.shape, g, 4,
                AssignMode::SigmaSearch)
            .unwrap();
            decoded
                .set(tm.name, Tensor::new(tm.shape.clone(), qt.decode()).unwrap())
                .unwrap();
        }
        let mut r = crate::util::rng::Rng::new(22);
        let xdata: Vec<f32> = (0..2 * 32 * 32 * 3).map(|_| r.f32()).collect();
        let x = Tensor::new(vec![2, 32, 32, 3], xdata).unwrap();
        let got = engine.forward(&x).unwrap();
        let want = forward(&decoded, &x).unwrap();
        let diff = got.max_abs_diff(&want);
        assert!(diff < 5e-2, "convnet engine vs decoded-store forward: {diff}");
        assert_eq!(ops::argmax_rows(&got), ops::argmax_rows(&want));
    }

    #[test]
    fn calibrated_quantized_engine_tracks_the_f32_path_and_flags_act_bits() {
        let store = random_store(31, crate::model::meta::ModelKind::Lenet);
        let quality = QualityConfig { phi: 4, group: 16 };
        let mut engine =
            QuantizedEngine::quantize_store(&store, quality, AssignMode::SigmaSearch).unwrap();
        let mut r = crate::util::rng::Rng::new(32);
        let xdata: Vec<f32> = (0..4 * 28 * 28).map(|_| r.f32()).collect();
        let x = Tensor::new(vec![4, 28, 28, 1], xdata).unwrap();

        // the f32 oracle of the very same packed layers, before calibration
        let mut scratch = Scratch::new();
        let f32_ref = engine.forward_scalar_reference(&x, &mut scratch).unwrap();
        assert!(
            engine.act_plan().is_none()
                && engine.forward_int_scalar_reference(&x, &mut scratch).is_err(),
            "the integer reference must demand a calibrated plan"
        );

        engine.calibrate(&x).unwrap();
        let plan = engine.act_plan().expect("calibrate sets the plan");
        assert_eq!(plan.formats().count(), 4, "all four quantized LeNet layers calibrated");
        assert_eq!(plan.act_bits(), 16);

        // integer serving stays close to the f32 oracle: same predictions,
        // only activation-quantization noise apart
        let got = engine.forward_with(&x, &mut scratch).unwrap();
        assert_eq!(got.shape(), f32_ref.shape());
        let diff = got.max_abs_diff(&f32_ref);
        assert!(diff < 5e-2, "integer datapath vs f32 oracle: {diff}");
        assert_eq!(ops::argmax_rows(&got), ops::argmax_rows(&f32_ref));
        // the lifetime ledger now carries the activation-width gauge and
        // the integer-layer adds
        let l = engine.ledger();
        assert_eq!(l.act_bits, 16, "a calibrated forward must raise the act_bits gauge");
        assert!(l.int_adds > 0, "integer layers must charge int adds");
    }

    #[test]
    fn integer_serving_is_bitwise_equal_to_its_scalar_reference_and_freezes() {
        let store = random_store(33, crate::model::meta::ModelKind::Lenet);
        let quality = QualityConfig { phi: 4, group: 16 };
        let mut engine =
            QuantizedEngine::quantize_store(&store, quality, AssignMode::SigmaSearch).unwrap();
        let mut r = crate::util::rng::Rng::new(34);
        let xdata: Vec<f32> = (0..4 * 28 * 28).map(|_| r.f32()).collect();
        let x = Tensor::new(vec![4, 28, 28, 1], xdata).unwrap();
        engine.calibrate(&x).unwrap();

        let mut scratch = Scratch::new();
        let first = engine.forward_with(&x, &mut scratch).unwrap();
        // integer plane sums are exact in any order, so the lane-ized
        // serving path and the scalar oracle agree bitwise
        let oracle = engine.forward_int_scalar_reference(&x, &mut scratch).unwrap();
        assert_eq!(first.data(), oracle.data(), "integer lane vs scalar oracle");
        // warm integer forwards allocate nothing: the i16 ping/pong twins
        // and the qpatches/qpadded arena pair are sized after pass one
        let cold_allocs = scratch.stats.allocs;
        for _ in 0..3 {
            let again = engine.forward_with(&x, &mut scratch).unwrap();
            assert_eq!(again.data(), first.data(), "warm integer pass changed the result");
        }
        assert_eq!(
            scratch.stats.allocs, cold_allocs,
            "warm integer requests must not allocate: {:?}",
            scratch.stats
        );
    }

    #[test]
    fn calibrated_csd_engine_tracks_the_f32_path() {
        let store = random_store(35, crate::model::meta::ModelKind::Lenet);
        let mut engine = CsdEngine::from_store(&store, CsdQuality::exact()).unwrap();
        let mut r = crate::util::rng::Rng::new(36);
        let xdata: Vec<f32> = (0..3 * 28 * 28).map(|_| r.f32()).collect();
        let x = Tensor::new(vec![3, 28, 28, 1], xdata).unwrap();
        let mut scratch = Scratch::new();
        let f32_ref = engine.forward_scalar_reference(&x, &mut scratch).unwrap();
        engine.calibrate(&x).unwrap();
        let got = engine.forward_with(&x, &mut scratch).unwrap();
        let diff = got.max_abs_diff(&f32_ref);
        assert!(diff < 5e-2, "csd integer datapath vs f32 oracle: {diff}");
        assert_eq!(ops::argmax_rows(&got), ops::argmax_rows(&f32_ref));
        let oracle = engine.forward_int_scalar_reference(&x, &mut scratch).unwrap();
        assert_eq!(got.data(), oracle.data(), "csd integer lane vs scalar oracle");
        assert_eq!(engine.ledger().act_bits, 16);
    }

    #[test]
    fn calibration_is_deterministic() {
        let store = random_store(37, crate::model::meta::ModelKind::Convnet);
        let quality = QualityConfig { phi: 4, group: 16 };
        let mut r = crate::util::rng::Rng::new(38);
        let xdata: Vec<f32> = (0..2 * 32 * 32 * 3).map(|_| r.f32()).collect();
        let x = Tensor::new(vec![2, 32, 32, 3], xdata).unwrap();
        let mut a =
            QuantizedEngine::quantize_store(&store, quality, AssignMode::SigmaSearch).unwrap();
        let mut b =
            QuantizedEngine::quantize_store(&store, quality, AssignMode::SigmaSearch).unwrap();
        a.calibrate(&x).unwrap();
        b.calibrate(&x).unwrap();
        // and recalibrating on the same batch cannot move the plan either
        let first = a.act_plan().unwrap().clone();
        a.calibrate(&x).unwrap();
        assert_eq!(a.act_plan().unwrap(), &first, "recalibration moved the plan");
        assert_eq!(a.act_plan().unwrap(), b.act_plan().unwrap(), "calibration must be a pure fold");
        let fa = a.forward(&x).unwrap();
        let fb = b.forward(&x).unwrap();
        assert_eq!(fa.data(), fb.data());
    }
}
