//! Pure-rust inference engines.
//!
//! Two engines live here, both mirroring the L2 model graphs exactly (same
//! im2col ordering, same layer stack):
//!
//! * the f32 path ([`forward`]) — runs every layer on the blocked/parallel
//!   GEMM ([`crate::kernels::blocked`] via `ops::matmul`).  It is the oracle
//!   the PJRT path is validated against and the fallback when `artifacts/`
//!   is absent.
//! * [`QuantizedEngine`] — the code-domain path: quantized layers execute on
//!   [`crate::kernels::qgemm`] straight from packed codes (zero-skip,
//!   shift/add, hoisted alpha), only the fp32 head and biases touch the f32
//!   GEMM.  This is what the edge side actually serves with.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::codec::{EncodedModel, EncodedTensor};
use crate::device::QualityConfig;
use crate::kernels::{self, PackedQTensor};
use crate::model::meta::ModelKind;
use crate::model::store::WeightStore;
use crate::quant::qsq::{quantize, AssignMode};
use crate::quant::vectorize::Grouping;
use crate::tensor::{ops, Tensor};

/// Forward one batch through the model, host-side.
pub fn forward(store: &WeightStore, x: &Tensor) -> Result<Tensor> {
    match store.kind {
        ModelKind::Lenet => lenet_fwd(store, x),
        ModelKind::Convnet => convnet_fwd(store, x),
    }
}

/// LeNet-5: x [B,28,28,1] -> logits [B,10].
pub fn lenet_fwd(store: &WeightStore, x: &Tensor) -> Result<Tensor> {
    let feat = lenet_features(store, x)?;
    let h = ops::add_bias(&ops::matmul(&feat, store.get("f3w")?)?, store.get("f3b")?)?;
    Ok(h)
}

/// LeNet backbone up to the 84-d features (input of the fp32 head).
pub fn lenet_features(store: &WeightStore, x: &Tensor) -> Result<Tensor> {
    if x.shape().len() != 4 || x.shape()[1] != 28 {
        bail!("lenet expects [B,28,28,1], got {:?}", x.shape());
    }
    let b = x.shape()[0];
    let h = ops::add_bias(&ops::conv2d(x, store.get("c1w")?)?, store.get("c1b")?)?.relu();
    let h = ops::maxpool2(&h)?;
    let h = ops::add_bias(&ops::conv2d(&h, store.get("c2w")?)?, store.get("c2b")?)?.relu();
    let h = ops::maxpool2(&h)?;
    let h = h.reshape(vec![b, 256])?;
    let h = ops::add_bias(&ops::matmul(&h, store.get("f1w")?)?, store.get("f1b")?)?.relu();
    let h = ops::add_bias(&ops::matmul(&h, store.get("f2w")?)?, store.get("f2b")?)?.relu();
    Ok(h)
}

/// ConvNet-4: x [B,32,32,3] -> logits [B,10].
pub fn convnet_fwd(store: &WeightStore, x: &Tensor) -> Result<Tensor> {
    if x.shape().len() != 4 || x.shape()[1] != 32 {
        bail!("convnet expects [B,32,32,3], got {:?}", x.shape());
    }
    let b = x.shape()[0];
    let mut h = x.clone();
    for (kw, bw) in [("k1", "b1"), ("k2", "b2"), ("k3", "b3"), ("k4", "b4")] {
        h = ops::add_bias(&ops::conv2d_same(&h, store.get(kw)?)?, store.get(bw)?)?.relu();
        h = ops::maxpool2(&h)?;
    }
    let h = h.reshape(vec![b, 256])?;
    ops::add_bias(&ops::matmul(&h, store.get("fcw")?)?, store.get("fcb")?)
}

/// Quantize every quantized tensor of a store at (phi, N) — the one
/// canonical policy (per-tensor nearest-divisor grouping) shared by the
/// deploy pipeline's `encode_store` and the serving engine.
pub fn quantize_tensors(
    store: &WeightStore,
    quality: QualityConfig,
    mode: AssignMode,
) -> Result<Vec<EncodedTensor>> {
    let mut tensors = Vec::new();
    for tm in store.meta.quantized_tensors() {
        let w = store.get(tm.name)?;
        let group = Grouping::nearest_divisor(&tm.shape, quality.group)?;
        let qt = quantize(w.data(), &tm.shape, group, quality.phi, mode)?;
        tensors.push(EncodedTensor { name: tm.name.to_string(), tensor: qt });
    }
    Ok(tensors)
}

/// The code-domain serving engine: quantized tensors stay as packed codes
/// and execute on [`kernels::qgemm`]; everything else (biases, fp32 head)
/// comes from the wrapped [`WeightStore`] and runs on the blocked f32 GEMM.
/// The f32 forms of packed tensors are dropped from the wrapped store, so
/// quantized-layer weights exist only as codes.
#[derive(Clone, Debug)]
pub struct QuantizedEngine {
    store: WeightStore,
    packed: BTreeMap<String, PackedQTensor>,
}

impl QuantizedEngine {
    /// Quantize the store's quantized tensors at (phi, N) and pack them.
    pub fn quantize_store(
        store: &WeightStore,
        quality: QualityConfig,
        mode: AssignMode,
    ) -> Result<QuantizedEngine> {
        let em = EncodedModel { tensors: quantize_tensors(store, quality, mode)? };
        QuantizedEngine::from_encoded(store, &em)
    }

    /// Build from codes that arrived over the channel (the edge side): the
    /// shipped [`EncodedModel`] supplies the quantized tensors, `store`
    /// supplies the fp32 head/biases.
    pub fn from_encoded(store: &WeightStore, em: &EncodedModel) -> Result<QuantizedEngine> {
        let mut packed = BTreeMap::new();
        for et in &em.tensors {
            store
                .meta
                .tensor(&et.name)
                .with_context(|| format!("encoded tensor {} not in model meta", et.name))?;
            packed.insert(et.name.clone(), PackedQTensor::pack(&et.tensor)?);
        }
        // drop the f32 forms the packed codes shadow — dense()/conv() never
        // read them, so keeping them would double quantized-layer memory
        let mut store = store.clone();
        for name in packed.keys() {
            store.remove(name);
        }
        Ok(QuantizedEngine { store, packed })
    }

    pub fn kind(&self) -> ModelKind {
        self.store.kind
    }

    /// Fraction of packed codes the qgemm never touches (realized zero-skip).
    pub fn skipped_fraction(&self) -> f64 {
        let (mut total, mut skip) = (0u64, 0u64);
        for p in self.packed.values() {
            total += p.skip.total;
            skip += p.skip.skippable;
        }
        if total == 0 {
            0.0
        } else {
            skip as f64 / total as f64
        }
    }

    /// Forward one batch, dispatching each layer to qgemm or the f32 GEMM.
    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        match self.store.kind {
            ModelKind::Lenet => self.lenet(x),
            ModelKind::Convnet => self.convnet(x),
        }
    }

    fn dense(&self, x: &Tensor, name: &str) -> Result<Tensor> {
        match self.packed.get(name) {
            Some(p) => kernels::qgemm(x, p),
            None => ops::matmul(x, self.store.get(name)?),
        }
    }

    fn conv(&self, x: &Tensor, name: &str, same: bool) -> Result<Tensor> {
        let Some(p) = self.packed.get(name) else {
            let w = self.store.get(name)?;
            return if same { ops::conv2d_same(x, w) } else { ops::conv2d(x, w) };
        };
        if p.shape.len() != 4 {
            bail!("{name}: packed conv weight must be [kh,kw,C,OC], got {:?}", p.shape);
        }
        let (kh, kw, oc) = (p.shape[0], p.shape[1], p.shape[3]);
        let padded;
        let xin = if same {
            padded = ops::pad_hw(x, kh / 2)?;
            &padded
        } else {
            x
        };
        let (patches, oh, ow) = ops::im2col(xin, kh, kw)?;
        let out = kernels::qgemm(&patches, p)?;
        out.reshape(vec![xin.shape()[0], oh, ow, oc])
    }

    fn lenet(&self, x: &Tensor) -> Result<Tensor> {
        if x.shape().len() != 4 || x.shape()[1] != 28 {
            bail!("lenet expects [B,28,28,1], got {:?}", x.shape());
        }
        let b = x.shape()[0];
        let h = ops::add_bias(&self.conv(x, "c1w", false)?, self.store.get("c1b")?)?.relu();
        let h = ops::maxpool2(&h)?;
        let h = ops::add_bias(&self.conv(&h, "c2w", false)?, self.store.get("c2b")?)?.relu();
        let h = ops::maxpool2(&h)?;
        let h = h.reshape(vec![b, 256])?;
        let h = ops::add_bias(&self.dense(&h, "f1w")?, self.store.get("f1b")?)?.relu();
        let h = ops::add_bias(&self.dense(&h, "f2w")?, self.store.get("f2b")?)?.relu();
        ops::add_bias(&self.dense(&h, "f3w")?, self.store.get("f3b")?)
    }

    fn convnet(&self, x: &Tensor) -> Result<Tensor> {
        if x.shape().len() != 4 || x.shape()[1] != 32 {
            bail!("convnet expects [B,32,32,3], got {:?}", x.shape());
        }
        let b = x.shape()[0];
        let mut h = x.clone();
        for (kw, bw) in [("k1", "b1"), ("k2", "b2"), ("k3", "b3"), ("k4", "b4")] {
            h = ops::add_bias(&self.conv(&h, kw, true)?, self.store.get(bw)?)?.relu();
            h = ops::maxpool2(&h)?;
        }
        let h = h.reshape(vec![b, 256])?;
        ops::add_bias(&self.dense(&h, "fcw")?, self.store.get("fcb")?)
    }
}

/// Batched accuracy over a dataset slice.
pub fn accuracy(
    store: &WeightStore,
    x: &Tensor,
    y: &[i32],
    batch: usize,
) -> Result<f64> {
    let n = x.shape()[0];
    if n != y.len() || n == 0 {
        bail!("dataset size mismatch");
    }
    let s = x.shape();
    let stride: usize = s[1..].iter().product();
    let mut hits = 0usize;
    let mut i = 0;
    while i < n {
        let b = batch.min(n - i);
        let xb = Tensor::new(
            vec![b, s[1], s[2], s[3]],
            x.data()[i * stride..(i + b) * stride].to_vec(),
        )?;
        let logits = forward(store, &xb)?;
        for (j, &pred) in ops::argmax_rows(&logits).iter().enumerate() {
            if pred as i32 == y[i + j] {
                hits += 1;
            }
        }
        i += b;
    }
    Ok(hits as f64 / n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    // Full-weights tests live in tests/ (need artifacts); here: shape guards.

    #[test]
    fn rejects_wrong_input_shape() {
        // A store can't be constructed without artifacts, so just check the
        // shape guard logic via the public error path using a fake store is
        // impossible — covered by integration tests. Here we only pin the
        // accuracy() precondition.
        let x = Tensor::zeros(vec![2, 28, 28, 1]);
        let y = vec![0i32; 3];
        // mismatched n vs y.len() must error before touching weights
        let meta_err = accuracy(
            // SAFETY: never dereferenced — constructed store is required, so
            // we validate only via the public API in integration tests.
            // This test just documents the contract.
            &fake_store(),
            &x,
            &y,
            2,
        );
        assert!(meta_err.is_err());
    }

    fn fake_store() -> WeightStore {
        // minimal store with correct metadata but zero tensors of right shape
        let meta = crate::model::meta::ModelMeta::lenet();
        let mut s = WeightStore::empty(crate::model::meta::ModelKind::Lenet);
        for t in &meta.tensors {
            s.set_unchecked(t.name, Tensor::zeros(t.shape.clone()));
        }
        s
    }

    #[test]
    fn zero_weights_give_uniform_logits() {
        let store = fake_store();
        let x = Tensor::zeros(vec![1, 28, 28, 1]);
        let logits = forward(&store, &x).unwrap();
        assert_eq!(logits.shape(), &[1, 10]);
        assert!(logits.data().iter().all(|&v| v == 0.0));
    }

    fn random_store(seed: u64) -> WeightStore {
        let mut r = crate::util::rng::Rng::new(seed);
        let meta = crate::model::meta::ModelMeta::lenet();
        let mut s = WeightStore::empty(crate::model::meta::ModelKind::Lenet);
        for t in &meta.tensors {
            let data: Vec<f32> = (0..t.numel()).map(|_| (r.normal() * 0.1) as f32).collect();
            s.set_unchecked(t.name, Tensor::new(t.shape.clone(), data).unwrap());
        }
        s
    }

    #[test]
    fn quantized_engine_matches_decoded_store_forward() {
        let store = random_store(3);
        let quality = QualityConfig { phi: 4, group: 16 };
        let engine =
            QuantizedEngine::quantize_store(&store, quality, AssignMode::SigmaSearch).unwrap();

        // reference: decode the same quantization into f32 weights, run the
        // plain f32 engine
        let mut decoded = store.clone();
        for tm in store.meta.quantized_tensors() {
            let g = Grouping::nearest_divisor(&tm.shape, quality.group).unwrap();
            let qt = quantize(store.get(tm.name).unwrap().data(), &tm.shape, g, 4,
                AssignMode::SigmaSearch)
            .unwrap();
            decoded
                .set(tm.name, Tensor::new(tm.shape.clone(), qt.decode()).unwrap())
                .unwrap();
        }

        let mut r = crate::util::rng::Rng::new(9);
        let xdata: Vec<f32> = (0..2 * 28 * 28).map(|_| r.f32()).collect();
        let x = Tensor::new(vec![2, 28, 28, 1], xdata).unwrap();
        let got = engine.forward(&x).unwrap();
        let want = forward(&decoded, &x).unwrap();
        assert_eq!(got.shape(), want.shape());
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-2, "qgemm engine vs decoded-store forward: {diff}");
        // same predictions
        assert_eq!(ops::argmax_rows(&got), ops::argmax_rows(&want));
        assert!(engine.skipped_fraction() > 0.0);
        assert_eq!(engine.kind(), crate::model::meta::ModelKind::Lenet);
    }
}
