//! Pure-rust fallback inference engine.
//!
//! Mirrors the L2 model graphs exactly (same im2col ordering, same layer
//! stack), so it serves three roles:
//!   1. independent oracle the PJRT path is validated against,
//!   2. fallback when `artifacts/` is absent (e.g. unit-test environments),
//!   3. the "device simulator" arm of the energy accounting (it can run with
//!      the QSM multiplier model to produce bit-accurate energy ledgers).

use anyhow::{bail, Result};

use crate::model::meta::ModelKind;
use crate::model::store::WeightStore;
use crate::tensor::{ops, Tensor};

/// Forward one batch through the model, host-side.
pub fn forward(store: &WeightStore, x: &Tensor) -> Result<Tensor> {
    match store.kind {
        ModelKind::Lenet => lenet_fwd(store, x),
        ModelKind::Convnet => convnet_fwd(store, x),
    }
}

/// LeNet-5: x [B,28,28,1] -> logits [B,10].
pub fn lenet_fwd(store: &WeightStore, x: &Tensor) -> Result<Tensor> {
    let feat = lenet_features(store, x)?;
    let h = ops::add_bias(&ops::matmul(&feat, store.get("f3w")?)?, store.get("f3b")?)?;
    Ok(h)
}

/// LeNet backbone up to the 84-d features (input of the fp32 head).
pub fn lenet_features(store: &WeightStore, x: &Tensor) -> Result<Tensor> {
    if x.shape().len() != 4 || x.shape()[1] != 28 {
        bail!("lenet expects [B,28,28,1], got {:?}", x.shape());
    }
    let b = x.shape()[0];
    let h = ops::add_bias(&ops::conv2d(x, store.get("c1w")?)?, store.get("c1b")?)?.relu();
    let h = ops::maxpool2(&h)?;
    let h = ops::add_bias(&ops::conv2d(&h, store.get("c2w")?)?, store.get("c2b")?)?.relu();
    let h = ops::maxpool2(&h)?;
    let h = h.reshape(vec![b, 256])?;
    let h = ops::add_bias(&ops::matmul(&h, store.get("f1w")?)?, store.get("f1b")?)?.relu();
    let h = ops::add_bias(&ops::matmul(&h, store.get("f2w")?)?, store.get("f2b")?)?.relu();
    Ok(h)
}

/// ConvNet-4: x [B,32,32,3] -> logits [B,10].
pub fn convnet_fwd(store: &WeightStore, x: &Tensor) -> Result<Tensor> {
    if x.shape().len() != 4 || x.shape()[1] != 32 {
        bail!("convnet expects [B,32,32,3], got {:?}", x.shape());
    }
    let b = x.shape()[0];
    let mut h = x.clone();
    for (kw, bw) in [("k1", "b1"), ("k2", "b2"), ("k3", "b3"), ("k4", "b4")] {
        h = ops::add_bias(&ops::conv2d_same(&h, store.get(kw)?)?, store.get(bw)?)?.relu();
        h = ops::maxpool2(&h)?;
    }
    let h = h.reshape(vec![b, 256])?;
    ops::add_bias(&ops::matmul(&h, store.get("fcw")?)?, store.get("fcb")?)
}

/// Batched accuracy over a dataset slice.
pub fn accuracy(
    store: &WeightStore,
    x: &Tensor,
    y: &[i32],
    batch: usize,
) -> Result<f64> {
    let n = x.shape()[0];
    if n != y.len() || n == 0 {
        bail!("dataset size mismatch");
    }
    let s = x.shape();
    let stride: usize = s[1..].iter().product();
    let mut hits = 0usize;
    let mut i = 0;
    while i < n {
        let b = batch.min(n - i);
        let xb = Tensor::new(
            vec![b, s[1], s[2], s[3]],
            x.data()[i * stride..(i + b) * stride].to_vec(),
        )?;
        let logits = forward(store, &xb)?;
        for (j, &pred) in ops::argmax_rows(&logits).iter().enumerate() {
            if pred as i32 == y[i + j] {
                hits += 1;
            }
        }
        i += b;
    }
    Ok(hits as f64 / n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    // Full-weights tests live in tests/ (need artifacts); here: shape guards.

    #[test]
    fn rejects_wrong_input_shape() {
        // A store can't be constructed without artifacts, so just check the
        // shape guard logic via the public error path using a fake store is
        // impossible — covered by integration tests. Here we only pin the
        // accuracy() precondition.
        let x = Tensor::zeros(vec![2, 28, 28, 1]);
        let y = vec![0i32; 3];
        // mismatched n vs y.len() must error before touching weights
        let meta_err = accuracy(
            // SAFETY: never dereferenced — constructed store is required, so
            // we validate only via the public API in integration tests.
            // This test just documents the contract.
            &fake_store(),
            &x,
            &y,
            2,
        );
        assert!(meta_err.is_err());
    }

    fn fake_store() -> WeightStore {
        // minimal store with correct metadata but zero tensors of right shape
        let meta = crate::model::meta::ModelMeta::lenet();
        let mut s = WeightStore::empty(crate::model::meta::ModelKind::Lenet);
        for t in &meta.tensors {
            s.set_unchecked(t.name, Tensor::zeros(t.shape.clone()));
        }
        s
    }

    #[test]
    fn zero_weights_give_uniform_logits() {
        let store = fake_store();
        let x = Tensor::zeros(vec![1, 28, 28, 1]);
        let logits = forward(&store, &x).unwrap();
        assert_eq!(logits.shape(), &[1, 10]);
        assert!(logits.data().iter().all(|&v| v == 0.0));
    }
}
