//! The unified serving-engine abstraction: one [`Engine`] trait over every
//! inference path, one [`EngineReport`] telemetry schema, and the pluggable
//! [`DispatchPolicy`] the server routes batches with.
//!
//! Before this module each engine was a bespoke special case: the server's
//! backend was a five-variant enum with per-variant match arms, the
//! batch-aware `Auto` hybrid could not reach the CSD engine at all, and
//! every engine exported its own ad-hoc metrics (`skipped_fraction` here, an
//! energy ledger there).  Now every engine — the fused f32 host path
//! ([`F32Engine`]), the code-domain [`QuantizedEngine`], the truncated-CSD
//! [`CsdEngine`], and the PJRT artifact wrapper ([`PjrtEngine`]) — is a
//! first-class `Engine`:
//!
//! * [`Engine::forward_with`] — one batch through the engine, reusing the
//!   worker's [`Scratch`] arena (engines that stage nothing, like PJRT,
//!   simply ignore it);
//! * [`Engine::kind`] / [`Engine::name`] — the stable identity dispatch
//!   policies and metrics key off;
//! * [`Engine::report`] — the uniform [`EngineReport`]: forwards served,
//!   realized zero-skip, mean partial products per MAC, the accumulated
//!   energy [`Ledger`], and the worker-pool counters.  The server exports it
//!   as the `engine.<name>.*` gauge family (see `docs/METRICS.md`), the same
//!   schema for every engine.
//!
//! A [`DispatchPolicy`] then routes each popped batch over a roster of boxed
//! engines (`coordinator::server::Roster`): [`BatchFillPolicy`] is the
//! classic quarter-full artifact crossover, [`LatencyFloorPolicy`] keeps
//! every partial batch off the padded artifact, and [`EnergyBudgetPolicy`]
//! sends the smallest batches to the shift-and-add CSD engine — the
//! minimum-energy path that was previously unreachable from `Auto`.
//! Policies are selected with `--policy` on the CLI ([`PolicySelect`]).

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::hw::energy::Ledger;
use crate::kernels::{PoolStats, Scratch, ScratchStats};
use crate::model::meta::{ModelKind, ModelMeta};
use crate::model::store::WeightStore;
use crate::runtime::client::{ArgValue, Executable, Runtime};
use crate::runtime::host::{CsdEngine, F32Engine, QuantizedEngine};
use crate::tensor::Tensor;

/// Which compute path an engine runs — the identity dispatch policies route
/// on and metrics are keyed by.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum EngineKind {
    /// Fused f32 host path on the blocked/microtiled GEMM.
    F32,
    /// Code-domain engine: plane-packed codes on qgemm v2.
    Quantized,
    /// Truncated-CSD shift-and-add engine (`kernels::csd`).
    Csd,
    /// Compiled PJRT artifact, padded to its compiled batch size.
    Pjrt,
}

impl EngineKind {
    /// Stable engine name — the `<name>` of the `engine.<name>.*` gauge
    /// family and the `dispatch_*` counters.
    pub fn name(self) -> &'static str {
        match self {
            EngineKind::F32 => "host-f32",
            EngineKind::Quantized => "host-qgemm",
            EngineKind::Csd => "host-csd",
            EngineKind::Pjrt => "pjrt",
        }
    }
}

/// The uniform telemetry snapshot every [`Engine`] produces — one schema for
/// what used to be per-engine ad-hoc counters.  Fields an engine has nothing
/// to say about stay at their zero values (e.g. `mean_pp` for the f32 path),
/// so consumers can always read the full family.
#[derive(Clone, Debug)]
pub struct EngineReport {
    pub kind: EngineKind,
    /// [`EngineKind::name`] of `kind` (denormalized for exporters).
    pub name: &'static str,
    /// Forwards completed over the engine's lifetime (one per batch).
    pub forwards: u64,
    /// Fraction of MACs the packed form skips outright (zero codes for the
    /// code-domain engine, fully gated weights for CSD; 0 for f32/PJRT).
    pub skipped_fraction: f64,
    /// Mean kept partial products per MAC (CSD digit dial; 0 elsewhere).
    pub mean_pp: f64,
    /// Accumulated energy over every forward ([`Ledger`]); for PJRT an
    /// estimate from the model's MACs at the padded batch size.
    pub ledger: Ledger,
    /// Worker-pool counters, when the engine dispatches on the shared pool.
    pub pool: Option<PoolStats>,
}

impl EngineReport {
    /// An all-zero report for `kind` — engines fill in what they track.
    pub fn new(kind: EngineKind) -> EngineReport {
        EngineReport {
            kind,
            name: kind.name(),
            forwards: 0,
            skipped_fraction: 0.0,
            mean_pp: 0.0,
            ledger: Ledger::new(),
            pool: None,
        }
    }

    /// Emit the report as the uniform `engine.<name>.*` gauge family (the
    /// schema `docs/METRICS.md` documents).  `set` receives (key, value)
    /// pairs — the server hands it `Metrics::set_gauge`.
    pub fn export(&self, mut set: impl FnMut(&str, f64)) {
        let p = format!("engine.{}", self.name);
        set(&format!("{p}.forwards"), self.forwards as f64);
        set(&format!("{p}.skipped_fraction"), self.skipped_fraction);
        set(&format!("{p}.mean_pp"), self.mean_pp);
        set(&format!("{p}.energy.partial_products"), self.ledger.partial_products as f64);
        set(&format!("{p}.energy.gated_rows"), self.ledger.gated_rows as f64);
        set(&format!("{p}.energy.skipped_macs"), self.ledger.skipped_macs as f64);
        set(&format!("{p}.energy.fp_muls"), self.ledger.fp_muls as f64);
        set(&format!("{p}.energy.fp_adds"), self.ledger.fp_adds as f64);
        set(&format!("{p}.energy.int_adds"), self.ledger.int_adds as f64);
        set(&format!("{p}.energy.act_bits"), self.ledger.act_bits as f64);
        set(&format!("{p}.energy.compute_pj"), self.ledger.compute_pj());
        set(&format!("{p}.energy.total_pj"), self.ledger.total_pj());
        if let Some(ps) = self.pool {
            set(&format!("{p}.pool.spawns"), ps.spawns as f64);
            set(&format!("{p}.pool.wakeups"), ps.wakeups as f64);
            set(&format!("{p}.pool.jobs"), ps.jobs as f64);
            set(&format!("{p}.pool.pin_hits"), ps.pin_hits as f64);
            set(&format!("{p}.pool.pin_misses"), ps.pin_misses as f64);
        }
    }
}

/// One inference engine on the serving path.  Implemented by the fused f32
/// host path, the code-domain and CSD engines, and the PJRT artifact
/// wrapper; the server holds them as `Box<dyn Engine + Send + Sync>` in a
/// shared roster drained by replicated inference workers, and
/// routes batches with a [`DispatchPolicy`].
pub trait Engine {
    /// Forward one batch, reusing the worker's scratch arena (engines with
    /// no host staging ignore it).  Implementations count the forward in
    /// their lifetime telemetry on success.
    fn forward_with(&self, x: &Tensor, scratch: &mut Scratch) -> Result<Tensor>;

    /// The compute path this engine runs.
    fn kind(&self) -> EngineKind;

    /// Stable name ([`EngineKind::name`] unless an impl overrides it).
    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// The model graph this engine serves.
    fn model(&self) -> ModelKind;

    /// Uniform telemetry snapshot (see [`EngineReport`]).
    fn report(&self) -> EngineReport;
}

impl Engine for F32Engine {
    fn forward_with(&self, x: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        F32Engine::forward_with(self, x, scratch)
    }

    fn kind(&self) -> EngineKind {
        EngineKind::F32
    }

    fn model(&self) -> ModelKind {
        F32Engine::model(self)
    }

    fn report(&self) -> EngineReport {
        EngineReport {
            forwards: self.forwards(),
            ledger: self.ledger(),
            pool: Some(self.pool().stats()),
            ..EngineReport::new(EngineKind::F32)
        }
    }
}

impl Engine for QuantizedEngine {
    fn forward_with(&self, x: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        QuantizedEngine::forward_with(self, x, scratch)
    }

    fn kind(&self) -> EngineKind {
        EngineKind::Quantized
    }

    fn model(&self) -> ModelKind {
        QuantizedEngine::model(self)
    }

    fn report(&self) -> EngineReport {
        EngineReport {
            forwards: self.forwards(),
            skipped_fraction: self.skipped_fraction(),
            ledger: self.ledger(),
            pool: Some(self.pool().stats()),
            ..EngineReport::new(EngineKind::Quantized)
        }
    }
}

impl Engine for CsdEngine {
    fn forward_with(&self, x: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        CsdEngine::forward_with(self, x, scratch)
    }

    fn kind(&self) -> EngineKind {
        EngineKind::Csd
    }

    fn model(&self) -> ModelKind {
        CsdEngine::model(self)
    }

    fn report(&self) -> EngineReport {
        EngineReport {
            forwards: self.forwards(),
            skipped_fraction: self.skipped_fraction(),
            mean_pp: self.mean_pp(),
            ledger: self.ledger(),
            pool: Some(self.pool().stats()),
            ..EngineReport::new(EngineKind::Csd)
        }
    }
}

/// The PJRT artifact as an [`Engine`]: the compiled executable plus a
/// prebuilt argument vector (slot 0 is replaced with each batch tensor,
/// slots 1.. hold the weights, wrapped once at construction so dispatching a
/// batch never re-copies the model).  Input batches are padded to the
/// compiled batch size and only the real rows of the logits are returned, so
/// the roster can treat this engine exactly like the host paths.
///
/// `Send + Sync` like the host engines, so it can sit on the shared roster
/// under replicated inference workers: the prebuilt argument vector is the
/// only mutable state, and the `Mutex` around it serializes forwards — the
/// PJRT executable runs one padded batch at a time by construction, so
/// concurrent callers queue on the lock instead of racing slot 0.
pub struct PjrtEngine {
    /// Keeps the PJRT client alive for the executable's lifetime.
    _rt: Runtime,
    exe: Arc<Executable>,
    /// Prebuilt args; only slot 0 changes per forward and the trait takes
    /// `&self`, so the mutex both provides interior mutability and
    /// serializes the single-execution PJRT path under worker replication.
    args: Mutex<Vec<ArgValue>>,
    /// The compiled (padded) execution batch size.
    batch: usize,
    model: ModelKind,
    /// MACs of one forward at the compiled batch (the padded rows pay too —
    /// that is exactly the padding waste the dispatch policies trade off).
    macs_per_exec: u64,
    forwards: AtomicU64,
}

impl PjrtEngine {
    /// Load and compile the artifact for `(model, batch)` from `artifacts`,
    /// wrapping `store`'s weights into the prebuilt argument vector.
    pub fn load(
        artifacts: &Path,
        model: ModelKind,
        batch: usize,
        store: &WeightStore,
    ) -> Result<PjrtEngine> {
        let mut rt = Runtime::new(artifacts)?;
        let (art, compiled) = crate::coordinator::router::artifact_for(model, batch)?;
        let exe = rt.load(&art)?;
        let mut args = vec![ArgValue::F32(Tensor::zeros(vec![0]))];
        args.extend(store.ordered().into_iter().map(|t| ArgValue::F32(t.clone())));
        Ok(PjrtEngine {
            _rt: rt,
            exe,
            args: Mutex::new(args),
            batch: compiled,
            model,
            macs_per_exec: ModelMeta::of(model).macs_per_image() * compiled as u64,
            forwards: AtomicU64::new(0),
        })
    }

    /// The compiled (padded) batch size.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Forwards completed since construction.
    pub fn forwards(&self) -> u64 {
        self.forwards.load(Ordering::Relaxed)
    }

    /// Forward one batch (one-shot scratch): pad to the compiled size,
    /// execute, return the real rows of the logits.
    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        self.forward_with(x, &mut Scratch::new())
    }

    /// Forward one batch, accounting the padded staging against the worker's
    /// scratch arena stats: the slot-0 buffer is re-padded *in place* on warm
    /// forwards ([`stage_padded`]), so like the host engines a warm PJRT
    /// engine allocates nothing per request beyond the returned logits.
    pub fn forward_with(&self, x: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        let s = x.shape();
        let (h, w, c) = self.model.input_hwc();
        if s.len() != 4 || s[1] != h || s[2] != w || s[3] != c {
            bail!("{:?} artifact expects [B,{h},{w},{c}], got {s:?}", self.model);
        }
        let b = s[0];
        if b > self.batch {
            bail!("batch {b} exceeds the compiled artifact batch {}", self.batch);
        }
        let out = {
            let mut args = self.args.lock().unwrap();
            stage_padded(&mut args[0], x, self.batch, (h, w, c), &mut scratch.stats)?;
            self.exe.run(&args)?
        };
        let logits = &out[0];
        let ls = logits.shape();
        if ls.len() != 2 || ls[0] < b {
            bail!("artifact returned logits {ls:?} for a {b}-row batch");
        }
        let n = ls[1];
        let trimmed = Tensor::new(vec![b, n], logits.data()[..b * n].to_vec())?;
        self.forwards.fetch_add(1, Ordering::Relaxed);
        Ok(trimmed)
    }
}

/// Stage a `b`-row batch into the prebuilt slot-0 argument, padded to the
/// compiled `batch`: when the slot already holds a padded tensor of the
/// right shape the rows are copied in and the tail zeroed **in place** (a
/// [`ScratchStats`] reuse — the warm path allocates nothing); only a cold or
/// reshaped slot allocates the padded buffer (an alloc).
fn stage_padded(
    slot: &mut ArgValue,
    x: &Tensor,
    batch: usize,
    hwc: (usize, usize, usize),
    stats: &mut ScratchStats,
) -> Result<()> {
    let (h, w, c) = hwc;
    let pix = h * w * c;
    let b = x.shape()[0];
    match slot {
        ArgValue::F32(t) if t.shape() == [batch, h, w, c] => {
            stats.reuses += 1;
            let d = t.data_mut();
            d[..b * pix].copy_from_slice(x.data());
            // clear rows a previous, larger batch staged
            d[b * pix..].fill(0.0);
        }
        other => {
            stats.allocs += 1;
            let mut xdata = vec![0.0f32; batch * pix];
            xdata[..b * pix].copy_from_slice(x.data());
            *other = ArgValue::F32(Tensor::new(vec![batch, h, w, c], xdata)?);
        }
    }
    Ok(())
}

impl Engine for PjrtEngine {
    fn forward_with(&self, x: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        PjrtEngine::forward_with(self, x, scratch)
    }

    fn kind(&self) -> EngineKind {
        EngineKind::Pjrt
    }

    fn model(&self) -> ModelKind {
        self.model
    }

    fn report(&self) -> EngineReport {
        let fwd = self.forwards();
        // the compiled kernels' cost model: every forward executes the full
        // padded batch worth of f32 MACs, real rows or not
        let macs = fwd * self.macs_per_exec;
        EngineReport {
            forwards: fwd,
            ledger: Ledger { fp_muls: macs, fp_adds: macs, ..Ledger::default() },
            ..EngineReport::new(EngineKind::Pjrt)
        }
    }
}

/// Chaos wrapper around any [`Engine`]: before each forward it consults the
/// armed fault plan ([`crate::util::faults::engine_action`], keyed by the
/// wrapped engine's name) and injects the decided failure — an error return,
/// a panic (exercising the supervised worker), or a latency spike — else
/// delegates untouched.  Identity (`kind`/`name`/`model`/`report`) passes
/// straight through, so metrics keys, dispatch policies, and quarantine all
/// see the real engine.
///
/// Only the roster build constructs this, and only when fault injection is
/// armed at that moment — the disarmed serving path never allocates or
/// checks anything fault-related per forward.  Carries the roster's
/// `Send + Sync` bound through, so wrapped generations still share across
/// replicated workers.
pub struct FaultInjector {
    inner: Box<dyn Engine + Send + Sync>,
}

impl FaultInjector {
    pub fn new(inner: Box<dyn Engine + Send + Sync>) -> FaultInjector {
        FaultInjector { inner }
    }
}

impl Engine for FaultInjector {
    fn forward_with(&self, x: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        use crate::util::faults::{engine_action, Action};
        match engine_action(self.inner.name()) {
            Some(Action::Error) => bail!("injected fault: {} errored", self.inner.name()),
            Some(Action::Panic) => panic!("injected fault: {} panicked", self.inner.name()),
            Some(Action::Delay(d)) => {
                std::thread::sleep(d);
                self.inner.forward_with(x, scratch)
            }
            None => self.inner.forward_with(x, scratch),
        }
    }

    fn kind(&self) -> EngineKind {
        self.inner.kind()
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn model(&self) -> ModelKind {
        self.inner.model()
    }

    fn report(&self) -> EngineReport {
        self.inner.report()
    }
}

/// The batch-size crossover of artifact dispatch: running a padded artifact
/// costs the full compiled batch regardless of occupancy, and the compiled
/// kernels are roughly a few times faster per row than the host engines —
/// so the artifact wins once a batch fills at least a quarter of the
/// compiled size, and below that the padding waste hands the batch to a
/// low-latency host engine.
pub fn batch_prefers_artifact(n: usize, artifact_batch: usize) -> bool {
    n.saturating_mul(4) >= artifact_batch
}

/// A pluggable batch-dispatch policy: given the popped batch size, the
/// compiled artifact batch, and the kinds on the roster, pick the engine
/// index to run.  Policies must tolerate any roster composition (a kind they
/// would prefer may be absent — e.g. PJRT without artifacts), which is what
/// the preference-order helper below encodes.
pub trait DispatchPolicy {
    /// Stable policy name (`--policy` value, `counter.policy_<name>`).
    fn name(&self) -> &'static str;

    /// Engine index in `kinds` for an `n`-row batch.
    fn route(&self, n: usize, artifact_batch: usize, kinds: &[EngineKind]) -> usize;
}

/// First kind of `prefs` present in `kinds` (index into `kinds`); falls back
/// to engine 0 so a route always lands on a live engine.
fn first_of(kinds: &[EngineKind], prefs: &[EngineKind]) -> usize {
    prefs
        .iter()
        .find_map(|p| kinds.iter().position(|k| k == p))
        .unwrap_or(0)
}

/// Engines that amortize an artifact-filling batch best, in order.
const ARTIFACT_PREFS: [EngineKind; 4] =
    [EngineKind::Pjrt, EngineKind::F32, EngineKind::Quantized, EngineKind::Csd];
/// Low-latency small-batch engines, in order.  Every exact path ranks
/// ahead of the truncated CSD engine: if the code-domain engine is absent
/// (a degraded roster), small batches must fall back to an *exact* engine
/// — padded PJRT included — matching the old hybrid's degrade behavior.
/// Only [`ENERGY_PREFS`] opts into CSD's approximation deliberately.
const LATENCY_PREFS: [EngineKind; 4] =
    [EngineKind::Quantized, EngineKind::F32, EngineKind::Pjrt, EngineKind::Csd];
/// Minimum-energy engines (shift-and-add first), in order.
const ENERGY_PREFS: [EngineKind; 4] =
    [EngineKind::Csd, EngineKind::Quantized, EngineKind::F32, EngineKind::Pjrt];

/// The classic quarter-full crossover ([`batch_prefers_artifact`]):
/// artifact-filling batches go to the compiled artifact (threaded f32 host
/// when PJRT is absent), everything smaller to the code-domain engine.
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchFillPolicy;

impl DispatchPolicy for BatchFillPolicy {
    fn name(&self) -> &'static str {
        "batch-fill"
    }

    fn route(&self, n: usize, artifact_batch: usize, kinds: &[EngineKind]) -> usize {
        if batch_prefers_artifact(n, artifact_batch) {
            first_of(kinds, &ARTIFACT_PREFS)
        } else {
            first_of(kinds, &LATENCY_PREFS)
        }
    }
}

/// Latency-floor dispatch: a partial batch on the padded artifact pays the
/// full compiled-batch latency, so *only* batches that actually fill the
/// artifact run on it — every partial batch stays on the low-latency host
/// engines.  Trades peak throughput for a flat tail latency.  A corollary
/// the contract implies: if the dynamic-batching cap is below the compiled
/// artifact batch, no batch can ever fill the artifact, so the artifact
/// engine deliberately sees no traffic (the server warns at startup).
#[derive(Clone, Copy, Debug, Default)]
pub struct LatencyFloorPolicy;

impl DispatchPolicy for LatencyFloorPolicy {
    fn name(&self) -> &'static str {
        "latency-floor"
    }

    fn route(&self, n: usize, artifact_batch: usize, kinds: &[EngineKind]) -> usize {
        if n >= artifact_batch {
            first_of(kinds, &ARTIFACT_PREFS)
        } else {
            first_of(kinds, &LATENCY_PREFS)
        }
    }
}

/// Energy-budget dispatch: artifact-filling batches amortize the compiled
/// kernels, mid-size batches run code-domain (adds only, zero-skip), and the
/// smallest batches — below an eighth of the compiled size, where per-request
/// energy dominates — run on the truncated-CSD shift-and-add engine, the
/// cheapest path per MAC ([`crate::hw::energy::pj::QSM_PARTIAL_PRODUCT`] vs
/// a full f32 multiply).  This is the route that makes the CSD engine
/// reachable from `Auto`.
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyBudgetPolicy;

impl DispatchPolicy for EnergyBudgetPolicy {
    fn name(&self) -> &'static str {
        "energy-budget"
    }

    fn route(&self, n: usize, artifact_batch: usize, kinds: &[EngineKind]) -> usize {
        if batch_prefers_artifact(n, artifact_batch) {
            first_of(kinds, &ARTIFACT_PREFS)
        } else if n.saturating_mul(8) < artifact_batch {
            first_of(kinds, &ENERGY_PREFS)
        } else {
            first_of(kinds, &LATENCY_PREFS)
        }
    }
}

/// CLI-level policy selection (`--policy batch-fill|latency|energy`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PolicySelect {
    /// [`BatchFillPolicy`] — the quarter-full artifact crossover (default).
    #[default]
    BatchFill,
    /// [`LatencyFloorPolicy`] — partial batches never pay artifact padding.
    LatencyFloor,
    /// [`EnergyBudgetPolicy`] — smallest batches take the CSD energy path.
    EnergyBudget,
}

impl PolicySelect {
    /// Parse a `--policy` value (short and long spellings accepted).
    pub fn from_name(s: &str) -> Result<PolicySelect> {
        Ok(match s {
            "batch-fill" | "batchfill" => PolicySelect::BatchFill,
            "latency" | "latency-floor" => PolicySelect::LatencyFloor,
            "energy" | "energy-budget" => PolicySelect::EnergyBudget,
            other => bail!("unknown policy {other:?} (batch-fill|latency|energy)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            PolicySelect::BatchFill => "batch-fill",
            PolicySelect::LatencyFloor => "latency-floor",
            PolicySelect::EnergyBudget => "energy-budget",
        }
    }

    /// Instantiate the policy.  Policies are stateless, so the trait object
    /// carries `Send + Sync` and the shared roster can route from any
    /// inference worker.
    pub fn build(self) -> Box<dyn DispatchPolicy + Send + Sync> {
        match self {
            PolicySelect::BatchFill => Box::new(BatchFillPolicy),
            PolicySelect::LatencyFloor => Box::new(LatencyFloorPolicy),
            PolicySelect::EnergyBudget => Box::new(EnergyBudgetPolicy),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::EngineKind::{Csd, Pjrt, Quantized, F32};

    #[test]
    fn kind_names_are_stable() {
        // metrics keys and dispatch counters are derived from these — a
        // rename is a dashboard-breaking change
        assert_eq!(F32.name(), "host-f32");
        assert_eq!(Quantized.name(), "host-qgemm");
        assert_eq!(Csd.name(), "host-csd");
        assert_eq!(Pjrt.name(), "pjrt");
    }

    #[test]
    fn crossover_prefers_artifact_only_when_batch_fills_it() {
        assert!(!batch_prefers_artifact(1, 32));
        assert!(!batch_prefers_artifact(7, 32));
        assert!(batch_prefers_artifact(8, 32));
        assert!(batch_prefers_artifact(32, 32));
        // degenerate compiled sizes never panic
        assert!(batch_prefers_artifact(1, 1));
        assert!(batch_prefers_artifact(0, 0));
    }

    #[test]
    fn batch_fill_routes_like_the_old_hybrid() {
        let kinds = [Pjrt, Quantized, Csd];
        let p = BatchFillPolicy;
        assert_eq!(p.route(32, 32, &kinds), 0, "full batch -> artifact");
        assert_eq!(p.route(8, 32, &kinds), 0, "quarter-full -> artifact");
        assert_eq!(p.route(1, 32, &kinds), 1, "singleton -> code-domain");
        // PJRT absent: the f32 engine takes the artifact-class batches
        let kinds = [F32, Quantized, Csd];
        assert_eq!(p.route(32, 32, &kinds), 0);
        assert_eq!(p.route(3, 32, &kinds), 1);
    }

    #[test]
    fn latency_floor_keeps_partial_batches_off_the_artifact() {
        let kinds = [Pjrt, Quantized, Csd];
        let p = LatencyFloorPolicy;
        assert_eq!(p.route(32, 32, &kinds), 0, "only a full batch pays padding");
        // batch-fill would send these to the artifact; latency-floor won't
        assert_eq!(p.route(31, 32, &kinds), 1);
        assert_eq!(p.route(8, 32, &kinds), 1);
        assert_eq!(p.route(1, 32, &kinds), 1);
    }

    #[test]
    fn energy_budget_reaches_every_engine_class() {
        let kinds = [Pjrt, Quantized, Csd];
        let p = EnergyBudgetPolicy;
        assert_eq!(p.route(32, 32, &kinds), 0, "artifact-filling -> compiled");
        assert_eq!(p.route(5, 32, &kinds), 1, "mid-size -> code-domain");
        assert_eq!(p.route(1, 32, &kinds), 2, "smallest -> CSD shift-and-add");
        assert_eq!(p.route(3, 32, &kinds), 2, "below an eighth -> CSD");
        assert_eq!(p.route(4, 32, &kinds), 1, "an eighth exactly -> code-domain");
    }

    #[test]
    fn policies_survive_any_roster_composition() {
        // a roster missing the preferred kind falls through the preference
        // order; a single-engine roster always routes to it
        for policy in [
            PolicySelect::BatchFill.build(),
            PolicySelect::LatencyFloor.build(),
            PolicySelect::EnergyBudget.build(),
        ] {
            for n in [0usize, 1, 4, 8, 32, 100] {
                assert_eq!(policy.route(n, 32, &[Csd]), 0);
                let i = policy.route(n, 32, &[Quantized, Csd]);
                assert!(i < 2, "{} n={n}: index {i}", policy.name());
            }
        }
        // artifact-class traffic without pjrt or f32 still routes somewhere
        assert_eq!(BatchFillPolicy.route(32, 32, &[Quantized, Csd]), 0);
    }

    #[test]
    fn degraded_rosters_fall_back_to_exact_engines() {
        // when the code-domain engine failed to build, small batches must
        // not silently land on the truncated CSD engine: batch-fill and
        // latency-floor degrade to an exact path (f32, or padded PJRT),
        // exactly like the old hybrid; only the energy policy picks CSD
        for p in [&BatchFillPolicy as &dyn DispatchPolicy, &LatencyFloorPolicy] {
            assert_eq!(p.route(1, 32, &[Csd, F32]), 1, "{}: f32 is exact", p.name());
            assert_eq!(p.route(1, 32, &[Csd, Pjrt]), 1, "{}: pjrt is exact", p.name());
        }
        assert_eq!(EnergyBudgetPolicy.route(1, 32, &[Csd, Pjrt]), 0, "energy opts into CSD");
    }

    #[test]
    fn policy_select_parses_and_builds() {
        assert_eq!(PolicySelect::from_name("batch-fill").unwrap(), PolicySelect::BatchFill);
        assert_eq!(PolicySelect::from_name("latency").unwrap(), PolicySelect::LatencyFloor);
        assert_eq!(PolicySelect::from_name("energy").unwrap(), PolicySelect::EnergyBudget);
        assert_eq!(PolicySelect::from_name("energy-budget").unwrap(), PolicySelect::EnergyBudget);
        assert!(PolicySelect::from_name("round-robin").is_err());
        assert_eq!(PolicySelect::default(), PolicySelect::BatchFill);
        assert_eq!(PolicySelect::EnergyBudget.build().name(), "energy-budget");
    }

    #[test]
    fn fault_injector_is_transparent_when_disarmed() {
        // identity and forwards delegate untouched (fault injection is
        // never armed inside unit tests — arming is process-global; the
        // armed behavior is covered by the test_chaos integration binary)
        let store = crate::data::synth_store(91, crate::model::meta::ModelKind::Lenet);
        let inner: Box<dyn Engine + Send + Sync> =
            Box::new(crate::runtime::host::F32Engine::new(store));
        let wrapped = FaultInjector::new(inner);
        assert_eq!(wrapped.kind(), EngineKind::F32);
        assert_eq!(wrapped.name(), "host-f32");
        assert_eq!(wrapped.model(), crate::model::meta::ModelKind::Lenet);
        let mut scratch = Scratch::new();
        let x = Tensor::new(vec![2, 28, 28, 1], vec![0.1; 2 * 28 * 28]).unwrap();
        let y = wrapped.forward_with(&x, &mut scratch).unwrap();
        assert_eq!(y.shape(), &[2, 10]);
        assert_eq!(wrapped.report().forwards, 1, "report reads through the wrapper");
    }

    #[test]
    fn pjrt_padded_staging_reuses_the_slot_buffer_when_warm() {
        let mut stats = ScratchStats::default();
        let mut slot = ArgValue::F32(Tensor::zeros(vec![0]));
        let x =
            Tensor::new(vec![2, 4, 4, 1], (0..32).map(|i| i as f32).collect()).unwrap();
        stage_padded(&mut slot, &x, 8, (4, 4, 1), &mut stats).unwrap();
        assert_eq!(stats.allocs, 1, "cold staging grows the slot once");
        match &slot {
            ArgValue::F32(t) => {
                assert_eq!(t.shape(), &[8, 4, 4, 1]);
                assert_eq!(&t.data()[..32], x.data());
                assert!(t.data()[32..].iter().all(|&v| v == 0.0), "tail is zero-padded");
            }
            _ => panic!("slot must hold the padded batch tensor"),
        }
        // warm passes re-pad in place: no allocation, and the rows a larger
        // earlier batch staged are cleared
        let y = Tensor::new(vec![1, 4, 4, 1], vec![7.0; 16]).unwrap();
        stage_padded(&mut slot, &y, 8, (4, 4, 1), &mut stats).unwrap();
        stage_padded(&mut slot, &y, 8, (4, 4, 1), &mut stats).unwrap();
        assert_eq!(stats.allocs, 1, "warm staging must not allocate");
        assert_eq!(stats.reuses, 2);
        match &slot {
            ArgValue::F32(t) => {
                assert_eq!(&t.data()[..16], &[7.0f32; 16][..]);
                assert!(t.data()[16..].iter().all(|&v| v == 0.0), "stale rows cleared");
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn report_exports_the_uniform_gauge_family() {
        let mut rep = EngineReport::new(EngineKind::Csd);
        rep.forwards = 3;
        rep.mean_pp = 2.5;
        rep.ledger.partial_products = 120;
        rep.ledger.act_bits = 16;
        rep.pool = Some(PoolStats { spawns: 4, wakeups: 9, jobs: 12, pin_hits: 7, pin_misses: 2 });
        let mut keys = Vec::new();
        rep.export(|k, v| keys.push((k.to_string(), v)));
        let get = |name: &str| {
            keys.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
        };
        assert_eq!(get("engine.host-csd.forwards"), Some(3.0));
        assert_eq!(get("engine.host-csd.mean_pp"), Some(2.5));
        assert_eq!(get("engine.host-csd.energy.partial_products"), Some(120.0));
        assert_eq!(get("engine.host-csd.energy.act_bits"), Some(16.0));
        assert_eq!(get("engine.host-csd.pool.spawns"), Some(4.0));
        assert_eq!(get("engine.host-csd.pool.pin_hits"), Some(7.0));
        assert_eq!(get("engine.host-csd.pool.pin_misses"), Some(2.0));
        // every engine exports the same core family, populated or not
        let mut f32_keys = Vec::new();
        EngineReport::new(EngineKind::F32).export(|k, _| f32_keys.push(k.to_string()));
        for suffix in [
            "forwards",
            "skipped_fraction",
            "mean_pp",
            "energy.partial_products",
            "energy.int_adds",
            "energy.act_bits",
            "energy.total_pj",
        ] {
            assert!(
                f32_keys.iter().any(|k| k == &format!("engine.host-f32.{suffix}")),
                "missing engine.host-f32.{suffix}"
            );
        }
    }
}
