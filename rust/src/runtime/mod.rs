//! Runtime layer: the PJRT client that loads + executes `artifacts/*.hlo.txt`
//! ([`client`]) and the pure-rust fallback/oracle engine ([`host`]).

pub mod client;
pub mod host;

pub use client::{ArgValue, Executable, Runtime};
