//! Runtime layer: the PJRT client that loads + executes `artifacts/*.hlo.txt`
//! ([`client`]), the pure-rust engines ([`host`]), and the unified
//! [`engine::Engine`] trait + batch-dispatch policies the server routes a
//! roster of boxed engines with ([`engine`]).

pub mod client;
pub mod engine;
pub mod host;

pub use client::{ArgValue, Executable, Runtime};
pub use engine::{DispatchPolicy, Engine, EngineKind, EngineReport, PjrtEngine, PolicySelect};
