//! PJRT runtime: loads HLO-text artifacts (produced by `make artifacts`)
//! onto the CPU PJRT client and executes them from the serving hot path.
//!
//! Interchange is HLO **text**: jax >= 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §3).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::model::store::Manifest;
use crate::quant::codes::Code;
use crate::tensor::Tensor;

/// Declared dtype of an artifact argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArgDtype {
    F32,
    I8,
    I32,
}

impl ArgDtype {
    fn from_str(s: &str) -> Result<ArgDtype> {
        Ok(match s {
            "f32" => ArgDtype::F32,
            "i8" => ArgDtype::I8,
            "i32" => ArgDtype::I32,
            other => bail!("unsupported artifact dtype {other}"),
        })
    }
}

/// One declared argument.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: ArgDtype,
}

/// Runtime argument values (host side).
#[derive(Clone, Debug)]
pub enum ArgValue {
    F32(Tensor),
    /// Codes carried as int8 (one code per byte in the PJRT artifact; the
    /// dense 3-bit packing exists on the wire/container only).
    I8 {
        shape: Vec<usize>,
        data: Vec<i8>,
    },
    Scalar(f32),
}

impl ArgValue {
    pub fn codes(shape: Vec<usize>, codes: &[Code]) -> ArgValue {
        ArgValue::I8 { shape, data: codes.iter().map(|c| c.0 as i8).collect() }
    }
}

/// A compiled artifact + its manifest spec.
pub struct Executable {
    pub name: String,
    pub args: Vec<ArgSpec>,
    pub n_outputs: usize,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host values; returns output tensors (f32).
    pub fn run(&self, values: &[ArgValue]) -> Result<Vec<Tensor>> {
        if values.len() != self.args.len() {
            bail!(
                "{}: got {} args, artifact declares {}",
                self.name,
                values.len(),
                self.args.len()
            );
        }
        let mut literals = Vec::with_capacity(values.len());
        for (spec, val) in self.args.iter().zip(values) {
            literals.push(to_literal(spec, val).with_context(|| {
                format!("artifact {} argument {}", self.name, spec.name)
            })?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for lit in parts {
            out.push(literal_to_tensor(&lit)?);
        }
        Ok(out)
    }
}

fn to_literal(spec: &ArgSpec, val: &ArgValue) -> Result<xla::Literal> {
    match val {
        ArgValue::F32(t) => {
            if t.shape() != spec.shape.as_slice() {
                bail!("shape {:?} vs declared {:?}", t.shape(), spec.shape);
            }
            if spec.dtype != ArgDtype::F32 {
                bail!("expected {:?}, got f32", spec.dtype);
            }
            if spec.shape.is_empty() {
                return Ok(xla::Literal::scalar(t.data()[0]));
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            Ok(xla::Literal::vec1(t.data()).reshape(&dims)?)
        }
        ArgValue::Scalar(v) => {
            if !spec.shape.is_empty() {
                bail!("scalar arg for non-scalar spec {:?}", spec.shape);
            }
            Ok(xla::Literal::scalar(*v))
        }
        ArgValue::I8 { shape, data } => {
            if shape != &spec.shape {
                bail!("shape {:?} vs declared {:?}", shape, spec.shape);
            }
            if spec.dtype != ArgDtype::I8 {
                bail!("expected {:?}, got i8", spec.dtype);
            }
            let n: usize = shape.iter().product();
            if n != data.len() {
                bail!("i8 data len {} vs shape {:?}", data.len(), shape);
            }
            let bytes: Vec<u8> = data.iter().map(|&v| v as u8).collect();
            Ok(xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S8,
                shape,
                &bytes,
            )?)
        }
    }
}

fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Tensor::new(dims, data)
}

/// The runtime: PJRT client + manifest + compiled-executable cache.
///
/// NOT `Sync` — the serving design gives each inference worker thread its own
/// `Runtime` or channels requests into a single owner thread.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: HashMap<String, Arc<Executable>>,
}

impl Runtime {
    /// CPU PJRT client over the given artifacts directory.
    pub fn new(artifacts: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, manifest, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached).
    pub fn load(&mut self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name);
        if spec.is_null() {
            bail!("artifact {name} not in manifest");
        }
        let path = self.manifest.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;

        let mut args = Vec::new();
        for a in spec.get("args").as_arr().unwrap_or(&[]) {
            args.push(ArgSpec {
                name: a.get("name").as_str().unwrap_or("?").to_string(),
                shape: a
                    .get("shape")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|v| v.as_usize().unwrap_or(0))
                    .collect(),
                dtype: ArgDtype::from_str(a.get("dtype").as_str().unwrap_or("f32"))?,
            });
        }
        let n_outputs = spec.get("outputs").as_arr().map(|a| a.len()).unwrap_or(1);
        let e = Arc::new(Executable { name: name.to_string(), args, n_outputs, exe });
        self.cache.insert(name.to_string(), e.clone());
        Ok(e)
    }

    pub fn loaded(&self) -> Vec<&str> {
        self.cache.keys().map(|s| s.as_str()).collect()
    }
}
