//! The compute-kernel layer — the serving hot path.
//!
//! Everything under `kernels/` exists to make inference run as fast as the
//! host hardware allows while staying dependency-free (std only):
//!
//! * [`blocked`] — cache-blocked f32 GEMM with a 4x8 register-accumulator
//!   microtile, parallelized over row bands on the worker pool.  This is
//!   what [`crate::tensor::ops::matmul`] (and therefore im2col conv and the
//!   fp32 model head) dispatches to; the original ikj loop survives as
//!   [`crate::tensor::ops::matmul_naive`], the bitwise oracle.
//! * [`mod@qgemm`] — the code-domain GEMM, in two generations.  v1
//!   ([`PackedQTensor`] + [`qgemm`](qgemm::qgemm)) is the retained
//!   single-thread reference: zero codes dropped at pack time, shift/add
//!   contribution tables, hoisted per-group alpha.  v2
//!   ([`PackedQTensorV2`] + [`qgemm2`]) repacks the surviving codes into six
//!   per-level *offset planes* per (group, column) cell, so the inner loop is
//!   a straight contiguous sum per plane (lane-friendly for the
//!   autovectorizer, no 8-way LUT select, half the bytes per entry) and the
//!   row dimension is split across pool workers with the same band scheme
//!   as the blocked f32 kernel.  v2 is what the serving engine runs.
//! * [`mod@csd`] — the CSD-domain GEMM: f32 weights fixed-point recoded,
//!   CSD-encoded, truncated to a per-weight digit budget
//!   ([`crate::device::CsdQuality`], the paper's §V.B quality dial), and
//!   packed into per-(column, exponent, sign) digit planes so the inner loop
//!   is pure shift-and-add with at most `max_digits` partial products per
//!   weight.  Exact CSD is bitwise-reconcilable with the per-scalar
//!   [`crate::hw::multiplier`] datapath simulator; the digit statistics feed
//!   the serving engine's per-request energy ledger (`engine.host-csd.*` gauges).
//! * [`mod@qconv`] — the fused conv pipeline: im2col patches are staged
//!   chunk-by-chunk into a reusable [`Scratch`] arena and multiplied
//!   band-by-band on the plane-packed qgemm, the CSD shift-and-add kernel,
//!   or the f32 microkernel, so the full patch matrix is never materialized
//!   and steady-state serving allocates nothing per request.
//! * [`mod@lanes`] — the lane-ized reduction primitives under all of the
//!   above: the plane-sum hot path ([`lanes::gather_sum`], fixed-width
//!   chunked f32 gathers with one accumulator per lane) that qgemm2 and the
//!   CSD kernel call per plane, plus the true SWAR-on-`u64` integer sums
//!   ([`lanes::sum_i8`] / [`lanes::sum_i16`]) with carry-safe lane widening
//!   every fixed word count.  The scalar forms are retained as bitwise
//!   oracles; `tests/test_lanes.rs` is the differential harness that sweeps
//!   every chunk/tail boundary and the widening overflow edge.
//! * [`mod@calib`] — the activation-calibration pass of the integer
//!   datapath: observe per-layer activation ranges on a representative
//!   batch, pick one saturating Q-format per layer ([`calib::ActPlan`]),
//!   and quantize/dequantize activations through it.  With a plan in hand
//!   the fused pipeline runs layer-to-layer on i16 ping/pong buffers
//!   ([`Scratch::qact_a`] / [`Scratch::qact_b`]) and the qgemm2/CSD plane
//!   sums gather i16 activations through [`lanes::gather_sum_i16`] — the
//!   inner loop becomes a pure SWAR integer reduction with one
//!   dequant-rescale per (group, column) cell.  `tests/test_intpath.rs` is
//!   the differential gate: i16 gathers bitwise vs their scalar oracle,
//!   calibration determinism, saturation clamp-never-wrap, and the whole
//!   integer forward against `forward_scalar_reference`.
//! * [`mod@pool`] — the persistent worker pool every row-band kernel
//!   (blocked f32, qgemm2, csd, and the fused conv driver) dispatches on.
//!   Workers are spawned once (lazily, on first kernel use)
//!   and then *parked*; a warm dispatch costs one condvar wakeup per band
//!   instead of a `std::thread::scope` spawn + join per matmul, so
//!   steady-state serving spawns zero threads per request
//!   ([`PoolStats::spawns`] freezes after initialization, exactly like
//!   [`ScratchStats::allocs`] freezes once the arena is warm).  In its
//!   default *pinned* mode the pool leases each band index to a preferred
//!   worker, so the same row ranges land on the same (cache-warm) worker
//!   across the layers of one forward and across warm forwards; the
//!   [`PoolStats::pin_hits`] / [`PoolStats::pin_misses`] counters expose how
//!   often locality actually held.
//!
//! ## The `PALLAS_POOL_THREADS` knob
//!
//! The global pool sizes itself to `available_parallelism`, capped at
//! [`pool::MAX_POOL_THREADS`].  Set `PALLAS_POOL_THREADS=<n>` (read once, at
//! the first parallel kernel call) to override: `n` is the total compute
//! width *including* the dispatching thread, so `PALLAS_POOL_THREADS=1`
//! spawns no workers at all and every kernel runs its serial single-thread
//! path — useful on tiny edge cores, under cgroup CPU quotas the runtime
//! cannot see, or to pin down nondeterministic scheduling while debugging.
//! Band partitioning is by whole rows either way, so threaded and serial
//! runs are bitwise identical.  A value that is not an integer `>= 1` is
//! rejected loudly ([`pool::parse_pool_threads`] returns an error, and the
//! server refuses to start) instead of silently falling back.
//! `PALLAS_POOL_PIN=0` disables band pinning (bands lease arbitrary idle
//! workers, the pre-pinning behavior); results are bitwise identical either
//! way — pinning moves *where* a band runs, never how its rows reduce.
//!
//! The remaining member of the kernel set lives with the quantizer it
//! accelerates: [`crate::quant::sigma_fast`] scores the whole 19x8
//! (gamma, delta) grid from sorted-|w| prefix sums in O(sort) instead of 152
//! full assignment passes.
//!
//! `benches/bench_kernels.rs` tracks all of these against their naive
//! oracles and emits `BENCH_kernels.json` for cross-PR perf trajectories
//! (including the pool's spawn-vs-wakeup counters and the arena's per-layer
//! high-water marks).

pub mod blocked;
pub mod calib;
pub mod csd;
pub mod lanes;
pub mod pool;
pub mod qconv;
pub mod qgemm;

pub use calib::{
    bias_relu_quantize_into, dequant_scale, format_for_max_abs, max_abs, quantize_bias,
    quantize_into, ActPlan, ACT_TOTAL_BITS,
};
pub use csd::{
    csd_gemm, csd_gemm_i16_into_on, csd_gemm_i16_scalar_on, csd_gemm_into, csd_gemm_into_on,
    csd_gemm_scalar_on, csd_gemm_threads, CsdStats, PackedCsdTensor,
};
pub use pool::{Pool, PoolStats};
pub use qconv::{
    csd_conv, csd_conv_i16_into, csd_conv_i16_scalar_into, csd_conv_into, csd_conv_scalar_into,
    fconv_into, qconv, qconv_i16_into, qconv_i16_scalar_into, qconv_into, qconv_scalar_into,
};
pub use qgemm::{
    qgemm, qgemm2, qgemm2_i16_into_on, qgemm2_i16_scalar_on, qgemm2_into, qgemm2_into_on,
    qgemm2_qt, qgemm2_scalar_on, qgemm2_threads, qgemm_qt, PackedQTensor, PackedQTensorV2,
};

/// Decide how many band workers a row-parallel kernel should use: one
/// unless the total inner-loop work amortizes dispatch cost, then at most
/// one per row, per core, capped at [`pool::MAX_POOL_THREADS`].  The pool
/// entry points additionally clamp this to their pool's width, so a
/// `PALLAS_POOL_THREADS=1` global pool serves fully serially — this
/// function itself stays pool-agnostic (it neither touches nor initializes
/// the global pool).
pub fn threads_for_rows(m: usize, total_ops: usize, par_threshold: usize) -> usize {
    if total_ops < par_threshold || m < 2 {
        return 1;
    }
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    cores.min(m).min(pool::MAX_POOL_THREADS)
}

/// One pre-split row band awaiting pickup by a pool job: `(first_row,
/// out_band, x_band)`, taken exactly once by the job that owns the index.
/// Generic over the activation element (`f32` for the float path, `i16`
/// for the fixed-point datapath) — the output accumulator stays f32.
type BandPart<'a, T> = std::sync::Mutex<Option<(usize, &'a mut [f32], &'a [T])>>;

/// Split `out` (`m` rows of `out_cols`) and `x` (`m` rows of `x_cols`) into
/// matching row bands and run `band(first_row, out_band, x_band)` on each,
/// spread over `pool`'s workers plus the calling thread.  Bands partition
/// whole rows, so per-element reduction order is untouched: a pooled run is
/// bitwise identical to `band(0, out, x)`.
#[allow(clippy::too_many_arguments)] // a GEMM band is inherently 3 shapes + 2 slices + dispatch
pub fn for_each_row_band_on<F>(
    pool: &Pool,
    out: &mut [f32],
    x: &[f32],
    m: usize,
    x_cols: usize,
    out_cols: usize,
    nthreads: usize,
    band: F,
) where
    F: Fn(usize, &mut [f32], &[f32]) + Sync,
{
    for_each_row_band_t_on(pool, out, x, m, x_cols, out_cols, nthreads, band)
}

/// [`for_each_row_band_on`] for i16 activation rows — the band splitter of
/// the integer-datapath kernels (`qgemm2_i16_into_on`,
/// `csd_gemm_i16_into_on`).  Identical banding, so the integer kernels
/// inherit the same bitwise serial-vs-pooled guarantee.
#[allow(clippy::too_many_arguments)]
pub fn for_each_row_band_i16_on<F>(
    pool: &Pool,
    out: &mut [f32],
    x: &[i16],
    m: usize,
    x_cols: usize,
    out_cols: usize,
    nthreads: usize,
    band: F,
) where
    F: Fn(usize, &mut [f32], &[i16]) + Sync,
{
    for_each_row_band_t_on(pool, out, x, m, x_cols, out_cols, nthreads, band)
}

#[allow(clippy::too_many_arguments)]
fn for_each_row_band_t_on<T: Sync, F>(
    pool: &Pool,
    out: &mut [f32],
    x: &[T],
    m: usize,
    x_cols: usize,
    out_cols: usize,
    nthreads: usize,
    band: F,
) where
    F: Fn(usize, &mut [f32], &[T]) + Sync,
{
    if m == 0 {
        return;
    }
    if nthreads <= 1 || x_cols == 0 || out_cols == 0 {
        band(0, out, x);
        return;
    }
    let rows_per_band = m.div_ceil(nthreads);
    let nbands = m.div_ceil(rows_per_band);
    if nbands <= 1 {
        band(0, out, x);
        return;
    }
    let parts: Vec<BandPart<T>> = out
        .chunks_mut(rows_per_band * out_cols)
        .zip(x.chunks(rows_per_band * x_cols))
        .enumerate()
        .map(|(bi, (ob, xb))| std::sync::Mutex::new(Some((bi * rows_per_band, ob, xb))))
        .collect();
    pool.run_bands(nbands, &|bi: usize| {
        let (row0, ob, xb) = parts[bi].lock().unwrap().take().expect("band taken once");
        band(row0, ob, xb);
    });
}

/// [`for_each_row_band_on`] on the global pool — the form the kernels use.
pub fn for_each_row_band<F>(
    out: &mut [f32],
    x: &[f32],
    m: usize,
    x_cols: usize,
    out_cols: usize,
    nthreads: usize,
    band: F,
) where
    F: Fn(usize, &mut [f32], &[f32]) + Sync,
{
    for_each_row_band_on(Pool::global(), out, x, m, x_cols, out_cols, nthreads, band)
}

/// Counters for the scratch arena: how often a kernel found a warm buffer
/// already big enough (`reuses`) vs had to grow one (`allocs`).  In steady
/// state serving, `allocs` stops moving after the first request.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScratchStats {
    pub reuses: u64,
    pub allocs: u64,
}

/// Per-layer high-water marks of the scratch arena: the peak bytes a named
/// layer ever staged in each buffer class.  Engines fold these into
/// [`Scratch::note_layer`]; the server exports them as metrics gauges, so
/// "how much arena does each layer actually need" is visible in the
/// `/metrics`-style snapshot without a debugger.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LayerPeak {
    /// Peak im2col patch-slab bytes (all band slabs of one call combined).
    pub patch_bytes: usize,
    /// Peak SAME-conv zero-pad staging bytes.
    pub pad_bytes: usize,
    /// Peak activation (kernel output) bytes.
    pub act_bytes: usize,
}

impl LayerPeak {
    /// Fold a kernel call's staging sizes (in f32 elements) into the peak.
    pub(crate) fn grow(&mut self, patch_elems: usize, pad_elems: usize, act_elems: usize) {
        let b = std::mem::size_of::<f32>();
        self.patch_bytes = self.patch_bytes.max(patch_elems * b);
        self.pad_bytes = self.pad_bytes.max(pad_elems * b);
        self.act_bytes = self.act_bytes.max(act_elems * b);
    }

    /// Fold an integer-path kernel call's staging sizes (in i16 elements)
    /// into the peak — half the bytes per element of the f32 path, which is
    /// exactly the arena saving the fixed-point datapath buys.
    pub(crate) fn grow_i16(&mut self, patch_elems: usize, pad_elems: usize, act_elems: usize) {
        let b = std::mem::size_of::<i16>();
        self.patch_bytes = self.patch_bytes.max(patch_elems * b);
        self.pad_bytes = self.pad_bytes.max(pad_elems * b);
        self.act_bytes = self.act_bytes.max(act_elems * b);
    }

    fn merge(&mut self, other: LayerPeak) {
        self.patch_bytes = self.patch_bytes.max(other.patch_bytes);
        self.pad_bytes = self.pad_bytes.max(other.pad_bytes);
        self.act_bytes = self.act_bytes.max(other.act_bytes);
    }
}

/// Reusable per-worker buffers for the fused serving pipeline.  One arena
/// lives on each inference worker (and inside every one-shot `forward`), so
/// im2col patch staging, SAME-conv padding, and layer activations stop
/// allocating once the buffers have grown to the largest layer.
#[derive(Debug, Default)]
pub struct Scratch {
    /// im2col patch staging — per-band chunk slabs, never the full matrix.
    pub patches: Vec<f32>,
    /// SAME-conv zero-pad staging.
    pub padded: Vec<f32>,
    /// Activation ping buffer (layer inputs / pooled outputs).
    pub act_a: Vec<f32>,
    /// Activation pong buffer (conv / dense outputs before pooling).
    pub act_b: Vec<f32>,
    /// Fixed-point twin of [`Scratch::act_a`]: quantized layer inputs /
    /// pooled outputs on the integer datapath (i16 at the layer's
    /// calibrated Q-format).
    pub qact_a: Vec<i16>,
    /// Fixed-point twin of [`Scratch::act_b`]: quantized conv / dense
    /// outputs before pooling.
    pub qact_b: Vec<i16>,
    /// Fixed-point twin of [`Scratch::patches`]: i16 im2col band slabs.
    pub qpatches: Vec<i16>,
    /// Fixed-point twin of [`Scratch::padded`]: i16 SAME-conv zero-pad
    /// staging.
    pub qpadded: Vec<i16>,
    pub stats: ScratchStats,
    /// Staging sizes of the most recent kernel call(s), pending attribution
    /// to a layer by [`Scratch::note_layer`].
    pub(crate) last: LayerPeak,
    /// Per-layer high-water marks, ordered by first execution.
    layer_peaks: Vec<(String, LayerPeak)>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Attribute the staging sizes recorded since the previous call to the
    /// named layer, folding them into that layer's high-water mark.  The
    /// fused engines call this once per layer.
    pub fn note_layer(&mut self, name: &str) {
        let last = std::mem::take(&mut self.last);
        match self.layer_peaks.iter_mut().find(|(n, _)| n == name) {
            Some((_, pk)) => pk.merge(last),
            None => self.layer_peaks.push((name.to_string(), last)),
        }
    }

    /// Per-layer arena high-water marks, in first-execution order.
    pub fn layer_peaks(&self) -> &[(String, LayerPeak)] {
        &self.layer_peaks
    }
}

/// Grow `buf` to hold at least `len` elements without touching existing
/// contents (callers overwrite their slice before reading it).  Counts the
/// warm-hit/grow in `stats`.
pub fn ensure_cap(buf: &mut Vec<f32>, len: usize, stats: &mut ScratchStats) {
    if buf.len() >= len {
        stats.reuses += 1;
        return;
    }
    if buf.capacity() >= len {
        stats.reuses += 1;
    } else {
        stats.allocs += 1;
    }
    buf.resize(len, 0.0);
}

/// [`ensure_cap`] for the i16 twin buffers of the integer datapath — same
/// warm-hit/grow accounting in the same [`ScratchStats`], so the
/// alloc-freeze assertion covers both element widths.
pub fn ensure_cap_i16(buf: &mut Vec<i16>, len: usize, stats: &mut ScratchStats) {
    if buf.len() >= len {
        stats.reuses += 1;
        return;
    }
    if buf.capacity() >= len {
        stats.reuses += 1;
    } else {
        stats.allocs += 1;
    }
    buf.resize(len, 0);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threads_for_rows_thresholds() {
        assert_eq!(threads_for_rows(64, 100, 1 << 20), 1, "small work stays serial");
        assert_eq!(threads_for_rows(1, usize::MAX, 1), 1, "one row stays serial");
        let t = threads_for_rows(64, 1 << 22, 1 << 20);
        assert!(t >= 1 && t <= pool::MAX_POOL_THREADS);
        assert!(threads_for_rows(2, 1 << 22, 1 << 20) <= 2, "never more threads than rows");
    }

    #[test]
    fn row_bands_cover_all_rows_once() {
        let (m, xc, oc) = (7, 3, 2);
        let x: Vec<f32> = (0..m * xc).map(|v| v as f32).collect();
        let mut out = vec![0.0f32; m * oc];
        // band kernel: out[i][j] = first_row + local_i (checks offsets line up)
        for_each_row_band(&mut out, &x, m, xc, oc, 3, |row0, ob, xb| {
            let rows = ob.len() / oc;
            assert_eq!(xb.len(), rows * xc);
            for i in 0..rows {
                for j in 0..oc {
                    ob[i * oc + j] += (row0 + i) as f32;
                }
            }
        });
        for i in 0..m {
            for j in 0..oc {
                assert_eq!(out[i * oc + j], i as f32, "row {i} col {j}");
            }
        }
    }

    #[test]
    fn row_bands_single_thread_and_empty() {
        let mut out = vec![0.0f32; 4];
        for_each_row_band(&mut out, &[1.0, 2.0], 2, 1, 2, 1, |row0, ob, _| {
            assert_eq!(row0, 0);
            ob.fill(5.0);
        });
        assert_eq!(out, vec![5.0; 4]);
        let mut empty: Vec<f32> = vec![];
        for_each_row_band(&mut empty, &[], 0, 4, 4, 8, |_, _, _| panic!("no rows, no bands"));
    }

    #[test]
    fn row_bands_on_private_pool_match_serial() {
        let pool = Pool::new(3);
        let (m, xc, oc) = (10, 4, 3);
        let x: Vec<f32> = (0..m * xc).map(|v| (v as f32).sin()).collect();
        let mut serial = vec![0.0f32; m * oc];
        for_each_row_band_on(&pool, &mut serial, &x, m, xc, oc, 1, |row0, ob, xb| {
            for i in 0..ob.len() / oc {
                for j in 0..oc {
                    ob[i * oc + j] = xb[i * xc] * (row0 + i + j) as f32;
                }
            }
        });
        let mut pooled = vec![0.0f32; m * oc];
        for_each_row_band_on(&pool, &mut pooled, &x, m, xc, oc, 3, |row0, ob, xb| {
            for i in 0..ob.len() / oc {
                for j in 0..oc {
                    ob[i * oc + j] = xb[i * xc] * (row0 + i + j) as f32;
                }
            }
        });
        assert_eq!(pooled, serial, "pooled bands must be bitwise identical to serial");
        assert!(pool.stats().wakeups > 0, "the 3-wide pool must actually run bands");
    }

    #[test]
    fn ensure_cap_counts_reuse() {
        let mut stats = ScratchStats::default();
        let mut buf = Vec::new();
        ensure_cap(&mut buf, 64, &mut stats);
        assert_eq!((stats.allocs, stats.reuses), (1, 0));
        assert_eq!(buf.len(), 64);
        ensure_cap(&mut buf, 32, &mut stats);
        ensure_cap(&mut buf, 64, &mut stats);
        assert_eq!((stats.allocs, stats.reuses), (1, 2), "warm buffer must not realloc");
    }

    #[test]
    fn ensure_cap_i16_counts_reuse() {
        let mut stats = ScratchStats::default();
        let mut buf: Vec<i16> = Vec::new();
        ensure_cap_i16(&mut buf, 64, &mut stats);
        assert_eq!((stats.allocs, stats.reuses), (1, 0));
        assert_eq!(buf.len(), 64);
        ensure_cap_i16(&mut buf, 32, &mut stats);
        ensure_cap_i16(&mut buf, 64, &mut stats);
        assert_eq!((stats.allocs, stats.reuses), (1, 2), "warm i16 buffer must not realloc");
    }

    #[test]
    fn i16_row_bands_cover_all_rows_once() {
        let (m, xc, oc) = (7, 3, 2);
        let x: Vec<i16> = (0..(m * xc) as i16).collect();
        let pool = Pool::new(3);
        let mut out = vec![0.0f32; m * oc];
        for_each_row_band_i16_on(&pool, &mut out, &x, m, xc, oc, 3, |row0, ob, xb| {
            let rows = ob.len() / oc;
            assert_eq!(xb.len(), rows * xc);
            for i in 0..rows {
                for j in 0..oc {
                    ob[i * oc + j] += (row0 + i) as f32 + xb[i * xc] as f32;
                }
            }
        });
        for i in 0..m {
            for j in 0..oc {
                assert_eq!(out[i * oc + j], (i + i * xc) as f32, "row {i} col {j}");
            }
        }
    }

    #[test]
    fn layer_peaks_track_component_maxima() {
        let mut s = Scratch::new();
        s.last.grow(100, 0, 400);
        s.note_layer("c1w");
        s.last.grow(50, 20, 800);
        s.note_layer("c1w");
        s.last.grow(10, 10, 10);
        s.note_layer("f1w");
        let peaks = s.layer_peaks();
        assert_eq!(peaks.len(), 2);
        assert_eq!(peaks[0].0, "c1w");
        assert_eq!(
            peaks[0].1,
            LayerPeak { patch_bytes: 400, pad_bytes: 80, act_bytes: 3200 },
            "per-component max over both passes, in bytes"
        );
        assert_eq!(peaks[1].1.act_bytes, 40);
        // `last` is drained by note_layer
        assert_eq!(s.last, LayerPeak::default());
    }
}
