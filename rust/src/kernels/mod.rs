//! The compute-kernel layer — the serving hot path.
//!
//! Everything under `kernels/` exists to make inference run as fast as the
//! host hardware allows while staying dependency-free (std only):
//!
//! * [`blocked`] — cache-blocked f32 GEMM with a 4x8 register-accumulator
//!   microtile, parallelized over row bands with scoped threads.  This is
//!   what [`crate::tensor::ops::matmul`] (and therefore im2col conv and the
//!   fp32 model head) dispatches to; the original ikj loop survives as
//!   [`crate::tensor::ops::matmul_naive`], the bitwise oracle.
//! * [`qgemm`] — the code-domain GEMM, in two generations.  v1
//!   ([`PackedQTensor`] + [`qgemm`](qgemm::qgemm)) is the retained
//!   single-thread reference: zero codes dropped at pack time, shift/add
//!   contribution tables, hoisted per-group alpha.  v2
//!   ([`PackedQTensorV2`] + [`qgemm2`]) repacks the surviving codes into six
//!   per-level *offset planes* per (group, column) cell, so the inner loop is
//!   a straight contiguous sum per plane (lane-friendly for the
//!   autovectorizer, no 8-way LUT select, half the bytes per entry) and the
//!   row dimension is split across scoped threads with the same band scheme
//!   as the blocked f32 kernel.  v2 is what the serving engine runs.
//! * [`qconv`] — the fused conv pipeline: im2col patches are staged
//!   chunk-by-chunk into a reusable [`Scratch`] arena and multiplied
//!   band-by-band on the plane-packed qgemm (or the f32 microkernel), so the
//!   full patch matrix is never materialized and steady-state serving
//!   allocates nothing per request.
//!
//! The remaining member of the kernel set lives with the quantizer it
//! accelerates: [`crate::quant::sigma_fast`] scores the whole 19x8
//! (gamma, delta) grid from sorted-|w| prefix sums in O(sort) instead of 152
//! full assignment passes.
//!
//! `benches/bench_kernels.rs` tracks all of these against their naive
//! oracles and emits `BENCH_kernels.json` for cross-PR perf trajectories.

pub mod blocked;
pub mod qconv;
pub mod qgemm;

pub use qconv::{fconv_into, qconv, qconv_into};
pub use qgemm::{
    qgemm, qgemm2, qgemm2_into, qgemm2_qt, qgemm2_threads, qgemm_qt, PackedQTensor,
    PackedQTensorV2,
};

/// Decide how many scoped worker threads a row-parallel kernel should use:
/// one unless the total inner-loop work amortizes spawn cost, then at most
/// one per row, per core, capped at 16 (diminishing returns on the band
/// sizes this crate serves).
pub fn threads_for_rows(m: usize, total_ops: usize, par_threshold: usize) -> usize {
    if total_ops < par_threshold || m < 2 {
        return 1;
    }
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    cores.min(m).min(16)
}

/// Split `out` (`m` rows of `out_cols`) and `x` (`m` rows of `x_cols`) into
/// matching row bands and run `band(first_row, out_band, x_band)` on each
/// from its own scoped thread.  Bands partition whole rows, so per-element
/// reduction order is untouched: a threaded run is bitwise identical to
/// `band(0, out, x)`.
pub fn for_each_row_band<F>(
    out: &mut [f32],
    x: &[f32],
    m: usize,
    x_cols: usize,
    out_cols: usize,
    nthreads: usize,
    band: F,
) where
    F: Fn(usize, &mut [f32], &[f32]) + Sync,
{
    if m == 0 {
        return;
    }
    if nthreads <= 1 || x_cols == 0 || out_cols == 0 {
        band(0, out, x);
        return;
    }
    let rows_per_band = m.div_ceil(nthreads);
    std::thread::scope(|scope| {
        for (bi, (oband, xband)) in out
            .chunks_mut(rows_per_band * out_cols)
            .zip(x.chunks(rows_per_band * x_cols))
            .enumerate()
        {
            let bref = &band;
            scope.spawn(move || bref(bi * rows_per_band, oband, xband));
        }
    });
}

/// Counters for the scratch arena: how often a kernel found a warm buffer
/// already big enough (`reuses`) vs had to grow one (`allocs`).  In steady
/// state serving, `allocs` stops moving after the first request.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScratchStats {
    pub reuses: u64,
    pub allocs: u64,
}

/// Reusable per-worker buffers for the fused serving pipeline.  One arena
/// lives on each inference worker (and inside every one-shot `forward`), so
/// im2col patch staging, SAME-conv padding, and layer activations stop
/// allocating once the buffers have grown to the largest layer.
#[derive(Debug, Default)]
pub struct Scratch {
    /// im2col patch staging — per-thread chunk slabs, never the full matrix.
    pub patches: Vec<f32>,
    /// SAME-conv zero-pad staging.
    pub padded: Vec<f32>,
    /// Activation ping buffer (layer inputs / pooled outputs).
    pub act_a: Vec<f32>,
    /// Activation pong buffer (conv / dense outputs before pooling).
    pub act_b: Vec<f32>,
    pub stats: ScratchStats,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }
}

/// Grow `buf` to hold at least `len` elements without touching existing
/// contents (callers overwrite their slice before reading it).  Counts the
/// warm-hit/grow in `stats`.
pub fn ensure_cap(buf: &mut Vec<f32>, len: usize, stats: &mut ScratchStats) {
    if buf.len() >= len {
        stats.reuses += 1;
        return;
    }
    if buf.capacity() >= len {
        stats.reuses += 1;
    } else {
        stats.allocs += 1;
    }
    buf.resize(len, 0.0);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threads_for_rows_thresholds() {
        assert_eq!(threads_for_rows(64, 100, 1 << 20), 1, "small work stays serial");
        assert_eq!(threads_for_rows(1, usize::MAX, 1), 1, "one row stays serial");
        let t = threads_for_rows(64, 1 << 22, 1 << 20);
        assert!(t >= 1 && t <= 16);
        assert!(threads_for_rows(2, 1 << 22, 1 << 20) <= 2, "never more threads than rows");
    }

    #[test]
    fn row_bands_cover_all_rows_once() {
        let (m, xc, oc) = (7, 3, 2);
        let x: Vec<f32> = (0..m * xc).map(|v| v as f32).collect();
        let mut out = vec![0.0f32; m * oc];
        // band kernel: out[i][j] = first_row + local_i (checks offsets line up)
        for_each_row_band(&mut out, &x, m, xc, oc, 3, |row0, ob, xb| {
            let rows = ob.len() / oc;
            assert_eq!(xb.len(), rows * xc);
            for i in 0..rows {
                for j in 0..oc {
                    ob[i * oc + j] += (row0 + i) as f32;
                }
            }
        });
        for i in 0..m {
            for j in 0..oc {
                assert_eq!(out[i * oc + j], i as f32, "row {i} col {j}");
            }
        }
    }

    #[test]
    fn row_bands_single_thread_and_empty() {
        let mut out = vec![0.0f32; 4];
        for_each_row_band(&mut out, &[1.0, 2.0], 2, 1, 2, 1, |row0, ob, _| {
            assert_eq!(row0, 0);
            ob.fill(5.0);
        });
        assert_eq!(out, vec![5.0; 4]);
        let mut empty: Vec<f32> = vec![];
        for_each_row_band(&mut empty, &[], 0, 4, 4, 8, |_, _, _| panic!("no rows, no bands"));
    }

    #[test]
    fn ensure_cap_counts_reuse() {
        let mut stats = ScratchStats::default();
        let mut buf = Vec::new();
        ensure_cap(&mut buf, 64, &mut stats);
        assert_eq!((stats.allocs, stats.reuses), (1, 0));
        assert_eq!(buf.len(), 64);
        ensure_cap(&mut buf, 32, &mut stats);
        ensure_cap(&mut buf, 64, &mut stats);
        assert_eq!((stats.allocs, stats.reuses), (1, 2), "warm buffer must not realloc");
    }
}
