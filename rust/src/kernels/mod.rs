//! The compute-kernel layer — the serving hot path.
//!
//! Everything under `kernels/` exists to make inference run as fast as the
//! host hardware allows while staying dependency-free (std only):
//!
//! * [`blocked`] — cache-blocked, scoped-thread-parallel f32 GEMM.  This is
//!   what [`crate::tensor::ops::matmul`] (and therefore `im2col` conv and the
//!   fp32 model head) dispatches to; the original ikj loop survives as
//!   [`crate::tensor::ops::matmul_naive`], the bitwise oracle.
//! * [`qgemm`] — the code-domain GEMM.  It consumes a packed
//!   [`crate::quant::QuantizedTensor`] directly: zero codes are skipped at
//!   pack time, each surviving code contributes via sign/shift-built tables
//!   (no multiplies in the inner loop), and the per-group `alpha` scales each
//!   partial sum exactly once.  This turns the paper's decode hardware story
//!   (Table II: shift + invert + skip) into actual host-side speedup, and is
//!   what [`crate::runtime::host::QuantizedEngine`] runs quantized layers on.
//!
//! The third member of this PR's kernel set lives with the quantizer it
//! accelerates: [`crate::quant::sigma_fast`] scores the whole 19x8
//! (gamma, delta) grid from sorted-|w| prefix sums in O(sort) instead of 152
//! full assignment passes.
//!
//! `benches/bench_kernels.rs` tracks all three against their naive oracles
//! and emits `BENCH_kernels.json` for cross-PR perf trajectories.

pub mod blocked;
pub mod qgemm;

pub use qgemm::{qgemm, qgemm_qt, PackedQTensor};
