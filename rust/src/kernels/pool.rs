//! Persistent worker pool for the row-band kernels (std only).
//!
//! Before this module existed, every parallel matmul paid a
//! `std::thread::scope` spawn + join per call.  At the small batch sizes
//! edge serving sees, that fixed dispatch overhead rivals the kernel work
//! itself.  Here the workers are spawned once, then *parked* on a condvar;
//! dispatching a warm kernel costs one mutex/condvar wakeup per band instead
//! of a thread spawn, and steady-state serving spawns **zero** threads per
//! request (the [`PoolStats::spawns`] counter freezes after initialization,
//! exactly like `ScratchStats::allocs` freezes after warm-up).
//!
//! Design:
//!
//! * **Per-worker job slots.**  Each worker owns one `Slot` (a mutex +
//!   condvar).  A caller leases idle workers from a free-list, posts one
//!   band job into each leased slot, runs the remaining bands itself, and
//!   waits for the leased workers to report back.  Because leasing is
//!   non-blocking — a caller takes only workers that are currently idle and
//!   runs everything else inline — two engines dispatching concurrently
//!   simply split the worker set and can never deadlock, even if a band
//!   function itself re-enters the pool.
//! * **Epoch/generation barrier.**  Every slot carries a `seq` generation
//!   counter bumped when a job is posted and a `done` counter the worker
//!   sets when it finishes.  `run_bands` returns only after `done` has
//!   caught up with `seq` on every leased slot, so the band closure (which
//!   borrows the caller's stack) is provably never used after `run_bands`
//!   returns — that barrier is what makes the internal lifetime erasure
//!   sound.
//! * **Identical banding.**  The pool only *executes* band indices; the
//!   whole-row band partitioning (and therefore every per-element reduction
//!   order) is fixed by the caller exactly as the scoped-thread
//!   `for_each_row_band` fixed it, so a pooled run stays bitwise identical
//!   to a single-thread run.
//! * **Sticky band pinning.**  Leasing used to hand bands to *arbitrary*
//!   idle workers, so the worker that computed rows 8..16 of layer 1 rarely
//!   saw those rows again in layer 2 — every layer restarted cold on both
//!   the activation slice and the worker's cache.  In pinned mode (the
//!   default, see [`Pool::set_pinned`] and `PALLAS_POOL_PIN`) band `b`
//!   prefers worker `(b - 1) % workers` and falls back to any idle worker
//!   only when the preferred one is busy; [`PoolStats::pin_hits`] /
//!   [`PoolStats::pin_misses`] count how often locality held.  A small
//!   affinity table additionally *persists* the band→worker assignment
//!   across layers and warm forwards: once a band lands anywhere — static
//!   seat or fallback — later dispatches prefer that same worker, so one
//!   transient collision does not strand a band's rows on a cold cache for
//!   the rest of the serving session.  Only the executing thread changes —
//!   banding, and therefore every reduction order, is untouched, so pinned
//!   and redealt runs are bitwise identical.
//! * **Sizing.**  The lazily-initialized global pool
//!   ([`Pool::global`], via `OnceLock`) sizes itself to
//!   `available_parallelism` capped at [`MAX_POOL_THREADS`].  The
//!   `PALLAS_POOL_THREADS` environment variable overrides the size (read
//!   once, at first use); `PALLAS_POOL_THREADS=1` keeps zero workers and
//!   every kernel degrades to the serial single-thread path.  A value that
//!   does not parse as an integer >= 1 is rejected ([`parse_pool_threads`])
//!   — the server validates at startup ([`validate_env`]) and refuses to
//!   boot rather than run at a silently-wrong width.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Hard cap on pool width (caller + workers): beyond this the band sizes
/// this crate serves see diminishing returns, and it bounds the damage of a
/// typo'd `PALLAS_POOL_THREADS`.
pub const MAX_POOL_THREADS: usize = 16;

/// Size of the cross-forward band→worker affinity table.  Band lease slot
/// `i` remembers the worker it last ran on in `affinity[i % AFFINITY_BANDS]`
/// so the *next* dispatch of the same band layout — the next layer of the
/// same forward, or the next warm forward entirely — prefers that worker
/// again even when the static `i % workers` seat was busy the first time.
/// Kernel dispatches band far fewer than 256 ways, so wrapping never aliases
/// in practice.
const AFFINITY_BANDS: usize = 256;

/// One posted band job: the type-erased band closure and the band index the
/// worker must run.  The `'static` is a lie told by [`Pool::run_bands`]'s
/// lifetime erasure; its epoch barrier guarantees the reference is never
/// dereferenced after `run_bands` returns.
struct Job {
    f: &'static (dyn Fn(usize) + Sync),
    band: usize,
}

/// Worker-side state guarded by the slot mutex.
#[derive(Default)]
struct SlotState {
    /// The posted job, taken by the worker exactly once per generation.
    job: Option<Job>,
    /// Generation counter: bumped by the caller when a job is posted.
    seq: u64,
    /// Completion counter: set to `seq` by the worker when the job is done.
    done: u64,
    /// The job's band closure panicked (re-raised on the caller).
    panicked: bool,
    /// Pool is being dropped; the worker exits once its slot is drained.
    shutdown: bool,
}

/// One parked worker's mailbox: callers post under the mutex and signal the
/// condvar; the worker signals the same condvar when the job completes.
#[derive(Default)]
struct Slot {
    state: Mutex<SlotState>,
    cv: Condvar,
}

/// Monotonic pool counters (see [`Pool::stats`]).  In steady-state serving
/// `spawns` is flat — threads are created only when the pool is built —
/// while `wakeups` and `jobs` keep climbing with traffic.  With band
/// pinning enabled (the default), `pin_hits` vs `pin_misses` shows how
/// often a band actually landed on its preferred (cache-warm) worker: a
/// lone dispatching engine should hit nearly always, while concurrent
/// engines competing for workers show up as misses.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Worker threads ever spawned (frozen after pool construction).
    pub spawns: u64,
    /// Band jobs handed to a parked worker (one condvar wakeup each).
    pub wakeups: u64,
    /// Band jobs executed in total, inline bands included.
    pub jobs: u64,
    /// Pinned leases that landed on the band's preferred worker.
    pub pin_hits: u64,
    /// Pinned leases that fell back to an arbitrary idle worker (preferred
    /// one busy).  Both counters stay 0 with pinning disabled.
    pub pin_misses: u64,
}

struct Stats {
    spawns: AtomicU64,
    wakeups: AtomicU64,
    jobs: AtomicU64,
    pin_hits: AtomicU64,
    pin_misses: AtomicU64,
}

/// The persistent worker pool.  See the module docs for the design; see
/// [`Pool::global`] for the process-wide instance the kernels use.
pub struct Pool {
    slots: Vec<std::sync::Arc<Slot>>,
    /// Indices of currently idle workers (leased/returned by `run_bands`).
    free: Mutex<Vec<usize>>,
    /// Band-pinning mode: lease band `b` to worker `(b - 1) % workers` when
    /// that worker is idle, so the same row ranges land on the same worker
    /// across layers and warm forwards (see [`Pool::set_pinned`]).
    pinned: std::sync::atomic::AtomicBool,
    /// Cross-forward affinity memory: `affinity[i % AFFINITY_BANDS]` holds
    /// the worker lease slot `i` actually ran on last time (or `usize::MAX`
    /// before the first dispatch).  In pinned mode the remembered worker
    /// *is* the preferred worker, so a band that once fell back to an
    /// arbitrary idle worker keeps returning to that same worker — and its
    /// warmed cache lines — in every later layer and warm forward, instead
    /// of oscillating back toward the static seat.
    affinity: Vec<AtomicUsize>,
    stats: Stats,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool")
            .field("width", &self.width())
            .field("stats", &self.stats())
            .finish()
    }
}

/// Resolve a `PALLAS_POOL_THREADS`-style override: unset falls back to
/// `default`; a parseable value >= 1 is clamped to [`MAX_POOL_THREADS`];
/// anything else — garbage, empty, `0` — is an **error**.  A typo'd
/// override used to fall back silently, which meant a misconfigured
/// deployment ran at the wrong compute width with no signal; now the server
/// refuses to start and says why.
pub fn parse_pool_threads(raw: Option<&str>, default: usize) -> Result<usize, String> {
    match raw {
        None => Ok(default.clamp(1, MAX_POOL_THREADS)),
        Some(s) => match s.trim().parse::<usize>() {
            Ok(n) if n >= 1 => Ok(n.min(MAX_POOL_THREADS)),
            _ => Err(format!(
                "PALLAS_POOL_THREADS must be an integer >= 1 (total compute width \
                 including the dispatching thread), got {s:?}"
            )),
        },
    }
}

/// Resolve a `PALLAS_POOL_PIN`-style flag: unset and anything but an
/// explicit off-value means pinned (the default).
pub fn parse_pool_pin(raw: Option<&str>) -> bool {
    !matches!(
        raw.map(str::trim),
        Some("0") | Some("off") | Some("false") | Some("no")
    )
}

/// Validate the pool environment without building a pool — the server calls
/// this at startup so a malformed `PALLAS_POOL_THREADS` fails the boot with
/// a clear error instead of panicking at the first parallel kernel call.
pub fn validate_env() -> Result<(), String> {
    parse_pool_threads(
        std::env::var("PALLAS_POOL_THREADS").ok().as_deref(),
        default_threads(),
    )
    .map(|_| ())
}

fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1)
        .min(MAX_POOL_THREADS)
}

static GLOBAL: OnceLock<Pool> = OnceLock::new();

impl Pool {
    /// The process-wide pool every kernel entry point defaults to.  Built
    /// lazily on first use (`OnceLock`), sized by `PALLAS_POOL_THREADS` or
    /// `available_parallelism` capped at [`MAX_POOL_THREADS`].
    pub fn global() -> &'static Pool {
        GLOBAL.get_or_init(Pool::from_env)
    }

    /// Build a pool sized from the environment (the global pool's recipe,
    /// constructible privately so tests can pin the env override).  Panics
    /// on a malformed `PALLAS_POOL_THREADS` — the server validates the
    /// environment first ([`validate_env`]) so it can fail startup
    /// gracefully instead.
    pub fn from_env() -> Pool {
        let threads = parse_pool_threads(
            std::env::var("PALLAS_POOL_THREADS").ok().as_deref(),
            default_threads(),
        )
        .unwrap_or_else(|e| panic!("{e}"));
        let pool = Pool::new(threads);
        pool.set_pinned(parse_pool_pin(std::env::var("PALLAS_POOL_PIN").ok().as_deref()));
        pool
    }

    /// Build a pool of total width `threads` (the caller counts as one, so
    /// `threads - 1` workers are spawned and parked; `threads <= 1` spawns
    /// none and [`Pool::run_bands`] runs everything serially).
    pub fn new(threads: usize) -> Pool {
        let nworkers = threads.clamp(1, MAX_POOL_THREADS) - 1;
        let pool = Pool {
            slots: (0..nworkers).map(|_| std::sync::Arc::new(Slot::default())).collect(),
            free: Mutex::new((0..nworkers).collect()),
            pinned: std::sync::atomic::AtomicBool::new(true),
            affinity: (0..AFFINITY_BANDS).map(|_| AtomicUsize::new(usize::MAX)).collect(),
            stats: Stats {
                spawns: AtomicU64::new(0),
                wakeups: AtomicU64::new(0),
                jobs: AtomicU64::new(0),
                pin_hits: AtomicU64::new(0),
                pin_misses: AtomicU64::new(0),
            },
            handles: Mutex::new(Vec::with_capacity(nworkers)),
        };
        let mut handles = Vec::with_capacity(nworkers);
        for (i, slot) in pool.slots.iter().enumerate() {
            let slot = slot.clone();
            pool.stats.spawns.fetch_add(1, Ordering::Relaxed);
            let h = std::thread::Builder::new()
                .name(format!("pallas-pool-{i}"))
                .spawn(move || worker_loop(&slot))
                .expect("spawn pool worker");
            handles.push(h);
        }
        *pool.handles.lock().unwrap() = handles;
        pool
    }

    /// Total compute width: the dispatching caller plus the parked workers.
    pub fn width(&self) -> usize {
        self.slots.len() + 1
    }

    /// Parked worker count (`width - 1`).
    pub fn workers(&self) -> usize {
        self.slots.len()
    }

    /// Enable or disable sticky band pinning (default: enabled; the global
    /// pool additionally honors `PALLAS_POOL_PIN=0`).  With pinning on,
    /// [`Pool::run_bands`] leases band `b` to the worker it last ran on
    /// (the affinity table; `(b - 1) % workers` before any history exists)
    /// whenever that worker is idle, so a forward pass that dispatches the
    /// same band layout layer after layer — and forward after forward —
    /// keeps each row range on the same worker, and its slice of
    /// activations in that worker's cache.  The band *partitioning* never
    /// changes, only which thread executes a band, so pinned and redealt
    /// runs are bitwise identical.
    pub fn set_pinned(&self, on: bool) {
        self.pinned.store(on, Ordering::Relaxed);
    }

    /// Whether sticky band pinning is enabled.
    pub fn is_pinned(&self) -> bool {
        self.pinned.load(Ordering::Relaxed)
    }

    /// Snapshot of the pool counters.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            spawns: self.stats.spawns.load(Ordering::Relaxed),
            wakeups: self.stats.wakeups.load(Ordering::Relaxed),
            jobs: self.stats.jobs.load(Ordering::Relaxed),
            pin_hits: self.stats.pin_hits.load(Ordering::Relaxed),
            pin_misses: self.stats.pin_misses.load(Ordering::Relaxed),
        }
    }

    /// Run `f(0), f(1), .., f(nbands - 1)`, each call exactly once, spread
    /// over idle pool workers plus the calling thread.
    ///
    /// The caller always runs band 0 (and every band no worker was free
    /// for), so a width-1 pool — or a fully leased-out one — degrades to the
    /// serial loop.  Band functions must partition their data by band index;
    /// the pool adds no ordering of its own, so results are identical to the
    /// serial loop no matter how bands land on workers.
    ///
    /// Panics in `f` (on either a worker or the caller) are re-raised here
    /// after the barrier, never lost, and never wedge a worker.
    pub fn run_bands(&self, nbands: usize, f: &(dyn Fn(usize) + Sync)) {
        if nbands == 0 {
            return;
        }
        self.stats.jobs.fetch_add(nbands as u64, Ordering::Relaxed);
        if nbands == 1 || self.slots.is_empty() {
            for b in 0..nbands {
                f(b);
            }
            return;
        }
        // lease whatever is idle, never more than the spare bands; leasing
        // is non-blocking, which is what makes concurrent callers (and
        // re-entrant band functions) deadlock-free.  `leased[i]` runs band
        // `i + 1`: in pinned mode band `b` prefers worker `(b - 1) %
        // workers` — a stable mapping, so repeated dispatches of the same
        // band layout reuse each worker's cache-warm rows — and falls back
        // to any idle worker (a pin miss) when the preferred one is busy.
        let leased: Vec<usize> = {
            let mut free = self.free.lock().unwrap();
            let take = free.len().min(nbands - 1);
            if take > 0 && self.pinned.load(Ordering::Relaxed) {
                let mut leased = vec![usize::MAX; take];
                let mut hits = 0u64;
                for (i, w) in leased.iter_mut().enumerate() {
                    // prefer the worker this slot actually ran on last time
                    // (cross-forward affinity); before any history exists
                    // that is the static seat `i % workers`, so an
                    // uncontended pool behaves exactly as pure static
                    // pinning did
                    let remembered = self.affinity[i % AFFINITY_BANDS].load(Ordering::Relaxed);
                    let pref = if remembered < self.slots.len() {
                        remembered
                    } else {
                        i % self.slots.len()
                    };
                    if let Some(pos) = free.iter().position(|&f| f == pref) {
                        free.swap_remove(pos);
                        *w = pref;
                        hits += 1;
                        self.affinity[i % AFFINITY_BANDS].store(pref, Ordering::Relaxed);
                    }
                }
                for (i, w) in
                    leased.iter_mut().enumerate().filter(|(_, w)| **w == usize::MAX)
                {
                    *w = free.pop().expect("take <= free.len() idle workers");
                    // remember the fallback too: next dispatch of this band
                    // layout goes straight back to the worker whose cache
                    // this band just warmed
                    self.affinity[i % AFFINITY_BANDS].store(*w, Ordering::Relaxed);
                }
                self.stats.pin_hits.fetch_add(hits, Ordering::Relaxed);
                self.stats.pin_misses.fetch_add(take as u64 - hits, Ordering::Relaxed);
                leased
            } else {
                let at = free.len() - take;
                free.split_off(at)
            }
        };
        // SAFETY (lifetime erasure): the erased reference is dereferenced
        // only by leased workers, and the epoch barrier below does not let
        // this function return before every leased worker has set
        // `done == seq` for the generation posted here — so the borrow of
        // `f` (and everything it captures) strictly outlives every use.
        let fj: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), _>(f) };
        let mut posted = Vec::with_capacity(leased.len());
        for (i, &w) in leased.iter().enumerate() {
            let slot = &self.slots[w];
            let mut st = slot.state.lock().unwrap();
            st.seq += 1;
            st.job = Some(Job { f: fj, band: i + 1 });
            posted.push(st.seq);
            slot.cv.notify_all();
        }
        self.stats.wakeups.fetch_add(leased.len() as u64, Ordering::Relaxed);
        // the caller is a worker too: band 0, plus the bands nobody was
        // free to take.  Catch a panic so an unwinding caller still waits
        // out the barrier before the band closure's stack frame dies.
        let caller = catch_unwind(AssertUnwindSafe(|| {
            f(0);
            for b in leased.len() + 1..nbands {
                f(b);
            }
        }));
        // epoch barrier: every leased worker must finish its generation
        let mut worker_panicked = false;
        for (&w, &seq) in leased.iter().zip(&posted) {
            let slot = &self.slots[w];
            let mut st = slot.state.lock().unwrap();
            while st.done < seq {
                st = slot.cv.wait(st).unwrap();
            }
            worker_panicked |= std::mem::take(&mut st.panicked);
        }
        self.free.lock().unwrap().extend_from_slice(&leased);
        if let Err(p) = caller {
            resume_unwind(p);
        }
        assert!(!worker_panicked, "kernel pool worker panicked while running a band");
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        for slot in &self.slots {
            let mut st = slot.state.lock().unwrap();
            st.shutdown = true;
            slot.cv.notify_all();
        }
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

/// A parked worker: wait for a job on the slot condvar, run it, publish
/// `done`, park again.  A panicking band closure is caught so the worker
/// (and the caller's barrier) survive; the flag is re-raised caller-side.
fn worker_loop(slot: &Slot) {
    loop {
        let job = {
            let mut st = slot.state.lock().unwrap();
            loop {
                if let Some(job) = st.job.take() {
                    break job;
                }
                if st.shutdown {
                    return;
                }
                st = slot.cv.wait(st).unwrap();
            }
        };
        let ok = catch_unwind(AssertUnwindSafe(|| (job.f)(job.band))).is_ok();
        let mut st = slot.state.lock().unwrap();
        st.done = st.seq;
        st.panicked |= !ok;
        slot.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn runs_every_band_exactly_once() {
        let pool = Pool::new(4);
        for nbands in [1usize, 2, 3, 4, 9] {
            let hits: Vec<AtomicUsize> = (0..nbands).map(|_| AtomicUsize::new(0)).collect();
            pool.run_bands(nbands, &|b| {
                hits[b].fetch_add(1, Ordering::Relaxed);
            });
            for (b, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "band {b} of {nbands}");
            }
        }
        let s = pool.stats();
        assert_eq!(s.spawns, 3, "width-4 pool spawns exactly 3 workers, once");
        assert_eq!(s.jobs, 1 + 2 + 3 + 4 + 9);
        assert!(s.wakeups > 0);
    }

    #[test]
    fn width_one_pool_is_serial() {
        let pool = Pool::new(1);
        assert_eq!(pool.workers(), 0);
        let hits: Vec<AtomicUsize> = (0..5).map(|_| AtomicUsize::new(0)).collect();
        pool.run_bands(5, &|b| {
            hits[b].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        let s = pool.stats();
        assert_eq!((s.spawns, s.wakeups), (0, 0), "serial pool never spawns or wakes");
        assert_eq!(s.jobs, 5);
    }

    #[test]
    fn spawns_freeze_after_construction() {
        let pool = Pool::new(3);
        let cold = pool.stats().spawns;
        for _ in 0..50 {
            pool.run_bands(3, &|_| {});
        }
        let warm = pool.stats();
        assert_eq!(warm.spawns, cold, "warm dispatches must not spawn threads");
        assert_eq!(warm.jobs, 150);
    }

    #[test]
    fn concurrent_callers_share_the_pool_without_deadlock() {
        let pool = Pool::new(4);
        let total = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..100 {
                        pool.run_bands(4, &|_| {
                            total.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 100 * 4);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = Pool::new(2);
        let hit = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_bands(2, &|b| {
                if b == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(hit.is_err(), "worker panic must reach the caller");
        // the pool is still usable afterwards
        let n = AtomicUsize::new(0);
        pool.run_bands(2, &|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn parse_pool_threads_override() {
        assert_eq!(parse_pool_threads(Some("1"), 8), Ok(1));
        assert_eq!(parse_pool_threads(Some(" 4 "), 8), Ok(4));
        assert_eq!(parse_pool_threads(Some("999"), 8), Ok(MAX_POOL_THREADS));
        assert_eq!(parse_pool_threads(None, 8), Ok(8));
        assert_eq!(parse_pool_threads(None, 0), Ok(1), "default itself is clamped");
    }

    #[test]
    fn parse_pool_threads_rejects_garbage_loudly() {
        for bad in ["nope", "0", "", "  ", "-3", "1.5", "1e2"] {
            let got = parse_pool_threads(Some(bad), 8);
            let err = got.expect_err(&format!("{bad:?} must be rejected, not defaulted"));
            assert!(
                err.contains("PALLAS_POOL_THREADS") && err.contains(bad.trim()),
                "error must name the variable and echo the value: {err}"
            );
        }
    }

    #[test]
    fn parse_pool_pin_flag() {
        assert!(parse_pool_pin(None), "pinning defaults on");
        assert!(parse_pool_pin(Some("1")));
        for off in ["0", "off", "false", "no", " 0 "] {
            assert!(!parse_pool_pin(Some(off)), "{off:?} must disable pinning");
        }
    }

    #[test]
    fn pinned_leasing_is_sticky_when_workers_are_free() {
        let pool = Pool::new(4);
        assert!(pool.is_pinned(), "pinning is the default mode");
        for _ in 0..20 {
            pool.run_bands(4, &|_| {});
        }
        let s = pool.stats();
        // a lone caller with all workers idle lands every band on its
        // preferred worker: 3 leased bands per call, all hits
        assert_eq!(s.pin_hits, 60, "every lease must hit its preferred worker");
        assert_eq!(s.pin_misses, 0);
    }

    #[test]
    fn affinity_persists_a_fallback_assignment_across_forwards() {
        // width-4 pool: 3 workers, run_bands(4) leases 3 bands.  Steal
        // worker 0 from the free list so lease slot 0's static seat is
        // "busy" for the first dispatch, then watch the affinity table
        // re-route later forwards to the worker the band actually warmed.
        let pool = Pool::new(4);
        let stolen = {
            let mut free = pool.free.lock().unwrap();
            let pos = free.iter().position(|&w| w == 0).unwrap();
            free.swap_remove(pos)
        };
        assert_eq!(stolen, 0);

        // forward A: slot 0 wants worker 0 (no history) -> busy, falls back
        // to worker 2 and remembers it; slot 1 hits worker 1.  take = 2.
        pool.run_bands(4, &|_| {});
        let a = pool.stats();
        assert_eq!((a.pin_hits, a.pin_misses), (1, 1), "slot 0 must miss its cold seat");
        assert_eq!(pool.affinity[0].load(Ordering::Relaxed), 2, "fallback must be remembered");
        assert_eq!(pool.affinity[1].load(Ordering::Relaxed), 1);

        // worker 0 comes back; forward B leases all 3 slots.  Slot 0 now
        // *prefers* worker 2 (affinity) and hits; slot 1 hits worker 1;
        // slot 2's static seat 2 is taken by slot 0, so it falls back to
        // worker 0 and remembers that.
        pool.free.lock().unwrap().push(stolen);
        pool.run_bands(4, &|_| {});
        let b = pool.stats();
        assert_eq!((b.pin_hits - a.pin_hits, b.pin_misses - a.pin_misses), (2, 1));
        assert_eq!(pool.affinity[2].load(Ordering::Relaxed), 0);

        // forward C: the table now covers all three slots (2, 1, 0) — a
        // permutation of the workers — so every lease is a hit and the
        // assignment is stable from here on.
        pool.run_bands(4, &|_| {});
        let c = pool.stats();
        assert_eq!((c.pin_hits - b.pin_hits, c.pin_misses - b.pin_misses), (3, 0));
        assert_eq!(
            [0, 1, 2].map(|s| pool.affinity[s].load(Ordering::Relaxed)),
            [2, 1, 0],
            "the realized band->worker permutation must be frozen"
        );
    }

    #[test]
    fn redealt_mode_counts_no_pin_stats_and_stays_bitwise() {
        let pool = Pool::new(3);
        pool.set_pinned(false);
        assert!(!pool.is_pinned());
        // band b writes its own disjoint cells; values must not depend on
        // which worker ran the band
        let out = std::sync::Mutex::new(vec![0.0f32; 6]);
        pool.run_bands(3, &|b| {
            let mut o = out.lock().unwrap();
            o[b * 2] = b as f32;
            o[b * 2 + 1] = (b * 10) as f32;
        });
        assert_eq!(*out.lock().unwrap(), [0.0, 0.0, 1.0, 10.0, 2.0, 20.0]);
        let s = pool.stats();
        assert_eq!((s.pin_hits, s.pin_misses), (0, 0), "redealt mode never counts pins");
    }

    #[test]
    fn pinned_and_redealt_runs_are_bitwise_identical() {
        // same band partition, only executor placement differs
        let run = |pinned: bool| {
            let pool = Pool::new(4);
            pool.set_pinned(pinned);
            let out = std::sync::Mutex::new(vec![0.0f32; 8]);
            for pass in 0..5u32 {
                pool.run_bands(4, &|b| {
                    let mut o = out.lock().unwrap();
                    o[b * 2] += (b as f32 + 0.1).sin() * pass as f32;
                    o[b * 2 + 1] += (b as f32).cos();
                });
            }
            let v = out.lock().unwrap().clone();
            v
        };
        assert_eq!(run(true), run(false));
    }
}
