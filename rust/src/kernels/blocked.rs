//! Cache-blocked, scoped-thread-parallel f32 GEMM (std only).
//!
//! The naive ikj loop in `tensor/ops.rs` streams the whole `w` matrix through
//! cache once per output row.  This kernel tiles columns (`TILE_J`) and the
//! reduction dimension (`TILE_K`) so each `w` tile is reused across a whole
//! band of rows while it is hot, and splits the row dimension across scoped
//! threads for large problems.
//!
//! Numerical contract: for every output element the reduction runs over `k`
//! in ascending order with the same zero-activation skip as the naive loop,
//! so the result is bitwise identical to `ops::matmul_naive` (threading
//! partitions whole rows and cannot reorder any per-element accumulation).

/// Column-tile width: one tile of `out`/`w` rows stays resident in L1.
pub const TILE_J: usize = 64;
/// Reduction-tile depth: `TILE_K` rows of a `w` column tile fit in L2.
pub const TILE_K: usize = 128;
/// Below this many MACs the blocked single-thread path runs un-threaded.
const PAR_THRESHOLD_MACS: usize = 1 << 20;

/// `out[M,N] += x[M,K] @ w[K,N]` for one band of rows, blocked over (j, k).
fn gemm_band(out: &mut [f32], xd: &[f32], wd: &[f32], k: usize, n: usize) {
    let rows = out.len() / n;
    for jj in (0..n).step_by(TILE_J) {
        let jend = (jj + TILE_J).min(n);
        for kk in (0..k).step_by(TILE_K) {
            let kend = (kk + TILE_K).min(k);
            for i in 0..rows {
                let orow = &mut out[i * n + jj..i * n + jend];
                let xrow = &xd[i * k..(i + 1) * k];
                for (kx, &a) in xrow.iter().enumerate().take(kend).skip(kk) {
                    if a == 0.0 {
                        continue;
                    }
                    let wrow = &wd[kx * n + jj..kx * n + jend];
                    for (o, &wv) in orow.iter_mut().zip(wrow) {
                        *o += a * wv;
                    }
                }
            }
        }
    }
}

/// Number of worker threads for an `m x k x n` GEMM.
fn threads_for(m: usize, k: usize, n: usize) -> usize {
    let macs = m.saturating_mul(k).saturating_mul(n);
    if macs < PAR_THRESHOLD_MACS || m < 2 {
        return 1;
    }
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    cores.min(m).min(16)
}

/// `out[M,N] = x[M,K] @ w[K,N]` (caller provides a zeroed `out`).
///
/// Dispatches to the blocked kernel, parallelized over row bands with scoped
/// threads when the problem is large enough to amortize spawn cost.
pub fn matmul_into(out: &mut [f32], xd: &[f32], wd: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(out.len(), m * n);
    debug_assert_eq!(xd.len(), m * k);
    debug_assert_eq!(wd.len(), k * n);
    if m == 0 || n == 0 {
        return;
    }
    let nthreads = threads_for(m, k, n);
    if nthreads <= 1 {
        gemm_band(out, xd, wd, k, n);
        return;
    }
    // uniform row bands (the last one may be short); each thread owns one
    // disjoint band of `out` and the matching rows of `x`
    let rows_per_band = m.div_ceil(nthreads);
    std::thread::scope(|scope| {
        for (oband, xband) in out
            .chunks_mut(rows_per_band * n)
            .zip(xd.chunks(rows_per_band * k))
        {
            scope.spawn(move || gemm_band(oband, xband, wd, k, n));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(xd: &[f32], wd: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let a = xd[i * k + kk];
                if a == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out[i * n + j] += a * wd[kk * n + j];
                }
            }
        }
        out
    }

    fn gauss(seed: u64, len: usize) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..len).map(|_| (r.normal() * 0.5) as f32).collect()
    }

    #[test]
    fn matches_naive_various_shapes() {
        // exercise tile remainders, single rows/cols, and the threaded path
        for (si, &(m, k, n)) in [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (17, 130, 65),
            (64, 256, 120),
            (33, 100, 200),
        ]
        .iter()
        .enumerate()
        {
            let xd = gauss(si as u64, m * k);
            let wd = gauss(100 + si as u64, k * n);
            let mut out = vec![0.0f32; m * n];
            matmul_into(&mut out, &xd, &wd, m, k, n);
            let want = naive(&xd, &wd, m, k, n);
            assert_eq!(out, want, "shape ({m},{k},{n}) diverged from naive");
        }
    }

    #[test]
    fn threaded_band_matches_naive() {
        // big enough to cross PAR_THRESHOLD_MACS with several bands
        let (m, k, n) = (64, 256, 256);
        let xd = gauss(7, m * k);
        let wd = gauss(8, k * n);
        let mut out = vec![0.0f32; m * n];
        matmul_into(&mut out, &xd, &wd, m, k, n);
        assert_eq!(out, naive(&xd, &wd, m, k, n));
    }

    #[test]
    fn zero_sized_ok() {
        let mut out: Vec<f32> = vec![];
        matmul_into(&mut out, &[], &[], 0, 4, 0);
    }
}
