//! Cache-blocked, scoped-thread-parallel f32 GEMM (std only).
//!
//! The naive ikj loop in `tensor/ops.rs` streams the whole `w` matrix through
//! cache once per output row.  This kernel tiles columns (`TILE_J`) so a `w`
//! column tile is reused across a whole band of rows while hot, and runs a
//! 4x8 register microtile ([`MR`] x [`NR`]) inside each tile: 32 accumulators
//! live in registers across the entire `k` reduction, one 8-wide `w` strip is
//! loaded once per four rows instead of once per row, and the accumulator
//! arrays are shaped for the autovectorizer's lanes.  The row dimension
//! splits across the persistent worker pool for large problems
//! ([`crate::kernels::for_each_row_band`] on [`crate::kernels::Pool`]).
//!
//! Numerical contract: for every output element the reduction runs over `k`
//! in ascending order into a single accumulator starting at +0.0, with the
//! same zero-activation skip as the naive loop, so the result is bitwise
//! identical to `ops::matmul_naive` (threading partitions whole rows, and
//! spilling a register accumulator into a zeroed output adds +0.0, which
//! cannot change the value).

/// Column-tile width: one tile of `out`/`w` columns stays resident in L1.
pub const TILE_J: usize = 64;
/// Microtile rows: how many `out` rows accumulate in registers at once.
pub const MR: usize = 4;
/// Microtile columns: the register accumulator width per row.
pub const NR: usize = 8;
/// Below this many MACs the blocked single-thread path runs un-threaded.
pub(crate) const PAR_THRESHOLD_MACS: usize = 1 << 20;

/// `out[rows,N] += x[rows,K] @ w[K,N]` for one band of rows (`out` zeroed by
/// the caller), blocked over columns with a [`MR`]x[`NR`] register microtile.
pub fn gemm_band(out: &mut [f32], xd: &[f32], wd: &[f32], k: usize, n: usize) {
    if n == 0 || k == 0 {
        return;
    }
    let rows = out.len() / n;
    for jj in (0..n).step_by(TILE_J) {
        let jend = (jj + TILE_J).min(n);
        let mut j = jj;
        while j + NR <= jend {
            // MR-row quads: 32 register accumulators across the whole k loop
            let mut i = 0;
            while i + MR <= rows {
                let mut acc = [[0.0f32; NR]; MR];
                for kx in 0..k {
                    let wrow = &wd[kx * n + j..kx * n + j + NR];
                    for (r, accr) in acc.iter_mut().enumerate() {
                        let a = xd[(i + r) * k + kx];
                        if a == 0.0 {
                            continue;
                        }
                        for (c, &wv) in accr.iter_mut().zip(wrow) {
                            *c += a * wv;
                        }
                    }
                }
                for (r, accr) in acc.iter().enumerate() {
                    let orow = &mut out[(i + r) * n + j..(i + r) * n + j + NR];
                    for (o, &c) in orow.iter_mut().zip(accr) {
                        *o += c;
                    }
                }
                i += MR;
            }
            // leftover rows: one NR-wide accumulator row at a time
            while i < rows {
                let mut accr = [0.0f32; NR];
                let xrow = &xd[i * k..(i + 1) * k];
                for (kx, &a) in xrow.iter().enumerate() {
                    if a == 0.0 {
                        continue;
                    }
                    let wrow = &wd[kx * n + j..kx * n + j + NR];
                    for (c, &wv) in accr.iter_mut().zip(wrow) {
                        *c += a * wv;
                    }
                }
                let orow = &mut out[i * n + j..i * n + j + NR];
                for (o, &c) in orow.iter_mut().zip(&accr) {
                    *o += c;
                }
                i += 1;
            }
            j += NR;
        }
        // leftover columns (< NR): a fixed-width register accumulator array
        // (only the first `jend - j` lanes live) instead of accumulating
        // through `out` memory each k step — the same lane shape the main
        // microtile hands the autovectorizer.  Per element the reduction is
        // still k-ascending into a single accumulator spilled once into the
        // zeroed output, so the bitwise contract with `matmul_naive` holds.
        if j < jend {
            let rem = jend - j;
            for i in 0..rows {
                let mut accr = [0.0f32; NR];
                let xrow = &xd[i * k..(i + 1) * k];
                for (kx, &a) in xrow.iter().enumerate() {
                    if a == 0.0 {
                        continue;
                    }
                    let wrow = &wd[kx * n + j..kx * n + jend];
                    for (c, &wv) in accr[..rem].iter_mut().zip(wrow) {
                        *c += a * wv;
                    }
                }
                let orow = &mut out[i * n + j..i * n + jend];
                for (o, &c) in orow.iter_mut().zip(&accr) {
                    *o += c;
                }
            }
        }
    }
}

/// `out[M,N] = x[M,K] @ w[K,N]` (caller provides a zeroed `out`).
///
/// Dispatches to the microtiled kernel, parallelized over row bands on the
/// global worker pool when the problem is large enough to amortize dispatch.
pub fn matmul_into(out: &mut [f32], xd: &[f32], wd: &[f32], m: usize, k: usize, n: usize) {
    matmul_into_on(super::Pool::global(), out, xd, wd, m, k, n)
}

/// [`matmul_into`] with an explicit worker-pool handle (the serving engines
/// thread their pool through here).
pub fn matmul_into_on(
    pool: &super::Pool,
    out: &mut [f32],
    xd: &[f32],
    wd: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(out.len(), m * n);
    debug_assert_eq!(xd.len(), m * k);
    debug_assert_eq!(wd.len(), k * n);
    if m == 0 || n == 0 {
        return;
    }
    let macs = m.saturating_mul(k).saturating_mul(n);
    let nthreads = super::threads_for_rows(m, macs, PAR_THRESHOLD_MACS).min(pool.width());
    let band = |_: usize, oband: &mut [f32], xband: &[f32]| gemm_band(oband, xband, wd, k, n);
    super::for_each_row_band_on(pool, out, xd, m, k, n, nthreads, band);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(xd: &[f32], wd: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let a = xd[i * k + kk];
                if a == 0.0 {
                    continue;
                }
                for j in 0..n {
                    out[i * n + j] += a * wd[kk * n + j];
                }
            }
        }
        out
    }

    fn gauss(seed: u64, len: usize) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..len).map(|_| (r.normal() * 0.5) as f32).collect()
    }

    #[test]
    fn matches_naive_various_shapes() {
        // exercise microtile remainders (rows % MR, cols % NR, tile edges),
        // single rows/cols, and the threaded path
        for (si, &(m, k, n)) in [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (5, 9, 9),
            (6, 13, 17),
            (17, 130, 65),
            (64, 256, 120),
            (33, 100, 200),
        ]
        .iter()
        .enumerate()
        {
            let xd = gauss(si as u64, m * k);
            let wd = gauss(100 + si as u64, k * n);
            let mut out = vec![0.0f32; m * n];
            matmul_into(&mut out, &xd, &wd, m, k, n);
            let want = naive(&xd, &wd, m, k, n);
            assert_eq!(out, want, "shape ({m},{k},{n}) diverged from naive");
        }
    }

    #[test]
    fn microtile_bitwise_on_dyadic_data() {
        // integer data: every accumulation is exact, so any divergence is a
        // structural bug rather than float reassociation
        let mut r = Rng::new(41);
        for (m, k, n) in [(4usize, 8usize, 8usize), (7, 11, 19), (9, 16, 8)] {
            let xd: Vec<f32> = (0..m * k).map(|_| r.range_i64(-4, 4) as f32).collect();
            let wd: Vec<f32> = (0..k * n).map(|_| r.range_i64(-4, 4) as f32).collect();
            let mut out = vec![0.0f32; m * n];
            gemm_band(&mut out, &xd, &wd, k, n);
            assert_eq!(out, naive(&xd, &wd, m, k, n), "dyadic ({m},{k},{n})");
        }
    }

    #[test]
    fn threaded_band_matches_naive() {
        // big enough to cross PAR_THRESHOLD_MACS with several bands
        let (m, k, n) = (64, 256, 256);
        let xd = gauss(7, m * k);
        let wd = gauss(8, k * n);
        let mut out = vec![0.0f32; m * n];
        matmul_into(&mut out, &xd, &wd, m, k, n);
        assert_eq!(out, naive(&xd, &wd, m, k, n));
    }

    #[test]
    fn zero_sized_ok() {
        let mut out: Vec<f32> = vec![];
        matmul_into(&mut out, &[], &[], 0, 4, 0);
        gemm_band(&mut out, &[], &[], 0, 0);
    }
}
