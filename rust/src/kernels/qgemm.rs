//! Packed code-domain GEMM: multiply a f32 activation matrix by a
//! [`QuantizedTensor`] without ever decoding the weights to f32.
//!
//! The QSQ levels are {0, ±1, ±2, ±4}, so each weight contributes to a dot
//! product as a sign flip plus at most two left shifts of the activation.
//! Two generations of the kernel live here:
//!
//! * **v1** ([`PackedQTensor`] + [`qgemm`]) — the retained single-thread
//!   reference.  Nonzero codes are stored as interleaved (row-offset, code)
//!   entries per (group, column) cell; the inner loop selects each entry's
//!   contribution from an 8-wide shift table rebuilt per group row.
//! * **v2** ([`PackedQTensorV2`] + [`qgemm2`]) — the serving kernel.  The
//!   surviving codes of each cell are split into six *offset planes*, one
//!   per nonzero level (+1, +2, +4, −1, −2, −4).  The inner loop is then a
//!   straight sum of activations over each contiguous plane — no LUT build,
//!   no per-entry code select, 2 bytes per entry instead of 4 — run on the
//!   lane-ized gather reduction ([`super::lanes::gather_sum`]; the scalar
//!   order survives as [`qgemm2_scalar_on`], the differential oracle) — and
//!   the six plane sums are combined with adds only
//!   (`acc = (s₁−m₁) + 2(s₂−m₂) + 4(s₄−m₄)`, doublings as self-adds).  Rows
//!   are split across the persistent worker pool with the same band scheme
//!   as [`super::blocked`], so a pooled run is bitwise identical to the
//!   single-thread one.
//!
//! Both kernels share the structural wins of the code domain: zero/reserved
//! codes are dropped at pack time (zero-skip), the inner loop contains no
//! multiply, and the per-(group, column) `alpha` scales each partial sum
//! exactly once.  On dyadic data (integer activations, power-of-two scalars)
//! v1, v2, and decode-then-matmul are all exact and therefore bitwise equal
//! — the property tests assert exactly that.

use anyhow::{bail, Result};

use crate::hw::zskip::SkipStats;
use crate::quant::qsq::QuantizedTensor;
use crate::tensor::Tensor;

/// One non-skippable v1 code: (row offset within the group, 3-bit code).
type Entry = (u16, u8);

/// Below this many inner-loop adds a qgemm runs un-threaded (code-domain
/// adds are cheap, so the crossover sits lower than the f32 GEMM's).
pub(crate) const QGEMM_PAR_THRESHOLD: usize = 1 << 18;

/// A [`QuantizedTensor`] repacked for the v1 code-domain GEMM: per
/// (group, column) runs of nonzero codes in CSR-like form.
#[derive(Clone, Debug)]
pub struct PackedQTensor {
    pub k: usize,
    pub oc: usize,
    pub group: usize,
    /// Original tensor shape (C-order compatible with `[K, OC]`).
    pub shape: Vec<usize>,
    /// `[K/group, OC]` row-major per-group scalars.
    scalars: Vec<f32>,
    /// Nonzero codes, grouped by (group, column), rows ascending.
    entries: Vec<Entry>,
    /// CSR offsets into `entries`, length `(K/group)*OC + 1`.
    starts: Vec<u32>,
    /// Zero-skip statistics realized by this packing.
    pub skip: SkipStats,
}

fn check_groups(qt: &QuantizedTensor) -> Result<()> {
    if qt.group == 0 || qt.k % qt.group != 0 {
        bail!("group {} must divide K={}", qt.group, qt.k);
    }
    if qt.group > u16::MAX as usize + 1 {
        bail!("group {} too large for packed offsets", qt.group);
    }
    Ok(())
}

impl PackedQTensor {
    /// Pack a quantized tensor (drops zero/reserved codes).
    pub fn pack(qt: &QuantizedTensor) -> Result<PackedQTensor> {
        check_groups(qt)?;
        let g = qt.k / qt.group;
        let cells = g * qt.oc;
        let mut entries = Vec::with_capacity(qt.codes.len());
        let mut starts = Vec::with_capacity(cells + 1);
        starts.push(0u32);
        for gi in 0..g {
            for j in 0..qt.oc {
                for r in 0..qt.group {
                    let code = qt.codes[(gi * qt.group + r) * qt.oc + j];
                    if !code.is_skippable() {
                        entries.push((r as u16, code.0 & 7));
                    }
                }
                starts.push(entries.len() as u32);
            }
        }
        let total = qt.codes.len() as u64;
        let skip = SkipStats { total, skippable: total - entries.len() as u64 };
        Ok(PackedQTensor {
            k: qt.k,
            oc: qt.oc,
            group: qt.group,
            shape: qt.shape.clone(),
            scalars: qt.scalars.clone(),
            entries,
            starts,
            skip,
        })
    }

    /// Fraction of codes the GEMM never touches.
    pub fn skipped_fraction(&self) -> f64 {
        self.skip.fraction()
    }
}

/// `x [M,K] @ packed [K,OC] -> [M,OC]`, entirely in the code domain — the
/// v1 kernel, retained single-threaded as the reference v2 is checked
/// against.
pub fn qgemm(x: &Tensor, p: &PackedQTensor) -> Result<Tensor> {
    let xs = x.shape();
    if xs.len() != 2 || xs[1] != p.k {
        bail!("qgemm shapes {:?} x [{}, {}]", xs, p.k, p.oc);
    }
    let (m, k, oc) = (xs[0], p.k, p.oc);
    let g = k / p.group;
    let xd = x.data();
    let mut out = vec![0.0f32; m * oc];
    // per-group shift table: lut[r*8 + code] = level(code) * a, built with
    // adds/negations only (a2 = a+a, a4 = a2+a2)
    let mut lut = vec![0.0f32; p.group * 8];
    for i in 0..m {
        let xrow = &xd[i * k..(i + 1) * k];
        let orow = &mut out[i * oc..(i + 1) * oc];
        for gi in 0..g {
            for r in 0..p.group {
                let a = xrow[gi * p.group + r];
                let a2 = a + a;
                let a4 = a2 + a2;
                let l = &mut lut[r * 8..r * 8 + 8];
                l[0] = 0.0;
                l[1] = a;
                l[2] = a2;
                l[3] = a4;
                l[4] = -a;
                l[5] = -a2;
                l[6] = -a4;
                l[7] = 0.0;
            }
            let cell0 = gi * oc;
            for j in 0..oc {
                let s = p.starts[cell0 + j] as usize;
                let e = p.starts[cell0 + j + 1] as usize;
                let mut acc = 0.0f32;
                for &(r, c) in &p.entries[s..e] {
                    acc += lut[(r as usize) * 8 + c as usize];
                }
                // the only multiply: one alpha per (group, column)
                orow[j] += p.scalars[cell0 + j] * acc;
            }
        }
    }
    Tensor::new(vec![m, oc], out)
}

/// Convenience: pack on the fly (prefer holding a [`PackedQTensor`] on hot
/// paths — packing costs one pass over the codes).
pub fn qgemm_qt(x: &Tensor, qt: &QuantizedTensor) -> Result<Tensor> {
    qgemm(x, &PackedQTensor::pack(qt)?)
}

/// Number of offset planes per (group, column) cell — one per nonzero level.
const PLANES: usize = 6;

/// A [`QuantizedTensor`] repacked for the v2 code-domain GEMM: per
/// (group, column) cell, six contiguous row-offset planes (one per nonzero
/// level), so the inner loop never selects on a code.
#[derive(Clone, Debug)]
pub struct PackedQTensorV2 {
    pub k: usize,
    pub oc: usize,
    pub group: usize,
    /// Original tensor shape (C-order compatible with `[K, OC]`).
    pub shape: Vec<usize>,
    /// `[K/group, OC]` row-major per-group scalars.
    scalars: Vec<f32>,
    /// Row offsets within the group, plane-major per cell:
    /// `[+1 plane | +2 | +4 | −1 | −2 | −4]` for cell 0, then cell 1, …
    offsets: Vec<u16>,
    /// Plane boundaries into `offsets`:
    /// `bounds[cell*6 + p] .. bounds[cell*6 + p + 1]` is plane `p` of
    /// `cell`; length `cells*6 + 1`.
    bounds: Vec<u32>,
    /// Zero-skip statistics realized by this packing.
    pub skip: SkipStats,
}

impl PackedQTensorV2 {
    /// Pack a quantized tensor into offset planes (drops zero/reserved
    /// codes, same zero-skip as v1 — only the layout differs).
    pub fn pack(qt: &QuantizedTensor) -> Result<PackedQTensorV2> {
        check_groups(qt)?;
        let g = qt.k / qt.group;
        let cells = g * qt.oc;
        let mut offsets = Vec::with_capacity(qt.codes.len());
        let mut bounds = Vec::with_capacity(cells * PLANES + 1);
        bounds.push(0u32);
        // reusable per-plane buckets: one pass over each cell's codes, then
        // drained in plane order (codes 1..=6 are the nonzero levels)
        let mut buckets: [Vec<u16>; PLANES] = Default::default();
        for gi in 0..g {
            for j in 0..qt.oc {
                for r in 0..qt.group {
                    let code = qt.codes[(gi * qt.group + r) * qt.oc + j];
                    if !code.is_skippable() {
                        buckets[(code.0 & 7) as usize - 1].push(r as u16);
                    }
                }
                for bucket in buckets.iter_mut() {
                    offsets.extend_from_slice(bucket);
                    bounds.push(offsets.len() as u32);
                    bucket.clear();
                }
            }
        }
        let total = qt.codes.len() as u64;
        let skip = SkipStats { total, skippable: total - offsets.len() as u64 };
        Ok(PackedQTensorV2 {
            k: qt.k,
            oc: qt.oc,
            group: qt.group,
            shape: qt.shape.clone(),
            scalars: qt.scalars.clone(),
            offsets,
            bounds,
            skip,
        })
    }

    /// Fraction of codes the GEMM never touches.
    pub fn skipped_fraction(&self) -> f64 {
        self.skip.fraction()
    }

    /// Inner-loop adds one activation row costs (used for thread dispatch).
    pub(crate) fn ops_per_row(&self) -> usize {
        self.offsets.len() + self.bounds.len()
    }
}

/// One row band of the v2 kernel: `out` is `rows x OC` (pre-zeroed, rows
/// inferred), `xb` the matching rows of the activation matrix.  Accumulates
/// into `out`.
///
/// Loop order is (group, column, row): the six plane segments and the cell's
/// alpha are loaded once and reused across every row of the band, so only
/// the activation gathers vary in the inner loop.  Per output element the
/// group partials still accumulate in ascending group order with the same
/// combine expression, so reordering rows/columns cannot change any value.
/// The per-plane reduction is whatever `plane_sum` implements — the lane
/// form for serving, the scalar oracle for the differential reference path —
/// and is a pure function of the plane, so banding still cannot reorder it.
#[inline(always)]
fn qgemm2_band_with<S: Fn(&[u16], &[f32]) -> f32>(
    out: &mut [f32],
    xb: &[f32],
    p: &PackedQTensorV2,
    plane_sum: S,
) {
    let (k, oc) = (p.k, p.oc);
    if oc == 0 {
        return;
    }
    let g = k / p.group;
    let rows = out.len() / oc;
    for gi in 0..g {
        let cell0 = gi * oc;
        let x0 = gi * p.group;
        for j in 0..oc {
            let b = &p.bounds[(cell0 + j) * PLANES..(cell0 + j) * PLANES + PLANES + 1];
            let alpha = p.scalars[cell0 + j];
            // the six offset planes of this (group, column) cell
            let seg = [
                &p.offsets[b[0] as usize..b[1] as usize],
                &p.offsets[b[1] as usize..b[2] as usize],
                &p.offsets[b[2] as usize..b[3] as usize],
                &p.offsets[b[3] as usize..b[4] as usize],
                &p.offsets[b[4] as usize..b[5] as usize],
                &p.offsets[b[5] as usize..b[6] as usize],
            ];
            for i in 0..rows {
                let xg = &xb[i * k + x0..i * k + x0 + p.group];
                // combine with adds only: (s1-m1) + 2(s2-m2) + 4(s4-m4)
                let t1 = plane_sum(seg[0], xg) - plane_sum(seg[3], xg);
                let mut t2 = plane_sum(seg[1], xg) - plane_sum(seg[4], xg);
                t2 += t2;
                let mut t4 = plane_sum(seg[2], xg) - plane_sum(seg[5], xg);
                t4 += t4;
                t4 += t4;
                // the only multiply: one alpha per (group, column)
                out[i * oc + j] += alpha * (t1 + t2 + t4);
            }
        }
    }
}

/// The serving band: plane sums on the [`super::lanes::gather_sum`] lane
/// reduction (fixed-width chunks, one accumulator per lane).
pub(crate) fn qgemm2_band(out: &mut [f32], xb: &[f32], p: &PackedQTensorV2) {
    qgemm2_band_with(out, xb, p, super::lanes::gather_sum)
}

/// The retained scalar-oracle band: plane sums in single-accumulator order
/// ([`super::lanes::gather_sum_scalar`]).  The differential harness and the
/// scalar-reference engine forwards run on this.
pub(crate) fn qgemm2_band_scalar(out: &mut [f32], xb: &[f32], p: &PackedQTensorV2) {
    qgemm2_band_with(out, xb, p, super::lanes::gather_sum_scalar)
}

/// The integer-activation serving band: i16 plane sums on the SWAR
/// [`super::lanes::gather_sum_i16`] reduction (the fused-conv slab kernel of
/// the integer datapath).
pub(crate) fn qgemm2_band_i16(out: &mut [f32], xb: &[i16], p: &PackedQTensorV2, dequant_in: f32) {
    qgemm2_band_i16_with(out, xb, p, dequant_in, super::lanes::gather_sum_i16)
}

/// The integer-activation scalar-oracle band — bitwise equal to
/// [`qgemm2_band_i16`] on every input (integer sums are exact either way).
pub(crate) fn qgemm2_band_i16_scalar(
    out: &mut [f32],
    xb: &[i16],
    p: &PackedQTensorV2,
    dequant_in: f32,
) {
    qgemm2_band_i16_with(out, xb, p, dequant_in, super::lanes::gather_sum_i16_scalar)
}

/// `out[M,OC] = x[M,K] @ packed` on the plane-packed layout (caller provides
/// a zeroed `out` of exactly `m * OC`), row bands on the global worker pool.
pub fn qgemm2_into(out: &mut [f32], xd: &[f32], m: usize, p: &PackedQTensorV2) {
    qgemm2_into_on(super::Pool::global(), out, xd, m, p)
}

/// [`qgemm2_into`] with an explicit worker-pool handle (the serving engines
/// thread their pool through here).
pub fn qgemm2_into_on(
    pool: &super::Pool,
    out: &mut [f32],
    xd: &[f32],
    m: usize,
    p: &PackedQTensorV2,
) {
    debug_assert_eq!(out.len(), m * p.oc);
    debug_assert_eq!(xd.len(), m * p.k);
    let total = m.saturating_mul(p.ops_per_row());
    let nthreads = super::threads_for_rows(m, total, QGEMM_PAR_THRESHOLD).min(pool.width());
    let band = |_: usize, ob: &mut [f32], xb: &[f32]| qgemm2_band(ob, xb, p);
    super::for_each_row_band_on(pool, out, xd, m, p.k, p.oc, nthreads, band);
}

/// [`qgemm2_into_on`] with every plane sum on the retained scalar oracle —
/// identical banding, single-accumulator reduction order.  This is the
/// baseline the lane kernel is differentially compared against (and what
/// the engines' scalar-reference forwards run on); it is not a serving
/// path.
pub fn qgemm2_scalar_on(
    pool: &super::Pool,
    out: &mut [f32],
    xd: &[f32],
    m: usize,
    p: &PackedQTensorV2,
) {
    debug_assert_eq!(out.len(), m * p.oc);
    debug_assert_eq!(xd.len(), m * p.k);
    let total = m.saturating_mul(p.ops_per_row());
    let nthreads = super::threads_for_rows(m, total, QGEMM_PAR_THRESHOLD).min(pool.width());
    let band = |_: usize, ob: &mut [f32], xb: &[f32]| qgemm2_band_scalar(ob, xb, p);
    super::for_each_row_band_on(pool, out, xd, m, p.k, p.oc, nthreads, band);
}

/// One row band of the *integer-activation* v2 kernel: `xb` holds raw i16
/// activations (the layer's calibrated fixed-point domain), and every plane
/// sum is an exact i64 integer reduction — the serving form routes through
/// [`super::lanes::gather_sum_i16`], i.e. the SWAR `sum_i16` word loop.
/// The six plane totals combine with integer adds only (doublings as
/// self-adds, mirroring the f32 band), and the **one multiply per
/// (group, column) cell** folds the cell's alpha together with the
/// activation dequant-rescale `dequant_in = 2^-frac`: the f32 accumulator
/// sees `(alpha * dequant_in) * t` with `t` exact.  Because both the lane
/// and the scalar plane sums are integer-exact, the two orders are bitwise
/// equal at every length — stronger than the f32 band's ULP bound.
#[inline(always)]
fn qgemm2_band_i16_with<S: Fn(&[u16], &[i16]) -> i64>(
    out: &mut [f32],
    xb: &[i16],
    p: &PackedQTensorV2,
    dequant_in: f32,
    plane_sum: S,
) {
    let (k, oc) = (p.k, p.oc);
    if oc == 0 {
        return;
    }
    let g = k / p.group;
    let rows = out.len() / oc;
    for gi in 0..g {
        let cell0 = gi * oc;
        let x0 = gi * p.group;
        for j in 0..oc {
            let b = &p.bounds[(cell0 + j) * PLANES..(cell0 + j) * PLANES + PLANES + 1];
            // one dequant-rescale per cell, fused into the existing alpha
            let scale = p.scalars[cell0 + j] * dequant_in;
            let seg = [
                &p.offsets[b[0] as usize..b[1] as usize],
                &p.offsets[b[1] as usize..b[2] as usize],
                &p.offsets[b[2] as usize..b[3] as usize],
                &p.offsets[b[3] as usize..b[4] as usize],
                &p.offsets[b[4] as usize..b[5] as usize],
                &p.offsets[b[5] as usize..b[6] as usize],
            ];
            for i in 0..rows {
                let xg = &xb[i * k + x0..i * k + x0 + p.group];
                // integer combine: (s1-m1) + 2(s2-m2) + 4(s4-m4), exact
                let t1 = plane_sum(seg[0], xg) - plane_sum(seg[3], xg);
                let mut t2 = plane_sum(seg[1], xg) - plane_sum(seg[4], xg);
                t2 += t2;
                let mut t4 = plane_sum(seg[2], xg) - plane_sum(seg[5], xg);
                t4 += t4;
                t4 += t4;
                out[i * oc + j] += scale * ((t1 + t2 + t4) as f32);
            }
        }
    }
}

/// `out[M,OC] += dequant(xq[M,K]) @ packed` with i16 activations: the
/// integer-datapath serving kernel, plane sums on the SWAR
/// [`super::lanes::gather_sum_i16`] reduction, row bands on `pool`.
/// `dequant_in` is the activation format's reciprocal scale
/// ([`super::calib::dequant_scale`]).
pub fn qgemm2_i16_into_on(
    pool: &super::Pool,
    out: &mut [f32],
    xq: &[i16],
    m: usize,
    p: &PackedQTensorV2,
    dequant_in: f32,
) {
    debug_assert_eq!(out.len(), m * p.oc);
    debug_assert_eq!(xq.len(), m * p.k);
    let total = m.saturating_mul(p.ops_per_row());
    let nthreads = super::threads_for_rows(m, total, QGEMM_PAR_THRESHOLD).min(pool.width());
    let band = |_: usize, ob: &mut [f32], xb: &[i16]| {
        qgemm2_band_i16_with(ob, xb, p, dequant_in, super::lanes::gather_sum_i16)
    };
    super::for_each_row_band_i16_on(pool, out, xq, m, p.k, p.oc, nthreads, band);
}

/// [`qgemm2_i16_into_on`] with every plane sum on the scalar gather oracle
/// ([`super::lanes::gather_sum_i16_scalar`]) — the differential baseline.
/// Integer reductions are exact in both orders, so this must be **bitwise**
/// equal to the SWAR form on every input.
pub fn qgemm2_i16_scalar_on(
    pool: &super::Pool,
    out: &mut [f32],
    xq: &[i16],
    m: usize,
    p: &PackedQTensorV2,
    dequant_in: f32,
) {
    debug_assert_eq!(out.len(), m * p.oc);
    debug_assert_eq!(xq.len(), m * p.k);
    let total = m.saturating_mul(p.ops_per_row());
    let nthreads = super::threads_for_rows(m, total, QGEMM_PAR_THRESHOLD).min(pool.width());
    let band = |_: usize, ob: &mut [f32], xb: &[i16]| {
        qgemm2_band_i16_with(ob, xb, p, dequant_in, super::lanes::gather_sum_i16_scalar)
    };
    super::for_each_row_band_i16_on(pool, out, xq, m, p.k, p.oc, nthreads, band);
}

/// Shared tensor-level entry: validate shapes, run with the given thread
/// count (`None` = the production heuristic, via [`qgemm2_into`]).
fn qgemm2_run(x: &Tensor, p: &PackedQTensorV2, nthreads: Option<usize>) -> Result<Tensor> {
    let xs = x.shape();
    if xs.len() != 2 || xs[1] != p.k {
        bail!("qgemm2 shapes {:?} x [{}, {}]", xs, p.k, p.oc);
    }
    let m = xs[0];
    let mut out = vec![0.0f32; m * p.oc];
    match nthreads {
        None => qgemm2_into(&mut out, x.data(), m, p),
        Some(nt) => {
            let band = |_: usize, ob: &mut [f32], xb: &[f32]| qgemm2_band(ob, xb, p);
            super::for_each_row_band(&mut out, x.data(), m, p.k, p.oc, nt, band);
        }
    }
    Tensor::new(vec![m, p.oc], out)
}

/// `x [M,K] @ packed [K,OC] -> [M,OC]` on the v2 plane-packed kernel.
pub fn qgemm2(x: &Tensor, p: &PackedQTensorV2) -> Result<Tensor> {
    qgemm2_run(x, p, None)
}

/// [`qgemm2`] with an explicit thread count — lets tests pin band
/// boundaries (`m < bands`, `m % bands != 0`) and check the parallel run is
/// bitwise identical to the single-thread one.
pub fn qgemm2_threads(x: &Tensor, p: &PackedQTensorV2, nthreads: usize) -> Result<Tensor> {
    qgemm2_run(x, p, Some(nthreads))
}

/// Convenience: pack into planes on the fly (prefer holding a
/// [`PackedQTensorV2`] on hot paths).
pub fn qgemm2_qt(x: &Tensor, qt: &QuantizedTensor) -> Result<Tensor> {
    qgemm2(x, &PackedQTensorV2::pack(qt)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codes::Code;
    use crate::quant::qsq::{quantize, AssignMode};
    use crate::tensor::ops;
    use crate::util::rng::Rng;

    /// Build a QuantizedTensor with random codes and power-of-two scalars so
    /// decode-then-matmul and qgemm are both exact in f32.
    fn dyadic_qt(seed: u64, k: usize, oc: usize, group: usize) -> QuantizedTensor {
        let mut r = Rng::new(seed);
        let levels = [0i32, 1, 2, 4, -1, -2, -4];
        let codes: Vec<Code> = (0..k * oc)
            .map(|_| Code::from_level(levels[r.below(7) as usize]).unwrap())
            .collect();
        let scalars: Vec<f32> = (0..(k / group) * oc)
            .map(|_| (2.0f32).powi(r.range_i64(-2, 2) as i32))
            .collect();
        QuantizedTensor {
            codes,
            scalars,
            k,
            oc,
            group,
            phi: 4,
            gamma: 0.5,
            delta: 2.0,
            shape: vec![k, oc],
        }
    }

    fn int_activations(seed: u64, m: usize, k: usize) -> Tensor {
        let mut r = Rng::new(seed);
        let data: Vec<f32> = (0..m * k).map(|_| r.range_i64(-8, 8) as f32).collect();
        Tensor::new(vec![m, k], data).unwrap()
    }

    #[test]
    fn exact_vs_decode_matmul_on_dyadic_data() {
        for (seed, m, k, oc, group) in [(1u64, 3, 16, 5, 4), (2, 7, 48, 9, 16), (3, 1, 8, 1, 8)] {
            let qt = dyadic_qt(seed, k, oc, group);
            let x = int_activations(seed + 100, m, k);
            let dec = Tensor::new(vec![k, oc], qt.decode()).unwrap();
            let want = ops::matmul_naive(&x, &dec).unwrap();
            let got = qgemm_qt(&x, &qt).unwrap();
            assert_eq!(got.shape(), want.shape());
            // all values dyadic and well within the f32 mantissa -> exact
            assert_eq!(got.data(), want.data(), "seed {seed} diverged");
            // v2 must agree bitwise with both on dyadic data
            let got2 = qgemm2_qt(&x, &qt).unwrap();
            assert_eq!(got2.data(), want.data(), "seed {seed}: v2 diverged");
        }
    }

    #[test]
    fn close_on_real_quantized_gaussian_weights() {
        let mut r = Rng::new(9);
        let w: Vec<f32> = (0..150 * 16).map(|_| (r.normal() * 0.2) as f32).collect();
        let qt = quantize(&w, &[150, 16], 6, 4, AssignMode::SigmaSearch).unwrap();
        let xdata: Vec<f32> = (0..24 * 150).map(|_| (r.normal() * 0.8) as f32).collect();
        let x = Tensor::new(vec![24, 150], xdata).unwrap();
        let dec = Tensor::new(vec![150, 16], qt.decode()).unwrap();
        let want = ops::matmul_naive(&x, &dec).unwrap();
        let got = qgemm_qt(&x, &qt).unwrap();
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-3, "qgemm vs decode+matmul: {diff}");
        let got2 = qgemm2_qt(&x, &qt).unwrap();
        let diff2 = got2.max_abs_diff(&want);
        assert!(diff2 < 1e-3, "qgemm2 vs decode+matmul: {diff2}");
    }

    #[test]
    fn zero_codes_are_dropped_at_pack_time() {
        let mut qt = dyadic_qt(5, 16, 4, 4);
        for c in qt.codes.iter_mut().step_by(2) {
            *c = Code::ZERO;
        }
        let p = PackedQTensor::pack(&qt).unwrap();
        assert!(p.skipped_fraction() >= 0.5);
        assert_eq!(p.skip.total, 64);
        let p2 = PackedQTensorV2::pack(&qt).unwrap();
        assert_eq!(p2.skip, p.skip, "both layouts realize the same zero-skip");
        let x = int_activations(6, 2, 16);
        let dec = Tensor::new(vec![16, 4], qt.decode()).unwrap();
        let want = ops::matmul_naive(&x, &dec).unwrap();
        assert_eq!(qgemm(&x, &p).unwrap().data(), want.data());
        assert_eq!(qgemm2(&x, &p2).unwrap().data(), want.data());
    }

    #[test]
    fn v2_parallel_bands_bitwise_equal_single_thread() {
        // gaussian (non-dyadic) data: banding must not reorder any reduction
        let mut r = Rng::new(31);
        let w: Vec<f32> = (0..64 * 9).map(|_| (r.normal() * 0.3) as f32).collect();
        let qt = quantize(&w, &[64, 9], 16, 4, AssignMode::SigmaSearch).unwrap();
        let p = PackedQTensorV2::pack(&qt).unwrap();
        for m in [1usize, 3, 5, 8] {
            let xdata: Vec<f32> = (0..m * 64).map(|_| (r.normal()) as f32).collect();
            let x = Tensor::new(vec![m, 64], xdata).unwrap();
            let st = qgemm2_threads(&x, &p, 1).unwrap();
            for nt in [2usize, 3, 4, 7] {
                let par = qgemm2_threads(&x, &p, nt).unwrap();
                assert_eq!(par.data(), st.data(), "m={m} nt={nt} diverged");
            }
        }
    }

    #[test]
    fn lane_band_matches_scalar_oracle_band() {
        let mut r = Rng::new(77);
        let w: Vec<f32> = (0..96 * 12).map(|_| (r.normal() * 0.3) as f32).collect();
        let qt = quantize(&w, &[96, 12], 24, 4, AssignMode::SigmaSearch).unwrap();
        let p = PackedQTensorV2::pack(&qt).unwrap();
        let pool = crate::kernels::Pool::new(1);
        for m in [1usize, 4, 9] {
            // gaussian data: lane reassociation may round differently, but
            // stays within normal f32 noise of the scalar order
            let xg: Vec<f32> = (0..m * 96).map(|_| r.normal() as f32).collect();
            let mut lane = vec![0.0f32; m * 12];
            qgemm2_into_on(&pool, &mut lane, &xg, m, &p);
            let mut scalar = vec![0.0f32; m * 12];
            qgemm2_scalar_on(&pool, &mut scalar, &xg, m, &p);
            for (a, b) in lane.iter().zip(&scalar) {
                assert!((a - b).abs() < 1e-4, "m={m}: lane {a} vs scalar {b}");
            }
            // integer activations: every plane sum is exact in both orders,
            // so lane and scalar must be bitwise equal
            let xi: Vec<f32> = (0..m * 96).map(|_| r.range_i64(-8, 8) as f32).collect();
            let mut lane_i = vec![0.0f32; m * 12];
            qgemm2_into_on(&pool, &mut lane_i, &xi, m, &p);
            let mut scalar_i = vec![0.0f32; m * 12];
            qgemm2_scalar_on(&pool, &mut scalar_i, &xi, m, &p);
            assert_eq!(lane_i, scalar_i, "m={m}: integer data must be exact in both orders");
        }
    }

    #[test]
    fn i16_band_bitwise_equals_f32_band_on_unit_scale_integers() {
        // frac = 0 and integer activations: the i16 raw domain IS the f32
        // value domain, and every reduction is exact on both paths, so the
        // integer kernel must reproduce the f32 kernel bitwise
        let qt = dyadic_qt(21, 48, 7, 16);
        let p = PackedQTensorV2::pack(&qt).unwrap();
        let pool = crate::kernels::Pool::new(1);
        let m = 5;
        let x = int_activations(22, m, 48);
        let xq: Vec<i16> = x.data().iter().map(|&v| v as i16).collect();
        let mut f32_out = vec![0.0f32; m * 7];
        qgemm2_into_on(&pool, &mut f32_out, x.data(), m, &p);
        let mut i16_out = vec![0.0f32; m * 7];
        qgemm2_i16_into_on(&pool, &mut i16_out, &xq, m, &p, 1.0);
        assert_eq!(i16_out, f32_out);
    }

    #[test]
    fn i16_lane_and_scalar_orders_are_bitwise_equal() {
        let mut r = Rng::new(23);
        let w: Vec<f32> = (0..96 * 11).map(|_| (r.normal() * 0.3) as f32).collect();
        let qt = quantize(&w, &[96, 11], 24, 4, AssignMode::SigmaSearch).unwrap();
        let p = PackedQTensorV2::pack(&qt).unwrap();
        let pool = crate::kernels::Pool::new(4);
        for m in [1usize, 4, 9] {
            let xq: Vec<i16> =
                (0..m * 96).map(|_| r.range_i64(-32768, 32767) as i16).collect();
            let dq = 1.0f32 / 4096.0;
            let mut lane = vec![0.0f32; m * 11];
            qgemm2_i16_into_on(&pool, &mut lane, &xq, m, &p, dq);
            let mut scalar = vec![0.0f32; m * 11];
            qgemm2_i16_scalar_on(&pool, &mut scalar, &xq, m, &p, dq);
            assert_eq!(lane, scalar, "m={m}: integer plane sums are exact in both orders");
        }
    }

    #[test]
    fn shape_mismatch_rejected() {
        let qt = dyadic_qt(7, 16, 4, 4);
        let x = int_activations(8, 2, 12);
        assert!(qgemm_qt(&x, &qt).is_err());
        assert!(qgemm2_qt(&x, &qt).is_err());
    }
}
