//! Packed code-domain GEMM: multiply a f32 activation matrix by a
//! [`QuantizedTensor`] without ever decoding the weights to f32.
//!
//! The QSQ levels are {0, ±1, ±2, ±4}, so each weight contributes to a dot
//! product as a sign flip plus at most two left shifts of the activation.
//! The kernel exploits all three structural properties of the code tensor:
//!
//! * **zero skip** — zero/reserved codes are dropped at pack time, so the
//!   inner loop never touches them (the paper's "+6 % zeros" becomes real
//!   work saved, not just [`crate::hw::zskip`] bookkeeping);
//! * **shift/add only** — per activation value `a` the eight possible
//!   contributions {0, a, 2a, 4a, -a, -2a, -4a, 0} are built once per group
//!   with additions and negations only, then selected by code — the inner
//!   loop contains no multiply;
//! * **hoisted scaling** — the per-(group, column) scalar `alpha` multiplies
//!   the group partial sum once, instead of once per element as the
//!   decode-then-matmul path does.

use anyhow::{bail, Result};

use crate::hw::zskip::SkipStats;
use crate::quant::qsq::QuantizedTensor;
use crate::tensor::Tensor;

/// One non-skippable code: (row offset within the group, 3-bit code).
type Entry = (u16, u8);

/// A [`QuantizedTensor`] repacked for the code-domain GEMM: per
/// (group, column) runs of nonzero codes in CSR-like form.
#[derive(Clone, Debug)]
pub struct PackedQTensor {
    pub k: usize,
    pub oc: usize,
    pub group: usize,
    /// Original tensor shape (C-order compatible with `[K, OC]`).
    pub shape: Vec<usize>,
    /// `[K/group, OC]` row-major per-group scalars.
    scalars: Vec<f32>,
    /// Nonzero codes, grouped by (group, column), rows ascending.
    entries: Vec<Entry>,
    /// CSR offsets into `entries`, length `(K/group)*OC + 1`.
    starts: Vec<u32>,
    /// Zero-skip statistics realized by this packing.
    pub skip: SkipStats,
}

impl PackedQTensor {
    /// Pack a quantized tensor (drops zero/reserved codes).
    pub fn pack(qt: &QuantizedTensor) -> Result<PackedQTensor> {
        if qt.group == 0 || qt.k % qt.group != 0 {
            bail!("group {} must divide K={}", qt.group, qt.k);
        }
        if qt.group > u16::MAX as usize + 1 {
            bail!("group {} too large for packed offsets", qt.group);
        }
        let g = qt.k / qt.group;
        let cells = g * qt.oc;
        let mut entries = Vec::with_capacity(qt.codes.len());
        let mut starts = Vec::with_capacity(cells + 1);
        starts.push(0u32);
        for gi in 0..g {
            for j in 0..qt.oc {
                for r in 0..qt.group {
                    let code = qt.codes[(gi * qt.group + r) * qt.oc + j];
                    if !code.is_skippable() {
                        entries.push((r as u16, code.0 & 7));
                    }
                }
                starts.push(entries.len() as u32);
            }
        }
        let total = qt.codes.len() as u64;
        let skip = SkipStats { total, skippable: total - entries.len() as u64 };
        Ok(PackedQTensor {
            k: qt.k,
            oc: qt.oc,
            group: qt.group,
            shape: qt.shape.clone(),
            scalars: qt.scalars.clone(),
            entries,
            starts,
            skip,
        })
    }

    /// Fraction of codes the GEMM never touches.
    pub fn skipped_fraction(&self) -> f64 {
        self.skip.fraction()
    }
}

/// `x [M,K] @ packed [K,OC] -> [M,OC]`, entirely in the code domain.
pub fn qgemm(x: &Tensor, p: &PackedQTensor) -> Result<Tensor> {
    let xs = x.shape();
    if xs.len() != 2 || xs[1] != p.k {
        bail!("qgemm shapes {:?} x [{}, {}]", xs, p.k, p.oc);
    }
    let (m, k, oc) = (xs[0], p.k, p.oc);
    let g = k / p.group;
    let xd = x.data();
    let mut out = vec![0.0f32; m * oc];
    // per-group shift table: lut[r*8 + code] = level(code) * a, built with
    // adds/negations only (a2 = a+a, a4 = a2+a2)
    let mut lut = vec![0.0f32; p.group * 8];
    for i in 0..m {
        let xrow = &xd[i * k..(i + 1) * k];
        let orow = &mut out[i * oc..(i + 1) * oc];
        for gi in 0..g {
            for r in 0..p.group {
                let a = xrow[gi * p.group + r];
                let a2 = a + a;
                let a4 = a2 + a2;
                let l = &mut lut[r * 8..r * 8 + 8];
                l[0] = 0.0;
                l[1] = a;
                l[2] = a2;
                l[3] = a4;
                l[4] = -a;
                l[5] = -a2;
                l[6] = -a4;
                l[7] = 0.0;
            }
            let cell0 = gi * oc;
            for j in 0..oc {
                let s = p.starts[cell0 + j] as usize;
                let e = p.starts[cell0 + j + 1] as usize;
                let mut acc = 0.0f32;
                for &(r, c) in &p.entries[s..e] {
                    acc += lut[(r as usize) * 8 + c as usize];
                }
                // the only multiply: one alpha per (group, column)
                orow[j] += p.scalars[cell0 + j] * acc;
            }
        }
    }
    Tensor::new(vec![m, oc], out)
}

/// Convenience: pack on the fly (prefer holding a [`PackedQTensor`] on hot
/// paths — packing costs one pass over the codes).
pub fn qgemm_qt(x: &Tensor, qt: &QuantizedTensor) -> Result<Tensor> {
    qgemm(x, &PackedQTensor::pack(qt)?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::codes::Code;
    use crate::quant::qsq::{quantize, AssignMode};
    use crate::tensor::ops;
    use crate::util::rng::Rng;

    /// Build a QuantizedTensor with random codes and power-of-two scalars so
    /// decode-then-matmul and qgemm are both exact in f32.
    fn dyadic_qt(seed: u64, k: usize, oc: usize, group: usize) -> QuantizedTensor {
        let mut r = Rng::new(seed);
        let levels = [0i32, 1, 2, 4, -1, -2, -4];
        let codes: Vec<Code> = (0..k * oc)
            .map(|_| Code::from_level(levels[r.below(7) as usize]).unwrap())
            .collect();
        let scalars: Vec<f32> = (0..(k / group) * oc)
            .map(|_| (2.0f32).powi(r.range_i64(-2, 2) as i32))
            .collect();
        QuantizedTensor {
            codes,
            scalars,
            k,
            oc,
            group,
            phi: 4,
            gamma: 0.5,
            delta: 2.0,
            shape: vec![k, oc],
        }
    }

    fn int_activations(seed: u64, m: usize, k: usize) -> Tensor {
        let mut r = Rng::new(seed);
        let data: Vec<f32> = (0..m * k).map(|_| r.range_i64(-8, 8) as f32).collect();
        Tensor::new(vec![m, k], data).unwrap()
    }

    #[test]
    fn exact_vs_decode_matmul_on_dyadic_data() {
        for (seed, m, k, oc, group) in [(1u64, 3, 16, 5, 4), (2, 7, 48, 9, 16), (3, 1, 8, 1, 8)] {
            let qt = dyadic_qt(seed, k, oc, group);
            let x = int_activations(seed + 100, m, k);
            let dec = Tensor::new(vec![k, oc], qt.decode()).unwrap();
            let want = ops::matmul_naive(&x, &dec).unwrap();
            let got = qgemm_qt(&x, &qt).unwrap();
            assert_eq!(got.shape(), want.shape());
            // all values dyadic and well within the f32 mantissa -> exact
            assert_eq!(got.data(), want.data(), "seed {seed} diverged");
        }
    }

    #[test]
    fn close_on_real_quantized_gaussian_weights() {
        let mut r = Rng::new(9);
        let w: Vec<f32> = (0..150 * 16).map(|_| (r.normal() * 0.2) as f32).collect();
        let qt = quantize(&w, &[150, 16], 6, 4, AssignMode::SigmaSearch).unwrap();
        let xdata: Vec<f32> = (0..24 * 150).map(|_| (r.normal() * 0.8) as f32).collect();
        let x = Tensor::new(vec![24, 150], xdata).unwrap();
        let dec = Tensor::new(vec![150, 16], qt.decode()).unwrap();
        let want = ops::matmul_naive(&x, &dec).unwrap();
        let got = qgemm_qt(&x, &qt).unwrap();
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-3, "qgemm vs decode+matmul: {diff}");
    }

    #[test]
    fn zero_codes_are_dropped_at_pack_time() {
        let mut qt = dyadic_qt(5, 16, 4, 4);
        for c in qt.codes.iter_mut().step_by(2) {
            *c = Code::ZERO;
        }
        let p = PackedQTensor::pack(&qt).unwrap();
        assert!(p.skipped_fraction() >= 0.5);
        assert_eq!(p.skip.total, 64);
        let x = int_activations(6, 2, 16);
        let dec = Tensor::new(vec![16, 4], qt.decode()).unwrap();
        assert_eq!(
            qgemm(&x, &p).unwrap().data(),
            ops::matmul_naive(&x, &dec).unwrap().data()
        );
    }

    #[test]
    fn shape_mismatch_rejected() {
        let qt = dyadic_qt(7, 16, 4, 4);
        let x = int_activations(8, 2, 12);
        assert!(qgemm_qt(&x, &qt).is_err());
    }
}
