//! Lane-ized reduction primitives for the plane-sum inner loops (std only,
//! no nightly, no intrinsics).
//!
//! Every code-domain inner loop in this crate — qgemm2's level planes,
//! the CSD digit planes — bottoms out in the same operation: *sum the f32
//! activations a contiguous `u16` offset stream selects*.  The scalar form
//! folds every element into one accumulator, so the whole plane serializes
//! on one ~4-cycle add latency chain.  [`gather_sum`] breaks that chain:
//! offsets are walked in fixed [`F32_LANES`]-wide chunks with one
//! independent accumulator per lane (the shape autovectorizers and
//! out-of-order cores both want), and the lanes are folded with a *fixed*
//! pairwise tree so the reduction order — and therefore the result — is a
//! deterministic function of the plane alone, never of banding or timing.
//!
//! The scalar forms ([`gather_sum_scalar`], [`sum_i8_scalar`],
//! [`sum_i16_scalar`]) are retained as the bitwise oracles the differential
//! harness (`tests/test_lanes.rs`) and `benches/bench_kernels.rs` compare
//! against.
//!
//! Alongside the f32 gather lanes live the true SWAR word sums the paper's
//! integer datapath maps onto: [`sum_i8`] packs 8 biased bytes per `u64`
//! word and [`sum_i16`] 4 biased half-words, accumulating into split
//! even/odd lane registers and **widening every fixed number of words**
//! ([`I8_WIDEN_WORDS`] / [`I16_WIDEN_WORDS`]) so a lane's partial sum can
//! never carry into its neighbor.  The widening interval is chosen from the
//! lane arithmetic, not tuned: an i8 lane holds at most `255 * words` in a
//! u16 (overflow past 257 words), an i16 lane at most `65535 * words` in a
//! u32 (overflow past 65537 words).  The differential harness drives
//! all-extremal inputs *longer* than those intervals, so a missed widen
//! fails loudly instead of wrapping silently.

/// Chunk width of the f32 gather lanes: how many independent accumulators
/// [`gather_sum`] carries through a plane.
pub const F32_LANES: usize = 8;

/// i8 SWAR lanes per `u64` word.
pub const I8_LANES: usize = 8;

/// Words accumulated between i8 lane widenings.  Each word adds at most
/// 255 (a biased byte) to each u16 lane, so `255 * I8_WIDEN_WORDS` must
/// stay below `u16::MAX`: 256 words leave lane headroom of exactly one
/// more word.
pub const I8_WIDEN_WORDS: usize = 256;

/// i16 SWAR lanes per `u64` word.
pub const I16_LANES: usize = 4;

/// Words accumulated between i16 lane widenings.  Each word adds at most
/// 65535 (a biased half-word) to each u32 lane, so
/// `65535 * I16_WIDEN_WORDS` must stay below `u32::MAX`: 65536 words leave
/// lane headroom of exactly one more word.
pub const I16_WIDEN_WORDS: usize = 1 << 16;

/// Sum the activations an offset plane selects, one accumulator — the
/// scalar oracle the lane form is differentially tested against.
#[inline]
pub fn gather_sum_scalar(offsets: &[u16], xs: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for &off in offsets {
        s += xs[off as usize];
    }
    s
}

/// Sum the activations an offset plane selects with [`F32_LANES`]
/// independent accumulators — the plane-sum hot path of
/// [`super::qgemm::qgemm2`] and the CSD digit planes.
///
/// Planes shorter than one chunk take the scalar loop unchanged (bitwise
/// equal to [`gather_sum_scalar`], and the common case for sparse qgemm2
/// cells).  Longer planes reassociate the reduction — lane partials fold in
/// a fixed pairwise tree, then the sub-chunk tail — so the result can
/// differ from the scalar order by normal f32 rounding, but is itself fully
/// deterministic: it depends only on the plane contents, never on banding,
/// pinning, or thread count.
#[inline]
pub fn gather_sum(offsets: &[u16], xs: &[f32]) -> f32 {
    if offsets.len() < F32_LANES {
        return gather_sum_scalar(offsets, xs);
    }
    let mut acc = [0.0f32; F32_LANES];
    let mut chunks = offsets.chunks_exact(F32_LANES);
    for ch in &mut chunks {
        for (a, &off) in acc.iter_mut().zip(ch) {
            *a += xs[off as usize];
        }
    }
    let mut tail = 0.0f32;
    for &off in chunks.remainder() {
        tail += xs[off as usize];
    }
    // fixed pairwise fold: (0+4)+(2+6) then (1+5)+(3+7), tail last
    (((acc[0] + acc[4]) + (acc[2] + acc[6])) + ((acc[1] + acc[5]) + (acc[3] + acc[7]))) + tail
}

/// Scalar i8 sum into i64 — the oracle for [`sum_i8`].
pub fn sum_i8_scalar(xs: &[i8]) -> i64 {
    xs.iter().map(|&v| v as i64).sum()
}

/// Sum an `i8` slice via SWAR on `u64`: [`I8_LANES`] biased bytes per word,
/// even/odd bytes split into two 4×u16 lane registers, widened into the
/// i64 total every [`I8_WIDEN_WORDS`] words so no lane can carry into its
/// neighbor.  Exact for every input (integer arithmetic — bitwise equal to
/// [`sum_i8_scalar`]).
pub fn sum_i8(xs: &[i8]) -> i64 {
    // XOR with 0x80 maps i8 to its biased (x + 128) u8 representation
    const BIAS: u64 = 0x8080_8080_8080_8080;
    const LO_BYTES: u64 = 0x00FF_00FF_00FF_00FF;
    let mut total: i64 = 0;
    let mut biased: i64 = 0; // elements folded through the biased lanes
    let mut even: u64 = 0; // bytes 0,2,4,6 as 4 x u16 lanes
    let mut odd: u64 = 0; // bytes 1,3,5,7 as 4 x u16 lanes
    let mut words = 0usize;
    let mut chunks = xs.chunks_exact(I8_LANES);
    for ch in &mut chunks {
        let mut b = [0u8; 8];
        for (d, &s) in b.iter_mut().zip(ch) {
            *d = s as u8;
        }
        let w = u64::from_le_bytes(b) ^ BIAS;
        even += w & LO_BYTES;
        odd += (w >> 8) & LO_BYTES;
        words += 1;
        if words == I8_WIDEN_WORDS {
            total += fold_u16_lanes(even) + fold_u16_lanes(odd);
            biased += (words * I8_LANES) as i64;
            (even, odd, words) = (0, 0, 0);
        }
    }
    if words > 0 {
        total += fold_u16_lanes(even) + fold_u16_lanes(odd);
        biased += (words * I8_LANES) as i64;
    }
    total -= 128 * biased; // undo the per-element bias
    for &v in chunks.remainder() {
        total += v as i64;
    }
    total
}

/// Scalar i16 sum into i64 — the oracle for [`sum_i16`].
pub fn sum_i16_scalar(xs: &[i16]) -> i64 {
    xs.iter().map(|&v| v as i64).sum()
}

/// Sum an `i16` slice via SWAR on `u64`: [`I16_LANES`] biased half-words
/// per word, even/odd halves split into two 2×u32 lane registers, widened
/// into the i64 total every [`I16_WIDEN_WORDS`] words.  Exact for every
/// input (bitwise equal to [`sum_i16_scalar`]); in particular the total may
/// exceed `i32` — the widen carries lanes into i64 before any lane can
/// wrap, which is exactly what the overflow-adversarial harness cases pin.
pub fn sum_i16(xs: &[i16]) -> i64 {
    // XOR with 0x8000 maps i16 to its biased (x + 32768) u16 representation
    const BIAS: u64 = 0x8000_8000_8000_8000;
    const LO_HALVES: u64 = 0x0000_FFFF_0000_FFFF;
    let mut total: i64 = 0;
    let mut biased: i64 = 0;
    let mut even: u64 = 0; // half-words 0,2 as 2 x u32 lanes
    let mut odd: u64 = 0; // half-words 1,3 as 2 x u32 lanes
    let mut words = 0usize;
    let mut chunks = xs.chunks_exact(I16_LANES);
    for ch in &mut chunks {
        let w = (ch[0] as u16 as u64)
            | ((ch[1] as u16 as u64) << 16)
            | ((ch[2] as u16 as u64) << 32)
            | ((ch[3] as u16 as u64) << 48);
        let w = w ^ BIAS;
        even += w & LO_HALVES;
        odd += (w >> 16) & LO_HALVES;
        words += 1;
        if words == I16_WIDEN_WORDS {
            total += fold_u32_lanes(even) + fold_u32_lanes(odd);
            biased += (words * I16_LANES) as i64;
            (even, odd, words) = (0, 0, 0);
        }
    }
    if words > 0 {
        total += fold_u32_lanes(even) + fold_u32_lanes(odd);
        biased += (words * I16_LANES) as i64;
    }
    total -= 32768 * biased;
    for &v in chunks.remainder() {
        total += v as i64;
    }
    total
}

/// Stack-chunk width of the i16 gather: offsets are materialized into a
/// fixed `[i16; I16_GATHER_CHUNK]` buffer and each chunk is reduced by
/// [`sum_i16`], so the gather never allocates and the SWAR word loop runs
/// over contiguous half-words.
pub const I16_GATHER_CHUNK: usize = 256;

/// Sum the i16 activations an offset plane selects, one accumulator — the
/// scalar oracle for [`gather_sum_i16`].  Integer arithmetic into i64, so
/// the value is exact at every length.
#[inline]
pub fn gather_sum_i16_scalar(offsets: &[u16], xs: &[i16]) -> i64 {
    let mut s = 0i64;
    for &off in offsets {
        s += xs[off as usize] as i64;
    }
    s
}

/// Sum the i16 activations an offset plane selects through the SWAR word
/// reduction — the integer-activation plane-sum hot path of
/// [`super::qgemm::qgemm2`] and the CSD digit planes.
///
/// The plane's offsets are gathered [`I16_GATHER_CHUNK`] at a time into a
/// fixed stack buffer and each contiguous chunk is reduced by [`sum_i16`]
/// (four biased half-words per `u64` word).  Every addition is integer, so
/// unlike the f32 [`gather_sum`] there is no reassociation caveat: the
/// result is **bitwise equal** to [`gather_sum_i16_scalar`] at every
/// length.  Planes shorter than one SWAR word take the scalar loop.
#[inline]
pub fn gather_sum_i16(offsets: &[u16], xs: &[i16]) -> i64 {
    if offsets.len() < I16_LANES {
        return gather_sum_i16_scalar(offsets, xs);
    }
    let mut buf = [0i16; I16_GATHER_CHUNK];
    let mut total = 0i64;
    for ch in offsets.chunks(I16_GATHER_CHUNK) {
        let b = &mut buf[..ch.len()];
        for (d, &off) in b.iter_mut().zip(ch) {
            *d = xs[off as usize];
        }
        total += sum_i16(b);
    }
    total
}

#[inline]
fn fold_u16_lanes(acc: u64) -> i64 {
    ((acc & 0xFFFF) + ((acc >> 16) & 0xFFFF) + ((acc >> 32) & 0xFFFF) + (acc >> 48)) as i64
}

#[inline]
fn fold_u32_lanes(acc: u64) -> i64 {
    ((acc & 0xFFFF_FFFF) + (acc >> 32)) as i64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn gather_sum_short_planes_bitwise_equal_scalar() {
        let xs: Vec<f32> = (0..64).map(|v| (v as f32).sin()).collect();
        for len in 0..F32_LANES {
            let offsets: Vec<u16> = (0..len as u16).map(|o| (o * 7) % 64).collect();
            let (s, l) = (gather_sum_scalar(&offsets, &xs), gather_sum(&offsets, &xs));
            assert_eq!(s.to_bits(), l.to_bits(), "len {len} must take the scalar path");
        }
    }

    #[test]
    fn gather_sum_exact_on_integer_activations() {
        // integer activations: both orders are exact, so lane == scalar
        let mut r = Rng::new(7);
        let xs: Vec<f32> = (0..256).map(|_| r.range_i64(-16, 16) as f32).collect();
        for len in [8usize, 9, 63, 64, 65, 500] {
            let offsets: Vec<u16> = (0..len).map(|_| r.below(256) as u16).collect();
            assert_eq!(gather_sum(&offsets, &xs), gather_sum_scalar(&offsets, &xs), "len {len}");
        }
    }

    #[test]
    fn swar_sums_match_scalar_oracles() {
        let mut r = Rng::new(11);
        let i8s: Vec<i8> = (0..3000).map(|_| r.range_i64(-128, 127) as i8).collect();
        let i16s: Vec<i16> = (0..3000).map(|_| r.range_i64(-32768, 32767) as i16).collect();
        for len in [0usize, 1, 7, 8, 9, 63, 65, 3000] {
            assert_eq!(sum_i8(&i8s[..len]), sum_i8_scalar(&i8s[..len]), "i8 len {len}");
            let l16 = len.min(i16s.len());
            assert_eq!(sum_i16(&i16s[..l16]), sum_i16_scalar(&i16s[..l16]), "i16 len {len}");
        }
    }

    #[test]
    fn gather_sum_i16_bitwise_equal_scalar_at_chunk_boundaries() {
        let mut r = Rng::new(13);
        let xs: Vec<i16> = (0..512).map(|_| r.range_i64(-32768, 32767) as i16).collect();
        for len in [0usize, 1, 3, 4, 5, 255, 256, 257, 511, 512, 1000] {
            let offsets: Vec<u16> = (0..len).map(|_| r.below(512) as u16).collect();
            assert_eq!(
                gather_sum_i16(&offsets, &xs),
                gather_sum_i16_scalar(&offsets, &xs),
                "len {len}"
            );
        }
    }

    #[test]
    fn gather_sum_i16_extremes_do_not_wrap() {
        // every offset hits the same extremal value: the chunk sums stress
        // the biased-lane arithmetic while the true total is exact in i64
        let xs = vec![i16::MIN; 4];
        let offsets: Vec<u16> = vec![0; 3 * I16_GATHER_CHUNK + 7];
        assert_eq!(gather_sum_i16(&offsets, &xs), i16::MIN as i64 * offsets.len() as i64);
    }

    #[test]
    fn widen_interval_leaves_lane_headroom() {
        // the compile-time arithmetic the widening intervals rely on
        assert!(255u32 * I8_WIDEN_WORDS as u32 <= u16::MAX as u32);
        assert!(65535u64 * I16_WIDEN_WORDS as u64 <= u32::MAX as u64);
    }
}
