//! CSD-domain GEMM: the paper's Quality Scalable Multiplier (§V.B) as a
//! packed tensor kernel on the serving hot path.
//!
//! `hw::multiplier` simulates the QSM one scalar multiply at a time: the
//! weight operand is fixed-point recoded, CSD-encoded (digits in {-1, 0, +1},
//! no two adjacent non-zeros), truncated to at most `max_digits` non-zero
//! digits, and multiplied by shift-and-add — one partial product per kept
//! digit, everything below the budget clock-gated away.  This module carries
//! the same value semantics on the tensor path, with the layout tricks of
//! [`mod@super::qgemm`]'s v2 generation:
//!
//! * **Pack once, per-column digit planes.**  [`PackedCsdTensor::pack`]
//!   fixed-point-quantizes every f32 weight ([`CsdQuality::fmt`]), CSD-recodes
//!   it, truncates to the [`CsdQuality::max_digits`] most-significant
//!   non-zero digits, and buckets the survivors by (column, digit exponent,
//!   sign).  Each bucket becomes one contiguous *digit plane* of row
//!   offsets — the CSD analogue of qgemm2's per-level offset planes.
//! * **Shift-and-add inner loop.**  Per output element the kernel sums the
//!   activations each plane selects (a straight pass over a contiguous `u16`
//!   stream, run on the [`super::lanes::gather_sum`] lane reduction; the
//!   scalar order survives as [`csd_gemm_scalar_on`], the differential
//!   oracle) and combines plane sums as `acc += 2^(e - frac) * (pos - neg)`.
//!   The only multiplies are those exact power-of-two scalings — wire shifts
//!   in the QSM datapath, exact f32 ops here — so at most `max_digits`
//!   partial products are spent per weight, exactly like the hardware.
//! * **Same banding, same fusion.**  Rows split across the persistent worker
//!   pool via [`super::for_each_row_band_on`] (pooled runs are bitwise
//!   identical to serial), and [`super::qconv::csd_conv_into`] runs the same
//!   band/chunk `Scratch`-arena conv pipeline as the code-domain kernel.
//!
//! Exact CSD (`max_digits = usize::MAX`) reproduces the fixed-point product
//! bit-for-bit, so on activations where the fixed-point path is lossless the
//! kernel is *bitwise* equal to the [`crate::hw::multiplier::dot`] oracle —
//! the property tests assert exactly that.  Truncation error is monotone in
//! the digit budget (fewer digits, more error, less energy); the per-tensor
//! digit statistics ([`CsdStats`]) feed the [`Ledger`] the serving engine
//! accumulates per forward and exports via the `engine.host-csd.*` gauges.
//!
//! ```
//! use qsq_edge::device::CsdQuality;
//! use qsq_edge::kernels::csd::{csd_gemm, PackedCsdTensor};
//! use qsq_edge::tensor::Tensor;
//!
//! // pack a [K=2, OC=2] weight matrix at a 2-digit budget; all four
//! // weights are <= 2-digit CSD values, so the truncation loses nothing
//! let w = [0.75f32, -0.5, 1.0, 0.375];
//! let p = PackedCsdTensor::pack(&w, &[2, 2], CsdQuality::new(2)).unwrap();
//! assert_eq!(p.stats.digits_dropped, 0);
//!
//! let x = Tensor::new(vec![1, 2], vec![1.0, 2.0]).unwrap();
//! let y = csd_gemm(&x, &p).unwrap();
//! assert_eq!(y.data(), &[2.75, 0.25]); // [1*0.75 + 2*1.0, 1*-0.5 + 2*0.375]
//! ```

use anyhow::{bail, Result};

use crate::device::CsdQuality;
use crate::hw::csd::{nonzero_count, to_csd, truncate_msd};
use crate::hw::energy::Ledger;
use crate::hw::fixedpoint::Fixed;
use crate::tensor::Tensor;

/// Below this many inner-loop adds a csd_gemm runs un-threaded (shift-and-add
/// work per entry matches the code-domain kernel, so the crossover does too).
pub(crate) const CSD_PAR_THRESHOLD: usize = 1 << 18;

/// One digit plane: every kept CSD digit of one column that shares an
/// exponent, positive rows first.  `offsets[start..mid]` are the +1 digits'
/// row indices, `offsets[mid..end]` the -1 digits'.
#[derive(Clone, Copy, Debug)]
struct Plane {
    /// `2^(digit_index - frac)`: the exact power-of-two weight of the plane.
    scale: f32,
    /// The digit index itself — the left-shift the integer-activation band
    /// applies (`scale` with the format's `2^-frac` factored out).
    exp: u8,
    start: u32,
    mid: u32,
    end: u32,
}

/// Digit statistics realized by a packing — the energy side of the dial.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CsdStats {
    /// Weights packed (MAC operands per activation row).
    pub weights: u64,
    /// Non-zero CSD digits kept = partial products spent per activation row.
    pub digits_kept: u64,
    /// Non-zero digits the `max_digits` budget truncated (gated) away.
    pub digits_dropped: u64,
    /// Weights whose kept digit string is empty — fully skipped MACs
    /// (zero weights, or everything truncated at tiny budgets).
    pub zero_weights: u64,
}

impl CsdStats {
    /// Mean kept partial products per MAC.
    pub fn mean_pp(&self) -> f64 {
        if self.weights == 0 {
            0.0
        } else {
            self.digits_kept as f64 / self.weights as f64
        }
    }

    /// Fraction of MACs fully gated (no digits survive the budget).
    pub fn skipped_fraction(&self) -> f64 {
        if self.weights == 0 {
            0.0
        } else {
            self.zero_weights as f64 / self.weights as f64
        }
    }

    /// Fold another tensor's digit statistics into this aggregate (the
    /// engine sums its packed tensors through here).
    pub fn add(&mut self, other: &CsdStats) {
        self.weights += other.weights;
        self.digits_kept += other.digits_kept;
        self.digits_dropped += other.digits_dropped;
        self.zero_weights += other.zero_weights;
    }
}

/// An f32 weight tensor packed into truncated-CSD digit planes for the
/// shift-and-add GEMM ([`csd_gemm`]) and the fused conv pipeline
/// ([`super::qconv::csd_conv_into`]).
#[derive(Clone, Debug)]
pub struct PackedCsdTensor {
    pub k: usize,
    pub oc: usize,
    /// The dial this tensor was packed at (format + digit budget).
    pub quality: CsdQuality,
    /// Original tensor shape (C-order compatible with `[K, OC]`).
    pub shape: Vec<usize>,
    /// Row offsets (within K) of every digit plane, concatenated.
    offsets: Vec<u16>,
    /// Digit planes, grouped by column, exponent ascending within a column.
    planes: Vec<Plane>,
    /// `planes[col_bounds[j] .. col_bounds[j+1]]` are column `j`'s planes.
    col_bounds: Vec<u32>,
    /// Digit statistics realized by this packing.
    pub stats: CsdStats,
}

/// `2^e` as an exact f32 (`e` stays within f32's normal exponent range for
/// every valid [`crate::hw::fixedpoint::Format`]).
fn pow2(e: i32) -> f32 {
    (e as f64).exp2() as f32
}

impl PackedCsdTensor {
    /// Fixed-point recode, CSD-encode, and truncate `w` (C-order, shape
    /// `[.., OC]` flattened to `[K, OC]`) at `quality`, bucketing the kept
    /// digits into per-(column, exponent, sign) planes.
    pub fn pack(w: &[f32], shape: &[usize], quality: CsdQuality) -> Result<PackedCsdTensor> {
        let (k, oc) = crate::quant::qsq::matrix_dims(shape)?;
        if w.len() != k * oc {
            bail!("csd pack: {} weights vs shape {:?}", w.len(), shape);
        }
        if k > u16::MAX as usize + 1 {
            bail!("csd pack: K={k} too large for packed row offsets");
        }
        let fmt = quality.fmt;
        let mut offsets: Vec<u16> = Vec::new();
        let mut planes: Vec<Plane> = Vec::new();
        let mut col_bounds: Vec<u32> = Vec::with_capacity(oc + 1);
        col_bounds.push(0);
        let mut stats = CsdStats { weights: (k * oc) as u64, ..CsdStats::default() };
        // per-column buckets: digit index -> (+1 rows, -1 rows), drained in
        // ascending-exponent order so the accumulation order is canonical
        let mut buckets: std::collections::BTreeMap<u32, (Vec<u16>, Vec<u16>)> =
            std::collections::BTreeMap::new();
        for j in 0..oc {
            buckets.clear();
            for r in 0..k {
                let raw = Fixed::from_f64(w[r * oc + j] as f64, fmt).raw;
                let full = to_csd(raw);
                let total_nz = nonzero_count(&full);
                let kept = truncate_msd(&full, quality.max_digits);
                let kept_nz = nonzero_count(&kept);
                stats.digits_kept += kept_nz as u64;
                stats.digits_dropped += (total_nz - kept_nz) as u64;
                if kept_nz == 0 {
                    stats.zero_weights += 1;
                    continue;
                }
                for (i, &d) in kept.iter().enumerate() {
                    if d != 0 {
                        let bucket = buckets.entry(i as u32).or_default();
                        if d > 0 {
                            bucket.0.push(r as u16);
                        } else {
                            bucket.1.push(r as u16);
                        }
                    }
                }
            }
            for (&i, (pos, neg)) in buckets.iter() {
                let start = offsets.len() as u32;
                offsets.extend_from_slice(pos);
                let mid = offsets.len() as u32;
                offsets.extend_from_slice(neg);
                let end = offsets.len() as u32;
                planes.push(Plane {
                    scale: pow2(i as i32 - fmt.frac as i32),
                    exp: i as u8,
                    start,
                    mid,
                    end,
                });
            }
            col_bounds.push(planes.len() as u32);
        }
        Ok(PackedCsdTensor {
            k,
            oc,
            quality,
            shape: shape.to_vec(),
            offsets,
            planes,
            col_bounds,
            stats,
        })
    }

    /// The approximate f32 weights this packing represents (`[K, OC]`
    /// row-major): exactly `from_csd(truncate_msd(to_csd(fixed(w)), k))`
    /// renormalized, the value the shift-and-add datapath computes with.
    pub fn decode(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.k * self.oc];
        for j in 0..self.oc {
            let (lo, hi) = (self.col_bounds[j] as usize, self.col_bounds[j + 1] as usize);
            for pl in &self.planes[lo..hi] {
                for &r in &self.offsets[pl.start as usize..pl.mid as usize] {
                    out[r as usize * self.oc + j] += pl.scale;
                }
                for &r in &self.offsets[pl.mid as usize..pl.end as usize] {
                    out[r as usize * self.oc + j] -= pl.scale;
                }
            }
        }
        out
    }

    /// Fraction of MACs fully gated (no digits survive the budget).
    pub fn skipped_fraction(&self) -> f64 {
        self.stats.skipped_fraction()
    }

    /// Inner-loop adds one activation row costs (for thread dispatch).
    pub(crate) fn ops_per_row(&self) -> usize {
        self.offsets.len() + 2 * self.planes.len()
    }

    /// The energy this tensor spends on `rows` activation rows: one partial
    /// product per kept digit per row, one gated row per provisioned-but-idle
    /// multiplier row ([`CsdQuality::max_rows`]), one skipped MAC per fully
    /// gated weight.  The serving engine folds this into its per-request
    /// [`Ledger`] and exports it via the `engine.host-csd.*` gauges.
    pub fn ledger_for_rows(&self, rows: usize) -> Ledger {
        let r = rows as u64;
        let provisioned = self.stats.weights * self.quality.max_rows() as u64;
        Ledger {
            partial_products: r * self.stats.digits_kept,
            gated_rows: r * (provisioned - self.stats.digits_kept),
            skipped_macs: r * self.stats.zero_weights,
            ..Ledger::default()
        }
    }
}

/// One row band of the CSD kernel: `out` is `rows x OC` (rows inferred),
/// `xb` the matching rows of the activation matrix.  Accumulates into `out`.
///
/// Loop order is (column, row, plane): a column's plane list is resolved
/// once and reused across every row of the band.  Per output element the
/// planes accumulate in ascending exponent order with a deterministic
/// reduction inside each plane (`plane_sum` — the lane gather for serving,
/// the scalar oracle for the reference path), so band/chunk splits cannot
/// change any value.
#[inline(always)]
fn csd_band_with<S: Fn(&[u16], &[f32]) -> f32>(
    out: &mut [f32],
    xb: &[f32],
    p: &PackedCsdTensor,
    plane_sum: S,
) {
    let (k, oc) = (p.k, p.oc);
    if oc == 0 {
        return;
    }
    let rows = out.len() / oc;
    for j in 0..oc {
        let (lo, hi) = (p.col_bounds[j] as usize, p.col_bounds[j + 1] as usize);
        let planes = &p.planes[lo..hi];
        if planes.is_empty() {
            continue; // fully gated column: every MAC skipped
        }
        for i in 0..rows {
            let xrow = &xb[i * k..(i + 1) * k];
            let mut acc = 0.0f32;
            for pl in planes {
                let s = plane_sum(&p.offsets[pl.start as usize..pl.mid as usize], xrow)
                    - plane_sum(&p.offsets[pl.mid as usize..pl.end as usize], xrow);
                // the only multiply: an exact power-of-two scaling (a wire
                // shift in the QSM datapath)
                acc += pl.scale * s;
            }
            out[i * oc + j] += acc;
        }
    }
}

/// The serving band: digit-plane sums on the [`super::lanes::gather_sum`]
/// lane reduction.
pub(crate) fn csd_band(out: &mut [f32], xb: &[f32], p: &PackedCsdTensor) {
    csd_band_with(out, xb, p, super::lanes::gather_sum)
}

/// The retained scalar-oracle band: digit-plane sums in single-accumulator
/// order ([`super::lanes::gather_sum_scalar`]).
pub(crate) fn csd_band_scalar(out: &mut [f32], xb: &[f32], p: &PackedCsdTensor) {
    csd_band_with(out, xb, p, super::lanes::gather_sum_scalar)
}

/// The integer-activation serving band: i16 digit-plane sums on the SWAR
/// [`super::lanes::gather_sum_i16`] reduction (the fused-conv slab kernel of
/// the integer datapath).
pub(crate) fn csd_band_i16(out: &mut [f32], xb: &[i16], p: &PackedCsdTensor, dequant_in: f32) {
    csd_band_i16_with(out, xb, p, dequant_in, super::lanes::gather_sum_i16)
}

/// The integer-activation scalar-oracle band — bitwise equal to
/// [`csd_band_i16`] on every input (integer sums are exact either way).
pub(crate) fn csd_band_i16_scalar(
    out: &mut [f32],
    xb: &[i16],
    p: &PackedCsdTensor,
    dequant_in: f32,
) {
    csd_band_i16_with(out, xb, p, dequant_in, super::lanes::gather_sum_i16_scalar)
}

/// `out[M,OC] = x[M,K] @ packed` on the digit-plane layout (caller provides
/// a zeroed `out` of exactly `m * OC`), row bands on the global worker pool.
pub fn csd_gemm_into(out: &mut [f32], xd: &[f32], m: usize, p: &PackedCsdTensor) {
    csd_gemm_into_on(super::Pool::global(), out, xd, m, p)
}

/// [`csd_gemm_into`] with an explicit worker-pool handle (the serving
/// engines thread their pool through here).
pub fn csd_gemm_into_on(
    pool: &super::Pool,
    out: &mut [f32],
    xd: &[f32],
    m: usize,
    p: &PackedCsdTensor,
) {
    debug_assert_eq!(out.len(), m * p.oc);
    debug_assert_eq!(xd.len(), m * p.k);
    let total = m.saturating_mul(p.ops_per_row());
    let nthreads = super::threads_for_rows(m, total, CSD_PAR_THRESHOLD).min(pool.width());
    let band = |_: usize, ob: &mut [f32], xb: &[f32]| csd_band(ob, xb, p);
    super::for_each_row_band_on(pool, out, xd, m, p.k, p.oc, nthreads, band);
}

/// [`csd_gemm_into_on`] with every digit-plane sum on the retained scalar
/// oracle — identical banding, single-accumulator reduction order.  The
/// differential baseline, not a serving path.
pub fn csd_gemm_scalar_on(
    pool: &super::Pool,
    out: &mut [f32],
    xd: &[f32],
    m: usize,
    p: &PackedCsdTensor,
) {
    debug_assert_eq!(out.len(), m * p.oc);
    debug_assert_eq!(xd.len(), m * p.k);
    let total = m.saturating_mul(p.ops_per_row());
    let nthreads = super::threads_for_rows(m, total, CSD_PAR_THRESHOLD).min(pool.width());
    let band = |_: usize, ob: &mut [f32], xb: &[f32]| csd_band_scalar(ob, xb, p);
    super::for_each_row_band_on(pool, out, xd, m, p.k, p.oc, nthreads, band);
}

/// One row band of the *integer-activation* CSD kernel: `xb` holds raw i16
/// activations, every digit-plane sum is an exact i64 reduction (the SWAR
/// [`super::lanes::gather_sum_i16`] for serving, the scalar gather for the
/// oracle), and a plane's power-of-two weight is applied as a **left shift
/// of its integer sum** — the literal shift-and-add of the QSM datapath,
/// with no f32 op inside the column accumulation at all.  The single
/// dequant-rescale per (column, row) cell folds the weight format's
/// `2^-frac` together with the activation format's reciprocal scale.
/// Integer reductions are exact in any order, so the lane and scalar forms
/// are bitwise equal on every input.
#[inline(always)]
fn csd_band_i16_with<S: Fn(&[u16], &[i16]) -> i64>(
    out: &mut [f32],
    xb: &[i16],
    p: &PackedCsdTensor,
    dequant_in: f32,
    plane_sum: S,
) {
    let (k, oc) = (p.k, p.oc);
    if oc == 0 {
        return;
    }
    let rows = out.len() / oc;
    // one dequant-rescale per cell: weight-format and activation-format
    // reciprocal scales folded into a single exact power-of-two-times-dq
    let scale = pow2(-(p.quality.fmt.frac as i32)) * dequant_in;
    for j in 0..oc {
        let (lo, hi) = (p.col_bounds[j] as usize, p.col_bounds[j + 1] as usize);
        let planes = &p.planes[lo..hi];
        if planes.is_empty() {
            continue; // fully gated column: every MAC skipped
        }
        for i in 0..rows {
            let xrow = &xb[i * k..(i + 1) * k];
            let mut acc = 0i64;
            for pl in planes {
                let s = plane_sum(&p.offsets[pl.start as usize..pl.mid as usize], xrow)
                    - plane_sum(&p.offsets[pl.mid as usize..pl.end as usize], xrow);
                // the digit's power-of-two weight is a pure integer shift
                acc += s << pl.exp;
            }
            out[i * oc + j] += scale * (acc as f32);
        }
    }
}

/// `out[M,OC] += dequant(xq[M,K]) @ packed` with i16 activations on the
/// truncated-CSD shift-and-add kernel: digit-plane sums through the SWAR
/// [`super::lanes::gather_sum_i16`] reduction, row bands on `pool`.
/// `dequant_in` is the activation format's reciprocal scale.
pub fn csd_gemm_i16_into_on(
    pool: &super::Pool,
    out: &mut [f32],
    xq: &[i16],
    m: usize,
    p: &PackedCsdTensor,
    dequant_in: f32,
) {
    debug_assert_eq!(out.len(), m * p.oc);
    debug_assert_eq!(xq.len(), m * p.k);
    let total = m.saturating_mul(p.ops_per_row());
    let nthreads = super::threads_for_rows(m, total, CSD_PAR_THRESHOLD).min(pool.width());
    let band = |_: usize, ob: &mut [f32], xb: &[i16]| {
        csd_band_i16_with(ob, xb, p, dequant_in, super::lanes::gather_sum_i16)
    };
    super::for_each_row_band_i16_on(pool, out, xq, m, p.k, p.oc, nthreads, band);
}

/// [`csd_gemm_i16_into_on`] with every digit-plane sum on the scalar gather
/// oracle — the differential baseline; must be **bitwise** equal to the
/// SWAR form on every input (integer sums are exact in both orders).
pub fn csd_gemm_i16_scalar_on(
    pool: &super::Pool,
    out: &mut [f32],
    xq: &[i16],
    m: usize,
    p: &PackedCsdTensor,
    dequant_in: f32,
) {
    debug_assert_eq!(out.len(), m * p.oc);
    debug_assert_eq!(xq.len(), m * p.k);
    let total = m.saturating_mul(p.ops_per_row());
    let nthreads = super::threads_for_rows(m, total, CSD_PAR_THRESHOLD).min(pool.width());
    let band = |_: usize, ob: &mut [f32], xb: &[i16]| {
        csd_band_i16_with(ob, xb, p, dequant_in, super::lanes::gather_sum_i16_scalar)
    };
    super::for_each_row_band_i16_on(pool, out, xq, m, p.k, p.oc, nthreads, band);
}

/// Shared tensor-level entry: validate shapes, run with the given thread
/// count (`None` = the production heuristic, via [`csd_gemm_into`]).
fn csd_gemm_run(x: &Tensor, p: &PackedCsdTensor, nthreads: Option<usize>) -> Result<Tensor> {
    let xs = x.shape();
    if xs.len() != 2 || xs[1] != p.k {
        bail!("csd_gemm shapes {:?} x [{}, {}]", xs, p.k, p.oc);
    }
    let m = xs[0];
    let mut out = vec![0.0f32; m * p.oc];
    match nthreads {
        None => csd_gemm_into(&mut out, x.data(), m, p),
        Some(nt) => {
            let band = |_: usize, ob: &mut [f32], xb: &[f32]| csd_band(ob, xb, p);
            super::for_each_row_band(&mut out, x.data(), m, p.k, p.oc, nt, band);
        }
    }
    Tensor::new(vec![m, p.oc], out)
}

/// `x [M,K] @ packed [K,OC] -> [M,OC]` on the truncated-CSD shift-and-add
/// kernel.
pub fn csd_gemm(x: &Tensor, p: &PackedCsdTensor) -> Result<Tensor> {
    csd_gemm_run(x, p, None)
}

/// [`csd_gemm`] with an explicit thread count — lets tests pin band
/// boundaries and check the parallel run is bitwise identical to the
/// single-thread one.
pub fn csd_gemm_threads(x: &Tensor, p: &PackedCsdTensor, nthreads: usize) -> Result<Tensor> {
    csd_gemm_run(x, p, Some(nthreads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::csd::{from_csd, is_canonic};
    use crate::hw::fixedpoint::Format;
    use crate::hw::multiplier::{dot, QsmConfig};
    use crate::tensor::ops;
    use crate::util::prop::{check, forall};
    use crate::util::rng::Rng;

    const FMT: Format = Format::Q16_14;

    fn quality(max_digits: usize) -> CsdQuality {
        CsdQuality { fmt: FMT, max_digits }
    }

    /// Gaussian weights clamped to |w| <= 0.9 so the fixed-point oracle
    /// never saturates, even after MSD-first truncation rounds a value up.
    fn safe_weights(r: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| ((r.normal() * 0.2).clamp(-0.9, 0.9)) as f32).collect()
    }

    /// Ternary activations keep every partial sum of the kernel a small
    /// multiple of 2^-frac — exactly representable in f32 at these shapes.
    fn ternary_x(r: &mut Rng, m: usize, k: usize) -> Tensor {
        let data: Vec<f32> = (0..m * k).map(|_| r.range_i64(-1, 1) as f32).collect();
        Tensor::new(vec![m, k], data).unwrap()
    }

    /// The (exponent, sign) digits a packing stores for weight (r, j).
    fn weight_digits(p: &PackedCsdTensor, r: usize, j: usize) -> Vec<(i32, i8)> {
        let mut out = Vec::new();
        let (lo, hi) = (p.col_bounds[j] as usize, p.col_bounds[j + 1] as usize);
        for pl in &p.planes[lo..hi] {
            let e = (pl.scale.log2().round() as i32) + p.quality.fmt.frac as i32;
            for &row in &p.offsets[pl.start as usize..pl.mid as usize] {
                if row as usize == r {
                    out.push((e, 1i8));
                }
            }
            for &row in &p.offsets[pl.mid as usize..pl.end as usize] {
                if row as usize == r {
                    out.push((e, -1i8));
                }
            }
        }
        out
    }

    #[test]
    fn exact_decode_matches_fixed_point_quantization() {
        let mut r = Rng::new(1);
        let w = safe_weights(&mut r, 48 * 5);
        let p = PackedCsdTensor::pack(&w, &[48, 5], quality(usize::MAX)).unwrap();
        let dec = p.decode();
        for (i, (&wi, &di)) in w.iter().zip(&dec).enumerate() {
            let want = Fixed::from_f64(wi as f64, FMT).to_f64() as f32;
            assert_eq!(di, want, "weight {i}: {wi}");
        }
        assert_eq!(p.stats.digits_dropped, 0, "exact packing drops nothing");
    }

    #[test]
    fn prop_packed_digits_keep_csd_invariants() {
        // the packed tensor form preserves per-weight NAF structure: <= k
        // non-zeros, non-adjacent exponents, and the value equals the
        // truncated integer-CSD reconstruction
        forall(
            40,
            |r| (r.next_u64(), r.below(4) as usize + 1),
            |&(seed, max_digits)| {
                let mut r = Rng::new(seed);
                let (k, oc) = (12usize, 4usize);
                let w = safe_weights(&mut r, k * oc);
                let p = PackedCsdTensor::pack(&w, &[k, oc], quality(max_digits)).unwrap();
                for row in 0..k {
                    for j in 0..oc {
                        let mut digits = weight_digits(&p, row, j);
                        digits.sort_by_key(|&(e, _)| e);
                        check(digits.len() <= max_digits, "digit budget exceeded")?;
                        check(
                            digits.windows(2).all(|d| d[1].0 > d[0].0 + 1),
                            "adjacent CSD exponents in packed form",
                        )?;
                        let raw = Fixed::from_f64(w[row * oc + j] as f64, FMT).raw;
                        let want = from_csd(&truncate_msd(&to_csd(raw), max_digits));
                        let got: i64 = digits.iter().map(|&(e, s)| s as i64 * (1i64 << e)).sum();
                        check(got == want, "packed digits != truncated CSD value")?;
                        check(is_canonic(&to_csd(raw)), "source CSD not canonic")?;
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_truncation_error_monotone_in_digit_budget() {
        forall(
            30,
            |r| r.next_u64(),
            |&seed| {
                let mut r = Rng::new(seed);
                let (k, oc) = (16usize, 3usize);
                let w = safe_weights(&mut r, k * oc);
                let exact_pack = PackedCsdTensor::pack(&w, &[k, oc], quality(usize::MAX)).unwrap();
                let exact = exact_pack.decode();
                let total_digits = exact_pack.stats.digits_kept;
                let mut last_err = f64::MAX;
                let mut last_pp = 0u64;
                for budget in [1usize, 2, 3, 4, 6, usize::MAX] {
                    let p = PackedCsdTensor::pack(&w, &[k, oc], quality(budget)).unwrap();
                    let err: f64 = p
                        .decode()
                        .iter()
                        .zip(&exact)
                        .map(|(&a, &b)| (a - b).abs() as f64)
                        .sum();
                    check(err <= last_err + 1e-12, "error grew with a larger budget")?;
                    check(p.stats.digits_kept >= last_pp, "pp shrank with a larger budget")?;
                    check(
                        p.stats.digits_kept + p.stats.digits_dropped == total_digits,
                        "kept + dropped != total digits",
                    )?;
                    last_err = err;
                    last_pp = p.stats.digits_kept;
                }
                check(last_err == 0.0, "unlimited budget must reproduce exact CSD")
            },
        );
    }

    #[test]
    fn exact_csd_gemm_bitwise_matches_qsm_dot_oracle_at_model_shapes() {
        // lenet-c2 [5,5,6,16] -> [150,16] and f1w [256,120]: on ternary
        // activations every value of both paths is an exact small multiple
        // of 2^-frac, so the kernel must equal the per-scalar fixed-point
        // datapath simulator bit for bit.
        let mut r = Rng::new(7);
        for (shape, m) in [(vec![5usize, 5, 6, 16], 3usize), (vec![256, 120], 2)] {
            let (k, oc) = crate::quant::qsq::matrix_dims(&shape).unwrap();
            let w = safe_weights(&mut r, k * oc);
            let p = PackedCsdTensor::pack(&w, &shape, quality(usize::MAX)).unwrap();
            let x = ternary_x(&mut r, m, k);
            let got = csd_gemm(&x, &p).unwrap();
            let cfg = QsmConfig::new(FMT, usize::MAX);
            for j in 0..oc {
                let ws: Vec<f64> = (0..k).map(|row| w[row * oc + j] as f64).collect();
                for i in 0..m {
                    let xs: Vec<f64> =
                        x.data()[i * k..(i + 1) * k].iter().map(|&v| v as f64).collect();
                    let (want, _) = dot(cfg, &xs, &ws);
                    assert_eq!(
                        got.data()[i * oc + j],
                        want as f32,
                        "shape {shape:?}: out[{i},{j}] diverged from the QSM oracle"
                    );
                }
            }
        }
    }

    #[test]
    fn truncated_csd_gemm_bitwise_matches_qsm_dot_oracle() {
        let mut r = Rng::new(9);
        let (k, oc, m) = (64usize, 8usize, 4usize);
        let w = safe_weights(&mut r, k * oc);
        let x = ternary_x(&mut r, m, k);
        for budget in [1usize, 2, 3, 5] {
            let p = PackedCsdTensor::pack(&w, &[k, oc], quality(budget)).unwrap();
            let got = csd_gemm(&x, &p).unwrap();
            let cfg = QsmConfig::new(FMT, budget);
            for j in 0..oc {
                let ws: Vec<f64> = (0..k).map(|row| w[row * oc + j] as f64).collect();
                for i in 0..m {
                    let xs: Vec<f64> =
                        x.data()[i * k..(i + 1) * k].iter().map(|&v| v as f64).collect();
                    let (want, st) = dot(cfg, &xs, &ws);
                    assert_eq!(got.data()[i * oc + j], want as f32, "k={budget} [{i},{j}]");
                    assert!(st.multiplies == k as u64);
                }
            }
        }
    }

    #[test]
    fn csd_gemm_equals_decode_matmul_and_is_close_on_gaussian_data() {
        let mut r = Rng::new(11);
        let (k, oc, m) = (48usize, 9usize, 5usize);
        let w = safe_weights(&mut r, k * oc);
        for budget in [2usize, 4, usize::MAX] {
            let p = PackedCsdTensor::pack(&w, &[k, oc], quality(budget)).unwrap();
            let dec = Tensor::new(vec![k, oc], p.decode()).unwrap();
            // exact equality on ternary data (both paths exact in f32)
            let xi = ternary_x(&mut r, m, k);
            let got = csd_gemm(&xi, &p).unwrap();
            let want = ops::matmul_naive(&xi, &dec).unwrap();
            assert_eq!(got.data(), want.data(), "budget {budget} on ternary data");
            // tight closeness on gaussian activations (different reduction
            // orders, same approximate weights)
            let xdata: Vec<f32> = (0..m * k).map(|_| r.normal() as f32).collect();
            let xg = Tensor::new(vec![m, k], xdata).unwrap();
            let got = csd_gemm(&xg, &p).unwrap();
            let want = ops::matmul_naive(&xg, &dec).unwrap();
            let diff = got.max_abs_diff(&want);
            assert!(diff < 1e-4, "budget {budget}: {diff}");
        }
    }

    #[test]
    fn parallel_bands_bitwise_equal_single_thread() {
        let mut r = Rng::new(13);
        let (k, oc) = (64usize, 7usize);
        let w = safe_weights(&mut r, k * oc);
        let p = PackedCsdTensor::pack(&w, &[k, oc], quality(3)).unwrap();
        for m in [1usize, 3, 5, 8] {
            let xdata: Vec<f32> = (0..m * k).map(|_| r.normal() as f32).collect();
            let x = Tensor::new(vec![m, k], xdata).unwrap();
            let st = csd_gemm_threads(&x, &p, 1).unwrap();
            for nt in [2usize, 3, 4, 7] {
                let par = csd_gemm_threads(&x, &p, nt).unwrap();
                assert_eq!(par.data(), st.data(), "m={m} nt={nt} diverged");
            }
        }
    }

    #[test]
    fn zero_weights_are_skipped_and_counted() {
        let w = vec![0.0f32; 32];
        let p = PackedCsdTensor::pack(&w, &[8, 4], quality(usize::MAX)).unwrap();
        assert_eq!(p.stats.zero_weights, 32);
        assert_eq!(p.stats.digits_kept, 0);
        assert_eq!(p.skipped_fraction(), 1.0);
        let x = Tensor::new(vec![2, 8], vec![1.0; 16]).unwrap();
        assert!(csd_gemm(&x, &p).unwrap().data().iter().all(|&v| v == 0.0));
        // a zero digit budget gates everything, harmlessly
        let mut r = Rng::new(17);
        let w = safe_weights(&mut r, 32);
        let p0 = PackedCsdTensor::pack(&w, &[8, 4], quality(0)).unwrap();
        assert_eq!(p0.stats.zero_weights, 32);
        assert!(csd_gemm(&x, &p0).unwrap().data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn ledger_counts_scale_with_rows() {
        let mut r = Rng::new(19);
        let w = safe_weights(&mut r, 24 * 4);
        let p = PackedCsdTensor::pack(&w, &[24, 4], quality(2)).unwrap();
        let l1 = p.ledger_for_rows(1);
        let l8 = p.ledger_for_rows(8);
        assert_eq!(l1.partial_products, p.stats.digits_kept);
        assert_eq!(l8.partial_products, 8 * l1.partial_products);
        assert_eq!(l8.gated_rows, 8 * l1.gated_rows);
        assert_eq!(l8.skipped_macs, 8 * l1.skipped_macs);
        // provisioned rows = weights * max_rows, split pp vs gated
        assert_eq!(
            l1.partial_products + l1.gated_rows,
            p.stats.weights * p.quality.max_rows() as u64
        );
        assert!(l1.total_pj() > 0.0);
    }

    #[test]
    fn i16_band_bitwise_equals_f32_band_on_ternary_activations() {
        // Ternary activations at dequant 1.0: both paths compute the same
        // integers scaled by the same power of two, every f32 add exact
        // (partial sums stay far below 2^24), so the integer band must be
        // *bitwise* equal to the f32 band.
        let mut r = Rng::new(29);
        let (k, oc) = (48usize, 5usize);
        let w = safe_weights(&mut r, k * oc);
        let p = PackedCsdTensor::pack(&w, &[k, oc], quality(3)).unwrap();
        let pool = crate::kernels::Pool::new(1);
        for m in [1usize, 4, 6] {
            let x = ternary_x(&mut r, m, k);
            let xq: Vec<i16> = x.data().iter().map(|&v| v as i16).collect();
            let want = csd_gemm_threads(&x, &p, 1).unwrap();
            let mut got = vec![0.0f32; m * oc];
            csd_gemm_i16_into_on(&pool, &mut got, &xq, m, &p, 1.0);
            assert_eq!(got.as_slice(), want.data(), "m={m} diverged");
        }
    }

    #[test]
    fn i16_lane_and_scalar_orders_are_bitwise_equal() {
        // Integer plane sums are exact in any order, so the SWAR gather and
        // the scalar gather must agree bitwise on every input — including
        // full-range i16 activations.
        let mut r = Rng::new(31);
        let (k, oc) = (96usize, 11usize);
        let w = safe_weights(&mut r, k * oc);
        let p = PackedCsdTensor::pack(&w, &[k, oc], quality(4)).unwrap();
        let pool = crate::kernels::Pool::new(4);
        let dq = 1.0f32 / 4096.0;
        for m in [1usize, 4, 9] {
            let xq: Vec<i16> =
                (0..m * k).map(|_| r.range_i64(-32768, 32767) as i16).collect();
            let mut lane = vec![0.0f32; m * oc];
            let mut scalar = vec![0.0f32; m * oc];
            csd_gemm_i16_into_on(&pool, &mut lane, &xq, m, &p, dq);
            csd_gemm_i16_scalar_on(&pool, &mut scalar, &xq, m, &p, dq);
            assert_eq!(lane, scalar, "m={m} diverged");
        }
    }

    #[test]
    fn rejects_bad_shapes() {
        let w = vec![0.1f32; 12];
        assert!(PackedCsdTensor::pack(&w, &[5, 2], quality(2)).is_err(), "len mismatch");
        assert!(PackedCsdTensor::pack(&w, &[12], quality(2)).is_err(), "rank 1");
        let p = PackedCsdTensor::pack(&w, &[6, 2], quality(2)).unwrap();
        let x = Tensor::new(vec![2, 5], vec![0.0; 10]).unwrap();
        assert!(csd_gemm(&x, &p).is_err(), "K mismatch");
    }
}
