//! Activation calibration for the fixed-point integer datapath.
//!
//! The paper's QSM pipeline is integer end-to-end; what the serving path
//! needs to join it is a *per-layer activation Q-format*.  This module is
//! that calibration pass: observe the max-|activation| each layer's input
//! sees on a representative (synth/validation) batch, pick the widest
//! [`Format`] whose fractional scaling still covers that range without
//! saturating ([`format_for_max_abs`]), and freeze the choice into an
//! [`ActPlan`].  With a plan in hand the fused pipeline
//! (`runtime::host::FusedFwd`) quantizes activations between layers inside
//! the i16 ping/pong scratch buffers and the qgemm2/CSD kernels gather them
//! through `lanes::gather_sum_i16` — a pure SWAR integer reduction with one
//! dequant-rescale per (group, column) cell.
//!
//! Two properties the differential harness (`tests/test_intpath.rs`) pins:
//!
//! * **Determinism** — the same batch always yields the same formats: the
//!   pass is a pure fold over the activations, no RNG, no timing.
//! * **Saturation, never wraparound** — quantization is round-to-nearest
//!   with clamping ([`quantize_into`], same semantics as
//!   [`crate::hw::fixedpoint::Fixed::from_f64`]).  An activation outside
//!   the calibrated range clips to the format's extremes; it can never wrap
//!   sign like a bare `as i16` cast would.

use std::collections::BTreeMap;

use crate::hw::fixedpoint::Format;

/// Total bits of the activation fixed-point format (sign included).  i16 is
/// the carrier the SWAR word sums pack four-per-`u64`, and 16 activation
/// bits is the paper's edge operating point; the fraction is what
/// calibration picks per layer.
pub const ACT_TOTAL_BITS: u32 = 16;

/// Largest |x| in a buffer (0.0 for an empty one).  The range statistic the
/// calibration pass folds per layer; symmetric formats only need the one
/// number.
pub fn max_abs(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |a, &v| a.max(v.abs()))
}

/// Pick the activation format for a layer whose inputs reached `max_abs`:
/// the largest fractional shift `f` (at [`ACT_TOTAL_BITS`] total) such that
/// `max_abs * 2^f` still rounds inside the raw range — i.e. the finest
/// resolution that represents the whole observed range without saturating.
/// A degenerate all-zero layer gets the finest format; a range beyond the
/// integer capacity of the format (`max_abs > max_raw`) gets `frac = 0` and
/// relies on saturation.
pub fn format_for_max_abs(max_abs: f32) -> Format {
    let total = ACT_TOTAL_BITS;
    let max_raw = ((1i64 << (total - 1)) - 1) as f64;
    let mut frac = total - 1;
    if max_abs > 0.0 {
        let v = max_abs as f64;
        while frac > 0 && (v * (1u64 << frac) as f64).round() > max_raw {
            frac -= 1;
        }
    }
    Format { total, frac }
}

/// Quantize f32 activations to the format's raw i16 domain: round to
/// nearest, **clamp** to `[min_raw, max_raw]` (saturate, never wrap) —
/// element-for-element the semantics of
/// [`crate::hw::fixedpoint::Fixed::from_f64`] on the i16 carrier.
pub fn quantize_into(xs: &[f32], fmt: Format, dst: &mut [i16]) {
    debug_assert!(dst.len() >= xs.len());
    let s = fmt.scale();
    let (lo, hi) = (fmt.min_raw(), fmt.max_raw());
    for (d, &v) in dst.iter_mut().zip(xs) {
        *d = ((v as f64 * s).round() as i64).clamp(lo, hi) as i16;
    }
}

/// The reciprocal scale that maps the format's raw domain back to f32 —
/// the one dequant-rescale factor each integer plane sum pays per
/// (group, column) cell.
pub fn dequant_scale(fmt: Format) -> f32 {
    (1.0 / fmt.scale()) as f32
}

/// Pre-quantize a layer's f32 bias vector into the i32 raw domain of the
/// layer-output format, so the serving epilogue adds integers (computed
/// once at calibration time, never per forward).
pub fn quantize_bias(bias: &[f32], fmt: Format) -> Vec<i32> {
    let s = fmt.scale();
    bias.iter().map(|&b| (b as f64 * s).round() as i32).collect()
}

/// The integer-domain layer epilogue: requantize a GEMM accumulator row
/// block `acc` (`rows x n` f32) into the next layer's format while adding
/// the pre-quantized bias and applying ReLU — all in raw integers.  ReLU is
/// the lower clamp at 0; the upper clamp saturates at the format maximum,
/// so a post-bias overshoot clips instead of wrapping.
pub fn bias_relu_quantize_into(acc: &[f32], bias_q: &[i32], fmt: Format, dst: &mut [i16]) {
    let n = bias_q.len();
    debug_assert!(dst.len() >= acc.len());
    if n == 0 {
        return;
    }
    let s = fmt.scale();
    let hi = fmt.max_raw();
    for (row, drow) in acc.chunks_exact(n).zip(dst.chunks_exact_mut(n)) {
        for ((d, &v), &bq) in drow.iter_mut().zip(row).zip(bias_q) {
            let q = (v as f64 * s).round() as i64 + bq as i64;
            *d = q.clamp(0, hi) as i16;
        }
    }
}

/// The calibrated per-layer activation plan: one Q-format per layer input
/// (keyed by the layer's weight-tensor name) plus the pre-quantized bias
/// vectors (keyed by the bias-tensor name, in the *output* format of their
/// layer).  Built once by an engine's `calibrate` pass, then read-only on
/// the serving path.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ActPlan {
    formats: BTreeMap<String, Format>,
    biases: BTreeMap<String, Vec<i32>>,
}

impl ActPlan {
    /// The calibrated input format of layer `name`, if calibration saw it.
    pub fn format(&self, name: &str) -> Option<Format> {
        self.formats.get(name).copied()
    }

    /// The pre-quantized bias raw values for bias tensor `name`.
    pub fn bias_q(&self, name: &str) -> Option<&[i32]> {
        self.biases.get(name).map(|v| v.as_slice())
    }

    /// Record layer `name`'s input format.
    pub fn set_format(&mut self, name: &str, fmt: Format) {
        self.formats.insert(name.to_string(), fmt);
    }

    /// Record bias tensor `name`'s pre-quantized raw values.
    pub fn set_bias_q(&mut self, name: &str, q: Vec<i32>) {
        self.biases.insert(name.to_string(), q);
    }

    /// Activation bit-width of the plan (the Ledger's `act_bits` gauge).
    pub fn act_bits(&self) -> u32 {
        ACT_TOTAL_BITS
    }

    /// True when no layer has been calibrated.
    pub fn is_empty(&self) -> bool {
        self.formats.is_empty()
    }

    /// The calibrated `(layer, format)` pairs, sorted by layer name.
    pub fn formats(&self) -> impl Iterator<Item = (&str, Format)> {
        self.formats.iter().map(|(n, &f)| (n.as_str(), f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn format_covers_observed_range_without_saturation() {
        for ma in [1e-4f32, 0.3, 1.0, 1.9994, 7.5, 100.0, 30000.0] {
            let fmt = format_for_max_abs(ma);
            assert_eq!(fmt.total, ACT_TOTAL_BITS);
            // the observed extreme itself must quantize inside the range
            let q = (ma as f64 * fmt.scale()).round() as i64;
            assert!(q <= fmt.max_raw(), "max_abs {ma} saturates Q{}.{}", fmt.total, fmt.frac);
            // ... and one more fractional bit would not fit (finest choice)
            if fmt.frac + 1 < fmt.total {
                let q2 = (ma as f64 * 2.0 * fmt.scale()).round() as i64;
                assert!(q2 > fmt.max_raw(), "format for {ma} is not the finest");
            }
        }
    }

    #[test]
    fn degenerate_ranges_get_the_finest_format() {
        assert_eq!(format_for_max_abs(0.0).frac, ACT_TOTAL_BITS - 1);
        // beyond integer capacity: integer format, saturation handles it
        assert_eq!(format_for_max_abs(1e9).frac, 0);
    }

    #[test]
    fn quantize_saturates_and_never_wraps() {
        let fmt = format_for_max_abs(1.0);
        let xs = [0.5f32, -0.25, 1.0, 2.0, -3.0, 1e9, -1e9];
        let mut q = vec![0i16; xs.len()];
        quantize_into(&xs, fmt, &mut q);
        let d = dequant_scale(fmt);
        assert!((q[0] as f32 * d - 0.5).abs() < 1e-3);
        assert!((q[1] as f32 * d + 0.25).abs() < 1e-3);
        // everything past the range clamps to the extremes — same sign in,
        // extreme of the same sign out (a wrap would flip it)
        assert_eq!(q[3], fmt.max_raw() as i16);
        assert_eq!(q[5], fmt.max_raw() as i16);
        assert_eq!(q[4], fmt.min_raw() as i16);
        assert_eq!(q[6], fmt.min_raw() as i16);
    }

    #[test]
    fn integer_epilogue_matches_float_bias_relu_within_epsilon() {
        let fmt = format_for_max_abs(4.0);
        let bias = [0.25f32, -1.0, 0.5];
        let bq = quantize_bias(&bias, fmt);
        let acc = [0.5f32, 0.4, -2.0, 3.9, 0.9, -0.1];
        let mut q = vec![0i16; acc.len()];
        bias_relu_quantize_into(&acc, &bq, fmt, &mut q);
        let d = dequant_scale(fmt);
        for (i, (&v, &qi)) in acc.iter().zip(&q).enumerate() {
            let want = (v + bias[i % 3]).max(0.0);
            assert!(
                (qi as f32 * d - want).abs() <= 2.0 * d,
                "cell {i}: {} vs {want}",
                qi as f32 * d
            );
            assert!(qi >= 0, "ReLU output must be non-negative in the raw domain");
        }
    }

    #[test]
    fn plan_is_a_value_type() {
        let mut a = ActPlan::default();
        assert!(a.is_empty());
        a.set_format("c1w", format_for_max_abs(1.0));
        a.set_bias_q("c1b", vec![1, 2, 3]);
        let b = a.clone();
        assert_eq!(a, b);
        assert_eq!(a.format("c1w"), Some(format_for_max_abs(1.0)));
        assert_eq!(a.bias_q("c1b"), Some(&[1i32, 2, 3][..]));
        assert_eq!(a.act_bits(), 16);
    }
}
