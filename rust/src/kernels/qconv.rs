//! Fused conv pipeline: im2col patches staged chunk-by-chunk into the
//! [`Scratch`] arena and multiplied band-by-band, so the full patch matrix
//! is never materialized and steady-state serving allocates nothing.
//!
//! The classic path (`ops::conv2d`) builds the whole `[B*H'*W', kh*kw*C]`
//! patch matrix — for ConvNet's first layer at batch 32 that is a ~3.5 MB
//! allocation per request before the GEMM even starts.  Here each band of
//! output rows runs as one persistent-pool job ([`super::pool`]) owning one
//! small staging slab ([`CHUNK`] patch rows); it alternates staging a slab
//! with multiplying it on the band kernel, so patch data is consumed while
//! still hot in L1/L2.  The same driver serves all three kernels:
//!
//! * [`qconv_into`] — code-domain: the slab hits the plane-packed,
//!   multiplication-free `qgemm2_band`;
//! * [`csd_conv_into`] — CSD-domain: the slab hits the truncated-CSD
//!   shift-and-add band kernel ([`mod@super::csd`], the quality-dial path);
//! * [`fconv_into`] — f32: the slab hits [`super::blocked::gemm_band`]
//!   (4x8 register microtile).
//!
//! Both produce output bitwise identical to pad + im2col + (q)gemm over the
//! materialized matrix: chunking only splits *rows* of the patch matrix, and
//! every per-element reduction runs in the same order.

use anyhow::{bail, Result};

use super::blocked;
use super::csd::{csd_band, csd_band_i16, PackedCsdTensor, CSD_PAR_THRESHOLD};
use super::qgemm::{qgemm2_band, qgemm2_band_i16, PackedQTensorV2, QGEMM_PAR_THRESHOLD};
use super::{ensure_cap, ensure_cap_i16, threads_for_rows, LayerPeak, Pool, Scratch, ScratchStats};
use crate::tensor::ops;
use crate::tensor::Tensor;

/// Patch rows staged per slab: small enough that a slab stays cache-hot,
/// large enough to amortize the staging loop.
pub const CHUNK: usize = 32;

/// Resolved conv geometry (pre/post padding and output dims).
struct Geom {
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    /// Post-padding input dims.
    h2: usize,
    w2: usize,
    kh: usize,
    kw: usize,
    oc: usize,
    pad: usize,
    /// Patch width `kh*kw*c`.
    kcols: usize,
    oh: usize,
    ow: usize,
    /// Output rows `b*oh*ow`.
    rows: usize,
}

fn geometry(
    xlen: usize,
    (b, h, w, c): (usize, usize, usize, usize),
    (kh, kw, oc): (usize, usize, usize),
    same: bool,
) -> Result<Geom> {
    if xlen != b * h * w * c {
        bail!("conv input len {xlen} != {b}x{h}x{w}x{c}");
    }
    let pad = if same { kh / 2 } else { 0 };
    let (h2, w2) = (h + 2 * pad, w + 2 * pad);
    if h2 < kh || w2 < kw {
        bail!("conv window {kh}x{kw} larger than input {h2}x{w2}");
    }
    let (oh, ow) = (h2 - kh + 1, w2 - kw + 1);
    Ok(Geom {
        b,
        h,
        w,
        c,
        h2,
        w2,
        kh,
        kw,
        oc,
        pad,
        kcols: kh * kw * c,
        oh,
        ow,
        rows: b * oh * ow,
    })
}

/// The element domain of the conv activation datapath — `f32` or raw `i16`.
/// The trait routes each domain's structural staging primitives and its
/// [`Scratch`] arena pair, so the one band/chunk driver below serves both
/// the float pipeline and the calibrated integer pipeline without touching
/// the f32 code paths (the f32 impl delegates to the exact functions the
/// driver called before it was generic).
trait ConvElem: Copy + Default + Sync {
    fn ensure(buf: &mut Vec<Self>, len: usize, stats: &mut ScratchStats);
    fn stage_patch_rows(
        xd: &[Self],
        dims: (usize, usize, usize, usize),
        kh: usize,
        kw: usize,
        row0: usize,
        nrows: usize,
        dst: &mut [Self],
    );
    fn pad_into(xd: &[Self], dims: (usize, usize, usize, usize), p: usize, dst: &mut [Self]);
    fn grow_peak(last: &mut LayerPeak, patch_elems: usize, pad_elems: usize, act_elems: usize);
    /// This domain's `(patches, padded, stats, last)` arena fields, split
    /// out of the one `&mut Scratch` borrow.
    fn arena(
        scratch: &mut Scratch,
    ) -> (&mut Vec<Self>, &mut Vec<Self>, &mut ScratchStats, &mut LayerPeak);
}

impl ConvElem for f32 {
    fn ensure(buf: &mut Vec<f32>, len: usize, stats: &mut ScratchStats) {
        ensure_cap(buf, len, stats)
    }
    fn stage_patch_rows(
        xd: &[f32],
        dims: (usize, usize, usize, usize),
        kh: usize,
        kw: usize,
        row0: usize,
        nrows: usize,
        dst: &mut [f32],
    ) {
        ops::im2col_rows_into(xd, dims, kh, kw, row0, nrows, dst)
    }
    fn pad_into(xd: &[f32], dims: (usize, usize, usize, usize), p: usize, dst: &mut [f32]) {
        ops::pad_hw_into(xd, dims, p, dst)
    }
    fn grow_peak(last: &mut LayerPeak, patch_elems: usize, pad_elems: usize, act_elems: usize) {
        last.grow(patch_elems, pad_elems, act_elems)
    }
    fn arena(
        s: &mut Scratch,
    ) -> (&mut Vec<f32>, &mut Vec<f32>, &mut ScratchStats, &mut LayerPeak) {
        (&mut s.patches, &mut s.padded, &mut s.stats, &mut s.last)
    }
}

impl ConvElem for i16 {
    fn ensure(buf: &mut Vec<i16>, len: usize, stats: &mut ScratchStats) {
        ensure_cap_i16(buf, len, stats)
    }
    fn stage_patch_rows(
        xd: &[i16],
        dims: (usize, usize, usize, usize),
        kh: usize,
        kw: usize,
        row0: usize,
        nrows: usize,
        dst: &mut [i16],
    ) {
        ops::im2col_rows_i16_into(xd, dims, kh, kw, row0, nrows, dst)
    }
    fn pad_into(xd: &[i16], dims: (usize, usize, usize, usize), p: usize, dst: &mut [i16]) {
        ops::pad_hw_i16_into(xd, dims, p, dst)
    }
    fn grow_peak(last: &mut LayerPeak, patch_elems: usize, pad_elems: usize, act_elems: usize) {
        last.grow_i16(patch_elems, pad_elems, act_elems)
    }
    fn arena(
        s: &mut Scratch,
    ) -> (&mut Vec<i16>, &mut Vec<i16>, &mut ScratchStats, &mut LayerPeak) {
        (&mut s.qpatches, &mut s.qpadded, &mut s.stats, &mut s.last)
    }
}

/// Stage the zero-padded input into the `padded` scratch buffer (or pass
/// the input through untouched for VALID convs).
fn staged_input<'a, T: ConvElem>(
    xd: &'a [T],
    g: &Geom,
    padded: &'a mut Vec<T>,
    stats: &mut ScratchStats,
) -> &'a [T] {
    if g.pad == 0 {
        return xd;
    }
    let plen = g.b * g.h2 * g.w2 * g.c;
    T::ensure(padded, plen, stats);
    let pd = &mut padded[..plen];
    pd.fill(T::default());
    T::pad_into(xd, (g.b, g.h, g.w, g.c), g.pad, pd);
    &padded[..plen]
}

/// One pre-split conv band awaiting pickup by a pool job: `(first_row,
/// out_band, patch_slab)`, taken exactly once by the job that owns the
/// index.
type ConvBandPart<'a, T> = std::sync::Mutex<Option<(usize, &'a mut [f32], &'a mut [T])>>;

/// The shared band/chunk driver: split the `[B*H'*W']` patch-row space into
/// row bands, one persistent-pool job each; within a band, alternate
/// staging a [`CHUNK`]-row im2col slab into this band's slice of `patches`
/// with running `kernel` (which accumulates `slab @ weight` into its zeroed
/// out chunk).  `cost = (work_per_row, par_threshold)` feeds band dispatch;
/// `last` collects the staging high-water for layer telemetry.
#[allow(clippy::too_many_arguments)] // geometry + 3 disjoint scratch fields + pool, by design
fn conv_driver<T, K>(
    pool: &Pool,
    xin: &[T],
    g: &Geom,
    cost: (usize, usize),
    patches: &mut Vec<T>,
    stats: &mut ScratchStats,
    last: &mut LayerPeak,
    out: &mut [f32],
    kernel: &K,
) where
    T: ConvElem,
    K: Fn(&mut [f32], &[T]) + Sync,
{
    debug_assert_eq!(out.len(), g.rows * g.oc);
    if g.rows == 0 || g.oc == 0 {
        return;
    }
    let nthreads =
        threads_for_rows(g.rows, g.rows.saturating_mul(cost.0), cost.1).min(pool.width());
    T::ensure(patches, nthreads * CHUNK * g.kcols, stats);
    // patch slabs are T-wide; the output accumulator is always f32
    T::grow_peak(last, nthreads * CHUNK * g.kcols, 0, 0);
    last.grow(0, 0, out.len());
    let (kcols, oc) = (g.kcols, g.oc);
    let run_band = |row0: usize, oband: &mut [f32], pband: &mut [T]| {
        let band_rows = oband.len() / oc;
        let mut done = 0;
        while done < band_rows {
            let nr = CHUNK.min(band_rows - done);
            let slab = &mut pband[..nr * kcols];
            T::stage_patch_rows(xin, (g.b, g.h2, g.w2, g.c), g.kh, g.kw, row0 + done, nr, slab);
            let ochunk = &mut oband[done * oc..(done + nr) * oc];
            ochunk.fill(0.0);
            kernel(ochunk, slab);
            done += nr;
        }
    };
    if nthreads <= 1 {
        run_band(0, out, &mut patches[..CHUNK * kcols]);
        return;
    }
    let rpb = g.rows.div_ceil(nthreads);
    let nbands = g.rows.div_ceil(rpb);
    let parts: Vec<ConvBandPart<T>> = out
        .chunks_mut(rpb * oc)
        .zip(patches.chunks_mut(CHUNK * kcols))
        .enumerate()
        .map(|(bi, (ob, pb))| std::sync::Mutex::new(Some((bi * rpb, ob, pb))))
        .collect();
    pool.run_bands(nbands, &|bi: usize| {
        let (row0, ob, pb) = parts[bi].lock().unwrap().take().expect("band taken once");
        run_band(row0, ob, pb);
    });
}

/// Shared prologue + driver for the packed conv kernels ([`qconv_into`],
/// [`csd_conv_into`]): validate the `[kh,kw,C,OC]` packed `shape` (with
/// GEMM reduction width `k`) against the input geometry, stage the arena
/// buffers, and run the band/chunk driver with the given band `kernel`.
/// `what` names the caller in errors; `cost` feeds thread dispatch.
#[allow(clippy::too_many_arguments)] // geometry + 2 packed fields + scratch + kernel, by design
fn packed_conv_into<T, K>(
    pool: &Pool,
    xd: &[T],
    dims: (usize, usize, usize, usize),
    what: &str,
    shape: &[usize],
    k: usize,
    cost: (usize, usize),
    same: bool,
    scratch: &mut Scratch,
    out: &mut Vec<f32>,
    kernel: &K,
) -> Result<(usize, usize, usize)>
where
    T: ConvElem,
    K: Fn(&mut [f32], &[T]) + Sync,
{
    if shape.len() != 4 {
        bail!("{what}: packed weight must be [kh,kw,C,OC], got {shape:?}");
    }
    let (kh, kw, oc) = (shape[0], shape[1], shape[3]);
    if shape[2] != dims.3 {
        bail!("{what} channel mismatch: input C={} vs weight {shape:?}", dims.3);
    }
    let g = geometry(xd.len(), dims, (kh, kw, oc), same)?;
    if g.kcols != k {
        bail!("{what}: weight K={k} but window is {kh}x{kw}x{}", dims.3);
    }
    let (patches, padded, stats, last) = T::arena(scratch);
    ensure_cap(out, g.rows * g.oc, stats);
    if g.pad > 0 {
        T::grow_peak(last, 0, g.b * g.h2 * g.w2 * g.c, 0);
    }
    let xin = staged_input(xd, &g, padded, stats);
    conv_driver(pool, xin, &g, cost, patches, stats, last, &mut out[..g.rows * g.oc], kernel);
    Ok((g.oh, g.ow, oc))
}

/// Fused code-domain conv: `x [B,H,W,C]` (flat slice) ⊛ packed
/// `[kh,kw,C,OC]` → `out [B*H'*W'*OC]` (grown in place, never reallocated
/// once warm).  Band jobs run on `pool`.  Returns `(H', W', OC)`.
pub fn qconv_into(
    pool: &Pool,
    xd: &[f32],
    dims: (usize, usize, usize, usize),
    p: &PackedQTensorV2,
    same: bool,
    scratch: &mut Scratch,
    out: &mut Vec<f32>,
) -> Result<(usize, usize, usize)> {
    packed_conv_into(
        pool,
        xd,
        dims,
        "qconv",
        &p.shape,
        p.k,
        (p.ops_per_row(), QGEMM_PAR_THRESHOLD),
        same,
        scratch,
        out,
        &|o: &mut [f32], slab: &[f32]| qgemm2_band(o, slab, p),
    )
}

/// [`qconv_into`] with plane sums on the retained scalar oracle — identical
/// banding and chunking, single-accumulator reduction order.  The
/// scalar-reference forward path, not a serving path.
pub fn qconv_scalar_into(
    pool: &Pool,
    xd: &[f32],
    dims: (usize, usize, usize, usize),
    p: &PackedQTensorV2,
    same: bool,
    scratch: &mut Scratch,
    out: &mut Vec<f32>,
) -> Result<(usize, usize, usize)> {
    packed_conv_into(
        pool,
        xd,
        dims,
        "qconv",
        &p.shape,
        p.k,
        (p.ops_per_row(), QGEMM_PAR_THRESHOLD),
        same,
        scratch,
        out,
        &|o: &mut [f32], slab: &[f32]| super::qgemm::qgemm2_band_scalar(o, slab, p),
    )
}

/// Fused code-domain conv on the integer datapath: raw-i16 activations
/// `xq [B,H,W,C]` (at the layer's calibrated Q-format, reciprocal scale
/// `dequant_in`) ⊛ packed `[kh,kw,C,OC]` → f32 `out [B*H'*W'*OC]`.  Same
/// band/chunk arena driver as [`qconv_into`], staging i16 patch slabs in
/// `scratch.qpatches` / `scratch.qpadded` (half the arena bytes), plane
/// sums on the SWAR i16 gather.  Returns `(H', W', OC)`.
pub fn qconv_i16_into(
    pool: &Pool,
    xq: &[i16],
    dims: (usize, usize, usize, usize),
    p: &PackedQTensorV2,
    dequant_in: f32,
    same: bool,
    scratch: &mut Scratch,
    out: &mut Vec<f32>,
) -> Result<(usize, usize, usize)> {
    packed_conv_into(
        pool,
        xq,
        dims,
        "qconv",
        &p.shape,
        p.k,
        (p.ops_per_row(), QGEMM_PAR_THRESHOLD),
        same,
        scratch,
        out,
        &|o: &mut [f32], slab: &[i16]| qgemm2_band_i16(o, slab, p, dequant_in),
    )
}

/// [`qconv_i16_into`] with plane sums on the scalar i16 gather oracle —
/// bitwise equal to the SWAR form on every input.
pub fn qconv_i16_scalar_into(
    pool: &Pool,
    xq: &[i16],
    dims: (usize, usize, usize, usize),
    p: &PackedQTensorV2,
    dequant_in: f32,
    same: bool,
    scratch: &mut Scratch,
    out: &mut Vec<f32>,
) -> Result<(usize, usize, usize)> {
    packed_conv_into(
        pool,
        xq,
        dims,
        "qconv",
        &p.shape,
        p.k,
        (p.ops_per_row(), QGEMM_PAR_THRESHOLD),
        same,
        scratch,
        out,
        &|o: &mut [f32], slab: &[i16]| super::qgemm::qgemm2_band_i16_scalar(o, slab, p, dequant_in),
    )
}

/// Fused CSD-domain conv: `x [B,H,W,C]` (flat slice) ⊛ truncated-CSD packed
/// `[kh,kw,C,OC]` → `out [B*H'*W'*OC]` (grown in place, never reallocated
/// once warm) — the same band/chunk arena driver as [`qconv_into`] with the
/// shift-and-add band kernel.  Band jobs run on `pool`.  Returns
/// `(H', W', OC)`.
pub fn csd_conv_into(
    pool: &Pool,
    xd: &[f32],
    dims: (usize, usize, usize, usize),
    p: &PackedCsdTensor,
    same: bool,
    scratch: &mut Scratch,
    out: &mut Vec<f32>,
) -> Result<(usize, usize, usize)> {
    packed_conv_into(
        pool,
        xd,
        dims,
        "csd_conv",
        &p.shape,
        p.k,
        (p.ops_per_row(), CSD_PAR_THRESHOLD),
        same,
        scratch,
        out,
        &|o: &mut [f32], slab: &[f32]| csd_band(o, slab, p),
    )
}

/// [`csd_conv_into`] with digit-plane sums on the retained scalar oracle —
/// identical banding and chunking, single-accumulator reduction order.
pub fn csd_conv_scalar_into(
    pool: &Pool,
    xd: &[f32],
    dims: (usize, usize, usize, usize),
    p: &PackedCsdTensor,
    same: bool,
    scratch: &mut Scratch,
    out: &mut Vec<f32>,
) -> Result<(usize, usize, usize)> {
    packed_conv_into(
        pool,
        xd,
        dims,
        "csd_conv",
        &p.shape,
        p.k,
        (p.ops_per_row(), CSD_PAR_THRESHOLD),
        same,
        scratch,
        out,
        &|o: &mut [f32], slab: &[f32]| super::csd::csd_band_scalar(o, slab, p),
    )
}

/// Fused CSD-domain conv on the integer datapath: raw-i16 activations ⊛
/// truncated-CSD packed `[kh,kw,C,OC]` → f32 `out` — shift-and-add digit
/// planes over SWAR i16 gathers, i16 arena staging.  Returns `(H', W', OC)`.
pub fn csd_conv_i16_into(
    pool: &Pool,
    xq: &[i16],
    dims: (usize, usize, usize, usize),
    p: &PackedCsdTensor,
    dequant_in: f32,
    same: bool,
    scratch: &mut Scratch,
    out: &mut Vec<f32>,
) -> Result<(usize, usize, usize)> {
    packed_conv_into(
        pool,
        xq,
        dims,
        "csd_conv",
        &p.shape,
        p.k,
        (p.ops_per_row(), CSD_PAR_THRESHOLD),
        same,
        scratch,
        out,
        &|o: &mut [f32], slab: &[i16]| csd_band_i16(o, slab, p, dequant_in),
    )
}

/// [`csd_conv_i16_into`] with digit-plane sums on the scalar i16 gather
/// oracle — bitwise equal to the SWAR form on every input.
pub fn csd_conv_i16_scalar_into(
    pool: &Pool,
    xq: &[i16],
    dims: (usize, usize, usize, usize),
    p: &PackedCsdTensor,
    dequant_in: f32,
    same: bool,
    scratch: &mut Scratch,
    out: &mut Vec<f32>,
) -> Result<(usize, usize, usize)> {
    packed_conv_into(
        pool,
        xq,
        dims,
        "csd_conv",
        &p.shape,
        p.k,
        (p.ops_per_row(), CSD_PAR_THRESHOLD),
        same,
        scratch,
        out,
        &|o: &mut [f32], slab: &[i16]| super::csd::csd_band_i16_scalar(o, slab, p, dequant_in),
    )
}

/// Convenience wrapper over [`csd_conv_into`] on the global pool (allocates
/// the result; serving paths use `csd_conv_into` with a reusable output
/// buffer instead).
pub fn csd_conv(
    x: &Tensor,
    p: &PackedCsdTensor,
    same: bool,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    let s = x.shape();
    if s.len() != 4 {
        bail!("csd_conv expects NHWC, got {:?}", s);
    }
    let dims = (s[0], s[1], s[2], s[3]);
    let mut out = Vec::new();
    let (oh, ow, oc) = csd_conv_into(Pool::global(), x.data(), dims, p, same, scratch, &mut out)?;
    out.truncate(dims.0 * oh * ow * oc);
    Tensor::new(vec![dims.0, oh, ow, oc], out)
}

/// Fused f32 conv: same pipeline with the blocked microkernel.  `wd` is the
/// conv weight `[kh,kw,C,OC]` flattened — row-major, which is exactly the
/// reshaped `[kh*kw*C, OC]` GEMM operand.  Returns `(H', W')`.
#[allow(clippy::too_many_arguments)] // conv geometry is irreducibly wide
pub fn fconv_into(
    pool: &Pool,
    xd: &[f32],
    dims: (usize, usize, usize, usize),
    wd: &[f32],
    (kh, kw, oc): (usize, usize, usize),
    same: bool,
    scratch: &mut Scratch,
    out: &mut Vec<f32>,
) -> Result<(usize, usize)> {
    let g = geometry(xd.len(), dims, (kh, kw, oc), same)?;
    if wd.len() != g.kcols * oc {
        bail!("fconv weight len {} != {}x{}x{}x{}", wd.len(), kh, kw, dims.3, oc);
    }
    ensure_cap(out, g.rows * g.oc, &mut scratch.stats);
    if g.pad > 0 {
        scratch.last.grow(0, g.b * g.h2 * g.w2 * g.c, 0);
    }
    let xin = staged_input(xd, &g, &mut scratch.padded, &mut scratch.stats);
    let kcols = g.kcols;
    conv_driver(
        pool,
        xin,
        &g,
        (kcols * oc, blocked::PAR_THRESHOLD_MACS),
        &mut scratch.patches,
        &mut scratch.stats,
        &mut scratch.last,
        &mut out[..g.rows * g.oc],
        &|o: &mut [f32], slab: &[f32]| blocked::gemm_band(o, slab, wd, kcols, oc),
    );
    Ok((g.oh, g.ow))
}

/// Convenience wrapper over [`qconv_into`]: `x [B,H,W,C]` ⊛ packed →
/// `[B,H',W',OC]` tensor on the global pool (allocates the result; serving
/// paths use `qconv_into` with a reusable output buffer instead).
pub fn qconv(x: &Tensor, p: &PackedQTensorV2, same: bool, scratch: &mut Scratch) -> Result<Tensor> {
    let s = x.shape();
    if s.len() != 4 {
        bail!("qconv expects NHWC, got {:?}", s);
    }
    let dims = (s[0], s[1], s[2], s[3]);
    let mut out = Vec::new();
    let (oh, ow, oc) = qconv_into(Pool::global(), x.data(), dims, p, same, scratch, &mut out)?;
    out.truncate(dims.0 * oh * ow * oc);
    Tensor::new(vec![dims.0, oh, ow, oc], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::qgemm2;
    use crate::quant::qsq::{quantize, AssignMode};
    use crate::tensor::ops as tops;
    use crate::util::rng::Rng;

    fn gauss(r: &mut Rng, len: usize, s: f64) -> Vec<f32> {
        (0..len).map(|_| (r.normal() * s) as f32).collect()
    }

    /// The materialized oracle: pad + full im2col + plane-packed qgemm.
    fn oracle(x: &Tensor, p: &PackedQTensorV2, same: bool) -> Tensor {
        let (kh, kw, oc) = (p.shape[0], p.shape[1], p.shape[3]);
        let padded;
        let xin = if same {
            padded = tops::pad_hw(x, kh / 2).unwrap();
            &padded
        } else {
            x
        };
        let (patches, oh, ow) = tops::im2col(xin, kh, kw).unwrap();
        let out = qgemm2(&patches, p).unwrap();
        out.reshape(vec![x.shape()[0], oh, ow, oc]).unwrap()
    }

    #[test]
    fn fused_qconv_bitwise_equals_materialized_oracle() {
        let mut r = Rng::new(5);
        for (wshape, xs, same) in [
            (vec![5usize, 5, 1, 6], vec![2usize, 28, 28, 1], false), // lenet c1
            (vec![3, 3, 3, 8], vec![2, 16, 16, 3], true),            // convnet-ish k1
            (vec![3, 3, 8, 4], vec![1, 8, 8, 8], true),
            (vec![1, 1, 4, 4], vec![3, 6, 6, 4], false),
        ] {
            let nw: usize = wshape.iter().product();
            let w = gauss(&mut r, nw, 0.3);
            let group = crate::quant::vectorize::Grouping::nearest_divisor(&wshape, 8).unwrap();
            let qt = quantize(&w, &wshape, group, 4, AssignMode::SigmaSearch).unwrap();
            let p = PackedQTensorV2::pack(&qt).unwrap();
            let nx: usize = xs.iter().product();
            let x = Tensor::new(xs.clone(), gauss(&mut r, nx, 1.0)).unwrap();
            let want = oracle(&x, &p, same);
            let mut scratch = Scratch::new();
            let got = qconv(&x, &p, same, &mut scratch).unwrap();
            assert_eq!(got.shape(), want.shape(), "{wshape:?} same={same}");
            assert_eq!(got.data(), want.data(), "{wshape:?} same={same} diverged");
        }
    }

    #[test]
    fn fused_csd_conv_bitwise_equals_materialized_oracle() {
        use crate::device::CsdQuality;
        use crate::hw::fixedpoint::Format;
        use crate::kernels::csd::{csd_gemm, PackedCsdTensor};
        let mut r = Rng::new(21);
        for (wshape, xs, same, digits) in [
            (vec![5usize, 5, 1, 6], vec![2usize, 28, 28, 1], false, 2usize), // lenet c1
            (vec![3, 3, 3, 8], vec![2, 12, 12, 3], true, usize::MAX),
        ] {
            let nw: usize = wshape.iter().product();
            let w = gauss(&mut r, nw, 0.3);
            let q = CsdQuality { fmt: Format::Q16_14, max_digits: digits };
            let p = PackedCsdTensor::pack(&w, &wshape, q).unwrap();
            let nx: usize = xs.iter().product();
            let x = Tensor::new(xs.clone(), gauss(&mut r, nx, 1.0)).unwrap();
            // materialized oracle: pad + full im2col + csd_gemm
            let (kh, kw, oc) = (wshape[0], wshape[1], wshape[3]);
            let padded;
            let xin = if same {
                padded = tops::pad_hw(&x, kh / 2).unwrap();
                &padded
            } else {
                &x
            };
            let (patches, oh, ow) = tops::im2col(xin, kh, kw).unwrap();
            let want =
                csd_gemm(&patches, &p).unwrap().reshape(vec![xs[0], oh, ow, oc]).unwrap();
            let mut scratch = Scratch::new();
            let got = csd_conv(&x, &p, same, &mut scratch).unwrap();
            assert_eq!(got.shape(), want.shape(), "{wshape:?} same={same}");
            assert_eq!(
                got.data(),
                want.data(),
                "{wshape:?} same={same} digits={digits} diverged"
            );
        }
    }

    #[test]
    fn fused_f32_conv_bitwise_equals_conv2d() {
        let mut r = Rng::new(6);
        let x = Tensor::new(vec![2, 10, 10, 3], gauss(&mut r, 2 * 10 * 10 * 3, 1.0)).unwrap();
        let w = Tensor::new(vec![3, 3, 3, 5], gauss(&mut r, 3 * 3 * 3 * 5, 0.5)).unwrap();
        for same in [false, true] {
            let want = if same {
                tops::conv2d_same(&x, &w).unwrap()
            } else {
                tops::conv2d(&x, &w).unwrap()
            };
            let mut scratch = Scratch::new();
            let mut out = Vec::new();
            let (oh, ow) = fconv_into(
                Pool::global(),
                x.data(),
                (2, 10, 10, 3),
                w.data(),
                (3, 3, 5),
                same,
                &mut scratch,
                &mut out,
            )
            .unwrap();
            assert_eq!(want.shape(), &[2, oh, ow, 5]);
            assert_eq!(&out[..2 * oh * ow * 5], want.data(), "same={same} diverged");
        }
    }

    #[test]
    fn scratch_stops_allocating_after_first_pass() {
        let mut r = Rng::new(7);
        let w = gauss(&mut r, 3 * 3 * 8 * 4, 0.3);
        let qt = quantize(&w, &[3, 3, 8, 4], 8, 4, AssignMode::SigmaSearch).unwrap();
        let p = PackedQTensorV2::pack(&qt).unwrap();
        let x = Tensor::new(vec![2, 8, 8, 8], gauss(&mut r, 2 * 8 * 8 * 8, 1.0)).unwrap();
        let mut scratch = Scratch::new();
        let mut out = Vec::new();
        let pool = Pool::global();
        qconv_into(pool, x.data(), (2, 8, 8, 8), &p, true, &mut scratch, &mut out).unwrap();
        let cold_allocs = scratch.stats.allocs;
        assert!(cold_allocs > 0);
        for _ in 0..3 {
            qconv_into(pool, x.data(), (2, 8, 8, 8), &p, true, &mut scratch, &mut out).unwrap();
        }
        assert_eq!(scratch.stats.allocs, cold_allocs, "warm passes must not allocate");
        assert!(scratch.stats.reuses >= 9, "stats: {:?}", scratch.stats);
    }

    #[test]
    fn i16_conv_bitwise_equals_f32_conv_on_integer_activations() {
        // Integer activations at dequant 1.0: the i16 driver stages the same
        // values through the same bands and chunks, every plane sum is exact
        // in both domains, and `alpha * 1.0` is exact — so both the code-
        // domain and CSD-domain integer convs must be bitwise equal to their
        // f32 twins.
        let mut r = Rng::new(23);
        let (wshape, xs) = (vec![3usize, 3, 3, 8], vec![2usize, 12, 12, 3]);
        let nw: usize = wshape.iter().product();
        let w = gauss(&mut r, nw, 0.3);
        let group = crate::quant::vectorize::Grouping::nearest_divisor(&wshape, 8).unwrap();
        let qt = quantize(&w, &wshape, group, 4, AssignMode::SigmaSearch).unwrap();
        let pq = PackedQTensorV2::pack(&qt).unwrap();
        let cq = crate::device::CsdQuality {
            fmt: crate::hw::fixedpoint::Format::Q16_14,
            max_digits: 2,
        };
        let pc = PackedCsdTensor::pack(&w, &wshape, cq).unwrap();
        let nx: usize = xs.iter().product();
        let dims = (xs[0], xs[1], xs[2], xs[3]);
        let pool = Pool::global();
        for same in [false, true] {
            let xd: Vec<f32> = (0..nx).map(|_| r.range_i64(-8, 8) as f32).collect();
            let xq: Vec<i16> = xd.iter().map(|&v| v as i16).collect();
            let mut sf = Scratch::new();
            let mut si = Scratch::new();
            let (mut of, mut oi) = (Vec::new(), Vec::new());
            let shp = qconv_into(pool, &xd, dims, &pq, same, &mut sf, &mut of).unwrap();
            let shpi = qconv_i16_into(pool, &xq, dims, &pq, 1.0, same, &mut si, &mut oi).unwrap();
            assert_eq!(shp, shpi);
            let n = dims.0 * shp.0 * shp.1 * shp.2;
            assert_eq!(&oi[..n], &of[..n], "qconv same={same} diverged");
            let (mut cf, mut ci) = (Vec::new(), Vec::new());
            let xt: Vec<f32> = (0..nx).map(|_| r.range_i64(-1, 1) as f32).collect();
            let xtq: Vec<i16> = xt.iter().map(|&v| v as i16).collect();
            let shp = csd_conv_into(pool, &xt, dims, &pc, same, &mut sf, &mut cf).unwrap();
            let shpi =
                csd_conv_i16_into(pool, &xtq, dims, &pc, 1.0, same, &mut si, &mut ci).unwrap();
            assert_eq!(shp, shpi);
            let n = dims.0 * shp.0 * shp.1 * shp.2;
            assert_eq!(&ci[..n], &cf[..n], "csd_conv same={same} diverged");
        }
    }

    #[test]
    fn i16_conv_scratch_freezes_and_scalar_oracle_is_bitwise() {
        let mut r = Rng::new(27);
        let wshape = vec![3usize, 3, 8, 4];
        let w = gauss(&mut r, 3 * 3 * 8 * 4, 0.3);
        let qt = quantize(&w, &wshape, 8, 4, AssignMode::SigmaSearch).unwrap();
        let p = PackedQTensorV2::pack(&qt).unwrap();
        let dims = (2usize, 8usize, 8usize, 8usize);
        let xq: Vec<i16> =
            (0..2 * 8 * 8 * 8).map(|_| r.range_i64(-32768, 32767) as i16).collect();
        let dq = 1.0f32 / 1024.0;
        let pool = Pool::global();
        let mut scratch = Scratch::new();
        let mut out = Vec::new();
        qconv_i16_into(pool, &xq, dims, &p, dq, true, &mut scratch, &mut out).unwrap();
        let cold_allocs = scratch.stats.allocs;
        assert!(cold_allocs > 0);
        for _ in 0..3 {
            qconv_i16_into(pool, &xq, dims, &p, dq, true, &mut scratch, &mut out).unwrap();
        }
        assert_eq!(scratch.stats.allocs, cold_allocs, "warm i16 passes must not allocate");
        // SWAR gather vs scalar gather: integer sums, bitwise equal
        let mut sout = Vec::new();
        qconv_i16_scalar_into(pool, &xq, dims, &p, dq, true, &mut scratch, &mut sout).unwrap();
        assert_eq!(out, sout, "i16 lane vs scalar conv diverged");
    }

    #[test]
    fn rejects_bad_shapes() {
        let mut r = Rng::new(8);
        let w = gauss(&mut r, 3 * 3 * 4 * 2, 0.3);
        let qt = quantize(&w, &[3, 3, 4, 2], 4, 4, AssignMode::Nearest).unwrap();
        let p = PackedQTensorV2::pack(&qt).unwrap();
        let mut scratch = Scratch::new();
        // channel mismatch
        let x = Tensor::new(vec![1, 6, 6, 3], vec![0.0; 108]).unwrap();
        assert!(qconv(&x, &p, false, &mut scratch).is_err());
        // window larger than input
        let x = Tensor::new(vec![1, 2, 2, 4], vec![0.0; 16]).unwrap();
        assert!(qconv(&x, &p, false, &mut scratch).is_err());
    }
}
