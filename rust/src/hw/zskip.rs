//! Zero-skip statistics (paper Fig. 2's "red" zero data points): MACs whose
//! weight code is zero can be skipped entirely by the accelerator.

use crate::hw::energy::pj;
use crate::quant::codes::Code;

/// Skip statistics over a quantized weight tensor.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SkipStats {
    pub total: u64,
    pub skippable: u64,
}

impl SkipStats {
    pub fn fraction(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.skippable as f64 / self.total as f64
        }
    }

    /// Energy saved per activation row (one MAC per weight): skipped MACs
    /// avoid a fp32 multiply + add.
    pub fn saved_pj_per_row(&self) -> f64 {
        self.skippable as f64 * (pj::MUL_FP32 + pj::ADD_FP32)
    }
}

pub fn from_codes(codes: &[Code]) -> SkipStats {
    SkipStats {
        total: codes.len() as u64,
        skippable: codes.iter().filter(|c| c.is_skippable()).count() as u64,
    }
}

/// Zero fraction of raw f32 weights (|w| <= tol), for the "+6 % zeros" claim
/// comparison between original and quantized tensors.
pub fn raw_zero_fraction(ws: &[f32], tol: f32) -> f64 {
    if ws.is_empty() {
        return 0.0;
    }
    ws.iter().filter(|w| w.abs() <= tol).count() as f64 / ws.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_zero_codes() {
        let codes = vec![Code(0), Code(1), Code(7), Code(4)];
        let st = from_codes(&codes);
        assert_eq!(st.skippable, 2);
        assert_eq!(st.fraction(), 0.5);
        assert!(st.saved_pj_per_row() > 0.0);
    }

    #[test]
    fn raw_zeros() {
        assert_eq!(raw_zero_fraction(&[0.0, 1.0, -0.0005, 2.0], 1e-3), 0.5);
        assert_eq!(raw_zero_fraction(&[], 1e-3), 0.0);
    }
}
