//! The Quality Scalable Multiplier (paper §V.B): a shift-and-add multiplier
//! whose weight operand is CSD-recoded and truncated to at most `max_digits`
//! non-zero digits; partial-product rows beyond that are clock-gated.
//!
//! Bit-accurate in fixed point (the datapath), with per-multiply energy and
//! error statistics.  This is the per-scalar *oracle*; the serving hot path
//! carries the same value semantics in tensor form as
//! [`crate::kernels::csd`] (truncated-CSD digit planes, shift-and-add inner
//! loop), and the property tests pin the two against each other bit for bit
//! on lossless inputs.  [`super::csd::spt_approx`] is the float mirror of
//! the same truncation.

use super::csd;
use super::energy::pj;
use super::fixedpoint::{Fixed, Format};

/// Multiplier configuration: number format + quality knob.
#[derive(Clone, Copy, Debug)]
pub struct QsmConfig {
    pub fmt: Format,
    /// Max CSD partial products (the quality knob). usize::MAX = exact CSD.
    pub max_digits: usize,
}

impl QsmConfig {
    pub fn new(fmt: Format, max_digits: usize) -> QsmConfig {
        QsmConfig { fmt, max_digits }
    }
    /// Max partial-product rows the hardware provisions: CSD of a `total`-bit
    /// number has at most ceil((total+1)/2) non-zeros (non-adjacency).
    pub fn max_rows(&self) -> usize {
        (self.fmt.total as usize + 2) / 2
    }
}

/// Result of one simulated multiply.
#[derive(Clone, Copy, Debug)]
pub struct MulResult {
    /// Approximate product (datapath output), as f64.
    pub value: f64,
    /// Exact product of the *fixed-point* operands (same format, no CSD
    /// truncation) — isolates the CSD truncation error from quantization.
    pub exact_fixed: f64,
    /// Partial products actually summed.
    pub partial_products: usize,
    /// Rows clock-gated off.
    pub gated_rows: usize,
    /// Energy of this multiply (pJ): active partial products only — gate
    /// clocking means gated rows cost (approximately) nothing.
    pub energy_pj: f64,
}

/// Multiply activation `a` by weight `w` through the QSM datapath.
pub fn multiply(cfg: QsmConfig, a: f64, w: f64) -> MulResult {
    let af = Fixed::from_f64(a, cfg.fmt);
    let wf = Fixed::from_f64(w, cfg.fmt);

    let digits = csd::to_csd(wf.raw);
    let kept = csd::truncate_msd(&digits, cfg.max_digits);
    let pp = csd::nonzero_count(&kept);

    // shift-and-add: sum_{i: d_i != 0} d_i * (a << i), renormalized by frac
    let mut acc: i128 = 0;
    for (i, &d) in kept.iter().enumerate() {
        if d != 0 {
            acc += d as i128 * ((af.raw as i128) << i);
        }
    }
    let raw = (acc >> cfg.fmt.frac) as i64;
    let clamped = raw.clamp(cfg.fmt.min_raw(), cfg.fmt.max_raw());

    MulResult {
        value: Fixed { raw: clamped, fmt: cfg.fmt }.to_f64(),
        exact_fixed: af.mul(wf).to_f64(),
        partial_products: pp,
        gated_rows: cfg.max_rows().saturating_sub(pp),
        energy_pj: pp as f64 * pj::QSM_PARTIAL_PRODUCT,
    }
}

/// Aggregate statistics over a dot product / a whole layer.
#[derive(Clone, Debug, Default)]
pub struct QsmStats {
    pub multiplies: u64,
    pub partial_products: u64,
    pub gated_rows: u64,
    pub energy_pj: f64,
    pub max_abs_err: f64,
    pub sum_sq_err: f64,
}

impl QsmStats {
    pub fn mean_pp(&self) -> f64 {
        if self.multiplies == 0 {
            0.0
        } else {
            self.partial_products as f64 / self.multiplies as f64
        }
    }
    pub fn rms_err(&self) -> f64 {
        if self.multiplies == 0 {
            0.0
        } else {
            (self.sum_sq_err / self.multiplies as f64).sqrt()
        }
    }
}

/// Dot product through the QSM; returns (approx value, stats).
pub fn dot(cfg: QsmConfig, xs: &[f64], ws: &[f64]) -> (f64, QsmStats) {
    assert_eq!(xs.len(), ws.len());
    let mut acc = 0.0;
    let mut st = QsmStats::default();
    for (&x, &w) in xs.iter().zip(ws) {
        let r = multiply(cfg, x, w);
        acc += r.value;
        st.multiplies += 1;
        st.partial_products += r.partial_products as u64;
        st.gated_rows += r.gated_rows as u64;
        st.energy_pj += r.energy_pj;
        let err = (r.value - r.exact_fixed).abs();
        st.max_abs_err = st.max_abs_err.max(err);
        st.sum_sq_err += err * err;
    }
    (acc, st)
}

/// Histogram of CSD non-zero counts over a weight slice (Fig. 11).
pub fn csd_nonzero_histogram(ws: &[f32], fmt: Format) -> Vec<u64> {
    let mut hist = vec![0u64; (fmt.total as usize + 2) / 2 + 1];
    for &w in ws {
        let f = Fixed::from_f64(w as f64, fmt);
        let nz = csd::nonzero_count(&csd::to_csd(f.raw));
        let idx = nz.min(hist.len() - 1);
        hist[idx] += 1;
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, forall};

    const FMT: Format = Format::Q32_24;

    #[test]
    fn exact_when_digits_unlimited() {
        let cfg = QsmConfig::new(FMT, usize::MAX);
        for (a, w) in [(1.5, 0.75), (-2.0, 0.3), (0.1, -0.1), (3.0, 0.0)] {
            let r = multiply(cfg, a, w);
            assert!(
                (r.value - r.exact_fixed).abs() < 1e-9,
                "a={a} w={w}: {} vs {}",
                r.value,
                r.exact_fixed
            );
        }
    }

    #[test]
    fn power_of_two_weight_single_pp() {
        let cfg = QsmConfig::new(FMT, usize::MAX);
        let r = multiply(cfg, 1.2345, 0.5);
        assert_eq!(r.partial_products, 1);
        assert!((r.value - 1.2345 * 0.5).abs() < 1e-6);
    }

    #[test]
    fn zero_weight_zero_energy() {
        let cfg = QsmConfig::new(FMT, 4);
        let r = multiply(cfg, 5.0, 0.0);
        assert_eq!(r.partial_products, 0);
        assert_eq!(r.energy_pj, 0.0);
        assert_eq!(r.value, 0.0);
    }

    #[test]
    fn prop_error_monotone_in_digits() {
        forall(
            100,
            |r| (r.normal(), r.normal() * 0.5),
            |&(a, w)| {
                let mut last = f64::MAX;
                for k in 1..=6 {
                    let r = multiply(QsmConfig::new(FMT, k), a, w);
                    let err = (r.value - r.exact_fixed).abs();
                    check(err <= last + 1e-12, "error grew with more digits")?;
                    last = err;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_energy_monotone_in_digits() {
        forall(
            100,
            |r| (r.normal(), r.normal() * 0.5),
            |&(a, w)| {
                let mut last = 0.0f64;
                for k in 1..=6 {
                    let r = multiply(QsmConfig::new(FMT, k), a, w);
                    check(r.energy_pj >= last - 1e-12, "energy not monotone")?;
                    last = r.energy_pj;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_pp_bounded_by_quality() {
        forall(
            200,
            |r| (r.normal(), r.normal(), r.below(6) as usize + 1),
            |&(a, w, k)| {
                let r = multiply(QsmConfig::new(FMT, k), a, w);
                check(r.partial_products <= k, "pp exceeds quality knob")
            },
        );
    }

    #[test]
    fn dot_accumulates() {
        let cfg = QsmConfig::new(FMT, usize::MAX);
        let xs = [1.0, 2.0, 3.0];
        let ws = [0.5, -0.5, 1.0];
        let (v, st) = dot(cfg, &xs, &ws);
        assert!((v - 2.5).abs() < 1e-6);
        assert_eq!(st.multiplies, 3);
        assert!(st.energy_pj > 0.0);
    }

    #[test]
    fn histogram_shape() {
        // Fig. 11's point: most trained-looking weights need few CSD digits
        let mut r = crate::util::rng::Rng::new(1);
        let ws: Vec<f32> = (0..5000).map(|_| (r.normal() * 0.05) as f32).collect();
        let hist = csd_nonzero_histogram(&ws, Format::Q16_14);
        let total: u64 = hist.iter().sum();
        let low: u64 = hist[..6].iter().sum();
        assert_eq!(total, 5000);
        assert!(low as f64 / total as f64 > 0.8, "hist {hist:?}");
    }
}
