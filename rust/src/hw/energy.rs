//! Energy model — the constants behind the paper's Figs. 1/2 and §IV.C.
//!
//! Per-operation energies are the 45 nm numbers of Horowitz (ISSCC'14) as
//! popularized by Han et al. / Yang et al. (the paper's reference [8]).  The
//! paper itself states 6400 pJ for a 32-bit DRAM transfer (§IV.C); the
//! Horowitz figure is 640 pJ.  Both are kept: `DRAM_32` (Horowitz) drives the
//! Fig.-2 breakdown, `PAPER_DRAM_32` reproduces the paper's §IV.C/Fig.-10
//! arithmetic exactly.

/// pJ per operation (45 nm).
pub mod pj {
    pub const ADD_INT8: f64 = 0.03;
    pub const ADD_INT32: f64 = 0.1;
    pub const ADD_FP16: f64 = 0.4;
    pub const ADD_FP32: f64 = 0.9;
    pub const MUL_INT8: f64 = 0.2;
    pub const MUL_INT32: f64 = 3.1;
    pub const MUL_FP16: f64 = 1.1;
    pub const MUL_FP32: f64 = 3.7;
    /// 8 KB SRAM read, 32 bits.
    pub const SRAM_32: f64 = 5.0;
    /// DRAM read, 32 bits (Horowitz).
    pub const DRAM_32: f64 = 640.0;
    /// DRAM read, 32 bits, as stated by the paper (§IV.C).
    pub const PAPER_DRAM_32: f64 = 6400.0;
    /// One shift-and-add partial product in the QSM (shift is wiring; the
    /// add is an int32 add plus registering overhead).
    pub const QSM_PARTIAL_PRODUCT: f64 = 0.15;
    /// Decoder ops: exponent add / sign flip are sub-pJ register ops.
    pub const DECODER_OP: f64 = 0.02;
}

/// Fig.-1 rows: (label, pJ) for the energy-per-operation chart.
pub fn fig1_rows() -> Vec<(&'static str, f64)> {
    vec![
        ("8b int ADD", pj::ADD_INT8),
        ("32b int ADD", pj::ADD_INT32),
        ("16b fp ADD", pj::ADD_FP16),
        ("32b fp ADD", pj::ADD_FP32),
        ("8b int MULT", pj::MUL_INT8),
        ("32b int MULT", pj::MUL_INT32),
        ("16b fp MULT", pj::MUL_FP16),
        ("32b fp MULT", pj::MUL_FP32),
        ("32b SRAM read", pj::SRAM_32),
        ("32b DRAM read", pj::DRAM_32),
    ]
}

/// Mutable ledger accumulated while simulating an inference or a transfer.
#[derive(Clone, Debug, Default)]
pub struct Ledger {
    pub dram_bits: u64,
    pub sram_bits: u64,
    pub fp_adds: u64,
    pub fp_muls: u64,
    pub int_adds: u64,
    pub partial_products: u64,
    /// QSM partial-product rows clock-gated off by the digit budget —
    /// tracked for the gating ratio, charged (approximately) nothing in
    /// [`Ledger::compute_pj`].
    pub gated_rows: u64,
    pub decoder_ops: u64,
    pub skipped_macs: u64,
    /// Activation bit-width gauge: 0 while every forward ran the f32
    /// activation path, 16 once a calibrated integer (i16) forward ran.
    /// A *gauge*, not a counter — [`Ledger::add`] max-merges it and
    /// [`Ledger::compute_pj`] does not price it (the integer datapath's
    /// cost shows up as `int_adds`/`fp_muls` instead).
    pub act_bits: u64,
}

impl Ledger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn dram_pj(&self) -> f64 {
        self.dram_bits as f64 / 32.0 * pj::DRAM_32
    }
    pub fn sram_pj(&self) -> f64 {
        self.sram_bits as f64 / 32.0 * pj::SRAM_32
    }
    pub fn compute_pj(&self) -> f64 {
        self.fp_adds as f64 * pj::ADD_FP32
            + self.fp_muls as f64 * pj::MUL_FP32
            + self.int_adds as f64 * pj::ADD_INT32
            + self.partial_products as f64 * pj::QSM_PARTIAL_PRODUCT
            + self.decoder_ops as f64 * pj::DECODER_OP
    }
    pub fn total_pj(&self) -> f64 {
        self.dram_pj() + self.sram_pj() + self.compute_pj()
    }

    pub fn add(&mut self, other: &Ledger) {
        self.dram_bits += other.dram_bits;
        self.sram_bits += other.sram_bits;
        self.fp_adds += other.fp_adds;
        self.fp_muls += other.fp_muls;
        self.int_adds += other.int_adds;
        self.partial_products += other.partial_products;
        self.gated_rows += other.gated_rows;
        self.decoder_ops += other.decoder_ops;
        self.skipped_macs += other.skipped_macs;
        // gauge, not counter: the merged ledger ran at the widest
        // activation width either side ever used
        self.act_bits = self.act_bits.max(other.act_bits);
    }
}

/// Energy to move `bits` over the DRAM interface (paper §IV.C arithmetic).
pub fn transfer_pj(bits: u64, paper_constant: bool) -> f64 {
    let per32 = if paper_constant { pj::PAPER_DRAM_32 } else { pj::DRAM_32 };
    bits as f64 / 32.0 * per32
}

/// The paper's "energy efficiency" metric for Fig. 10: the *savings* of
/// moving the encoded model instead of the full-precision one.
pub fn energy_efficiency(full_bits: u64, encoded_bits: u64) -> f64 {
    if full_bits == 0 {
        return 0.0;
    }
    1.0 - encoded_bits as f64 / full_bits as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_ordering() {
        // DRAM must dominate everything else by >2 orders of magnitude
        let rows = fig1_rows();
        let dram = rows.iter().find(|r| r.0.contains("DRAM")).unwrap().1;
        for (label, e) in &rows {
            if !label.contains("DRAM") {
                assert!(dram / e > 100.0, "{label}");
            }
        }
    }

    #[test]
    fn ledger_totals() {
        let mut l = Ledger::new();
        l.dram_bits = 64;
        l.fp_muls = 10;
        l.fp_adds = 10;
        l.gated_rows = 7;
        assert!((l.dram_pj() - 2.0 * pj::DRAM_32).abs() < 1e-9);
        // gated rows are tracked but cost nothing
        assert!((l.compute_pj() - (10.0 * pj::MUL_FP32 + 10.0 * pj::ADD_FP32)).abs() < 1e-9);
        let mut l2 = Ledger::new();
        l2.add(&l);
        assert_eq!(l2.total_pj(), l.total_pj());
        assert_eq!(l2.gated_rows, 7);
    }

    #[test]
    fn act_bits_is_a_max_merged_unpriced_gauge() {
        let mut l = Ledger::new();
        l.act_bits = 16;
        let before = l.total_pj();
        let mut wide = Ledger::new();
        wide.act_bits = 32;
        l.add(&wide);
        assert_eq!(l.act_bits, 32, "merge keeps the widest width");
        let mut narrow = Ledger::new();
        narrow.act_bits = 16;
        l.add(&narrow);
        assert_eq!(l.act_bits, 32, "a narrower forward cannot lower the gauge");
        assert_eq!(l.total_pj(), before, "act_bits is never priced");
    }

    #[test]
    fn transfer_uses_paper_constant() {
        assert_eq!(transfer_pj(32, true), pj::PAPER_DRAM_32);
        assert_eq!(transfer_pj(32, false), pj::DRAM_32);
        assert_eq!(transfer_pj(64, false), 2.0 * pj::DRAM_32);
    }

    #[test]
    fn efficiency_metric() {
        // 3-bit codes + 1 scalar per 16 weights vs 32-bit weights
        let full = 1600 * 32u64;
        let enc = 1600 * 3 + 100 * 32u64;
        let eff = energy_efficiency(full, enc);
        assert!(eff > 0.8 && eff < 0.95, "{eff}");
        assert_eq!(energy_efficiency(0, 10), 0.0);
    }
}
