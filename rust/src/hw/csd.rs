//! Canonic Signed Digit (CSD / non-adjacent form) arithmetic — the number
//! representation behind the paper's Quality Scalable Multiplier (§V.B).
//!
//! CSD re-codes an integer with digits in {-1, 0, +1} such that no two
//! adjacent digits are non-zero; it is the minimal-weight signed-digit form,
//! so a shift-and-add multiplier needs one partial product per non-zero
//! digit.  The QSM truncates least-significant non-zero digits to trade
//! accuracy for partial products (energy).

/// CSD digits, least-significant first, each in {-1, 0, +1}.
pub type Digits = Vec<i8>;

/// Non-adjacent-form encoding of `n`.
pub fn to_csd(mut n: i64) -> Digits {
    let mut out = Vec::new();
    while n != 0 {
        if n & 1 != 0 {
            // d = 2 - (n mod 4) in {-1, +1}
            let d = 2 - (n.rem_euclid(4)) as i8;
            out.push(d);
            n -= d as i64;
        } else {
            out.push(0);
        }
        n /= 2;
    }
    out
}

/// Value of a digit string.
pub fn from_csd(d: &[i8]) -> i64 {
    d.iter()
        .enumerate()
        .map(|(i, &di)| di as i64 * (1i64 << i))
        .sum()
}

/// Number of non-zero digits (= partial products of a CSD multiplier).
pub fn nonzero_count(d: &[i8]) -> usize {
    d.iter().filter(|&&x| x != 0).count()
}

/// NAF property: no two adjacent non-zeros.
pub fn is_canonic(d: &[i8]) -> bool {
    d.windows(2).all(|w| w[0] == 0 || w[1] == 0)
}

/// Keep only the `k` most-significant non-zero digits (the QSM quality knob:
/// everything below is clock-gated away).
pub fn truncate_msd(d: &[i8], k: usize) -> Digits {
    let mut out = d.to_vec();
    let mut kept = 0;
    for i in (0..out.len()).rev() {
        if out[i] != 0 {
            if kept < k {
                kept += 1;
            } else {
                out[i] = 0;
            }
        }
    }
    out
}

/// Value-level k-term signed-power-of-two approximation of an f64 — the
/// float mirror of `truncate_msd` (greedy nearest power of two, MSD first).
/// The tensor-path form of the same truncation is
/// [`crate::kernels::csd::PackedCsdTensor`].
pub fn spt_approx(w: f64, digits: usize) -> f64 {
    let mut out = 0.0;
    let mut r = w;
    for _ in 0..digits {
        let mag = r.abs();
        if mag <= 1e-30 {
            break;
        }
        // nearest power of two: 2^floor(log2(4/3 * |r|))
        let e = (mag * (4.0 / 3.0)).log2().floor();
        let term = r.signum() * e.exp2();
        out += term;
        r -= term;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, forall};

    #[test]
    fn known_encodings() {
        // 7 = 8 - 1
        assert_eq!(from_csd(&to_csd(7)), 7);
        assert_eq!(nonzero_count(&to_csd(7)), 2);
        // 15 = 16 - 1
        assert_eq!(nonzero_count(&to_csd(15)), 2);
        // powers of two use one digit
        assert_eq!(nonzero_count(&to_csd(64)), 1);
        assert_eq!(to_csd(0), Vec::<i8>::new());
    }

    #[test]
    fn prop_roundtrip_and_canonic() {
        forall(
            300,
            |r| r.range_i64(-(1 << 40), 1 << 40),
            |&n| {
                let d = to_csd(n);
                check(from_csd(&d) == n, "roundtrip")?;
                check(is_canonic(&d), "adjacent non-zeros")?;
                check(d.iter().all(|&x| (-1..=1).contains(&x)), "digit range")
            },
        );
    }

    #[test]
    fn prop_csd_weight_no_worse_than_binary() {
        // CSD is the minimal-weight signed representation: non-zero count
        // never exceeds the binary popcount.
        forall(
            300,
            |r| r.range_i64(0, 1 << 40),
            |&n| {
                let d = to_csd(n);
                check(
                    nonzero_count(&d) <= (n as u64).count_ones() as usize,
                    "csd heavier than binary",
                )
            },
        );
    }

    #[test]
    fn prop_truncation_error_bounded() {
        // dropped digits are all strictly below the last kept one; with NAF
        // non-adjacency their sum is < 2/3 * 2^(e_kept_min) * 2 — bound by
        // the weight of the smallest kept digit.
        forall(
            200,
            |r| (r.range_i64(1, 1 << 30), r.below(4) as usize + 1),
            |&(n, k)| {
                let d = to_csd(n);
                let t = truncate_msd(&d, k);
                if nonzero_count(&d) <= k {
                    return check(from_csd(&t) == n, "truncation changed exact value");
                }
                let kept_min = t
                    .iter()
                    .enumerate()
                    .filter(|(_, &x)| x != 0)
                    .map(|(i, _)| i)
                    .min()
                    .unwrap();
                let err = (n - from_csd(&t)).abs();
                check(err < (1i64 << kept_min), "truncation error too large")
            },
        );
    }

    #[test]
    fn prop_truncation_monotone() {
        forall(
            200,
            |r| r.range_i64(1, 1 << 30),
            |&n| {
                let d = to_csd(n);
                let mut last = i64::MAX;
                for k in 1..=6 {
                    let err = (n - from_csd(&truncate_msd(&d, k))).abs();
                    check(err <= last, "error grew with more digits")?;
                    last = err;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn spt_matches_integer_csd_on_powers() {
        for v in [1.0f64, -2.0, 0.5, 4.0, -0.25] {
            assert_eq!(spt_approx(v, 1), v);
        }
    }

    #[test]
    fn prop_spt_error_shrinks() {
        forall(
            200,
            |r| r.normal() * 3.0,
            |&w| {
                let mut last = f64::MAX;
                for k in 1..=8 {
                    let err = (spt_approx(w, k) - w).abs();
                    check(err <= last + 1e-12, "spt error grew")?;
                    last = err;
                }
                // k-term greedy SPT error halves at least geometrically (1/3 ratio
                // per term is the theoretical bound; we check a loose 2^-k).
                check(last <= w.abs() / 256.0 + 1e-9, "8-term error too large")
            },
        );
    }

    #[test]
    fn spt_and_csd_truncation_agree_on_error_scale() {
        // both are k-term SPT approximations; their error magnitudes should
        // be within the weight of the smallest kept term of each other
        for n in [7i64, 11, 100, 1000, 12345] {
            for k in 1..=3usize {
                let csd_err = (n - from_csd(&truncate_msd(&to_csd(n), k))).abs() as f64;
                let spt_err = (n as f64 - spt_approx(n as f64, k)).abs();
                let scale = (n as f64) / (1 << k) as f64 + 1.0;
                assert!(
                    (csd_err - spt_err).abs() <= scale,
                    "n={n} k={k}: csd {csd_err} vs spt {spt_err}"
                );
            }
        }
    }
}
