//! Signed fixed-point Q(m.n) values on an i64 carrier — the number format of
//! the multiplier datapath simulator.

use anyhow::{bail, Result};

/// Fixed-point format: `frac` fractional bits, `total` total bits (incl sign).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Format {
    pub total: u32,
    pub frac: u32,
}

impl Format {
    pub const Q16_14: Format = Format { total: 16, frac: 14 };
    pub const Q32_24: Format = Format { total: 32, frac: 24 };

    pub fn new(total: u32, frac: u32) -> Result<Format> {
        if total == 0 || total > 63 || frac >= total {
            bail!("bad fixed-point format Q{total}.{frac}");
        }
        Ok(Format { total, frac })
    }

    pub fn scale(&self) -> f64 {
        (1u64 << self.frac) as f64
    }

    pub fn max_raw(&self) -> i64 {
        (1i64 << (self.total - 1)) - 1
    }

    pub fn min_raw(&self) -> i64 {
        -(1i64 << (self.total - 1))
    }
}

/// A fixed-point value: raw integer + format.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fixed {
    pub raw: i64,
    pub fmt: Format,
}

impl Fixed {
    /// Quantize an f64 with round-to-nearest and saturation.
    pub fn from_f64(v: f64, fmt: Format) -> Fixed {
        let scaled = (v * fmt.scale()).round() as i64;
        Fixed { raw: scaled.clamp(fmt.min_raw(), fmt.max_raw()), fmt }
    }

    pub fn to_f64(self) -> f64 {
        self.raw as f64 / self.fmt.scale()
    }

    /// Quantization step of the format.
    pub fn epsilon(fmt: Format) -> f64 {
        1.0 / fmt.scale()
    }

    /// Exact product (raw i128 intermediate) renormalized to `fmt`.
    pub fn mul(self, other: Fixed) -> Fixed {
        assert_eq!(self.fmt, other.fmt);
        let prod = self.raw as i128 * other.raw as i128;
        let shifted = (prod >> self.fmt.frac) as i64;
        Fixed { raw: shifted.clamp(self.fmt.min_raw(), self.fmt.max_raw()), fmt: self.fmt }
    }

    pub fn saturated(self) -> bool {
        self.raw == self.fmt.max_raw() || self.raw == self.fmt.min_raw()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_within_epsilon() {
        let fmt = Format::Q16_14;
        for v in [-1.5f64, -0.333, 0.0, 0.125, 1.9] {
            let f = Fixed::from_f64(v, fmt);
            assert!((f.to_f64() - v).abs() <= Fixed::epsilon(fmt) / 2.0 + 1e-12, "{v}");
        }
    }

    #[test]
    fn saturates() {
        let fmt = Format::Q16_14;
        let f = Fixed::from_f64(100.0, fmt);
        assert!(f.saturated());
        assert!((f.to_f64() - 2.0).abs() < 0.01); // Q16.14 max ~ 1.99994
    }

    #[test]
    fn multiply() {
        let fmt = Format::Q32_24;
        let a = Fixed::from_f64(1.5, fmt);
        let b = Fixed::from_f64(-0.5, fmt);
        assert!((a.mul(b).to_f64() + 0.75).abs() < 1e-6);
    }

    #[test]
    fn bad_formats_rejected() {
        assert!(Format::new(0, 0).is_err());
        assert!(Format::new(16, 16).is_err());
        assert!(Format::new(64, 10).is_err());
    }

    #[test]
    fn power_of_two_exact() {
        let fmt = Format::Q16_14;
        assert_eq!(Fixed::from_f64(0.5, fmt).to_f64(), 0.5);
        assert_eq!(Fixed::from_f64(-0.25, fmt).to_f64(), -0.25);
    }
}
