//! Bit-accurate hardware simulators for the paper's micro-architecture story
//! (§V): the shift-and-scale decoder (Table II), the CSD quality-scalable
//! multiplier with gate clocking, fixed-point arithmetic, the energy model
//! (Figs. 1/2), and zero-skip statistics.
//!
//! These run on the L3 side; the TPU-shaped value models live in the Pallas
//! kernels (DESIGN.md §Hardware-Adaptation).  Tests pin the two against each
//! other.

pub mod csd;
pub mod decoder_rtl;
pub mod energy;
pub mod fixedpoint;
pub mod multiplier;
pub mod zskip;
