//! Bit-accurate hardware simulators for the paper's micro-architecture story
//! (§V): the shift-and-scale decoder (Table II), the CSD quality-scalable
//! multiplier with gate clocking, fixed-point arithmetic, the energy model
//! (Figs. 1/2), and zero-skip statistics.
//!
//! These are the per-scalar oracles.  The QSM's tensor-path twin lives on
//! the serving hot path as [`crate::kernels::csd`] (truncated-CSD digit
//! planes over the worker pool); tests pin kernel and simulator against
//! each other bit for bit, and the serving engine accumulates
//! [`energy::Ledger`]s that price each request in these models' pJ
//! constants.

pub mod csd;
pub mod decoder_rtl;
pub mod energy;
pub mod fixedpoint;
pub mod multiplier;
pub mod zskip;
