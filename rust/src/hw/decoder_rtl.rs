//! Bit-level simulator of the on-chip shift-and-scale decoder (paper §III,
//! Table II).
//!
//! The decoder receives a 3-bit code and the group's full-precision scalar
//! and recovers the approximate weight using only:
//!   * sign inversion  — XOR of the f32 sign bit,
//!   * "shifts"        — on a float datapath, ±1/±2 in the exponent field
//!     (a power-of-two scale *is* an exponent add — no multiplier needed).
//!
//! This is the float-datapath realization of Table II; saturation at the
//! exponent-field boundaries (overflow → ±inf clamp, underflow → 0) is
//! modelled the way a hardware implementation would clamp.

use crate::quant::codes::Code;

/// Operation counts for energy accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DecodeOps {
    pub exponent_adds: u32,
    pub sign_flips: u32,
    pub zero_outputs: u32,
}

/// Decode one (code, scalar) pair on the bit level.
pub fn decode_bits(code: Code, alpha_bits: u32) -> (u32, DecodeOps) {
    let mut ops = DecodeOps::default();
    if code.is_skippable() {
        ops.zero_outputs = 1;
        return (0, ops); // +0.0
    }

    let sign = alpha_bits & 0x8000_0000;
    let exp = (alpha_bits >> 23) & 0xFF;
    let frac = alpha_bits & 0x007F_FFFF;

    // zero / denormal scalar: decoder outputs zero (denormals flushed)
    if exp == 0 {
        ops.zero_outputs = 1;
        return (sign, ops);
    }
    // NaN / inf scalar propagates unchanged magnitude-wise
    let mut new_exp = exp;
    let shifts = code.shifts();
    if shifts > 0 && exp != 0xFF {
        ops.exponent_adds = 1; // one adder pass regardless of shift amount
        let e = exp + shifts;
        new_exp = if e >= 0xFF { 0xFE } else { e }; // saturate below inf
    }
    let mut out_sign = sign;
    if code.inverts() {
        ops.sign_flips = 1;
        out_sign ^= 0x8000_0000;
    }
    ((out_sign) | (new_exp << 23) | frac, ops)
}

/// Decode to f32 (convenience wrapper used by tests and the codec).
pub fn decode_f32(code: Code, alpha: f32) -> (f32, DecodeOps) {
    let (bits, ops) = decode_bits(code, alpha.to_bits());
    (f32::from_bits(bits), ops)
}

/// Decode a whole code/scalar stream in the `[K, OC]` matmul layout
/// (codes row-major `[K, OC]`, scalars `[K/group, OC]`); returns weights +
/// total op counts.
///
/// §Perf: per-scalar-row 8-entry decode LUT — the bit-level datapath runs
/// once per (scalar, code) pair instead of once per weight (8/group of the
/// naive cost), and the inner loop becomes a table lookup.  Op counts come
/// from a code histogram (ops are a pure function of the code for normal
/// scalars).  Before/after in EXPERIMENTS.md §Perf.
pub fn decode_stream(
    codes: &[Code],
    scalars: &[f32],
    group: usize,
    oc: usize,
) -> (Vec<f32>, DecodeOps) {
    assert!(oc > 0 && group > 0 && codes.len() % oc == 0);
    let k = codes.len() / oc;
    assert!(k % group == 0 && scalars.len() == (k / group) * oc);
    let g = k / group;

    let mut out = vec![0.0f32; codes.len()];
    // per-group-row decode LUTs: value + op-bitfield (bit0=exp-add,
    // bit1=sign-flip, bit2=zero-output), one entry per (column, code)
    let mut lut = vec![0.0f32; oc * 8];
    let mut ops_lut = vec![0u8; oc * 8];
    let (mut ea, mut sf, mut zo) = (0u64, 0u64, 0u64);
    for gi in 0..g {
        let srow = &scalars[gi * oc..(gi + 1) * oc];
        for (j, &alpha) in srow.iter().enumerate() {
            for c in 0..8u8 {
                let (v, ops) = decode_f32(Code(c), alpha);
                lut[j * 8 + c as usize] = v;
                ops_lut[j * 8 + c as usize] = (ops.exponent_adds as u8)
                    | ((ops.sign_flips as u8) << 1)
                    | ((ops.zero_outputs as u8) << 2);
            }
        }
        for i in 0..group {
            let ki = gi * group + i;
            let crow = &codes[ki * oc..(ki + 1) * oc];
            let orow = &mut out[ki * oc..(ki + 1) * oc];
            for (j, (&c, o)) in crow.iter().zip(orow.iter_mut()).enumerate() {
                let idx = j * 8 + (c.0 & 7) as usize;
                *o = lut[idx];
                let ops = ops_lut[idx];
                ea += (ops & 1) as u64;
                sf += ((ops >> 1) & 1) as u64;
                zo += ((ops >> 2) & 1) as u64;
            }
        }
    }
    let total = DecodeOps {
        exponent_adds: ea as u32,
        sign_flips: sf as u32,
        zero_outputs: zo as u32,
    };
    (out, total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, forall};

    #[test]
    fn matches_arithmetic_decode() {
        // bit-level decode == multiplier*alpha for normal-range scalars
        // (skippable codes output hard +0.0; arithmetic may give -0.0)
        for c in 0..8u8 {
            let code = Code(c);
            for alpha in [0.5f32, 1.0, -0.75, 3.25e-3, 1.7e8] {
                let (got, _) = decode_f32(code, alpha);
                let want = code.decode(alpha);
                if code.is_skippable() {
                    assert_eq!(got, 0.0, "code={c} alpha={alpha}");
                } else {
                    assert_eq!(got.to_bits(), want.to_bits(), "code={c} alpha={alpha}");
                }
            }
        }
    }

    #[test]
    fn op_counts_match_table2() {
        let (_, ops) = decode_f32(Code(0), 1.0);
        assert_eq!(ops, DecodeOps { exponent_adds: 0, sign_flips: 0, zero_outputs: 1 });
        let (_, ops) = decode_f32(Code(1), 1.0);
        assert_eq!(ops, DecodeOps::default());
        let (_, ops) = decode_f32(Code(3), 1.0);
        assert_eq!(ops.exponent_adds, 1);
        let (_, ops) = decode_f32(Code(6), 1.0);
        assert_eq!((ops.exponent_adds, ops.sign_flips), (1, 1));
    }

    #[test]
    fn saturates_near_overflow() {
        let huge = f32::MAX; // exponent 0xFE
        let (v, _) = decode_f32(Code(3), huge); // x4 would overflow
        assert!(v.is_finite());
        assert!(v >= huge);
    }

    #[test]
    fn zero_scalar_decodes_zero() {
        let (v, ops) = decode_f32(Code(2), 0.0);
        assert_eq!(v, 0.0);
        assert_eq!(ops.zero_outputs, 1);
    }

    #[test]
    fn prop_bitlevel_equals_float_decode() {
        forall(
            300,
            |r| (Code(r.below(8) as u8), (r.normal() * 0.3) as f32),
            |&(code, alpha)| {
                if alpha == 0.0 || !alpha.is_normal() {
                    return Ok(());
                }
                let (got, _) = decode_f32(code, alpha);
                let want = code.decode(alpha);
                if code.is_skippable() {
                    return check(got == 0.0, "skippable code not zero");
                }
                // stay clear of overflow/underflow saturation
                if want.is_normal() {
                    check(got.to_bits() == want.to_bits(), "bit mismatch")
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn stream_counts_accumulate() {
        let codes = vec![Code(0), Code(1), Code(5), Code(3)];
        let scalars = vec![1.0f32, 2.0];
        let (ws, ops) = decode_stream(&codes, &scalars, 2, 1);
        assert_eq!(ws, vec![0.0, 1.0, -4.0, 8.0]);
        assert_eq!(ops.zero_outputs, 1);
        assert_eq!(ops.sign_flips, 1);
        assert_eq!(ops.exponent_adds, 2);
    }

    #[test]
    fn stream_matches_quantizer_decode() {
        // decode_stream must reproduce QuantizedTensor::decode exactly for a
        // multi-column tensor (the layout bug class this test pins)
        use crate::quant::qsq::{quantize, AssignMode};
        use crate::util::prop::gen_weights;
        let mut r = crate::util::rng::Rng::new(3);
        let w = gen_weights(&mut r, 24 * 6, 0.2);
        let qt = quantize(&w, &[24, 6], 4, 4, AssignMode::SigmaSearch).unwrap();
        let (ws, _) = decode_stream(&qt.codes, &qt.scalars, qt.group, qt.oc);
        assert_eq!(ws, qt.decode());
    }
}
