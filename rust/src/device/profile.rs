//! Device classes with memory/compute/link budgets (Fig.-3-style spread) and
//! the quality-selection policies the router uses: the QSQ dial
//! ([`QualityConfig`]) and the CSD multiplier dial ([`CsdQuality`]).

use crate::channel::LinkConfig;
use crate::hw::fixedpoint::Format;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DeviceClass {
    /// MCU-class: tens of KB of SRAM for weights (think Cortex-M).
    McuTiny,
    /// Small FPGA / embedded Linux: ~1 MB budget.
    EdgeSmall,
    /// Larger edge SoC: ~16 MB budget.
    EdgeLarge,
    /// Workstation-class fallback (full precision is fine).
    Server,
}

/// Resource budget of one device.
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    pub name: String,
    pub class: DeviceClass,
    /// Bytes available for model storage.
    pub model_budget_bytes: u64,
    /// Sustained MACs per second (scales the latency model).
    pub macs_per_s: f64,
    /// Downlink characteristics for the model push.
    pub link: LinkConfig,
}

/// Quality configuration chosen for a device.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QualityConfig {
    /// phi in {1, 2, 4}; higher = more levels = better accuracy.
    pub phi: u32,
    /// Nominal vector length N (per-tensor resolved via nearest divisor).
    pub group: usize,
}

/// The second, orthogonal quality dial (paper §V.B): how many CSD
/// partial-product rows the Quality Scalable Multiplier keeps per weight.
/// Weights are fixed-point recoded in `fmt`, CSD-encoded, and truncated to
/// the `max_digits` most-significant non-zero digits; everything below is
/// clock-gated away.  `max_digits = usize::MAX` is exact CSD (the full
/// fixed-point product), `1` is a single signed power of two per weight.
///
/// This composes with [`QualityConfig`]: (phi, N) decides which codes cross
/// the channel, `CsdQuality` decides how many partial products the edge
/// multiplier spends on each surviving weight
/// ([`crate::kernels::csd`] / [`crate::runtime::host::CsdEngine`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CsdQuality {
    /// Fixed-point recoding format of the weight operand.
    pub fmt: Format,
    /// Max kept CSD digits (partial products) per weight.
    pub max_digits: usize,
}

impl CsdQuality {
    /// Default weight format: Q16.14 covers the (-2, 2) range every
    /// QSQ-decoded weight lives in, at 14 fractional bits.
    pub const DEFAULT_FMT: Format = Format::Q16_14;

    /// Dial at `max_digits` partial products in the default weight format.
    ///
    /// # Panics
    /// `max_digits` must be at least 1: a zero budget truncates *every*
    /// weight to zero and the engine would serve an all-zero model.  The
    /// kernels handle `max_digits = 0` harmlessly (everything gated), so a
    /// caller that really wants that degenerate dial can construct
    /// `CsdQuality { max_digits: 0, .. }` directly — but it is never a
    /// quality level worth selecting, so the constructor rejects it.
    pub fn new(max_digits: usize) -> CsdQuality {
        assert!(
            max_digits > 0,
            "CsdQuality::new(0) would gate every weight (an all-zero model); \
             use max_digits >= 1, or build the struct directly for the degenerate dial"
        );
        CsdQuality { fmt: Self::DEFAULT_FMT, max_digits }
    }

    /// Exact CSD: no truncation, bit-identical to the fixed-point product.
    pub fn exact() -> CsdQuality {
        Self::new(usize::MAX)
    }

    /// Partial-product rows the hardware provisions — delegates to
    /// [`crate::hw::multiplier::QsmConfig::max_rows`] (the NAF bound
    /// `ceil((total + 1) / 2)`), so kernel-side gating accounting can never
    /// drift from the per-scalar datapath simulator.
    pub fn max_rows(&self) -> usize {
        crate::hw::multiplier::QsmConfig::new(self.fmt, self.max_digits).max_rows()
    }
}

impl DeviceProfile {
    /// The Fig.-3-style roster of devices used across examples/benches.
    pub fn roster() -> Vec<DeviceProfile> {
        vec![
            DeviceProfile {
                name: "mcu-m4".into(),
                class: DeviceClass::McuTiny,
                model_budget_bytes: 48 * 1024,
                macs_per_s: 5e6,
                link: LinkConfig { bandwidth_bps: 250e3, latency_s: 0.08, ..Default::default() },
            },
            DeviceProfile {
                name: "edge-fpga-small".into(),
                class: DeviceClass::EdgeSmall,
                model_budget_bytes: 1 << 20,
                macs_per_s: 2e8,
                link: LinkConfig { bandwidth_bps: 5e6, latency_s: 0.03, ..Default::default() },
            },
            DeviceProfile {
                name: "edge-soc-large".into(),
                class: DeviceClass::EdgeLarge,
                model_budget_bytes: 16 << 20,
                macs_per_s: 5e9,
                link: LinkConfig { bandwidth_bps: 50e6, latency_s: 0.01, ..Default::default() },
            },
            DeviceProfile {
                name: "server".into(),
                class: DeviceClass::Server,
                model_budget_bytes: 1 << 30,
                macs_per_s: 1e11,
                link: LinkConfig { bandwidth_bps: 1e9, latency_s: 0.001, ..Default::default() },
            },
        ]
    }

    /// Joint quality selection over the three stacked dials (the full §V
    /// story): the *highest* QSQ quality whose encoded model fits the memory
    /// budget (`bits_at(phi, group)` estimates the encoded size), paired
    /// with the largest CSD digit budget the device's MACs-derived energy
    /// budget affords for a model costing `macs` MACs per inference
    /// ([`Self::select_csd_quality`]), plus the activation bit-width of the
    /// device class ([`Self::select_act_bits`]).  The search is separable
    /// because the dials price different resources — (phi, N) buys bytes on
    /// the device, `max_digits` buys partial-product rows per request,
    /// act-bits buys per-activation energy on the serving datapath — and the
    /// paper's methodology stacks them: the codes that fit cross the
    /// channel, the edge multiplier truncates their CSD form on top, and the
    /// activations between the layers run at the class's fixed-point width.
    /// A device profile alone therefore determines the full stacked-dial
    /// configuration.
    ///
    /// Returns `None` only when no (phi, N) fits the memory budget.
    pub fn select_quality(
        &self,
        bits_at: impl Fn(u32, usize) -> u64,
        macs: u64,
    ) -> Option<(QualityConfig, CsdQuality, u32)> {
        // quality-ordered candidates: high phi + small N (best accuracy)
        // down to low phi + large N (smallest model)
        let candidates = [
            (4u32, 8usize),
            (4, 16),
            (4, 32),
            (2, 16),
            (2, 32),
            (1, 16),
            (1, 32),
            (1, 64),
        ];
        for (phi, group) in candidates {
            if bits_at(phi, group) / 8 <= self.model_budget_bytes {
                return Some((
                    QualityConfig { phi, group },
                    self.select_csd_quality(macs),
                    self.select_act_bits(),
                ));
            }
        }
        None
    }

    /// The third quality dial: the activation bit-width the device serves
    /// at.  Every edge class runs the calibrated fixed-point datapath —
    /// activations quantized to i16 between layers
    /// ([`crate::kernels::ACT_TOTAL_BITS`]), plane sums as pure integer
    /// reductions — while the server class keeps f32 activations (reported
    /// as 32): it has the FLOPs to spare and stays the exact oracle the
    /// integer datapath is validated against.
    pub fn select_act_bits(&self) -> u32 {
        match self.class {
            DeviceClass::McuTiny | DeviceClass::EdgeSmall | DeviceClass::EdgeLarge => {
                crate::kernels::ACT_TOTAL_BITS
            }
            DeviceClass::Server => 32,
        }
    }

    /// Size the CSD digit dial from the device's energy/compute budget: the
    /// device sustains [`DeviceProfile::macs_per_s`] multiplier rows per
    /// second, and serving wants each inference inside
    /// [`ENERGY_LATENCY_TARGET_S`] — so it can afford
    /// `macs_per_s * target` shift-and-add rows per request.  Each MAC
    /// spends at most `max_digits` rows, so the largest affordable budget is
    /// `floor(affordable_rows / macs)`, clamped to at least 1 (the memory
    /// dial already decided the model fits; a device below the target just
    /// serves slower at the cheapest dial) and promoted to
    /// [`CsdQuality::exact`] once it reaches the NAF row bound (more digits
    /// than the multiplier provisions buy nothing).
    pub fn select_csd_quality(&self, macs: u64) -> CsdQuality {
        if macs == 0 {
            return CsdQuality::exact();
        }
        let affordable_rows = self.macs_per_s * ENERGY_LATENCY_TARGET_S;
        let digits = ((affordable_rows / macs as f64).floor() as usize).max(1);
        if digits >= CsdQuality::exact().max_rows() {
            CsdQuality::exact()
        } else {
            CsdQuality::new(digits)
        }
    }

    /// Crude per-inference latency model: MACs / throughput.
    pub fn inference_latency_s(&self, macs: u64) -> f64 {
        macs as f64 / self.macs_per_s
    }
}

/// Serving-rate target the energy dial is sized against: every profile
/// should sustain ~100 inferences/s (10 ms each) at its selected digit
/// budget.  This is what makes the budget *MACs-derived*: a device that can
/// afford more multiplier rows per 10 ms window gets more CSD digits per
/// weight, an MCU that cannot even afford one full row per MAC serves at
/// the 1-digit floor.
pub const ENERGY_LATENCY_TARGET_S: f64 = 0.01;

#[cfg(test)]
mod tests {
    use super::*;

    /// size model: codes at code_bits(phi) + one f32 per group of 16k weights
    fn bits(total_weights: u64) -> impl Fn(u32, usize) -> u64 {
        move |phi, group| {
            let cb = crate::quant::codes::code_bits(phi) as u64;
            total_weights * cb + total_weights / group as u64 * 32
        }
    }

    /// LeNet-scale per-inference MACs (the roster tests' energy workload).
    const LENET_MACS: u64 = 281_640;

    #[test]
    fn bigger_device_gets_better_quality() {
        let roster = DeviceProfile::roster();
        let weights = 10_000_000u64; // 10M-param model
        let q: Vec<Option<(QualityConfig, CsdQuality, u32)>> =
            roster.iter().map(|d| d.select_quality(bits(weights), LENET_MACS)).collect();
        // the MCU can't fit a 10M-weight model at any quality
        assert!(q[0].is_none());
        // larger devices pick phi=4
        assert_eq!(q[2].unwrap().0.phi, 4);
        assert_eq!(q[3].unwrap().0.phi, 4);
    }

    #[test]
    fn mcu_fits_small_model() {
        let mcu = &DeviceProfile::roster()[0];
        let (q, csd, act) = mcu.select_quality(bits(45_000), LENET_MACS).unwrap(); // LeNet-scale
        assert!(q.phi >= 1);
        assert!(csd.max_digits >= 1);
        assert_eq!(act, 16, "edge classes serve fixed-point activations");
    }

    #[test]
    fn joint_selection_scales_the_digit_budget_with_compute() {
        // the acceptance invariant: the MCU-class profile provably selects
        // a smaller digit budget than the server-class profile, with the
        // middle of the roster in between
        let roster = DeviceProfile::roster();
        let csd: Vec<CsdQuality> =
            roster.iter().map(|d| d.select_csd_quality(LENET_MACS)).collect();
        let mcu = csd[0].max_digits;
        let server = csd[3].max_digits;
        assert!(mcu < server, "mcu budget {mcu} must be below server budget {server}");
        // the MCU cannot afford even one row per MAC in the 10 ms window,
        // so it serves at the 1-digit floor; the server is unconstrained
        assert_eq!(mcu, 1);
        assert_eq!(csd[3], CsdQuality::exact());
        // budgets are monotone in device compute
        for w in csd.windows(2) {
            assert!(w[0].max_digits <= w[1].max_digits, "{csd:?} not monotone");
        }
        // the small-FPGA tier lands strictly between floor and exact:
        // 2e8 MACs/s * 10 ms = 2e6 rows / 281640 MACs = 7 digits
        assert_eq!(csd[1].max_digits, 7);
        // joint selection returns the same digit dial next to the QSQ dial
        let (_, joint, _) = roster[1].select_quality(bits(45_000), LENET_MACS).unwrap();
        assert_eq!(joint, csd[1]);
    }

    #[test]
    #[should_panic(expected = "all-zero model")]
    fn csd_quality_rejects_zero_digit_budget() {
        let _ = CsdQuality::new(0);
    }

    #[test]
    fn latency_scales_inverse_compute() {
        let roster = DeviceProfile::roster();
        let macs = 1_000_000;
        assert!(
            roster[0].inference_latency_s(macs) > 100.0 * roster[3].inference_latency_s(macs)
        );
    }

    #[test]
    fn csd_quality_rows_match_naf_bound() {
        assert_eq!(CsdQuality::exact().max_rows(), 9, "Q16.14: ceil(17/2)");
        assert_eq!(
            CsdQuality { fmt: Format::Q32_24, max_digits: 4 }.max_rows(),
            17,
            "Q32.24: ceil(33/2)"
        );
        assert_eq!(CsdQuality::new(3).max_digits, 3);
        assert_eq!(CsdQuality::new(1).fmt, CsdQuality::DEFAULT_FMT);
    }

    #[test]
    fn quality_order_prefers_accuracy() {
        // an unconstrained device must get the best quality on both dials
        let d = &DeviceProfile::roster()[3];
        let (q, csd, act) = d.select_quality(|_, _| 0, 1_000_000).unwrap();
        assert_eq!(q, QualityConfig { phi: 4, group: 8 });
        assert_eq!(csd, CsdQuality::exact());
        assert_eq!(act, 32, "the server class stays on f32 activations");
        // a zero-MAC model is degenerate: exact CSD, not a panic
        assert_eq!(d.select_csd_quality(0), CsdQuality::exact());
    }

    #[test]
    fn act_bits_dial_splits_edge_from_server() {
        let roster = DeviceProfile::roster();
        let bits: Vec<u32> = roster.iter().map(|d| d.select_act_bits()).collect();
        assert_eq!(bits, [16, 16, 16, 32], "every edge class is fixed-point, server is f32");
        // the edge width is the calibration module's carrier width — the
        // dial and the datapath can never disagree
        assert_eq!(bits[0], crate::kernels::ACT_TOTAL_BITS);
    }
}
