//! Device classes with memory/compute/link budgets (Fig.-3-style spread) and
//! the quality-selection policies the router uses: the QSQ dial
//! ([`QualityConfig`]) and the CSD multiplier dial ([`CsdQuality`]).

use crate::channel::LinkConfig;
use crate::hw::fixedpoint::Format;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DeviceClass {
    /// MCU-class: tens of KB of SRAM for weights (think Cortex-M).
    McuTiny,
    /// Small FPGA / embedded Linux: ~1 MB budget.
    EdgeSmall,
    /// Larger edge SoC: ~16 MB budget.
    EdgeLarge,
    /// Workstation-class fallback (full precision is fine).
    Server,
}

/// Resource budget of one device.
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    pub name: String,
    pub class: DeviceClass,
    /// Bytes available for model storage.
    pub model_budget_bytes: u64,
    /// Sustained MACs per second (scales the latency model).
    pub macs_per_s: f64,
    /// Downlink characteristics for the model push.
    pub link: LinkConfig,
}

/// Quality configuration chosen for a device.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QualityConfig {
    /// phi in {1, 2, 4}; higher = more levels = better accuracy.
    pub phi: u32,
    /// Nominal vector length N (per-tensor resolved via nearest divisor).
    pub group: usize,
}

/// The second, orthogonal quality dial (paper §V.B): how many CSD
/// partial-product rows the Quality Scalable Multiplier keeps per weight.
/// Weights are fixed-point recoded in `fmt`, CSD-encoded, and truncated to
/// the `max_digits` most-significant non-zero digits; everything below is
/// clock-gated away.  `max_digits = usize::MAX` is exact CSD (the full
/// fixed-point product), `1` is a single signed power of two per weight.
///
/// This composes with [`QualityConfig`]: (phi, N) decides which codes cross
/// the channel, `CsdQuality` decides how many partial products the edge
/// multiplier spends on each surviving weight
/// ([`crate::kernels::csd`] / [`crate::runtime::host::CsdEngine`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CsdQuality {
    /// Fixed-point recoding format of the weight operand.
    pub fmt: Format,
    /// Max kept CSD digits (partial products) per weight.
    pub max_digits: usize,
}

impl CsdQuality {
    /// Default weight format: Q16.14 covers the (-2, 2) range every
    /// QSQ-decoded weight lives in, at 14 fractional bits.
    pub const DEFAULT_FMT: Format = Format::Q16_14;

    /// Dial at `max_digits` partial products in the default weight format.
    pub fn new(max_digits: usize) -> CsdQuality {
        CsdQuality { fmt: Self::DEFAULT_FMT, max_digits }
    }

    /// Exact CSD: no truncation, bit-identical to the fixed-point product.
    pub fn exact() -> CsdQuality {
        Self::new(usize::MAX)
    }

    /// Partial-product rows the hardware provisions — delegates to
    /// [`crate::hw::multiplier::QsmConfig::max_rows`] (the NAF bound
    /// `ceil((total + 1) / 2)`), so kernel-side gating accounting can never
    /// drift from the per-scalar datapath simulator.
    pub fn max_rows(&self) -> usize {
        crate::hw::multiplier::QsmConfig::new(self.fmt, self.max_digits).max_rows()
    }
}

impl DeviceProfile {
    /// The Fig.-3-style roster of devices used across examples/benches.
    pub fn roster() -> Vec<DeviceProfile> {
        vec![
            DeviceProfile {
                name: "mcu-m4".into(),
                class: DeviceClass::McuTiny,
                model_budget_bytes: 48 * 1024,
                macs_per_s: 5e6,
                link: LinkConfig { bandwidth_bps: 250e3, latency_s: 0.08, ..Default::default() },
            },
            DeviceProfile {
                name: "edge-fpga-small".into(),
                class: DeviceClass::EdgeSmall,
                model_budget_bytes: 1 << 20,
                macs_per_s: 2e8,
                link: LinkConfig { bandwidth_bps: 5e6, latency_s: 0.03, ..Default::default() },
            },
            DeviceProfile {
                name: "edge-soc-large".into(),
                class: DeviceClass::EdgeLarge,
                model_budget_bytes: 16 << 20,
                macs_per_s: 5e9,
                link: LinkConfig { bandwidth_bps: 50e6, latency_s: 0.01, ..Default::default() },
            },
            DeviceProfile {
                name: "server".into(),
                class: DeviceClass::Server,
                model_budget_bytes: 1 << 30,
                macs_per_s: 1e11,
                link: LinkConfig { bandwidth_bps: 1e9, latency_s: 0.001, ..Default::default() },
            },
        ]
    }

    /// Pick the *highest* quality whose encoded model fits the budget.
    /// `bits_at(phi, group)` estimates the encoded model size.
    pub fn select_quality(
        &self,
        bits_at: impl Fn(u32, usize) -> u64,
    ) -> Option<QualityConfig> {
        // quality-ordered candidates: high phi + small N (best accuracy)
        // down to low phi + large N (smallest model)
        let candidates = [
            (4u32, 8usize),
            (4, 16),
            (4, 32),
            (2, 16),
            (2, 32),
            (1, 16),
            (1, 32),
            (1, 64),
        ];
        for (phi, group) in candidates {
            if bits_at(phi, group) / 8 <= self.model_budget_bytes {
                return Some(QualityConfig { phi, group });
            }
        }
        None
    }

    /// Crude per-inference latency model: MACs / throughput.
    pub fn inference_latency_s(&self, macs: u64) -> f64 {
        macs as f64 / self.macs_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// size model: codes at code_bits(phi) + one f32 per group of 16k weights
    fn bits(total_weights: u64) -> impl Fn(u32, usize) -> u64 {
        move |phi, group| {
            let cb = crate::quant::codes::code_bits(phi) as u64;
            total_weights * cb + total_weights / group as u64 * 32
        }
    }

    #[test]
    fn bigger_device_gets_better_quality() {
        let roster = DeviceProfile::roster();
        let weights = 10_000_000u64; // 10M-param model
        let q: Vec<Option<QualityConfig>> =
            roster.iter().map(|d| d.select_quality(bits(weights))).collect();
        // the MCU can't fit a 10M-weight model at any quality
        assert!(q[0].is_none());
        // larger devices pick phi=4
        assert_eq!(q[2].unwrap().phi, 4);
        assert_eq!(q[3].unwrap().phi, 4);
    }

    #[test]
    fn mcu_fits_small_model() {
        let mcu = &DeviceProfile::roster()[0];
        let q = mcu.select_quality(bits(45_000)).unwrap(); // LeNet-scale
        assert!(q.phi >= 1);
    }

    #[test]
    fn latency_scales_inverse_compute() {
        let roster = DeviceProfile::roster();
        let macs = 1_000_000;
        assert!(
            roster[0].inference_latency_s(macs) > 100.0 * roster[3].inference_latency_s(macs)
        );
    }

    #[test]
    fn csd_quality_rows_match_naf_bound() {
        assert_eq!(CsdQuality::exact().max_rows(), 9, "Q16.14: ceil(17/2)");
        assert_eq!(
            CsdQuality { fmt: Format::Q32_24, max_digits: 4 }.max_rows(),
            17,
            "Q32.24: ceil(33/2)"
        );
        assert_eq!(CsdQuality::new(3).max_digits, 3);
        assert_eq!(CsdQuality::new(1).fmt, CsdQuality::DEFAULT_FMT);
    }

    #[test]
    fn quality_order_prefers_accuracy() {
        // an unconstrained device must get the best quality (phi=4, N=8)
        let d = &DeviceProfile::roster()[3];
        let q = d.select_quality(|_, _| 0).unwrap();
        assert_eq!(q, QualityConfig { phi: 4, group: 8 });
    }
}
