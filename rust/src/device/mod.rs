//! Edge-device profiles and quality selection — the paper's Fig. 3 point:
//! edge hardware spans orders of magnitude in memory/compute, so the
//! deployment must pick a quality level (phi, N) per device.

pub mod profile;

pub use profile::{CsdQuality, DeviceClass, DeviceProfile, QualityConfig};
