//! qsq-edge CLI — leader entry point for the L3 coordinator.
//!
//! ```text
//! qsq-edge info                                  # artifacts + platform
//! qsq-edge eval   --model lenet [--phi 4 --n 16 --mode sigma-search]
//! qsq-edge encode --model lenet --phi 4 --n 16 --out model.qsq
//! qsq-edge decode --in model.qsq                 # container inspection
//! qsq-edge deploy-sim --model lenet --device edge-fpga-small [--ber 1e-5]
//! qsq-edge finetune --epochs 5 [--lr 0.05]
//! qsq-edge serve  --port 9000 [--model lenet --batch 32]
//! qsq-edge client --port 9000 --n 64             # synthetic load
//! qsq-edge repro  --exp table3 [--fast]          # paper tables/figures
//! qsq-edge repro  --exp all [--fast]
//! ```

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use qsq_edge::coordinator::{deploy, finetune, server};
use qsq_edge::data::RequestGen;
use qsq_edge::device::{CsdQuality, DeviceProfile, QualityConfig};
use qsq_edge::model::meta::ModelKind;
use qsq_edge::model::store::{artifacts_dir, Dataset, Manifest, WeightStore};
use qsq_edge::quant::qsq::AssignMode;
use qsq_edge::repro::{self, Ctx};
use qsq_edge::runtime::client::Runtime;
use qsq_edge::util::cli::Args;
use qsq_edge::util::log;

fn main() {
    log::level_from_env();
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts(args: &Args) -> PathBuf {
    args.get("artifacts").map(PathBuf::from).unwrap_or_else(artifacts_dir)
}

fn model_kind(args: &Args) -> Result<ModelKind> {
    ModelKind::from_name(&args.get_or("model", "lenet"))
}

fn mode(args: &Args) -> Result<AssignMode> {
    let name = args.get_or("mode", "sigma-search");
    AssignMode::from_name(&name).with_context(|| format!("unknown mode {name}"))
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "" | "help" => {
            println!("{}", HELP);
            Ok(())
        }
        "info" => cmd_info(args),
        "eval" => cmd_eval(args),
        "encode" => cmd_encode(args),
        "decode" => cmd_decode(args),
        "deploy-sim" => cmd_deploy_sim(args),
        "finetune" => cmd_finetune(args),
        "serve" => cmd_serve(args),
        "client" => cmd_client(args),
        "repro" => cmd_repro(args),
        other => bail!("unknown subcommand {other:?} (try `qsq-edge help`)"),
    }
}

const HELP: &str = "qsq-edge — Quality Scalable Quantization for deep learning on edge
subcommands:
  info          artifacts inventory + PJRT platform
  eval          accuracy of a model (optionally quantized: --phi --n --mode)
  encode        quantize + write a QSQ container  (--out model.qsq)
  decode        inspect a QSQ container           (--in model.qsq)
  deploy-sim    full encode→channel→decode pipeline vs a device profile
  finetune      on-device FC fine-tuning of the quantized LeNet
  serve         TCP inference server (multiplexed JSON lines, pipelined
                ids, out-of-order replies; GET /healthz, /metrics
                [Prometheus], /metrics.json on the same port;
                --engine auto|pjrt|host|host-quant|host-csd
                [--digits K: CSD partial products/weight, K >= 1; omit for exact]
                [--policy batch-fill|latency|energy: Auto batch dispatch]
                [--queue-cap N: admission cap, 0 = 4x batch]
                [--deadline-ms MS: shed jobs queued longer than this]
                [--workers N: replicated inference workers, 0 = all cores]
                [--synth: serve a synthetic store, no artifacts needed]
                [--serve-secs S: bounded run + clean shutdown, for CI])
  client        synthetic load against a server (--port, --n)
  repro         regenerate a paper table/figure   (--exp table3|fig7|...|all)
common flags: --artifacts DIR  --model lenet|convnet  --fast
chaos: PALLAS_FAULTS arms deterministic fault injection (see README)";

fn cmd_info(args: &Args) -> Result<()> {
    let dir = artifacts(args);
    let manifest = Manifest::load(&dir)?;
    let mut rt = Runtime::new(&dir)?;
    println!("artifacts dir : {}", dir.display());
    println!("platform      : {}", rt.platform());
    let mut names = manifest.artifact_names();
    names.sort();
    println!("artifacts ({}):", names.len());
    for n in &names {
        let a = manifest.artifact(n);
        let args_n = a.get("args").as_arr().map(|x| x.len()).unwrap_or(0);
        println!("  {n:<28} {args_n:>2} args  {}", a.get("file").as_str().unwrap_or("?"));
    }
    for key in ["lenet_test_acc", "convnet_test_acc"] {
        if let Some(v) = manifest.metric(key) {
            println!("metric {key} = {v:.4}");
        }
    }
    // compile one artifact as a smoke check
    let e = rt.load("lenet_fwd_b1")?;
    println!("compiled lenet_fwd_b1: {} args OK", e.args.len());
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let dir = artifacts(args);
    let kind = model_kind(args)?;
    let mut rt = Runtime::new(&dir)?;
    let store = WeightStore::load(&dir, kind)?;
    let test = Dataset::load(&dir, kind.dataset(), "test")?;
    let limit = if args.has_flag("fast") { 512 } else { usize::MAX };

    let store = if let Some(phi) = args.get("phi") {
        let phi: u32 = phi.parse().context("--phi")?;
        let n = args.get_usize("n", 16);
        let names = repro::quantized_names(kind);
        println!("quantizing {names:?} at phi={phi}, N={n}, mode={}", mode(args)?.name());
        repro::quantized_store(&store, &names, phi, n, mode(args)?)?
    } else {
        store
    };
    let acc = repro::eval_store(&mut rt, &store, &test, limit)?;
    println!("{} accuracy: {:.4}", kind.name(), acc);
    Ok(())
}

fn cmd_encode(args: &Args) -> Result<()> {
    let dir = artifacts(args);
    let kind = model_kind(args)?;
    let store = WeightStore::load(&dir, kind)?;
    let q = QualityConfig { phi: args.get_usize("phi", 4) as u32, group: args.get_usize("n", 16) };
    let encoded = deploy::encode_store(&store, q, mode(args)?)?;
    let bytes = qsq_edge::codec::encode_model(&encoded)?;
    let out = args.get_or("out", "model.qsq");
    std::fs::write(&out, &bytes)?;
    println!(
        "wrote {out}: {} bytes ({} tensors, phi={}, N={}), savings {:.2}% vs fp32",
        bytes.len(),
        encoded.tensors.len(),
        q.phi,
        q.group,
        100.0 * (1.0 - encoded.encoded_bits() as f64 / encoded.full_precision_bits() as f64)
    );
    Ok(())
}

fn cmd_decode(args: &Args) -> Result<()> {
    let path = args.get("in").context("--in <file.qsq> required")?;
    let bytes = std::fs::read(path)?;
    let model = qsq_edge::codec::decode_model(&bytes)?;
    println!("container {path}: {} bytes, {} tensors", bytes.len(), model.tensors.len());
    for t in &model.tensors {
        let qt = &t.tensor;
        println!(
            "  {:<6} shape {:?} phi={} group={} gamma={:.2} delta={:.2} zeros={:.1}% bits={}",
            t.name,
            qt.shape,
            qt.phi,
            qt.group,
            qt.gamma,
            qt.delta,
            100.0 * qt.zeros_fraction(),
            qt.encoded_bits(32),
        );
    }
    Ok(())
}

fn cmd_deploy_sim(args: &Args) -> Result<()> {
    let dir = artifacts(args);
    let kind = model_kind(args)?;
    let store = WeightStore::load(&dir, kind)?;
    let roster = DeviceProfile::roster();
    let dev_name = args.get_or("device", "edge-fpga-small");
    let device = roster
        .iter()
        .find(|d| d.name == dev_name)
        .with_context(|| {
            format!(
                "unknown device {dev_name} (roster: {:?})",
                roster.iter().map(|d| &d.name).collect::<Vec<_>>()
            )
        })?;

    let mut link_cfg = device.link;
    if let Some(ber) = args.get("ber") {
        link_cfg.ber = ber.parse().context("--ber")?;
    }
    // chaos harness: PALLAS_FAULTS="link.burst=ENTER:EXIT:BER" layers a
    // Gilbert–Elliott burst profile over the device link, so the deploy
    // pipeline's ARQ can be exercised under correlated (not i.i.d.) loss
    qsq_edge::util::faults::arm_from_env()?;
    if let Some(b) = qsq_edge::util::faults::link_burst() {
        println!(
            "link burst     : Gilbert–Elliott p_enter={} p_exit={} ber_bad={} (PALLAS_FAULTS)",
            b.p_enter, b.p_exit, b.ber_bad
        );
        link_cfg.burst = Some(b);
    }
    // joint two-dial deployment: the profile's memory budget sizes (phi, N),
    // its MACs-derived energy budget sizes the CSD digit dial, and the model
    // ships over the (possibly --ber-overridden) link — one pipeline pass
    let (edge, engine, rep) = match deploy::deploy_for_device_with_link(
        &store,
        device,
        mode(args)?,
        link_cfg,
        args.get_u64("seed", 7),
    ) {
        Ok(t) => t,
        Err(e) => {
            // ARQ exhaustion: surface what the doomed transfer cost before
            // it was abandoned, not just that it failed
            if let Some(te) = e.downcast_ref::<qsq_edge::channel::TransferError>() {
                println!(
                    "transfer FAILED: frame {} exceeded {} retries",
                    te.frame, te.max_retries
                );
                println!(
                    "partial        : {}/{} frames delivered, {} retransmissions, \
                     {} wire bytes and {:.3} s wasted",
                    te.partial.frames_delivered,
                    te.partial.frames,
                    te.partial.retransmissions,
                    te.partial.wire_bytes,
                    te.partial.elapsed_s,
                );
            }
            return Err(e);
        }
    };
    let quality = rep.quality;
    let csd = rep.csd.expect("csd engine deployment records the digit dial");
    let digits = if csd.max_digits == usize::MAX {
        "exact".to_string()
    } else {
        csd.max_digits.to_string()
    };
    println!(
        "device {dev_name}: selected quality phi={}, N={} + csd digits={digits}",
        quality.phi, quality.group
    );
    println!(
        "container      : {} bytes ({} frames, {} retransmissions)",
        rep.container_bytes, rep.transfer.frames, rep.transfer.retransmissions
    );
    println!(
        "transfer       : {:.3} s over {:.1} Mbps (+{:.0} µJ DRAM-equivalent)",
        rep.transfer.elapsed_s,
        link_cfg.bandwidth_bps / 1e6,
        rep.transfer.transfer_energy_pj / 1e6
    );
    println!(
        "memory savings : {:.2}% (encoded {} bits vs {} bits fp32)",
        100.0 * rep.memory_savings(),
        rep.encoded_bits,
        rep.full_bits
    );
    println!(
        "decoder ops    : {} exp-adds, {} sign-flips, {} zero-outputs",
        rep.decoder_ops.exponent_adds, rep.decoder_ops.sign_flips, rep.decoder_ops.zero_outputs
    );
    println!(
        "zeros fraction : {:.2}%  mean rel err: {:.4}",
        100.0 * rep.zeros_fraction,
        rep.mean_rel_error
    );

    // the stacked second dial: the CSD engine the deployment built on the
    // post-channel edge store at the selected digit budget
    let (h, w, c) = kind.input_hwc();
    engine.forward(&qsq_edge::tensor::Tensor::zeros(vec![1, h, w, c]))?;
    let led = engine.ledger();
    println!(
        "csd engine     : {:.2} pp/MAC at digits={digits}, {:.1}% MACs gated, \
         {:.1} nJ compute/request",
        engine.mean_pp(),
        100.0 * engine.skipped_fraction(),
        led.compute_pj() / 1e3
    );

    // score the decoded edge model
    let mut rt = Runtime::new(&dir)?;
    let test = Dataset::load(&dir, kind.dataset(), "test")?;
    let limit = if args.has_flag("fast") { 512 } else { usize::MAX };
    let base = repro::eval_store(&mut rt, &store, &test, limit)?;
    let edge_acc = repro::eval_store(&mut rt, &edge, &test, limit)?;
    println!("accuracy       : fp32 {base:.4} -> edge {edge_acc:.4}");
    Ok(())
}

fn cmd_finetune(args: &Args) -> Result<()> {
    let dir = artifacts(args);
    let mut rt = Runtime::new(&dir)?;
    let store = WeightStore::load(&dir, ModelKind::Lenet)?;
    let train = Dataset::load(&dir, "mnist", "train")?;
    let test = Dataset::load(&dir, "mnist", "test")?;
    let names = repro::quantized_names(ModelKind::Lenet);
    let q = repro::quantized_store(
        &store,
        &names,
        args.get_usize("phi", 4) as u32,
        args.get_usize("n", 16),
        mode(args)?,
    )?;
    let epochs = args.get_usize("epochs", 5);
    let lr = args.get_f64("lr", 0.05) as f32;
    let (_, _, rep) = finetune::finetune_fc(&mut rt, &q, &train, &test, epochs, lr, 0)?;
    println!("fine-tune (quantized backbone frozen, fp32 head, {epochs} epochs, lr {lr}):");
    println!("  accuracy {:.4} -> {:.4}", rep.acc_before, rep.acc_after);
    println!("  epoch losses: {:?}", rep.losses);
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = artifacts(args);
    let engine = match args.get_or("engine", "auto").as_str() {
        "auto" => server::EngineSelect::Auto,
        "pjrt" => server::EngineSelect::Pjrt,
        "host" => server::EngineSelect::Host,
        "host-quant" => server::EngineSelect::HostQuantized(QualityConfig {
            phi: args.get_usize("phi", 4) as u32,
            group: args.get_usize("n", 16),
        }),
        // --digits N = CSD partial products per weight; omitted = exact.
        "host-csd" => server::EngineSelect::HostCsd(match args.get("digits") {
            None => CsdQuality::exact(),
            Some(d) => {
                let digits: usize = d
                    .parse()
                    .with_context(|| format!("--digits {d:?} is not a number"))?;
                if digits == 0 {
                    // a zero budget truncates every weight to zero — the
                    // server would happily serve an all-zero model
                    bail!(
                        "--digits 0 would gate every weight and serve an all-zero \
                         model; use --digits 1 for the cheapest dial, or omit \
                         --digits for exact CSD"
                    );
                }
                CsdQuality::new(digits)
            }
        }),
        other => bail!("unknown engine {other:?} (auto|pjrt|host|host-quant|host-csd)"),
    };
    let policy = qsq_edge::runtime::engine::PolicySelect::from_name(
        &args.get_or("policy", "batch-fill"),
    )?;
    let cfg = server::ServerConfig {
        model: model_kind(args)?,
        batch: args.get_usize("batch", 32),
        max_delay: std::time::Duration::from_millis(args.get_u64("delay-ms", 5)),
        bind: format!("127.0.0.1:{}", args.get_usize("port", 9000)),
        engine,
        policy,
        // admission control: 0 derives the cap (4x batch); jobs queued past
        // the deadline are shed with a terminal `deadline exceeded` reply
        queue_cap: args.get_usize("queue-cap", 0),
        deadline: std::time::Duration::from_millis(args.get_u64("deadline-ms", 2000)),
        // replicated inference workers (0 = available_parallelism)
        workers: args.get_usize("workers", 0),
        ..Default::default()
    };
    // --synth: serve a deterministic synthetic store with no artifacts on
    // disk (the PJRT path is skipped) — CI smokes the full serving stack
    // this way on runners that never ran `make artifacts`
    let srv = if args.has_flag("synth") {
        let store = qsq_edge::data::synth_store(args.get_u64("seed", 7), cfg.model);
        server::Server::start_with_store(store, cfg)?
    } else {
        server::Server::start(dir, cfg)?
    };
    println!("serving on 127.0.0.1:{} (ctrl-c to stop)", srv.port);
    // --serve-secs N: run bounded, then exercise the graceful-shutdown path
    // and exit 0 (CI end-to-end smoke); omitted = serve until killed
    let serve_secs = args.get_u64("serve-secs", 0);
    let t0 = std::time::Instant::now();
    loop {
        std::thread::sleep(std::time::Duration::from_secs(
            if serve_secs > 0 { 1 } else { 5 },
        ));
        println!("{}", srv.metrics.snapshot().to_json());
        if serve_secs > 0 && t0.elapsed().as_secs() >= serve_secs {
            srv.stop();
            println!("served {serve_secs}s; clean shutdown");
            return Ok(());
        }
    }
}

fn cmd_client(args: &Args) -> Result<()> {
    let kind = model_kind(args)?;
    let port = args.get_usize("port", 9000);
    let n = args.get_usize("n", 64);
    let mut gen = RequestGen::new(kind, args.get_u64("seed", 1));
    let mut client = server::Client::connect(&format!("127.0.0.1:{port}"))?;
    let t0 = std::time::Instant::now();
    let mut lat_us = Vec::with_capacity(n);
    for i in 0..n {
        let (img, _) = gen.next();
        let reply = client.infer(i as u64, img.data())?;
        if !reply.get("error").is_null() {
            bail!("server error: {}", reply.get("error").as_str().unwrap_or("?"));
        }
        lat_us.push(reply.get("latency_us").as_f64().unwrap_or(0.0));
    }
    let total = t0.elapsed().as_secs_f64();
    let lat: Vec<f64> = lat_us.iter().map(|v| v / 1e3).collect();
    println!(
        "{n} requests in {total:.3} s ({:.1} req/s); latency ms p50={:.2} p95={:.2} max={:.2}",
        n as f64 / total,
        qsq_edge::util::stats::percentile(&lat, 50.0),
        qsq_edge::util::stats::percentile(&lat, 95.0),
        lat.iter().cloned().fold(0.0, f64::max),
    );
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<()> {
    let ctx = Ctx::new(artifacts(args), args.has_flag("fast"));
    let exp = args.get_or("exp", "all");
    if exp == "all" {
        for e in repro::ALL_EXPERIMENTS {
            println!("================ {e} ================");
            match repro::run_experiment(&ctx, e) {
                Ok(s) => println!("{s}"),
                Err(err) => println!("FAILED: {err:#}"),
            }
        }
        Ok(())
    } else {
        let s = repro::run_experiment(&ctx, &exp)?;
        println!("{s}");
        Ok(())
    }
}
