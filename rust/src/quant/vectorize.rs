//! Vector (group) selection strategies — paper Figs. 5/6.
//!
//! The quantizer groups contiguous runs of rows in the `[K, OC]` matmul
//! layout, where K is ordered (di, dj, c) with channels fastest.  That makes
//! the paper's two strategies:
//!
//! * **channel-wise** (Fig. 5): group = C — each vector is the C channel
//!   values at one kernel position for one output filter.
//! * **filter-wise** (Fig. 6): group = K — one vector per output filter.
//! * **fixed-N**: any divisor of K (the Fig. 8/9/10 sweeps).

use anyhow::{bail, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Grouping {
    /// One vector per kernel position across channels (Fig. 5); group = C.
    ChannelWise,
    /// One vector per output filter (Fig. 6); group = K.
    FilterWise,
    /// Fixed vector length N (must divide K).
    FixedN(usize),
}

impl Grouping {
    /// Resolve to a concrete group length for a tensor shape.
    pub fn resolve(self, shape: &[usize]) -> Result<usize> {
        let (k, c) = match shape.len() {
            4 => (shape[0] * shape[1] * shape[2], shape[2]),
            2 => (shape[0], shape[0]),
            _ => bail!("unsupported rank {}", shape.len()),
        };
        let g = match self {
            Grouping::ChannelWise => c,
            Grouping::FilterWise => k,
            Grouping::FixedN(n) => n,
        };
        if g == 0 || k % g != 0 {
            bail!("group {g} does not divide K={k} (shape {shape:?})");
        }
        Ok(g)
    }

    /// Best-effort fixed-N: largest divisor of K that is <= n (so sweeps can
    /// use one nominal N across tensors with awkward K, as the paper does
    /// for N in {2,4,8,...,64}).
    pub fn nearest_divisor(shape: &[usize], n: usize) -> Result<usize> {
        let k = match shape.len() {
            4 => shape[0] * shape[1] * shape[2],
            2 => shape[0],
            _ => bail!("unsupported rank {}", shape.len()),
        };
        for g in (1..=n.min(k)).rev() {
            if k % g == 0 {
                return Ok(g);
            }
        }
        Ok(1)
    }

    pub fn name(&self) -> String {
        match self {
            Grouping::ChannelWise => "channel-wise".into(),
            Grouping::FilterWise => "filter-wise".into(),
            Grouping::FixedN(n) => format!("N={n}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channelwise_is_c() {
        assert_eq!(Grouping::ChannelWise.resolve(&[5, 5, 6, 16]).unwrap(), 6);
    }

    #[test]
    fn filterwise_is_k() {
        assert_eq!(Grouping::FilterWise.resolve(&[5, 5, 6, 16]).unwrap(), 150);
        assert_eq!(Grouping::FilterWise.resolve(&[256, 120]).unwrap(), 256);
    }

    #[test]
    fn fixed_n_must_divide() {
        assert_eq!(Grouping::FixedN(25).resolve(&[5, 5, 6, 16]).unwrap(), 25);
        assert!(Grouping::FixedN(7).resolve(&[5, 5, 6, 16]).is_err());
    }

    #[test]
    fn nearest_divisor_falls_back() {
        // K = 150: nearest divisor <= 64 is 50
        assert_eq!(Grouping::nearest_divisor(&[5, 5, 6, 16], 64).unwrap(), 50);
        assert_eq!(Grouping::nearest_divisor(&[5, 5, 6, 16], 2).unwrap(), 2);
        // K = 25: nearest divisor <= 8 is 5
        assert_eq!(Grouping::nearest_divisor(&[5, 5, 1, 6], 8).unwrap(), 5);
    }
}
