//! The 3-bit QSQ code alphabet (paper Table II).
//!
//! | code | bits | level | decode operation on the scalar      |
//! |------|------|-------|--------------------------------------|
//! | 0    | 000  |  0    | skipped (zero-skip eligible)         |
//! | 1    | 001  | +1    | scalar as-is                         |
//! | 2    | 010  | +2    | shift left once                      |
//! | 3    | 011  | +4    | shift left twice                     |
//! | 4    | 100  | -1    | invert                               |
//! | 5    | 101  | -2    | invert, shift once                  |
//! | 6    | 110  | -4    | invert, shift twice                 |
//! | 7    | 111  |  —    | unused (reserved); decodes to 0      |

/// One Table-II code. Stored as its 3-bit pattern in a u8.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Code(pub u8);

/// Decode multiplier lookup (index = code value).
pub const LUT: [f32; 8] = [0.0, 1.0, 2.0, 4.0, -1.0, -2.0, -4.0, 0.0];

impl Code {
    pub const ZERO: Code = Code(0);

    /// Construct from a signed level in {0, ±1, ±2, ±4}.
    pub fn from_level(level: i32) -> Option<Code> {
        Some(Code(match level {
            0 => 0,
            1 => 1,
            2 => 2,
            4 => 3,
            -1 => 4,
            -2 => 5,
            -4 => 6,
            _ => return None,
        }))
    }

    /// The level multiplier this code decodes to.
    #[inline]
    pub fn multiplier(self) -> f32 {
        LUT[(self.0 & 7) as usize]
    }

    /// Signed integer level.
    #[inline]
    pub fn level(self) -> i32 {
        self.multiplier() as i32
    }

    /// Number of left shifts the decoder applies (0..=2).
    #[inline]
    pub fn shifts(self) -> u32 {
        match self.0 & 7 {
            2 | 5 => 1,
            3 | 6 => 2,
            _ => 0,
        }
    }

    /// Whether the decoder inverts the sign.
    #[inline]
    pub fn inverts(self) -> bool {
        matches!(self.0 & 7, 4 | 5 | 6)
    }

    /// Whether the multiply can be skipped entirely (zero or reserved).
    #[inline]
    pub fn is_skippable(self) -> bool {
        matches!(self.0 & 7, 0 | 7)
    }

    pub fn is_reserved(self) -> bool {
        self.0 & 7 == 7
    }

    /// Decode against a scalar: `multiplier * alpha` (Table II semantics).
    #[inline]
    pub fn decode(self, alpha: f32) -> f32 {
        self.multiplier() * alpha
    }
}

/// Maximum code level available at quality `phi` (1, 2 or 4).
pub fn max_level(phi: u32) -> i32 {
    phi as i32
}

/// Available signed levels at quality `phi`.
pub fn levels_for_phi(phi: u32) -> Vec<i32> {
    match phi {
        1 => vec![0, 1],
        2 => vec![0, 1, 2],
        4 => vec![0, 1, 2, 4],
        _ => panic!("phi must be 1, 2 or 4, got {phi}"),
    }
}

/// Bits per code at quality `phi` (canonicalized eq. 8 — see DESIGN.md §6).
pub fn code_bits(phi: u32) -> u32 {
    let levels = 2 * (1 + phi.ilog2()) + 1; // 0 plus +/- each power of two
    (levels as f64).log2().ceil() as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_semantics() {
        for (code, want) in LUT.iter().enumerate() {
            assert_eq!(Code(code as u8).decode(1.0), *want);
        }
        // decode really is shift+invert: multiplier == ±2^shifts
        for c in 0..8u8 {
            let code = Code(c);
            if code.is_skippable() {
                assert_eq!(code.multiplier(), 0.0);
            } else {
                let sign = if code.inverts() { -1.0 } else { 1.0 };
                assert_eq!(code.multiplier(), sign * (1 << code.shifts()) as f32);
            }
        }
    }

    #[test]
    fn level_roundtrip() {
        for lvl in [0, 1, 2, 4, -1, -2, -4] {
            assert_eq!(Code::from_level(lvl).unwrap().level(), lvl);
        }
        assert!(Code::from_level(3).is_none());
        assert!(Code::from_level(8).is_none());
    }

    #[test]
    fn code_bits_eq8() {
        assert_eq!(code_bits(1), 2);
        assert_eq!(code_bits(2), 3);
        assert_eq!(code_bits(4), 3);
    }

    #[test]
    fn levels_per_phi() {
        assert_eq!(levels_for_phi(1), vec![0, 1]);
        assert_eq!(levels_for_phi(4), vec![0, 1, 2, 4]);
    }

    #[test]
    fn reserved_code_decodes_zero() {
        assert_eq!(Code(7).decode(123.0), 0.0);
        assert!(Code(7).is_skippable());
    }
}
