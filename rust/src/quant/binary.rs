//! XNOR-Net / BWN-style binary baseline (paper eqs. 2–3):
//! B* = sign(W), alpha* = ||W||_l1 / n.

use anyhow::Result;

use super::qsq::matrix_dims;

/// Binary-quantized tensor: one sign bit per weight + per-group alpha.
#[derive(Clone, Debug)]
pub struct BinaryTensor {
    /// true = +1, false = -1 (sign(0) stored as +1).
    pub signs: Vec<bool>,
    pub scalars: Vec<f32>,
    pub k: usize,
    pub oc: usize,
    pub group: usize,
    pub shape: Vec<usize>,
}

impl BinaryTensor {
    pub fn decode(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.k * self.oc];
        for ki in 0..self.k {
            let gi = ki / self.group;
            for j in 0..self.oc {
                let a = self.scalars[gi * self.oc + j];
                out[ki * self.oc + j] = if self.signs[ki * self.oc + j] { a } else { -a };
            }
        }
        out
    }

    pub fn error(&self, w: &[f32]) -> f64 {
        self.decode()
            .iter()
            .zip(w)
            .map(|(d, &x)| {
                let e = (x - d) as f64;
                e * e
            })
            .sum()
    }

    /// 1 bit per weight + fp scalars.
    pub fn encoded_bits(&self, fpb: u32) -> u64 {
        self.signs.len() as u64 + self.scalars.len() as u64 * fpb as u64
    }
}

/// eq. 2/3: B = sign(W), alpha = mean |W| per group.
pub fn quantize_binary(w: &[f32], shape: &[usize], group: usize) -> Result<BinaryTensor> {
    let (k, oc) = matrix_dims(shape)?;
    anyhow::ensure!(w.len() == k * oc, "weight len mismatch");
    anyhow::ensure!(group > 0 && k % group == 0, "group {group} must divide K={k}");
    let g = k / group;
    let mut signs = vec![true; k * oc];
    let mut scalars = vec![0.0f32; g * oc];
    for gi in 0..g {
        for j in 0..oc {
            let mut abs_sum = 0.0f64;
            for i in 0..group {
                let x = w[(gi * group + i) * oc + j];
                abs_sum += (x as f64).abs();
                signs[(gi * group + i) * oc + j] = x >= 0.0;
            }
            scalars[gi * oc + j] = (abs_sum / group as f64) as f32;
        }
    }
    Ok(BinaryTensor { signs, scalars, k, oc, group, shape: shape.to_vec() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::gen_weights;
    use crate::util::rng::Rng;

    #[test]
    fn eq2_eq3_exact() {
        let w = [1.0f32, -3.0, 2.0, -2.0];
        let b = quantize_binary(&w, &[4, 1], 4).unwrap();
        assert_eq!(b.scalars[0], 2.0); // (1+3+2+2)/4
        assert_eq!(b.decode(), vec![2.0, -2.0, 2.0, -2.0]);
    }

    #[test]
    fn alpha_is_l2_optimal_for_signs() {
        // for fixed B=sign(W), alpha=mean|W| minimizes ||W - aB||^2:
        // perturbing alpha must increase error
        let mut r = Rng::new(4);
        let w = gen_weights(&mut r, 32, 1.0);
        let b = quantize_binary(&w, &[32, 1], 32).unwrap();
        let base = b.error(&w);
        for eps in [-0.05f32, 0.05] {
            let mut b2 = b.clone();
            b2.scalars[0] += eps;
            assert!(b2.error(&w) >= base - 1e-9);
        }
    }

    #[test]
    fn binary_bits() {
        let w = vec![1.0f32; 64];
        let b = quantize_binary(&w, &[64, 1], 16).unwrap();
        assert_eq!(b.encoded_bits(32), 64 + 4 * 32);
    }
}
