//! The Quality Scalable Quantizer (paper §III.B, eqs. 5–10).
//!
//! Layout convention (shared with `python/compile/qsq_lib.py` — keep in
//! sync): tensors are quantized in matmul layout `[K, OC]` row-major; conv
//! weights `[kh,kw,C,OC]` reinterpret directly (C-order reshape is a no-op).
//! Groups are contiguous runs of `group` rows per output column; scalars are
//! `[K/group, OC]` row-major.
//!
//! Assignment modes (DESIGN.md §6):
//! * `SigmaSearch` — the paper's method: per-sign sigma thresholds
//!   (gamma·sigma, sigma, delta·sigma), (gamma, delta) tuned per tensor by
//!   exhaustive grid search minimizing eq. 5.
//! * `Sigma { gamma, delta }` — fixed thresholds.
//! * `Nearest` — nearest level given the eq.-9 alpha (optimal for eq. 5).
//! * `NearestOpt` — ablation: per-group 1-D line search over alpha (eq. 9
//!   clamps everything above mean|w|, which collapses deep all-layer
//!   quantization — this mode shows the recoverable gap).

use anyhow::{bail, Result};

use super::codes::{self, Code};
use super::gaussian::{group_stats, GroupStats};
use super::sigma_fast;

/// Exhaustive-search grids (match qsq_lib.GAMMA_GRID / DELTA_GRID).
pub const GAMMA_GRID: [f64; 19] = [
    0.05, 0.10, 0.15, 0.20, 0.25, 0.30, 0.35, 0.40, 0.45, 0.50, 0.55, 0.60, 0.65, 0.70, 0.75,
    0.80, 0.85, 0.90, 0.95,
];
pub const DELTA_GRID: [f64; 8] = [1.1, 1.25, 1.5, 1.75, 2.0, 2.25, 2.5, 3.0];
/// Alpha multiplier candidates for `NearestOpt` (match qsq_lib._ALPHA_MULTS).
pub const ALPHA_MULTS: [f64; 8] = [0.5, 0.75, 1.0, 1.5, 2.0, 2.5, 3.0, 4.0];

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AssignMode {
    SigmaSearch,
    Sigma { gamma: f64, delta: f64 },
    Nearest,
    NearestOpt,
}

impl AssignMode {
    pub fn name(&self) -> &'static str {
        match self {
            AssignMode::SigmaSearch => "sigma-search",
            AssignMode::Sigma { .. } => "sigma",
            AssignMode::Nearest => "nearest",
            AssignMode::NearestOpt => "nearest-opt",
        }
    }

    pub fn from_name(s: &str) -> Option<AssignMode> {
        Some(match s {
            "sigma-search" => AssignMode::SigmaSearch,
            "sigma" => AssignMode::Sigma { gamma: 0.5, delta: 2.0 },
            "nearest" => AssignMode::Nearest,
            "nearest-opt" => AssignMode::NearestOpt,
            _ => return None,
        })
    }
}

/// One quantized tensor: Table-II codes + per-group scalars.
#[derive(Clone, Debug)]
pub struct QuantizedTensor {
    /// [K, OC] row-major.
    pub codes: Vec<Code>,
    /// [K/group, OC] row-major.
    pub scalars: Vec<f32>,
    pub k: usize,
    pub oc: usize,
    pub group: usize,
    pub phi: u32,
    pub gamma: f64,
    pub delta: f64,
    /// Original tensor shape (C-order compatible with [K, OC]).
    pub shape: Vec<usize>,
}

impl QuantizedTensor {
    /// Shift-and-scale decode back to f32 in the original C-order layout.
    pub fn decode(&self) -> Vec<f32> {
        let g = self.k / self.group;
        let mut out = vec![0.0f32; self.k * self.oc];
        for ki in 0..self.k {
            let gi = ki / self.group;
            debug_assert!(gi < g);
            for j in 0..self.oc {
                let alpha = self.scalars[gi * self.oc + j];
                out[ki * self.oc + j] = self.codes[ki * self.oc + j].decode(alpha);
            }
        }
        out
    }

    /// Eq.-5 objective: sum of squared reconstruction error vs `w` [K,OC].
    pub fn error(&self, w: &[f32]) -> f64 {
        assert_eq!(w.len(), self.codes.len());
        self.decode()
            .iter()
            .zip(w)
            .map(|(d, &x)| {
                let e = (x - d) as f64;
                e * e
            })
            .sum()
    }

    /// Fraction of zero codes (the paper's "+6 % zeros" claim).
    pub fn zeros_fraction(&self) -> f64 {
        let z = self.codes.iter().filter(|c| c.is_skippable()).count();
        z as f64 / self.codes.len().max(1) as f64
    }

    /// Eq. 12: bits for codes + full-precision scalars.
    pub fn encoded_bits(&self, fpb: u32) -> u64 {
        self.codes.len() as u64 * codes::code_bits(self.phi) as u64
            + self.scalars.len() as u64 * fpb as u64
    }

    /// Eq. 11: bits of the unquantized tensor.
    pub fn full_precision_bits(&self, fpb: u32) -> u64 {
        self.codes.len() as u64 * fpb as u64
    }

    /// 1 - encoded/full (the paper's "memory savings" metric).
    pub fn memory_savings(&self, fpb: u32) -> f64 {
        1.0 - self.encoded_bits(fpb) as f64 / self.full_precision_bits(fpb) as f64
    }
}

/// Validate quantizer inputs and compute the per-(group, column) statistics
/// (strided column scan), shared by [`quantize`] and the search oracles.
fn validate_and_stats(
    w: &[f32],
    shape: &[usize],
    group: usize,
    phi: u32,
) -> Result<(usize, usize, Vec<GroupStats>)> {
    let (k, oc) = matrix_dims(shape)?;
    if w.len() != k * oc {
        bail!("weight len {} != {}x{}", w.len(), k, oc);
    }
    if group == 0 || k % group != 0 {
        bail!("group {group} must divide K={k}");
    }
    if !matches!(phi, 1 | 2 | 4) {
        bail!("phi must be 1, 2 or 4");
    }
    let g = k / group;
    let mut stats: Vec<GroupStats> = Vec::with_capacity(g * oc);
    let mut vbuf = vec![0.0f32; group];
    for gi in 0..g {
        for j in 0..oc {
            for (i, slot) in vbuf.iter_mut().enumerate() {
                *slot = w[(gi * group + i) * oc + j];
            }
            stats.push(group_stats(&vbuf, phi));
        }
    }
    Ok((k, oc, stats))
}

/// Sigma-threshold code assignment (eqs. 6/8) for one (gamma, delta).
pub(crate) fn assign_sigma_codes(
    w: &[f32],
    k: usize,
    oc: usize,
    group: usize,
    phi: u32,
    stats: &[GroupStats],
    gamma: f64,
    delta: f64,
) -> Vec<Code> {
    let mut codes_out = vec![Code::ZERO; k * oc];
    for ki in 0..k {
        let gi = ki / group;
        for j in 0..oc {
            let st = &stats[gi * oc + j];
            let x = w[ki * oc + j] as f64;
            let sig = if x >= 0.0 { st.sigma_p } else { st.sigma_n };
            let mag = x.abs();
            let mut lvl = 0i32;
            if mag >= gamma * sig {
                lvl = 1;
            }
            if phi >= 2 && mag >= sig {
                lvl = 2;
            }
            if phi >= 4 && mag >= delta * sig {
                lvl = 4;
            }
            let signed = if x > 0.0 { lvl } else if x < 0.0 { -lvl } else { 0 };
            codes_out[ki * oc + j] = Code::from_level(signed).unwrap();
        }
    }
    codes_out
}

/// Eq.-5 error of a code assignment under the eq.-9 scalars.
pub(crate) fn eq5_error_eq9_alpha(
    w: &[f32],
    k: usize,
    oc: usize,
    group: usize,
    codes_v: &[Code],
    stats: &[GroupStats],
) -> f64 {
    let mut tot = 0.0f64;
    for ki in 0..k {
        let gi = ki / group;
        for j in 0..oc {
            let a = stats[gi * oc + j].alpha;
            let d = codes_v[ki * oc + j].multiplier() as f64 * a;
            let e = w[ki * oc + j] as f64 - d;
            tot += e * e;
        }
    }
    tot
}

/// Delta-grid candidates at quality `phi` (below phi=4 the level-4 threshold
/// is unused, so a single placeholder keeps the search shape).
pub(crate) fn deltas_for(phi: u32) -> &'static [f64] {
    if phi >= 4 {
        &DELTA_GRID
    } else {
        &[2.0]
    }
}

/// Quantize `w` (row-major `[K, OC]`, possibly a reshaped conv tensor).
pub fn quantize(
    w: &[f32],
    shape: &[usize],
    group: usize,
    phi: u32,
    mode: AssignMode,
) -> Result<QuantizedTensor> {
    let (k, oc, stats) = validate_and_stats(w, shape, group, phi)?;
    let g = k / group;

    let assign_sigma =
        |gamma: f64, delta: f64| assign_sigma_codes(w, k, oc, group, phi, &stats, gamma, delta);
    let eq9_alpha = |gi: usize, j: usize| stats[gi * oc + j].alpha;

    let levels = codes::levels_for_phi(phi);
    let assign_nearest = |alpha_of: &dyn Fn(usize, usize) -> f64| -> Vec<Code> {
        let mut codes_out = vec![Code::ZERO; k * oc];
        for ki in 0..k {
            let gi = ki / group;
            for j in 0..oc {
                let a = alpha_of(gi, j);
                let x = w[ki * oc + j] as f64;
                let mag = x.abs();
                // first minimum wins (replicates np.argmin tie behaviour)
                let mut best = (f64::INFINITY, 0i32);
                for &l in &levels {
                    let d = (mag - l as f64 * a).abs();
                    if d < best.0 {
                        best = (d, l);
                    }
                }
                let signed = if x > 0.0 { best.1 } else if x < 0.0 { -best.1 } else { 0 };
                codes_out[ki * oc + j] = Code::from_level(signed).unwrap();
            }
        }
        codes_out
    };

    let (codes_v, scalars, gamma, delta) = match mode {
        AssignMode::Sigma { gamma, delta } => {
            let c = assign_sigma(gamma, delta);
            (c, eq9_scalars(&stats, g, oc), gamma, delta)
        }
        AssignMode::SigmaSearch => {
            // O(sort) grid scoring (see `sigma_fast`): same argmin as the
            // naive 19x8 assignment sweep, then a single assignment pass.
            let (gam, dlt) = sigma_fast::search(w, k, oc, group, phi, &stats);
            let c = assign_sigma(gam, dlt);
            (c, eq9_scalars(&stats, g, oc), gam, dlt)
        }
        AssignMode::Nearest => {
            let c = assign_nearest(&eq9_alpha);
            (c, eq9_scalars(&stats, g, oc), -1.0, -1.0)
        }
        AssignMode::NearestOpt => {
            // per-group alpha line search (strict-improvement, in ALPHA_MULTS
            // order — replicates the python loop exactly)
            let mut best_alpha: Vec<f64> = (0..g * oc).map(|i| stats[i].alpha).collect();
            let mut best_err = vec![f64::INFINITY; g * oc];
            for &m in ALPHA_MULTS.iter() {
                for gi in 0..g {
                    for j in 0..oc {
                        let a = stats[gi * oc + j].alpha * m;
                        let mut e = 0.0f64;
                        for i in 0..group {
                            let x = w[(gi * group + i) * oc + j] as f64;
                            let mag = x.abs();
                            let mut bd = f64::INFINITY;
                            for &l in &levels {
                                let d = (mag - l as f64 * a).abs();
                                if d < bd {
                                    bd = d;
                                }
                            }
                            e += bd * bd;
                        }
                        if e < best_err[gi * oc + j] {
                            best_err[gi * oc + j] = e;
                            best_alpha[gi * oc + j] = a;
                        }
                    }
                }
            }
            let alpha_of = |gi: usize, j: usize| best_alpha[gi * oc + j];
            let c = assign_nearest(&alpha_of);
            let scalars: Vec<f32> = best_alpha.iter().map(|&a| a as f32).collect();
            (c, scalars, -1.0, -1.0)
        }
    };

    Ok(QuantizedTensor {
        codes: codes_v,
        scalars,
        k,
        oc,
        group,
        phi,
        gamma,
        delta,
        shape: shape.to_vec(),
    })
}

/// The pre-kernel SigmaSearch: one full assignment + error pass per grid
/// candidate (152 passes at phi=4).  Oracle for `sigma_fast` identity tests
/// and the speedup baseline in `bench_kernels`.
pub fn quantize_sigma_search_naive(
    w: &[f32],
    shape: &[usize],
    group: usize,
    phi: u32,
) -> Result<QuantizedTensor> {
    let (k, oc, stats) = validate_and_stats(w, shape, group, phi)?;
    let g = k / group;
    let (gamma, delta) = sigma_fast::search_naive(w, k, oc, group, phi, &stats);
    let codes_v = assign_sigma_codes(w, k, oc, group, phi, &stats, gamma, delta);
    Ok(QuantizedTensor {
        codes: codes_v,
        scalars: eq9_scalars(&stats, g, oc),
        k,
        oc,
        group,
        phi,
        gamma,
        delta,
        shape: shape.to_vec(),
    })
}

fn eq9_scalars(stats: &[GroupStats], g: usize, oc: usize) -> Vec<f32> {
    (0..g * oc).map(|i| stats[i].alpha as f32).collect()
}

/// Collapse a tensor shape to matmul dims (K, OC): last axis is OC.
pub fn matrix_dims(shape: &[usize]) -> Result<(usize, usize)> {
    match shape.len() {
        2 => Ok((shape[0], shape[1])),
        4 => Ok((shape[0] * shape[1] * shape[2], shape[3])),
        _ => bail!("unsupported tensor rank {} for quantization", shape.len()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, forall, gen_weights};
    use crate::util::rng::Rng;

    fn gauss(seed: u64, n: usize) -> Vec<f32> {
        let mut r = Rng::new(seed);
        gen_weights(&mut r, n, 0.1)
    }

    #[test]
    fn decode_values_are_levels_times_alpha() {
        let w = gauss(0, 24 * 8);
        let qt = quantize(&w, &[24, 8], 4, 4, AssignMode::Nearest).unwrap();
        let dec = qt.decode();
        for ki in 0..24 {
            for j in 0..8 {
                let a = qt.scalars[(ki / 4) * 8 + j];
                let d = dec[ki * 8 + j];
                if a != 0.0 {
                    let ratio = (d / a).abs();
                    assert!(
                        [0.0, 1.0, 2.0, 4.0].iter().any(|l| (ratio - l).abs() < 1e-5),
                        "ratio {ratio}"
                    );
                }
            }
        }
    }

    #[test]
    fn nearest_beats_sigma_search() {
        let w = gauss(1, 24 * 8);
        for phi in [1u32, 2, 4] {
            let en = quantize(&w, &[24, 8], 4, phi, AssignMode::Nearest).unwrap().error(&w);
            let es = quantize(&w, &[24, 8], 4, phi, AssignMode::SigmaSearch).unwrap().error(&w);
            assert!(en <= es + 1e-9, "phi={phi}: {en} > {es}");
        }
    }

    #[test]
    fn prop_nearest_error_monotone_in_phi() {
        forall(
            40,
            |r| gen_weights(r, 16 * 4, 0.2),
            |w| {
                let e1 = quantize(w, &[16, 4], 4, 1, AssignMode::Nearest).unwrap().error(w);
                let e2 = quantize(w, &[16, 4], 4, 2, AssignMode::Nearest).unwrap().error(w);
                let e4 = quantize(w, &[16, 4], 4, 4, AssignMode::Nearest).unwrap().error(w);
                check(e1 >= e2 - 1e-9 && e2 >= e4 - 1e-9, "error not monotone in phi")
            },
        );
    }

    #[test]
    fn prop_alpha_opt_no_worse_than_eq9() {
        forall(
            30,
            |r| gen_weights(r, 8 * 6, 0.3),
            |w| {
                let eo = quantize(w, &[8, 6], 4, 4, AssignMode::NearestOpt).unwrap().error(w);
                let en = quantize(w, &[8, 6], 4, 4, AssignMode::Nearest).unwrap().error(w);
                check(eo <= en + 1e-9, "alpha search made error worse")
            },
        );
    }

    #[test]
    fn prop_decode_bounded_by_phi_alpha() {
        forall(
            30,
            |r| gen_weights(r, 32, 0.5),
            |w| {
                let qt = quantize(w, &[32, 1], 8, 4, AssignMode::SigmaSearch).unwrap();
                let dec = qt.decode();
                for (ki, &d) in dec.iter().enumerate() {
                    let a = qt.scalars[ki / 8];
                    if d.abs() > 4.0 * a.abs() + 1e-6 {
                        return Err(format!("decoded {d} exceeds 4*alpha {a}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn zero_tensor_all_zero_codes() {
        let w = vec![0.0f32; 16];
        let qt = quantize(&w, &[16, 1], 4, 4, AssignMode::Nearest).unwrap();
        assert_eq!(qt.zeros_fraction(), 1.0);
        assert!(qt.decode().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn bit_accounting() {
        let w = gauss(2, 150 * 16);
        let qt = quantize(&w, &[5, 5, 6, 16], 6, 4, AssignMode::Nearest).unwrap();
        assert_eq!(qt.full_precision_bits(32), 2400 * 32);
        assert_eq!(qt.encoded_bits(32), 2400 * 3 + 400 * 32);
        assert!(qt.memory_savings(32) > 0.7);
    }

    #[test]
    fn conv_shape_matrix_dims() {
        assert_eq!(matrix_dims(&[5, 5, 6, 16]).unwrap(), (150, 16));
        assert_eq!(matrix_dims(&[256, 120]).unwrap(), (256, 120));
        assert!(matrix_dims(&[3]).is_err());
    }

    #[test]
    fn invalid_inputs_rejected() {
        let w = vec![0.0f32; 12];
        assert!(quantize(&w, &[12, 1], 5, 4, AssignMode::Nearest).is_err()); // 5 !| 12
        assert!(quantize(&w, &[12, 1], 4, 3, AssignMode::Nearest).is_err()); // phi=3
        assert!(quantize(&w, &[10, 1], 2, 4, AssignMode::Nearest).is_err()); // len mismatch
    }

    #[test]
    fn fast_sigma_search_identical_to_naive_grid() {
        for phi in [1u32, 2, 4] {
            let w = gauss(40 + phi as u64, 48 * 6);
            let fast = quantize(&w, &[48, 6], 8, phi, AssignMode::SigmaSearch).unwrap();
            let naive = quantize_sigma_search_naive(&w, &[48, 6], 8, phi).unwrap();
            assert_eq!(fast.gamma, naive.gamma, "phi={phi}");
            assert_eq!(fast.delta, naive.delta, "phi={phi}");
            assert_eq!(fast.codes, naive.codes, "phi={phi}");
            assert_eq!(fast.scalars, naive.scalars, "phi={phi}");
        }
    }

    #[test]
    fn phi1_never_emits_high_levels() {
        let w = gauss(3, 64);
        let qt = quantize(&w, &[64, 1], 8, 1, AssignMode::SigmaSearch).unwrap();
        for c in &qt.codes {
            assert!(c.level().abs() <= 1);
        }
    }
}
