//! O(sort) sigma-threshold grid search (the encode-side kernel).
//!
//! `AssignMode::SigmaSearch` scores every (gamma, delta) candidate of the
//! 19x8 grid by the eq.-5 reconstruction error.  The naive method runs one
//! full assignment pass per candidate: `152 * K * OC` threshold compares and
//! error terms.  This module computes the identical argmin from sorted
//! per-(group, column, sign) magnitudes:
//!
//! For one cell side with eq.-9 alpha `a`, sorted magnitudes `m[0..n]` and
//! suffix sums `SM(i) = sum(m[i..n])`, the thresholds `t1 = gamma*sigma`,
//! `t2 = sigma`, `t3 = delta*sigma` split the side into level bins at the
//! partition indices `i1 <= i2 <= i3` (the grids guarantee
//! `gamma < 1 < delta`), and the squared error decomposes as
//!
//! ```text
//! err = sum(m^2)                                   (candidate-independent)
//!     - 2a*SM(i1) +    a^2*(n-i1)                  (depends on gamma only)
//!     - 2a*SM(i2) +  3*a^2*(n-i2)                  (constant; phi >= 2)
//!     - 4a*SM(i3) + 12*a^2*(n-i3)                  (depends on delta only)
//! ```
//!
//! so the whole grid costs one binary search per gamma plus one per delta
//! per cell side — `O(K*OC*log(group))` total instead of
//! `O(152*K*OC)` — and the scored objective is algebraically identical to
//! the naive pass, so the search returns the same (gamma, delta) (and hence
//! bitwise-identical codes once assigned).  Candidates whose assignments
//! coincide produce exactly equal scores in both methods, so first-wins
//! tie-breaking agrees too.  The one caveat: candidates with *distinct*
//! assignments are ranked by f64 sums accumulated in different orders, so
//! two candidates whose true errors differ by less than accumulated
//! rounding (~1e-13 relative) could in principle rank oppositely; for
//! continuous weight distributions such near-exact error ties do not occur
//! (the identity tests and `bench_kernels` assert agreement on real
//! tensors).

use super::gaussian::GroupStats;
use super::qsq::{assign_sigma_codes, deltas_for, eq5_error_eq9_alpha, GAMMA_GRID};

/// One sign side of a (group, column) cell: sorted |w| plus suffix sums.
struct Side {
    mags: Vec<f64>,
    /// `suffix[i] = sum(mags[i..])`, length `mags.len() + 1`.
    suffix: Vec<f64>,
}

impl Side {
    fn build(mut mags: Vec<f64>) -> Side {
        mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut suffix = vec![0.0f64; mags.len() + 1];
        for i in (0..mags.len()).rev() {
            suffix[i] = suffix[i + 1] + mags[i];
        }
        Side { mags, suffix }
    }

    /// First index with `mags[i] >= t` (the naive pass levels up on `>=`).
    #[inline]
    fn split(&self, t: f64) -> usize {
        self.mags.partition_point(|&m| m < t)
    }

    /// `-c1*a*SM(i) + c2*a^2*(n-i)` — one bin-boundary term of the error.
    #[inline]
    fn term(&self, i: usize, a: f64, c1: f64, c2: f64) -> f64 {
        -c1 * a * self.suffix[i] + c2 * a * a * (self.mags.len() - i) as f64
    }
}

/// Search the (gamma, delta) grid; identical argmin to [`search_naive`].
///
/// `stats` are the per-(group, column) eq.-7/eq.-9 statistics in the same
/// `[K/group, OC]` row-major order the quantizer uses.
pub fn search(
    w: &[f32],
    k: usize,
    oc: usize,
    group: usize,
    phi: u32,
    stats: &[GroupStats],
) -> (f64, f64) {
    let g = k / group;
    let deltas = deltas_for(phi);

    let mut s2 = 0.0f64;
    let mut t1 = vec![0.0f64; GAMMA_GRID.len()];
    let mut t2 = 0.0f64;
    let mut t3 = vec![0.0f64; deltas.len()];

    let mut pos = Vec::with_capacity(group);
    let mut neg = Vec::with_capacity(group);
    for gi in 0..g {
        for j in 0..oc {
            pos.clear();
            neg.clear();
            for i in 0..group {
                let x = w[(gi * group + i) * oc + j] as f64;
                s2 += x * x;
                if x > 0.0 {
                    pos.push(x);
                } else if x < 0.0 {
                    neg.push(-x);
                }
                // exact zeros always assign level 0 with zero error
            }
            let st = &stats[gi * oc + j];
            let a = st.alpha;
            for (side, sig) in [
                (Side::build(std::mem::take(&mut pos)), st.sigma_p),
                (Side::build(std::mem::take(&mut neg)), st.sigma_n),
            ] {
                if side.mags.is_empty() {
                    continue;
                }
                if phi >= 2 {
                    t2 += side.term(side.split(sig), a, 2.0, 3.0);
                }
                for (ig, &gamma) in GAMMA_GRID.iter().enumerate() {
                    t1[ig] += side.term(side.split(gamma * sig), a, 2.0, 1.0);
                }
                if phi >= 4 {
                    for (id, &delta) in deltas.iter().enumerate() {
                        t3[id] += side.term(side.split(delta * sig), a, 4.0, 12.0);
                    }
                }
            }
        }
    }

    let base = s2 + if phi >= 2 { t2 } else { 0.0 };
    let mut best = (f64::INFINITY, 0.5, 2.0);
    for (ig, &gamma) in GAMMA_GRID.iter().enumerate() {
        for (id, &delta) in deltas.iter().enumerate() {
            let e = base + t1[ig] + if phi >= 4 { t3[id] } else { 0.0 };
            if e < best.0 {
                best = (e, gamma, delta);
            }
        }
    }
    (best.1, best.2)
}

/// The original exhaustive search: one full assignment + error pass per grid
/// candidate.  Kept as the oracle for tests and `bench_kernels`.
pub fn search_naive(
    w: &[f32],
    k: usize,
    oc: usize,
    group: usize,
    phi: u32,
    stats: &[GroupStats],
) -> (f64, f64) {
    let mut best = (f64::INFINITY, 0.5, 2.0);
    for &gamma in GAMMA_GRID.iter() {
        for &delta in deltas_for(phi) {
            let codes = assign_sigma_codes(w, k, oc, group, phi, stats, gamma, delta);
            let e = eq5_error_eq9_alpha(w, k, oc, group, &codes, stats);
            if e < best.0 {
                best = (e, gamma, delta);
            }
        }
    }
    (best.1, best.2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gaussian::group_stats;
    use crate::util::prop::{check, forall, gen_weights};

    fn stats_for(w: &[f32], k: usize, oc: usize, group: usize, phi: u32) -> Vec<GroupStats> {
        let g = k / group;
        let mut stats = Vec::with_capacity(g * oc);
        let mut vbuf = vec![0.0f32; group];
        for gi in 0..g {
            for j in 0..oc {
                for (i, slot) in vbuf.iter_mut().enumerate() {
                    *slot = w[(gi * group + i) * oc + j];
                }
                stats.push(group_stats(&vbuf, phi));
            }
        }
        stats
    }

    #[test]
    fn prop_fast_matches_naive_grid() {
        for phi in [1u32, 2, 4] {
            forall(
                12,
                |r| gen_weights(r, 48 * 8, 0.2),
                |w| {
                    let stats = stats_for(w, 48, 8, 4, phi);
                    let fast = search(w, 48, 8, 4, phi, &stats);
                    let naive = search_naive(w, 48, 8, 4, phi, &stats);
                    check(fast == naive, &format!("phi={phi}: {fast:?} != {naive:?}"))
                },
            );
        }
    }

    #[test]
    fn split_uses_geq_threshold() {
        let side = Side::build(vec![1.0, 2.0, 3.0]);
        assert_eq!(side.split(2.0), 1); // m == t levels up, like `mag >= t`
        assert_eq!(side.split(2.5), 2);
        assert_eq!(side.split(0.5), 0);
        assert_eq!(side.split(9.0), 3);
        assert_eq!(side.suffix, vec![6.0, 5.0, 3.0, 0.0]);
    }

    #[test]
    fn all_zero_tensor_picks_first_candidate() {
        let w = vec![0.0f32; 32];
        let stats = stats_for(&w, 32, 1, 8, 4);
        let fast = search(&w, 32, 1, 8, 4, &stats);
        let naive = search_naive(&w, 32, 1, 8, 4, &stats);
        assert_eq!(fast, naive);
        assert_eq!(fast, (GAMMA_GRID[0], crate::quant::qsq::DELTA_GRID[0]));
    }

    #[test]
    fn single_sided_cells_agree() {
        // all-positive weights: the negative side is empty everywhere
        let w: Vec<f32> = (0..64).map(|i| 0.01 + (i % 7) as f32 * 0.05).collect();
        for phi in [1u32, 2, 4] {
            let stats = stats_for(&w, 64, 1, 8, phi);
            assert_eq!(
                search(&w, 64, 1, 8, phi, &stats),
                search_naive(&w, 64, 1, 8, phi, &stats),
                "phi={phi}"
            );
        }
    }
}
