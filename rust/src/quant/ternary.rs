//! TWN-style ternary baseline (Li et al. 2016; paper Table I, "2-bit").
//!
//! Threshold Δ = 0.7·mean(|w|); alpha = mean of |w| over the above-threshold
//! entries; codes in {-1, 0, +1} (2 bits each).  Used as the 2-bit arm of the
//! Fig.-10 design-space comparison.

use anyhow::Result;

use super::codes::Code;
use super::qsq::{matrix_dims, QuantizedTensor};

/// Quantize `w` ([K,OC] row-major or conv shape) to ternary with per-group
/// alpha; `group` rows per column share one alpha (mirrors QSQ grouping so
/// the Fig.-10 sweep compares like for like).
pub fn quantize_ternary(w: &[f32], shape: &[usize], group: usize) -> Result<QuantizedTensor> {
    let (k, oc) = matrix_dims(shape)?;
    anyhow::ensure!(w.len() == k * oc, "weight len mismatch");
    anyhow::ensure!(group > 0 && k % group == 0, "group {group} must divide K={k}");
    let g = k / group;
    let mut codes = vec![Code::ZERO; k * oc];
    let mut scalars = vec![0.0f32; g * oc];

    for gi in 0..g {
        for j in 0..oc {
            // Δ* = 0.7/n Σ|w| (TWN approximation of eq. 4's argmax)
            let mut abs_sum = 0.0f64;
            for i in 0..group {
                abs_sum += (w[(gi * group + i) * oc + j] as f64).abs();
            }
            let delta = 0.7 * abs_sum / group as f64;
            // alpha = mean |w| over entries above threshold
            let (mut sum, mut cnt) = (0.0f64, 0usize);
            for i in 0..group {
                let a = (w[(gi * group + i) * oc + j] as f64).abs();
                if a > delta {
                    sum += a;
                    cnt += 1;
                }
            }
            let alpha = if cnt > 0 { sum / cnt as f64 } else { 0.0 };
            scalars[gi * oc + j] = alpha as f32;
            for i in 0..group {
                let x = w[(gi * group + i) * oc + j] as f64;
                let lvl = if x > delta {
                    1
                } else if x < -delta {
                    -1
                } else {
                    0
                };
                codes[(gi * group + i) * oc + j] = Code::from_level(lvl).unwrap();
            }
        }
    }

    Ok(QuantizedTensor {
        codes,
        scalars,
        k,
        oc,
        group,
        phi: 1, // ternary levels {0, ±1} == phi=1 alphabet (2-bit)
        gamma: 0.7,
        delta: 0.7,
        shape: shape.to_vec(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::gen_weights;
    use crate::util::rng::Rng;

    #[test]
    fn levels_are_ternary() {
        let mut r = Rng::new(0);
        let w = gen_weights(&mut r, 64, 0.2);
        let qt = quantize_ternary(&w, &[64, 1], 16).unwrap();
        assert!(qt.codes.iter().all(|c| c.level().abs() <= 1));
    }

    #[test]
    fn alpha_matches_twn_formula() {
        // weights {1, -1, 0.1, -0.1}: Δ=0.7*0.55=0.385; alpha = mean{1,1}=1
        let w = [1.0f32, -1.0, 0.1, -0.1];
        let qt = quantize_ternary(&w, &[4, 1], 4).unwrap();
        assert!((qt.scalars[0] - 1.0).abs() < 1e-6);
        assert_eq!(
            qt.codes.iter().map(|c| c.level()).collect::<Vec<_>>(),
            vec![1, -1, 0, 0]
        );
    }

    #[test]
    fn ternary_error_worse_or_equal_qsq_phi4_nearest() {
        // with the same grouping, richer alphabet + optimal assignment wins
        let mut r = Rng::new(9);
        let w = gen_weights(&mut r, 128, 0.3);
        let t = quantize_ternary(&w, &[128, 1], 16).unwrap().error(&w);
        let q = super::super::qsq::quantize(
            &w,
            &[128, 1],
            16,
            4,
            super::super::qsq::AssignMode::NearestOpt,
        )
        .unwrap()
        .error(&w);
        assert!(q <= t + 1e-9, "qsq {q} vs ternary {t}");
    }

    #[test]
    fn encoded_bits_uses_2bit_codes() {
        let w = vec![0.5f32; 32];
        let qt = quantize_ternary(&w, &[32, 1], 8).unwrap();
        assert_eq!(qt.encoded_bits(32), 32 * 2 + 4 * 32);
    }
}
