//! Quality Scalable Quantization (the paper's §III) plus the baselines it
//! compares against.
//!
//! * [`codes`]     — the 3-bit Table-II code alphabet and its decode ops.
//! * [`gaussian`]  — per-group MLE statistics (eq. 7) with sign splitting.
//! * [`qsq`]       — the quantizer (eqs. 5–10): grouping, alpha (eq. 9),
//!   sigma-threshold assignment with exhaustive (gamma, delta) search, plus
//!   the `Nearest` / `NearestOpt` ablation modes.  Mirrors
//!   `python/compile/qsq_lib.py`; parity is enforced by integration tests
//!   against `artifacts/parity/`.
//! * [`sigma_fast`] — O(sort) scoring of the whole (gamma, delta) grid from
//!   sorted-|w| prefix sums; identical argmin to the naive 152-pass sweep.
//! * [`ternary`]   — TWN-style 2-bit baseline (Li et al., paper Table I).
//! * [`binary`]    — XNOR/BWN-style 1-bit baseline (paper eqs. 2–3).
//! * [`vectorize`] — channel-wise / filter-wise grouping (paper Figs. 5/6).

pub mod binary;
pub mod codes;
pub mod gaussian;
pub mod qsq;
pub mod sigma_fast;
pub mod ternary;
pub mod vectorize;

pub use codes::Code;
pub use qsq::{AssignMode, QuantizedTensor};
