//! Per-group Gaussian statistics (paper eq. 7, MLE) with sign splitting.
//!
//! Mirrors `qsq_lib._group_stats`: sigma_P over positive entries, sigma_N
//! over |negative| entries, with the same fallbacks when a sign side is
//! empty or degenerate.  Computed in f64 (numpy promotes reductions), so the
//! cross-language parity tests hold to ~1e-6.

/// Per-group statistics for code assignment.
#[derive(Clone, Copy, Debug)]
pub struct GroupStats {
    /// mean(|v|) — the numerator of eq. 9.
    pub abs_mean: f64,
    /// eq.-9 scalar: mean(|v|)/phi.
    pub alpha: f64,
    /// MLE sigma of positive entries (with fallback).
    pub sigma_p: f64,
    /// MLE sigma of |negative| entries (with fallback).
    pub sigma_n: f64,
}

/// Compute stats for one vector (group) of weights.
pub fn group_stats(v: &[f32], phi: u32) -> GroupStats {
    let n = v.len().max(1) as f64;
    let abs_mean = v.iter().map(|&x| (x as f64).abs()).sum::<f64>() / n;
    let alpha = abs_mean / phi as f64;

    let (sig_p, mu_p) = side_stats(v.iter().filter(|&&x| x > 0.0).map(|&x| x as f64));
    let (sig_n, mu_n) = side_stats(v.iter().filter(|&&x| x < 0.0).map(|&x| -x as f64));

    let fallback = if abs_mean > 0.0 { abs_mean } else { 1.0 };
    let fix = |sig: Option<f64>, mu: Option<f64>| match sig {
        Some(s) if s > 0.0 => s,
        _ => match mu {
            Some(m) => m.max(1e-12),
            None => fallback,
        },
    };
    GroupStats {
        abs_mean,
        alpha,
        sigma_p: fix(sig_p, mu_p),
        sigma_n: fix(sig_n, mu_n),
    }
}

/// (MLE sigma, mean) of an iterator; None for empty sides.
fn side_stats(it: impl Iterator<Item = f64>) -> (Option<f64>, Option<f64>) {
    let xs: Vec<f64> = it.collect();
    if xs.is_empty() {
        return (None, None);
    }
    let m = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
    (Some(var.sqrt()), Some(m))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_is_eq9() {
        let v = [1.0f32, 2.0, 3.0, -2.0];
        let s = group_stats(&v, 4);
        assert!((s.alpha - 2.0 / 4.0).abs() < 1e-12);
        assert!((s.abs_mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn sign_split_sigma() {
        // positives {1,3}: mean 2, MLE sigma 1; negatives {-2}: single value
        // -> sigma falls back to mean magnitude 2
        let v = [1.0f32, 3.0, -2.0];
        let s = group_stats(&v, 1);
        assert!((s.sigma_p - 1.0).abs() < 1e-12);
        assert!((s.sigma_n - 2.0).abs() < 1e-12);
    }

    #[test]
    fn all_zero_fallback() {
        let s = group_stats(&[0.0f32; 8], 4);
        assert_eq!(s.alpha, 0.0);
        assert_eq!(s.sigma_p, 1.0);
        assert_eq!(s.sigma_n, 1.0);
    }

    #[test]
    fn single_sided() {
        let v = [0.5f32, 0.5, 0.5];
        let s = group_stats(&v, 1);
        // degenerate sigma (0) falls back to side mean 0.5
        assert!((s.sigma_p - 0.5).abs() < 1e-12);
        // no negatives: falls back to abs_mean
        assert!((s.sigma_n - 0.5).abs() < 1e-12);
    }
}
