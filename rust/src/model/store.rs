//! Weight/dataset store backed by the `artifacts/` directory produced by
//! `make artifacts` (trained .npy tensors + eval splits + manifest.json).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::model::meta::{ModelKind, ModelMeta};
use crate::tensor::Tensor;
use crate::util::{json, npy};

/// Loaded weights for one model.
#[derive(Clone, Debug)]
pub struct WeightStore {
    pub kind: ModelKind,
    pub meta: ModelMeta,
    tensors: BTreeMap<String, Tensor>,
}

impl WeightStore {
    /// Empty store (tests / incremental construction via `set_unchecked`).
    pub fn empty(kind: ModelKind) -> WeightStore {
        WeightStore { kind, meta: ModelMeta::of(kind), tensors: BTreeMap::new() }
    }

    /// Insert without shape validation (test fixtures, decoded tensors whose
    /// metadata was already checked by the codec).
    pub fn set_unchecked(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    /// Load `<artifacts>/weights/<model>/<tensor>.npy` for every tensor in
    /// the model's metadata, validating shapes.
    pub fn load(artifacts: &Path, kind: ModelKind) -> Result<WeightStore> {
        let meta = ModelMeta::of(kind);
        let dir = artifacts.join("weights").join(kind.name());
        let mut tensors = BTreeMap::new();
        for tm in &meta.tensors {
            let path = dir.join(format!("{}.npy", tm.name));
            let arr = npy::read(&path)?;
            if arr.shape != tm.shape {
                bail!(
                    "{}: shape {:?} in npy vs {:?} in metadata",
                    path.display(),
                    arr.shape,
                    tm.shape
                );
            }
            tensors.insert(tm.name.to_string(), Tensor::new(arr.shape.clone(), arr.to_f32()?)?);
        }
        Ok(WeightStore { kind, meta, tensors })
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .with_context(|| format!("tensor {name} not in store"))
    }

    /// Tensors in declaration order (the artifact argument order).  Tensors
    /// that were [`remove`](Self::remove)d are skipped — callers that need
    /// the full artifact argument list (the PJRT path) get a clean
    /// arg-count error from the executable instead of a panic here.
    pub fn ordered(&self) -> Vec<&Tensor> {
        self.meta
            .tensors
            .iter()
            .filter_map(|t| self.tensors.get(t.name))
            .collect()
    }

    /// Remove a tensor (e.g. once packed codes shadow its f32 form).
    pub fn remove(&mut self, name: &str) -> Option<Tensor> {
        self.tensors.remove(name)
    }

    /// Replace a tensor (e.g. with decoded approximate weights).
    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        let tm = self
            .meta
            .tensor(name)
            .with_context(|| format!("unknown tensor {name}"))?;
        if t.shape() != tm.shape.as_slice() {
            bail!("set {name}: shape {:?} vs {:?}", t.shape(), tm.shape);
        }
        self.tensors.insert(name.to_string(), t);
        Ok(())
    }
}

/// An eval/train split loaded from artifacts.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// [N, H, W, C]
    pub x: Tensor,
    pub y: Vec<i32>,
}

impl Dataset {
    pub fn load(artifacts: &Path, dataset: &str, split: &str) -> Result<Dataset> {
        let dir = artifacts.join("data");
        let x = npy::read(dir.join(format!("{dataset}_{split}_x.npy")))?;
        let y = npy::read(dir.join(format!("{dataset}_{split}_y.npy")))?;
        if x.shape.len() != 4 || y.shape.len() != 1 || x.shape[0] != y.shape[0] {
            bail!("dataset {dataset}/{split}: bad shapes {:?} / {:?}", x.shape, y.shape);
        }
        Ok(Dataset { x: Tensor::new(x.shape.clone(), x.to_f32()?)?, y: y.to_i32()? })
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Copy one image [H, W, C].
    pub fn image(&self, i: usize) -> Tensor {
        let s = self.x.shape();
        let (h, w, c) = (s[1], s[2], s[3]);
        let stride = h * w * c;
        Tensor::new(
            vec![h, w, c],
            self.x.data()[i * stride..(i + 1) * stride].to_vec(),
        )
        .unwrap()
    }

    /// Copy a contiguous batch [B, H, W, C] starting at `start`.
    pub fn batch(&self, start: usize, b: usize) -> Tensor {
        let s = self.x.shape();
        let (h, w, c) = (s[1], s[2], s[3]);
        let stride = h * w * c;
        Tensor::new(
            vec![b, h, w, c],
            self.x.data()[start * stride..(start + b) * stride].to_vec(),
        )
        .unwrap()
    }
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: json::Value,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(artifacts: &Path) -> Result<Manifest> {
        let path = artifacts.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = json::parse(&text).map_err(|e| anyhow::anyhow!("manifest.json: {e}"))?;
        Ok(Manifest { root, dir: artifacts.to_path_buf() })
    }

    pub fn artifact(&self, name: &str) -> &json::Value {
        self.root.get("artifacts").get(name)
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.root
            .get("artifacts")
            .as_obj()
            .map(|o| o.keys().cloned().collect())
            .unwrap_or_default()
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        let f = self
            .artifact(name)
            .get("file")
            .as_str()
            .with_context(|| format!("artifact {name} not in manifest"))?;
        Ok(self.dir.join(f))
    }

    /// Baseline metric recorded at train time (e.g. "lenet_test_acc").
    pub fn metric(&self, key: &str) -> Option<f64> {
        self.root.get("metrics").get(key).as_f64()
    }
}

/// Default artifacts directory: $QSQ_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("QSQ_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Filesystem-dependent tests live in tests/ (integration); here only the
    // pure helpers.
    #[test]
    fn artifacts_dir_env_override() {
        std::env::set_var("QSQ_ARTIFACTS", "/tmp/somewhere");
        assert_eq!(artifacts_dir(), PathBuf::from("/tmp/somewhere"));
        std::env::remove_var("QSQ_ARTIFACTS");
        assert_eq!(artifacts_dir(), PathBuf::from("artifacts"));
    }
}
