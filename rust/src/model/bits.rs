//! Eq.-11/12 bit accounting — the arithmetic behind Figs. 9/10 and the
//! "82.49 % memory savings" headline.

use crate::model::meta::ModelMeta;
use crate::quant::codes::code_bits;
use crate::quant::vectorize::Grouping;

pub const FPB: u32 = 32;

/// Eq. 11: full-precision bits of one tensor.
pub fn nbits_full(numel: usize) -> u64 {
    numel as u64 * FPB as u64
}

/// Eq. 12: encoded bits of one tensor (codes + one fp scalar per group).
pub fn nbits_encoded(numel: usize, group: usize, phi: u32) -> u64 {
    let groups = (numel / group) as u64;
    numel as u64 * code_bits(phi) as u64 + groups * FPB as u64
}

/// Whole-model accounting at a nominal vector length N (per-tensor resolved
/// via nearest divisor, as the paper's sweeps do).
#[derive(Clone, Copy, Debug)]
pub struct ModelBits {
    pub full_bits: u64,
    pub encoded_bits: u64,
}

impl ModelBits {
    pub fn savings(&self) -> f64 {
        1.0 - self.encoded_bits as f64 / self.full_bits as f64
    }
}

/// Account the quantized tensors of `meta` at (phi, nominal N); unquantized
/// tensors (biases, head) are carried at full precision in both columns.
pub fn model_bits(meta: &ModelMeta, phi: u32, nominal_n: usize) -> ModelBits {
    let mut full = 0u64;
    let mut enc = 0u64;
    for t in &meta.tensors {
        let bits_full = nbits_full(t.numel());
        full += bits_full;
        if t.quantized {
            let g = Grouping::nearest_divisor(&t.shape, nominal_n).unwrap_or(1);
            enc += nbits_encoded(t.numel(), g, phi);
        } else {
            enc += bits_full;
        }
    }
    ModelBits { full_bits: full, encoded_bits: enc }
}

/// Savings over only the quantized tensors (the paper reports per-parameter
/// compression of the encoded filters; the fp32 head dilutes whole-model
/// numbers for tiny nets like LeNet).
pub fn quantized_only_bits(meta: &ModelMeta, phi: u32, nominal_n: usize) -> ModelBits {
    let mut full = 0u64;
    let mut enc = 0u64;
    for t in meta.quantized_tensors() {
        full += nbits_full(t.numel());
        let g = Grouping::nearest_divisor(&t.shape, nominal_n).unwrap_or(1);
        enc += nbits_encoded(t.numel(), g, phi);
    }
    ModelBits { full_bits: full, encoded_bits: enc }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq11_eq12_exact() {
        assert_eq!(nbits_full(2400), 2400 * 32);
        // LeNet c2w at channel-wise N=6, phi=4: 3 bits/code + 400 scalars
        assert_eq!(nbits_encoded(2400, 6, 4), 2400 * 3 + 400 * 32);
        // ternary at 2 bits
        assert_eq!(nbits_encoded(2400, 6, 1), 2400 * 2 + 400 * 32);
    }

    #[test]
    fn lenet_headline_savings() {
        // The paper's headline: "parameters of LeNet reduced upto 82.4919 %".
        // Quantized-tensor savings at phi=4, N=16 land in that band.
        let meta = ModelMeta::lenet();
        let b = quantized_only_bits(&meta, 4, 16);
        assert!(
            b.savings() > 0.80 && b.savings() < 0.86,
            "savings {:.4} not in the paper's band",
            b.savings()
        );
    }

    #[test]
    fn savings_increase_with_n() {
        let meta = ModelMeta::convnet();
        let mut last = 0.0;
        for n in [2usize, 4, 8, 16, 32, 64] {
            let s = quantized_only_bits(&meta, 4, n).savings();
            assert!(s >= last, "N={n}: {s} < {last}");
            last = s;
        }
    }

    #[test]
    fn ternary_saves_more_than_3bit() {
        let meta = ModelMeta::convnet();
        let s2 = quantized_only_bits(&meta, 1, 16).savings();
        let s3 = quantized_only_bits(&meta, 4, 16).savings();
        assert!(s2 > s3);
        // ... but only slightly (the paper's Fig.-10 argument)
        assert!(s2 - s3 < 0.05);
    }

    #[test]
    fn whole_model_less_than_quantized_only() {
        let meta = ModelMeta::lenet();
        let w = model_bits(&meta, 4, 16).savings();
        let q = quantized_only_bits(&meta, 4, 16).savings();
        assert!(w < q); // fp32 head dilutes
        assert!(w > 0.5); // but still majority savings
    }
}
