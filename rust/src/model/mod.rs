//! Model metadata (LeNet-5, ConvNet-4), the weight store backed by the AOT
//! artifacts, and the eq.-11/12 bit accounting behind Figs. 9/10.

pub mod bits;
pub mod meta;
pub mod store;

pub use meta::{ModelKind, ModelMeta, TensorMeta};
pub use store::WeightStore;
