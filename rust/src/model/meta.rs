//! Static architecture metadata — mirrors `python/compile/model.py`
//! (LENET_SHAPES / CONVNET_SHAPES); the integration tests cross-check this
//! against `artifacts/manifest.json` so the two can never drift silently.

use anyhow::{bail, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelKind {
    Lenet,
    Convnet,
}

impl ModelKind {
    pub fn name(self) -> &'static str {
        match self {
            ModelKind::Lenet => "lenet",
            ModelKind::Convnet => "convnet",
        }
    }

    pub fn from_name(s: &str) -> Result<ModelKind> {
        Ok(match s {
            "lenet" => ModelKind::Lenet,
            "convnet" => ModelKind::Convnet,
            other => bail!("unknown model {other:?}"),
        })
    }

    pub fn dataset(self) -> &'static str {
        match self {
            ModelKind::Lenet => "mnist",
            ModelKind::Convnet => "cifar",
        }
    }

    /// Input image shape (H, W, C).
    pub fn input_hwc(self) -> (usize, usize, usize) {
        match self {
            ModelKind::Lenet => (28, 28, 1),
            ModelKind::Convnet => (32, 32, 3),
        }
    }
}

/// One parameter tensor.
#[derive(Clone, Debug)]
pub struct TensorMeta {
    pub name: &'static str,
    pub shape: Vec<usize>,
    /// Included in the QSQ pipeline (heads/biases stay fp32 — DESIGN.md §6).
    pub quantized: bool,
}

impl TensorMeta {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Full model description.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub kind: ModelKind,
    pub tensors: Vec<TensorMeta>,
}

impl ModelMeta {
    pub fn lenet() -> ModelMeta {
        let t = |name, shape: &[usize], q| TensorMeta { name, shape: shape.to_vec(), quantized: q };
        ModelMeta {
            kind: ModelKind::Lenet,
            tensors: vec![
                t("c1w", &[5, 5, 1, 6], true),
                t("c1b", &[6], false),
                t("c2w", &[5, 5, 6, 16], true),
                t("c2b", &[16], false),
                t("f1w", &[256, 120], true),
                t("f1b", &[120], false),
                t("f2w", &[120, 84], true),
                t("f2b", &[84], false),
                t("f3w", &[84, 10], false),
                t("f3b", &[10], false),
            ],
        }
    }

    pub fn convnet() -> ModelMeta {
        let t = |name, shape: &[usize], q| TensorMeta { name, shape: shape.to_vec(), quantized: q };
        ModelMeta {
            kind: ModelKind::Convnet,
            tensors: vec![
                t("k1", &[3, 3, 3, 32], true),
                t("b1", &[32], false),
                t("k2", &[3, 3, 32, 32], true),
                t("b2", &[32], false),
                t("k3", &[3, 3, 32, 64], true),
                t("b3", &[64], false),
                t("k4", &[3, 3, 64, 64], true),
                t("b4", &[64], false),
                t("fcw", &[256, 10], false),
                t("fcb", &[10], false),
            ],
        }
    }

    pub fn of(kind: ModelKind) -> ModelMeta {
        match kind {
            ModelKind::Lenet => ModelMeta::lenet(),
            ModelKind::Convnet => ModelMeta::convnet(),
        }
    }

    pub fn tensor(&self, name: &str) -> Option<&TensorMeta> {
        self.tensors.iter().find(|t| t.name == name)
    }

    pub fn quantized_tensors(&self) -> impl Iterator<Item = &TensorMeta> {
        self.tensors.iter().filter(|t| t.quantized)
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    /// MACs of one forward pass (conv as im2col matmul + dense layers).
    pub fn macs_per_image(&self) -> u64 {
        match self.kind {
            ModelKind::Lenet => {
                // conv1 24*24*150_col? -> out 24x24x6, K=25
                let c1 = 24 * 24 * 6 * 25u64;
                let c2 = 8 * 8 * 16 * 150u64;
                let f = (256 * 120 + 120 * 84 + 84 * 10) as u64;
                c1 + c2 + f
            }
            ModelKind::Convnet => {
                let c1 = 32 * 32 * 32 * 27u64;
                let c2 = 16 * 16 * 32 * 288u64;
                let c3 = 8 * 8 * 64 * 288u64;
                let c4 = 4 * 4 * 64 * 576u64;
                c1 + c2 + c3 + c4 + 256 * 10
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet_param_count() {
        // 150+6 + 2400+16 + 30720+120 + 10080+84 + 840+10 = 44426
        assert_eq!(ModelMeta::lenet().total_params(), 44426);
    }

    #[test]
    fn convnet_param_count() {
        let m = ModelMeta::convnet();
        let want = 3 * 3 * 3 * 32 + 32 + 3 * 3 * 32 * 32 + 32 + 3 * 3 * 32 * 64 + 64
            + 3 * 3 * 64 * 64 + 64 + 256 * 10 + 10;
        assert_eq!(m.total_params(), want);
    }

    #[test]
    fn quantized_set_matches_python() {
        let l = ModelMeta::lenet();
        let q: Vec<&str> = l.quantized_tensors().map(|t| t.name).collect();
        assert_eq!(q, vec!["c1w", "c2w", "f1w", "f2w"]);
        let c = ModelMeta::convnet();
        let q: Vec<&str> = c.quantized_tensors().map(|t| t.name).collect();
        assert_eq!(q, vec!["k1", "k2", "k3", "k4"]);
    }

    #[test]
    fn kind_roundtrip() {
        assert_eq!(ModelKind::from_name("lenet").unwrap(), ModelKind::Lenet);
        assert!(ModelKind::from_name("vgg").is_err());
    }

    #[test]
    fn macs_positive_and_ordered() {
        assert!(ModelMeta::convnet().macs_per_image() > ModelMeta::lenet().macs_per_image());
    }
}
