//! Mini property-testing framework (proptest is not in the offline crate
//! set).  No shrinking; failures report the seed + case index so any case is
//! replayable with `QSQ_PROP_SEED`.
//!
//! ```ignore
//! forall(200, |r| gen_weights(r), |w| {
//!     check(roundtrip(w) == *w, "roundtrip mismatch")
//! });
//! ```

use crate::util::rng::Rng;

pub type PropResult = Result<(), String>;

/// Run `check` against `iters` generated cases. Panics (test failure) on the
/// first violated property, printing the master seed and case index.
pub fn forall<T, G, F>(iters: u64, gen: G, check: F)
where
    T: std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    F: Fn(&T) -> PropResult,
{
    let seed = std::env::var("QSQ_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut master = Rng::new(seed);
    for case in 0..iters {
        let mut r = master.fork();
        let input = gen(&mut r);
        if let Err(msg) = check(&input) {
            panic!(
                "property failed (seed={seed}, case={case}): {msg}\ninput: {input:?}"
            );
        }
    }
}

/// Assertion helper for property bodies.
pub fn check(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Approximate float comparison helper.
pub fn check_close(a: f64, b: f64, tol: f64, msg: &str) -> PropResult {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{msg}: {a} vs {b} (tol {tol})"))
    }
}

/// Generate a vector of roughly-Gaussian f32 weights.
pub fn gen_weights(r: &mut Rng, n: usize, scale: f64) -> Vec<f32> {
    (0..n).map(|_| (r.normal() * scale) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        forall(
            50,
            |r| r.below(100),
            |_| {
                // cannot mutate captured count in Fn; use a cell
                Ok(())
            },
        );
        count += 50;
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(10, |r| r.below(10), |&x| check(x < 5, "x too big"));
    }

    #[test]
    fn check_close_tolerates() {
        assert!(check_close(1.0, 1.0 + 1e-9, 1e-6, "x").is_ok());
        assert!(check_close(1.0, 2.0, 1e-6, "x").is_err());
    }
}
