//! Leveled stderr logger with a process-global level (no `log` crate offline).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level_from_env() {
    if let Ok(v) = std::env::var("QSQ_LOG") {
        let l = match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            _ => Level::Info,
        };
        set_level(l);
    }
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, module: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0);
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    eprintln!("[{t:.3} {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnlog {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debuglog {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
