//! Small statistics helpers: moments, percentiles, online accumulation.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population (MLE, ddof=0) standard deviation — matches numpy's default and
/// the paper's eq. 7.
pub fn std_mle(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Online mean/min/max/count accumulator (Welford for variance).
#[derive(Clone, Debug, Default)]
pub struct Online {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Online {
    pub fn new() -> Self {
        Online { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn var(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        // numpy: np.std([1,2,3,4]) = 1.118033988749895
        assert!((std_mle(&xs) - 1.118033988749895).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.std() - std_mle(&xs)).abs() < 1e-12);
        assert_eq!(o.min, 1.0);
        assert_eq!(o.max, 9.0);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_mle(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
